"""Cluster co-scheduling demo: three pipelines share one core pool, one
event loop, one joint solver.

Builds a 3-pipeline cluster with anti-correlated bursty traces (each
pipeline spikes while the others idle) and replays it under the joint
knapsack arbitration (``ipa``) and the proportional static split
(``split_ipa``) at the same total core budget — the joint policy moves
cores to whichever pipeline's burst buys the most accuracy per core.

  PYTHONPATH=src python examples/cluster.py
"""
import sys
import os

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))

from bench_cluster import OBJ, anti_correlated_traces, make_cluster, \
    pick_budget  # noqa: E402
from repro.core import adapter as AD  # noqa: E402
from repro.core.cluster import ClusterModel  # noqa: E402


def main() -> None:
    seconds, n_pipes = 180, 3
    cluster0 = make_cluster(n_pipes)
    rates = anti_correlated_traces(seconds, n_pipes)
    budget = pick_budget(cluster0, rates)
    cluster = ClusterModel(cluster0.name, cluster0.pipelines, float(budget))
    names = [p.name for p in cluster.pipelines]
    print(f"cluster of {n_pipes} pipelines ({', '.join(names)}), "
          f"C={budget} shared cores, {seconds}s anti-correlated traces\n")

    header = f"{'policy':12s} {'mean PAS':>9s} {'cost':>7s} {'dropped':>8s}  per-pipeline PAS"
    print(header)
    for pol in ("ipa", "split_ipa"):
        res = AD.run_cluster_trace(cluster, rates, policy=pol, obj=OBJ,
                                   seed=7)
        per = " ".join(f"{name}={r.mean_pas:.1f}"
                       for name, r in zip(names, res.per_pipeline))
        print(f"{pol:12s} {res.mean_pas:9.2f} {res.mean_cost:7.1f} "
              f"{res.dropped:8d}  {per}")
    print("\n'ipa' arbitrates one Pareto frontier point per pipeline under"
          "\nsum(cost) <= C; 'split_ipa' locks each pipeline into its"
          "\ndemand-proportional share of C and plans alone inside it.")


if __name__ == "__main__":
    main()
