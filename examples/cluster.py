"""Cluster co-scheduling demo: three pipelines share one core pool, one
event loop, one joint solver.

Builds a 3-pipeline cluster with anti-correlated bursty traces (each
pipeline spikes while the others idle) and replays it under the joint
knapsack arbitration (``ipa``) and the proportional static split
(``split_ipa``) at the same total core budget — the joint policy moves
cores to whichever pipeline's burst buys the most accuracy per core.

Then it demonstrates the switch-cost / SLA-weight knobs of the joint
solver (``optimizer.solve_cluster`` via ``adapter.run_cluster_trace``):

* ``adaptation_delay`` — the §5.3 transition: a reconfigured pipeline
  keeps serving its old config for ~8 s before the new one takes effect,
  so interval PAS/cost records become realized time-weighted values.
  The arbitration is transition-overlap-aware: through the window a
  changed pipeline is budgeted (solver) and charged (ledger) at
  max(old, new) cores, so a downsizer's freed cores only become
  grantable once its window closes — serving capacity never exceeds C.
* ``switch_cost`` — hysteresis: every config change is charged this much
  objective in the knapsack, and the held (incumbent) config competes
  penalty-free, so a challenger must beat it by more than the transition
  cost.  Sized at the cost-term churn scale it suppresses PAS-neutral
  replica-shuffling thrash without blocking accuracy-driven switches.
* ``switch_budget`` — a hard cap on how many pipelines may change per
  10 s adaptation interval.
* ``sla_weights`` — INFaaS-style workload importance: a pipeline with
  weight w counts w-fold in the arbitration objective, so under
  contention the heavy pipeline's accuracy is sacrificed last.  Weights
  can also live on the ``ClusterModel`` itself (``sla_weights=...``).

  PYTHONPATH=src python examples/cluster.py
"""
import sys
import os

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))

from bench_cluster import ADAPT_DELAY_S, OBJ, SWITCH_COST, \
    anti_correlated_traces, make_cluster, pick_budget  # noqa: E402
from repro.core import adapter as AD  # noqa: E402
from repro.core.cluster import ClusterModel  # noqa: E402


def main() -> None:
    seconds, n_pipes = 180, 3
    cluster0 = make_cluster(n_pipes)
    rates = anti_correlated_traces(seconds, n_pipes)
    budget = pick_budget(cluster0, rates)
    cluster = ClusterModel(cluster0.name, cluster0.pipelines, float(budget))
    names = [p.name for p in cluster.pipelines]
    print(f"cluster of {n_pipes} pipelines ({', '.join(names)}), "
          f"C={budget} shared cores, {seconds}s anti-correlated traces\n")

    header = f"{'policy':22s} {'mean PAS':>9s} {'cost':>7s} {'dropped':>8s} {'reconf':>7s}  per-pipeline PAS"
    print(header)

    def show(tag, **kw):
        res = AD.run_cluster_trace(cluster, rates, obj=OBJ, seed=7, **kw)
        per = " ".join(f"{name}={r.mean_pas:.1f}"
                       for name, r in zip(names, res.per_pipeline))
        print(f"{tag:22s} {res.mean_pas:9.2f} {res.mean_cost:7.1f} "
              f"{res.dropped:8d} {res.n_reconfigs:7d}  {per}")

    show("ipa", policy="ipa")
    show("split_ipa", policy="split_ipa")
    # §5.3 transition modeled: each change serves the old config for ~8 s;
    # switch-cost hysteresis then suppresses PAS-neutral thrash
    show("ipa+adapt", policy="ipa", adaptation_delay=ADAPT_DELAY_S)
    show("ipa+adapt+hyst", policy="ipa", adaptation_delay=ADAPT_DELAY_S,
         switch_cost=SWITCH_COST)
    # reconfiguration budget: at most two pipelines may change per
    # interval (under a binding core budget a reallocation needs a donor
    # AND a receiver, so a budget of 1 would freeze arbitration entirely)
    show("ipa+switch_budget=2", policy="ipa",
         adaptation_delay=ADAPT_DELAY_S, switch_cost=SWITCH_COST,
         switch_budget=2)
    # SLA weighting: the first pipeline's accuracy counts 4x in the
    # knapsack, so under contention cores migrate toward it
    show(f"ipa w={names[0]}:4x", policy="ipa",
         sla_weights=(4.0,) + (1.0,) * (n_pipes - 1))

    print("\n'ipa' arbitrates one Pareto frontier point per pipeline under"
          "\nsum(cost) <= C; 'split_ipa' locks each pipeline into its"
          "\ndemand-proportional share of C and plans alone inside it."
          "\n'+adapt' models the 8 s §5.3 transition (realized PAS),"
          "\n'+hyst' charges each change switch_cost in the knapsack so"
          "\nthe incumbent wins ties, 'switch_budget' caps changes per"
          "\ninterval, and 'sla_weights' biases arbitration toward the"
          "\nweighted pipeline.")


if __name__ == "__main__":
    main()
