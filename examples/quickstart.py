"""Quickstart: IPA on the paper's video pipeline in ~a minute.

Builds the two-stage video pipeline (YOLO family -> ResNet family) from the
paper's appendix profiles, solves the Eq.-10 Integer Program at a few loads,
and runs the full online adaptation loop against a bursty Twitter-style
trace, comparing IPA with the FA2/RIM baselines.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import adapter as AD
from repro.core import baselines as BL
from repro.core import optimizer as OPT
from repro.core import paper_profiles as PP
from repro.core import trace as TR


def main() -> None:
    pipe = PP.video()
    print(f"pipeline: {pipe.name}   SLA_P = {pipe.sla:.2f}s")
    for st in pipe.stages:
        print(f"  stage {st.name}: "
              + ", ".join(f"{v.name}(acc={v.accuracy}, R={v.base_alloc})"
                          for v in st.variants))

    obj = OPT.Objective(**PP.PAPER_WEIGHTS["video"], metric="pas")
    print("\n-- one-shot decisions (Eq. 10) --")
    for lam in (5.0, 20.0, 40.0):
        sol = BL.ipa(pipe, lam, obj=obj)
        cfg = [(s.variant, s.batch, s.replicas) for s in sol.config.stages]
        print(f"lambda={lam:5.1f} rps -> {cfg}  PAS={sol.pas:.1f} "
              f"cost={sol.cost:.0f} cores  ({sol.solve_time*1e3:.0f} ms)")

    print("\n-- online adaptation on a bursty trace (Figs. 8-12) --")
    rates = TR.excerpt("bursty", seconds=180)
    for pol in ("ipa", "fa2_low", "fa2_high", "rim"):
        res = AD.run_trace(pipe, rates, policy=pol, obj=obj, seed=0)
        s = res.summary()
        print(f"{pol:9s} PAS={s['mean_pas']:6.2f} cost={s['mean_cost']:6.1f} "
              f"viol={s['sla_violation_rate']:.3f} drops={s['dropped']}")


if __name__ == "__main__":
    main()
