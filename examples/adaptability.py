"""Fig.-14 style adaptability demo: steer the accuracy/cost trade-off with
alpha and beta across all five paper pipelines.

  PYTHONPATH=src python examples/adaptability.py
"""
from repro.core import optimizer as OPT
from repro.core import paper_profiles as PP


def main() -> None:
    lam = 15.0
    print(f"{'pipeline':12s} {'preference':16s} {'PAS':>7s} {'cost':>6s}")
    for pname, fn in PP.PIPELINES.items():
        pipe = fn()
        for alpha, beta, tag in ((0.2, 2.0, "resource-prior"),
                                 (2.0, 1.0, "balanced"),
                                 (50.0, 0.2, "accuracy-prior")):
            sol = OPT.solve_enum(pipe, lam,
                                 OPT.Objective(alpha=alpha, beta=beta))
            if sol.feasible:
                print(f"{pname:12s} {tag:16s} {sol.pas:7.2f} {sol.cost:6.0f}")
            else:
                print(f"{pname:12s} {tag:16s} infeasible at lambda={lam}")


if __name__ == "__main__":
    main()
