"""Train a ~100M-class model for a few hundred steps (deliverable b).

Trains the reduced starcoder2 variant on the synthetic Markov stream with
AdamW + cosine schedule, checkpoints it, and reloads the checkpoint to show
the loss is preserved.  (Variant families for serving are produced exactly
like this — train small/medium/large, measure accuracy, hand to IPA.)

  PYTHONPATH=src python examples/train_variant.py [--steps 300]
"""
import argparse
import os
import tempfile

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import model as M
from repro.training import checkpoint, data, optim
from repro.training.train import loss_fn, train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="starcoder2-3b")
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, reduced=True)
    print(f"training reduced {args.arch}: {cfg.n_layers}L d={cfg.d_model} "
          f"({cfg.n_params()/1e6:.1f}M params)")
    stream = data.SyntheticStream(cfg, data.DataConfig(seq_len=128,
                                                       batch_size=8))
    ocfg = optim.AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    params, _, hist = train_loop(cfg, stream, steps=args.steps, ocfg=ocfg,
                                 log_every=max(args.steps // 10, 1))
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

    path = os.path.join(tempfile.mkdtemp(), "variant.npz")
    checkpoint.save(path, params)
    restored = checkpoint.load(path, jax.eval_shape(lambda: params))
    batch = {k: jnp.asarray(v) for k, v in stream.batch(10_000).items()}
    l1, _ = loss_fn(params, cfg, batch, impl="naive")
    l2, _ = loss_fn(restored, cfg, batch, impl="naive")
    print(f"checkpoint roundtrip: loss {float(l1):.4f} == {float(l2):.4f}")
    assert abs(float(l1) - float(l2)) < 1e-5
    print("saved variant to", path)


if __name__ == "__main__":
    main()
