"""End-to-end driver (deliverable b): serve a small real model pipeline with
batched requests under IPA control.

Two assigned architectures (phi-3-vision -> yi-34b reduced families) form a
video-monitoring-style pipeline on the REAL JAX engine: the profiler measures
each variant's prefill+decode latency on this machine, Eq. 1 computes base
allocations, and the IPA adapter replays a workload excerpt, switching
variants/batches/replicas online.  Finally the chosen config serves actual
batched token requests through both stages.

  PYTHONPATH=src python examples/serve_pipeline.py
"""
import numpy as np

from repro.core import adapter as AD
from repro.core import optimizer as OPT
from repro.core import trace as TR
from repro.launch.serve import build_pipeline


def main() -> None:
    pipe, engine = build_pipeline("vlm-classify", gen_tokens=2,
                                  profile_batches=(1, 2), th=0.5)
    print(f"profiled pipeline SLA_P = {pipe.sla:.2f}s")
    for st in pipe.stages:
        for v in st.variants:
            print(f"  {st.name}/{v.name}: l(1)={v.latency(1)*1e3:.0f}ms "
                  f"R={v.base_alloc} acc={v.accuracy}")

    rates = TR.excerpt("fluctuating", seconds=60) * 0.1  # laptop-scale RPS
    obj = OPT.Objective(alpha=10.0, beta=0.5, metric="pas")
    res = AD.run_trace(pipe, rates, policy="ipa", obj=obj, seed=0)
    print("adaptation summary:", res.summary())

    # apply the final decision to the real engine and serve a batch
    final = res.intervals[-1]
    print(f"final interval: PAS={final.pas:.2f} cost={final.cost:.0f}")
    prompts = np.random.default_rng(0).integers(0, 400, (4, 12)).astype(np.int32)
    out, lats = engine.serve(prompts)
    print(f"served batch of 4 through 2 stages -> output tokens {out.shape}, "
          f"stage latencies {[f'{l*1e3:.0f}ms' for l in lats]}, "
          f"engine PAS={engine.pas:.2f}")


if __name__ == "__main__":
    main()
