#!/usr/bin/env python
"""Bench-regression gate: the checked-in BENCH artifacts can only ratchet.

Compares the throughput and solve-wall fields of ``BENCH_sim.json`` and
``BENCH_scale.json`` against the recorded baselines in
``scripts/bench_baselines/`` and fails on any >20% regression — an ev/s
or speedup field dropping, or a solver-wall field rising, past the
tolerance.  Wired into ``scripts/tier1.sh``, where it is a pure JSON
diff (milliseconds): day-to-day the artifacts equal the baselines and
the gate is a no-op; the moment a PR regenerates a BENCH file with worse
numbers, tier-1 fails loudly and the author either fixes the regression
or consciously re-records the baseline with ``--update`` (and defends
the change in review).  Live perf floors are the benches' own smoke
gates; this gate pins the *recorded evidence* so it cannot drift
backwards silently.

Usage:
    python scripts/check_bench.py            # gate (tier-1 mode)
    python scripts/check_bench.py --update   # re-record baselines
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
BASELINE_DIR = os.path.join(REPO, "scripts", "bench_baselines")
TOLERANCE = 0.20

# (dotted path, direction): "up" = higher is better (throughput), "down" =
# lower is better (solve wall).  A "*" component fans out over every key
# at that level, so new policies/cores are gated automatically.
SPECS = {
    "BENCH_sim.json": (
        ("core.speedup", "up"),
        ("core.new.events_per_sec", "up"),
        ("policies.*.events_per_sec", "up"),
        ("policies.*.solver_wall_s", "down"),
    ),
    "BENCH_scale.json": (
        ("simulator.heap.evps", "up"),
        ("simulator.struct.evps", "up"),
        ("simulator.round.evps", "up"),
        ("simulator.speedup", "up"),
        ("simulator.round_speedup", "up"),
        ("solver.max_solve_s", "down"),
        ("adapter.*.solver_wall_s", "down"),
    ),
}


def _resolve(obj, parts):
    """Expand a dotted path with ``*`` fan-out into (path, value) leaves."""
    if not parts:
        return [("", obj)]
    head, rest = parts[0], parts[1:]
    if head == "*":
        if not isinstance(obj, dict):
            return []
        out = []
        for k in obj:
            out.extend((f"{k}.{p}".rstrip("."), v)
                       for p, v in _resolve(obj[k], rest))
        return out
    if not isinstance(obj, dict) or head not in obj:
        return []
    return [(f"{head}.{p}".rstrip("."), v)
            for p, v in _resolve(obj[head], rest)]


def check_file(name: str, specs, tolerance: float) -> list:
    cand_path = os.path.join(REPO, name)
    base_path = os.path.join(BASELINE_DIR, name)
    for p in (cand_path, base_path):
        if not os.path.exists(p):
            return [f"{name}: missing {p} (run the full bench, then "
                    f"`check_bench.py --update` to record the baseline)"]
    with open(cand_path) as f:
        cand = json.load(f)
    with open(base_path) as f:
        base = json.load(f)
    fails = []
    for path, direction in specs:
        parts = path.split(".")
        base_leaves = dict(_resolve(base, parts))
        cand_leaves = dict(_resolve(cand, parts))
        if not base_leaves:
            fails.append(f"{name}: baseline lacks `{path}` — re-record "
                         f"with --update")
            continue
        for leaf, bval in base_leaves.items():
            cval = cand_leaves.get(leaf)
            if cval is None:
                fails.append(f"{name}: `{leaf}` present in baseline but "
                             f"missing from the candidate")
                continue
            bval, cval = float(bval), float(cval)
            if direction == "up":
                floor = bval * (1.0 - tolerance)
                if cval < floor:
                    fails.append(
                        f"{name}: `{leaf}` regressed {bval:g} -> {cval:g} "
                        f"(> {tolerance:.0%} drop)")
            else:
                ceil = bval * (1.0 + tolerance)
                if cval > ceil:
                    fails.append(
                        f"{name}: `{leaf}` regressed {bval:g} -> {cval:g} "
                        f"(> {tolerance:.0%} rise)")
    return fails


def update_baselines() -> int:
    os.makedirs(BASELINE_DIR, exist_ok=True)
    for name in SPECS:
        src = os.path.join(REPO, name)
        if not os.path.exists(src):
            print(f"check_bench: cannot record {name}: not present "
                  f"(run the full bench first)")
            return 1
        shutil.copyfile(src, os.path.join(BASELINE_DIR, name))
        print(f"check_bench: recorded {name} -> scripts/bench_baselines/")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--update", action="store_true",
                    help="re-record the baselines from the current BENCH "
                         "artifacts (after a deliberate perf change)")
    ap.add_argument("--tolerance", type=float, default=TOLERANCE,
                    help="allowed relative regression (default 0.20)")
    args = ap.parse_args()
    if args.update:
        return update_baselines()
    fails = []
    for name, specs in SPECS.items():
        fails.extend(check_file(name, specs, args.tolerance))
    for msg in fails:
        print(f"check_bench: REGRESSION {msg}")
    if not fails:
        print("check_bench: BENCH_sim.json + BENCH_scale.json within "
              f"{args.tolerance:.0%} of recorded baselines")
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
