#!/usr/bin/env bash
# Doc-link checker: fail when README.md / docs/ARCHITECTURE.md reference a
# file, module or symbol that no longer exists, so the docs cannot rot
# silently.  Three checkable reference conventions (all backtick-quoted):
#   `file.ext` / `dir/file.ext` -> the file must exist in the repo
#                                  (repo-root-relative, e.g. `BENCH_sim.json`)
#   `repro.mod.sub`      -> src/repro/mod/sub.py (or package dir) must exist;
#                           a trailing non-module component must be a
#                           def/class/assignment in the parent module
#   `symbol()`           -> a `def symbol(` must exist under src/ benchmarks/
set -euo pipefail
cd "$(dirname "$0")/.."
for f in README.md docs/ARCHITECTURE.md; do
  [[ -f "$f" ]] || { echo "doc-link: missing doc: $f"; exit 1; }
done
fails=0
while IFS= read -r t; do
  if [[ "$t" =~ \.(py|sh|md|json|toml)$ ]]; then
    [[ -e "$t" ]] || { echo "doc-link: missing file: $t"; fails=1; }
  elif [[ "$t" =~ ^repro(\.[A-Za-z_][A-Za-z0-9_]*)+$ ]]; then
    p="src/${t//.//}"
    if [[ ! -e "$p.py" && ! -d "$p" ]]; then
      mod="src/$(dirname "${t//.//}").py" sym="${t##*.}"
      grep -qE "(def|class) ${sym}\b|^${sym} *=" "$mod" 2>/dev/null \
        || { echo "doc-link: missing symbol: $t"; fails=1; }
    fi
  elif [[ "$t" =~ ^[A-Za-z_][A-Za-z0-9_]*\(\)$ ]]; then
    grep -rqE "def ${t%()}\(" src benchmarks scripts \
      || { echo "doc-link: missing function: $t"; fails=1; }
  fi
done < <(grep -ho '`[^`]*`' README.md docs/ARCHITECTURE.md | tr -d '`' | sort -u)
[[ "$fails" == 0 ]] && echo "doc-link: README.md + docs/ARCHITECTURE.md OK"
exit "$fails"
