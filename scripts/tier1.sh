#!/usr/bin/env bash
# Tier-1 gate: fast test loop + simulator perf smoke.
# Fails loudly on test regressions AND on event-driven-core perf regressions.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q
python benchmarks/bench_simulator.py --smoke
