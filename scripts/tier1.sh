#!/usr/bin/env bash
# Tier-1 gate: fast test loop + simulator perf smoke + cluster-arbitration
# smoke.  Fails loudly on test regressions, on event-driven-core perf
# regressions, and on the joint knapsack losing to the proportional
# static split (which its feasible-set superset makes impossible unless
# the arbitration layer is broken).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q
python benchmarks/bench_simulator.py --smoke
python benchmarks/bench_cluster.py --smoke
