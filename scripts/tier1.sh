#!/usr/bin/env bash
# Tier-1 gate: fast test loop + simulator perf smoke + cluster-arbitration
# smoke.  Fails loudly on test regressions, on event-driven-core perf
# regressions, on policy-trace throughput falling below the solver-in-
# the-loop floor (bench_simulator --smoke runs an ipa adaptation trace
# and gates events/sec — the vectorized-solver ratchet, alongside the
# core-speedup floor), on the joint knapsack losing to the proportional
# static split (which its feasible-set superset makes impossible unless
# the arbitration layer is broken), and on the switch scenario: with the
# §5.3 adaptation window modeled, the hysteresis run must reconfigure no
# more often than the no-hysteresis run at equal-or-better realized PAS
# (bench_cluster --smoke runs both gates, plus the transition-overlap
# invariant: serving cost <= C at every instant, plus the dag scenario:
# the video_fanout DAG plan must never lose to its linearized chain at
# the chain's own budget and must strictly win at some rate, with both
# event cores replaying each plan bit-identically), and on the production-
# scale scenario (bench_scale --smoke: 50 pipelines at C=512 — struct
# event core ev/s floor + speedup over the heapq core with identical
# metrics, and a per-solve wall ceiling on every solve_cluster planning
# mode), and on the sweep harness (sweep --smoke: a tiny grid must hash
# identically at nproc=1 and nproc=4, and on >=4-CPU hosts the 4-worker
# pass must clear a 2x speedup floor — skipped, never faked, below
# that), and on the hetero scenario (bench_cluster --smoke: joint
# multi-dimensional knapsack >= every per-class proportional split at
# every boundary, every solve under the 10 s decision ceiling including
# the wide scale probe, both event cores bit-identical).  Slow tests
# (LSTM training, jax decode loops) stay opt-in via `pytest -m slow`.
# The pytest step enforces the fast tier two ways: --enforce-fast fails
# any un-marked test slower than 2 s (tests/conftest.py), and
# scripts/check_tests.py ratchets the collected-test count against
# scripts/tier1_test_floor.txt so the suite can only grow — a module
# that silently stops collecting is a loud failure, not missing
# coverage.  scripts/check_bench.py pins the recorded bench evidence:
# the checked-in BENCH_sim.json / BENCH_scale.json throughput and
# solve-wall fields must stay within 20% of the recorded baselines
# (scripts/bench_baselines/), so a PR cannot silently regenerate the
# artifacts with worse numbers — deliberate changes re-record with
# --update.  The doc-link checker fails if README.md /
# docs/ARCHITECTURE.md reference a file or symbol that no longer exists.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

JUNIT="$(mktemp /tmp/tier1_tests.XXXXXX.xml)"
trap 'rm -f "$JUNIT"' EXIT
python -m pytest -x -q --enforce-fast --junitxml="$JUNIT"
python scripts/check_tests.py "$JUNIT"
python benchmarks/bench_simulator.py --smoke
python benchmarks/bench_cluster.py --smoke
python benchmarks/bench_scale.py --smoke
python benchmarks/sweep.py --smoke
python scripts/check_bench.py
bash scripts/check_docs.sh
