#!/usr/bin/env python
"""Tier-1 suite-count ratchet: the fast tier may only grow.

Reads the junit XML that ``scripts/tier1.sh`` asks pytest to emit and
fails if the number of collected tier-1 test cases ever falls below the
recorded floor (``scripts/tier1_test_floor.txt``).  A silently
import-broken or accidentally deselected module shrinks the count long
before anyone notices missing coverage — this turns that into a loud
failure.  When the suite grows, the checker says so; bump the floor in
the same PR that adds the tests so the ratchet holds for the next one.

Usage: check_tests.py <junit-xml-path>
"""
from __future__ import annotations

import os
import sys
import xml.etree.ElementTree as ET

FLOOR_FILE = os.path.join(os.path.dirname(__file__), "tier1_test_floor.txt")


def main() -> int:
    if len(sys.argv) != 2:
        print(__doc__)
        return 2
    root = ET.parse(sys.argv[1]).getroot()
    suites = root.iter("testsuite")
    tests = errors = failures = 0
    for s in suites:
        tests += int(s.get("tests", 0))
        errors += int(s.get("errors", 0))
        failures += int(s.get("failures", 0))
    with open(FLOOR_FILE) as f:
        floor = int(f.read().strip())
    print(f"tier-1 suite: {tests} tests collected "
          f"(floor {floor}, errors {errors}, failures {failures})")
    if errors or failures:
        print("FAIL: tier-1 tests errored/failed (see pytest output)")
        return 1
    if tests < floor:
        print(f"FAIL: tier-1 suite shrank to {tests} < floor {floor} — a "
              "test module stopped collecting (import error, accidental "
              "mark, deleted file).  Restore it or justify lowering the "
              "floor explicitly.")
        return 1
    if tests > floor:
        print(f"note: suite grew past the floor ({tests} > {floor}); bump "
              f"{os.path.relpath(FLOOR_FILE)} in this PR")
    return 0


if __name__ == "__main__":
    sys.exit(main())
