"""Production mesh builders (functions, so importing never touches jax
device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2 pods x 256 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
