"""Production mesh builders (functions, so importing never touches jax
device state)."""
from __future__ import annotations

import jax


def mesh_axis_types(n: int):
    """``jax.sharding.AxisType`` appeared with explicit sharding in newer
    jax; on older releases meshes are implicitly Auto. Returns the
    ``axis_types`` kwarg value, or None when the installed jax predates it."""
    at = getattr(jax.sharding, "AxisType", None)
    return (at.Auto,) * n if at is not None else None


def make_mesh_compat(shape, axes):
    """jax.make_mesh across jax versions with/without axis_types."""
    at = mesh_axis_types(len(axes))
    if at is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=at)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2 pods x 256 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests)."""
    return make_mesh_compat((data, model), ("data", "model"))
