"""Distributed training launcher.

Real execution on whatever devices exist (CPU smoke: reduced configs); the
production meshes are exercised by ``dryrun.py``.  Uses the same sharding
rules as the dry-run so a run on real hardware only changes the mesh.

  PYTHONPATH=src python -m repro.launch.train --arch yi-34b --reduced \
      --steps 100 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.distributed import api as dapi
from repro.distributed import sharding as shd
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.training import checkpoint, data, optim
from repro.training.train import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCH_IDS))
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (full configs need a real cluster)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--save", default=None, help="npz checkpoint path")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, reduced=args.reduced)
    if not args.reduced:
        print("WARNING: full config on local devices — expect OOM; "
              "use the dry-run for production shapes.")
    mesh = make_local_mesh(args.data_par, args.model_par)
    dapi.set_axis_rules(shd.axis_rules(mesh))

    ocfg = optim.AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                             total_steps=args.steps)
    params = M.init(jax.random.PRNGKey(0), cfg)
    opt_state = optim.init_state(params)
    pspec = shd.param_specs(jax.eval_shape(lambda: params), mesh, fsdp=True)
    ospec = {"mu": pspec, "nu": pspec, "step": jax.sharding.PartitionSpec()}
    step_fn = make_train_step(cfg, ocfg, impl="naive")

    stream = data.SyntheticStream(
        cfg, data.DataConfig(seq_len=args.seq, batch_size=args.batch))
    with jax.set_mesh(mesh):
        jitted = jax.jit(step_fn, in_shardings=(pspec, ospec, None),
                         out_shardings=(pspec, ospec, None),
                         donate_argnums=(0, 1))
        it = iter(stream)
        t0 = time.time()
        for i in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            params, opt_state, m = jitted(params, opt_state, batch)
            if i % args.log_every == 0 or i == args.steps - 1:
                print(f"step {i:5d} loss={float(m['loss']):.4f} "
                      f"gnorm={float(m['grad_norm']):.3f} "
                      f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)
    if args.save:
        checkpoint.save(args.save, params)
        print("saved", args.save)
    dapi.set_axis_rules(None)


if __name__ == "__main__":
    main()
