"""Serving launcher: an IPA-managed pipeline on the real JAX engine.

Builds a pipeline from assigned-architecture variant families, profiles it
(paper §4.2) on this machine, then replays a workload excerpt with the IPA
adapter making variant/batch/replica decisions online.

  PYTHONPATH=src python -m repro.launch.serve --pipeline vlm-classify \
      --trace bursty --seconds 120
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro import configs
from repro.core import adapter as AD
from repro.core import optimizer as OPT
from repro.core import profiler as PF
from repro.core import trace as TR
from repro.core.pipeline import PipelineModel
from repro.serving.engine import PipelineEngine, StageServer

# pipelines over the assigned architectures (analogues of the paper's five)
ENGINE_PIPELINES = {
    # video-monitoring analogue: VLM "detector" -> dense classifier
    "vlm-classify": [("phi-3-vision-4.2b", 4), ("yi-34b", 4)],
    # audio-qa analogue: whisper ASR backbone -> code/QA dense model
    "asr-qa": [("whisper-medium", 4), ("starcoder2-3b", 4)],
    # nlp analogue: gemma3 -> qwen2-moe -> mamba2 chain
    "nlp-chain": [("gemma3-27b", 4), ("qwen2-moe-a2.7b", 4),
                  ("mamba2-2.7b", 4)],
}


def build_pipeline(name: str, *, gen_tokens: int = 4, profile_batches=(1, 2, 4),
                   th: float = 2.0, verbose: bool = True):
    """Returns (PipelineModel for the control plane, PipelineEngine)."""
    servers = []
    stages = []
    for arch, _ in ENGINE_PIPELINES[name]:
        fam = configs.get_variant_family(arch)
        srv = StageServer(arch, fam, gen_tokens=gen_tokens)
        if verbose:
            print(f"profiling stage {arch} ({len(fam)} variants)...",
                  flush=True)
        profs = PF.profile_stage_server(srv, batches=profile_batches)
        stage = PF.build_stage(arch, profs, th=th,
                               batch_choices=profile_batches,
                               max_batch=max(profile_batches))
        servers.append(srv)
        stages.append(stage)
    return PipelineModel(name, tuple(stages)), PipelineEngine(servers)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pipeline", default="vlm-classify",
                    choices=list(ENGINE_PIPELINES))
    ap.add_argument("--trace", default="bursty", choices=list(TR.EXCERPTS))
    ap.add_argument("--seconds", type=int, default=120)
    ap.add_argument("--policy", default="ipa",
                    choices=["ipa", "fa2_low", "fa2_high", "rim"])
    ap.add_argument("--alpha", type=float, default=10.0)
    ap.add_argument("--beta", type=float, default=0.5)
    ap.add_argument("--scale-rps", type=float, default=0.25,
                    help="scale the trace to this machine's capacity")
    args = ap.parse_args()

    pipe, engine = build_pipeline(args.pipeline)
    print(f"pipeline SLA_P = {pipe.sla:.2f}s")
    rates = TR.excerpt(args.trace, seconds=args.seconds) * args.scale_rps
    obj = OPT.Objective(alpha=args.alpha, beta=args.beta, metric="pas")
    res = AD.run_trace(pipe, rates, policy=args.policy, obj=obj)
    print(json.dumps(res.summary(), indent=1))

    # demonstrate the data plane actually serving under the chosen config
    last = res.intervals[-1]
    print(f"final interval PAS={last.pas:.2f} cost={last.cost:.0f}")
    toks = np.random.randint(0, 400, (2, 16)).astype(np.int32)
    out, lats = engine.serve(toks)
    print("engine sanity:", out.shape,
          [f"{l*1e3:.0f}ms" for l in lats])


if __name__ == "__main__":
    main()
