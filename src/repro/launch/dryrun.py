import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, print memory/cost analysis, and derive the roofline
terms (DESIGN.md §7).  MUST be run as its own process (the device-count flag
above is locked in at first jax init) — never import this module from tests.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import argparse
import json
import re
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.configs.base import InputShape, ModelConfig
from repro.distributed import api as dapi
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.training import optim
from repro.training.data import input_specs

# --- TPU v5e hardware constants (roofline) ---------------------------------
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*)\[([0-9,]*)\]")


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op in (partitioned) HLO."""
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*(.*?)\s+(%?[a-z0-9\-]*?)"
                      r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                      r"collective-permute)(-start|-done)?(\.[0-9]+)?\(",
                      stripped)
        if not m:
            continue
        kind = m.group(3)
        if m.group(4) == "-done":            # avoid double counting async pairs
            continue
        total = 0
        for dt, dims in _SHAPE_RE.findall(m.group(1)):
            nbytes = _DTYPE_BYTES.get(dt)
            if nbytes is None:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * nbytes
        out[kind] += total
        counts[kind] += 1
    out["_counts"] = counts
    return out


def serving_fsdp(cfg: ModelConfig, mesh) -> bool:
    """Shard serving weights over data too when TP-only exceeds ~8 GB/chip."""
    model_sz = mesh.shape.get("model", 1)
    return cfg.n_params() * 2 / model_sz > 8e9


# ---------------------------------------------------------------------------
# step builders: (fn, arg ShapeDtypeStructs, in_shardings, donate)
# ---------------------------------------------------------------------------
def _weights(cfg, mesh, weights_mode):
    """-> (fsdp, expert_mode) for serving param specs."""
    if weights_mode == "auto":
        return serving_fsdp(cfg, mesh), "none"
    if weights_mode == "tp":
        return False, "none"
    if weights_mode == "fsdp":
        return True, "none"
    if weights_mode == "expert2d":
        return True, "hidden_data"
    if weights_mode == "expertff":
        return False, "hidden_model"
    raise ValueError(weights_mode)


def build_case(cfg: ModelConfig, shape: InputShape, mesh, *,
               moe_impl: str = "einsum", attn_chunk: int = 1024,
               unroll: bool = False, weights_mode: str = "auto",
               microbatch: int = 1):
    ax = shd.MeshAxes.of(mesh)
    data_axes = ax.data
    batch_dim = shape.global_batch
    bspec_axis = data_axes if batch_dim % max(
        np.prod([mesh.shape[a] for a in data_axes]), 1) == 0 else None
    if bspec_axis is not None and len(bspec_axis) == 1:
        bspec_axis = bspec_axis[0]

    params_shape = jax.eval_shape(lambda: M.init(jax.random.PRNGKey(0), cfg))

    if shape.kind == "train":
        ocfg = optim.AdamWConfig()
        opt_shape = jax.eval_shape(lambda: optim.init_state(params_shape))

        def loss_fn_u(p, batch):
            from repro.training.train import cross_entropy
            hidden, aux = M.forward(p, cfg, batch, impl="chunked",
                                    moe_impl=moe_impl, remat=True,
                                    unroll=unroll)
            ce = cross_entropy(hidden, p["embed"], batch["labels"])
            return ce + aux, {"ce": ce, "aux": aux}

        def grads_of(params, batch):
            return jax.value_and_grad(
                lambda p: loss_fn_u(p, batch), has_aux=True)(params)

        def step(params, opt_state, batch):
            from repro.training import optim as _optim
            if microbatch > 1:
                # gradient accumulation: peak activation memory ~ 1/N of the
                # full-batch step (§Perf capacity iteration for *train_4k)
                mb = {k: v.reshape((microbatch, v.shape[0] // microbatch)
                                   + v.shape[1:]) for k, v in batch.items()}

                def body(acc, one):
                    (l, parts), g = grads_of(params, one)
                    acc_g, acc_l = acc
                    return (jax.tree.map(jnp.add, acc_g, g), acc_l + l), None

                zero = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (gsum, lsum), _ = jax.lax.scan(
                    body, (zero, jnp.zeros((), jnp.float32)), mb)
                grads = jax.tree.map(lambda g: g / microbatch, gsum)
                loss = lsum / microbatch
                parts = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}
            else:
                (loss, parts), grads = grads_of(params, batch)
            params, opt_state, om = _optim.apply_updates(
                params, grads, opt_state, ocfg)
            return params, opt_state, {"loss": loss, **parts, **om}
        pspec = shd.param_specs(params_shape, mesh, fsdp=True)
        ospec = {
            "mu": pspec, "nu": pspec, "step": P(),
        }
        batch_shape = input_specs(cfg, shape.seq_len, batch_dim, "train",
                                  dtype=cfg.dtype)
        bspec = {k: P(bspec_axis, *([None] * (len(v.shape) - 1)))
                 for k, v in batch_shape.items()}
        args = (params_shape, opt_shape, batch_shape)
        in_shardings = (pspec, ospec, bspec)
        out_shardings = (pspec, ospec, None)
        donate = (0, 1)
        fn = step
    elif shape.kind == "prefill":
        fsdp, e2d = _weights(cfg, mesh, weights_mode)
        pspec = shd.param_specs(params_shape, mesh, fsdp=fsdp, expert_mode=e2d)
        batch_shape = input_specs(cfg, shape.seq_len, batch_dim, "prefill",
                                  dtype=cfg.dtype)
        bspec = {k: P(bspec_axis, *([None] * (len(v.shape) - 1)))
                 for k, v in batch_shape.items()}

        def fn(params, batch):
            hl, caches, _ = M.prefill(params, cfg, batch, impl="chunked",
                                      moe_impl=moe_impl, unroll=unroll)
            return hl, caches

        args = (params_shape, batch_shape)
        in_shardings = (pspec, bspec)
        out_shardings = None
        donate = ()
    elif shape.kind == "decode":
        fsdp, e2d = _weights(cfg, mesh, weights_mode)
        pspec = shd.param_specs(params_shape, mesh, fsdp=fsdp, expert_mode=e2d)
        prefix = cfg.n_patches if cfg.family == "vlm" else 0
        cache_shape = jax.eval_shape(
            lambda: M.init_cache(cfg, batch_dim, shape.seq_len + prefix))
        cspec = shd.cache_specs(cfg, shape, mesh, cache_shape)
        batch_shape = input_specs(cfg, shape.seq_len, batch_dim, "decode",
                                  dtype=cfg.dtype)
        tspec = P(bspec_axis, None)

        def fn(params, caches, cache_len, tokens):
            return M.decode_step(params, cfg, caches, cache_len, tokens,
                                 moe_impl=moe_impl, unroll=unroll)

        args = (params_shape, cache_shape,
                jax.ShapeDtypeStruct((), jnp.int32), batch_shape["tokens"])
        in_shardings = (pspec, cspec, P(), tspec)
        out_shardings = (None, cspec)
        donate = (1,)
    else:
        raise ValueError(shape.kind)
    return fn, args, in_shardings, out_shardings, donate


def model_flops_per_device(cfg: ModelConfig, shape: InputShape,
                           n_devices: int) -> float:
    n = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens / n_devices
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens / n_devices
    return 2.0 * n * shape.global_batch / n_devices   # decode: 1 tok/seq


def _probe_cfg(cfg: ModelConfig, k: int) -> ModelConfig:
    """k-block-deep clone of cfg (same pattern period + remainder)."""
    import dataclasses as dc

    from repro.models.stack import plan
    pl = plan(cfg, cross=(cfg.family == "encdec"))
    changes = {"n_layers": k * pl.period + len(pl.rem)}
    if cfg.family == "encdec":
        changes["n_encoder_layers"] = k
    return dc.replace(cfg, **changes)


def probe_costs(cfg: ModelConfig, shape: InputShape, mesh, *,
                moe_impl: str = "einsum", weights_mode: str = "auto",
                microbatch: int = 1):
    """Exact per-block cost via two unrolled probes (k=1, k=2 blocks).

    XLA's cost_analysis counts a while-loop body once, so the scanned
    deployment program under-reports flops/bytes/collectives by ~n_rep.
    cost(k) is affine in k for a homogeneous stack, so
      total(n_rep) = cost(1) + (n_rep - 1) * (cost(2) - cost(1)).
    """
    from repro.models.stack import plan
    pl_full = plan(cfg, cross=(cfg.family == "encdec"))
    res = {}
    for k in (1, 2):
        pcfg = _probe_cfg(cfg, k)
        fn, args, in_sh, out_sh, donate = build_case(
            pcfg, shape, mesh, moe_impl=moe_impl, unroll=True,
            weights_mode=weights_mode, microbatch=microbatch)
        with jax.set_mesh(mesh):
            compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                               donate_argnums=donate).lower(*args).compile()
        ca = compiled.cost_analysis() or {}
        col = collective_bytes(compiled.as_text())
        res[k] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": {kk: v for kk, v in col.items() if not kk.startswith("_")},
        }
    n_rep = pl_full.n_rep

    def extrap(a, b):
        return max(a + (n_rep - 1) * (b - a), 0.0)

    out = {
        "flops": extrap(res[1]["flops"], res[2]["flops"]),
        "bytes": extrap(res[1]["bytes"], res[2]["bytes"]),
        "coll": {kk: extrap(res[1]["coll"][kk], res[2]["coll"][kk])
                 for kk in res[1]["coll"]},
        "probe_raw": res,
        "n_rep": n_rep,
    }
    return out


def make_custom_mesh(spec: str):
    """'32x8' -> (data=32, model=8) mesh over the first 256 host devices."""
    d, m = (int(x) for x in spec.split("x"))
    devs = np.array(jax.devices()[:d * m]).reshape(d, m)
    from jax.sharding import Mesh

    from repro.launch.mesh import mesh_axis_types
    at = mesh_axis_types(2)
    if at is None:
        return Mesh(devs, ("data", "model"))
    return Mesh(devs, ("data", "model"), axis_types=at)


def run_case(arch: str, shape_name: str, *, multi_pod: bool = False,
             moe_impl: str = "einsum", verbose: bool = True,
             save_hlo: Optional[str] = None, mesh_shape: Optional[str] = None,
             weights_mode: str = "auto", microbatch: int = 1) -> Dict:
    cfg = configs.get_config(arch)
    shape = configs.INPUT_SHAPES[shape_name]
    mesh = (make_custom_mesh(mesh_shape) if mesh_shape
            else make_production_mesh(multi_pod=multi_pod))
    n_dev = int(np.prod(list(mesh.shape.values())))
    rec = {"arch": arch, "shape": shape_name, "mesh": "x".join(
        f"{k}={v}" for k, v in mesh.shape.items()), "devices": n_dev,
        "moe_impl": moe_impl, "weights_mode": weights_mode, "ok": False}
    t0 = time.time()
    try:
        dapi.set_axis_rules(shd.axis_rules(mesh))
        fn, args, in_sh, out_sh, donate = build_case(
            cfg, shape, mesh, moe_impl=moe_impl, weights_mode=weights_mode,
            microbatch=microbatch)
        with jax.set_mesh(mesh):
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            rec["lower_s"] = round(time.time() - t0, 1)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 1)
        mem = compiled.memory_analysis()
        if verbose:
            print(mem)
        ca = compiled.cost_analysis() or {}
        if verbose:
            print({k: ca.get(k) for k in ("flops", "bytes accessed",
                                          "transcendentals")})
        hlo = compiled.as_text()
        col = collective_bytes(hlo)
        if save_hlo:
            with open(save_hlo, "w") as f:
                f.write(hlo)

        # exact costs from the unrolled 1-/2-block probes (scan bodies are
        # counted once by XLA's cost model — see probe_costs)
        probe = probe_costs(cfg, shape, mesh, moe_impl=moe_impl,
                            weights_mode=weights_mode, microbatch=microbatch)
        flops = probe["flops"]
        bytes_acc = probe["bytes"]
        col_total = sum(probe["coll"].values())
        col = {**probe["coll"], "_counts": col.get("_counts", {}),
               "_scanned_raw": {k: v for k, v in col.items()
                                if not k.startswith("_")}}
        mflops = model_flops_per_device(cfg, shape, n_dev)
        rec.update({
            "ok": True,
            "hlo_flops_per_dev": flops,
            "hlo_bytes_per_dev": bytes_acc,
            "collective_bytes_per_dev": col_total,
            "collectives": col,
            "mem": {
                "argument_gb": mem.argument_size_in_bytes / 2**30,
                "output_gb": mem.output_size_in_bytes / 2**30,
                "temp_gb": mem.temp_size_in_bytes / 2**30,
                "alias_gb": mem.alias_size_in_bytes / 2**30,
            },
            "model_flops_per_dev": mflops,
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": bytes_acc / HBM_BW,
            "collective_s": col_total / ICI_BW,
            "useful_flops_ratio": mflops / flops if flops else 0.0,
        })
        terms = {"compute": rec["compute_s"], "memory": rec["memory_s"],
                 "collective": rec["collective_s"]}
        rec["bottleneck"] = max(terms, key=terms.get)
        if verbose:
            print({k: f"{v:.3e}" for k, v in terms.items()},
                  "->", rec["bottleneck"],
                  f"useful={rec['useful_flops_ratio']:.3f}")
    except Exception as e:  # noqa: BLE001 — report, don't die mid-sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print("FAILED:", rec["error"])
    finally:
        dapi.set_axis_rules(None)
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--moe-impl", default="einsum")
    ap.add_argument("--mesh-shape", default=None,
                    help="override mesh, e.g. 32x8 (hillclimb experiments)")
    ap.add_argument("--weights-mode", default="auto",
                    choices=["auto", "tp", "fsdp", "expert2d", "expertff"])
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    pairs = (configs.all_dryrun_pairs() if args.all
             else [(args.arch, configs.INPUT_SHAPES[args.shape])])
    tag = "multipod" if args.multi_pod else "singlepod"
    if args.mesh_shape:
        tag = f"mesh{args.mesh_shape}"
    if args.weights_mode != "auto":
        tag += f"__{args.weights_mode}"
    if args.microbatch > 1:
        tag += f"__mb{args.microbatch}"
    n_ok = 0
    for arch, shape in pairs:
        sname = shape.name if hasattr(shape, "name") else shape
        path = os.path.join(args.out,
                            f"{arch}__{sname}__{tag}__{args.moe_impl}.json")
        if args.skip_existing and os.path.exists(path):
            print(f"skip {arch} x {sname} ({tag})")
            n_ok += 1
            continue
        print(f"=== {arch} x {sname} ({tag}, moe={args.moe_impl}) ===",
              flush=True)
        rec = run_case(arch, sname, multi_pod=args.multi_pod,
                       moe_impl=args.moe_impl, mesh_shape=args.mesh_shape,
                       weights_mode=args.weights_mode,
                       microbatch=args.microbatch)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        n_ok += int(rec["ok"])
        print(f"    -> ok={rec['ok']} total={rec['total_s']}s", flush=True)
    print(f"dry-run complete: {n_ok}/{len(pairs)} ok")


if __name__ == "__main__":
    main()
