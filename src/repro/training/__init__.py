from repro.training import checkpoint, data, optim, train  # noqa: F401
