"""Loss + train step (chunked cross-entropy, AdamW, remat-aware)."""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.api import constrain
from repro.models import model as M
from repro.training import optim

LOSS_CHUNK = 2048


def cross_entropy(hidden, embed, labels, chunk: int = LOSS_CHUNK):
    """hidden: (B, S, d); embed: (V, d); labels: (B, S) with -1 = masked.

    Computed in sequence chunks so the (B, C, V) logits block — not the full
    (B, S, V) tensor — is the peak memory.
    """
    b, s, d = hidden.shape

    def chunk_loss(h, y):
        lg = jnp.einsum("bcd,vd->bcv", h, embed,
                        preferred_element_type=jnp.float32)
        lg = constrain(lg, "data", None, "model")
        logz = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(
            lg, jnp.maximum(y, 0)[..., None], axis=-1)[..., 0]
        mask = (y >= 0).astype(jnp.float32)
        return jnp.sum((logz - gold) * mask), jnp.sum(mask)

    if s % chunk == 0 and s > chunk:
        nc = s // chunk
        hc = hidden.reshape(b, nc, chunk, d).swapaxes(0, 1)
        yc = labels.reshape(b, nc, chunk).swapaxes(0, 1)

        def body(carry, xs):
            tot, cnt = carry
            l, c = chunk_loss(*xs)
            return (tot + l, cnt + c), None

        (tot, cnt), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (hc, yc))
    else:
        tot, cnt = chunk_loss(hidden, labels)
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, cfg: ModelConfig, batch, *, impl="chunked",
            moe_impl="einsum", remat=False):
    hidden, aux = M.forward(params, cfg, batch, impl=impl,
                            moe_impl=moe_impl, remat=remat)
    ce = cross_entropy(hidden, params["embed"], batch["labels"])
    return ce + aux, {"ce": ce, "aux": aux}


def make_train_step(cfg: ModelConfig, ocfg: optim.AdamWConfig, *,
                    impl="chunked", moe_impl="einsum", remat=False):
    """Returns train_step(params, opt_state, batch) -> (params, state, metrics).

    The returned function is NOT jitted — callers jit it with their own
    in/out shardings (launch/train.py) or plainly (smoke tests).
    """

    def train_step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, impl=impl,
                              moe_impl=moe_impl, remat=remat),
            has_aux=True)(params)
        params, opt_state, om = optim.apply_updates(params, grads, opt_state, ocfg)
        metrics = {"loss": loss, **parts, **om}
        return params, opt_state, metrics

    return train_step


def train_loop(cfg: ModelConfig, stream, steps: int, *, seed: int = 0,
               ocfg: Optional[optim.AdamWConfig] = None, log_every: int = 10,
               impl="naive", verbose: bool = True):
    """Single-host training driver (examples + tests)."""
    ocfg = ocfg or optim.AdamWConfig(total_steps=steps)
    params = M.init(jax.random.PRNGKey(seed), cfg)
    opt_state = optim.init_state(params)
    step_fn = jax.jit(make_train_step(cfg, ocfg, impl=impl))
    history = []
    it = iter(stream)
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i == steps - 1:
            rec = {k: float(v) for k, v in m.items()}
            rec["step"] = i
            history.append(rec)
            if verbose:
                print(f"step {i:5d} loss={rec['loss']:.4f} "
                      f"ce={rec['ce']:.4f} gnorm={rec['grad_norm']:.3f}")
    return params, opt_state, history
