"""Synthetic data pipeline.

Deterministic, seekable token stream with learnable structure (a randomly
drawn order-1 Markov chain over the vocabulary), so a ~100M model trained
for a few hundred steps shows a cleanly decreasing loss.  Multimodal
architectures additionally get stub frame/patch embeddings correlated with
the token stream prefix so the backbone has cross-modal signal to exploit.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class DataConfig:
    seq_len: int = 256
    batch_size: int = 8
    seed: int = 0
    markov_concentration: float = 0.3   # lower = more predictable stream


class SyntheticStream:
    """Order-1 Markov token stream; batch ``i`` is reproducible from (seed, i)."""

    def __init__(self, cfg: ModelConfig, dcfg: DataConfig):
        self.cfg = cfg
        self.dcfg = dcfg
        rng = np.random.default_rng(dcfg.seed)
        v = cfg.vocab
        probs = rng.dirichlet(np.full(min(v, 64), dcfg.markov_concentration),
                              size=v)
        # each row transitions among 64 random successor states
        succ = np.stack([rng.choice(v, size=min(v, 64), replace=False)
                         for _ in range(v)])
        self._succ = succ.astype(np.int32)
        self._cum = np.cumsum(probs, axis=1).astype(np.float64)

    def _walk(self, rng: np.random.Generator, n: int) -> np.ndarray:
        out = np.empty(n, np.int32)
        state = int(rng.integers(self.cfg.vocab))
        u = rng.random(n)
        for i in range(n):
            j = int(np.searchsorted(self._cum[state], u[i]))
            j = min(j, self._succ.shape[1] - 1)
            state = int(self._succ[state, j])
            out[i] = state
        return out

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        d, c = self.dcfg, self.cfg
        rng = np.random.default_rng((d.seed, index))
        toks = np.stack([self._walk(rng, d.seq_len + 1)
                         for _ in range(d.batch_size)])
        out = {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
        if c.family == "encdec":
            emb = rng.standard_normal((d.batch_size, c.encoder_seq, c.d_model))
            out["frames"] = emb.astype(np.float32) * 0.02
        if c.family == "vlm":
            emb = rng.standard_normal((d.batch_size, c.n_patches, c.d_model))
            out["patches"] = emb.astype(np.float32) * 0.02
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1


def input_specs(cfg: ModelConfig, seq_len: int, batch: int, kind: str,
                dtype=None):
    """ShapeDtypeStruct stand-ins for every model input (dry-run path)."""
    dtype = dtype or cfg.dtype
    sds = jax.ShapeDtypeStruct
    if kind == "train":
        out = {"tokens": sds((batch, seq_len), jnp.int32),
               "labels": sds((batch, seq_len), jnp.int32)}
    elif kind == "prefill":
        out = {"tokens": sds((batch, seq_len), jnp.int32)}
    elif kind == "decode":
        out = {"tokens": sds((batch, 1), jnp.int32)}
    else:
        raise ValueError(kind)
    if cfg.family == "encdec" and kind != "decode":
        out["frames"] = sds((batch, cfg.encoder_seq, cfg.d_model), dtype)
    if cfg.family == "vlm" and kind != "decode":
        out["patches"] = sds((batch, cfg.n_patches, cfg.d_model), dtype)
    return out
