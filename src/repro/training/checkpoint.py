"""Flat-npz pytree checkpointing (no orbax dependency)."""
from __future__ import annotations

import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "::"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_key_str(k) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return f"d{k.key}"
    if hasattr(k, "idx"):
        return f"i{k.idx}"
    return str(k)


def save(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten(tree))


def load(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_keys, leaf in leaves_like:
        key = _SEP.join(_key_str(k) for k in path_keys)
        arr = data[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        out.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)
