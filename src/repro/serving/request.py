"""Request/response records flowing through an inference pipeline."""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional

_ids = itertools.count()


@dataclasses.dataclass(slots=True)
class Request:
    arrival: float                       # seconds, pipeline ingress
    payload: Any = None                  # tokens (np.ndarray) or None (synthetic)
    req_id: int = dataclasses.field(default_factory=lambda: next(_ids))
    sla: Optional[float] = None          # end-to-end latency SLA (s)
    # bookkeeping filled in as the request flows
    stage_enter: Dict[int, float] = dataclasses.field(default_factory=dict)
    stage_exit: Dict[int, float] = dataclasses.field(default_factory=dict)
    dropped_at: Optional[int] = None
    done: float = float("nan")
    # per-pipeline request id stamped by the simulator at first-stage
    # entry of a DAG pipeline (join matching + drop propagation); -1 on
    # chain pipelines, which never need it
    rid: int = -1

    @property
    def latency(self) -> float:
        return self.done - self.arrival

    @property
    def dropped(self) -> bool:
        return self.dropped_at is not None

    def reset(self, arrival: float, sla: Optional[float] = None) -> "Request":
        """Re-initialize for reuse out of a ``RequestPool`` (fresh id)."""
        self.arrival = arrival
        self.payload = None
        self.req_id = next(_ids)
        self.sla = sla
        self.stage_enter.clear()
        self.stage_exit.clear()
        self.dropped_at = None
        self.done = float("nan")
        self.rid = -1
        return self


class RequestPool:
    """Free-list of ``Request`` objects for allocation-heavy replay loops.

    The simulator hot path creates no requests itself, but its drivers
    (adapter traces, benchmarks) allocate one per arrival; with a pool the
    simulator releases each request back at its terminal event (completion
    or drop) so steady-state replay reuses a small working set instead of
    churning the allocator.  Only safe when the driver does not hold
    references to injected requests past their completion.
    """

    __slots__ = ("_free", "allocated", "reused")

    def __init__(self):
        self._free: List[Request] = []
        self.allocated = 0
        self.reused = 0

    def acquire(self, arrival: float, sla: Optional[float] = None) -> Request:
        if self._free:
            self.reused += 1
            return self._free.pop().reset(arrival, sla)
        self.allocated += 1
        return Request(arrival=arrival, sla=sla)

    def acquire_many(self, arrivals, sla: Optional[float] = None
                     ) -> List[Request]:
        """Bulk ``acquire``: recycle up to ``len(arrivals)`` pooled
        requests in one slice, allocate the rest.  Requests come back in
        arrival order (ids are stamped in that order, as sequential
        ``acquire`` calls would)."""
        free = self._free
        k = len(arrivals)
        reuse = min(len(free), k)
        out: List[Request] = []
        if reuse:
            self.reused += reuse
            recycled = free[-reuse:]
            del free[-reuse:]
            out.extend(r.reset(t, sla)
                       for r, t in zip(recycled, arrivals))
        if reuse < k:
            self.allocated += k - reuse
            out.extend(Request(arrival=t, sla=sla)
                       for t in arrivals[reuse:])
        return out

    def release(self, req: Request) -> None:
        self._free.append(req)

    def release_many(self, reqs) -> None:
        self._free.extend(reqs)


@dataclasses.dataclass
class BatchRecord:
    stage: int
    size: int
    formed_at: float
    started: float
    finished: float
    replica: int
    variant: str
