"""Request/response records flowing through an inference pipeline."""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional

_ids = itertools.count()


@dataclasses.dataclass(slots=True)
class Request:
    arrival: float                       # seconds, pipeline ingress
    payload: Any = None                  # tokens (np.ndarray) or None (synthetic)
    req_id: int = dataclasses.field(default_factory=lambda: next(_ids))
    sla: Optional[float] = None          # end-to-end latency SLA (s)
    # bookkeeping filled in as the request flows
    stage_enter: Dict[int, float] = dataclasses.field(default_factory=dict)
    stage_exit: Dict[int, float] = dataclasses.field(default_factory=dict)
    dropped_at: Optional[int] = None
    done: float = float("nan")

    @property
    def latency(self) -> float:
        return self.done - self.arrival

    @property
    def dropped(self) -> bool:
        return self.dropped_at is not None


@dataclasses.dataclass
class BatchRecord:
    stage: int
    size: int
    formed_at: float
    started: float
    finished: float
    replica: int
    variant: str
