"""Real JAX serving engine: batched prefill + greedy decode with KV cache,
and hot-swappable model variants (the data plane under IPA's control plane).

A ``StageServer`` owns one inference *task* (a stage of the pipeline) and a
family of model variants for it.  ``set_variant`` switches the active
parameter pytree — the serving analogue of the paper's model switching.  A
``PipelineEngine`` chains stages: the token output of stage i is the prompt
of stage i+1 (the abstraction the paper uses for e.g. detector -> classifier
or ASR -> QA chains).
"""
from __future__ import annotations

import functools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M


class StageServer:
    def __init__(self, name: str,
                 variants: Sequence[Tuple[str, ModelConfig, float]],
                 *, gen_tokens: int = 8, max_ctx: int = 192, seed: int = 0,
                 params_by_variant: Optional[Dict[str, dict]] = None):
        self.name = name
        self.gen_tokens = gen_tokens
        self.max_ctx = max_ctx
        self.variants: Dict[str, Tuple[ModelConfig, float]] = {}
        self.params: Dict[str, dict] = {}
        for i, (vname, cfg, acc) in enumerate(variants):
            self.variants[vname] = (cfg, acc)
            if params_by_variant and vname in params_by_variant:
                self.params[vname] = params_by_variant[vname]
            else:
                self.params[vname] = M.init(jax.random.PRNGKey(seed + i), cfg)
        self.active = list(self.variants)[0]
        self._prefill_cache = {}
        self._decode_cache = {}

    # -- control plane hooks -------------------------------------------------
    def set_variant(self, vname: str) -> None:
        assert vname in self.variants, (vname, list(self.variants))
        self.active = vname

    @property
    def accuracy(self) -> float:
        return self.variants[self.active][1]

    @property
    def config(self) -> ModelConfig:
        return self.variants[self.active][0]

    # -- data plane -----------------------------------------------------------
    def _get_prefill(self, vname: str, b: int, s: int):
        key = (vname, b, s)
        if key not in self._prefill_cache:
            cfg = self.variants[vname][0]
            cap = min(self.max_ctx, s + self.gen_tokens)

            @jax.jit
            def fn(params, tokens):
                hl, caches, _ = M.prefill(params, cfg, {"tokens": tokens},
                                          impl="naive", capacity=cap)
                lg = jnp.einsum("bd,vd->bv", hl, params["embed"])
                return lg, caches
            self._prefill_cache[key] = fn
        return self._prefill_cache[key]

    def _get_decode(self, vname: str, b: int):
        key = (vname, b)
        if key not in self._decode_cache:
            cfg = self.variants[vname][0]

            @jax.jit
            def fn(params, caches, clen, tok):
                return M.decode_step(params, cfg, caches, clen, tok)
            self._decode_cache[key] = fn
        return self._decode_cache[key]

    def process(self, tokens: np.ndarray) -> Tuple[np.ndarray, float]:
        """tokens: (B, S) int32 prompts. Greedy-decodes ``gen_tokens``.

        Returns (generated (B, gen_tokens), wall_seconds).
        """
        cfg = self.config
        tokens = np.asarray(tokens, np.int32) % cfg.vocab
        b, s = tokens.shape
        t0 = time.perf_counter()
        prefill = self._get_prefill(self.active, b, s)
        decode = self._get_decode(self.active, b)
        params = self.params[self.active]
        lg, caches = prefill(params, jnp.asarray(tokens))
        out = []
        tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)[:, None]
        clen = s
        for _ in range(self.gen_tokens):
            out.append(tok)
            lg, caches = decode(params, caches, jnp.int32(clen), tok)
            tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)[:, None]
            clen += 1
        gen = jnp.concatenate(out, axis=1)
        gen.block_until_ready()
        return np.asarray(gen), time.perf_counter() - t0


class PipelineEngine:
    """Chains StageServers; stage i's generated tokens prompt stage i+1."""

    def __init__(self, stages: Sequence[StageServer]):
        self.stages = list(stages)

    def configure(self, variants: Sequence[str]) -> None:
        for st, v in zip(self.stages, variants):
            st.set_variant(v)

    def serve(self, tokens: np.ndarray) -> Tuple[np.ndarray, List[float]]:
        lats = []
        cur = tokens
        for st in self.stages:
            cur, lat = st.process(cur)
            lats.append(lat)
        return cur, lats

    @property
    def pas(self) -> float:
        """Pipeline Accuracy Score of the currently active variants (Eq. 8)."""
        p = 1.0
        for st in self.stages:
            p *= st.accuracy / 100.0
        return p * 100.0
