"""Centralized per-stage queue with batch formation (paper §3 "Pipeline
System": one central queue per stage, round-robin dispatch to replicas).

The queue forms a batch as soon as ``batch_size`` requests are waiting, or
when the oldest request has waited ``max_wait`` (so low load does not stall
forever — the paper's simulator uses the same arrival-driven bound that
yields the worst-case queueing delay q(b) = (b-1)/lambda of Eq. 7).
"""
from __future__ import annotations

import collections
from typing import Deque, List, Optional, Tuple

from repro.serving.request import Request


class CentralQueue:
    def __init__(self, batch_size: int = 1, max_wait: float = 2.0):
        self.batch_size = batch_size
        self.max_wait = max_wait
        self._q: Deque[Request] = collections.deque()

    def __len__(self) -> int:
        return len(self._q)

    def push(self, req: Request) -> None:
        self._q.append(req)

    def reconfigure(self, batch_size: int, max_wait: Optional[float] = None):
        self.batch_size = batch_size
        if max_wait is not None:
            self.max_wait = max_wait

    def oldest_wait(self, now: float) -> float:
        if not self._q:
            return 0.0
        return now - self._q[0].arrival

    def ready(self, now: float) -> bool:
        if len(self._q) >= self.batch_size:
            return True
        return bool(self._q) and self.oldest_wait(now) >= self.max_wait

    def pop_batch(self, now: float) -> List[Request]:
        n = min(self.batch_size, len(self._q))
        return [self._q.popleft() for _ in range(n)]

    def drain_expired(self, now: float, stage: int,
                      drop_factor: float = 2.0) -> List[Request]:
        """Paper §4.5: drop requests whose age already exceeds
        ``drop_factor x SLA`` (they cannot meet the SLA anyway)."""
        dropped = []
        keep: Deque[Request] = collections.deque()
        while self._q:
            r = self._q.popleft()
            if r.sla is not None and (now - r.arrival) > drop_factor * r.sla:
                r.dropped_at = stage
                r.done = now
                dropped.append(r)
            else:
                keep.append(r)
        self._q = keep
        return dropped
