from repro.serving import batching, engine, request  # noqa: F401
