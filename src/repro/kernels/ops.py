"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode; on a real TPU
set ``REPRO_PALLAS_INTERPRET=0`` (or rely on the default backend detection)
to lower them to Mosaic.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import ssd_scan as _ssd


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, q_pos=None, k_pos=None, *, causal: bool = True,
                    window: Optional[int] = None, block_q: int = 128,
                    block_k: int = 128, interpret: Optional[bool] = None):
    """Signature-compatible with repro.models.layers.attention."""
    if interpret is None:
        interpret = _interpret_default()
    bq = min(block_q, q.shape[1])
    bk = min(block_k, k.shape[1])
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=bq, block_k=bk, interpret=interpret)


def decode_attention(q, k_cache, v_cache, lengths, *, block_k: int = 128,
                     interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _interpret_default()
    bk = min(block_k, k_cache.shape[1])
    return _dec.decode_attention(q, k_cache, v_cache, lengths,
                                 block_k=bk, interpret=interpret)


def ssd_scan(x, dt, a_neg, b_mat, c_mat, *, chunk: int = 256,
             init_state=None, interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _interpret_default()
    chunk = min(chunk, x.shape[1])
    return _ssd.ssd_scan(x, dt, a_neg, b_mat, c_mat, chunk=chunk,
                         init_state=init_state, interpret=interpret)
