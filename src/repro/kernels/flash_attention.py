"""Pallas TPU flash attention (prefill path).

Block-tiled online-softmax attention.  Grid is (B, H, num_q_blocks,
num_kv_blocks) with the KV axis sequential ("arbitrary") so the f32
accumulator/row-max/row-sum scratch in VMEM carries across KV blocks.
GQA is handled in the index map (kv head = h // (H // KV)) — the kernel never
materializes repeated K/V.  Block sizes default to MXU-aligned 128s; the
per-step VMEM working set is
  bq*hd (q) + 2*bk*hd (k,v) + bq*bk (scores) + bq*hd (acc)  floats.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, nk: int, scale: float,
                  causal: bool, window: Optional[int]):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :]                                   # (bq, hd)
    k = k_ref[0, :, 0, :]                                   # (bk, hd)
    v = v_ref[0, :, 0, :]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qi = pl.program_id(2)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    if causal:
        ok = k_pos <= q_pos
        if window is not None:
            ok &= k_pos > q_pos - window
        s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]                                     # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                                  # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                         # (bq, 1)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd) with KV | H.  Causal masking
    assumes queries and keys are position-aligned (Sq == Sk)."""
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    assert h % kv == 0 and sq % block_q == 0 and sk % block_k == 0, \
        (q.shape, k.shape, block_q, block_k)
    group = h // kv
    nq, nk = sq // block_q, sk // block_k
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _flash_kernel, bq=block_q, bk=block_k, nk=nk, scale=scale,
        causal=causal, window=window)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, qi, ki: (b, ki, h // group, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, qi, ki: (b, ki, h // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
