"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None):
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd)."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    k = jnp.repeat(k, h // kv, axis=2)
    v = jnp.repeat(v, h // kv, axis=2)
    scale = 1.0 / (hd ** 0.5)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        qp = jnp.arange(sq)[:, None]
        kp = jnp.arange(k.shape[1])[None, :]
        ok = kp <= qp
        if window is not None:
            ok &= kp > qp - window
        s = jnp.where(ok[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """q: (B, H, hd); caches: (B, L, KV, hd); lengths: (B,)."""
    b, h, hd = q.shape
    L, kv = k_cache.shape[1], k_cache.shape[2]
    k = jnp.repeat(k_cache, h // kv, axis=2)
    v = jnp.repeat(v_cache, h // kv, axis=2)
    scale = 1.0 / (hd ** 0.5)
    s = jnp.einsum("bhd,bkhd->bhk", q, k,
                   preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(L)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", p.astype(v.dtype), v)


def ssd_scan_ref(x, dt, a_neg, b_mat, c_mat, init_state=None):
    """Naive O(S) recurrence; see repro.models.ssm.ssd_reference."""
    from repro.models.ssm import ssd_reference
    return ssd_reference(x, dt, a_neg, b_mat, c_mat, init_state=init_state)
