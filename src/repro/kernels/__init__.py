from repro.kernels import ops, ref  # noqa: F401
from repro.kernels.decode_attention import decode_attention  # noqa: F401
from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.ssd_scan import ssd_scan  # noqa: F401
