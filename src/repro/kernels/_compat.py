"""Version shims for jax API renames shared by all pallas kernels."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both so the
# kernels run on this container's jax and on newer releases unchanged
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
