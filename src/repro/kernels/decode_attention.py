"""Pallas TPU decode attention: one query token over a long KV cache.

The decode hot spot is memory-bound (the whole KV cache streams HBM->VMEM
once per token), so the kernel is organized to read each cache block exactly
once: grid (B, KV_heads, num_cache_blocks), sequential over cache blocks with
the per-(batch, kv-head) group of GQA query heads (H/KV of them) resident in
VMEM.  A `lengths` operand masks ring-buffer slots past the valid length.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                   *, bk: int, nk: int, scale: float):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0, :, :]                                   # (group, hd)
    k = k_ref[0, :, 0, :]                                   # (bk, hd)
    v = v_ref[0, :, 0, :]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    valid_len = len_ref[pl.program_id(0)]
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(k_pos < valid_len, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, lengths, *, block_k: int = 128,
                     interpret: bool = False):
    """q: (B, H, hd); caches: (B, L, KV, hd); lengths: (B,) valid entries.

    Returns (B, H, hd).
    """
    b, h, hd = q.shape
    L, kv = k_cache.shape[1], k_cache.shape[2]
    assert h % kv == 0 and L % block_k == 0, (q.shape, k_cache.shape, block_k)
    group = h // kv
    nk = L // block_k
    scale = 1.0 / (hd ** 0.5)
    qg = q.reshape(b, kv, group, hd)

    kernel = functools.partial(_decode_kernel, bk=block_k, nk=nk, scale=scale)

    out = pl.pallas_call(
        kernel,
        grid=(b, kv, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, group, hd), lambda b, g, ki: (b, g, 0, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, g, ki: (b, ki, g, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, g, ki: (b, ki, g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, hd), lambda b, g, ki: (b, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, group, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, hd), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(b, h, hd)
