"""Pallas TPU kernel for the Mamba2 SSD chunked scan (arXiv:2405.21060).

TPU adaptation of the SSD algorithm: the sequence is split into chunks of
length `l`; each grid step loads one chunk's x/dt/B/C blocks into VMEM,
computes the intra-chunk (L x L) decay-masked attention-like matmuls on the
MXU, and carries the (P x N) inter-chunk SSM state in an f32 VMEM scratch
across the sequential chunk axis.  This replaces the GPU implementation's
warp-level scan with a grid-sequential state carry — the natural TPU
equivalent.  Grid: (B, H, num_chunks) with chunk axis "arbitrary".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, s0_ref,
                y_ref, sf_ref, st_ref, *, li: int, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        st_ref[...] = s0_ref[0, 0, :, :].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)               # (l, p)
    dt = dt_ref[0, :, 0].astype(jnp.float32)                # (l,)
    a = a_ref[pl.program_id(1)]                             # this head's decay rate
    bm = b_ref[0, :, 0, :].astype(jnp.float32)              # (l, n)
    cm = c_ref[0, :, 0, :].astype(jnp.float32)              # (l, n)

    da = dt * a                                             # (l,) log decay
    cum = jnp.cumsum(da)                                    # inclusive
    # decay matrix L[i, j] = exp(sum_{k in (j, i]} da_k), lower triangular
    seg = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (li, li), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (li, li), 1)
    lmat = jnp.where(ii >= jj, jnp.exp(seg), 0.0)           # (l, l)

    xdt = x * dt[:, None]                                   # (l, p)
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (l, l)
    y_diag = jax.lax.dot(scores * lmat, xdt,
                         preferred_element_type=jnp.float32)          # (l, p)

    state = st_ref[...]                                     # (p, n)
    out_decay = jnp.exp(cum)[:, None]                       # (l, 1)
    y_off = jax.lax.dot(cm, state.T,
                        preferred_element_type=jnp.float32) * out_decay

    y_ref[0, :, 0, :] = (y_diag + y_off).astype(y_ref.dtype)

    decay_to_end = jnp.exp(cum[-1] - cum)[:, None]          # (l, 1)
    new_contrib = jax.lax.dot_general(
        xdt * decay_to_end, bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                  # (p, n)
    st_ref[...] = state * jnp.exp(cum[-1]) + new_contrib

    @pl.when(ci == nc - 1)
    def _flush():
        sf_ref[0, 0, :, :] = st_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a_neg, b_mat, c_mat, *, chunk: int = 256,
             init_state=None, interpret: bool = False):
    """x: (B, S, H, P); dt: (B, S, H); a_neg: (H,);
    b_mat/c_mat: (B, S, G, N), H = G * hpg.  S must be a chunk multiple.
    Returns (y (B, S, H, P), final_state (B, H, P, N))."""
    b, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    hpg = h // g
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    kernel = functools.partial(_ssd_kernel, li=chunk, nc=nc)

    y, sf = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, chunk, 1, n), lambda b, h, c: (b, c, h // hpg, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda b, h, c: (b, c, h // hpg, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, a_neg.astype(jnp.float32), b_mat, c_mat, init_state)
    return y, sf
