"""Mamba2 mixer (SSD — state-space duality, arXiv:2405.21060).

The chunked SSD algorithm maps naturally onto the TPU MXU: intra-chunk terms
are (L x L) / (L x N) matmuls, the inter-chunk recurrence is a cheap
`lax.scan` over chunk states.  `repro.kernels.ssd_scan` is the Pallas version
of the same math; `ssd_reference` below is the naive O(S) recurrence oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models import layers as L


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------
def init_mamba(rng, d_model: int, scfg: SSMConfig, dtype):
    din = scfg.d_inner(d_model)
    nh = scfg.n_heads(d_model)
    conv_dim = din + 2 * scfg.n_groups * scfg.d_state
    ks = jax.random.split(rng, 4)
    return {
        "in_proj": L.init_dense(ks[0], d_model, 2 * din + 2 * scfg.n_groups * scfg.d_state + nh, dtype),
        "conv_w": (jax.random.normal(ks[1], (conv_dim, scfg.d_conv), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.zeros((din,), dtype),
        "out_proj": L.init_dense(ks[3], din, d_model, dtype),
    }


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------
def _segsum(a):
    """a: (..., l) -> (..., l, l) with out[i, j] = sum_{k in (j, i]} a_k (i>=j)."""
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    li = a.shape[-1]
    mask = jnp.tril(jnp.ones((li, li), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a_neg, b_mat, c_mat, chunk: int, init_state=None):
    """Chunked SSD (Mamba2 Listing 1, jnp).

    x: (B, S, H, P); dt: (B, S, H) (already softplus'ed);
    a_neg: (H,) negative decay; b_mat, c_mat: (B, S, G, N) with H = G*hpg.
    Returns (y: (B, S, H, P), final_state: (B, H, P, N)).
    """
    b, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc, li = sp // chunk, chunk
    hpg = h // g
    bm = jnp.repeat(b_mat, hpg, axis=2).astype(jnp.float32)       # (B,S,H,N)
    cm = jnp.repeat(c_mat, hpg, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32) * dt[..., None]                    # fold dt in
    da = dt * a_neg[None, None, :]                                # (B,S,H) log decay

    def ch(t):  # (B, S, ...) -> (B, nc, l, ...)
        return t.reshape((b, nc, li) + t.shape[2:])
    xc, bc, cc, dac = ch(xf), ch(bm), ch(cm), ch(da)

    # intra-chunk (diagonal blocks)
    dach = jnp.moveaxis(dac, -1, 2)                               # (B,nc,H,l)
    lmat = jnp.exp(_segsum(dach))                                 # (B,nc,H,l,l)
    scores = jnp.einsum("bclhn,bcshn->bchls", cc, bc)
    y_diag = jnp.einsum("bchls,bchls,bcshp->bclhp",
                        scores, lmat, xc, optimize=True)

    # per-chunk end states
    cum = jnp.cumsum(dach, axis=-1)                               # (B,nc,H,l)
    decay_to_end = jnp.exp(cum[..., -1:] - cum)                   # (B,nc,H,l)
    states = jnp.einsum("bcshn,bchs,bcshp->bchpn", bc, decay_to_end, xc)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cum[..., -1])                           # (B,nc,H)
    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(carry, inp):
        st, dec = inp
        prev = carry
        new = prev * dec[:, :, None, None] + st
        return new, prev                                          # emit exclusive prefix

    final, prev_states = jax.lax.scan(
        step, s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)                 # (B,nc,H,P,N)

    out_decay = jnp.exp(cum)                                      # (B,nc,H,l)
    y_off = jnp.einsum("bclhn,bchpn,bchl->bclhp", cc, prev_states, out_decay)

    y = (y_diag + y_off).reshape(b, sp, h, p)[:, :s]
    return y.astype(x.dtype), final


def ssd_reference(x, dt, a_neg, b_mat, c_mat, init_state=None):
    """Naive O(S) recurrence oracle (float32)."""
    b, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    hpg = h // g
    bm = jnp.repeat(b_mat, hpg, axis=2).astype(jnp.float32)
    cm = jnp.repeat(c_mat, hpg, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(state, inp):
        xt, dtt, bt, ct = inp                                     # (B,H,P),(B,H),(B,H,N),(B,H,N)
        da = jnp.exp(dtt * a_neg[None])                           # (B,H)
        state = state * da[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", xt * dtt[..., None], bt)
        y = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, y

    final, ys = jax.lax.scan(
        step, s0,
        (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
         jnp.moveaxis(bm, 1, 0), jnp.moveaxis(cm, 1, 0)))
    return jnp.moveaxis(ys, 0, 1), final


# ---------------------------------------------------------------------------
# full mixer
# ---------------------------------------------------------------------------
def _split_proj(params, x, d_model, scfg):
    din = scfg.d_inner(d_model)
    gn = scfg.n_groups * scfg.d_state
    zxbcdt = x @ params["in_proj"]
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din:2 * din + 2 * gn]
    dt_raw = zxbcdt[..., 2 * din + 2 * gn:]
    return z, xbc, dt_raw


def _causal_conv(xbc, w, bias):
    """Depthwise causal conv. xbc: (B, S, C); w: (C, K)."""
    k = w.shape[-1]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(k):
        out = out + pad[:, i:i + xbc.shape[1], :].astype(jnp.float32) * w[:, i].astype(jnp.float32)
    return jax.nn.silu(out + bias.astype(jnp.float32)).astype(xbc.dtype)


def mamba_forward(params, x, d_model: int, scfg: SSMConfig, init_state=None,
                  use_pallas: bool = False):
    """x: (B, S, d). Returns (y (B,S,d), cache dict)."""
    b, s, _ = x.shape
    din = scfg.d_inner(d_model)
    gn = scfg.n_groups * scfg.d_state
    nh = scfg.n_heads(d_model)
    z, xbc, dt_raw = _split_proj(params, x, d_model, scfg)
    conv_in = xbc
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xh = xbc[..., :din].reshape(b, s, nh, scfg.head_dim)
    bmat = xbc[..., din:din + gn].reshape(b, s, scfg.n_groups, scfg.d_state)
    cmat = xbc[..., din + gn:].reshape(b, s, scfg.n_groups, scfg.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    a_neg = -jnp.exp(params["A_log"])
    if use_pallas:
        from repro.kernels import ops as kops
        y, final = kops.ssd_scan(xh, dt, a_neg, bmat, cmat, chunk=scfg.chunk_size,
                                 init_state=init_state)
    else:
        y, final = ssd_chunked(xh, dt, a_neg, bmat, cmat, scfg.chunk_size,
                               init_state=init_state)
    y = y + (params["D"][None, None, :, None] * xh.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(b, s, din)
    y = L.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), params["norm"])
    out = y @ params["out_proj"]
    # decode cache: last (d_conv - 1) conv inputs + final SSM state
    k = scfg.d_conv
    conv_cache = conv_in[:, -(k - 1):, :] if s >= k - 1 else jnp.pad(
        conv_in, ((0, 0), (k - 1 - s, 0), (0, 0)))
    return out, {"conv": conv_cache, "state": final}


def mamba_decode(params, x, cache, d_model: int, scfg: SSMConfig):
    """x: (B, 1, d); cache: {"conv": (B, K-1, C), "state": (B, H, P, N)}."""
    b = x.shape[0]
    din = scfg.d_inner(d_model)
    gn = scfg.n_groups * scfg.d_state
    nh = scfg.n_heads(d_model)
    z, xbc, dt_raw = _split_proj(params, x, d_model, scfg)
    window = jnp.concatenate([cache["conv"], xbc], axis=1)        # (B, K, C)
    new_conv = window[:, 1:, :]
    w = params["conv_w"].astype(jnp.float32)                      # (C, K)
    conv_out = jnp.einsum("bkc,ck->bc", window.astype(jnp.float32), w)
    xbc1 = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32))
    xbc1 = xbc1.astype(x.dtype)[:, None, :]                       # (B,1,C)
    xh = xbc1[..., :din].reshape(b, nh, scfg.head_dim)
    bmat = xbc1[..., din:din + gn].reshape(b, scfg.n_groups, scfg.d_state)
    cmat = xbc1[..., din + gn:].reshape(b, scfg.n_groups, scfg.d_state)
    hpg = nh // scfg.n_groups
    bmat = jnp.repeat(bmat, hpg, axis=1)                          # (B,H,N)
    cmat = jnp.repeat(cmat, hpg, axis=1)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a_neg = -jnp.exp(params["A_log"])
    da = jnp.exp(dt * a_neg[None])
    state = cache["state"].astype(jnp.float32)
    state = state * da[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", xh.astype(jnp.float32) * dt[..., None], bmat.astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", state, cmat.astype(jnp.float32))
    y = y + params["D"][None, :, None] * xh.astype(jnp.float32)
    y = y.astype(x.dtype).reshape(b, 1, din)
    y = L.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), params["norm"])
    out = y @ params["out_proj"]
    return out, {"conv": new_conv, "state": state}
