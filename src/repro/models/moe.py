"""Mixture-of-Experts layer: top-k routing with capacity, expert-parallel.

Two dispatch implementations:

  * ``einsum`` -- classic one-hot dispatch/combine einsums (GShard/Switch
    style).  Robust under SPMD, but the dispatch einsum costs
    T*E*C*d MACs which can rival the expert matmuls themselves (visible in
    the roofline's MODEL_FLOPS/HLO_FLOPS ratio).
  * ``gather`` -- index-based dispatch (take / segment-sum combine): pure
    data movement, no dispatch FLOPs.  The beyond-paper optimized path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.distributed.api import constrain
from repro.models import layers as L


def init_moe(rng, d_model: int, mcfg: MoEConfig, gated: bool, dtype):
    ks = jax.random.split(rng, 5)
    p = {
        "router": L.init_dense(ks[0], d_model, mcfg.n_experts, jnp.float32),
        "w_in": _init_experts(ks[1], mcfg.n_experts, d_model, mcfg.d_ff_expert, dtype),
        "w_out": _init_experts(ks[2], mcfg.n_experts, mcfg.d_ff_expert, d_model, dtype),
    }
    if gated:
        p["w_gate"] = _init_experts(ks[3], mcfg.n_experts, d_model, mcfg.d_ff_expert, dtype)
    if mcfg.n_shared_experts:
        p["shared"] = L.init_mlp(ks[4], d_model, mcfg.d_ff_shared, gated, dtype)
    return p


def _init_experts(rng, e, d_in, d_out, dtype):
    scale = 1.0 / jnp.sqrt(jnp.asarray(d_in, jnp.float32))
    return (jax.random.normal(rng, (e, d_in, d_out), jnp.float32) * scale).astype(dtype)


def _capacity(n_tokens: int, mcfg: MoEConfig) -> int:
    c = int(mcfg.capacity_factor * mcfg.top_k * n_tokens / mcfg.n_experts) + 1
    return max(min(c, n_tokens), 1)


def _route(params, xf, mcfg: MoEConfig):
    """xf: (T, d) -> (top_w (T,k), top_i (T,k), aux_loss, probs)."""
    logits = (xf.astype(jnp.float32) @ params["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, mcfg.top_k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    # switch-style load balance loss
    me = jnp.mean(probs, axis=0)                                   # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_i, mcfg.n_experts, dtype=jnp.float32), axis=1),
        axis=0)
    aux = mcfg.n_experts * jnp.sum(me * ce) * mcfg.router_aux_weight
    return top_w, top_i, aux


def _expert_ffn(params, xd):
    """xd: (E, C, d) -> (E, C, d) via per-expert (Sw)iGLU."""
    h = jnp.einsum("ecd,edf->ecf", xd, params["w_in"])
    if "w_gate" in params:
        g = jnp.einsum("ecd,edf->ecf", xd, params["w_gate"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, params["w_out"])


import os

GROUP_SIZE = int(os.environ.get("REPRO_MOE_GROUP", 4096))
# routing-group tokens: capacity (and the dispatch tensor) is per group, as
# in Switch/GShard — a global capacity at 1M-token batches would be
# astronomically large (C ~ cf*k*T/E).  Dispatch/combine einsum flops are
# LINEAR in the group size (C ~ Tg), so REPRO_MOE_GROUP is a §Perf knob.


def moe_apply(params, x: jax.Array, mcfg: MoEConfig, impl: str = "einsum",
              group_size: int = GROUP_SIZE):
    """x: (B, S, d). Returns (y, aux_loss)."""
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    tg = min(group_size, t)
    if t % tg != 0:
        tg = t          # irregular small inputs: one group
    g = t // tg
    cap = _capacity(tg, mcfg)
    xg = constrain(xf.reshape(g, tg, d), "data", None, None)

    if impl == "einsum":
        # explicit group dim (no vmap) so SPMD sees the whole layout and the
        # sharding constraints below pin the cheap collective placement
        logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                            params["router"])
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_i = jax.lax.top_k(probs, mcfg.top_k)
        top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
        me = jnp.mean(probs, axis=1)                             # (G, E)
        ce = jnp.mean(jnp.sum(jax.nn.one_hot(
            top_i, mcfg.n_experts, dtype=jnp.float32), axis=2), axis=1)
        aux = jnp.mean(mcfg.n_experts * jnp.sum(me * ce, axis=-1)) \
            * mcfg.router_aux_weight
        pos = _positions_in_expert_grouped(top_i, mcfg, cap)     # (G, Tg, k)
        e_oh = jax.nn.one_hot(top_i, mcfg.n_experts, dtype=xf.dtype)
        c_oh = jax.nn.one_hot(pos, cap, dtype=xf.dtype)
        combine = jnp.einsum("gtke,gtkc,gtk->gtec", e_oh, c_oh,
                             top_w.astype(xf.dtype))
        dispatch = jnp.einsum("gtke,gtkc->gtec", e_oh, c_oh)
        xd = jnp.einsum("gtec,gtd->gecd", dispatch, xg)
        xd = constrain(xd, "data", "model", None, None)
        h = jnp.einsum("gecd,edf->gecf", xd, params["w_in"])
        if "w_gate" in params:
            gt = jnp.einsum("gecd,edf->gecf", xd, params["w_gate"])
            h = jax.nn.silu(gt) * h
        else:
            h = jax.nn.gelu(h)
        ye = jnp.einsum("gecf,efd->gecd", h, params["w_out"])
        y = jnp.einsum("gecd,gtec->gtd", ye, combine)
        y = constrain(y, "data", None, None).reshape(t, d)
        aux = aux
    elif impl == "gather":
        def one_group(xr):
            top_w, top_i, aux_g = _route(params, xr, mcfg)
            return _dispatch_gather(params, xr, top_w, top_i, mcfg, cap), aux_g
        yg, auxg = jax.vmap(one_group)(xg)
        y = yg.reshape(t, d)
        aux = jnp.mean(auxg)
    else:
        raise ValueError(f"unknown moe impl {impl!r}")

    if "shared" in params:
        y = y + L.mlp(params["shared"], xf)
    return y.reshape(b, s, d), aux


def _positions_in_expert_grouped(top_i, mcfg: MoEConfig, cap: int):
    """(G, Tg, k) slot indices within each group's expert buffers."""
    g, t, k = top_i.shape
    flat = top_i.reshape(g, t * k)
    oh = jax.nn.one_hot(flat, mcfg.n_experts, dtype=jnp.int32)   # (G, T*k, E)
    pos = jnp.cumsum(oh, axis=1) - oh
    pos = jnp.sum(pos * oh, axis=-1)
    return pos.reshape(g, t, k)


def _positions_in_expert(top_i, mcfg: MoEConfig, cap: int):
    """Slot of each (token, k) pair inside its expert's capacity buffer.

    Returns pos (T, k) int32 where overflowing pairs get pos >= cap (dropped
    by the one-hot / scatter downstream).
    """
    t, k = top_i.shape
    flat = top_i.reshape(-1)                                    # token-major, k fast
    oh = jax.nn.one_hot(flat, mcfg.n_experts, dtype=jnp.int32)  # (T*k, E)
    pos = jnp.cumsum(oh, axis=0) - oh                           # exclusive prefix count
    pos = jnp.sum(pos * oh, axis=-1)                            # (T*k,)
    return pos.reshape(t, k)


def _dispatch_einsum(params, xf, top_w, top_i, mcfg, cap):
    t, d = xf.shape
    pos = _positions_in_expert(top_i, mcfg, cap)                # (T, k)
    # (T, k) -> combine tensor (T, E, C); out-of-capacity one_hot -> all-zero
    e_oh = jax.nn.one_hot(top_i, mcfg.n_experts, dtype=xf.dtype)      # (T,k,E)
    c_oh = jax.nn.one_hot(pos, cap, dtype=xf.dtype)                    # (T,k,C)
    combine = jnp.einsum("tke,tkc,tk->tec", e_oh, c_oh, top_w.astype(xf.dtype))
    dispatch = jnp.einsum("tke,tkc->tec", e_oh, c_oh)
    xd = jnp.einsum("tec,td->ecd", dispatch, xf)
    ye = _expert_ffn(params, xd)
    return jnp.einsum("ecd,tec->td", ye, combine)


def _dispatch_gather(params, xf, top_w, top_i, mcfg, cap):
    """Index-based dispatch: no O(T*E*C*d) dispatch FLOPs."""
    t, d = xf.shape
    k = mcfg.top_k
    pos = _positions_in_expert(top_i, mcfg, cap)                # (T, k)
    keep = pos < cap
    # token id occupying slot (e, c); `t` indexes a zero row for empty slots.
    slot_token = jnp.full((mcfg.n_experts, cap), t, dtype=jnp.int32)
    flat_e = top_i.reshape(-1)
    flat_c = jnp.minimum(pos.reshape(-1), cap - 1)
    tok_ids = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    upd = jnp.where(keep.reshape(-1), tok_ids, t)
    slot_token = slot_token.at[flat_e, flat_c].min(upd)
    xz = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    xd = jnp.take(xz, slot_token.reshape(-1), axis=0).reshape(mcfg.n_experts, cap, d)
    ye = _expert_ffn(params, xd)                                 # (E, C, d)
    # combine: gather each (token, k) pair's slot output, weight, and sum
    ye_flat = ye.reshape(mcfg.n_experts * cap, d)
    gidx = flat_e * cap + flat_c
    yk = jnp.take(ye_flat, gidx, axis=0).reshape(t, k, d)
    w = jnp.where(keep, top_w, 0.0).astype(xf.dtype)
    return jnp.einsum("tkd,tk->td", yk, w)
