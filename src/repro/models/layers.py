"""Shared building blocks for every architecture family.

All functions are pure (params-in, activations-out) and mesh-agnostic; the
sharding of intermediates is steered by ``repro.distributed.api.constrain``
which is a no-op outside a mesh context.  Attention offers three
implementations:

  * ``naive``   -- materializes the (S, S) score matrix (oracle / tiny seqs),
  * ``chunked`` -- lax.scan over query chunks with online softmax; O(S * C)
                   memory, the XLA analogue of flash attention (default for
                   long sequences and the dry-run path),
  * ``pallas``  -- the Pallas TPU kernel from ``repro.kernels`` (validated in
                   interpret mode on CPU; the target path on real TPUs).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.api import constrain

DEFAULT_QUERY_CHUNK = 1024


# ---------------------------------------------------------------------------
# basics
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def init_dense(rng, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / jnp.sqrt(jnp.asarray(d_in, jnp.float32))
    return (jax.random.normal(rng, (d_in, d_out), jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n_heads, head_dim); positions: broadcastable to (..., S)."""
    freqs = rope_freqs(x.shape[-1], theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (..., S, hd/2)
    sin = jnp.sin(angles)[..., None, :]                          # (..., S, 1, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def init_mlp(rng, d_model: int, d_ff: int, gated: bool, dtype):
    ks = jax.random.split(rng, 3)
    p = {"w_in": init_dense(ks[0], d_model, d_ff, dtype),
         "w_out": init_dense(ks[1], d_ff, d_model, dtype)}
    if gated:
        p["w_gate"] = init_dense(ks[2], d_model, d_ff, dtype)
    return p


def mlp(params, x: jax.Array) -> jax.Array:
    h = x @ params["w_in"]
    if "w_gate" in params:
        h = jax.nn.silu(x @ params["w_gate"]) * h
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, "data", None, "model")
    return h @ params["w_out"]


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def init_attention(rng, d_model: int, n_heads: int, n_kv: int, head_dim: int, dtype):
    ks = jax.random.split(rng, 4)
    return {
        "wq": init_dense(ks[0], d_model, n_heads * head_dim, dtype).reshape(d_model, n_heads, head_dim),
        "wk": init_dense(ks[1], d_model, n_kv * head_dim, dtype).reshape(d_model, n_kv, head_dim),
        "wv": init_dense(ks[2], d_model, n_kv * head_dim, dtype).reshape(d_model, n_kv, head_dim),
        "wo": init_dense(ks[3], n_heads * head_dim, d_model, dtype).reshape(n_heads, head_dim, d_model),
    }


def _repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, n_kv, hd) -> (B, S, n_heads, hd) by group broadcast."""
    n_kv = k.shape[-2]
    if n_kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // n_kv, axis=-2)


def _mask_bias(q_pos, k_pos, window: Optional[int]) -> jax.Array:
    """Additive causal (+ sliding window) mask bias: (..., Sq, Sk) float32."""
    ok = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        ok &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def attention_naive(q, k, v, q_pos, k_pos, window: Optional[int] = None,
                    causal: bool = True) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Sk, Kv, hd). Returns (B, Sq, H, hd)."""
    h = q.shape[-2]
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32) * scale
    if causal:
        scores = scores + _mask_bias(q_pos, k_pos, window)[:, None]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out


def attention_chunked(q, k, v, q_pos, k_pos, window: Optional[int] = None,
                      causal: bool = True,
                      query_chunk: int = DEFAULT_QUERY_CHUNK) -> jax.Array:
    """Flash-style online-softmax attention, scanned over query chunks.

    Memory is O(Sq_chunk * Sk) per step instead of O(Sq * Sk).  For
    sliding-window layers only the KV slab that the chunk can see is sliced,
    making prefill O(S * (C + W)) instead of O(S^2).
    """
    b, sq, hq, hd = q.shape
    sk = k.shape[1]
    if sq % query_chunk != 0 or sq == query_chunk:
        return attention_naive(q, k, v, q_pos, k_pos, window, causal)
    n_chunks = sq // query_chunk
    k = _repeat_kv(k, hq)
    v = _repeat_kv(v, hq)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))

    # sliding window: each query chunk only sees a bounded KV slab.
    slab = sk
    if window is not None and causal:
        slab = min(sk, ((window + query_chunk + 127) // 128) * 128)

    qc = q.reshape(b, n_chunks, query_chunk, hq, hd).transpose(1, 0, 2, 3, 4)
    qp = q_pos.reshape(b, n_chunks, query_chunk).transpose(1, 0, 2)

    def body(_, xs):
        i, q_i, qp_i = xs
        if slab == sk:
            k_i, v_i, kp_i = k, v, k_pos
        else:
            # chunk i covers queries [i*C, (i+1)*C); visible kv start:
            start = jnp.maximum(i * query_chunk + query_chunk - slab, 0)
            start = jnp.minimum(start, sk - slab)
            k_i = jax.lax.dynamic_slice_in_dim(k, start, slab, axis=1)
            v_i = jax.lax.dynamic_slice_in_dim(v, start, slab, axis=1)
            kp_i = jax.lax.dynamic_slice_in_dim(k_pos, start, slab, axis=1)
        s = jnp.einsum("bqhd,bkhd->bhqk", q_i, k_i,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            s = s + _mask_bias(qp_i, kp_i, window)[:, None]
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v_i)
        return None, o

    _, out = jax.lax.scan(body, None,
                          (jnp.arange(n_chunks), qc, qp))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, hq, hd)


def attention_decode(q, k_cache, v_cache, cache_len, window: Optional[int] = None,
                     seq_sharded: bool = False):
    """Single-token decode attention.

    q: (B, 1, H, hd); caches: (B, L, Kv, hd) where L is the cache capacity
    (ring buffer for sliding-window layers).  ``cache_len`` (B,) int32 is the
    number of valid entries (== absolute position + 1 for full caches).

    ``seq_sharded``: the cache seq dim is context-parallel (model axis);
    constrain the score/prob tensors so the softmax stays seq-local with a
    small partial-max/sum collective — otherwise XLA gathers the whole cache
    per layer.
    """
    b, _, hq, hd = q.shape
    L = k_cache.shape[1]
    k = _repeat_kv(k_cache, hq)
    v = _repeat_kv(v_cache, hq)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32) * scale
    idx = jnp.arange(L)[None, :]                      # (1, L)
    valid = idx < jnp.minimum(cache_len, L)[:, None]  # ring buffer: all L valid once full
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    if seq_sharded:
        s = constrain(s, "data", None, None, "model")
    p = jax.nn.softmax(s, axis=-1)
    if seq_sharded:
        p = constrain(p, "data", None, None, "model")
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


def attention(q, k, v, q_pos, k_pos, window=None, causal=True, impl="chunked",
              query_chunk: int = DEFAULT_QUERY_CHUNK):
    if impl == "naive":
        return attention_naive(q, k, v, q_pos, k_pos, window, causal)
    if impl == "chunked":
        return attention_chunked(q, k, v, q_pos, k_pos, window, causal, query_chunk)
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, q_pos, k_pos, window=window, causal=causal)
    raise ValueError(f"unknown attention impl {impl!r}")


# ---------------------------------------------------------------------------
# attention block (projections + rope + residual-less core)
# ---------------------------------------------------------------------------
def attn_block(params, x, positions, theta, window=None, causal=True,
               impl="chunked", kv_override=None):
    """x: (B, S, d). Returns (out, (k, v)) so callers can build caches."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    q = constrain(q, "data", None, "model", None)
    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
        k = apply_rope(k, positions, theta)
        kv_pos = positions
    else:  # cross attention: kv comes from the encoder
        enc = kv_override
        k = jnp.einsum("bsd,dhk->bshk", enc, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc, params["wv"])
        kv_pos = jnp.broadcast_to(jnp.arange(enc.shape[1])[None], enc.shape[:2])
        causal = False
    q = apply_rope(q, positions, theta) if kv_override is None else q
    o = attention(q, k, v, positions, kv_pos, window=window, causal=causal, impl=impl)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
    return out, (k, v)
