"""Generic layer stack: scan over repeating pattern blocks.

Architectures repeat a short layer pattern (gemma3: 5 local + 1 global;
jamba: 7 mamba + 1 attention with alternating MoE; most others: period 1).
We run `lax.scan` over the repeated blocks (keeping the lowered HLO to ~one
block regardless of depth) and unroll only the non-repeating remainder
layers.  Parameters/caches for scanned blocks carry a leading `n_rep` dim.
"""
from __future__ import annotations

import math
import os
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.api import constrain
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S


_SEQ_PARALLEL = os.environ.get("REPRO_SEQ_PARALLEL", "0") == "1"


class LayerSpec(NamedTuple):
    is_attn: bool
    is_global: bool
    is_moe: bool
    has_cross: bool = False


class StackPlan(NamedTuple):
    period: int
    n_rep: int
    pattern: tuple            # LayerSpec per pattern position
    rem: tuple                # LayerSpec per remainder layer


def _spec(cfg: ModelConfig, i: int, cross: bool) -> LayerSpec:
    return LayerSpec(cfg.is_attn_layer(i), cfg.is_global_layer(i),
                     cfg.is_moe_layer(i), cross)


def plan(cfg: ModelConfig, *, cross: bool = False,
         n_layers: Optional[int] = None) -> StackPlan:
    n = n_layers if n_layers is not None else cfg.n_layers
    period = 1
    if cfg.sliding_window is not None and cfg.global_every > 0:
        period = math.lcm(period, cfg.global_every)
    if cfg.family == "hybrid" and cfg.attn_every > 0:
        period = math.lcm(period, cfg.attn_every)
    if cfg.moe is not None:
        period = math.lcm(period, cfg.moe.every)
    period = min(period, n)
    n_rep = n // period
    pattern = tuple(_spec(cfg, i, cross) for i in range(period))
    rem = tuple(_spec(cfg, n_rep * period + j, cross)
                for j in range(n - n_rep * period))
    return StackPlan(period, n_rep, pattern, rem)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_layer(rng, cfg: ModelConfig, spec: LayerSpec):
    d = cfg.d_model
    dt = cfg.dtype
    ks = jax.random.split(rng, 6)
    p = {"ln1": jnp.zeros((d,), dt)}
    if spec.is_attn:
        p["attn"] = L.init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.head_dim_, dt)
    else:
        p["ssm"] = S.init_mamba(ks[1], d, cfg.ssm, dt)
    if spec.has_cross:
        p["ln_x"] = jnp.zeros((d,), dt)
        p["cross"] = L.init_attention(ks[2], d, cfg.n_heads, cfg.n_kv_heads,
                                      cfg.head_dim_, dt)
    if spec.is_moe:
        p["ln2"] = jnp.zeros((d,), dt)
        p["moe"] = M.init_moe(ks[3], d, cfg.moe, cfg.mlp_gated, dt)
    elif cfg.d_ff > 0:
        p["ln2"] = jnp.zeros((d,), dt)
        p["mlp"] = L.init_mlp(ks[4], d, cfg.d_ff, cfg.mlp_gated, dt)
    return p


def init_stack(rng, cfg: ModelConfig, pl: StackPlan):
    blocks = []
    for j, spec in enumerate(pl.pattern):
        reps = [init_layer(jax.random.fold_in(rng, r * pl.period + j), cfg, spec)
                for r in range(pl.n_rep)]
        blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *reps))
    rem = [init_layer(jax.random.fold_in(rng, pl.n_rep * pl.period + j), cfg, spec)
           for j, spec in enumerate(pl.rem)]
    return {"blocks": tuple(blocks), "rem": tuple(rem)}


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------
def _layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int, capacity: int,
                 enc_len: int = 0):
    dt = cfg.dtype
    c = {}
    if spec.is_attn:
        cap = capacity
        if cfg.sliding_window is not None and not spec.is_global:
            cap = min(cfg.sliding_window, capacity)
        kv, hd = cfg.n_kv_heads, cfg.head_dim_
        c["k"] = jnp.zeros((batch, cap, kv, hd), dt)
        c["v"] = jnp.zeros((batch, cap, kv, hd), dt)
    else:
        s = cfg.ssm
        conv_dim = s.d_inner(cfg.d_model) + 2 * s.n_groups * s.d_state
        c["conv"] = jnp.zeros((batch, s.d_conv - 1, conv_dim), dt)
        c["state"] = jnp.zeros((batch, s.n_heads(cfg.d_model), s.head_dim,
                                s.d_state), jnp.float32)
    if spec.has_cross:
        kv, hd = cfg.n_kv_heads, cfg.head_dim_
        c["xk"] = jnp.zeros((batch, enc_len, kv, hd), dt)
        c["xv"] = jnp.zeros((batch, enc_len, kv, hd), dt)
    return c


def init_cache(cfg: ModelConfig, pl: StackPlan, batch: int, capacity: int,
               enc_len: int = 0):
    blocks = []
    for spec in pl.pattern:
        one = _layer_cache(cfg, spec, batch, capacity, enc_len)
        blocks.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x, (pl.n_rep,) + x.shape), one))
    rem = [_layer_cache(cfg, spec, batch, capacity, enc_len) for spec in pl.rem]
    return {"blocks": tuple(blocks), "rem": tuple(rem)}


# ---------------------------------------------------------------------------
# single layer application
# ---------------------------------------------------------------------------
def layer_apply(params, cfg: ModelConfig, spec: LayerSpec, x, positions, *,
                impl="chunked", moe_impl="einsum", enc_out=None, cache=None,
                cache_len=None, mode="train", capacity: Optional[int] = None):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    h = L.rms_norm(x, params["ln1"], cfg.norm_eps)
    window = None
    if cfg.sliding_window is not None and not spec.is_global:
        window = cfg.sliding_window

    if spec.is_attn:
        if mode == "decode":
            a, new_kv = _attn_decode(params["attn"], cfg, h, cache, cache_len, window)
            new_cache.update(new_kv)
        else:
            a, (k, v) = L.attn_block(params["attn"], h, positions, cfg.rope_theta,
                                     window=window, causal=True, impl=impl)
            if mode == "prefill":
                new_cache.update(_build_kv_cache(cfg, k, v, window, capacity))
    else:
        if mode == "decode":
            a, st = S.mamba_decode(params["ssm"], h, cache, cfg.d_model, cfg.ssm)
        else:
            a, st = S.mamba_forward(params["ssm"], h, cfg.d_model, cfg.ssm)
        if mode != "train":
            new_cache.update(st)
    x = x + a

    if spec.has_cross:
        h = L.rms_norm(x, params["ln_x"], cfg.norm_eps)
        if mode == "decode":
            q = jnp.einsum("bsd,dhk->bshk", h, params["cross"]["wq"])
            o = L.attention_decode(q, cache["xk"], cache["xv"],
                                   jnp.full((x.shape[0],), cache["xk"].shape[1]))
            a = jnp.einsum("bshk,hkd->bsd", o, params["cross"]["wo"])
            new_cache["xk"], new_cache["xv"] = cache["xk"], cache["xv"]
        else:
            a, (xk, xv) = L.attn_block(params["cross"], h, positions,
                                       cfg.rope_theta, impl="naive",
                                       kv_override=enc_out)
            if mode == "prefill":
                new_cache["xk"], new_cache["xv"] = xk, xv
        x = x + a

    if "moe" in params:
        h = L.rms_norm(x, params["ln2"], cfg.norm_eps)
        mo, aux = M.moe_apply(params["moe"], h, cfg.moe, impl=moe_impl)
        x = x + mo
    elif "mlp" in params:
        h = L.rms_norm(x, params["ln2"], cfg.norm_eps)
        x = x + L.mlp(params["mlp"], h)
    if _SEQ_PARALLEL and mode != "decode":
        # Megatron-SP-style: keep the residual stream sequence-sharded over
        # `model` between layers; XLA turns the per-layer f32 all-reduce into
        # a bf16 reduce-scatter + all-gather pair (§Perf hillclimb knob).
        x = constrain(x, "data", "model", None)
    else:
        x = constrain(x, "data", None, None)
    return x, new_cache, aux


def _build_kv_cache(cfg, k, v, window, capacity):
    """Arrange prefill K/V into the decode cache layout."""
    b, s = k.shape[:2]
    if window is not None:
        cap = min(window, capacity if capacity else window)
        if s >= cap:
            k_c, v_c = k[:, -cap:], v[:, -cap:]
            shift = s % cap
            k_c = jnp.roll(k_c, shift, axis=1)
            v_c = jnp.roll(v_c, shift, axis=1)
        else:
            pad = cap - s
            k_c = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v_c = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return {"k": k_c, "v": v_c}
    cap = capacity if capacity else s
    if cap == s:
        return {"k": k, "v": v}
    pad = cap - s
    k_c = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    v_c = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return {"k": k_c, "v": v_c}


def _attn_decode(params, cfg, h, cache, cache_len, window):
    """h: (B, 1, d). Insert the new K/V and attend over the cache."""
    b = h.shape[0]
    pos = jnp.full((b, 1), cache_len, jnp.int32)
    q = jnp.einsum("bsd,dhk->bshk", h, params["wq"])
    k1 = jnp.einsum("bsd,dhk->bshk", h, params["wk"])
    v1 = jnp.einsum("bsd,dhk->bshk", h, params["wv"])
    from repro.distributed.api import constrain as _con
    from repro.distributed.api import mesh_axis_size as _mas
    # engage the context-parallel decode plan only when the cache is big
    # enough that gathering it would dominate (small sliding-window ring
    # buffers are cheaper to gather than to re-shard q/k/v around — measured
    # 12% regression on starcoder2's 4k windows, see §Perf).
    seq_sharded = (cache["k"].shape[-2] % max(_mas("model"), 1) != 0
                   and cache["k"].shape[-3] > 8192)
    if seq_sharded:
        # context-parallel cache (kv heads don't divide the model axis; the
        # cache seq dim is model-sharded instead): replicate the tiny query
        # heads so the q@K einsum stays seq-local — otherwise XLA gathers
        # the whole cache per layer (EXPERIMENTS.md §Perf/kimi).
        q = _con(q, "data", None, None, None)
        k1 = _con(k1, "data", None, None, None)
        v1 = _con(v1, "data", None, None, None)
    q = L.apply_rope(q, pos, cfg.rope_theta)
    k1 = L.apply_rope(k1, pos, cfg.rope_theta)
    cap = cache["k"].shape[1]
    idx = jnp.mod(cache_len, cap)
    # masked insert instead of dynamic_update_slice: a DUS at a traced index
    # along a SHARDED cache dim triggers SPMD "involuntary full
    # rematerialization" (an f32 all-gather of the whole cache per layer —
    # see EXPERIMENTS.md §Perf/kimi); the select keeps every shard local and
    # fuses into the (donated, aliased) cache buffer.
    mask = (jax.lax.broadcasted_iota(jnp.int32, (1, cap, 1, 1), 1) == idx)
    k_c = jnp.where(mask, k1, cache["k"])
    v_c = jnp.where(mask, v1, cache["v"])
    valid = jnp.full((b,), cache_len + 1, jnp.int32)
    o = L.attention_decode(q, k_c, v_c, valid, window=window,
                           seq_sharded=seq_sharded)
    a = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
    return a, {"k": k_c, "v": v_c}


# ---------------------------------------------------------------------------
# full stack application
# ---------------------------------------------------------------------------
def apply_stack(params, cfg: ModelConfig, pl: StackPlan, x, positions, *,
                impl="chunked", moe_impl="einsum", enc_out=None, caches=None,
                cache_len=None, mode="train", capacity=None, remat=False,
                unroll=False):
    """Returns (x, new_caches, aux_total).

    ``unroll=True`` replaces the lax.scan over repeated blocks with a python
    loop — used by the dry-run cost probes (XLA's cost_analysis counts a
    while-loop body once, so scanned programs under-report flops).
    """
    want_cache = mode in ("prefill", "decode")

    def block_fn(x, block_params, block_caches):
        aux = jnp.zeros((), jnp.float32)
        new_caches = []
        for j, spec in enumerate(pl.pattern):
            cache_j = block_caches[j] if block_caches is not None else None
            x, nc, a = layer_apply(
                block_params[j], cfg, spec, x, positions, impl=impl,
                moe_impl=moe_impl, enc_out=enc_out, cache=cache_j,
                cache_len=cache_len, mode=mode, capacity=capacity)
            new_caches.append(nc)
            aux = aux + a
        return x, tuple(new_caches), aux

    if remat:
        block_fn = jax.checkpoint(block_fn)

    if pl.n_rep > 0 and unroll:
        aux = jnp.zeros((), jnp.float32)
        reps = []
        for r in range(pl.n_rep):
            bp = jax.tree.map(lambda t: t[r], params["blocks"])
            bc = (jax.tree.map(lambda t: t[r], caches["blocks"])
                  if caches is not None else None)
            x, nc, a = block_fn(x, bp, bc)
            aux = aux + a
            reps.append(nc)
        new_blocks = (jax.tree.map(lambda *xs: jnp.stack(xs), *reps)
                      if want_cache else None)
    elif pl.n_rep > 0:
        if want_cache:
            def body(carry, xs):
                x, aux = carry
                bp, bc = xs if caches is not None else (xs, None)
                x, nc, a = block_fn(x, bp, bc)
                return (x, aux + a), nc
            xs = (params["blocks"], caches["blocks"]) if caches is not None \
                else params["blocks"]
            (x, aux), new_blocks = jax.lax.scan(
                body, (x, jnp.zeros((), jnp.float32)), xs)
        else:
            def body(carry, bp):
                x, aux = carry
                x, _, a = block_fn(x, bp, None)
                return (x, aux + a), None
            (x, aux), _ = jax.lax.scan(
                body, (x, jnp.zeros((), jnp.float32)), params["blocks"])
            new_blocks = None
    else:
        aux = jnp.zeros((), jnp.float32)
        new_blocks = caches["blocks"] if caches else None

    new_rem = []
    for j, spec in enumerate(pl.rem):
        cache_j = caches["rem"][j] if caches is not None else None
        x, nc, a = layer_apply(
            params["rem"][j], cfg, spec, x, positions, impl=impl,
            moe_impl=moe_impl, enc_out=enc_out, cache=cache_j,
            cache_len=cache_len, mode=mode, capacity=capacity)
        new_rem.append(nc)
        aux = aux + a

    new_caches = {"blocks": new_blocks, "rem": tuple(new_rem)} if want_cache else None
    return x, new_caches, aux
