from repro.models import layers, model, moe, ssm, stack  # noqa: F401
