"""Top-level model API shared by all 10 architectures.

  init(rng, cfg)                          -> params
  forward(params, cfg, batch, ...)        -> (hidden (B,S,d), aux)
  logits(params, cfg, hidden)             -> (B, S, V)
  prefill(params, cfg, batch, ...)        -> (hidden_last (B,d), caches)
  decode_step(params, cfg, caches, t, tok)-> (logits (B,V), caches)

``batch`` keys: "tokens" (B,S) int32 always; "frames" (B,F,d) for encdec
(whisper frame-embedding stub); "patches" (B,P,d) for vlm (projected patch
stub).  Multimodal prefixes are prepended to the token embeddings; the
decode path operates past the prefix.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.api import constrain
from repro.models import layers as L
from repro.models import stack as ST


def _plans(cfg: ModelConfig):
    dec = ST.plan(cfg, cross=(cfg.family == "encdec"))
    enc = ST.plan(cfg, cross=False, n_layers=cfg.n_encoder_layers) \
        if cfg.family == "encdec" else None
    return dec, enc


def init(rng, cfg: ModelConfig):
    dec_plan, enc_plan = _plans(cfg)
    ks = jax.random.split(rng, 4)
    scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.d_model, jnp.float32))
    params = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model), jnp.float32)
                  * scale).astype(cfg.dtype),
        "stack": ST.init_stack(ks[1], cfg, dec_plan),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
    }
    if enc_plan is not None:
        # whisper encoder: non-causal self-attn layers over frame embeddings
        params["enc_stack"] = ST.init_stack(ks[2], cfg, enc_plan)
        params["enc_norm"] = jnp.zeros((cfg.d_model,), cfg.dtype)
    return params


def _embed(params, cfg, tokens):
    x = jnp.take(params["embed"], tokens, axis=0)
    return constrain(x, "data", None, None)


def _prefix(cfg, batch):
    if cfg.family == "vlm" and "patches" in batch:
        return batch["patches"]
    return None


def _encode(params, cfg: ModelConfig, frames, impl, unroll=False):
    """Whisper encoder over precomputed frame embeddings (stub frontend)."""
    _, enc_plan = _plans(cfg)
    pos = jnp.broadcast_to(jnp.arange(frames.shape[1])[None], frames.shape[:2])
    # encoder attention is bidirectional: use causal=False via a dedicated path
    x = frames.astype(cfg.dtype)
    pl = enc_plan

    def block_fn(x, block_params):
        for j, spec in enumerate(pl.pattern):
            p = block_params[j]
            h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
            a, _ = L.attn_block(p["attn"], h, pos, cfg.rope_theta,
                                causal=False, impl="naive")
            x = x + a
            h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
            x = x + L.mlp(p["mlp"], h)
        return x

    if unroll:
        for r in range(pl.n_rep):
            x = block_fn(x, jax.tree.map(lambda t: t[r],
                                         params["enc_stack"]["blocks"]))
    else:
        def body(x, bp):
            return block_fn(x, bp), None
        x, _ = jax.lax.scan(body, x, params["enc_stack"]["blocks"])
    for p in params["enc_stack"]["rem"]:
        x = block_fn(x, (p,))
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward(params, cfg: ModelConfig, batch, *, impl="chunked",
            moe_impl="einsum", remat=False, unroll=False):
    """Full-sequence forward (training / eval). Returns (hidden, aux)."""
    dec_plan, _ = _plans(cfg)
    x = _embed(params, cfg, batch["tokens"])
    prefix = _prefix(cfg, batch)
    if prefix is not None:
        x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(params, cfg, batch["frames"], impl, unroll=unroll)
    x, _, aux = ST.apply_stack(params["stack"], cfg, dec_plan, x, positions,
                               impl=impl, moe_impl=moe_impl, enc_out=enc_out,
                               mode="train", remat=remat, unroll=unroll)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if prefix is not None:
        x = x[:, prefix.shape[1]:]
    return x, aux


def logits(params, cfg: ModelConfig, hidden):
    out = jnp.einsum("bsd,vd->bsv", hidden, params["embed"])
    return constrain(out, "data", None, "model")


def prefill(params, cfg: ModelConfig, batch, *, impl="chunked",
            moe_impl="einsum", capacity: Optional[int] = None, unroll=False):
    """Process the prompt; returns (hidden_last (B, d), caches, prompt_len)."""
    dec_plan, _ = _plans(cfg)
    x = _embed(params, cfg, batch["tokens"])
    prefix = _prefix(cfg, batch)
    if prefix is not None:
        x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    b, s = x.shape[:2]
    cap = capacity if capacity else s
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(params, cfg, batch["frames"], impl, unroll=unroll)
    x, caches, _ = ST.apply_stack(params["stack"], cfg, dec_plan, x, positions,
                                  impl=impl, moe_impl=moe_impl, enc_out=enc_out,
                                  mode="prefill", capacity=cap, unroll=unroll)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x[:, -1], caches, s


def decode_step(params, cfg: ModelConfig, caches, cache_len, tokens, *,
                moe_impl="einsum", unroll=False):
    """tokens: (B, 1) int32; cache_len: scalar int32 (current context length).

    Returns (logits (B, V), new_caches).
    """
    dec_plan, _ = _plans(cfg)
    x = _embed(params, cfg, tokens)
    b = x.shape[0]
    positions = jnp.full((b, 1), cache_len, jnp.int32)
    x, caches, _ = ST.apply_stack(params["stack"], cfg, dec_plan, x, positions,
                                  moe_impl=moe_impl, caches=caches,
                                  cache_len=cache_len, mode="decode",
                                  unroll=unroll)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    lg = jnp.einsum("bsd,vd->bsv", x, params["embed"])[:, 0]
    return constrain(lg, "data", "model"), caches


def init_cache(cfg: ModelConfig, batch: int, capacity: int):
    dec_plan, _ = _plans(cfg)
    enc_len = cfg.encoder_seq if cfg.family == "encdec" else 0
    return ST.init_cache(cfg, dec_plan, batch, capacity, enc_len=enc_len)
