"""Parameter / batch / cache sharding rules for every architecture family.

Logical layout:
  * serving + training: attention heads, FFN hidden, experts, SSM heads and
    the vocabulary shard over the ``model`` axis (Megatron-style TP / expert
    parallel); the batch shards over ``data`` (x ``pod`` multi-pod).
  * training additionally FSDP-shards each >=2D weight's largest replicated
    dim over ``data`` (x ``pod``) — parameters/optimizer state stay sharded,
    XLA all-gathers per scanned block.
  * long-context decode (batch 1): the KV cache seq dim context-parallels
    over ``data``; XLA inserts the partial-softmax combine.

Axes that do not divide a dim are dropped (replicate instead) — e.g.
starcoder2's kv=2 heads cannot split 16 ways, so K/V stay replicated over
``model`` while Q (24 heads, pad-free divisors picked per arch) shards.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    data: Tuple[str, ...]            # ("data",) or ("pod", "data")
    model: Tuple[str, ...]           # ("model",)

    @classmethod
    def of(cls, mesh: Mesh) -> "MeshAxes":
        names = mesh.axis_names
        data = tuple(a for a in ("pod", "data") if a in names)
        return cls(data=data, model=("model",) if "model" in names else ())


def _axis_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    s = 1
    for a in axes:
        s *= mesh.shape[a]
    return s


def _fit(mesh: Mesh, dim: int, axes: Tuple[str, ...]):
    """axes if they evenly divide dim, else None (replicate)."""
    if not axes or dim % _axis_size(mesh, axes) != 0:
        return None
    return axes if len(axes) > 1 else axes[0]


def _leaf_spec(path: str, shape: Tuple[int, ...], mesh: Mesh, ax: MeshAxes,
               fsdp: bool, expert_mode: str = "none") -> P:
    """Spec for one parameter leaf, identified by its tree path string.

    ``expert_mode`` (§Perf hillclimbs):
      * "hidden_data": additionally shard expert FFN hidden over ``data``
        (2D-resident expert weights — no per-step weight all-gather),
      * "hidden_model": shard expert FFN hidden over ``model`` (for expert
        counts that don't divide the model axis, e.g. qwen2's 60)."""
    nd = len(shape)
    spec: list = [None] * nd

    def put(dim: int, axes: Tuple[str, ...]) -> bool:
        if 0 <= dim < nd and spec[dim] is None:
            got = _fit(mesh, shape[dim], axes)
            if got is not None:
                spec[dim] = got
                return True
        return False

    model = ax.model
    # dims are right-aligned (stacked scan params add leading dims)
    if path.endswith("embed"):
        put(nd - 2, model)                       # vocab
    elif "wq" in path or ("wk" in path) or ("wv" in path):
        put(nd - 2, model)                       # heads
    elif "wo" in path:
        put(nd - 3, model)                       # heads
    elif "w_in" in path or "w_gate" in path:
        if "moe" in path and nd >= 3:
            put(nd - 3, model)                   # experts
            if expert_mode == "hidden_data":
                put(nd - 1, ax.data)             # expert hidden over data
                return P(*spec)
            if expert_mode == "hidden_model":
                put(nd - 1, model)
                return P(*spec)
        else:
            put(nd - 1, model)                   # ffn hidden
    elif "w_out" in path:
        if "moe" in path and nd >= 3:
            put(nd - 3, model)                   # experts
            if expert_mode == "hidden_data":
                put(nd - 2, ax.data)
                return P(*spec)
            if expert_mode == "hidden_model":
                put(nd - 2, model)
                return P(*spec)
        else:
            put(nd - 2, model)                   # ffn hidden
    elif "router" in path:
        put(nd - 1, model)                       # experts
    elif "in_proj" in path:
        put(nd - 1, model)                       # ssm inner
    elif "out_proj" in path:
        put(nd - 2, model)                       # ssm inner
    elif "conv_w" in path:
        put(nd - 2, model)
    elif path.endswith(("conv_b", "A_log", "D", "dt_bias")) or path.endswith("norm"):
        put(nd - 1, model)

    if fsdp and nd >= 2:
        # shard the largest still-replicated dim over data(+pod)
        order = sorted(range(nd), key=lambda d: -shape[d])
        for d in order:
            if spec[d] is None and put(d, ax.data):
                break
    return P(*spec)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(params_shape, mesh: Mesh, *, fsdp: bool = False,
                expert_mode: str = "none"):
    """PartitionSpec pytree matching a params (or ShapeDtypeStruct) pytree."""
    ax = MeshAxes.of(mesh)

    def one(path, leaf):
        return _leaf_spec(_path_str(path), leaf.shape, mesh, ax, fsdp,
                          expert_mode)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    """Specs for the input batch dict."""
    ax = MeshAxes.of(mesh)
    bdim = _fit(mesh, shape.global_batch, ax.data)

    def spec_for(name: str, arr_shape):
        return P(bdim, *([None] * (len(arr_shape) - 1)))

    return spec_for


def cache_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh, caches_shape):
    """Decode cache specs: batch over data when divisible, else context-
    parallel (KV seq over data) + heads/experts over model."""
    ax = MeshAxes.of(mesh)
    batch_ok = shape.global_batch % max(_axis_size(mesh, ax.data), 1) == 0 \
        and shape.global_batch >= _axis_size(mesh, ax.data)

    def one(path, leaf):
        p = _path_str(path)
        nd = len(leaf.shape)
        spec: list = [None] * nd
        if p.endswith("k") or p.endswith("v") or "xk" in p or "xv" in p:
            # (..., B, L, kv, hd)
            b_dim, l_dim, h_dim = nd - 4, nd - 3, nd - 2
            if batch_ok:
                spec[b_dim] = _fit(mesh, leaf.shape[b_dim], ax.data)
            else:
                spec[l_dim] = _fit(mesh, leaf.shape[l_dim], ax.data)
            # kv heads over model when they divide; otherwise context-
            # parallel the cache seq dim over model (GQA kv < mesh model)
            spec[h_dim] = _fit(mesh, leaf.shape[h_dim], ax.model)
            if spec[h_dim] is None and spec[l_dim] is None:
                spec[l_dim] = _fit(mesh, leaf.shape[l_dim], ax.model)
        elif p.endswith("conv"):
            b_dim, c_dim = nd - 3, nd - 1
            if batch_ok:
                spec[b_dim] = _fit(mesh, leaf.shape[b_dim], ax.data)
            spec[c_dim] = _fit(mesh, leaf.shape[c_dim], ax.model)
        elif p.endswith("state"):
            b_dim, h_dim = nd - 4, nd - 3
            if batch_ok:
                spec[b_dim] = _fit(mesh, leaf.shape[b_dim], ax.data)
            spec[h_dim] = _fit(mesh, leaf.shape[h_dim], ax.model)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, caches_shape)


def axis_rules(mesh: Mesh) -> dict:
    ax = MeshAxes.of(mesh)
    return {"data": ax.data, "model": ax.model}


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
