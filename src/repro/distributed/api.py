"""Mesh-agnostic sharding hints.

Model code calls ``constrain(x, "data", None, "model")`` to pin intermediate
activations; outside a mesh context (CPU unit tests, single device) this is
the identity, so the model zoo stays runnable anywhere.  Axis *names* given
here are logical; ``resolve_axis`` maps them onto whatever physical mesh axes
exist (the multi-pod mesh folds "pod" into "data" for activations).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

# logical -> physical axis mapping; "data" may expand to ("pod", "data").
_ACTIVE_RULES: Optional[dict] = None


def set_axis_rules(rules: Optional[dict]) -> None:
    """rules: {"data": ("pod", "data"), "model": ("model",)} or None to clear."""
    global _ACTIVE_RULES
    _ACTIVE_RULES = rules


def get_axis_rules() -> Optional[dict]:
    return _ACTIVE_RULES


def _active_mesh_axes() -> Optional[Tuple[Tuple[str, ...], Tuple[int, ...]]]:
    """(axis_names, axis_sizes) of the ambient mesh, or None when no mesh
    is active.  Newer jax exposes ``jax.sharding.get_abstract_mesh``; older
    releases track the ``with mesh:`` context in thread resources."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        mesh = get()
        if mesh is None or mesh.empty:
            return None
        return tuple(mesh.axis_names), tuple(mesh.axis_sizes)
    try:
        from jax._src import mesh as _mesh_lib
        mesh = _mesh_lib.thread_resources.env.physical_mesh
    except Exception:
        return None
    if mesh is None or mesh.empty:
        return None
    return tuple(mesh.axis_names), tuple(mesh.shape[n]
                                         for n in mesh.axis_names)


def resolve(spec_names: Tuple[Optional[str], ...]) -> P:
    rules = _ACTIVE_RULES or {}
    out = []
    for name in spec_names:
        if name is None:
            out.append(None)
        else:
            phys = rules.get(name, ())
            if not phys:
                out.append(None)
            elif len(phys) == 1:
                out.append(phys[0])
            else:
                out.append(tuple(phys))
    return P(*out)


def mesh_axis_size(logical: str) -> int:
    """Active-mesh size of a logical axis ("data"/"model"); 1 if no mesh."""
    if _ACTIVE_RULES is None:
        return 1
    axes = _active_mesh_axes()
    if axes is None:
        return 1
    sizes = dict(zip(*axes))
    out = 1
    for phys in _ACTIVE_RULES.get(logical, ()):
        out *= sizes.get(phys, 1)
    return out


def constrain(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """with_sharding_constraint if a mesh is active, else identity.

    Skips axes whose size does not divide the dim, and skips entirely on
    rank mismatch (helpers are reused at several ranks)."""
    if _ACTIVE_RULES is None:
        return x
    mesh_axes = _active_mesh_axes()
    if mesh_axes is None:
        return x
    if getattr(x, "ndim", None) != len(names):
        return x
    spec = resolve(names)
    # drop axis names the current mesh lacks or whose size doesn't divide
    axes = dict(zip(*mesh_axes))

    def keep(entry, dim):
        if entry is None:
            return None
        if isinstance(entry, tuple):
            kept = tuple(a for a in entry if a in axes)
            if not kept:
                return None
            size = 1
            for a in kept:
                size *= axes[a]
            return kept if dim % size == 0 else None
        if entry not in axes or dim % axes[entry] != 0:
            return None
        return entry

    spec = P(*[keep(e, d) for e, d in zip(spec, x.shape)])
    return jax.lax.with_sharding_constraint(x, spec)
