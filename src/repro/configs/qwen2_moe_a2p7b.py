"""qwen2-moe-a2.7b [moe] — 60 routed experts top-4 + 4 shared experts.

[hf:Qwen/Qwen1.5-MoE-A2.7B] 24L d_model=2048 16H (kv=16) d_ff_expert=1408
vocab=151936.
"""
import dataclasses

from repro.configs.base import ModelConfig, MoEConfig

ARCH_ID = "qwen2-moe-a2.7b"


def full() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="moe",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=0,
        vocab=151_936,
        moe=MoEConfig(
            n_experts=60,
            top_k=4,
            d_ff_expert=1408,
            n_shared_experts=4,
            d_ff_shared=4 * 1408,
            capacity_factor=1.25,
        ),
        rope_theta=1_000_000.0,
        citation="hf:Qwen/Qwen1.5-MoE-A2.7B",
    )


def reduced(n_layers: int = 2, d_model: int = 256) -> ModelConfig:
    return dataclasses.replace(
        full(),
        n_layers=n_layers,
        d_model=d_model,
        n_heads=4,
        n_kv_heads=4,
        vocab=512,
        moe=MoEConfig(
            n_experts=4,
            top_k=2,
            d_ff_expert=d_model,
            n_shared_experts=2,
            d_ff_shared=2 * d_model,
            capacity_factor=2.0,
        ),
        dtype="float32",
    )


def variant_family():
    return [
        (f"{ARCH_ID}-n", reduced(2, 128), 57.9),
        (f"{ARCH_ID}-s", reduced(2, 256), 65.4),
        (f"{ARCH_ID}-m", reduced(4, 384), 71.7),
    ]
