"""starcoder2-3b [dense] — GQA (kv=2), RoPE, sliding-window 4096 (all layers).

[arXiv:2402.19173] 30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.
"""
import dataclasses

from repro.configs.base import ModelConfig

ARCH_ID = "starcoder2-3b"


def full() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="dense",
        n_layers=30,
        d_model=3072,
        n_heads=24,
        n_kv_heads=2,
        head_dim=128,
        d_ff=12288,
        vocab=49_152,
        sliding_window=4096,
        global_every=0,             # all layers sliding-window
        rope_theta=100_000.0,
        mlp_gated=False,
        citation="arXiv:2402.19173",
    )


def reduced(n_layers: int = 2, d_model: int = 256) -> ModelConfig:
    return dataclasses.replace(
        full(),
        n_layers=n_layers,
        d_model=d_model,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        d_ff=4 * d_model,
        vocab=512,
        sliding_window=64,
        dtype="float32",
    )


def variant_family():
    return [
        (f"{ARCH_ID}-n", reduced(2, 128), 51.0),
        (f"{ARCH_ID}-s", reduced(2, 256), 60.4),
        (f"{ARCH_ID}-m", reduced(4, 384), 65.9),
    ]
