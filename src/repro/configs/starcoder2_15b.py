"""starcoder2-15b [dense] — GQA (kv=4), RoPE, sliding-window 4096 (all layers).

[arXiv:2402.19173] 40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.
"""
import dataclasses

from repro.configs.base import ModelConfig

ARCH_ID = "starcoder2-15b"


def full() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="dense",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=4,
        head_dim=128,
        d_ff=24576,
        vocab=49_152,
        sliding_window=4096,
        global_every=0,
        rope_theta=100_000.0,
        mlp_gated=False,
        citation="arXiv:2402.19173",
    )


def reduced(n_layers: int = 2, d_model: int = 256) -> ModelConfig:
    return dataclasses.replace(
        full(),
        n_layers=n_layers,
        d_model=d_model,
        n_heads=8,
        n_kv_heads=2,
        head_dim=32,
        d_ff=4 * d_model,
        vocab=512,
        sliding_window=64,
        dtype="float32",
    )


def variant_family():
    return [
        (f"{ARCH_ID}-n", reduced(2, 128), 56.2),
        (f"{ARCH_ID}-s", reduced(2, 256), 66.0),
        (f"{ARCH_ID}-m", reduced(4, 384), 72.8),
    ]
