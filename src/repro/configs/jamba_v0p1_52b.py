"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.
Attention on every 8th layer (offset 4); MoE replaces the MLP on every 2nd
layer (offset 1); remaining layers are Mamba + dense MLP.
"""
import dataclasses

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

ARCH_ID = "jamba-v0.1-52b"


def full() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="hybrid",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab=65_536,
        attn_every=8,
        attn_offset=4,
        moe=MoEConfig(
            n_experts=16,
            top_k=2,
            d_ff_expert=14336,
            capacity_factor=1.25,
            every=2,
            offset=1,
        ),
        ssm=SSMConfig(d_state=16, head_dim=64, expand=2, d_conv=4, chunk_size=256),
        rope_theta=10_000.0,
        citation="arXiv:2403.19887",
    )


def reduced(n_layers: int = 2, d_model: int = 256) -> ModelConfig:
    # keep the 1 attn : (n-1) mamba flavour even at depth 2
    return dataclasses.replace(
        full(),
        n_layers=n_layers,
        d_model=d_model,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        d_ff=4 * d_model,
        vocab=512,
        attn_every=2,
        attn_offset=1,
        moe=MoEConfig(
            n_experts=4,
            top_k=2,
            d_ff_expert=2 * d_model,
            capacity_factor=2.0,
            every=2,
            offset=0,
        ),
        ssm=SSMConfig(d_state=16, head_dim=32, expand=2, d_conv=4, chunk_size=32),
        dtype="float32",
    )


def variant_family():
    return [
        (f"{ARCH_ID}-n", reduced(2, 128), 60.6),
        (f"{ARCH_ID}-s", reduced(2, 256), 68.9),
        (f"{ARCH_ID}-m", reduced(4, 384), 74.4),
    ]
