"""yi-34b [dense] — llama-architecture GQA.

[arXiv:2403.04652] 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
"""
import dataclasses

from repro.configs.base import ModelConfig

ARCH_ID = "yi-34b"


def full() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="dense",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=20480,
        vocab=64_000,
        rope_theta=5_000_000.0,
        citation="arXiv:2403.04652",
    )


def reduced(n_layers: int = 2, d_model: int = 256) -> ModelConfig:
    return dataclasses.replace(
        full(),
        n_layers=n_layers,
        d_model=d_model,
        n_heads=8,
        n_kv_heads=2,
        head_dim=32,
        d_ff=4 * d_model,
        vocab=512,
        dtype="float32",
    )


def variant_family():
    # plays the role of the paper's classifier family (Table 8, ResNets).
    return [
        (f"{ARCH_ID}-n", reduced(2, 128), 69.75),
        (f"{ARCH_ID}-s", reduced(2, 256), 76.13),
        (f"{ARCH_ID}-m", reduced(4, 384), 78.31),
    ]
