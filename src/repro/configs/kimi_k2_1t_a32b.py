"""kimi-k2-1t-a32b [moe] — trillion-parameter MoE, 384 experts top-8.

[arXiv:2501.kimi2, paper-table config] 61L d_model=7168 64H (GQA kv=8)
d_ff_expert=2048 vocab=163840, MoE 384e top-8 + 1 shared expert.
"""
import dataclasses

from repro.configs.base import ModelConfig, MoEConfig

ARCH_ID = "kimi-k2-1t-a32b"


def full() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=0,                       # every layer routed (+1 shared expert)
        vocab=163_840,
        moe=MoEConfig(
            n_experts=384,
            top_k=8,
            d_ff_expert=2048,
            n_shared_experts=1,
            d_ff_shared=2048,
            capacity_factor=1.25,
        ),
        rope_theta=50_000.0,
        citation="arXiv:2501.kimi2",
    )


def reduced(n_layers: int = 2, d_model: int = 256) -> ModelConfig:
    return dataclasses.replace(
        full(),
        n_layers=n_layers,
        d_model=d_model,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        vocab=512,
        moe=MoEConfig(
            n_experts=4,
            top_k=2,
            d_ff_expert=2 * d_model,
            n_shared_experts=1,
            d_ff_shared=2 * d_model,
            capacity_factor=2.0,
        ),
        dtype="float32",
    )


def variant_family():
    return [
        (f"{ARCH_ID}-n", reduced(2, 128), 62.5),
        (f"{ARCH_ID}-s", reduced(2, 256), 70.1),
        (f"{ARCH_ID}-m", reduced(4, 384), 76.0),
    ]
