"""whisper-medium [audio] — enc-dec transformer backbone, conv frontend STUB.

[arXiv:2212.04356] 24L(dec)+24L(enc) d_model=1024 16H (kv=16) d_ff=4096
vocab=51865.  ``input_specs`` supplies precomputed mel-frame embeddings
(B, 1500, 1024); the mel-spectrogram + conv feature extractor is the allowed
modality-frontend stub.
"""
import dataclasses

from repro.configs.base import ModelConfig

ARCH_ID = "whisper-medium"


def full() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="encdec",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=4096,
        vocab=51_865,
        n_encoder_layers=24,
        encoder_seq=1500,
        rope_theta=10_000.0,
        mlp_gated=False,
        citation="arXiv:2212.04356",
    )


def reduced(n_layers: int = 2, d_model: int = 256) -> ModelConfig:
    return dataclasses.replace(
        full(),
        n_layers=n_layers,
        n_encoder_layers=n_layers,
        d_model=d_model,
        n_heads=4,
        n_kv_heads=4,
        d_ff=4 * d_model,
        vocab=512,
        encoder_seq=48,
        dtype="float32",
    )


def variant_family():
    # plays the role of the paper's audio task family (Table 9, 1-WER).
    return [
        (f"{ARCH_ID}-n", reduced(2, 128), 58.72),
        (f"{ARCH_ID}-s", reduced(2, 256), 64.88),
        (f"{ARCH_ID}-m", reduced(4, 384), 72.35),
    ]
