"""Model configuration dataclasses shared by every architecture family.

A ``ModelConfig`` fully describes one transformer/SSM/hybrid backbone.  Each
assigned architecture module (``src/repro/configs/<arch>.py``) exports:

  * ``full()``     -- the exact published configuration (dry-run only),
  * ``reduced()``  -- a <=512 d_model, <=2 layer, <=4 expert smoke variant,
  * ``variant_family()`` -- a small accuracy/latency-spread family of reduced
    models that plays the role of the paper's "model variants" (ResNet18/50,
    YOLOv5n/m, ...) for the IPA control plane.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    d_ff_shared: int = 0          # total shared-expert hidden width
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # layers where MoE replaces the dense MLP: every `every`-th layer,
    # starting at `offset` (jamba: every 2nd; qwen2/kimi: every layer).
    every: int = 1
    offset: int = 0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    n_groups: int = 1
    chunk_size: int = 256

    def n_heads(self, d_model: int) -> int:
        return self.expand * d_model // self.head_dim

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # -- attention pattern ---------------------------------------------------
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None   # local-attention window, if any
    # every `global_every`-th layer uses full/global attention (gemma3 5:1);
    # 0 => all layers identical (all-global if sliding_window is None,
    # all-local otherwise).
    global_every: int = 0
    # -- hybrid (jamba): attention layer every `attn_every` layers -----------
    attn_every: int = 0
    attn_offset: int = 0
    # -- mixture of experts ---------------------------------------------------
    moe: Optional[MoEConfig] = None
    # -- state-space ----------------------------------------------------------
    ssm: Optional[SSMConfig] = None
    # -- encoder/decoder (whisper) --------------------------------------------
    n_encoder_layers: int = 0
    encoder_seq: int = 0            # precomputed frame embeddings length
    # -- vision-language ------------------------------------------------------
    n_patches: int = 0              # precomputed patch embeddings length
    # -- misc -----------------------------------------------------------------
    norm_eps: float = 1e-6
    mlp_gated: bool = True          # SwiGLU (3 mats) vs plain GELU (2 mats)
    tie_embeddings: bool = True
    dtype: jnp.dtype = jnp.bfloat16
    citation: str = ""

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    def is_attn_layer(self, i: int) -> bool:
        """Hybrid archs: is layer ``i`` an attention layer (vs. SSM)?"""
        if self.family != "hybrid":
            return self.family != "ssm"
        return self.attn_every > 0 and (i % self.attn_every) == self.attn_offset

    def is_global_layer(self, i: int) -> bool:
        """Sliding-window archs: does layer ``i`` use full/global attention?"""
        if self.sliding_window is None:
            return True
        if self.global_every <= 0:
            return False
        return (i % self.global_every) == (self.global_every - 1)

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        return (i % self.moe.every) == self.moe.offset

    def layer_flags(self) -> Tuple[Tuple[bool, bool, bool], ...]:
        """(is_attn, is_global, is_moe) per layer."""
        return tuple(
            (self.is_attn_layer(i), self.is_global_layer(i), self.is_moe_layer(i))
            for i in range(self.n_layers)
        )

    # ------------------------------------------------------------------
    def n_params(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, h = self.d_model, self.head_dim_
        p = self.vocab * d                      # embedding
        if not self.tie_embeddings:
            p += self.vocab * d
        for i in range(self.n_layers):
            p += 2 * d                           # norms
            if self.is_attn_layer(i):
                p += d * (self.n_heads * h) + 2 * d * (self.n_kv_heads * h)
                p += (self.n_heads * h) * d
            elif self.ssm is not None:           # mamba2 mixer
                s = self.ssm
                din = s.d_inner(d)
                nh = s.n_heads(d)
                conv_dim = din + 2 * s.n_groups * s.d_state
                p += d * (2 * din + 2 * s.n_groups * s.d_state + nh)  # in_proj
                p += conv_dim * s.d_conv + conv_dim                    # conv
                p += 3 * nh                                            # A, D, dt_bias
                p += din                                               # norm
                p += din * d                                           # out_proj
            n_mats = 3 if self.mlp_gated else 2
            if self.is_moe_layer(i):
                m = self.moe
                p += d * m.n_experts                                   # router
                p += m.n_experts * n_mats * d * m.d_ff_expert
                if m.n_shared_experts:
                    p += n_mats * d * m.d_ff_shared
            elif self.d_ff > 0:
                p += n_mats * d * self.d_ff                            # mlp
        for _ in range(self.n_encoder_layers):
            p += d * (self.n_heads * h) * 2 + 2 * d * (self.n_kv_heads * h)
            p += (3 if self.mlp_gated else 2) * d * self.d_ff + 3 * d
            # decoder cross-attention params
            p += d * (self.n_heads * h) + 2 * d * (self.n_kv_heads * h) + (self.n_heads * h) * d + d
        p += d                                    # final norm
        return p

    def n_active_params(self) -> int:
        """Params touched per token (MoE: shared + top_k experts only)."""
        if self.moe is None:
            return self.n_params()
        m = self.moe
        dense = dataclasses.replace(self, moe=None)
        p = dense.n_params()
        n_mats = 3 if self.mlp_gated else 2
        for i in range(self.n_layers):
            if self.is_moe_layer(i):
                p += self.d_model * m.n_experts                  # router
                p += m.top_k * n_mats * self.d_model * m.d_ff_expert
                if m.n_shared_experts:
                    p += n_mats * self.d_model * m.d_ff_shared
        return p


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
