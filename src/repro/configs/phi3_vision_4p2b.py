"""phi-3-vision-4.2b [vlm] — phi3-mini text backbone + CLIP patch STUB.

[hf:microsoft/Phi-3-vision-128k-instruct] 32L d_model=3072 32H (kv=32)
d_ff=8192 vocab=32064.  ``input_specs`` supplies precomputed projected patch
embeddings (B, 576, 3072) prepended to the text tokens; the ViT/CLIP encoder
and projector are the allowed modality-frontend stub.
"""
import dataclasses

from repro.configs.base import ModelConfig

ARCH_ID = "phi-3-vision-4.2b"


def full() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="vlm",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab=32_064,
        n_patches=576,
        rope_theta=10_000.0,
        citation="hf:microsoft/Phi-3-vision-128k-instruct",
    )


def reduced(n_layers: int = 2, d_model: int = 256) -> ModelConfig:
    return dataclasses.replace(
        full(),
        n_layers=n_layers,
        d_model=d_model,
        n_heads=4,
        n_kv_heads=4,
        d_ff=4 * d_model,
        vocab=512,
        n_patches=16,
        dtype="float32",
    )


def variant_family():
    return [
        (f"{ARCH_ID}-n", reduced(2, 128), 61.1),
        (f"{ARCH_ID}-s", reduced(2, 256), 68.3),
        (f"{ARCH_ID}-m", reduced(4, 384), 73.6),
    ]
