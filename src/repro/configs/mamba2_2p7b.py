"""mamba2-2.7b [ssm] — attention-free SSD (state-space duality).

[arXiv:2405.21060] 64L d_model=2560 d_ff=0 vocab=50280, d_state=128,
head_dim=64, expand=2 (SSD chunked algorithm).
"""
import dataclasses

from repro.configs.base import ModelConfig, SSMConfig

ARCH_ID = "mamba2-2.7b"


def full() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="ssm",
        n_layers=64,
        d_model=2560,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab=50_280,
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, d_conv=4, chunk_size=256),
        citation="arXiv:2405.21060",
    )


def reduced(n_layers: int = 2, d_model: int = 256) -> ModelConfig:
    return dataclasses.replace(
        full(),
        n_layers=n_layers,
        d_model=d_model,
        vocab=512,
        ssm=SSMConfig(d_state=16, head_dim=32, expand=2, d_conv=4, chunk_size=32),
        dtype="float32",
    )


def variant_family():
    return [
        (f"{ARCH_ID}-n", reduced(2, 128), 55.3),
        (f"{ARCH_ID}-s", reduced(2, 256), 63.8),
        (f"{ARCH_ID}-m", reduced(4, 384), 69.0),
    ]
