"""Config registry: the 10 assigned architectures + the paper's pipelines."""
from repro.configs import (
    gemma3_27b,
    jamba_v0p1_52b,
    kimi_k2_1t_a32b,
    mamba2_2p7b,
    phi3_vision_4p2b,
    qwen2_moe_a2p7b,
    starcoder2_15b,
    starcoder2_3b,
    whisper_medium,
    yi_34b,
)
from repro.configs.base import (
    DECODE_32K,
    INPUT_SHAPES,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    InputShape,
    ModelConfig,
    MoEConfig,
    SSMConfig,
)

_ARCH_MODULES = {
    m.ARCH_ID: m
    for m in (
        gemma3_27b,
        mamba2_2p7b,
        whisper_medium,
        starcoder2_3b,
        starcoder2_15b,
        phi3_vision_4p2b,
        kimi_k2_1t_a32b,
        qwen2_moe_a2p7b,
        yi_34b,
        jamba_v0p1_52b,
    )
}

ARCH_IDS = tuple(_ARCH_MODULES)


def arch_module(arch_id: str):
    try:
        return _ARCH_MODULES[arch_id]
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")


def get_config(arch_id: str, *, reduced: bool = False) -> ModelConfig:
    mod = arch_module(arch_id)
    return mod.reduced() if reduced else mod.full()


def get_variant_family(arch_id: str):
    return arch_module(arch_id).variant_family()


# Which input shapes apply to which architecture (see DESIGN.md §4).
_SUBQUADRATIC_DECODE = {
    # archs whose long-context cache is sub-quadratic / bounded:
    "mamba2-2.7b",        # O(1) SSM state
    "jamba-v0.1-52b",     # mamba layers O(1); 1:7 attn layers keep KV
    "gemma3-27b",         # 5:1 local(window 1024):global
    "starcoder2-3b",      # sliding-window 4096, all layers
    "starcoder2-15b",     # sliding-window 4096, all layers
}


def shapes_for_arch(arch_id: str):
    """The input shapes this architecture must lower for (see DESIGN.md)."""
    shapes = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if arch_id in _SUBQUADRATIC_DECODE:
        shapes.append(LONG_500K)
    return shapes


def all_dryrun_pairs():
    return [(a, s) for a in ARCH_IDS for s in shapes_for_arch(a)]
