"""gemma3-27b [dense] — 5:1 local:global sliding-window attention, 128k ctx.

[hf:google/gemma-3-1b-pt family; 27B config] 62L d_model=5376 32H (GQA kv=16)
d_ff=21504 vocab=262144, sliding window 1024 on local layers, every 6th layer
global.
"""
import dataclasses

from repro.configs.base import ModelConfig

ARCH_ID = "gemma3-27b"


def full() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="dense",
        n_layers=62,
        d_model=5376,
        n_heads=32,
        n_kv_heads=16,
        head_dim=128,
        d_ff=21504,
        vocab=262_144,
        sliding_window=1024,
        global_every=6,
        rope_theta=1_000_000.0,
        citation="hf:google/gemma-3-1b-pt",
    )


def reduced(n_layers: int = 2, d_model: int = 256) -> ModelConfig:
    return dataclasses.replace(
        full(),
        n_layers=n_layers,
        d_model=d_model,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        d_ff=4 * d_model,
        vocab=512,
        sliding_window=64,
        global_every=2,
        dtype="float32",
    )


def variant_family():
    """(name, config, accuracy%) triplets for the IPA control plane."""
    return [
        (f"{ARCH_ID}-n", reduced(2, 128), 58.0),
        (f"{ARCH_ID}-s", reduced(2, 256), 66.5),
        (f"{ARCH_ID}-m", reduced(4, 384), 71.2),
    ]
