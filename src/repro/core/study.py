"""Parallel Pareto-sweep study layer (the worker side of
``benchmarks/sweep.py``).

IPA's headline claim is a *trade-off surface* — accuracy vs cost vs
reconfigurations under varying SLAs and budgets (FA2 and InferLine both
evaluate across dense SLA/budget grids) — and a surface needs a grid of
full policy-trace runs, not spot checks.  Each grid **cell** is one
``(policy, SLA scale, core budget C, trace replicate, objective weights)``
tuple replayed end-to-end through ``adapter.run_cell``; cells are
embarrassingly parallel, so the runner fans them out over a
``ProcessPoolExecutor`` (spawn context) and this module holds everything a
worker process needs to compute a cell *from its spec alone*:

* ``CellSpec`` — a frozen, picklable, filesystem-addressable cell
  identity.  Every input a cell needs is derived deterministically from
  the spec, so a cell's result is independent of which worker runs it,
  in what order, after which other cells — the root of the harness's
  nproc-invariance guarantee (same grid, any worker count, byte-identical
  aggregate modulo wall-clock fields).
* deterministic seed derivation via ``np.random.SeedSequence`` spawn
  keys: replicate ``rep`` draws its trace-shape stream from
  ``SeedSequence(root_seed, spawn_key=(rep, 0))`` and its arrival streams
  from ``spawn_key=(rep, 1, pipeline))`` (the adapter extends the key per
  pipeline).  Distinct replicates can never collide — unlike the
  ``seed + k * i`` arithmetic this replaces — while cells that differ
  only in policy/budget/SLA *share* a replicate's workload by design
  (paired comparison: every policy is judged on the same arrivals).
* per-worker warm state (``worker_init`` + module globals): one
  long-lived ``optimizer.FrontierCache`` and small trace/cluster memos
  reused across all the cells a worker drains.  Exact frontier keying is
  bit-identical to uncached planning (property-tested), so warm caches
  change wall-clock only, never results.
* crash-safe incremental resume: every finished cell is written as one
  shard ``<shards>/<cell_id>.json`` (atomic tmp+rename); a rerun loads
  shards whose embedded spec still matches and recomputes only the rest.
* aggregation: per-(policy, sla, C, beta) means with seed-level 95%
  confidence intervals (Student t over replicates), Pareto fronts per
  (sla, beta) slice over (mean PAS up, mean cost down, reconfigs/hour
  down), and a ``result_hash`` over the volatile-stripped records — the
  equality witness the smoke gate compares across worker counts.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

try:
    from scipy.stats import t as _student_t
except ImportError:                      # pragma: no cover - scipy is baked in
    _student_t = None

from repro.core import adapter as AD
from repro.core import optimizer as OPT
from repro.core.cluster import ClusterModel
from repro.core.pipeline import ModelVariant, PipelineModel, StageModel

# §5.3: ~8 s adaptation window per reconfiguration (see bench_cluster's
# switch scenario for how these two constants were sized)
ADAPT_DELAY_S = 8.0
HYSTERESIS_SWITCH_COST = 0.08

# sweep policy name -> (run_cluster_trace policy, switch_cost).  The
# hysteresis variant is a *policy* here (not a knob) so the surface shows
# what the §5.3 switch penalty trades: fewer reconfigs/hour vs PAS.
SWEEP_POLICIES = {
    "ipa": ("ipa", 0.0),
    "ipa_hyst": ("ipa", HYSTERESIS_SWITCH_COST),
    "split_ipa": ("split_ipa", 0.0),
    "split_fa2_low": ("split_fa2_low", 0.0),
    "split_fa2_high": ("split_fa2_high", 0.0),
    "split_rim": ("split_rim", 0.0),
}


# ---------------------------------------------------------------------------
# cell identity
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CellSpec:
    """One grid cell.  Frozen + primitives only: hashable, picklable under
    the spawn context, and serializable into its own shard for resume
    validation.  ``sla_scale`` multiplies every stage SLA of the scenario
    pipelines; ``budget`` is the absolute shared core budget C (resolved
    from a budget fraction by the runner, so cells are self-contained);
    ``rep`` is the trace-replicate index the seed streams derive from."""
    policy: str                  # key of SWEEP_POLICIES
    sla_scale: float
    budget: int
    rep: int
    beta: float                  # objective cost weight (alpha fixed)
    alpha: float = 1.0
    seconds: int = 240
    n_pipelines: int = 3
    root_seed: int = 0
    adaptation_delay: float = ADAPT_DELAY_S
    event_core: str = "struct"

    @property
    def cell_id(self) -> str:
        """Filesystem-safe shard name.  Unique within one grid (grids vary
        the first five axes; the rest are grid-wide constants, and the
        shard loader re-validates the *full* spec anyway)."""
        return (f"{self.policy}__sla{self.sla_scale:g}__C{self.budget}"
                f"__rep{self.rep}__beta{self.beta:g}")

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def spec_from_dict(d: Dict) -> CellSpec:
    return CellSpec(**d)


def build_grid(policies: Sequence[str], sla_scales: Sequence[float],
               budgets: Sequence[int], reps: int, betas: Sequence[float],
               seconds: int, n_pipelines: int,
               root_seed: int = 0,
               adaptation_delay: float = ADAPT_DELAY_S,
               event_core: str = "struct") -> List[CellSpec]:
    """The full cross product, enumerated in a fixed nested order (the
    canonical record order every aggregate and hash uses)."""
    for p in policies:
        if p not in SWEEP_POLICIES:
            raise ValueError(f"unknown sweep policy {p!r}; "
                             f"choose from {sorted(SWEEP_POLICIES)}")
    return [CellSpec(policy=p, sla_scale=float(s), budget=int(c),
                     rep=r, beta=float(b), seconds=int(seconds),
                     n_pipelines=int(n_pipelines), root_seed=int(root_seed),
                     adaptation_delay=float(adaptation_delay),
                     event_core=event_core)
            for p in policies for s in sla_scales for c in budgets
            for b in betas for r in range(reps)]


# ---------------------------------------------------------------------------
# scenario: the bench_cluster anti-correlated-burst cluster, SLA-scalable
# and Generator-seeded (workers rebuild it from the spec alone)
# ---------------------------------------------------------------------------
def _sweep_pipeline(name: str, l1a: float, l1b: float, accs,
                    sla_scale: float) -> PipelineModel:
    """Two-stage pipeline with light/mid/heavy variants per stage (the
    bench_cluster scenario family); ``sla_scale`` multiplies each stage's
    SLA — the sweep's SLA axis."""
    def stage(sname, l1):
        variants = tuple(
            ModelVariant(f"{sname}_{tag}", acc, alloc,
                         (l1 * scale * 0.002, l1 * scale * 0.7,
                          l1 * scale * 0.3))
            for tag, acc, alloc, scale in zip(
                ("light", "mid", "heavy"), accs, (1, 2, 4), (1.0, 1.8, 3.2)))
        return StageModel(sname, variants, sla=5 * l1 * 1.8 * sla_scale,
                          batch_choices=(1, 2, 4, 8, 16))
    return PipelineModel(name, (stage(f"{name}_a", l1a),
                                stage(f"{name}_b", l1b)))


_PIPELINE_PROTOS = (
    ("vision", 0.040, 0.030, (55.0, 71.0, 82.0)),
    ("audio", 0.050, 0.020, (62.0, 70.0, 76.0)),
    ("nlp", 0.030, 0.030, (66.0, 74.0, 80.0)),
    ("video", 0.045, 0.025, (52.0, 68.0, 84.0)),
)

# rotating-burst trace shape (one pipeline near peak at a time — the
# regime where moving cores across pipelines pays)
TRACE_BASE_RPS = 4.0
TRACE_BURST_AMP = 22.0
TRACE_CYCLE_S = 90.0
TRACE_DECAY_S = 14.0


def sweep_cluster(n_pipelines: int, sla_scale: float = 1.0,
                  cores: float = float("inf")) -> ClusterModel:
    if not 1 <= n_pipelines <= len(_PIPELINE_PROTOS):
        raise ValueError(f"n_pipelines must be 1-{len(_PIPELINE_PROTOS)}")
    pipes = tuple(_sweep_pipeline(*proto, sla_scale=sla_scale)
                  for proto in _PIPELINE_PROTOS[:n_pipelines])
    return ClusterModel("sweep_cluster", pipes, float(cores))


def sweep_traces(seconds: int, n: int,
                 rng: np.random.Generator) -> List[np.ndarray]:
    """Anti-correlated rotating bursts, phase-shifted per pipeline, noise
    drawn from ``rng`` (a Generator, so the caller controls derivation)."""
    t = np.arange(seconds, dtype=np.float64)
    traces = []
    for i in range(n):
        phase = (t - i * TRACE_CYCLE_S / n) % TRACE_CYCLE_S
        burst = TRACE_BURST_AMP * np.exp(-phase / TRACE_DECAY_S)
        noise = rng.normal(0.0, 0.4, seconds)
        traces.append(np.clip(TRACE_BASE_RPS + burst + noise, 0.5, None))
    return traces


def resolve_budgets(n_pipelines: int, fracs: Sequence[float],
                    beta: float = 0.02) -> List[int]:
    """Budget fractions -> absolute core budgets C, deterministically.

    The reference is the unconstrained joint cost at the worst rotating
    window (one pipeline at the analytic burst peak, the rest at base
    load) under the grid's planning objective — the same sizing rule as
    ``bench_cluster.pick_budget`` but on analytic demand points, so it
    needs no traces and every invocation agrees on the result.  Budgets
    are resolved once per grid (not per beta): the C axis must stay
    comparable across objective weights."""
    unbounded = sweep_cluster(n_pipelines)
    obj = OPT.Objective(alpha=1.0, beta=beta, delta=1e-6)
    peak = TRACE_BASE_RPS + TRACE_BURST_AMP
    worst = 0.0
    for i in range(n_pipelines):
        lams = [peak if j == i else TRACE_BASE_RPS
                for j in range(n_pipelines)]
        worst = max(worst, OPT.solve_cluster(unbounded, lams, obj).cost)
    return [max(int(round(f * worst)), n_pipelines * 2) for f in fracs]


# ---------------------------------------------------------------------------
# deterministic seed derivation (collision-free by SeedSequence spawn keys)
# ---------------------------------------------------------------------------
def trace_seedseq(spec: CellSpec) -> np.random.SeedSequence:
    """Replicate ``rep``'s trace-shape noise stream."""
    return np.random.SeedSequence(entropy=spec.root_seed,
                                  spawn_key=(spec.rep, 0))


def arrival_seedseq(spec: CellSpec) -> np.random.SeedSequence:
    """Replicate ``rep``'s arrival-sampling root; ``run_cluster_trace``
    extends the spawn key per pipeline (``(rep, 1, i)``)."""
    return np.random.SeedSequence(entropy=spec.root_seed,
                                  spawn_key=(spec.rep, 1))


# ---------------------------------------------------------------------------
# worker side: warm state + the single-cell entry point
# ---------------------------------------------------------------------------
_WORKER: Dict = {}


def worker_init() -> None:
    """Per-process warm state, built once per worker (the pool passes this
    as the executor ``initializer``; the serial path calls it per run).
    The planner cache is exact-keyed at every layer (frontiers, whole
    solves, DP prefixes), so sharing it across every cell a worker drains
    is a pure wall-clock win — bit-identical results."""
    _WORKER["frontier_cache"] = OPT.PlannerCache(max_entries=8192)
    _WORKER["traces"] = {}
    _WORKER["clusters"] = {}


def _traces_for(spec: CellSpec) -> List[np.ndarray]:
    key = (spec.seconds, spec.n_pipelines, spec.root_seed, spec.rep)
    memo = _WORKER["traces"]
    if key not in memo:
        if len(memo) >= 32:              # bounded like the trace cache
            memo.pop(next(iter(memo)))
        rng = np.random.default_rng(trace_seedseq(spec))
        memo[key] = sweep_traces(spec.seconds, spec.n_pipelines, rng)
    return memo[key]


def _cluster_for(spec: CellSpec) -> ClusterModel:
    key = (spec.n_pipelines, spec.sla_scale, spec.budget)
    memo = _WORKER["clusters"]
    if key not in memo:
        memo[key] = sweep_cluster(spec.n_pipelines, spec.sla_scale,
                                  float(spec.budget))
    return memo[key]


def run_cell_spec(spec: CellSpec) -> Dict:
    """Compute one cell from its spec alone (worker entry point)."""
    if not _WORKER:
        worker_init()
    policy, switch_cost = SWEEP_POLICIES[spec.policy]
    rec = AD.run_cell(
        _cluster_for(spec), _traces_for(spec), policy=policy,
        obj=OPT.Objective(alpha=spec.alpha, beta=spec.beta, delta=1e-6),
        seed=arrival_seedseq(spec), switch_cost=switch_cost,
        adaptation_delay=spec.adaptation_delay,
        frontier_cache=_WORKER["frontier_cache"],
        event_core=spec.event_core)
    rec["cell"] = spec.cell_id
    rec["spec"] = spec.to_dict()
    return rec


def run_chunk(specs: Sequence[CellSpec]) -> List[Dict]:
    """A chunk of cells in one pool task (amortizes task dispatch; the
    runner keeps chunks small so free workers can steal queued ones)."""
    return [run_cell_spec(s) for s in specs]


# ---------------------------------------------------------------------------
# shards: crash-safe incremental resume
# ---------------------------------------------------------------------------
def shard_path(shard_dir: str, spec: CellSpec) -> str:
    return os.path.join(shard_dir, spec.cell_id + ".json")


def write_shard(shard_dir: str, rec: Dict) -> None:
    """Atomic per-cell result shard (tmp + rename in the same directory,
    so a crash mid-write can never leave a half-shard a resume would
    trust)."""
    os.makedirs(shard_dir, exist_ok=True)
    path = os.path.join(shard_dir, rec["cell"] + ".json")
    fd, tmp = tempfile.mkstemp(dir=shard_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(rec, f)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def load_shard(shard_dir: str, spec: CellSpec) -> Optional[Dict]:
    """A completed cell's record, or None if absent/corrupt/stale.  The
    embedded spec must match exactly — a shard from an edited grid (or a
    truncated write that somehow survived) is recomputed, not trusted."""
    path = shard_path(shard_dir, spec)
    try:
        with open(path) as f:
            rec = json.load(f)
    except (OSError, ValueError):
        return None
    if rec.get("spec") != spec.to_dict():
        return None
    return rec


# ---------------------------------------------------------------------------
# aggregation: CIs, Pareto fronts, determinism hash
# ---------------------------------------------------------------------------
# record fields that legitimately vary run-to-run (wall clock) or with
# warm-cache history (hit/miss counts): stripped before hashing, and the
# only fields the nproc-invariance guarantee excludes
VOLATILE_KEYS = frozenset({"wall_s", "solver_wall_s", "sim_wall_s",
                           "frontier_cache"})


def strip_volatile(obj):
    """Recursively drop wall-clock / cache-history fields."""
    if isinstance(obj, dict):
        return {k: strip_volatile(v) for k, v in obj.items()
                if k not in VOLATILE_KEYS}
    if isinstance(obj, (list, tuple)):
        return [strip_volatile(v) for v in obj]
    return obj


def result_hash(records: Sequence[Dict]) -> str:
    """sha256 over the canonical JSON of the volatile-stripped records,
    sorted by cell id — the byte-identity witness compared across worker
    counts."""
    canon = sorted((strip_volatile(r) for r in records),
                   key=lambda r: r["cell"])
    blob = json.dumps(canon, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def _ci(vals: Sequence[float]) -> Dict:
    """Mean with a seed-level 95% CI halfwidth (Student t over the
    replicate axis; ``ci95`` is None with a single replicate)."""
    v = np.asarray(vals, np.float64)
    n = len(v)
    out = {"mean": round(float(v.mean()), 6), "n": n}
    if n > 1:
        sd = float(v.std(ddof=1))
        mult = float(_student_t.ppf(0.975, n - 1)) if _student_t is not None \
            else 1.96                    # pragma: no cover - scipy absent
        out["std"] = round(sd, 6)
        out["ci95"] = round(mult * sd / np.sqrt(n), 6)
    else:
        out["std"] = None
        out["ci95"] = None
    return out


_SURFACE_METRICS = ("mean_pas", "mean_cost", "mean_objective",
                    "reconfigs_per_hour", "sla_violation_rate", "dropped",
                    "peak_serving_cores")


def aggregate(records: Sequence[Dict]) -> Dict:
    """Collapse cell records into the study output.

    ``groups``: one entry per (policy, sla_scale, budget, beta) with
    replicate-level mean/std/CI95 for each surface metric.  ``pareto``:
    per (sla_scale, beta) slice, every (policy, budget) operating point
    with its Pareto flag over (mean PAS maximized, mean cost minimized,
    reconfigs/hour minimized) — the paper's trade-off surface, read
    straight from the JSON."""
    groups: Dict[Tuple, List[Dict]] = {}
    for r in records:
        s = r["spec"]
        key = (s["policy"], s["sla_scale"], s["budget"], s["beta"])
        groups.setdefault(key, []).append(r)

    group_rows = []
    for (policy, sla, budget, beta) in sorted(groups):
        cells = sorted(groups[(policy, sla, budget, beta)],
                       key=lambda r: r["spec"]["rep"])
        row = {"policy": policy, "sla_scale": sla, "budget": budget,
               "beta": beta, "reps": [c["spec"]["rep"] for c in cells]}
        for m in _SURFACE_METRICS:
            row[m] = _ci([c[m] for c in cells])
        group_rows.append(row)

    fronts = []
    slices: Dict[Tuple, List[Dict]] = {}
    for row in group_rows:
        slices.setdefault((row["sla_scale"], row["beta"]), []).append(row)
    for (sla, beta) in sorted(slices):
        pts = [{"policy": row["policy"], "budget": row["budget"],
                "mean_pas": row["mean_pas"]["mean"],
                "mean_cost": row["mean_cost"]["mean"],
                "reconfigs_per_hour": row["reconfigs_per_hour"]["mean"]}
               for row in slices[(sla, beta)]]
        for p in pts:
            p["pareto"] = not any(
                q is not p
                and q["mean_pas"] >= p["mean_pas"]
                and q["mean_cost"] <= p["mean_cost"]
                and q["reconfigs_per_hour"] <= p["reconfigs_per_hour"]
                and (q["mean_pas"] > p["mean_pas"]
                     or q["mean_cost"] < p["mean_cost"]
                     or q["reconfigs_per_hour"] < p["reconfigs_per_hour"])
                for q in pts)
        fronts.append({"sla_scale": sla, "beta": beta, "points": pts})

    return {"groups": group_rows, "pareto": fronts}


def timing_rollup(records: Sequence[Dict], top_n: int = 5) -> Dict:
    """The volatile side, rolled up for diagnosability: total solver vs
    simulator wall across cells, aggregate frontier-cache hit rate, and
    the slowest cells (stragglers) with their own phase breakdown."""
    total_wall = sum(r["wall_s"] for r in records)
    solver = sum(r["solver_wall_s"] for r in records)
    sim = sum(r["sim_wall_s"] for r in records)
    hits = sum(r["frontier_cache"]["hits"] for r in records
               if r.get("frontier_cache"))
    misses = sum(r["frontier_cache"]["misses"] for r in records
                 if r.get("frontier_cache"))
    stragglers = sorted(records, key=lambda r: -r["wall_s"])[:top_n]
    return {
        "cells": len(records),
        "cell_wall_s_total": round(total_wall, 3),
        "solver_wall_s_total": round(solver, 3),
        "sim_wall_s_total": round(sim, 3),
        "frontier_cache": {
            "hits": hits, "misses": misses,
            "hit_rate": round(hits / (hits + misses), 4)
            if hits + misses else 0.0},
        "stragglers": [
            {"cell": r["cell"], "wall_s": r["wall_s"],
             "solver_wall_s": r["solver_wall_s"],
             "sim_wall_s": r["sim_wall_s"]} for r in stragglers],
    }
