"""Queueing model (paper Eq. 7, from FA2): worst-case batch-formation delay.

The first request of a batch waits for the remaining (b - 1) requests; at
arrival rate lambda the worst case is q(b) = (b - 1) / lambda.
"""
from __future__ import annotations

import numpy as np


def queue_delay(batch, arrival_rps) -> np.ndarray:
    batch = np.asarray(batch, dtype=np.float64)
    lam = max(float(arrival_rps), 1e-9)
    return (batch - 1.0) / lam
