"""Queueing model (paper Eq. 7, from FA2): worst-case batch-formation delay,
plus an opt-in expected-delay model (M/M/c-style).

The first request of a batch waits for the remaining (b - 1) requests; at
arrival rate lambda the worst case is q(b) = (b - 1) / lambda.  That bound
is what the paper plans against; ``expected_wait`` instead estimates the
*expected* delay (mean batch-formation wait + Erlang-C queue wait across
the stage's replicas), selected by ``latency_model="expected"`` in
``optimizer.stage_options`` / ``PipelineConfig.latency``.  The default
(worst-case) path is untouched.

Both the analytical planner (``PipelineConfig.latency`` -> ``queue_delay``)
and the discrete-event simulator (batch-formation timeout ->
``wait_bound``) derive from this single implementation so the optimizer's
latency estimate and the simulator's dispatch behaviour can never drift
apart.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def queue_delay(batch, arrival_rps) -> np.ndarray:
    """Worst-case batch-formation delay q(b) = (b - 1) / lambda (Eq. 7).

    Zero-demand semantics (defined here, once, for the whole stack): at
    lambda <= 0 only a batch of one is meaningfully priced — it never
    waits, so its delay is 0; any larger batch would wait forever for
    peers that never arrive, so its delay is ``inf``.  The planner's
    feasibility masks (``lat <= sla``) reject those options, and the
    simulator's batch-formation timeout caps the bound at ``max_wait``
    (see ``wait_bound``) — both therefore behave sanely on an idle
    interval instead of pricing batches at ~1e9·(b-1) seconds.
    """
    batch = np.asarray(batch, dtype=np.float64)
    lam = float(arrival_rps)
    if lam <= 0.0:
        return np.where(batch > 1.0, np.inf, 0.0)
    return (batch - 1.0) / lam


def expected_wait(batch: int, arrival_rps: float, replicas: int = 1,
                  service_time: Optional[float] = None) -> float:
    """Expected batch-formation + queue delay (M/M/c-style).

    Batch formation: a random request in a forming batch of ``b`` waits on
    average for the later ``(b - 1) / 2`` of its peers, so the mean wait is
    ``(b - 1) / (2 lambda)`` — exactly half of Eq. 7's worst case (the head
    request waiting for all ``b - 1``), hence always <= ``queue_delay``.

    Queue delay (only when ``service_time`` is given): formed batches
    arrive ~Poisson at ``lambda / b`` and are served by ``replicas``
    servers each taking ``service_time`` per batch; the expected wait is
    the M/M/c Erlang-C formula.  Returns ``inf`` when the stage is
    unstable (offered load >= replicas), which feasibility masks treat as
    a latency violation.
    """
    b = int(batch)
    lam = float(arrival_rps)
    if lam <= 0.0:
        # zero demand: same semantics as ``queue_delay`` — a batch of one
        # never waits, anything larger waits forever
        return 0.0 if b <= 1 else float("inf")
    form = (b - 1) / (2.0 * lam)
    if service_time is None:
        return form
    st = float(service_time)
    if st <= 0.0:
        return form
    c = max(int(replicas), 1)
    lam_b = lam / max(b, 1)              # batch arrival rate
    mu = 1.0 / st                        # per-server batch service rate
    a = lam_b / mu                       # offered load (erlangs)
    if a >= c:
        return float("inf")
    # Erlang C, computed iteratively to stay overflow-free at large c
    term = 1.0
    s = 1.0                              # sum_{k=0}^{c-1} a^k / k!
    for k in range(1, c):
        term *= a / k
        s += term
    top = term * a / c * c / (c - a)     # a^c / c! * c / (c - a)
    p_wait = top / (s + top)
    return form + p_wait / (c * mu - lam_b)


def wait_bound(batch: int, arrival_rps: float,
               max_wait: Optional[float] = None) -> float:
    """Batch-formation timeout: Eq. 7's q(b) capped at ``max_wait``.

    This is the deadline the simulator arms for a partially filled batch:
    the head request never waits longer than the worst-case queue delay the
    planner budgeted for, nor longer than the hard cap ``max_wait``.  A
    batch of one never waits.  At zero demand ``queue_delay`` is ``inf``
    for b > 1 (see its zero-demand semantics), so the timeout degrades to
    exactly ``max_wait`` — the same deadline the old 1e-9 clamp produced.
    """
    if batch <= 1:
        return 0.0
    q = float(queue_delay(batch, arrival_rps))
    if max_wait is not None:
        q = min(float(max_wait), q)
    return q
