"""Queueing model (paper Eq. 7, from FA2): worst-case batch-formation delay.

The first request of a batch waits for the remaining (b - 1) requests; at
arrival rate lambda the worst case is q(b) = (b - 1) / lambda.

Both the analytical planner (``PipelineConfig.latency`` -> ``queue_delay``)
and the discrete-event simulator (batch-formation timeout ->
``wait_bound``) derive from this single implementation so the optimizer's
latency estimate and the simulator's dispatch behaviour can never drift
apart.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def queue_delay(batch, arrival_rps) -> np.ndarray:
    """Worst-case batch-formation delay q(b) = (b - 1) / lambda (Eq. 7)."""
    batch = np.asarray(batch, dtype=np.float64)
    lam = max(float(arrival_rps), 1e-9)
    return (batch - 1.0) / lam


def wait_bound(batch: int, arrival_rps: float,
               max_wait: Optional[float] = None) -> float:
    """Batch-formation timeout: Eq. 7's q(b) capped at ``max_wait``.

    This is the deadline the simulator arms for a partially filled batch:
    the head request never waits longer than the worst-case queue delay the
    planner budgeted for, nor longer than the hard cap ``max_wait``.  A
    batch of one never waits.
    """
    if batch <= 1:
        return 0.0
    q = float(queue_delay(batch, arrival_rps))
    if max_wait is not None:
        q = min(float(max_wait), q)
    return q
