"""Offline profiler (paper §4.2).

* measures model latency at power-of-two batch sizes 1..64,
* fits the quadratic l(b) = a b^2 + b1 b + c (lower MSE than linear, §4.2),
* solves Eq. 1 for the base resource allocation R_m: the minimum allocation
  whose throughput clears the threshold `th` while the largest batch stays
  within the per-stage SLA,
* derives per-stage SLAs a la Swayam: 5 x mean batch-1 latency across the
  task's variants.

Hardware adaptation note (DESIGN.md §5): the container exposes a single CPU
device, so multi-core/chip scaling cannot be *measured*.  ``alloc_speedup``
models l(b; R) = l(b; 1) / R^0.75 (sub-linear parallel scaling, consistent
with the paper's Table 2 where 8 cores give ResNet18 75->14 ms ~ 5.4x).
On a real cluster this function is replaced by measurements.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.pipeline import (BATCH_CHOICES, ModelVariant, PipelineModel,
                                 StageModel)

ALLOC_CHOICES = (1, 2, 4, 8, 16, 32)
SLA_MULTIPLIER = 5.0          # Swayam heuristic (§4.2)
SPEEDUP_EXP = 0.75


def alloc_speedup(r: int) -> float:
    return float(r) ** SPEEDUP_EXP


def fit_quadratic(batches: Sequence[int], lats: Sequence[float]):
    """Least-squares fit of l(b) = a b^2 + b1 b + c; clipped to be
    non-decreasing and positive on the profiled range."""
    b = np.asarray(batches, np.float64)
    y = np.asarray(lats, np.float64)
    A = np.stack([b ** 2, b, np.ones_like(b)], axis=1)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    a, b1, c = (float(x) for x in coef)
    if c <= 0:
        c = float(max(y.min() * 0.5, 1e-6))
    return a, b1, c


def fit_mse(batches, lats, coeffs) -> float:
    b = np.asarray(batches, np.float64)
    y = np.asarray(lats, np.float64)
    a, b1, c = coeffs
    return float(np.mean((a * b ** 2 + b1 * b + c - y) ** 2))


def fit_linear_mse(batches, lats) -> float:
    b = np.asarray(batches, np.float64)
    y = np.asarray(lats, np.float64)
    A = np.stack([b, np.ones_like(b)], axis=1)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    return float(np.mean((A @ coef - y) ** 2))


@dataclasses.dataclass
class Profile:
    name: str
    batches: List[int]
    latencies: List[float]               # seconds at R = 1
    accuracy: float
    params_m: float = 0.0

    def coeffs(self):
        return fit_quadratic(self.batches, self.latencies)


def measure_latency(fn: Callable[[int], None], batches=BATCH_CHOICES,
                    warmup: int = 1, repeats: int = 3) -> List[float]:
    """Wall-clock profile of ``fn(batch_size)`` per batch size."""
    out = []
    for b in batches:
        for _ in range(warmup):
            fn(b)
        t0 = time.perf_counter()
        for _ in range(repeats):
            fn(b)
        out.append((time.perf_counter() - t0) / repeats)
    return out


def profile_stage_server(server, batches=(1, 2, 4, 8), prompt_len: int = 16,
                         repeats: int = 2) -> List[Profile]:
    """Profile every variant of a real serving StageServer (JAX CPU backend)."""
    import numpy as _np
    profs = []
    for vname, (cfg, acc) in server.variants.items():
        server.set_variant(vname)

        def run(b):
            toks = _np.zeros((b, prompt_len), _np.int32)
            server.process(toks)

        lats = measure_latency(run, batches=batches, warmup=1, repeats=repeats)
        profs.append(Profile(vname, list(batches), lats, acc))
    return profs


# ---------------------------------------------------------------------------
# Eq. 1: base allocation
# ---------------------------------------------------------------------------
def base_allocation(profile: Profile, th: float, sla_s: float,
                    max_batch: int = max(BATCH_CHOICES),
                    allocs=ALLOC_CHOICES) -> Optional[int]:
    """min R s.t. throughput(batch=1; R) >= th and l(max_batch; R) <= SLA_s."""
    a, b1, c = profile.coeffs()
    for r in allocs:
        sp = alloc_speedup(r)
        lat1 = (a + b1 + c) / sp
        lat_max = (a * max_batch ** 2 + b1 * max_batch + c) / sp
        if 1.0 / lat1 >= th and lat_max <= sla_s:
            return r
    return None


def derive_stage_sla(profiles: Sequence[Profile]) -> float:
    """Swayam: 5 x mean batch-1 latency over the task's variants (§4.2)."""
    lat1 = [p.coeffs()[0] + p.coeffs()[1] + p.coeffs()[2] for p in profiles]
    return SLA_MULTIPLIER * float(np.mean(lat1))


def build_stage(name: str, profiles: Sequence[Profile], th: float,
                batch_choices=BATCH_CHOICES, sla: Optional[float] = None,
                max_batch: Optional[int] = None) -> StageModel:
    """Profiler output -> control-plane StageModel (variants w/ Eq.-1 allocs).

    Variants whose Eq.-1 allocation does not exist (cannot meet th/SLA at any
    allocation) are excluded, mirroring the 'x' cells of Table 5.
    """
    sla_s = sla if sla is not None else derive_stage_sla(profiles)
    mb = max_batch if max_batch is not None else max(batch_choices)
    variants = []
    for p in profiles:
        r = base_allocation(p, th, sla_s, max_batch=mb)
        if r is None:
            continue
        a, b1, c = p.coeffs()
        sp = alloc_speedup(r)
        variants.append(ModelVariant(
            name=p.name, accuracy=p.accuracy, base_alloc=r,
            latency_coeffs=(a / sp, b1 / sp, c / sp), params_m=p.params_m))
    if not variants:
        raise ValueError(f"no variant of stage {name} meets th={th}, sla={sla_s}")
    return StageModel(name=name, variants=tuple(variants), sla=sla_s,
                      batch_choices=tuple(batch_choices))
