from repro.core import (accuracy, adapter, baselines, optimizer,  # noqa: F401
                        paper_profiles, pipeline, predictor, profiler,
                        queueing, simulator, trace)
