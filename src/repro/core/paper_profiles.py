"""The paper's five pipelines (Fig. 6) with Appendix-A variant tables.

The archive's measured latency profiles are not shipped with the paper, so
we reconstruct them from the anchors the paper *does* give:

  * l(1) anchors: YOLOv5n = 80 ms, ResNet18 = 75 ms (Tables 2/3),
  * per-stage SLA = 5 x mean batch-1 latency (§4.2) reproduces Table 6,
  * batch scaling l(8)/l(1) = 6.0 (Table 3: YOLOv5n 80 -> 481 ms),
  * across variants of a task, l(1) scales as params^0.6 (fits the
    YOLOv5n->m 80 -> ~347 ms and ResNet18->50 75 -> ~135 ms anchors).

With these, Eq. 1 run through our profiler reproduces the appendix base
allocations (e.g. YOLO: 1/1/2/4/8 at th=4, Table 7) — validated in tests.
Accuracies and parameter counts are the appendix tables verbatim.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.pipeline import ModelVariant, PipelineModel, StageModel
from repro.core.profiler import Profile, build_stage

BATCH_SHAPE = (0.3, 0.7, 0.001)     # l(b) = l1 * (c + m*b + q*b^2)
PARAM_EXP = 0.6


def _latency_curve(l1: float, batches: Sequence[int]) -> List[float]:
    c, m, q = BATCH_SHAPE
    denom = c + m + q
    return [l1 * (c + m * b + q * b * b) / denom for b in batches]


def _make_profiles(table: Sequence[Tuple[str, float, float]], anchor_l1: float,
                   batches: Sequence[int]) -> List[Profile]:
    """table rows: (name, params_m, accuracy); anchor_l1 = l(1) of row 0."""
    p0 = table[0][1] ** PARAM_EXP
    out = []
    for name, params_m, acc in table:
        l1 = anchor_l1 * (params_m ** PARAM_EXP) / p0
        out.append(Profile(name, list(batches), _latency_curve(l1, batches),
                           acc, params_m))
    return out


# --------------------------------------------------------------------------
# Appendix A tables: (name, params M, accuracy-like measure)
# --------------------------------------------------------------------------
YOLO = [("yolov5n", 1.9, 45.7), ("yolov5s", 7.2, 56.8), ("yolov5m", 21.2, 64.1),
        ("yolov5l", 46.5, 67.3), ("yolov5x", 86.7, 68.9)]               # mAP
RESNET = [("resnet18", 11.7, 69.75), ("resnet34", 21.8, 73.31),
          ("resnet50", 25.5, 76.13), ("resnet101", 44.54, 77.37),
          ("resnet152", 60.2, 78.31)]                                    # acc
AUDIO = [("s2t-small", 29.5, 58.72), ("s2t-medium", 71.2, 64.88),
         ("wav2vec2-base", 94.4, 66.15), ("s2t-large", 267.8, 66.74),
         ("wav2vec2-large", 315.5, 72.35)]                               # 1-WER
QA = [("roberta-base", 277.45, 77.14), ("roberta-large", 558.8, 83.79)]  # F1
SUM = [("distilbart-1-1", 82.9, 32.26), ("distilbart-12-1", 221.5, 33.37),
       ("distilbart-6-6", 229.9, 35.73), ("distilbart-12-3", 255.1, 36.39),
       ("distilbart-9-6", 267.7, 36.61), ("distilbart-12-6", 305.5, 36.99)]
SENT = [("distilbert", 66.9, 79.6), ("bert", 109.4, 79.9),
        ("roberta", 355.3, 83.0)]                                        # acc
LANGID = [("roberta-langid", 278.0, 79.62)]
NMT = [("opus-mt-fr-en", 74.6, 33.1), ("opus-mt-big-fr-en", 230.6, 34.4)]  # BLEU

# task -> (table, anchor l(1) seconds, threshold th RPS, batch choices)
TASKS: Dict[str, tuple] = {
    "object_detection": (YOLO, 0.080, 4, (1, 2, 4, 8)),
    "object_classification": (RESNET, 0.075, 4, (1, 2, 4, 8)),
    "audio": (AUDIO, 0.640, 1, (1, 2, 4, 8)),
    "qa": (QA, 0.120, 1, (1, 2, 4, 8)),
    "summarisation": (SUM, 0.280, 5, (1, 2, 4, 8)),
    "summarisation_long": (SUM, 1.400, 5, (1, 2, 4, 8)),   # NLP-pipeline inputs
    "sentiment": (SENT, 0.130, 1, (1, 2, 4, 8)),
    "language_id": (LANGID, 0.195, 4, (1, 2, 4, 8)),
    "translation": (NMT, 0.540, 4, (1, 2, 4, 8)),
}


def task_profiles(task: str) -> List[Profile]:
    table, anchor, th, batches = TASKS[task]
    return _make_profiles(table, anchor, batches)


def task_stage(task: str, name: str = None) -> StageModel:
    table, anchor, th, batches = TASKS[task]
    profs = _make_profiles(table, anchor, batches)
    return build_stage(name or task, profs, th=th, batch_choices=batches,
                       max_batch=max(batches))


# --------------------------------------------------------------------------
# the five pipelines of Fig. 6
# --------------------------------------------------------------------------
def video() -> PipelineModel:
    return PipelineModel("video", (task_stage("object_detection"),
                                   task_stage("object_classification")))


def audio_qa() -> PipelineModel:
    return PipelineModel("audio-qa", (task_stage("audio"), task_stage("qa")))


def audio_sent() -> PipelineModel:
    return PipelineModel("audio-sent", (task_stage("audio"),
                                        task_stage("sentiment")))


def sum_qa() -> PipelineModel:
    return PipelineModel("sum-qa", (task_stage("summarisation"),
                                    task_stage("qa")))


def nlp() -> PipelineModel:
    return PipelineModel("nlp", (task_stage("language_id"),
                                 task_stage("summarisation_long"),
                                 task_stage("translation")))


PIPELINES = {
    "video": video, "audio-qa": audio_qa, "audio-sent": audio_sent,
    "sum-qa": sum_qa, "nlp": nlp,
}


# --------------------------------------------------------------------------
# DAG-shaped variants of the Fig. 6 topologies
#
# The paper's video pipeline runs its two models sequentially (detector
# crops feed the classifier), but the same two tasks can run as parallel
# branches over the decoded frame (the InferLine-style prediction DAG),
# joined by a fusion stage.  These presets exercise the stage-graph
# machinery — fan-out, wait-for-all-parents joins, critical-path latency
# — over the paper's real variant tables.
# --------------------------------------------------------------------------
def passthrough_stage(name: str, latency: float = 0.002) -> StageModel:
    """A fixed-function stage (decoder, result fusion): one variant,
    accuracy 100 — the multiplicative PAS factor is exactly 1.0, so the
    stage never moves the pipeline's accuracy — one core, flat latency."""
    v = ModelVariant(name + "-fixed", 100.0, 1, (0.0, 0.0, latency))
    return StageModel(name, (v,), sla=5.0 * latency, batch_choices=(1, 2, 4, 8))


def video_fanout() -> PipelineModel:
    """decode → [object_detection ∥ object_classification] → fusion.

    The end-to-end budget is pinned at 1.5 s — tight enough that the
    large-batch service latencies (batch 8 ≈ 6 x batch 1, Table 3) fit
    only along the critical path, not serialized across both branches.
    That asymmetry is the operational reason to fan the two models out:
    a chain-shaped plan must give up batch economy (more replicas, more
    cores) exactly where the DAG plan keeps it."""
    return PipelineModel(
        "video-fanout",
        (passthrough_stage("decode"),
         task_stage("object_detection"),
         task_stage("object_classification"),
         passthrough_stage("fusion")),
        parents=((), (0,), (0,), (1, 2)),
        sla_override=1.5)


def network_edge_stage(name: str, delay: float = 0.060) -> StageModel:
    """A per-edge network link modelled as a stage: pure propagation delay.

    Zero-cost (``base_alloc`` 0 — a link consumes no budget in any device
    class, so no planner can ever spend cores on it) and accuracy-neutral
    (accuracy 100 → multiplicative PAS factor exactly 1.0).  Its only
    effect on a plan is the flat ``delay`` it adds to every source→sink
    path that crosses it — which is exactly how the edge-placement
    follow-up work charges WAN hops: latency on the path, nothing on the
    budget."""
    v = ModelVariant(name + "-link", 100.0, 0, (0.0, 0.0, delay))
    return StageModel(name, (v,), sla=5.0 * delay, batch_choices=(1,))


def video_edge(delay: float = 0.060) -> PipelineModel:
    """``video_fanout`` with the classification branch placed across a
    network edge: decode → [detect ∥ (uplink → classify)] → fusion.

    The uplink is a ``network_edge_stage``: it can lengthen the
    classification branch past the detection branch and thereby shift the
    critical path, but it never consumes budget — the planner's cost for
    this pipeline is identical to ``video_fanout``'s at every frontier
    point."""
    return PipelineModel(
        "video-edge",
        (passthrough_stage("decode"),
         task_stage("object_detection"),
         network_edge_stage("uplink", delay),
         task_stage("object_classification"),
         passthrough_stage("fusion")),
        parents=((), (0,), (0,), (2,), (1, 3)),
        sla_override=1.5)


def audio_fanout() -> PipelineModel:
    """audio → [qa ∥ sentiment] → fusion: one transcription feeding both
    downstream consumers of the paper's two audio pipelines in parallel."""
    return PipelineModel(
        "audio-fanout",
        (task_stage("audio"),
         task_stage("qa"),
         task_stage("sentiment"),
         passthrough_stage("fusion")),
        parents=((), (0,), (0,), (1, 2)))


DAG_PIPELINES = {
    "video-fanout": video_fanout, "audio-fanout": audio_fanout,
    "video-edge": video_edge,
}

# paper Appendix B objective weights per pipeline
PAPER_WEIGHTS = {
    "video": dict(alpha=2.0, beta=1.0, delta=1e-6),
    "audio-qa": dict(alpha=10.0, beta=0.5, delta=1e-6),
    "audio-sent": dict(alpha=30.0, beta=0.5, delta=1e-6),
    "sum-qa": dict(alpha=10.0, beta=0.5, delta=1e-6),
    "nlp": dict(alpha=40.0, beta=0.5, delta=1e-6),
}
