"""Online adapter (paper §3): monitor -> predict -> optimize -> reconfigure.

``run_trace`` drives a policy over a per-second rate trace through the
discrete-event simulator at a fixed adaptation interval (paper: 8 s
adaptation + <2 s decision = 10 s monitoring interval), recording
per-interval PAS / cost and global latency / drop / SLA metrics.

``run_cluster_trace`` is the cluster-level analogue: N per-pipeline rate
traces drive one ``ClusterSimulator`` (one event heap, one shared core
pool); at each boundary a cluster policy (joint knapsack, or proportional
static split) proposes a joint configuration, infeasible pipelines hold
the config the simulator is committed to, and the joint config is
admitted only if it fits the core budget through its §5.3 transition
windows — otherwise the admissible subset is applied staged (downsizes
now, grows once the freed cores leave their windows).

Cluster demand estimation mirrors what the single-pipeline ``run_trace``
already supports: reactive (max of the trailing window), burst-aware
(max over a longer window, so a spike that peaked a minute ago still
reserves capacity — what the static-split baselines get), per-pipeline
``LSTMPredictor``, or ``OraclePredictor`` ground truth.  The joint policy
can additionally be made switch-cost-aware (``switch_cost`` /
``switch_budget`` / ``adaptation_delay`` — paper §5.3's ~8 s adaptation
overhead), in which case each interval's recorded PAS is the *realized*
time-weighted value: a reconfigured pipeline serves the old config for
the adaptation window before the new one takes effect.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core import baselines as BL
from repro.core import optimizer as OPT
from repro.core.accuracy import pas_of
from repro.core.cluster import ClusterConfig, ClusterModel
from repro.core.pipeline import PipelineConfig, PipelineModel
from repro.core.simulator import (ClusterSimulator, PipelineSimulator,
                                  RoundPipelineSimulator,
                                  StructPipelineSimulator, EVENT_CORES,
                                  make_cluster_simulator)
from repro.core.trace import SeedLike, arrivals_from_rates
from repro.serving.request import Request, RequestPool

ADAPT_INTERVAL = 10.0       # paper §5.3: 8 s adaptation + 2 s decision


@dataclasses.dataclass
class IntervalRecord:
    t: float
    lam_true: float
    lam_hat: float
    pas: float
    cost: float
    feasible: bool
    solve_time: float


@dataclasses.dataclass
class TraceResult:
    policy: str
    intervals: List[IntervalRecord]
    latencies: np.ndarray
    arrived: int
    completed: int
    dropped: int
    sla: float
    # simulator observability (filled by run_trace; defaults keep older
    # constructors working)
    sim_events: int = 0
    peak_queue_depth: int = 0
    # total wall seconds spent inside the policy's solver over the run
    # (bootstrap decision included) — the benches' per-phase breakdown
    # (solver_wall_s vs sim_wall_s) reads this directly instead of
    # re-instrumenting externally
    solver_wall_s: float = 0.0

    @property
    def sla_violation_rate(self) -> float:
        if self.arrived == 0:
            return 0.0
        late = int(np.sum(self.latencies > self.sla))
        return (late + self.dropped) / self.arrived

    @property
    def mean_pas(self) -> float:
        return float(np.mean([r.pas for r in self.intervals]))

    @property
    def mean_cost(self) -> float:
        return float(np.mean([r.cost for r in self.intervals]))

    def summary(self) -> Dict[str, float]:
        return {
            "policy": self.policy,
            "mean_pas": round(self.mean_pas, 3),
            "mean_cost": round(self.mean_cost, 2),
            "sla_violation_rate": round(self.sla_violation_rate, 4),
            "dropped": self.dropped,
            "completed": self.completed,
            "p99_latency": round(float(np.percentile(self.latencies, 99)), 3)
            if len(self.latencies) else float("nan"),
        }


def run_trace(pipe: PipelineModel, rates: np.ndarray, policy: str = "ipa",
              obj: Optional[OPT.Objective] = None,
              predictor=None, oracle=None,
              interval: float = ADAPT_INTERVAL, seed: int = 0,
              max_replicas: int = OPT.DEFAULT_MAX_REPLICAS,
              solver: Optional[str] = None,
              event_core: str = "heap") -> TraceResult:
    """policy in {ipa, fa2_low, fa2_high, rim}; predictor: LSTMPredictor or
    None (reactive); oracle: OraclePredictor for the Fig.-16 'baseline'.
    ``solver`` overrides the policy's enumeration solver (``vec`` — the
    default hot path — ``brute`` or ``enum``); the vec-vs-brute pinning
    tests replay identical traces through both.  ``event_core`` selects
    the simulator hot loop (``"heap"`` reference, ``"struct"`` — the
    structured-array core — or ``"round"``, the columnar service-round
    engine; all event-for-event identical)."""
    rates = np.asarray(rates, np.float64)
    times = arrivals_from_rates(rates, seed=seed)

    # initial config from the first-second load
    lam0 = float(rates[:int(interval)].max())
    solver_wall = 0.0
    sol = _decide(pipe, lam0, policy, obj, max_replicas, solver)
    solver_wall += sol.solve_time
    if not sol.feasible:
        # bootstrap fallback: cheapest feasible config (production behaviour:
        # a policy must never leave the pipeline unconfigured); it honours
        # the same solver override so pinned replays stay single-solver
        sol = BL.fa2(pipe, lam0, "low", max_replicas=max_replicas,
                     **({"solver": solver} if solver is not None else {}))
        solver_wall += sol.solve_time
    if not sol.feasible:
        raise RuntimeError(f"no feasible initial config for {policy}")
    if event_core not in EVENT_CORES:
        raise ValueError(f"unknown event core {event_core!r}; "
                         f"choose from {EVENT_CORES}")
    # requests never outlive their completion event here, so the simulator
    # can recycle them through a pool instead of churning the allocator
    # (the struct core carries no request objects and ignores the pool)
    pool = RequestPool()
    sim_cls = {"heap": PipelineSimulator,
               "struct": StructPipelineSimulator,
               "round": RoundPipelineSimulator}[event_core]
    sim = sim_cls(pipe, sol.config, request_pool=pool)
    sim.lam_est = lam0
    records: List[IntervalRecord] = []

    horizon = len(rates)
    n_intervals = int(np.ceil(horizon / interval))
    ti = 0
    for k in range(n_intervals):
        t0, t1 = k * interval, min((k + 1) * interval, horizon)
        # --- monitor + predict (at the boundary, using only the past) ----
        hist = rates[:int(t0)]
        if oracle is not None:
            lam_hat = oracle.predict_at(int(t0))
        elif predictor is not None and len(hist) >= 1:
            lam_hat = predictor.predict(hist)
        else:
            lam_hat = float(hist[-20:].max()) if len(hist) else lam0
        # --- optimize + reconfigure --------------------------------------
        sol = _decide(pipe, lam_hat, policy, obj, max_replicas, solver)
        solver_wall += sol.solve_time
        if sol.feasible:
            sim.reconfigure(sol.config)
            sim.lam_est = lam_hat
            cfg = sol.config
        else:  # hold the config the simulator is actually running
            cfg = sim.current_config
        records.append(IntervalRecord(
            t=t0, lam_true=float(rates[int(t0):int(t1)].max()),
            lam_hat=float(lam_hat), pas=pas_of(cfg, pipe),
            cost=cfg.cost(pipe), feasible=sol.feasible,
            solve_time=sol.solve_time))
        # --- serve this interval -----------------------------------------
        # pre-sized arrival batching: one sorted-array cut + bulk inject
        # per window (the simulator acquires the requests from the pool)
        i1 = int(np.searchsorted(times, t1, side="left"))
        sim.inject_arrivals(times[ti:i1])
        ti = i1
        sim.run_until(t1)
    # flush stragglers
    sim.run_until(horizon + 4 * pipe.sla)
    m = sim.metrics
    return TraceResult(policy=policy, intervals=records,
                       latencies=np.array(m.latencies, dtype=np.float64),
                       arrived=m.arrived, completed=m.completed,
                       dropped=m.dropped, sla=pipe.sla,
                       sim_events=sim.events_processed,
                       peak_queue_depth=sim.peak_queue_depth,
                       solver_wall_s=float(solver_wall))


def _decide(pipe, lam, policy, obj, max_replicas, solver=None):
    try:
        fn = BL.POLICIES[policy]
    except KeyError:
        raise ValueError(policy) from None
    kw = {"max_replicas": max_replicas}
    if policy == "ipa":
        kw["obj"] = obj
    if solver is not None:
        kw["solver"] = solver
    return fn(pipe, lam, **kw)


# ---------------------------------------------------------------------------
# cluster level
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ClusterTraceResult:
    """One cluster policy over N per-pipeline traces in one shared pool."""
    policy: str
    budget: float
    per_pipeline: List[TraceResult]
    sim_events: int = 0
    peak_queue_depth: int = 0
    # committed pipeline-level reconfiguration decisions over the run (the
    # simulator's log: (decided_at, pipeline, scheduled_apply_at) tuples; a
    # decision superseded within its adaptation window keeps its entry but
    # its scheduled apply never fires)
    n_reconfigs: int = 0
    reconfig_log: List = dataclasses.field(default_factory=list)
    # supremum over the run of the cores the *serving* replica fleets held
    # at any instant (transition windows included) — the witness for the
    # overlap invariant peak_serving_cores <= budget
    peak_serving_cores: float = 0.0
    # total wall seconds inside the joint solver over the run (bootstrap
    # included; each interval's joint solve counted once, not per
    # pipeline) — the bench breakdown's solver_wall_s
    solver_wall_s: float = 0.0
    # FrontierCache.stats of the run's cache (None when caching was
    # bypassed) — hit-rate observability for the benches
    frontier_cache_stats: Optional[Dict] = None

    @property
    def mean_pas(self) -> float:
        """Mean over pipelines of per-pipeline interval-mean PAS."""
        return float(np.mean([r.mean_pas for r in self.per_pipeline]))

    @property
    def mean_cost(self) -> float:
        """Interval-mean of the summed (cluster-wide) core allocation."""
        return float(sum(r.mean_cost for r in self.per_pipeline))

    def mean_objective(self, obj: OPT.Objective) -> float:
        """Interval-mean summed alpha*PAS - beta*cost (the arbitration
        objective, minus the negligible delta batch penalty that the
        interval records do not carry)."""
        return float(sum(obj.alpha * r.mean_pas - obj.beta * r.mean_cost
                         for r in self.per_pipeline))

    @property
    def dropped(self) -> int:
        return sum(r.dropped for r in self.per_pipeline)

    @property
    def completed(self) -> int:
        return sum(r.completed for r in self.per_pipeline)

    @property
    def arrived(self) -> int:
        return sum(r.arrived for r in self.per_pipeline)

    def summary(self) -> Dict[str, object]:
        return {
            "policy": self.policy,
            "budget": self.budget,
            "mean_pas": round(self.mean_pas, 3),
            "mean_cost": round(self.mean_cost, 2),
            "dropped": self.dropped,
            "completed": self.completed,
            "per_pipeline": [r.summary() for r in self.per_pipeline],
        }


def reactive_demand(trace: np.ndarray, t0: float,
                    interval: float = ADAPT_INTERVAL,
                    window: int = 20) -> float:
    """Reactive (no-predictor) demand estimate at boundary ``t0``: max of
    the last ``window`` s of past rates, bootstrapping from the first
    interval, and 0 once the trace has ended (a finished pipeline must
    stop competing for shared cores).  Shared with the cluster bench's
    pointwise dominance gate so both always probe the same demand points.
    """
    i = int(t0)
    if i >= len(trace):
        return 0.0
    if i == 0:
        return float(trace[:int(interval)].max())
    return float(trace[max(i - window, 0):i].max())


def burst_demand(trace: np.ndarray, t0: float,
                 interval: float = ADAPT_INTERVAL,
                 window: int = 60) -> float:
    """Burst-aware max-of-window estimate: like ``reactive_demand`` but
    over a longer trailing window (default 60 s), so a burst that peaked
    tens of seconds ago still reserves capacity through its decay instead
    of the estimate collapsing the moment the 20 s window slides past the
    peak — the cheap anti-thrash guard the static-split baselines get."""
    return reactive_demand(trace, t0, interval, window=window)


DEMAND_ESTIMATORS = {"reactive": reactive_demand, "burst": burst_demand}


def _cluster_demands(rates, t0: float, interval: float, demand_mode: str,
                     predictors, oracles) -> List[float]:
    """Per-pipeline demand estimates at boundary ``t0``: oracle beats
    predictor beats the windowed fallback, per pipeline.  A pipeline whose
    trace has ended always estimates 0 (it must release the shared pool,
    whatever its predictor says about the stale history)."""
    try:
        fallback = DEMAND_ESTIMATORS[demand_mode]
    except KeyError:
        raise ValueError(f"demand_mode {demand_mode!r}") from None
    i = int(t0)
    out = []
    for p, r in enumerate(rates):
        if i >= len(r):
            out.append(0.0)
            continue
        if oracles is not None and oracles[p] is not None:
            out.append(float(oracles[p].predict_at(i)))
        elif predictors is not None and predictors[p] is not None and i >= 1:
            out.append(float(predictors[p].predict(r[:i])))
        else:
            out.append(fallback(r, t0, interval))
    return out


def _staged_admission(cluster, mixed: ClusterConfig,
                      committed: Sequence[PipelineConfig],
                      serving: Sequence[PipelineConfig]):
    """Admit the subset of a joint proposal that fits the budget *through*
    its transition windows, holding the rest for a later boundary.

    Used when ``mixed`` fits C after its windows but not through them
    (``sum_p max(old_p, new_p) > C``).  Changes are admitted greedily by
    ascending transition-charge delta — downsizes first (their charge is
    the old cost they already hold, so they are always admissible), then
    the cheapest grows — which is exactly the §5.3 staging a real shared
    pool needs: free the cores this interval, grant them the next.  This
    keeps policies that do not plan overlap-aware (the static splits)
    live on opposite-direction resizes: without it a shrink+grow pair
    whose combined transition never fits would be held forever.  Returns
    ``(staged config, per-pipeline admitted flags)``.
    """
    serve_c = [s.cost(pipe) for s, pipe in zip(serving, cluster.pipelines)]
    hold_c = [max(sc, c.cost(pipe))
              for sc, c, pipe in zip(serve_c, committed, cluster.pipelines)]
    total = sum(hold_c)
    chosen = list(committed)
    flags = [False] * cluster.n_pipelines
    if getattr(cluster, "is_hetero", False):
        # per-class ledgers: the same greedy order (ascending total-charge
        # delta), but a change is admitted only when *every* class fits
        classes = cluster.device_classes
        serve_v = [np.asarray(s.cost_by_class(pipe, classes))
                   for s, pipe in zip(serving, cluster.pipelines)]
        hold_v = [np.maximum(sv, np.asarray(c.cost_by_class(pipe, classes)))
                  for sv, c, pipe in zip(serve_v, committed,
                                         cluster.pipelines)]
        total_v = np.sum(hold_v, axis=0)
        budget_v = np.asarray(cluster.budget_vector)
        deltas = sorted(
            (float(np.sum(np.maximum(
                serve_v[p],
                np.asarray(mixed.pipelines[p].cost_by_class(pipe, classes)))
                - hold_v[p])), p)
            for p, pipe in enumerate(cluster.pipelines)
            if mixed.pipelines[p] != committed[p])
        for _, p in deltas:
            pipe = cluster.pipelines[p]
            new_hold = np.maximum(
                serve_v[p],
                np.asarray(mixed.pipelines[p].cost_by_class(pipe, classes)))
            cand = total_v + (new_hold - hold_v[p])
            if bool(np.all(cand <= budget_v + 1e-9)):
                chosen[p] = mixed.pipelines[p]
                flags[p] = True
                total_v = cand
                hold_v[p] = new_hold
        return ClusterConfig(tuple(chosen)), flags
    deltas = sorted(
        (max(serve_c[p], mixed.pipelines[p].cost(pipe)) - hold_c[p], p)
        for p, pipe in enumerate(cluster.pipelines)
        if mixed.pipelines[p] != committed[p])
    for d, p in deltas:
        if total + d <= cluster.cores + 1e-9:
            chosen[p] = mixed.pipelines[p]
            flags[p] = True
            total += d
    return ClusterConfig(tuple(chosen)), flags


def _pipeline_seeds(seed: SeedLike, n: int) -> List:
    """Per-pipeline arrival-stream seeds for ``run_cluster_trace``.

    A ``np.random.SeedSequence`` derives one child per pipeline —
    collision-free by construction, the hygiene the sweep harness relies
    on when thousands of cells each need N independent streams.  The
    children are built statelessly (entropy + extended spawn_key, exactly
    what ``spawn`` would produce on a fresh sequence) rather than via
    ``seed.spawn(n)``, whose internal counter would make a second run
    with the *same object* silently use different streams.  A plain int
    keeps the legacy ``seed + 1000003 * i`` arithmetic bit-for-bit (the
    golden cluster traces are pinned to those exact streams).
    """
    if isinstance(seed, np.random.SeedSequence):
        return [np.random.SeedSequence(
            entropy=seed.entropy,
            spawn_key=tuple(seed.spawn_key) + (i,)) for i in range(n)]
    return [seed + 1000003 * i for i in range(n)]


def _decide_cluster(cluster, lams, policy, obj, max_replicas,
                    ipa_kwargs=None, cache=None):
    try:
        fn = BL.CLUSTER_POLICIES[policy]
    except KeyError:
        raise ValueError(policy) from None
    kw = {"obj": obj, "max_replicas": max_replicas, "cache": cache}
    if policy == "ipa" and ipa_kwargs:
        kw.update(ipa_kwargs)
    return fn(cluster, lams, **kw)


def run_cluster_trace(cluster: ClusterModel,
                      rates: Sequence[np.ndarray],
                      policy: str = "ipa",
                      obj: Optional[OPT.Objective] = None,
                      interval: float = ADAPT_INTERVAL, seed: SeedLike = 0,
                      max_replicas: int = OPT.DEFAULT_MAX_REPLICAS,
                      predictors: Optional[Sequence] = None,
                      oracles: Optional[Sequence] = None,
                      demand_mode: str = "reactive",
                      switch_cost: float = 0.0,
                      switch_budget: Optional[int] = None,
                      adaptation_delay: float = 0.0,
                      sla_weights: Optional[Sequence[float]] = None,
                      frontier_cache="auto",
                      event_core: str = "heap"
                      ) -> ClusterTraceResult:
    """Drive N per-pipeline rate traces through one ``ClusterSimulator``.

    ``policy`` is a key of ``baselines.CLUSTER_POLICIES``: ``ipa`` (joint
    knapsack arbitration) or ``split_{ipa,fa2_low,fa2_high,rim}``
    (proportional static split).  At each adaptation boundary the policy
    proposes per-pipeline configs from the demand estimates; a pipeline
    whose sub-solution is infeasible holds the config the simulator is
    *committed* to (``pipeline_config`` — the in-flight transition target
    while one is rolling out, never the stale pre-transition config), and
    the mixed joint config is applied only if it fits the shared core
    budget — otherwise every pipeline holds.

    Demand estimation (per pipeline, past-only): ``oracles[p]`` (ground-
    truth future max, Fig. 16's baseline) beats ``predictors[p]`` (e.g. a
    trained ``LSTMPredictor``) beats the ``demand_mode`` fallback
    (``"reactive"``: trailing 20 s max; ``"burst"``: trailing 60 s max).

    Switch-cost knobs (joint policy only): ``switch_cost`` (objective
    units per changed pipeline — §5.3's adaptation overhead as lost
    objective, giving the solver hysteresis), ``switch_budget`` (max
    pipelines changed per interval) and ``sla_weights`` flow into
    ``optimizer.solve_cluster`` together with the simulator's committed
    config as the incumbent.  ``adaptation_delay > 0`` makes the simulator
    serve the old config for that window after each change; interval PAS
    *and cost* records become realized time-weighted values, the joint
    solver plans overlap-aware (``overlap=True`` with the serving configs,
    so each changed pipeline is budgeted at ``max(old, new)`` through its
    window), and a joint proposal is admitted only if it fits the budget
    throughout its transition (``ClusterSimulator.fits_transition``) —
    otherwise it is admitted *staged* via ``_staged_admission``:
    downsizes immediately (their transition charge is what they already
    hold), grows at a later boundary once the freed cores leave their
    windows.

    ``frontier_cache``: the cross-interval ``optimizer.FrontierCache``
    threaded through every boundary's policy call.  ``"auto"`` (default)
    creates a fresh exact-keyed cache for this run — arrival estimates
    repeat heavily across intervals, so most frontier builds become dict
    hits while staying bit-identical to uncached planning (property-
    tested).  ``None`` bypasses caching (the A/B knob); passing a
    ``FrontierCache`` instance shares it across runs of the *same* model
    objects.

    ``event_core``: the simulator hot loop — ``"heap"`` (reference),
    ``"struct"`` (structured-array batch-pop core) or ``"round"``
    (service-round core: per-pipeline event frontiers retired in
    independent rounds), all event-for-event identical; BENCH_scale
    replays and gates all three.
    """
    rates = [np.asarray(r, np.float64) for r in rates]
    if len(rates) != cluster.n_pipelines:
        raise ValueError("one rate trace per pipeline required")
    for name, seq in (("predictors", predictors), ("oracles", oracles)):
        if seq is not None and len(seq) != cluster.n_pipelines:
            raise ValueError(f"one {name} entry per pipeline required")
    if policy != "ipa" and (switch_cost != 0.0 or switch_budget is not None
                            or sla_weights is not None):
        # silently ignoring these would make a "split with hysteresis/
        # weights" benchmark measure the wrong experiment; weight split
        # baselines via ClusterModel.sla_weights instead
        raise ValueError("switch_cost/switch_budget/sla_weights apply to "
                         "the joint 'ipa' policy only")
    horizon = max(len(r) for r in rates)
    times = [arrivals_from_rates(r, seed=s)
             for r, s in zip(rates, _pipeline_seeds(seed, len(rates)))]
    ipa_kwargs = {"switch_cost": switch_cost, "switch_budget": switch_budget,
                  "sla_weights": sla_weights,
                  # §5.3 windows in play: plan against max(old, new) so a
                  # downsizer's freed cores are never granted mid-window
                  "overlap": adaptation_delay > 0}
    if frontier_cache == "auto":
        # the planner cache layers whole-solve / DP-prefix / eval memos on
        # top of the frontier memo, all exact-keyed: bit-identical results
        cache = OPT.PlannerCache()
    else:
        cache = frontier_cache          # an instance, or None = bypass

    # bootstrap from the first-interval peaks; fall back to cheapest
    # feasible (joint fa2-low split would still have to fit C, so use the
    # joint solver with a pure-cost objective)
    lam0 = [float(r[:int(interval)].max()) for r in rates]
    solver_wall = 0.0
    sol = _decide_cluster(cluster, lam0, policy, obj, max_replicas,
                          ipa_kwargs, cache)
    solver_wall += sol.solve_time
    if not sol.feasible:
        sol = OPT.solve_cluster(
            cluster, lam0, OPT.Objective(alpha=0.0, beta=1.0, delta=1e-6),
            max_replicas=max_replicas, cache=cache)
        solver_wall += sol.solve_time
    if not sol.feasible:
        raise RuntimeError(
            f"no feasible initial cluster config for {policy} "
            f"within budget {cluster.cores}")
    pool = RequestPool()
    sim = make_cluster_simulator(cluster, sol.config, event_core=event_core,
                                 request_pool=pool,
                                 adaptation_delay=adaptation_delay)
    for p, lam in enumerate(lam0):
        sim.set_lam_est(p, lam)

    records: List[List[IntervalRecord]] = [[] for _ in rates]
    ti = [0] * len(rates)
    # when the committed config of pipeline p changes at a boundary, its
    # stages keep serving the old config until this absolute time — the
    # realized-PAS blend below charges the transition window to it
    pending_until = [0.0] * len(rates)
    n_intervals = int(np.ceil(horizon / interval))
    for k in range(n_intervals):
        t0, t1 = k * interval, min((k + 1) * interval, horizon)
        # --- monitor + predict (at the boundary, using only the past) ----
        lam_hat = _cluster_demands(rates, t0, interval, demand_mode,
                                   predictors, oracles)
        # --- optimize + arbitrate + reconfigure --------------------------
        committed_before = [sim.pipeline_config(p)
                            for p in range(cluster.n_pipelines)]
        serving_before = [sim.serving_config(p)
                          for p in range(cluster.n_pipelines)]
        if policy == "ipa":
            ipa_kwargs["current"] = sim.current_config
            # mid-window the serving fleet differs from the committed
            # incumbent; the overlap charge must price what actually holds
            # cores right now
            ipa_kwargs["serving"] = ClusterConfig(tuple(serving_before))
        sol = _decide_cluster(cluster, lam_hat, policy, obj, max_replicas,
                              ipa_kwargs, cache)
        solver_wall += sol.solve_time
        per = sol.per_pipeline if sol.per_pipeline else [
            OPT._infeasible(0.0, sol.solver)] * cluster.n_pipelines
        mixed = ClusterConfig(tuple(
            s.config if s.feasible else committed_before[p]
            for p, s in enumerate(per)))
        # admission is transition-aware: the joint proposal must fit C
        # through every adaptation window (max(old, new) per changed
        # pipeline), not merely after them.  A proposal that only fits
        # after its windows is admitted *staged*: downsizes now (their
        # charge is already held), grows once the freed cores leave their
        # windows at a later boundary.  At zero delay there are no
        # windows and an over-budget proposal holds everyone (the PR 2/3
        # behaviour).
        if sim.fits_transition(mixed):
            admitted = [True] * cluster.n_pipelines
            applied = mixed
        elif adaptation_delay > 0:
            applied, admitted = _staged_admission(
                cluster, mixed, committed_before, serving_before)
        else:  # joint overflow, no windows to stage across: everyone holds
            admitted = [False] * cluster.n_pipelines
            applied = sim.current_config
        if any(admitted):
            sim.reconfigure(applied)
            for p, (s, lh) in enumerate(zip(per, lam_hat)):
                if admitted[p] and s.feasible:
                    sim.set_lam_est(p, lh)
        for p, pipe in enumerate(cluster.pipelines):
            cfg = applied.pipelines[p]
            if cfg != committed_before[p]:
                pending_until[p] = t0 + adaptation_delay
            # realized PAS and cost: the fraction of this interval still
            # served at the old config while the §5.3 adaptation window
            # runs out.  Both are blended time-weighted the same way; the
            # sum<=C budget invariant survives the blend because the
            # transition-charged ledger keeps instantaneous serving cost
            # <= C at every instant, so its per-interval time average
            # summed over pipelines is <= C too
            frac = 0.0
            if t1 > t0 and pending_until[p] > t0:
                frac = min(pending_until[p] - t0, t1 - t0) / (t1 - t0)
            pas = frac * pas_of(serving_before[p], pipe) \
                + (1.0 - frac) * pas_of(cfg, pipe)
            cost = frac * serving_before[p].cost(pipe) \
                + (1.0 - frac) * cfg.cost(pipe)
            seg = rates[p][int(t0):int(t1)]   # empty once a shorter
            records[p].append(IntervalRecord(  # pipeline's trace has ended
                t=t0, lam_true=float(seg.max()) if len(seg) else 0.0,
                lam_hat=lam_hat[p], pas=pas,
                # feasible means "this interval's proposal was applied for
                # this pipeline" — under staged admission only the admitted
                # subset counts; a zero-delay overflow holds everyone
                cost=cost,
                feasible=per[p].feasible and admitted[p],
                solve_time=sol.solve_time))
        # --- serve this interval -----------------------------------------
        # pre-sized arrival batching: one sorted-array cut + bulk inject
        # per pipeline per window (the simulator acquires from the pool)
        for p, tt in enumerate(times):
            i1 = int(np.searchsorted(tt, t1, side="left"))
            sim.inject_arrivals(tt[ti[p]:i1], p)
            ti[p] = i1
        sim.run_until(t1)
    # flush stragglers
    sim.run_until(horizon + 4 * max(sim.sla_of))
    results = []
    for p, pipe in enumerate(cluster.pipelines):
        m = sim.metrics_by_pipe[p]
        results.append(TraceResult(
            policy=policy, intervals=records[p],
            latencies=np.array(m.latencies, dtype=np.float64),
            arrived=m.arrived, completed=m.completed, dropped=m.dropped,
            sla=pipe.sla))
    return ClusterTraceResult(policy=policy, budget=float(cluster.cores),
                              per_pipeline=results,
                              sim_events=sim.events_processed,
                              peak_queue_depth=sim.peak_queue_depth,
                              n_reconfigs=sim.n_reconfigs,
                              reconfig_log=list(sim.reconfig_log),
                              peak_serving_cores=sim.peak_serving_cores,
                              solver_wall_s=float(solver_wall),
                              frontier_cache_stats=(
                                  cache.stats if cache is not None
                                  else None))


def run_cell(cluster: ClusterModel, rates: Sequence[np.ndarray],
             policy: str = "ipa",
             obj: Optional[OPT.Objective] = None,
             seed: SeedLike = 0,
             interval: float = ADAPT_INTERVAL,
             max_replicas: int = OPT.DEFAULT_MAX_REPLICAS,
             switch_cost: float = 0.0,
             switch_budget: Optional[int] = None,
             adaptation_delay: float = 0.0,
             demand_mode: str = "reactive",
             frontier_cache="auto",
             event_core: str = "heap") -> Dict:
    """One sweep cell: a full policy-trace run compacted to a JSON-ready
    record (the unit of work ``benchmarks/sweep.py`` fans out across
    worker processes).

    Wraps ``run_cluster_trace`` and flattens its result to plain python
    scalars/lists — no numpy arrays, no config objects — so the record
    pickles cheaply across the process boundary and serializes straight
    into a result shard.  Besides the headline metrics it carries the
    per-phase wall breakdown (``solver_wall_s`` from the trace result vs
    ``sim_wall_s`` = remaining wall) and the ``FrontierCache`` hit/miss
    stats — the *delta* attributable to this cell when the caller passes
    a warm per-worker cache instance — so straggler cells and cache-cold
    policies are diagnosable from the sweep JSON alone.

    Every field except the ``*wall_s`` timings (and the cache stats,
    which depend on what a warm cache saw before this cell) is a pure
    function of the inputs; the sweep's nproc-invariance hash is taken
    over exactly that deterministic remainder.
    """
    o = obj or OPT.Objective()
    cache = frontier_cache
    snap = cache.stats_snapshot() \
        if isinstance(cache, OPT.FrontierCache) else None
    t0 = time.perf_counter()
    res = run_cluster_trace(cluster, rates, policy=policy, obj=o, seed=seed,
                            interval=interval, max_replicas=max_replicas,
                            switch_cost=switch_cost,
                            switch_budget=switch_budget,
                            adaptation_delay=adaptation_delay,
                            demand_mode=demand_mode,
                            frontier_cache=cache, event_core=event_core)
    wall = time.perf_counter() - t0
    horizon = max(len(r) for r in rates)
    lat = np.concatenate([r.latencies for r in res.per_pipeline]) \
        if any(len(r.latencies) for r in res.per_pipeline) \
        else np.empty(0)
    late = sum(int(np.sum(r.latencies > r.sla)) for r in res.per_pipeline)
    arrived = res.arrived
    return {
        "policy": policy,
        "budget": float(cluster.cores),
        "horizon_s": int(horizon),
        "mean_pas": round(res.mean_pas, 6),
        "mean_cost": round(res.mean_cost, 6),
        "mean_objective": round(res.mean_objective(o), 6),
        "arrived": arrived,
        "completed": res.completed,
        "dropped": res.dropped,
        "sla_violation_rate": round((late + res.dropped) / arrived, 6)
        if arrived else 0.0,
        "p50_latency": round(float(np.percentile(lat, 50)), 6)
        if len(lat) else None,
        "p99_latency": round(float(np.percentile(lat, 99)), 6)
        if len(lat) else None,
        "n_reconfigs": res.n_reconfigs,
        "reconfigs_per_hour": round(res.n_reconfigs * 3600.0 / horizon, 3)
        if horizon else 0.0,
        "peak_serving_cores": round(res.peak_serving_cores, 6),
        "sim_events": res.sim_events,
        "peak_queue_depth": res.peak_queue_depth,
        "per_pipeline": [
            {"pipeline": pipe.name,
             "mean_pas": round(r.mean_pas, 6),
             "mean_cost": round(r.mean_cost, 6),
             "completed": r.completed, "dropped": r.dropped}
            for pipe, r in zip(cluster.pipelines, res.per_pipeline)],
        # wall-clock + warm-cache diagnostics: excluded from the sweep's
        # determinism hash (see study.strip_volatile)
        "wall_s": round(wall, 4),
        "solver_wall_s": round(res.solver_wall_s, 4),
        "sim_wall_s": round(wall - res.solver_wall_s, 4),
        "frontier_cache": (cache.stats_since(snap) if snap is not None
                           else res.frontier_cache_stats),
    }
