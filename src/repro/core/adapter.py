"""Online adapter (paper §3): monitor -> predict -> optimize -> reconfigure.

``run_trace`` drives a policy over a per-second rate trace through the
discrete-event simulator at a fixed adaptation interval (paper: 8 s
adaptation + <2 s decision = 10 s monitoring interval), recording
per-interval PAS / cost and global latency / drop / SLA metrics.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import baselines as BL
from repro.core import optimizer as OPT
from repro.core.accuracy import pas_of
from repro.core.pipeline import PipelineConfig, PipelineModel
from repro.core.simulator import PipelineSimulator
from repro.core.trace import arrivals_from_rates
from repro.serving.request import Request

ADAPT_INTERVAL = 10.0       # paper §5.3: 8 s adaptation + 2 s decision


@dataclasses.dataclass
class IntervalRecord:
    t: float
    lam_true: float
    lam_hat: float
    pas: float
    cost: float
    feasible: bool
    solve_time: float


@dataclasses.dataclass
class TraceResult:
    policy: str
    intervals: List[IntervalRecord]
    latencies: np.ndarray
    arrived: int
    completed: int
    dropped: int
    sla: float
    # simulator observability (filled by run_trace; defaults keep older
    # constructors working)
    sim_events: int = 0
    peak_queue_depth: int = 0

    @property
    def sla_violation_rate(self) -> float:
        if self.arrived == 0:
            return 0.0
        late = int(np.sum(self.latencies > self.sla))
        return (late + self.dropped) / self.arrived

    @property
    def mean_pas(self) -> float:
        return float(np.mean([r.pas for r in self.intervals]))

    @property
    def mean_cost(self) -> float:
        return float(np.mean([r.cost for r in self.intervals]))

    def summary(self) -> Dict[str, float]:
        return {
            "policy": self.policy,
            "mean_pas": round(self.mean_pas, 3),
            "mean_cost": round(self.mean_cost, 2),
            "sla_violation_rate": round(self.sla_violation_rate, 4),
            "dropped": self.dropped,
            "completed": self.completed,
            "p99_latency": round(float(np.percentile(self.latencies, 99)), 3)
            if len(self.latencies) else float("nan"),
        }


def run_trace(pipe: PipelineModel, rates: np.ndarray, policy: str = "ipa",
              obj: Optional[OPT.Objective] = None,
              predictor=None, oracle=None,
              interval: float = ADAPT_INTERVAL, seed: int = 0,
              max_replicas: int = OPT.DEFAULT_MAX_REPLICAS) -> TraceResult:
    """policy in {ipa, fa2_low, fa2_high, rim}; predictor: LSTMPredictor or
    None (reactive); oracle: OraclePredictor for the Fig.-16 'baseline'."""
    rates = np.asarray(rates, np.float64)
    times = arrivals_from_rates(rates, seed=seed)

    # initial config from the first-second load
    lam0 = float(rates[:int(interval)].max())
    sol = _decide(pipe, lam0, policy, obj, max_replicas)
    if not sol.feasible:
        # bootstrap fallback: cheapest feasible config (production behaviour:
        # a policy must never leave the pipeline unconfigured)
        sol = BL.fa2(pipe, lam0, "low", max_replicas=max_replicas)
    if not sol.feasible:
        raise RuntimeError(f"no feasible initial config for {policy}")
    sim = PipelineSimulator(pipe, sol.config)
    sim.lam_est = lam0
    records: List[IntervalRecord] = []

    horizon = len(rates)
    n_intervals = int(np.ceil(horizon / interval))
    ti = 0
    for k in range(n_intervals):
        t0, t1 = k * interval, min((k + 1) * interval, horizon)
        # --- monitor + predict (at the boundary, using only the past) ----
        hist = rates[:int(t0)]
        if oracle is not None:
            lam_hat = oracle.predict_at(int(t0))
        elif predictor is not None and len(hist) >= 1:
            lam_hat = predictor.predict(hist)
        else:
            lam_hat = float(hist[-20:].max()) if len(hist) else lam0
        # --- optimize + reconfigure --------------------------------------
        sol = _decide(pipe, lam_hat, policy, obj, max_replicas)
        if sol.feasible:
            sim.reconfigure(sol.config)
            sim.lam_est = lam_hat
            cfg = sol.config
        else:  # hold previous config
            cfg = PipelineConfig(tuple(sim.configs))
        records.append(IntervalRecord(
            t=t0, lam_true=float(rates[int(t0):int(t1)].max()),
            lam_hat=float(lam_hat), pas=pas_of(cfg, pipe),
            cost=cfg.cost(pipe), feasible=sol.feasible,
            solve_time=sol.solve_time))
        # --- serve this interval -----------------------------------------
        while ti < len(times) and times[ti] < t1:
            sim.inject(Request(arrival=float(times[ti]), sla=pipe.sla))
            ti += 1
        sim.run_until(t1)
    # flush stragglers
    sim.run_until(horizon + 4 * pipe.sla)
    m = sim.metrics
    return TraceResult(policy=policy, intervals=records,
                       latencies=np.array(m.latencies, dtype=np.float64),
                       arrived=m.arrived, completed=m.completed,
                       dropped=m.dropped, sla=pipe.sla,
                       sim_events=sim.events_processed,
                       peak_queue_depth=sim.peak_queue_depth)


def _decide(pipe, lam, policy, obj, max_replicas):
    try:
        fn = BL.POLICIES[policy]
    except KeyError:
        raise ValueError(policy) from None
    kw = {"max_replicas": max_replicas}
    if policy == "ipa":
        kw["obj"] = obj
    return fn(pipe, lam, **kw)
