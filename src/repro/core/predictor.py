"""Load predictor (paper §3 "Predictor" + §5.5).

A 25-unit LSTM + 1-unit dense head, implemented with lax.scan in pure JAX:
input = the past 120 s of per-second load, output = the *max* load of the
next 20 s.  Trained on the first 14 days of the (synthesized) Twitter trace
with our AdamW.  Also provides the reactive (last-window) and oracle
(ground-truth future) predictors used in the Fig.-16 ablation, and SMAPE.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.training import optim

HISTORY = 120          # seconds of history fed to the LSTM
HORIZON = 20           # predict max load over the next 20 s
HIDDEN = 25            # paper: 25-unit LSTM layer


def init_lstm(rng, hidden: int = HIDDEN):
    ks = jax.random.split(rng, 4)
    s_in = 1.0
    s_h = 1.0 / jnp.sqrt(jnp.asarray(hidden, jnp.float32))
    return {
        "w_x": jax.random.normal(ks[0], (1, 4 * hidden)) * s_in * 0.1,
        "w_h": jax.random.normal(ks[1], (hidden, 4 * hidden)) * s_h,
        "b": jnp.zeros((4 * hidden,)),
        "w_out": jax.random.normal(ks[2], (hidden, 1)) * s_h,
        "b_out": jnp.zeros((1,)),
    }


def lstm_apply(params, x):
    """x: (B, T) normalized loads -> (B,) prediction (normalized)."""
    b, t = x.shape
    h = params["w_h"].shape[0]

    def cell(carry, xt):
        hs, cs = carry
        gates = xt[:, None] @ params["w_x"] + hs @ params["w_h"] + params["b"]
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        cs = jax.nn.sigmoid(f + 1.0) * cs + jax.nn.sigmoid(i) * jnp.tanh(g)
        hs = jax.nn.sigmoid(o) * jnp.tanh(cs)
        return (hs, cs), None

    (hs, _), _ = jax.lax.scan(cell, (jnp.zeros((b, h)), jnp.zeros((b, h))),
                              x.T)
    return (hs @ params["w_out"] + params["b_out"])[:, 0]


def make_windows(trace: np.ndarray, stride: int = 10
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """(X: (N, HISTORY), y: (N,) = max of next HORIZON seconds)."""
    xs, ys = [], []
    for s in range(0, len(trace) - HISTORY - HORIZON, stride):
        xs.append(trace[s:s + HISTORY])
        ys.append(trace[s + HISTORY:s + HISTORY + HORIZON].max())
    return np.asarray(xs, np.float32), np.asarray(ys, np.float32)


def smape(pred: np.ndarray, true: np.ndarray) -> float:
    pred, true = np.asarray(pred), np.asarray(true)
    return float(100.0 * np.mean(
        np.abs(pred - true) / ((np.abs(pred) + np.abs(true)) / 2 + 1e-9)))


@dataclasses.dataclass
class LSTMPredictor:
    params: dict
    mean: float
    std: float

    @classmethod
    def train(cls, trace: np.ndarray, *, steps: int = 400, batch: int = 128,
              lr: float = 3e-3, seed: int = 0, stride: int = 10,
              verbose: bool = False) -> "LSTMPredictor":
        X, y = make_windows(trace, stride=stride)
        mean, std = float(X.mean()), float(X.std() + 1e-9)
        Xn, yn = (X - mean) / std, (y - mean) / std
        params = init_lstm(jax.random.PRNGKey(seed))
        ocfg = optim.AdamWConfig(lr=lr, warmup_steps=20, total_steps=steps,
                                 weight_decay=0.0, grad_clip=1.0)
        state = optim.init_state(params)

        @jax.jit
        def step(params, state, xb, yb):
            def loss(p):
                return jnp.mean((lstm_apply(p, xb) - yb) ** 2)
            l, g = jax.value_and_grad(loss)(params)
            params, state, _ = optim.apply_updates(params, g, state, ocfg)
            return params, state, l

        rng = np.random.default_rng(seed)
        for i in range(steps):
            idx = rng.integers(len(Xn), size=batch)
            params, state, l = step(params, state, jnp.asarray(Xn[idx]),
                                    jnp.asarray(yn[idx]))
            if verbose and i % 100 == 0:
                print(f"lstm step {i} mse={float(l):.4f}")
        return cls(params=params, mean=mean, std=std)

    def predict(self, history: np.ndarray) -> float:
        """history: most recent >= HISTORY per-second loads."""
        h = np.asarray(history, np.float32)[-HISTORY:]
        if len(h) < HISTORY:
            h = np.pad(h, (HISTORY - len(h), 0), mode="edge")
        x = (h[None] - self.mean) / self.std
        out = float(lstm_apply(self.params, jnp.asarray(x))[0])
        return max(out * self.std + self.mean, 0.1)

    def predict_batch(self, X: np.ndarray) -> np.ndarray:
        xn = (np.asarray(X, np.float32) - self.mean) / self.std
        out = np.asarray(lstm_apply(self.params, jnp.asarray(xn)))
        return np.maximum(out * self.std + self.mean, 0.1)


class ReactivePredictor:
    """No look-ahead: uses the recent max as the next-interval estimate."""

    def predict(self, history: np.ndarray) -> float:
        h = np.asarray(history, np.float64)
        return float(h[-HORIZON:].max()) if len(h) else 1.0


class OraclePredictor:
    """Ground-truth future max (the Fig.-16 'baseline predictor')."""

    def __init__(self, trace: np.ndarray, horizon: int = HORIZON):
        self.trace = np.asarray(trace, np.float64)
        self.horizon = int(horizon)

    def predict_at(self, now_s: int) -> float:
        fut = self.trace[now_s:now_s + self.horizon]
        return float(fut.max()) if len(fut) else float(self.trace[-1])

    @classmethod
    def for_traces(cls, traces, horizon: int = HORIZON):
        """One oracle per per-pipeline trace — the ``oracles`` argument of
        ``adapter.run_cluster_trace``."""
        return [cls(t, horizon) for t in traces]


def train_cluster_predictors(traces, **train_kw):
    """One ``LSTMPredictor`` per per-pipeline trace (each pipeline's load
    shape differs, so they do not share a model) — the ``predictors``
    argument of ``adapter.run_cluster_trace``."""
    return [LSTMPredictor.train(np.asarray(t, np.float32), **train_kw)
            for t in traces]
