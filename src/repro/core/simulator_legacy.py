"""Reference tick-based simulator — FROZEN, not part of the serving path.

This is the seed implementation of the discrete-event pipeline simulator,
kept verbatim for two purposes only:

* the old-vs-new equivalence harness (``tests/test_simulator_equivalence``)
  proving the event-driven core in ``simulator.py`` produces identical
  completed/dropped counts on deterministic traces, and
* the benchmark baseline in ``benchmarks/bench_simulator.py`` that tracks
  the speedup of the event-driven core over this tick flood.

Its flaw — and why it was replaced — is ``run_until``: it pushes a "tick"
event per stage every ``tick`` seconds of simulated time so partially
filled batches can time out, which schedules O(horizon / tick x stages)
no-op events before a single request arrives.  Do not import it from
production code.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import List, Tuple

from repro.core.pipeline import PipelineConfig, PipelineModel, StageConfig
from repro.serving.request import Request


@dataclasses.dataclass
class LegacySimMetrics:
    latencies: List[float] = dataclasses.field(default_factory=list)
    completed: int = 0
    dropped: int = 0
    arrived: int = 0

    def sla_violations(self, sla: float) -> float:
        if self.arrived == 0:
            return 0.0
        late = sum(1 for l in self.latencies if l > sla)
        return (late + self.dropped) / self.arrived


class LegacyTickSimulator:
    def __init__(self, pipe: PipelineModel, config: PipelineConfig,
                 drop_factor: float = 2.0, max_wait: float = 0.5,
                 seed: int = 0, variant_switch_delay: float = 0.0,
                 scale_up_delay: float = 0.0):
        self.pipe = pipe
        self.n_stages = len(pipe.stages)
        self.configs: List[StageConfig] = list(config.stages)
        self.drop_factor = drop_factor
        self.max_wait = max_wait
        self.variant_switch_delay = variant_switch_delay
        self.scale_up_delay = scale_up_delay
        self.queues: List[List[Request]] = [[] for _ in range(self.n_stages)]
        self.free_at: List[List[float]] = [
            [0.0] * sc.replicas for sc in self.configs]
        self.rr: List[int] = [0] * self.n_stages
        self.now = 0.0
        self.metrics = LegacySimMetrics()
        self._events: List[Tuple[float, int, str, object]] = []
        self._seq = itertools.count()
        self.lam_est = 10.0
        self.events_processed = 0

    def reconfigure(self, config: PipelineConfig) -> None:
        for s, sc in enumerate(config.stages):
            old = self.free_at[s]
            n = sc.replicas
            switched = sc.variant != self.configs[s].variant
            if switched and self.variant_switch_delay > 0:
                ready = self.now + self.variant_switch_delay
                old[:] = [max(t, ready) for t in old]
            if n >= len(old):
                start = self.now + (self.variant_switch_delay if switched
                                    else self.scale_up_delay)
                old.extend([start] * (n - len(old)))
            else:
                old.sort()
                del old[n:]
            self.configs[s] = sc

    def _push(self, t: float, kind: str, payload) -> None:
        heapq.heappush(self._events, (t, next(self._seq), kind, payload))

    def inject(self, req: Request) -> None:
        self.metrics.arrived += 1
        self._push(req.arrival, "arrive", (0, req))

    def _stage_latency(self, s: int, k: int) -> float:
        sc = self.configs[s]
        v = self.pipe.stages[s].variant(sc.variant)
        return float(v.latency(max(k, 1)))

    def _try_dispatch(self, s: int) -> None:
        q = self.queues[s]
        sc = self.configs[s]
        sla_p = self.pipe.sla
        kept = []
        for r in q:
            if (self.now - r.arrival) > self.drop_factor * sla_p:
                r.dropped_at = s
                r.done = self.now
                self.metrics.dropped += 1
            else:
                kept.append(r)
        q[:] = kept
        while q:
            free_idx = [i for i, t in enumerate(self.free_at[s])
                        if t <= self.now + 1e-12]
            if not free_idx:
                return
            full = len(q) >= sc.batch
            waited = self.now - q[0].stage_enter.get(s, q[0].arrival)
            timeout = waited >= self._wait_bound(sc.batch)
            if not (full or timeout):
                return
            k = min(sc.batch, len(q))
            batch, q[:] = q[:k], q[k:]
            rep = free_idx[self.rr[s] % len(free_idx)]
            self.rr[s] += 1
            lat = self._stage_latency(s, k)
            done_t = self.now + lat
            self.free_at[s][rep] = done_t
            self._push(done_t, "done", (s, batch))

    def _wait_bound(self, batch: int) -> float:
        return min(self.max_wait, (batch - 1) / max(self.lam_est, 1e-6)) \
            if batch > 1 else 0.0

    def _handle(self, kind: str, payload) -> None:
        if kind == "arrive":
            s, req = payload
            req.stage_enter[s] = self.now
            self.queues[s].append(req)
            self._try_dispatch(s)
        elif kind == "done":
            s, batch = payload
            for r in batch:
                r.stage_exit[s] = self.now
                if s + 1 < self.n_stages:
                    self._push(self.now, "arrive", (s + 1, r))
                else:
                    r.done = self.now
                    self.metrics.completed += 1
                    self.metrics.latencies.append(r.latency)
            self._try_dispatch(s)
        elif kind == "tick":
            s = payload
            self._try_dispatch(s)

    def run_until(self, t_end: float, tick: float = 0.05) -> None:
        t = self.now
        while t < t_end:
            t += tick
            for s in range(self.n_stages):
                self._push(t, "tick", s)
        while self._events and self._events[0][0] <= t_end:
            t, _, kind, payload = heapq.heappop(self._events)
            self.events_processed += 1
            self.now = max(self.now, t)
            self._handle(kind, payload)
        self.now = t_end
