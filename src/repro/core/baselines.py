"""Baseline policies from the paper's evaluation (§5.1).

* FA2 (Razavi et al., RTAS'22): optimal joint (batch, replicas) per stage for
  cost, but the model variant is FIXED.  FA2-low pins every stage to its
  lightest variant, FA2-high to its heaviest.  With the variant fixed, the
  minimum-cost feasible configuration is exactly what our enumeration solver
  returns with alpha = 0 (pure cost minimization) — equivalent to FA2's DP.
* RIM (Hu et al., IoTDI'21): model switching only; replication is pinned to a
  static high value, batching added for fairness (as the paper does).  RIM
  maximizes accuracy subject to latency/throughput feasibility.

All baselines plan against the same queueing model the simulator enforces:
``core.queueing`` provides both the analytical Eq. 7 delay (used by the
enumeration solver via ``PipelineConfig.latency``) and the batch-formation
``wait_bound`` the simulator arms as its dispatch timeout, so a config a
baseline deems feasible is judged by identical queueing assumptions at
simulation time.
"""
from __future__ import annotations

from typing import Optional

from repro.core import optimizer as OPT
from repro.core.pipeline import PipelineModel


def fa2(pipe: PipelineModel, arrival: float, level: str = "low",
        max_replicas: int = OPT.DEFAULT_MAX_REPLICAS) -> OPT.Solution:
    """FA2-low / FA2-high: fixed variants, min-cost (batch, replicas)."""
    variants = [s.lightest.name if level == "low" else s.heaviest.name
                for s in pipe.stages]
    obj = OPT.Objective(alpha=0.0, beta=1.0, delta=1e-6, metric="pas")
    return OPT.solve_enum(pipe, arrival, obj, max_replicas=max_replicas,
                          restrict_variants=variants)


def rim(pipe: PipelineModel, arrival: float, static_replicas: int = 24,
        max_replicas: int = OPT.DEFAULT_MAX_REPLICAS) -> OPT.Solution:
    """RIM: variant switching at a static (over-provisioned) replication."""
    obj = OPT.Objective(alpha=1.0, beta=0.0, delta=1e-6, metric="pas")
    return OPT.solve_enum(pipe, arrival, obj, max_replicas=max_replicas,
                          fixed_replicas=static_replicas)


def ipa(pipe: PipelineModel, arrival: float,
        obj: Optional[OPT.Objective] = None,
        max_replicas: int = OPT.DEFAULT_MAX_REPLICAS,
        solver: str = "auto") -> OPT.Solution:
    return OPT.solve(pipe, arrival, obj or OPT.Objective(),
                     solver=solver, max_replicas=max_replicas)


POLICIES = {
    "ipa": lambda pipe, lam, **kw: ipa(pipe, lam, **kw),
    "fa2_low": lambda pipe, lam, **kw: fa2(pipe, lam, "low", **kw),
    "fa2_high": lambda pipe, lam, **kw: fa2(pipe, lam, "high", **kw),
    "rim": lambda pipe, lam, **kw: rim(pipe, lam, **kw),
}
