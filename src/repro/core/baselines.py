"""Baseline policies from the paper's evaluation (§5.1), plus their
cluster-level variants (§6 discussion).

* FA2 (Razavi et al., RTAS'22): optimal joint (batch, replicas) per stage for
  cost, but the model variant is FIXED.  FA2-low pins every stage to its
  lightest variant, FA2-high to its heaviest.  With the variant fixed, the
  minimum-cost feasible configuration is exactly what our enumeration solver
  returns with alpha = 0 (pure cost minimization) — equivalent to FA2's DP.
* RIM (Hu et al., IoTDI'21): model switching only; replication is pinned to a
  static high value, batching added for fairness (as the paper does).  RIM
  maximizes accuracy subject to latency/throughput feasibility.

Cluster level: the joint IPA policy (``cluster_ipa``) arbitrates one
frontier point per pipeline under the shared core budget via the knapsack
in ``optimizer.solve_cluster``; the static-split baselines
(``cluster_split``) first divide the budget proportionally to per-pipeline
demand and then run a per-pipeline policy inside each share — the
INFaaS/InferLine-style strawman the joint solver has to beat.

All baselines plan against the same queueing model the simulator enforces:
``core.queueing`` provides both the analytical Eq. 7 delay (used by the
enumeration solver via ``PipelineConfig.latency``) and the batch-formation
``wait_bound`` the simulator arms as its dispatch timeout, so a config a
baseline deems feasible is judged by identical queueing assumptions at
simulation time.
"""
from __future__ import annotations

import time
from typing import Optional, Sequence

import numpy as np

from repro.core import optimizer as OPT
from repro.core.cluster import (ClusterConfig, ClusterModel,
                                proportional_split,
                                proportional_split_by_class)
from repro.core.pipeline import PipelineModel


def _over_cap(sol: OPT.Solution, pipe: PipelineModel, cap,
              classes) -> bool:
    """Does a solved config overflow its static-split share — the scalar
    cap, or (heterogeneous) any class's cap?"""
    if classes is None:
        return sol.cost > cap + 1e-9
    return any(cv > c + 1e-9
               for cv, c in zip(sol.config.cost_by_class(pipe, classes),
                                cap))


def fa2(pipe: PipelineModel, arrival: float, level: str = "low",
        max_replicas: int = OPT.DEFAULT_MAX_REPLICAS,
        solver: str = "vec") -> OPT.Solution:
    """FA2-low / FA2-high: fixed variants, min-cost (batch, replicas).

    ``solver`` names any ``optimizer.solve`` solver: ``vec`` (the float64
    broadcast hot path — default), ``brute`` (the plain-python oracle,
    bit-identical to ``vec``), or ``enum`` (the float32 JAX reference)."""
    variants = [s.lightest.name if level == "low" else s.heaviest.name
                for s in pipe.stages]
    obj = OPT.Objective(alpha=0.0, beta=1.0, delta=1e-6, metric="pas")
    return OPT.solve(pipe, arrival, obj, solver=solver,
                     max_replicas=max_replicas, restrict_variants=variants)


def rim(pipe: PipelineModel, arrival: float, static_replicas: int = 24,
        max_replicas: int = OPT.DEFAULT_MAX_REPLICAS,
        solver: str = "vec") -> OPT.Solution:
    """RIM: variant switching at a static (over-provisioned) replication.
    ``solver`` as in ``fa2``."""
    obj = OPT.Objective(alpha=1.0, beta=0.0, delta=1e-6, metric="pas")
    return OPT.solve(pipe, arrival, obj, solver=solver,
                     max_replicas=max_replicas,
                     fixed_replicas=static_replicas)


def ipa(pipe: PipelineModel, arrival: float,
        obj: Optional[OPT.Objective] = None,
        max_replicas: int = OPT.DEFAULT_MAX_REPLICAS,
        solver: str = "auto") -> OPT.Solution:
    return OPT.solve(pipe, arrival, obj or OPT.Objective(),
                     solver=solver, max_replicas=max_replicas)


POLICIES = {
    "ipa": lambda pipe, lam, **kw: ipa(pipe, lam, **kw),
    "fa2_low": lambda pipe, lam, **kw: fa2(pipe, lam, "low", **kw),
    "fa2_high": lambda pipe, lam, **kw: fa2(pipe, lam, "high", **kw),
    "rim": lambda pipe, lam, **kw: rim(pipe, lam, **kw),
}


# ---------------------------------------------------------------------------
# cluster level
# ---------------------------------------------------------------------------
def _objective_of(sol: OPT.Solution, pipe: PipelineModel,
                  obj: OPT.Objective) -> float:
    """A feasible solution's objective re-evaluated under ``obj`` (fa2/rim
    solve under their own internal weights)."""
    from repro.core import accuracy as ACC
    if obj.metric == "pas":
        acc = sol.pas
    elif obj.metric == "pas_prime":
        acc = ACC.pas_prime_of(sol.config, pipe)
    else:                                # log_pas: sum of log(a/100)
        acc = float(np.log(max(sol.pas, 1e-9) / 100.0))
    bat = sum(sc.batch for sc in sol.config.stages)
    return obj.alpha * acc - obj.beta * sol.cost - obj.delta * bat


def cluster_ipa(cluster: ClusterModel, lams: Sequence[float],
                obj: Optional[OPT.Objective] = None,
                max_replicas: int = OPT.DEFAULT_MAX_REPLICAS,
                current=None, switch_cost: float = 0.0,
                switch_budget: Optional[int] = None,
                sla_weights: Optional[Sequence[float]] = None,
                overlap: bool = False, serving=None,
                cache: Optional[OPT.FrontierCache] = None
                ) -> OPT.ClusterSolution:
    """Joint arbitration: one knapsack over per-pipeline Pareto frontiers
    under the shared core budget.  ``current``/``switch_cost``/
    ``switch_budget``/``sla_weights``/``overlap``/``serving`` make it
    switch-cost-aware, SLA-weighted and transition-overlap-aware (the knob
    semantics are documented in one place: ``optimizer.solve_cluster``);
    the defaults are the PR 2 behaviour bit-for-bit.  ``cache``: an
    optional ``optimizer.FrontierCache`` memoizing the frontier builds
    across adaptation intervals (bit-identical with exact keying)."""
    return OPT.solve_cluster(cluster, lams, obj or OPT.Objective(),
                             max_replicas=max_replicas, current=current,
                             switch_cost=switch_cost,
                             switch_budget=switch_budget,
                             sla_weights=sla_weights,
                             overlap=overlap, serving=serving,
                             cache=cache)


def cluster_split(cluster: ClusterModel, lams: Sequence[float],
                  inner: str = "ipa",
                  obj: Optional[OPT.Objective] = None,
                  max_replicas: int = OPT.DEFAULT_MAX_REPLICAS,
                  cache: Optional[OPT.FrontierCache] = None
                  ) -> OPT.ClusterSolution:
    """Proportional static split: pipeline i plans alone inside its demand
    share ``C * lam_i / sum(lam)`` of the core budget.

    ``inner`` picks the per-pipeline policy run inside each share: ``ipa``
    (cost-capped frontier pick), ``fa2_low`` / ``fa2_high`` / ``rim``
    (their usual solutions, rejected when they overflow the share).  A
    pipeline whose share is infeasible holds its previous config at the
    adapter level — its Solution comes back infeasible here.

    All returned objectives (per-pipeline and summed) are re-expressed
    under the caller's ``obj`` regardless of ``inner`` — fa2/rim solve
    with their own internal weights, and their raw objectives would be
    incommensurable with ``cluster_ipa``'s.  The summed objective is also
    SLA-weighted by the cluster's own ``sla_weights`` (per-pipeline
    objectives stay raw, as in ``cluster_ipa``), so joint-vs-split
    objective comparisons remain commensurable on weighted clusters.

    ``cache``: optional ``optimizer.FrontierCache`` for the inner ``ipa``
    sub-problem's frontier builds (the other inners do not build
    frontiers and ignore it).

    Heterogeneous clusters split *every class budget* by the same demand
    share (``proportional_split_by_class``) and cap the inner problems per
    class — the strongest static-split strawman the ``hetero`` benchmark
    measures the joint solver against.
    """
    t0 = time.perf_counter()
    o = obj or OPT.Objective()
    weights = cluster.weights
    hetero = getattr(cluster, "is_hetero", False)
    classes = cluster.device_classes if hetero else None
    if hetero:
        caps = proportional_split_by_class(cluster, lams)
    else:
        caps = proportional_split(cluster, lams)
    sols = []
    for pipe, lam, cap in zip(cluster.pipelines, lams, caps):
        if inner == "ipa":
            sol = OPT.solve_capped(pipe, lam, o, cap, max_replicas,
                                   cache=cache, classes=classes)
        elif inner in ("fa2_low", "fa2_high"):
            sol = fa2(pipe, lam, inner.split("_")[1], max_replicas)
            if sol.feasible and _over_cap(sol, pipe, cap, classes):
                sol = OPT._infeasible(t0, "split_" + inner)
            if sol.feasible:
                sol.objective = _objective_of(sol, pipe, o)
        elif inner == "rim":
            sol = rim(pipe, lam, max_replicas=max_replicas)
            if sol.feasible and _over_cap(sol, pipe, cap, classes):
                sol = OPT._infeasible(t0, "split_rim")
            if sol.feasible:
                sol.objective = _objective_of(sol, pipe, o)
        else:
            raise ValueError(inner)
        sols.append(sol)
    feasible = all(s.feasible for s in sols)
    cfg = (ClusterConfig(tuple(s.config for s in sols)) if feasible else None)
    return OPT.ClusterSolution(
        config=cfg, per_pipeline=sols,
        objective=float(sum(w * s.objective for w, s in zip(weights, sols)))
        if feasible else -np.inf,
        cost=float(sum(s.cost for s in sols if s.feasible)),
        feasible=feasible, solve_time=time.perf_counter() - t0,
        solver=f"split_{inner}")


CLUSTER_POLICIES = {
    "ipa": lambda cl, lams, **kw: cluster_ipa(cl, lams, **kw),
    "split_ipa": lambda cl, lams, **kw: cluster_split(cl, lams, "ipa", **kw),
    "split_fa2_low": lambda cl, lams, **kw: cluster_split(
        cl, lams, "fa2_low", **kw),
    "split_fa2_high": lambda cl, lams, **kw: cluster_split(
        cl, lams, "fa2_high", **kw),
    "split_rim": lambda cl, lams, **kw: cluster_split(cl, lams, "rim", **kw),
}
