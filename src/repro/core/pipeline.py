"""Control-plane data model: model variants, stages, pipelines (paper §2-4).

A ``ModelVariant`` is what the offline profiler produces: an accuracy scalar,
a base resource allocation R_m (Eq. 1) and a quadratic latency model
l(b) = alpha b^2 + beta b + gamma fitted on power-of-two batch profiles
(§4.2).  A ``StageModel`` is a task with its variant family and per-stage
SLA; a ``PipelineModel`` chains stages (linear pipelines, one input/output,
per §4.1).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

BATCH_CHOICES = (1, 2, 4, 8, 16, 32, 64)     # power-of-two profiling grid §4.2


@dataclasses.dataclass(frozen=True)
class ModelVariant:
    name: str
    accuracy: float                      # task measure, higher-is-better §4.1
    base_alloc: int                      # R_m: cores/chips per replica (Eq. 1)
    latency_coeffs: Tuple[float, float, float]   # (a, b, c): l = a b^2 + b x + c
    params_m: float = 0.0                # millions of parameters (metadata)

    def latency(self, batch) -> np.ndarray:
        a, b, c = self.latency_coeffs
        batch = np.asarray(batch, dtype=np.float64)
        return a * batch ** 2 + b * batch + c

    def throughput(self, batch) -> np.ndarray:
        """Per-replica RPS at batch size b (requests, not batches)."""
        batch = np.asarray(batch, dtype=np.float64)
        return batch / self.latency(batch)


@dataclasses.dataclass(frozen=True)
class StageModel:
    name: str
    variants: Tuple[ModelVariant, ...]
    sla: float                           # per-stage SLA_s (§4.2, Swayam x5)
    batch_choices: Tuple[int, ...] = BATCH_CHOICES

    def variant(self, name: str) -> ModelVariant:
        for v in self.variants:
            if v.name == name:
                return v
        raise KeyError(name)

    @property
    def lightest(self) -> ModelVariant:
        return min(self.variants, key=lambda v: (v.base_alloc, v.accuracy))

    @property
    def heaviest(self) -> ModelVariant:
        return max(self.variants, key=lambda v: (v.accuracy, v.base_alloc))


@dataclasses.dataclass(frozen=True)
class PipelineModel:
    name: str
    stages: Tuple[StageModel, ...]

    @property
    def sla(self) -> float:
        """SLA_P = sum of per-stage SLAs (§4.2)."""
        return float(sum(s.sla for s in self.stages))


@dataclasses.dataclass(frozen=True)
class StageConfig:
    variant: str
    batch: int
    replicas: int


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    stages: Tuple[StageConfig, ...]

    def cost(self, pipe: PipelineModel) -> float:
        """Sum_s n_s * R_s (paper's cost: replicas x cores-per-replica)."""
        return float(sum(
            sc.replicas * st.variant(sc.variant).base_alloc
            for sc, st in zip(self.stages, pipe.stages)))

    def latency(self, pipe: PipelineModel, arrival: float,
                latency_model: str = "worst_case") -> float:
        """End-to-end model latency + queueing delay (Eq. 7 + 10b).

        ``latency_model``: ``"worst_case"`` (default — Eq. 7's bound,
        bit-identical to the paper's planner) or ``"expected"`` (mean
        batch-formation wait + M/M/c Erlang-C wait across the stage's
        configured replicas; see ``core.queueing.expected_wait``).
        """
        from repro.core.queueing import expected_wait, queue_delay
        tot = 0.0
        for sc, st in zip(self.stages, pipe.stages):
            v = st.variant(sc.variant)
            svc = float(v.latency(sc.batch))
            if latency_model == "expected":
                tot += svc + expected_wait(sc.batch, arrival, sc.replicas, svc)
            elif latency_model == "worst_case":
                tot += svc + queue_delay(sc.batch, arrival)
            else:
                raise ValueError(latency_model)
        return tot

    def supports(self, pipe: PipelineModel, arrival: float) -> bool:
        """Throughput constraint 10c for every stage."""
        for sc, st in zip(self.stages, pipe.stages):
            v = st.variant(sc.variant)
            if sc.replicas * float(v.throughput(sc.batch)) < arrival - 1e-9:
                return False
        return True
