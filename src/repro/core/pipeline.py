"""Control-plane data model: model variants, stages, pipelines (paper §2-4).

A ``ModelVariant`` is what the offline profiler produces: an accuracy scalar,
a base resource allocation R_m (Eq. 1) and a quadratic latency model
l(b) = alpha b^2 + beta b + gamma fitted on power-of-two batch profiles
(§4.2).  A ``StageModel`` is a task with its variant family and per-stage
SLA; a ``PipelineModel`` holds a stage *graph*: by default a linear chain
(one input/output, per §4.1), or — via ``parents`` — a general DAG with
fan-out/fan-in the way IPA §5.1's real topologies and InferLine's
prediction DAGs are shaped (video → [detector ∥ classifier] → join).

DAG semantics in one paragraph: stages are listed in topological order;
``parents[i]`` names the stages feeding stage ``i`` (``parents[0]`` must be
empty — stage 0 is the single source — and exactly one stage, necessarily
the last, is referenced by nobody: the single sink).  Fan-out replicates a
request to every child, so *every* stage still sees the full arrival rate
lambda and Eq. 10c applies per branch unchanged.  Fan-in (a join) waits
for all parents.  The end-to-end latency bound (Eq. 7 per stage) is taken
along the *critical path*: the maximum over source→sink paths of the
per-stage service + queue-delay sums, because parallel branches overlap in
time rather than serialize.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

BATCH_CHOICES = (1, 2, 4, 8, 16, 32, 64)     # power-of-two profiling grid §4.2


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """One device class's measured profile of a model variant: the same
    (accuracy, R_m, quadratic latency) triple the offline profiler produces
    per hardware class (INFaaS-style variant+hardware selection).  Accuracy
    is per-class because hardware-specific builds (quantized edge binaries,
    reduced-precision GPU kernels) genuinely move the task measure."""
    device: str                          # class name, e.g. "cpu" / "gpu"
    latency_coeffs: Tuple[float, float, float]
    base_alloc: int                      # R_m in this class's budget units
    accuracy: float


@dataclasses.dataclass(frozen=True)
class ModelVariant:
    name: str
    accuracy: float                      # task measure, higher-is-better §4.1
    base_alloc: int                      # R_m: cores/chips per replica (Eq. 1)
    latency_coeffs: Tuple[float, float, float]   # (α, β, γ): l = α·b² + β·b + γ
    params_m: float = 0.0                # millions of parameters (metadata)
    # per-device-class profile table.  ``None`` (the default) is the legacy
    # single-class variant: it runs on exactly one class, "cpu", served by
    # the variant's own (accuracy, base_alloc, latency_coeffs) fields
    # through the identical float path — the device axis is invisible.
    device_profiles: Optional[Tuple[DeviceProfile, ...]] = None

    @property
    def device_classes(self) -> Tuple[str, ...]:
        """Device classes this variant can run on (legacy: ``("cpu",)``)."""
        if self.device_profiles is None:
            return ("cpu",)
        return tuple(dp.device for dp in self.device_profiles)

    def _fields_on(self, device: Optional[str]
                   ) -> Tuple[Tuple[float, float, float], int, float]:
        """(latency_coeffs, base_alloc, accuracy) on ``device``.

        ``None`` always means the variant's own fields (every legacy call
        site), as does ``"cpu"`` on a single-class variant — both hit the
        exact pre-device float path."""
        if device is None:
            return self.latency_coeffs, self.base_alloc, self.accuracy
        if self.device_profiles is None:
            if device != "cpu":
                raise KeyError(
                    f"variant {self.name} has no device class {device!r}")
            return self.latency_coeffs, self.base_alloc, self.accuracy
        for dp in self.device_profiles:
            if dp.device == device:
                return dp.latency_coeffs, dp.base_alloc, dp.accuracy
        raise KeyError(f"variant {self.name} has no device class {device!r}")

    def alloc(self, device: Optional[str] = None) -> int:
        """R_m on a device class (legacy fields when ``device`` is None)."""
        return self._fields_on(device)[1]

    def acc(self, device: Optional[str] = None) -> float:
        """Accuracy on a device class (legacy fields when ``device`` is
        None)."""
        return self._fields_on(device)[2]

    def latency(self, batch, device: Optional[str] = None) -> np.ndarray:
        a, b, c = self._fields_on(device)[0]
        batch = np.asarray(batch, dtype=np.float64)
        return a * batch ** 2 + b * batch + c

    def throughput(self, batch, device: Optional[str] = None) -> np.ndarray:
        """Per-replica RPS at batch size b (requests, not batches)."""
        batch = np.asarray(batch, dtype=np.float64)
        return batch / self.latency(batch, device)


@dataclasses.dataclass(frozen=True)
class StageModel:
    name: str
    variants: Tuple[ModelVariant, ...]
    sla: float                           # per-stage SLA_s (§4.2, Swayam x5)
    batch_choices: Tuple[int, ...] = BATCH_CHOICES

    def variant(self, name: str) -> ModelVariant:
        for v in self.variants:
            if v.name == name:
                return v
        raise KeyError(name)

    @property
    def lightest(self) -> ModelVariant:
        """Cheapest variant; equal-alloc ties prefer the *more* accurate."""
        return min(self.variants, key=lambda v: (v.base_alloc, -v.accuracy))

    @property
    def heaviest(self) -> ModelVariant:
        """Most accurate variant; equal-accuracy ties prefer the cheaper."""
        return max(self.variants, key=lambda v: (v.accuracy, -v.base_alloc))


@functools.lru_cache(maxsize=512)
def _all_paths(parents: Tuple[Tuple[int, ...], ...]) -> Tuple[Tuple[int, ...], ...]:
    """All source→sink stage paths, deterministic (children ascending)."""
    n = len(parents)
    children: List[List[int]] = [[] for _ in range(n)]
    for i, ps in enumerate(parents):
        for p in ps:
            children[p].append(i)
    out: List[Tuple[int, ...]] = []
    stack: List[int] = [0]

    def walk(i: int) -> None:
        if not children[i]:
            out.append(tuple(stack))
            return
        for c in children[i]:
            stack.append(c)
            walk(c)
            stack.pop()

    walk(0)
    return tuple(out)


def _chain_parents(n: int) -> Tuple[Tuple[int, ...], ...]:
    return tuple(() if i == 0 else (i - 1,) for i in range(n))


@dataclasses.dataclass(frozen=True)
class PipelineModel:
    """Stage graph.  ``parents=None`` (the default) is a linear chain;
    otherwise ``parents[i]`` lists the stages feeding stage ``i``.  Stages
    must be in topological order (each parent index < its child), which
    makes acyclicity free; stage 0 is the single source and exactly one
    stage — necessarily the last — may be a sink.  ``sla_override`` pins
    SLA_P explicitly (used e.g. by ``linearize`` so a chain-shaped planning
    model keeps the DAG's end-to-end budget)."""
    name: str
    stages: Tuple[StageModel, ...]
    parents: Optional[Tuple[Tuple[int, ...], ...]] = None
    sla_override: Optional[float] = None

    def __post_init__(self):
        if self.parents is None:
            return
        n = len(self.stages)
        if len(self.parents) != n:
            raise ValueError(
                f"parents has {len(self.parents)} entries for {n} stages")
        norm = tuple(tuple(sorted({int(p) for p in ps}))
                     for ps in self.parents)
        object.__setattr__(self, "parents", norm)
        if n == 0:
            return
        if norm[0] != ():
            raise ValueError("stage 0 must be the single source (no parents)")
        referenced = set()
        for i in range(1, n):
            ps = norm[i]
            if not ps:
                raise ValueError(
                    f"stage {i} has no parents: only stage 0 may be a source")
            if ps[0] < 0 or ps[-1] >= i:
                raise ValueError(
                    f"stage {i} parents {ps} must reference earlier stages "
                    "only (stages are listed in topological order)")
            referenced.update(ps)
        for i in range(n - 1):
            if i not in referenced:
                raise ValueError(
                    f"stage {i} feeds nothing: the graph must have a single "
                    f"sink (stage {n - 1})")

    # -- graph accessors ---------------------------------------------------
    @property
    def is_chain(self) -> bool:
        """True for a degenerate path graph (incl. explicit chain parents)."""
        return (self.parents is None
                or self.parents == _chain_parents(len(self.stages)))

    @property
    def effective_parents(self) -> Tuple[Tuple[int, ...], ...]:
        if self.parents is not None:
            return self.parents
        return _chain_parents(len(self.stages))

    def parents_of(self, i: int) -> Tuple[int, ...]:
        return self.effective_parents[i]

    def children_of(self, i: int) -> Tuple[int, ...]:
        return tuple(c for c, ps in enumerate(self.effective_parents)
                     if i in ps)

    def paths(self) -> Tuple[Tuple[int, ...], ...]:
        """All source→sink stage-index paths (a chain has exactly one)."""
        if self.parents is None:
            return (tuple(range(len(self.stages))),)
        return _all_paths(self.parents)

    def critical_path(self, weights: Optional[Sequence[float]] = None
                      ) -> Tuple[int, ...]:
        """The source→sink path maximizing the per-stage weight sum
        (default weights: the stage SLAs).  Ties break on path order."""
        w = ([s.sla for s in self.stages] if weights is None
             else [float(x) for x in weights])
        return max(self.paths(), key=lambda path: sum(w[i] for i in path))

    def linearize(self) -> "PipelineModel":
        """Chain-shaped planning model over the same stages, pinned to this
        pipeline's end-to-end SLA — what a chain-only planner (the
        pre-DAG IPA) would be forced to plan against: every stage's
        latency charged against the one budget, branches serialized."""
        return PipelineModel(self.name + "-linearized", self.stages,
                             parents=None, sla_override=self.sla)

    @property
    def sla(self) -> float:
        """SLA_P: sum of per-stage SLAs (§4.2) along the critical path —
        for a chain that is the plain sum over all stages."""
        if self.sla_override is not None:
            return float(self.sla_override)
        if self.parents is None:
            return float(sum(s.sla for s in self.stages))
        return float(max(sum(self.stages[i].sla for i in path)
                         for path in self.paths()))


@dataclasses.dataclass(frozen=True)
class StageConfig:
    variant: str
    batch: int
    replicas: int
    # device class the replicas are placed on.  The default keeps every
    # legacy 3-field construction (and its equality/hash) meaningful: a
    # single-class deployment is all-"cpu".
    device: str = "cpu"


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    stages: Tuple[StageConfig, ...]

    def cost(self, pipe: PipelineModel) -> float:
        """Sum_s n_s * R_s (paper's cost: replicas x cores-per-replica),
        totalled across device classes."""
        return float(sum(
            sc.replicas * st.variant(sc.variant).alloc(sc.device)
            for sc, st in zip(self.stages, pipe.stages)))

    def cost_by_class(self, pipe: PipelineModel,
                      classes: Sequence[str]) -> Tuple[float, ...]:
        """Per-device-class cost vector aligned with ``classes`` — the
        knapsack weight / ledger charge under per-class budgets.  A stage
        placed on a class outside ``classes`` is a configuration error."""
        tot: Dict[str, float] = {c: 0.0 for c in classes}
        for sc, st in zip(self.stages, pipe.stages):
            if sc.device not in tot:
                raise KeyError(
                    f"stage on device class {sc.device!r} but the budget "
                    f"only covers {tuple(classes)}")
            tot[sc.device] += sc.replicas * st.variant(sc.variant).alloc(
                sc.device)
        return tuple(float(tot[c]) for c in classes)

    def latency(self, pipe: PipelineModel, arrival: float,
                latency_model: str = "worst_case") -> float:
        """End-to-end model latency + queueing delay (Eq. 7 + 10b).

        For a chain this sums every stage; for a DAG it is the critical-
        path bound — the max over source→sink paths of the per-stage
        (service + queue delay) sums, since parallel branches overlap.
        Fan-out replicates arrivals, so each stage's queue delay is still
        priced at the full ``arrival`` rate.

        ``latency_model``: ``"worst_case"`` (default — Eq. 7's bound,
        bit-identical to the paper's planner) or ``"expected"`` (mean
        batch-formation wait + M/M/c Erlang-C wait across the stage's
        configured replicas; see ``core.queueing.expected_wait``).
        """
        from repro.core.queueing import expected_wait, queue_delay
        if pipe.is_chain:
            tot = 0.0
            for sc, st in zip(self.stages, pipe.stages):
                v = st.variant(sc.variant)
                svc = float(v.latency(sc.batch, sc.device))
                if latency_model == "expected":
                    tot += svc + expected_wait(sc.batch, arrival, sc.replicas,
                                               svc)
                elif latency_model == "worst_case":
                    tot += svc + queue_delay(sc.batch, arrival)
                else:
                    raise ValueError(latency_model)
            return tot
        terms = []
        for sc, st in zip(self.stages, pipe.stages):
            v = st.variant(sc.variant)
            svc = float(v.latency(sc.batch, sc.device))
            if latency_model == "expected":
                terms.append(svc + expected_wait(sc.batch, arrival,
                                                 sc.replicas, svc))
            elif latency_model == "worst_case":
                terms.append(svc + float(queue_delay(sc.batch, arrival)))
            else:
                raise ValueError(latency_model)
        best = None
        for path in pipe.paths():
            tot = 0.0
            for i in path:
                tot += terms[i]
            if best is None or tot > best:
                best = tot
        return float(best)

    def supports(self, pipe: PipelineModel, arrival: float) -> bool:
        """Throughput constraint 10c for every stage.

        Fan-out replicates the arrival stream to every child (and a join
        emits once per joined request), so each stage of a DAG sees the
        full rate lambda — the per-stage check is unchanged.
        """
        for sc, st in zip(self.stages, pipe.stages):
            v = st.variant(sc.variant)
            if sc.replicas * float(v.throughput(sc.batch, sc.device)) \
                    < arrival - 1e-9:
                return False
        return True
