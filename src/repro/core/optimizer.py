"""IPA's optimizer: the Integer Program of Eq. 10.

Decision per stage: (variant m, batch b, replicas n).  Key structural fact
used by every solver here: the objective strictly decreases in n (-beta n R)
and n appears only in the throughput constraint (10c), so the optimal
replica count for a chosen (m, b) is n*(m, b) = ceil(lambda / h_m(b)).
Substituting n* collapses the IP to "pick one (m, b) option per stage under
a total-latency budget" — which we solve four ways:

  * ``solve_vec``   -- exact enumeration of the option cross-product as one
    numpy broadcast over the per-stage option tables (feasibility mask, SLA
    cutoff and objective scoring all as float64 array ops; first-index
    argmax tie-break).  Exact for the true multiplicative PAS and
    bit-identical to ``solve_brute`` by construction (same accumulation
    order, same tie-break) — this is the adaptation loop's hot path.
  * ``solve_enum``  -- the same enumeration vectorized with JAX (vmap over
    combo indices, feasibility-masked argmax), kept as a cross-check
    reference.  NOTE: it evaluates in float32, so an *exact* objective tie
    can resolve to a different (equal-valued) config than the float64
    solvers; and the per-call ``jax.jit`` re-trace makes it ~100x slower
    than ``solve_vec`` in a decision loop.
  * ``solve_milp``  -- scipy HiGHS MILP (the Gurobi stand-in, §4.4) over
    binary x_{s,j}.  Exact for the *linear* accuracy metrics: PAS'
    (Appendix C) or log-PAS (a monotone surrogate of Eq. 8; exact tradeoff
    weighting differs from alpha*PAS — documented).  Scales to the paper's
    Fig.-13 regime (10 stages x 10 models in < 2 s).
  * ``solve_brute`` -- plain-python oracle for the property tests.

Cluster level (paper §6 discussion): ``pareto_frontier`` reduces one
pipeline at one rate to its cost -> objective Pareto frontier (any
off-frontier config is dominated and can never appear in an optimal joint
allocation); ``solve_cluster`` then arbitrates one frontier point per
pipeline under the shared core budget with an exact multiple-choice
knapsack DP (costs are integral: replicas x base allocation).  The DP is
switch-cost-aware (paper §5.3): given the incumbent ``ClusterConfig`` it
charges ``switch_cost`` per changed pipeline (the held config enters as a
zero-penalty stay candidate via ``evaluate_config``, which is hysteresis),
optionally caps changes per interval with an exact second DP dimension
(``switch_budget``), weights pipelines by SLA importance
(``sla_weights``), and — with ``overlap=True`` — plans each pipeline
against the transition charge ``max(old, new)`` so that a §5.3 adaptation
window (old fleet serving while the new one provisions) can never push
instantaneous serving capacity past the shared budget.  ``solve_capped``
is the per-pipeline sub-problem the proportional static-split baselines
run inside their budget share, and ``solve_cluster_brute`` is the
cross-product oracle for the tests.  The knob semantics live in one
place: the ``solve_cluster`` docstring.

``FrontierCache`` memoizes ``pareto_frontier`` across adaptation
intervals: a policy trace revisits the same (pipeline, rate) demand
points constantly (reactive estimators hold a value through many
boundaries), so with a cache threaded through ``solve_cluster`` /
``solve_capped`` most per-interval frontier builds become dict hits.
Exact keying (the default) is bit-identical to uncached planning.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core import accuracy as ACC
from repro.core.pipeline import (PipelineConfig, PipelineModel, StageConfig,
                                 StageModel)
from repro.core.queueing import expected_wait, queue_delay

DEFAULT_MAX_REPLICAS = 64


@dataclasses.dataclass(frozen=True)
class Objective:
    alpha: float = 1.0          # accuracy weight
    beta: float = 0.1           # resource weight
    delta: float = 1e-6         # batch penalty (paper: 1e-6)
    metric: str = "pas"         # pas | pas_prime | log_pas


@dataclasses.dataclass
class StageOptions:
    """Per-stage flattened (variant, device class, batch) options with n*
    substituted.  Single-class stages (no ``device_profiles`` anywhere)
    flatten to exactly the legacy (variant, batch) grid in the legacy
    order, with every ``devices`` entry ``"cpu"``."""
    names: List[str]
    batches: np.ndarray          # (J,)
    lat: np.ndarray              # (J,) model latency + queue delay
    cost: np.ndarray             # (J,) n* x R_m (in the class's own units)
    acc: np.ndarray              # (J,) raw accuracy (0-100 scale)
    acc_norm: np.ndarray         # (J,) rank-normalized (PAS')
    replicas: np.ndarray         # (J,) n*
    feasible: np.ndarray         # (J,) bool
    devices: List[str] = dataclasses.field(default_factory=list)


class _StageTable:
    """Rate-independent expansion of one stage's (variant, device, batch)
    grid: every column ``stage_options`` computes that does not depend on
    the arrival rate, flattened in the exact enumeration order.  Cached by
    ``PlannerCache`` so a frontier build at a *new* rate only runs the
    cheap n*/feasibility arithmetic over these columns instead of
    re-walking the model objects (the profiled hot spot of a cold solve).
    The per-option floats are the very values the uncached loop computes
    (same calls, made once), so the rebuilt ``StageOptions`` is
    bit-identical by construction.  Shared arrays (``batches``, ``acc``,
    ``accn``) and lists are treated as immutable by all callers — the same
    discipline as ``FrontierCache``'s shared frontier lists."""

    __slots__ = ("names", "devices", "batches_l", "h", "svc", "alloc",
                 "acc_l", "accn_l", "batches", "acc", "accn")

    def __init__(self, stage: StageModel):
        names, devices, batches, h, svc, alloc, acc, accn = \
            ([] for _ in range(8))
        pairs = [(v, d) for v in stage.variants for d in v.device_classes]
        norm = dict(zip(((v.name, d) for v, d in pairs),
                        ACC.rank_normalized([v.acc(d) for v, d in pairs])))
        for v in stage.variants:
            for d in v.device_classes:
                for b in stage.batch_choices:
                    names.append(v.name)
                    devices.append(d)
                    batches.append(b)
                    h.append(float(v.throughput(b, d)))
                    svc.append(float(v.latency(b, d)))
                    alloc.append(v.alloc(d))
                    acc.append(v.acc(d))
                    accn.append(norm[(v.name, d)])
        self.names = names
        self.devices = devices
        self.batches_l = batches
        self.h = h
        self.svc = svc
        self.alloc = alloc
        self.acc_l = acc
        self.accn_l = accn
        self.batches = np.array(batches)
        self.acc = np.array(acc)
        self.accn = np.array(accn)


def _options_from_table(tab: _StageTable, arrival: float, max_replicas: int,
                        latency_model: str) -> StageOptions:
    """The rate-dependent half of ``stage_options`` over cached static
    columns — the identical scalar arithmetic on the identical floats, so
    the result is bit-for-bit the uncached expansion."""
    lat, cost, reps, feas = [], [], [], []
    qd = {b: float(queue_delay(b, arrival)) for b in set(tab.batches_l)}
    expected = latency_model == "expected"
    for i, b in enumerate(tab.batches_l):
        h = tab.h[i]
        n = (max(1, math.ceil(max(arrival, 1e-9) / h)) if h > 0
             else max_replicas + 1)
        ok = n <= max_replicas and n * h >= arrival - 1e-9
        svc = tab.svc[i]
        if expected:
            lat.append(svc + float(expected_wait(b, arrival, n, svc)))
        else:
            lat.append(svc + qd[b])
        cost.append(n * tab.alloc[i])
        reps.append(n)
        feas.append(ok)
    return StageOptions(tab.names, tab.batches, np.array(lat),
                        np.array(cost, np.float64), tab.acc, tab.accn,
                        np.array(reps), np.array(feas), tab.devices)


def stage_options(stage: StageModel, arrival: float,
                  max_replicas: int = DEFAULT_MAX_REPLICAS,
                  latency_model: str = "worst_case",
                  tables: Optional[dict] = None) -> StageOptions:
    """Flatten a stage's (variant, device class, batch) grid with n*
    substituted.  The device loop nests between variant and batch, so a
    single-class stage enumerates bit-identically to the pre-device grid.

    ``latency_model``: ``"worst_case"`` keeps Eq. 7's bound (the default,
    bit-identical to the original planner); ``"expected"`` opts into the
    M/M/c-style mean delay (``core.queueing.expected_wait``) at the
    substituted replica count n*.

    ``tables``: an optional ``{stage: _StageTable}`` memo (threaded down
    from ``PlannerCache``) of the rate-independent columns; with it only
    the n*/feasibility/queue-delay arithmetic runs per rate.  Both paths
    produce bit-identical ``StageOptions`` (property-tested).
    """
    if latency_model not in ("worst_case", "expected"):
        raise ValueError(latency_model)
    if tables is not None:
        tab = tables.get(stage)
        if tab is None:
            tab = tables[stage] = _StageTable(stage)
        return _options_from_table(tab, arrival, max_replicas, latency_model)
    names, batches, lat, cost, acc, accn, reps, feas = ([] for _ in range(8))
    devices: List[str] = []
    pairs = [(v, d) for v in stage.variants for d in v.device_classes]
    norm = dict(zip(((v.name, d) for v, d in pairs),
                    ACC.rank_normalized([v.acc(d) for v, d in pairs])))
    for v in stage.variants:
        for d in v.device_classes:
            for b in stage.batch_choices:
                h = float(v.throughput(b, d))
                n = (max(1, math.ceil(max(arrival, 1e-9) / h)) if h > 0
                     else max_replicas + 1)
                ok = n <= max_replicas and n * h >= arrival - 1e-9
                names.append(v.name)
                devices.append(d)
                batches.append(b)
                svc = float(v.latency(b, d))
                if latency_model == "expected":
                    lat.append(svc + float(expected_wait(b, arrival, n, svc)))
                else:
                    lat.append(svc + float(queue_delay(b, arrival)))
                cost.append(n * v.alloc(d))
                acc.append(v.acc(d))
                accn.append(norm[(v.name, d)])
                reps.append(n)
                feas.append(ok)
    return StageOptions(names, np.array(batches), np.array(lat),
                        np.array(cost, np.float64), np.array(acc),
                        np.array(accn), np.array(reps), np.array(feas),
                        devices)


def _apply_restrictions(pipe: PipelineModel, opts: List[StageOptions],
                        restrict_variants: Optional[Sequence[str]],
                        fixed_replicas: Optional[int], arrival: float):
    if restrict_variants is not None:
        for o, vname in zip(opts, restrict_variants):
            keep = np.array([n == vname for n in o.names])
            o.feasible = o.feasible & keep
    if fixed_replicas is not None:
        for o, stage in zip(opts, pipe.stages):
            o.replicas = np.full_like(o.replicas, fixed_replicas)
            o.cost = np.array([fixed_replicas * stage.variant(n).alloc(d)
                               for n, d in zip(o.names, o.devices)],
                              np.float64)
            # throughput must still clear arrival at the pinned replication
            thr = np.array(
                [fixed_replicas * float(stage.variant(n).throughput(b, d))
                 for n, b, d in zip(o.names, o.batches, o.devices)])
            o.feasible = o.feasible & (thr >= arrival - 1e-9)
    return opts


def _acc_term(o: StageOptions, metric: str) -> np.ndarray:
    if metric == "pas":
        # log-space; combined multiplicatively then exponentiated exactly
        return np.log(np.maximum(o.acc, 1e-9) / 100.0)
    if metric == "pas_prime":
        return o.acc_norm
    if metric == "log_pas":
        return np.log(np.maximum(o.acc, 1e-9) / 100.0)
    raise ValueError(metric)


def _combine_acc(total_log_or_sum: np.ndarray, metric: str) -> np.ndarray:
    if metric == "pas":
        return 100.0 * np.exp(total_log_or_sum)
    return total_log_or_sum


@dataclasses.dataclass
class Solution:
    config: Optional[PipelineConfig]
    objective: float
    pas: float
    cost: float
    latency: float
    solve_time: float
    feasible: bool
    solver: str


def _mk_solution(pipe, opts, picks, obj: Objective, arrival, t0, solver):
    stages = []
    accs = []
    lats = []
    lat = cost = bat = 0.0
    for o, j, st in zip(opts, picks, pipe.stages):
        stages.append(StageConfig(o.names[j], int(o.batches[j]),
                                  int(o.replicas[j]), o.devices[j]))
        accs.append(o.acc[j])
        lats.append(o.lat[j])
        lat += o.lat[j]
        cost += o.cost[j]
        bat += o.batches[j]
    if not pipe.is_chain:
        # critical-path latency: max over source->sink paths of the
        # per-stage sums (parallel branches overlap, they don't serialize)
        lat = -np.inf
        for path in pipe.paths():
            t = 0.0
            for i in path:
                t += lats[i]
            if t > lat:
                lat = t
    acc_val = (ACC.pas(accs) if obj.metric == "pas"
               else sum(_acc_term(o, obj.metric)[j] for o, j in zip(opts, picks)))
    objective = obj.alpha * acc_val - obj.beta * cost - obj.delta * bat
    return Solution(PipelineConfig(tuple(stages)), float(objective),
                    ACC.pas(accs), float(cost), float(lat),
                    time.perf_counter() - t0, True, solver)


def _infeasible(t0, solver):
    return Solution(None, -np.inf, 0.0, 0.0, np.inf,
                    time.perf_counter() - t0, False, solver)


# ---------------------------------------------------------------------------
# exact enumeration (numpy broadcast — the hot path)
# ---------------------------------------------------------------------------
def _broadcast_eval(opts: List[StageOptions], obj: Objective, sla: float,
                    stage0_fastest: bool = True,
                    paths: Optional[Sequence[Tuple[int, ...]]] = None):
    """Evaluate the full option cross-product as one numpy broadcast.

    With ``stage0_fastest`` (the frontier/combo convention), combo ``k``'s
    stage-``s`` pick is ``(k // prod(sizes[:s])) % sizes[s]`` — stage ``s``
    maps to axis ``S-1-s`` of the broadcast lattice so the C-order ravel
    enumerates combos in exactly that order.  With it off, the flat order
    is ``itertools.product``'s (last stage fastest — ``solve_brute``'s
    scan order, which is what makes ``solve_vec``'s first-index argmax
    tie-break match the oracle's strict-improvement scan exactly).
    Either way, accumulation runs in stage order with the same float64
    operations as the retired per-stage fancy-indexing loop (and as
    ``solve_brute``'s python sums), so every returned array is
    bit-identical to both — the frontier/oracle property tests pin this.

    ``paths`` (DAG pipelines): the source→sink stage-index paths from
    ``PipelineModel.paths()``.  The SLA latency then becomes the
    critical-path reduction — per-path sums (stage adds in path order)
    maxed elementwise across paths in list order — instead of one total
    over all stages.  ``None`` (chains) keeps the legacy single-sum float
    path untouched; both reductions are pinned bit-identical to the brute
    path-enumeration oracle.

    Returns flat length-``prod(sizes)`` arrays:
    ``(ok, score, cost, pas, lat)`` — ``lat`` being the critical-path
    latency when ``paths`` is given.
    """
    S = len(opts)

    def view(col: np.ndarray, s: int) -> np.ndarray:
        shape = [1] * S
        shape[(S - 1 - s) if stage0_fastest else s] = len(col)
        return np.asarray(col).reshape(shape)

    lat_views = [view(o.lat, s) for s, o in enumerate(opts)]
    if paths is None:
        lat_tot = lat_views[0]
        for s in range(1, S):
            lat_tot = lat_tot + lat_views[s]
    else:
        lat_tot = None
        for path in paths:
            pl = lat_views[path[0]]
            for i in path[1:]:
                pl = pl + lat_views[i]
            lat_tot = pl if lat_tot is None else np.maximum(lat_tot, pl)
    cost_tot = view(opts[0].cost, 0)
    bat_tot = view(opts[0].batches.astype(np.float64), 0)
    pas_log_tot = view(_acc_term(opts[0], "pas"), 0)
    acc_tot = (pas_log_tot if obj.metric == "pas"
               else view(_acc_term(opts[0], obj.metric), 0))
    ok = view(opts[0].feasible, 0)
    for s, o in enumerate(opts[1:], start=1):
        cost_tot = cost_tot + view(o.cost, s)
        bat_tot = bat_tot + view(o.batches.astype(np.float64), s)
        pas_term = view(_acc_term(o, "pas"), s)
        pas_log_tot = pas_log_tot + pas_term
        acc_tot = (pas_log_tot if obj.metric == "pas"
                   else acc_tot + view(_acc_term(o, obj.metric), s))
        ok = ok & view(o.feasible, s)
    lat_tot = np.broadcast_to(lat_tot, ok.shape).reshape(-1)
    cost_tot = np.broadcast_to(cost_tot, ok.shape).reshape(-1)
    bat_tot = np.broadcast_to(bat_tot, ok.shape).reshape(-1)
    pas_log_tot = np.broadcast_to(pas_log_tot, ok.shape).reshape(-1)
    acc_tot = (pas_log_tot if obj.metric == "pas"
               else np.broadcast_to(acc_tot, ok.shape).reshape(-1))
    ok = ok.reshape(-1) & (lat_tot <= sla)
    acc_val = _combine_acc(acc_tot, obj.metric)
    score = obj.alpha * acc_val - obj.beta * cost_tot - obj.delta * bat_tot
    pas_val = 100.0 * np.exp(pas_log_tot)
    return ok, score, cost_tot, pas_val, lat_tot


def _unravel_picks(k: int, sizes: Sequence[int]) -> List[int]:
    """Per-stage option indices of flat combo ``k`` in
    ``itertools.product`` order (last stage fastest-varying)."""
    picks = []
    for j in reversed(sizes):
        picks.append(int(k % j))
        k //= j
    return list(reversed(picks))


def solve_vec(pipe: PipelineModel, arrival: float,
              obj: Objective = Objective(),
              max_replicas: int = DEFAULT_MAX_REPLICAS,
              restrict_variants=None, fixed_replicas=None,
              latency_model: str = "worst_case",
              max_combos: int = 1 << 23) -> Solution:
    """Exact enumeration of Eq. 10 as float64 numpy broadcast ops.

    Bit-identical to ``solve_brute`` by construction: same per-stage
    accumulation order, same feasibility/SLA boundary, and ``np.argmax``'s
    first-occurrence tie-break over the ``itertools.product``-ordered
    lattice matches the oracle's strict-improvement scan.  This is the
    per-interval decision loop's solver — no per-call JIT tracing, just a
    handful of array ops over the option lattice.
    """
    t0 = time.perf_counter()
    opts = [stage_options(s, arrival, max_replicas, latency_model)
            for s in pipe.stages]
    opts = _apply_restrictions(pipe, opts, restrict_variants, fixed_replicas,
                               arrival)
    sizes = [len(o.names) for o in opts]
    if math.prod(sizes) > max_combos:
        raise ValueError(f"pipeline {pipe.name}: {math.prod(sizes)} combos "
                         f"exceed the vectorized cap {max_combos}; use "
                         f"solve_milp")
    ok, score, _, _, _ = _broadcast_eval(
        opts, obj, pipe.sla, stage0_fastest=False,
        paths=None if pipe.is_chain else pipe.paths())
    score = np.where(ok, score, -np.inf)
    k = int(np.argmax(score))
    if not np.isfinite(score[k]):
        return _infeasible(t0, "vec")
    return _mk_solution(pipe, opts, _unravel_picks(k, sizes), obj, arrival,
                        t0, "vec")


# ---------------------------------------------------------------------------
# exact enumeration (JAX — float32 cross-check reference)
# ---------------------------------------------------------------------------
def solve_enum(pipe: PipelineModel, arrival: float, obj: Objective = Objective(),
               max_replicas: int = DEFAULT_MAX_REPLICAS,
               restrict_variants=None, fixed_replicas=None,
               chunk: int = 1 << 20,
               latency_model: str = "worst_case") -> Solution:
    import jax
    import jax.numpy as jnp

    t0 = time.perf_counter()
    opts = [stage_options(s, arrival, max_replicas, latency_model)
            for s in pipe.stages]
    opts = _apply_restrictions(pipe, opts, restrict_variants, fixed_replicas,
                               arrival)
    S = len(opts)
    J = max(len(o.names) for o in opts)

    def pad(x, fill):
        return np.stack([np.pad(np.asarray(x(o), np.float64),
                                (0, J - len(o.names)),
                                constant_values=fill) for o in opts])

    acc_t = pad(lambda o: _acc_term(o, obj.metric), 0.0)
    lat = pad(lambda o: o.lat, 1e18)
    cost = pad(lambda o: o.cost, 1e18)
    bat = pad(lambda o: o.batches.astype(np.float64), 1e18)
    valid = pad(lambda o: o.feasible.astype(np.float64), 0.0) > 0.5

    acc_t, lat, cost, bat, valid = map(jnp.asarray,
                                       (acc_t, lat, cost, bat, valid))
    sla = pipe.sla
    K = J ** S
    radix = jnp.array([J ** s for s in range(S)])
    path_idx = (None if pipe.is_chain
                else [jnp.array(p) for p in pipe.paths()])

    def eval_combo(k):
        js = (k // radix) % J
        idx = (jnp.arange(S), js)
        lat_k = lat[idx]
        if path_idx is None:
            lat_ok = jnp.sum(lat_k) <= sla
        else:                            # critical path: every path in SLA
            lat_ok = jnp.all(jnp.stack(
                [jnp.sum(lat_k[p]) for p in path_idx]) <= sla)
        ok = jnp.all(valid[idx]) & lat_ok
        a = jnp.sum(acc_t[idx])
        if obj.metric == "pas":
            a = 100.0 * jnp.exp(a)
        score = obj.alpha * a - obj.beta * jnp.sum(cost[idx]) \
            - obj.delta * jnp.sum(bat[idx])
        return jnp.where(ok, score, -jnp.inf)

    eval_v = jax.jit(jax.vmap(eval_combo))
    best_k, best_v = -1, -np.inf
    for start in range(0, K, chunk):
        ks = jnp.arange(start, min(start + chunk, K))
        vals = eval_v(ks)
        i = int(jnp.argmax(vals))
        if float(vals[i]) > best_v:
            best_v, best_k = float(vals[i]), start + i
    if not np.isfinite(best_v):
        return _infeasible(t0, "enum")
    picks = [(best_k // (J ** s)) % J for s in range(S)]
    return _mk_solution(pipe, opts, picks, obj, arrival, t0, "enum")


# ---------------------------------------------------------------------------
# plain-python oracle
# ---------------------------------------------------------------------------
def solve_brute(pipe: PipelineModel, arrival: float,
                obj: Objective = Objective(),
                max_replicas: int = DEFAULT_MAX_REPLICAS,
                restrict_variants=None, fixed_replicas=None,
                latency_model: str = "worst_case") -> Solution:
    t0 = time.perf_counter()
    opts = [stage_options(s, arrival, max_replicas, latency_model)
            for s in pipe.stages]
    opts = _apply_restrictions(pipe, opts, restrict_variants, fixed_replicas,
                               arrival)
    best, best_v = None, -np.inf
    paths = None if pipe.is_chain else pipe.paths()
    ranges = [range(len(o.names)) for o in opts]
    for picks in itertools.product(*ranges):
        if not all(o.feasible[j] for o, j in zip(opts, picks)):
            continue
        if paths is None:
            lat = sum(o.lat[j] for o, j in zip(opts, picks))
        else:
            # brute path enumeration: per-path sums in path order, maxed
            # in path-list order — the oracle _broadcast_eval must match
            lat = -np.inf
            for path in paths:
                t = 0.0
                for i in path:
                    t += opts[i].lat[picks[i]]
                if t > lat:
                    lat = t
        if lat > pipe.sla:
            continue
        a = sum(_acc_term(o, obj.metric)[j] for o, j in zip(opts, picks))
        if obj.metric == "pas":
            a = 100.0 * np.exp(a)
        v = obj.alpha * a - obj.beta * sum(o.cost[j] for o, j in zip(opts, picks)) \
            - obj.delta * sum(o.batches[j] for o, j in zip(opts, picks))
        if v > best_v:
            best_v, best = v, picks
    if best is None:
        return _infeasible(t0, "brute")
    return _mk_solution(pipe, opts, best, obj, arrival, t0, "brute")


# ---------------------------------------------------------------------------
# MILP (HiGHS — the Gurobi stand-in)
# ---------------------------------------------------------------------------
def solve_milp(pipe: PipelineModel, arrival: float,
               obj: Objective = Objective(metric="pas_prime"),
               max_replicas: int = DEFAULT_MAX_REPLICAS,
               restrict_variants=None, fixed_replicas=None,
               latency_model: str = "worst_case") -> Solution:
    from scipy import optimize as sopt
    from scipy import sparse

    t0 = time.perf_counter()
    opts = [stage_options(s, arrival, max_replicas, latency_model)
            for s in pipe.stages]
    opts = _apply_restrictions(pipe, opts, restrict_variants, fixed_replicas,
                               arrival)
    metric = obj.metric if obj.metric != "pas" else "log_pas"
    sizes = [len(o.names) for o in opts]
    n = sum(sizes)
    offs = np.cumsum([0] + sizes[:-1])

    c = np.concatenate([
        -(obj.alpha * _acc_term(o, metric)
          - obj.beta * o.cost - obj.delta * o.batches) for o in opts])
    # infeasible options: forbid via upper bound 0.  Options with an
    # infinite latency (zero-demand batches > 1) are likewise forbidden so
    # the latency rows stay finite for HiGHS.
    lat_all = np.concatenate([o.lat for o in opts])
    finite = np.isfinite(lat_all)
    ub = np.concatenate([o.feasible.astype(np.float64) for o in opts]) * finite
    lat_all = np.where(finite, lat_all, 0.0)

    rows, cols, vals = [], [], []
    for s, (o, off) in enumerate(zip(opts, offs)):
        for j in range(sizes[s]):
            rows.append(s); cols.append(off + j); vals.append(1.0)
    a_eq = sparse.coo_matrix((vals, (rows, cols)), shape=(len(opts), n))
    # one latency budget row per source->sink path (a chain has one path
    # covering every stage): sum of picked per-stage latencies <= SLA_P
    paths = pipe.paths()
    lat_rows = np.zeros((len(paths), n))
    for r, path in enumerate(paths):
        for s in path:
            off = offs[s]
            lat_rows[r, off:off + sizes[s]] = lat_all[off:off + sizes[s]]

    constraints = [
        sopt.LinearConstraint(a_eq, lb=1.0, ub=1.0),
        sopt.LinearConstraint(lat_rows, ub=pipe.sla),
    ]
    res = sopt.milp(c=c, constraints=constraints,
                    integrality=np.ones(n),
                    bounds=sopt.Bounds(lb=np.zeros(n), ub=ub))
    if not res.success or res.x is None:
        return _infeasible(t0, "milp")
    x = np.round(res.x).astype(int)
    picks = []
    for s, (o, off) in enumerate(zip(opts, offs)):
        sel = np.nonzero(x[off:off + sizes[s]])[0]
        if len(sel) != 1:
            return _infeasible(t0, "milp")
        picks.append(int(sel[0]))
    return _mk_solution(pipe, opts, picks, obj, arrival, t0, "milp")


def solve(pipe: PipelineModel, arrival: float, obj: Objective = Objective(),
          solver: str = "auto", **kw) -> Solution:
    if solver == "auto":
        combos = math.prod(len(s.variants) * len(s.batch_choices)
                           for s in pipe.stages)
        solver = "vec" if combos <= (1 << 23) else "milp"
    fn = {"vec": solve_vec, "enum": solve_enum, "brute": solve_brute,
          "milp": solve_milp}[solver]
    return fn(pipe, arrival, obj, **kw)


# ---------------------------------------------------------------------------
# cluster level: per-pipeline cost -> objective Pareto frontiers, arbitrated
# by a multiple-choice knapsack under the shared core budget
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FrontierPoint:
    """One Pareto-optimal (cost, objective) operating point of a pipeline.

    ``cost_vec``: per-device-class cost vector (aligned with the cluster's
    sorted ``device_classes``), set only by the heterogeneous frontier /
    oracle paths — the knapsack weight under per-class budgets.  ``cost``
    stays the scalar total either way."""
    cost: float                 # integer-valued: sum_s n*_s x R_m
    objective: float            # alpha*acc - beta*cost - delta*batches
    pas: float
    latency: float
    config: PipelineConfig
    cost_vec: Optional[Tuple[float, ...]] = None


def _combo_eval(pipe: PipelineModel, arrival: float, obj: Objective,
                max_replicas: int, latency_model: str,
                max_combos: int = 1 << 22,
                tables: Optional[dict] = None):
    """Vectorized evaluation of the full per-pipeline option cross-product.

    Returns (opts, feasible-combo indices as per-stage pick columns, cost,
    objective, pas) over feasible combos only.  Shared by the frontier
    builder and the brute cluster oracle.  The evaluation itself is one
    ``_broadcast_eval`` pass; only the surviving combos' per-stage pick
    columns are materialized.  ``tables``: optional ``_StageTable`` memo
    for the rate-independent half of ``stage_options``.
    """
    opts = [stage_options(s, arrival, max_replicas, latency_model,
                          tables=tables)
            for s in pipe.stages]
    sizes = [len(o.names) for o in opts]
    K = math.prod(sizes)
    if K > max_combos:
        raise ValueError(f"pipeline {pipe.name}: {K} combos exceed the "
                         f"frontier cap {max_combos}; use fewer options")
    ok, score, cost_tot, pas_val, lat_tot = _broadcast_eval(
        opts, obj, pipe.sla,
        paths=None if pipe.is_chain else pipe.paths())
    keep = np.flatnonzero(ok)
    picks = []
    radix = 1
    for j_size in sizes:
        picks.append((keep // radix) % j_size)
        radix *= j_size
    return (opts, picks, cost_tot[keep], score[keep],
            pas_val[keep], lat_tot[keep])


def _point_config(opts, picks, i) -> PipelineConfig:
    return PipelineConfig(tuple(
        StageConfig(o.names[js[i]], int(o.batches[js[i]]),
                    int(o.replicas[js[i]]), o.devices[js[i]])
        for o, js in zip(opts, picks)))


def _combo_cost_by_class(opts, picks, classes: Sequence[str]) -> np.ndarray:
    """Per-class cost columns ``(len(classes), n_combos)`` for the decoded
    combos of ``_combo_eval`` — each stage pick adds its n* x R_m to the
    row of its chosen device class."""
    n = len(picks[0]) if picks else 0
    out = np.zeros((len(classes), n))
    cidx = {c: i for i, c in enumerate(classes)}
    for o, js in zip(opts, picks):
        rows = np.array([cidx[d] for d in o.devices], dtype=np.int64)[js]
        costs = o.cost[js]
        for ci in range(len(classes)):
            mask = rows == ci
            out[ci][mask] += costs[mask]
    return out


def pareto_frontier_vec(pipe: PipelineModel, arrival: float,
                        obj: Objective, classes: Tuple[str, ...],
                        max_replicas: int = DEFAULT_MAX_REPLICAS,
                        latency_model: str = "worst_case",
                        tables: Optional[dict] = None
                        ) -> List[FrontierPoint]:
    """Vector-cost Pareto frontier of one pipeline at one rate: the
    surviving set under *strict* vector dominance — a combo dies only when
    some other combo matches or undercuts its cost in **every** device
    class and strictly beats its objective (exact ``(cost_vec, objective)``
    duplicates keep the earliest combo).  Strictness makes the prune
    invisible to the knapsack even on ties, mirroring the scalar
    discipline of ``_prune_candidates``.  Points come back in combo order
    with ``cost_vec`` set (aligned with ``classes``)."""
    opts, picks, cost, score, pas_v, lat = _combo_eval(
        pipe, arrival, obj, max_replicas, latency_model, tables=tables)
    n = len(cost)
    if n == 0:
        return []
    cvec = _combo_cost_by_class(opts, picks, classes).T    # (n, C)
    # score-descending scan (ties: earliest combo first) against the kept
    # set — kept points are mutually non-dominated, so each candidate only
    # compares against the (small) frontier built so far
    order = np.lexsort((np.arange(n), -score))
    kept: List[int] = []
    kept_cost: List[np.ndarray] = []
    kept_score: List[float] = []
    for i in order:
        ci, si = cvec[i], float(score[i])
        dominated = False
        for kc, ks in zip(kept_cost, kept_score):
            if (kc <= ci).all() and (ks > si or
                                     (ks == si and (kc == ci).all())):
                dominated = True        # strictly beaten, or exact duplicate
                break
        if dominated:
            continue
        kept.append(int(i))
        kept_cost.append(ci)
        kept_score.append(si)
    kept.sort()
    return [FrontierPoint(
        cost=float(cost[i]), objective=float(score[i]), pas=float(pas_v[i]),
        latency=float(lat[i]), config=_point_config(opts, picks, i),
        cost_vec=tuple(float(x) for x in cvec[i])) for i in kept]


def pareto_frontier(pipe: PipelineModel, arrival: float,
                    obj: Objective = Objective(),
                    max_replicas: int = DEFAULT_MAX_REPLICAS,
                    latency_model: str = "worst_case",
                    tables: Optional[dict] = None) -> List[FrontierPoint]:
    """Cost -> objective Pareto frontier of one pipeline at one rate.

    Points come back sorted by ascending cost with strictly increasing
    objective — any config off this frontier is dominated (same or less
    cost, same or better objective exists) and can never appear in an
    optimal joint allocation, which is what lets the cluster arbitration
    run a small knapsack per pipeline instead of the full cross-product.
    """
    opts, picks, cost, score, pas_v, lat = _combo_eval(
        pipe, arrival, obj, max_replicas, latency_model, tables=tables)
    if len(cost) == 0:
        return []
    order = np.lexsort((-score, cost))
    points: List[FrontierPoint] = []
    best = -np.inf
    for i in order:
        if score[i] > best + 1e-12:
            best = float(score[i])
            points.append(FrontierPoint(
                cost=float(cost[i]), objective=best, pas=float(pas_v[i]),
                latency=float(lat[i]),
                config=_point_config(opts, picks, int(i))))
    return points


class FrontierCache:
    """Cross-interval memo of per-pipeline Pareto frontiers.

    ``pareto_frontier`` is a pure function of ``(pipeline, arrival rate,
    objective, max_replicas, latency_model)``, and a policy trace revisits
    the same demand points constantly: reactive max-of-window estimators
    hold one value through many adaptation boundaries, and anti-correlated
    pipelines sit at base load most of the time.  Keying the memo on that
    exact tuple turns most per-interval frontier builds into dict hits
    while staying **bit-identical** to uncached planning (the cache
    property tests pin cached vs uncached traces config-for-config).

    Keys are hashable value objects (the frozen model dataclasses), so an
    entry can never go stale while its inputs are unchanged — the only
    invalidation semantics needed are explicit: ``clear()`` drops
    everything, and ``max_entries`` bounds memory by FIFO eviction.
    Passing ``cache=None`` to the solvers (or
    ``frontier_cache=None`` to ``adapter.run_cluster_trace``) bypasses
    caching entirely — the A/B knob the benchmarks use.

    ``quantize``: optional rate-bucket width.  When set, the rate the
    frontier is *computed at* snaps to ``round(lam / quantize) *
    quantize``, so nearby rates share one frontier — more hits, but the
    planning becomes approximate (deterministically so: the plan depends
    only on the bucketed rate, never on cache state).  The default
    ``None`` keys on the exact rate.
    """

    __slots__ = ("quantize", "max_entries", "hits", "misses", "_tab")

    def __init__(self, quantize: Optional[float] = None,
                 max_entries: int = 4096):
        if quantize is not None and quantize <= 0:
            raise ValueError("quantize must be positive")
        if max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        self.quantize = quantize
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        self._tab: dict = {}

    def __len__(self) -> int:
        return len(self._tab)

    def rate_of(self, arrival: float) -> float:
        """The (possibly bucketed) rate a frontier is computed/keyed at."""
        if self.quantize is None:
            return float(arrival)
        return round(float(arrival) / self.quantize) * self.quantize

    def frontier(self, pipe: PipelineModel, arrival: float, obj: Objective,
                 max_replicas: int = DEFAULT_MAX_REPLICAS,
                 latency_model: str = "worst_case",
                 classes: Optional[Tuple[str, ...]] = None
                 ) -> List[FrontierPoint]:
        """Memoized ``pareto_frontier`` (or, with ``classes``, the
        vector-cost ``pareto_frontier_vec`` keyed on the class axis too) —
        callers must treat the returned list as immutable (it is shared
        across hits)."""
        lam = self.rate_of(arrival)
        key = ((pipe, lam, obj, max_replicas, latency_model)
               if classes is None
               else (pipe, lam, obj, max_replicas, latency_model, classes))
        pts = self._tab.get(key)
        if pts is not None:
            self.hits += 1
            return pts
        self.misses += 1
        tables = self._stage_tables()
        if classes is None:
            pts = pareto_frontier(pipe, lam, obj, max_replicas,
                                  latency_model, tables=tables)
        else:
            pts = pareto_frontier_vec(pipe, lam, obj, classes, max_replicas,
                                      latency_model, tables=tables)
        if len(self._tab) >= self.max_entries:
            self._tab.pop(next(iter(self._tab)))
        self._tab[key] = pts
        return pts

    def _stage_tables(self) -> Optional[dict]:
        """Rate-independent ``_StageTable`` memo for frontier builds —
        ``None`` here (exact legacy build path); ``PlannerCache`` overrides
        with its table store."""
        return None

    def clear(self) -> None:
        self._tab.clear()

    # --- pickling -------------------------------------------------------
    # ``__slots__`` classes need explicit state hooks; the sweep harness
    # ships warm caches across process boundaries (and its tests pin the
    # round-trip), so keep this an API promise rather than an accident.
    # Entries are value objects (frozen model dataclasses -> FrontierPoint
    # lists), so the whole table pickles as-is.
    def __getstate__(self):
        return {"quantize": self.quantize, "max_entries": self.max_entries,
                "hits": self.hits, "misses": self.misses, "tab": self._tab}

    def __setstate__(self, state):
        self.quantize = state["quantize"]
        self.max_entries = state["max_entries"]
        self.hits = state["hits"]
        self.misses = state["misses"]
        self._tab = state["tab"]

    @property
    def stats(self) -> dict:
        """Hit/miss counters for bench observability."""
        total = self.hits + self.misses
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._tab),
                "hit_rate": round(self.hits / total, 4) if total else 0.0}

    def stats_snapshot(self) -> Tuple[int, int]:
        """Opaque counter snapshot for ``stats_since`` (hits, misses)."""
        return (self.hits, self.misses)

    def stats_since(self, snapshot: Tuple[int, int]) -> dict:
        """Hit/miss delta since a ``stats_snapshot()``.

        The sweep harness keeps one warm cache per worker process across
        all the cells that worker drains, so the *cumulative* ``stats``
        conflate every cell the worker has seen; the per-cell delta is
        what makes a cache-cold policy diagnosable from its own record.
        """
        h0, m0 = snapshot
        dh, dm = self.hits - h0, self.misses - m0
        total = dh + dm
        return {"hits": dh, "misses": dm, "entries": len(self._tab),
                "hit_rate": round(dh / total, 4) if total else 0.0}


_UNSET = object()


class PlannerCache(FrontierCache):
    """The incremental planning layer: a ``FrontierCache`` plus every memo
    the cross-interval ``solve_cluster`` fast path needs, all exact-keyed
    on value objects (the same discipline as the frontier memo) so every
    path is **bit-identical** to planning without it (property-tested
    against ``cache=None`` across switch costs, switch budgets, overlap
    charging and hetero vector costs).

    What it adds over the plain frontier memo:

    * ``_stage_tab`` — rate-independent ``_StageTable`` columns per stage,
      so a frontier build at a *new* rate (the dominant cost of a decision
      boundary once frontiers repeat) only runs the n*/feasibility
      arithmetic instead of re-walking the model objects.
    * ``_eval_tab`` — ``evaluate_config`` memo for the incumbent/revert
      stay candidates (keyed on the exact (pipe, config, rate, objective,
      latency model, classes) tuple).
    * ``_prune_tab`` — dominance-pruned knapsack candidate tables keyed on
      the exact candidate values, shared across solves whose tab repeats.
    * ``_sol_tab`` — whole-``solve_cluster`` memo keyed on every solve
      input; a boundary whose demand estimates and incumbent both held
      returns the previous solution outright.
    * ``_dp_state`` — the incumbent knapsack DP (per-pipeline dp rows and
      pick tables).  The next solve detects which pipelines' candidate
      tabs actually changed and resumes the DP after the longest unchanged
      *prefix*; the DP processes pipelines in order, so a prefix with
      identical tabs provably reproduces identical dp/pick rows (same
      float ops in the same order) and only the changed suffix re-solves
      against the inherited dp vector (the residual-budget view of the
      prefix).  A change in the first pipeline, a different budget grid or
      a different switch budget can't prove any reuse — those fall back to
      the full DP from scratch.  Either way the backtrack runs over the
      same pick tables a cold solve would produce: bit-identical, not
      merely equal-objective.

    Counters (`sol_hits`/`sol_misses`, `dp_prefix_pipes`, `dp_full_hits`)
    surface in ``stats`` for bench observability.  The DP state is
    volatile and intentionally not pickled (the sweep harness ships warm
    caches across processes; the memo dicts travel, the incumbent DP does
    not)."""

    __slots__ = ("_stage_tab", "_eval_tab", "_prune_tab", "_sol_tab",
                 "_dp_state", "sol_hits", "sol_misses", "dp_prefix_pipes",
                 "dp_full_hits")

    def __init__(self, quantize: Optional[float] = None,
                 max_entries: int = 4096):
        super().__init__(quantize, max_entries)
        self._stage_tab: dict = {}
        self._eval_tab: dict = {}
        self._prune_tab: dict = {}
        self._sol_tab: dict = {}
        self._dp_state: Optional[dict] = None
        self.sol_hits = 0
        self.sol_misses = 0
        self.dp_prefix_pipes = 0
        self.dp_full_hits = 0

    def _stage_tables(self) -> Optional[dict]:
        return self._stage_tab

    def eval_config(self, pipe, config, arrival, obj, latency_model,
                    classes):
        """Memoized ``evaluate_config`` (including ``None`` results)."""
        key = (pipe, config, float(arrival), obj, latency_model, classes)
        out = self._eval_tab.get(key, _UNSET)
        if out is _UNSET:
            out = evaluate_config(pipe, config, arrival, obj, latency_model,
                                  classes)
            if len(self._eval_tab) >= self.max_entries:
                self._eval_tab.pop(next(iter(self._eval_tab)))
            self._eval_tab[key] = out
        return out

    def clear(self) -> None:
        super().clear()
        self._stage_tab.clear()
        self._eval_tab.clear()
        self._prune_tab.clear()
        self._sol_tab.clear()
        self._dp_state = None

    def __getstate__(self):
        state = super().__getstate__()
        state.update(stage_tab=self._stage_tab, eval_tab=self._eval_tab,
                     prune_tab=self._prune_tab, sol_tab=self._sol_tab,
                     sol_hits=self.sol_hits, sol_misses=self.sol_misses,
                     dp_prefix_pipes=self.dp_prefix_pipes,
                     dp_full_hits=self.dp_full_hits)
        return state

    def __setstate__(self, state):
        super().__setstate__(state)
        self._stage_tab = state.get("stage_tab", {})
        self._eval_tab = state.get("eval_tab", {})
        self._prune_tab = state.get("prune_tab", {})
        self._sol_tab = state.get("sol_tab", {})
        self._dp_state = None
        self.sol_hits = state.get("sol_hits", 0)
        self.sol_misses = state.get("sol_misses", 0)
        self.dp_prefix_pipes = state.get("dp_prefix_pipes", 0)
        self.dp_full_hits = state.get("dp_full_hits", 0)

    @property
    def stats(self) -> dict:
        out = FrontierCache.stats.fget(self)
        total = self.sol_hits + self.sol_misses
        out["planner"] = {
            "sol_hits": self.sol_hits, "sol_misses": self.sol_misses,
            "sol_hit_rate": round(self.sol_hits / total, 4) if total
            else 0.0,
            "dp_prefix_pipes": self.dp_prefix_pipes,
            "dp_full_hits": self.dp_full_hits,
            "stage_tables": len(self._stage_tab),
        }
        return out


def _frontier(pipe: PipelineModel, arrival: float, obj: Objective,
              max_replicas: int, latency_model: str,
              cache: Optional[FrontierCache],
              classes: Optional[Tuple[str, ...]] = None
              ) -> List[FrontierPoint]:
    if cache is not None:
        return cache.frontier(pipe, arrival, obj, max_replicas,
                              latency_model, classes)
    if classes is None:
        return pareto_frontier(pipe, arrival, obj, max_replicas,
                               latency_model)
    return pareto_frontier_vec(pipe, arrival, obj, classes, max_replicas,
                               latency_model)


def solve_capped(pipe: PipelineModel, arrival: float,
                 obj: Objective = Objective(), cost_cap: float = np.inf,
                 max_replicas: int = DEFAULT_MAX_REPLICAS,
                 latency_model: str = "worst_case",
                 cache: Optional[FrontierCache] = None,
                 classes: Optional[Tuple[str, ...]] = None) -> Solution:
    """Best per-pipeline config whose cost fits ``cost_cap`` (the
    static-split baselines' per-pipeline sub-problem).  ``cache``: an
    optional ``FrontierCache`` memoizing the frontier build.  With
    ``classes``, ``cost_cap`` is a per-class cap vector aligned with it
    and the frontier carries vector costs — the per-class static split's
    sub-problem."""
    t0 = time.perf_counter()
    if classes is not None:
        pts = [p for p in _frontier(pipe, arrival, obj, max_replicas,
                                    latency_model, cache, classes)
               if all(cv <= cap + 1e-9
                      for cv, cap in zip(p.cost_vec, cost_cap))]
        if not pts:
            return _infeasible(t0, "capped")
        best = max(pts, key=lambda p: p.objective)  # first-wins on ties
        return Solution(best.config, best.objective, best.pas, best.cost,
                        best.latency, time.perf_counter() - t0, True,
                        "capped")
    pts = [p for p in _frontier(pipe, arrival, obj, max_replicas,
                                latency_model, cache)
           if p.cost <= cost_cap + 1e-9]
    if not pts:
        return _infeasible(t0, "capped")
    best = pts[-1]                       # frontier objective is increasing
    return Solution(best.config, best.objective, best.pas, best.cost,
                    best.latency, time.perf_counter() - t0, True, "capped")


@dataclasses.dataclass
class ClusterSolution:
    """Joint allocation: one frontier point per pipeline under sum(cost) <= C.

    ``objective`` is the arbitration score: the SLA-weighted sum of
    per-pipeline objectives minus ``switch_cost`` per *charged* switch.
    ``n_switches`` is that charged count (0 when no incumbent was given):
    pipelines whose chosen config differs from the committed incumbent and
    — when a serving config was given — from the still-serving config,
    whose re-proposal is a free cancel of the pending rollout.
    """
    config: Optional["ClusterConfig"]
    per_pipeline: List[Solution]
    objective: float                     # summed alpha*PAS - beta*cost - ...
    cost: float
    feasible: bool
    solve_time: float
    solver: str
    n_switches: int = 0

    @property
    def pas_values(self) -> List[float]:
        return [s.pas for s in self.per_pipeline]


def _cluster_solution(cluster, chosen: List[FrontierPoint], t0, solver,
                      weights: Optional[Sequence[float]] = None,
                      current=None, switch_cost: float = 0.0,
                      serving=None):
    from repro.core.cluster import ClusterConfig
    sols = [Solution(p.config, p.objective, p.pas, p.cost, p.latency,
                     0.0, True, solver) for p in chosen]
    cfg = ClusterConfig(tuple(p.config for p in chosen))
    if weights is None:
        weights = [1.0] * len(chosen)
    n_switches = _charged_switches(chosen, current, serving)
    objective = sum(w * p.objective for w, p in zip(weights, chosen)) \
        - switch_cost * n_switches
    return ClusterSolution(
        config=cfg, per_pipeline=sols,
        objective=float(objective),
        cost=float(sum(p.cost for p in chosen)),
        feasible=True, solve_time=time.perf_counter() - t0, solver=solver,
        n_switches=n_switches)


def _cluster_infeasible(cluster, t0, solver):
    return ClusterSolution(None, [], -np.inf, 0.0, False,
                           time.perf_counter() - t0, solver)


def _charged_switches(chosen: Sequence[FrontierPoint], current,
                      serving) -> int:
    """Switches that cost something: the chosen config differs from the
    committed incumbent AND — mid-window — from the still-serving config
    (re-proposing the serving config is a free cancel in the simulator:
    no new adaptation window, no reconfiguration counted)."""
    if current is None:
        return 0
    return sum(
        1 for i, p in enumerate(chosen)
        if p.config != current.pipelines[i]
        and (serving is None or p.config != serving.pipelines[i]))


def evaluate_config(pipe: PipelineModel, config: PipelineConfig,
                    arrival: float, obj: Objective = Objective(),
                    latency_model: str = "worst_case",
                    classes: Optional[Tuple[str, ...]] = None
                    ) -> Optional[FrontierPoint]:
    """Score one explicit ``PipelineConfig`` at a rate, or ``None`` when it
    cannot carry that rate (throughput 10c or the SLA 10b fails).

    This is how the cluster's *incumbent* config enters the switch-aware
    knapsack: the held config generally sits off the frontier built at the
    new rate (its replica counts were sized for the old rate), so it must
    be evaluated explicitly to become the zero-penalty "stay" candidate.
    """
    if not config.supports(pipe, arrival):
        return None
    lat = float(config.latency(pipe, arrival, latency_model))
    if lat > pipe.sla:
        return None
    # score through the same per-stage terms as _acc_term/_combine_acc so
    # the incumbent stay candidate is priced through the identical float
    # path as the frontier challengers it competes against in the knapsack
    accs = np.array([st.variant(sc.variant).acc(sc.device)
                     for sc, st in zip(config.stages, pipe.stages)])
    pas_log = np.log(np.maximum(accs, 1e-9) / 100.0)
    if obj.metric == "pas_prime":
        acc = ACC.pas_prime_of(config, pipe)  # same sums as acc_norm terms
    elif obj.metric in ("pas", "log_pas"):
        acc = _combine_acc(float(np.sum(pas_log)), obj.metric)
    else:
        raise ValueError(obj.metric)
    pas_val = 100.0 * float(np.exp(np.sum(pas_log)))
    cost = config.cost(pipe)
    bat = sum(sc.batch for sc in config.stages)
    objective = obj.alpha * acc - obj.beta * cost - obj.delta * bat
    cost_vec = (tuple(config.cost_by_class(pipe, classes))
                if classes is not None else None)
    return FrontierPoint(cost=float(cost), objective=float(objective),
                         pas=pas_val, latency=lat, config=config,
                         cost_vec=cost_vec)


@dataclasses.dataclass(frozen=True)
class _Candidate:
    """One knapsack choice for a pipeline: an operating point with its
    SLA-weighted, switch-penalized arbitration value.  ``cost`` is the
    knapsack *weight* — the transition charge ``max(old, new)`` under
    overlap-aware arbitration, which can exceed the operating point's own
    steady-state cost (``point.cost``).  Under per-class budgets it is a
    per-class int tuple (overlap maxes taken elementwise) instead of a
    scalar int."""
    cost: object                # int, or Tuple[int, ...] per device class
    value: float
    switch: bool
    point: FrontierPoint


def _switch_candidates(frontier: List[FrontierPoint],
                       incumbent: Optional[FrontierPoint],
                       weight: float, switch_cost: float,
                       old_cost=None,
                       revert: Optional[FrontierPoint] = None,
                       vector: bool = False) -> List[_Candidate]:
    """Frontier points (penalized unless they are free, below) plus the
    incumbent itself as the zero-penalty stay option when it is feasible at
    the new rate but off the frontier.  Frontier domination is preserved:
    the penalty is constant across all switch candidates, so any off-
    frontier *switch* stays dominated — only the free options need
    injecting.

    Free (unpenalized, no switch-budget slot) candidates match what the
    simulator executes without starting a new adaptation window: the
    committed incumbent (a hold is a no-op) and — mid-window only —
    ``revert``, the still-serving old config (re-proposing it cancels the
    pending rollout for free in ``ClusterSimulator.reconfigure_pipeline``).

    ``old_cost`` (overlap-aware arbitration): the cores the pipeline's
    currently *serving* fleet holds.  When given, every candidate's
    knapsack weight becomes ``max(old_cost, candidate cost)`` — during the
    §5.3 adaptation window the old fleet serves while the new one is
    provisioned, so the budget must admit the larger of the two.  The
    transform is monotone in cost, so frontier domination still holds.

    ``vector`` (per-class budgets): knapsack weights are per-class int
    tuples taken from each point's ``cost_vec``, and the overlap charge is
    the *elementwise* max against the serving fleet's per-class holdings —
    elementwise max is monotone per class, so vector domination survives
    the transform just like the scalar case."""
    inc_cfg = incumbent.config if incumbent is not None else None
    rev_cfg = revert.config if revert is not None else None

    def knap_cost(p: FrontierPoint):
        if vector:
            c = tuple(int(round(x)) for x in p.cost_vec)
            return c if old_cost is None else tuple(
                max(a, b) for a, b in zip(c, old_cost))
        c = int(round(p.cost))
        return c if old_cost is None else max(c, old_cost)

    cands = []
    seen_incumbent = seen_revert = False
    for p in frontier:
        stay = inc_cfg is not None and p.config == inc_cfg
        rev = rev_cfg is not None and p.config == rev_cfg
        seen_incumbent = seen_incumbent or stay
        seen_revert = seen_revert or rev
        free = stay or rev
        cands.append(_Candidate(knap_cost(p),
                                weight * p.objective
                                - (0.0 if free else switch_cost),
                                not free, p))
    if inc_cfg is not None and not seen_incumbent:
        cands.append(_Candidate(knap_cost(incumbent),
                                weight * incumbent.objective, False,
                                incumbent))
    if rev_cfg is not None and not seen_revert:
        cands.append(_Candidate(knap_cost(revert),
                                weight * revert.objective, False,
                                revert))
    return cands


def _overlap_old_costs(cluster, current, overlap: bool, serving,
                       classes: Optional[Tuple[str, ...]] = None
                       ) -> Optional[list]:
    """Per-pipeline cores held by the currently *serving* fleets, for the
    overlap-aware transition charge — ``None`` when overlap arbitration is
    off (no ``overlap`` flag or no incumbent to overlap with).  ``serving``
    defaults to ``current``; they differ only while an adaptation window is
    already in flight at decision time.  With ``classes`` each entry is a
    per-class int tuple (the per-class holdings the elementwise-max overlap
    charge is taken against) instead of a scalar."""
    if not overlap or current is None:
        return None
    serving_cfg = serving if serving is not None else current
    if len(serving_cfg.pipelines) != len(cluster.pipelines):
        raise ValueError("serving config/cluster pipeline count mismatch")
    if classes is not None:
        return [tuple(int(round(x)) for x in cfg.cost_by_class(pipe, classes))
                for cfg, pipe in zip(serving_cfg.pipelines, cluster.pipelines)]
    return [int(round(cfg.cost(pipe)))
            for cfg, pipe in zip(serving_cfg.pipelines, cluster.pipelines)]


def _resolve_weights(cluster, sla_weights) -> List[float]:
    if sla_weights is None:
        w = getattr(cluster, "weights", None)
        return list(w) if w is not None else [1.0] * len(cluster.pipelines)
    if len(sla_weights) != len(cluster.pipelines):
        raise ValueError("one SLA weight per pipeline required")
    return [float(w) for w in sla_weights]


def _remember(plan: Optional["PlannerCache"], skey, sol):
    """Store a finished solve in the planner's whole-solve memo (FIFO
    capped).  Infeasible solutions are remembered too — re-asking the same
    impossible question is just as common at a flapping boundary."""
    if plan is not None and skey is not None:
        if len(plan._sol_tab) >= plan.max_entries:
            plan._sol_tab.pop(next(iter(plan._sol_tab)))
        plan._sol_tab[skey] = sol
    return sol


def solve_cluster(cluster, arrivals: Sequence[float],
                  obj: Objective = Objective(),
                  budget: Optional[float] = None,
                  max_replicas: int = DEFAULT_MAX_REPLICAS,
                  latency_model: str = "worst_case",
                  current=None,
                  switch_cost: float = 0.0,
                  switch_budget: Optional[int] = None,
                  sla_weights: Optional[Sequence[float]] = None,
                  overlap: bool = False,
                  serving=None,
                  cache: Optional[FrontierCache] = None
                  ) -> ClusterSolution:
    """Joint arbitration: pick one frontier point per pipeline maximizing
    the SLA-weighted summed objective under ``sum(cost) <= budget``
    (default: the cluster's core budget C).

    This is the single place the cluster knobs are documented; the adapter
    (``adapter.run_cluster_trace``) and the joint policy
    (``baselines.cluster_ipa``) forward them here verbatim.

    Switch-cost awareness (paper §5.3: each reconfiguration costs ~8 s of
    transition during which the old config keeps serving): when ``current``
    (the incumbent ``ClusterConfig``) is given, every candidate that
    differs from a pipeline's held config is charged ``switch_cost``
    (objective units — the §5.3 adaptation overhead expressed as lost
    objective), and ``switch_budget`` caps how many pipelines may change
    per interval.  The incumbent enters the candidate set as a zero-penalty
    "stay" option whenever it can still carry the new rate — hysteresis
    falls out of the arithmetic: a challenger must beat the incumbent by
    more than its own transition cost to be picked.  ``sla_weights``
    multiplies each pipeline's objective in the knapsack (default: the
    cluster's own ``sla_weights``, else 1.0) — INFaaS-style workload
    importance.

    Transition-overlap awareness (``overlap=True``, requires ``current``):
    during the adaptation window a changed pipeline's *old* replica fleet
    keeps serving while the new one is provisioned, so the pipeline
    transiently holds ``max(old, new)`` cores, not ``new``.  With overlap
    on, every candidate's knapsack weight becomes that transition charge
    (old cost taken from ``serving`` — the config actually serving right
    now, which mid-window differs from the committed ``current`` — default
    ``current``), making overlapping grants of a downsizer's freed cores
    inadmissible *at decision time* instead of transiently violating the
    shared budget mid-window.  The reported ``ClusterSolution.cost`` stays
    the steady-state (post-transition) cost; only admissibility changes.
    ``overlap`` without ``current`` is a no-op (nothing old to overlap
    with), and the adapter only sets it when ``adaptation_delay > 0`` —
    at zero delay there is no window and the non-overlap path is
    bit-for-bit the PR 3 solver.

    Passing ``serving`` explicitly also prices the mid-window *revert*
    correctly: a pipeline's still-serving config (when it differs from the
    committed incumbent) enters as a second free candidate — no
    ``switch_cost``, no ``switch_budget`` slot — because re-proposing it
    cancels the pending rollout in the simulator without starting a new
    adaptation window.  ``n_switches`` counts only *charged* switches
    (differs from both the incumbent and the serving config).

    Costs are integral (replicas x base allocation), so the multiple-choice
    knapsack runs as an exact DP over budgets 0..C: processing pipelines in
    order, ``dp[b]`` is the best summed value of a prefix fitting in ``b``
    cores.  With a switch budget the DP gains a second exact dimension,
    ``dp[k][b]`` = best value using exactly ``k`` switches.  With
    ``switch_cost == 0``, no switch budget and ``overlap=False`` the path
    is the PR 2 DP bit-for-bit (weights of 1.0 multiply exactly).  All
    paths are validated against the ``solve_cluster_brute`` cross-product
    oracle in the property tests.

    ``cache``: an optional ``FrontierCache`` memoizing the per-pipeline
    frontier builds across calls (the dominant cost when rates repeat
    across adaptation intervals).  With exact keying (the default cache
    construction) results are bit-identical to ``cache=None``.  Passing a
    ``PlannerCache`` (the adapter's default) additionally memoizes whole
    solves on their exact inputs, the incumbent evaluations, and the
    knapsack DP's unchanged pipeline prefix across consecutive calls —
    every layer keyed on exact values, so still bit-identical to
    ``cache=None`` (property-tested in ``tests/test_incremental.py``).

    Heterogeneous clusters (``cluster.is_hetero``): the frontier carries
    vector costs, the knapsack runs over the per-class budget grid
    (``_knapsack_nd``), and ``budget`` may be a per-class mapping
    (default: the cluster's own ``class_budgets``) — a bare scalar budget
    is ambiguous there and rejected.  With a single class everything below
    degenerates to the scalar path bit-for-bit.
    """
    t0 = time.perf_counter()
    hetero = bool(getattr(cluster, "is_hetero", False))
    classes = cluster.device_classes if hetero else None
    if budget is None:
        budgets = cluster.budget_vector if hetero else None
        budget = cluster.cores
    elif hetero:
        if not isinstance(budget, Mapping):
            raise ValueError("heterogeneous cluster needs a per-class "
                             "budget mapping, not a scalar")
        budgets = tuple(float(budget.get(c, 0.0)) for c in classes)
    weights = _resolve_weights(cluster, sla_weights)
    if current is not None and len(current.pipelines) != len(cluster.pipelines):
        raise ValueError("current config/cluster pipeline count mismatch")
    plan = cache if isinstance(cache, PlannerCache) else None
    skey = None
    if plan is not None:
        skey = (tuple(cluster.pipelines), classes,
                budgets if hetero else float(budget), tuple(weights), obj,
                int(max_replicas), latency_model,
                None if current is None else tuple(current.pipelines),
                float(switch_cost),
                None if switch_budget is None else int(switch_budget),
                bool(overlap),
                None if serving is None else tuple(serving.pipelines),
                tuple(float(a) for a in arrivals))
        hit = plan._sol_tab.get(skey, _UNSET)
        if hit is not _UNSET:
            plan.sol_hits += 1
            return dataclasses.replace(
                hit, solve_time=time.perf_counter() - t0)
        plan.sol_misses += 1
    frontiers = [_frontier(p, lam, obj, max_replicas, latency_model, cache,
                           classes)
                 for p, lam in zip(cluster.pipelines, arrivals)]
    if any(not f for f in frontiers):
        return _remember(plan, skey,
                         _cluster_infeasible(cluster, t0, "cluster_knap"))

    old_costs = _overlap_old_costs(cluster, current, overlap, serving,
                                   classes)
    track_switches = current is not None and (switch_cost > 0.0
                                              or switch_budget is not None
                                              or old_costs is not None)
    if not track_switches:
        return _remember(plan, skey, _solve_cluster_plain(
            cluster, frontiers, weights, budgets if hetero else budget,
            current, t0, hetero, plan))

    serving_cfg = serving                 # current is not None here
    if serving_cfg is not None and \
            len(serving_cfg.pipelines) != len(cluster.pipelines):
        raise ValueError("serving config/cluster pipeline count mismatch")
    ev = evaluate_config if plan is None else plan.eval_config
    incumbents = [ev(pipe, cfg, lam, obj, latency_model, classes)
                  for pipe, cfg, lam in zip(cluster.pipelines,
                                            current.pipelines, arrivals)]
    # mid-window free-revert candidates: the still-serving config, whose
    # re-proposal cancels the pending rollout for free in the simulator
    reverts: List[Optional[FrontierPoint]] = [None] * len(cluster.pipelines)
    if serving_cfg is not None:
        reverts = [ev(pipe, scfg, lam, obj, latency_model, classes)
                   if scfg != ccfg else None
                   for pipe, scfg, ccfg, lam
                   in zip(cluster.pipelines, serving_cfg.pipelines,
                          current.pipelines, arrivals)]
    cand_tabs = [_switch_candidates(
        f, inc, w, switch_cost,
        old_costs[i] if old_costs is not None else None, reverts[i],
        vector=hetero)
        for i, (f, inc, w) in enumerate(zip(frontiers, incumbents, weights))]
    if hetero:
        chosen = _knapsack_nd(
            cand_tabs, budgets,
            min(int(switch_budget), len(cand_tabs))
            if switch_budget is not None else None, plan=plan)
    elif switch_budget is None:
        chosen = _knapsack_1d(cand_tabs, budget, plan=plan)
    else:
        chosen = _knapsack_2d(cand_tabs, budget,
                              min(int(switch_budget), len(cand_tabs)),
                              plan=plan)
    if chosen is None:
        return _remember(plan, skey,
                         _cluster_infeasible(cluster, t0, "cluster_knap"))
    return _remember(plan, skey, _cluster_solution(
        cluster, [c.point for c in chosen], t0, "cluster_knap", weights,
        current, switch_cost, serving_cfg))


def _solve_cluster_plain(cluster, frontiers, weights, budget, current, t0,
                         hetero: bool = False,
                         plan: Optional["PlannerCache"] = None):
    """The PR 2 exact 1-D knapsack (no switch dimension).  Weighted values
    only — with weights of 1.0 this is bit-identical to the unweighted DP
    (IEEE multiplication by 1.0 is exact, and ``_knapsack_1d`` runs the
    same candidate order, float operations and tie-breaking).  ``hetero``:
    ``budget`` is the per-class budget vector and the DP runs on the
    budget grid instead."""
    if hetero:
        cand_tabs = [[_Candidate(tuple(int(round(x)) for x in p.cost_vec),
                                 w * p.objective, False, p)
                      for p in f] for f, w in zip(frontiers, weights)]
        chosen = _knapsack_nd(cand_tabs, budget, plan=plan)
    else:
        cand_tabs = [[_Candidate(int(round(p.cost)), w * p.objective,
                                 False, p)
                      for p in f] for f, w in zip(frontiers, weights)]
        chosen = _knapsack_1d(cand_tabs, budget, plan=plan)
    if chosen is None:
        return _cluster_infeasible(cluster, t0, "cluster_knap")
    return _cluster_solution(cluster, [c.point for c in chosen], t0,
                             "cluster_knap", weights, current)


def _prune_candidates(cands: List[_Candidate],
                      cross_class: bool) -> List[_Candidate]:
    """Dominance pruning for one pipeline's knapsack tab: drop every
    candidate some other candidate *strictly* beats in value at no higher
    knapsack cost, plus exact ``(cost, value)`` duplicates (first kept).

    The strictness discipline makes pruning invisible, not merely
    objective-preserving: in the DP a strict dominator's total beats the
    dominated candidate's at every budget (``dp`` is monotone in budget),
    so the dominated row could never be picked — even on ties.  In the
    2-D exactly-k DP that argument only holds within a switch class
    (stay/switch draw from different ``k`` rows), so callers pass
    ``cross_class=False`` there and domination never crosses classes.

    This is where overlap-aware arbitration's frontier collapse pays off:
    ``max(old_cost, cost)`` maps every candidate at or below the serving
    fleet's cost onto one knapsack column, and all but the best of them
    die here instead of each burning an O(C) DP row."""
    n = len(cands)
    if n <= 1:
        return cands
    costs = np.array([c.cost for c in cands], dtype=np.int64)
    vals = np.array([c.value for c in cands])
    sw = np.array([c.switch for c in cands], dtype=bool)

    def prefix_best(mask: np.ndarray) -> np.ndarray:
        """Per candidate: the best value among masked candidates with
        cost <= its cost (-inf when none)."""
        if not mask.any():
            return np.full(n, -np.inf)
        order = np.argsort(costs[mask], kind="stable")
        mc = costs[mask][order]
        cm = np.maximum.accumulate(vals[mask][order])
        idx = np.searchsorted(mc, costs, side="right") - 1
        return np.where(idx >= 0, cm[np.maximum(idx, 0)], -np.inf)

    if cross_class:
        dominated = prefix_best(np.ones(n, dtype=bool)) > vals
    else:
        best_stay = prefix_best(~sw)
        best_switch = prefix_best(sw)
        dominated = np.where(sw, best_switch, best_stay) > vals
    seen = set()
    out = []
    for i, c in enumerate(cands):
        if dominated[i]:
            continue
        key = (c.cost, c.value) if cross_class else (c.cost, c.value,
                                                     c.switch)
        if key in seen:
            continue
        seen.add(key)
        out.append(c)
    return out


def _tab_key(cands: List[_Candidate]) -> tuple:
    """Exact value key of one pipeline's (unpruned) knapsack tab.  The DP
    is a pure function of these values, so equal keys across solves mean
    equal dp/pick rows bit-for-bit — the reuse test of the incremental
    solve path."""
    return tuple((c.cost, c.value, c.switch, c.point) for c in cands)


def _dp_prefix(plan: Optional["PlannerCache"], gkey: tuple,
               tab_keys: List[tuple]):
    """Longest prefix of the incumbent DP state reusable for this solve:
    the stored global key (DP flavor + budget grid + switch budget) must
    match exactly, then pipelines match in order until the first changed
    tab.  Returns ``(start, state)`` with ``state=None`` when nothing is
    reusable."""
    if plan is None:
        return 0, None
    st = plan._dp_state
    if st is None or st["gkey"] != gkey:
        return 0, None
    start = 0
    for a, b in zip(st["tab_keys"], tab_keys):
        if a != b:
            break
        start += 1
    plan.dp_prefix_pipes += start
    return start, st


def _pruned_memo(plan: Optional["PlannerCache"], key: tuple,
                 cands: List[_Candidate], cross_class: bool,
                 vec: bool) -> List[_Candidate]:
    """Memoized dominance pruning (pruning is a pure function of the tab
    values, so sharing the pruned list across solves is invisible)."""
    prune = _prune_candidates_vec if vec else _prune_candidates
    if plan is None:
        return prune(cands, cross_class)
    mkey = (key, cross_class)
    out = plan._prune_tab.get(mkey)
    if out is None:
        out = prune(cands, cross_class)
        if len(plan._prune_tab) >= plan.max_entries:
            plan._prune_tab.pop(next(iter(plan._prune_tab)))
        plan._prune_tab[mkey] = out
    return out


def _knapsack_1d(cand_tabs: List[List[_Candidate]], budget: float,
                 plan: Optional["PlannerCache"] = None
                 ) -> Optional[List[_Candidate]]:
    """Exact multiple-choice knapsack over pre-valued candidates (switch
    penalties already folded into ``value``).  Dominated rows are pruned
    first, and each pipeline's DP row only sweeps the budget columns its
    prefix can actually reach (``hi``) — the flat tail beyond is one
    broadcast fill, not per-candidate vector work.

    ``plan``: optional ``PlannerCache`` carrying the incumbent DP.  The
    solve resumes after the longest prefix of pipelines whose candidate
    tabs are value-identical to the incumbent's (their stored dp/pick rows
    are exactly what recomputing would produce), re-running only the
    changed suffix; bit-identical to the cold DP by construction."""
    if not np.isfinite(budget):
        return [max(cands, key=lambda c: c.value) for cands in cand_tabs]
    B = int(np.floor(budget + 1e-9))
    n = len(cand_tabs)
    tab_keys = [_tab_key(c) for c in cand_tabs] if plan is not None else []
    start, st = _dp_prefix(plan, ("1d", B), tab_keys)
    if st is not None and start == n == len(st["tab_keys"]):
        plan.dp_full_hits += 1
        ch = st["chosen"]
        return None if ch is None else list(ch)
    if start:
        pruned = list(st["pruned"][:start])
        pick_tabs = list(st["pick_tabs"][:start])
        dp_list = list(st["dp_list"][:start])
        hi_list = list(st["hi_list"][:start])
        dp, hi = dp_list[-1], hi_list[-1]
    else:
        pruned, pick_tabs, dp_list, hi_list = [], [], [], []
        dp = np.zeros(B + 1)
        hi = 0                           # reachable-cost bound so far
    for i in range(start, n):
        cands = _pruned_memo(plan, tab_keys[i] if plan is not None else (),
                             cand_tabs[i], cross_class=True, vec=False)
        pruned.append(cands)
        cur = np.full(B + 1, -np.inf)
        pick = np.full(B + 1, -1, dtype=np.int64)
        step = max((c.cost for c in cands if c.cost <= B), default=0)
        hi = min(B, hi + step)
        for j, c in enumerate(cands):
            if c.cost > B:
                continue
            cand = dp[:hi + 1 - c.cost] + c.value
            seg = cur[c.cost:hi + 1]
            sel = pick[c.cost:hi + 1]
            better = cand > seg
            seg[better] = cand[better]
            sel[better] = j
        if hi < B:                       # flat tail: nothing costs more
            cur[hi + 1:] = cur[hi]
            pick[hi + 1:] = pick[hi]
        pick_tabs.append(pick)
        dp_list.append(cur)
        hi_list.append(hi)
        dp = cur
    chosen = _backtrack_1d(pruned, pick_tabs, dp, B)
    if plan is not None:
        plan._dp_state = {
            "gkey": ("1d", B), "tab_keys": tab_keys, "pruned": pruned,
            "dp_list": dp_list, "hi_list": hi_list, "pick_tabs": pick_tabs,
            "chosen": None if chosen is None else tuple(chosen)}
    return chosen


def _backtrack_1d(pruned, pick_tabs, dp, B):
    if not np.isfinite(dp[B] if len(pick_tabs) else 0.0):
        return None
    b = B
    chosen_rev: List[_Candidate] = []
    for cands, pick in zip(reversed(pruned), reversed(pick_tabs)):
        j = int(pick[b])
        if j < 0:
            return None
        chosen_rev.append(cands[j])
        b -= cands[j].cost
    return list(reversed(chosen_rev))


def _knapsack_2d(cand_tabs: List[List[_Candidate]], budget: float, K: int,
                 plan: Optional["PlannerCache"] = None
                 ) -> Optional[List[_Candidate]]:
    """Exact DP over (switches used, cores used): ``dp[k][b]`` is the best
    prefix value using exactly ``k`` switches within ``b`` cores.  The
    reconfiguration budget K caps changed pipelines per interval.  Each
    tab is dominance-pruned per switch class first, the ``k`` rows swept
    per pipeline are capped at the prefix length, and budget columns
    beyond the prefix's reachable cost are filled flat rather than swept
    — all three provably change nothing, not even tie-breaks.

    ``plan`` resumes the incumbent DP after the longest value-identical
    pipeline prefix (see ``_knapsack_1d``); ``kmax`` uses the absolute
    pipeline index, which the resume loop preserves."""
    n = len(cand_tabs)
    if not np.isfinite(budget):
        return _bounded_switch_unbounded_cores(cand_tabs, K)
    B = int(np.floor(budget + 1e-9))
    tab_keys = [_tab_key(c) for c in cand_tabs] if plan is not None else []
    start, st = _dp_prefix(plan, ("2d", B, K), tab_keys)
    if st is not None and start == n == len(st["tab_keys"]):
        plan.dp_full_hits += 1
        ch = st["chosen"]
        return None if ch is None else list(ch)
    if start:
        pruned = list(st["pruned"][:start])
        pick_tabs = list(st["pick_tabs"][:start])
        dp_list = list(st["dp_list"][:start])
        hi_list = list(st["hi_list"][:start])
        dp, hi = dp_list[-1], hi_list[-1]
    else:
        pruned, pick_tabs, dp_list, hi_list = [], [], [], []
        dp = np.full((K + 1, B + 1), -np.inf)
        dp[0, :] = 0.0
        hi = 0                           # reachable-cost bound so far
    for i in range(start, n):
        cands = _pruned_memo(plan, tab_keys[i] if plan is not None else (),
                             cand_tabs[i], cross_class=False, vec=False)
        pruned.append(cands)
        cur = np.full((K + 1, B + 1), -np.inf)
        pick = np.full((K + 1, B + 1), -1, dtype=np.int64)
        step = max((c.cost for c in cands if c.cost <= B), default=0)
        hi = min(B, hi + step)
        kmax = min(K, i + 1)             # prefix can't switch more often
        for j, c in enumerate(cands):
            if c.cost > B:
                continue
            dk = 1 if c.switch else 0
            for k in range(dk, kmax + 1):
                cand = dp[k - dk, :hi + 1 - c.cost] + c.value
                seg = cur[k, c.cost:hi + 1]
                sel = pick[k, c.cost:hi + 1]
                better = cand > seg
                seg[better] = cand[better]
                sel[better] = j
        if hi < B:                       # flat tail: nothing costs more
            cur[:, hi + 1:] = cur[:, hi:hi + 1]
            pick[:, hi + 1:] = pick[:, hi:hi + 1]
        pick_tabs.append(pick)
        dp_list.append(cur)
        hi_list.append(hi)
        dp = cur
    chosen = _backtrack_2d(pruned, pick_tabs, dp, B)
    if plan is not None:
        plan._dp_state = {
            "gkey": ("2d", B, K), "tab_keys": tab_keys, "pruned": pruned,
            "dp_list": dp_list, "hi_list": hi_list, "pick_tabs": pick_tabs,
            "chosen": None if chosen is None else tuple(chosen)}
    return chosen


def _backtrack_2d(pruned, pick_tabs, dp, B):
    k_best = int(np.argmax(dp[:, B]))
    if not np.isfinite(dp[k_best, B]):
        return None
    k, b = k_best, B
    chosen_rev: List[_Candidate] = []
    for cands, pick in zip(reversed(pruned), reversed(pick_tabs)):
        j = int(pick[k, b])
        if j < 0:
            return None
        chosen_rev.append(cands[j])
        b -= cands[j].cost
        k -= 1 if cands[j].switch else 0
    return list(reversed(chosen_rev))


def _bounded_switch_unbounded_cores(cand_tabs: List[List[_Candidate]],
                                    K: int) -> Optional[List[_Candidate]]:
    """Unbounded cores, capped switches: pipelines are independent except
    for the switch count, so take each pipeline's best stay, then spend the
    K switches on the largest positive switch gains (pipelines with no
    feasible stay must switch and consume budget first)."""
    best_stay = []
    best_switch = []
    for cands in cand_tabs:
        stays = [c for c in cands if not c.switch]
        sws = [c for c in cands if c.switch]
        best_stay.append(max(stays, key=lambda c: c.value) if stays else None)
        best_switch.append(max(sws, key=lambda c: c.value) if sws else None)
    chosen: List[Optional[_Candidate]] = list(best_stay)
    forced = [i for i, s in enumerate(best_stay) if s is None]
    if len(forced) > K:
        return None
    for i in forced:
        if best_switch[i] is None:
            return None
        chosen[i] = best_switch[i]
    left = K - len(forced)
    gains = sorted(
        ((best_switch[i].value - best_stay[i].value, i)
         for i in range(len(cand_tabs))
         if best_stay[i] is not None and best_switch[i] is not None
         and best_switch[i].value > best_stay[i].value),
        reverse=True)
    for gain, i in gains[:left]:
        chosen[i] = best_switch[i]
    return chosen  # type: ignore[return-value]


def _prune_candidates_vec(cands: List[_Candidate],
                          cross_class: bool) -> List[_Candidate]:
    """Vector-cost analogue of ``_prune_candidates``: a candidate dies when
    some other candidate strictly beats its value at no higher cost in
    *every* device class, plus exact ``(cost, value)`` duplicates (first
    kept).  Same strictness discipline — a strict vector dominator wins at
    every budget vector in the monotone N-d DP, so pruning is invisible
    even on ties; with ``cross_class=False`` domination never crosses the
    stay/switch boundary (they draw from different ``k`` rows)."""
    n = len(cands)
    if n <= 1:
        return cands
    costs = np.array([c.cost for c in cands], dtype=np.int64)  # (n, C)
    vals = np.array([c.value for c in cands])
    sw = np.array([c.switch for c in cands], dtype=bool)
    le = (costs[None, :, :] <= costs[:, None, :]).all(axis=-1)  # j <= i
    gt = vals[None, :] > vals[:, None]                          # j beats i
    dom = le & gt
    if not cross_class:
        dom &= sw[None, :] == sw[:, None]
    dominated = dom.any(axis=1)
    seen = set()
    out = []
    for i, c in enumerate(cands):
        if dominated[i]:
            continue
        key = (c.cost, c.value) if cross_class else (c.cost, c.value,
                                                     c.switch)
        if key in seen:
            continue
        seen.add(key)
        out.append(c)
    return out


def _knapsack_nd(cand_tabs: List[List[_Candidate]],
                 budgets: Sequence[float],
                 K: Optional[int] = None,
                 plan: Optional["PlannerCache"] = None
                 ) -> Optional[List[_Candidate]]:
    """Exact multiple-choice knapsack over per-class budget vectors —
    candidate costs are int tuples aligned with the cluster's device
    classes.  Structurally the 1-D DP with the budget axis replaced by a
    budget *grid* (tuple slices shift every class at once), plus the
    optional exactly-``K``-switches leading axis of the 2-D DP.  Each
    class's axis is capped at the prefix's reachable cost (sum of per-tab
    maxima), so tiny accelerator budgets keep the grid tiny regardless of
    how large the CPU pool is.  Same candidate order, float operations and
    strict tie-breaking as the scalar DPs — the brute oracle's
    first-occurrence argmax is reproduced exactly.

    ``plan`` resumes the incumbent DP after the longest value-identical
    pipeline prefix (see ``_knapsack_1d``).  The reach-capped grid ``B``
    is part of the resume key: the reach sums span *all* tabs, so any tab
    change that moves the grid invalidates the whole state, and a matching
    key guarantees identical array shapes."""
    C = len(budgets)
    if all(not np.isfinite(b) for b in budgets):
        if K is None:
            return [max(cands, key=lambda c: c.value) for cands in cand_tabs]
        return _bounded_switch_unbounded_cores(cand_tabs, K)
    reach = [0] * C
    for cands in cand_tabs:
        for c in range(C):
            reach[c] += max((cc.cost[c] for cc in cands), default=0)
    B = tuple(min(int(np.floor(b + 1e-9)), reach[c]) if np.isfinite(b)
              else reach[c]
              for c, b in enumerate(budgets))
    n = len(cand_tabs)
    tab_keys = [_tab_key(c) for c in cand_tabs] if plan is not None else []
    start, st = _dp_prefix(plan, ("nd", B, K), tab_keys)
    if st is not None and start == n == len(st["tab_keys"]):
        plan.dp_full_hits += 1
        ch = st["chosen"]
        return None if ch is None else list(ch)
    shape = tuple(b + 1 for b in B)
    if start:
        pruned = list(st["pruned"][:start])
        pick_tabs = list(st["pick_tabs"][:start])
        dp_list = list(st["dp_list"][:start])
        dp = dp_list[-1]
    else:
        pruned, pick_tabs, dp_list = [], [], []
        if K is None:
            dp = np.zeros(shape)
        else:
            dp = np.full((K + 1,) + shape, -np.inf)
            dp[0] = 0.0
    for i in range(start, n):
        cands = _pruned_memo(plan, tab_keys[i] if plan is not None else (),
                             cand_tabs[i], cross_class=(K is None), vec=True)
        pruned.append(cands)
        cur = np.full(dp.shape, -np.inf)
        pick = np.full(dp.shape, -1, dtype=np.int64)
        kmax = min(K, i + 1) if K is not None else None
        for j, c in enumerate(cands):
            if any(cc > bb for cc, bb in zip(c.cost, B)):
                continue
            src = tuple(slice(0, bb + 1 - cc) for cc, bb in zip(c.cost, B))
            dst = tuple(slice(cc, None) for cc in c.cost)
            if K is None:
                cand = dp[src] + c.value
                seg = cur[dst]
                sel = pick[dst]
                better = cand > seg
                seg[better] = cand[better]
                sel[better] = j
            else:
                dk = 1 if c.switch else 0
                for k in range(dk, kmax + 1):
                    cand = dp[(k - dk,) + src] + c.value
                    seg = cur[(k,) + dst]
                    sel = pick[(k,) + dst]
                    better = cand > seg
                    seg[better] = cand[better]
                    sel[better] = j
        pick_tabs.append(pick)
        dp_list.append(cur)
        dp = cur
    chosen = _backtrack_nd(pruned, pick_tabs, dp, B, K)
    if plan is not None:
        plan._dp_state = {
            "gkey": ("nd", B, K), "tab_keys": tab_keys, "pruned": pruned,
            "dp_list": dp_list, "hi_list": [], "pick_tabs": pick_tabs,
            "chosen": None if chosen is None else tuple(chosen)}
    return chosen


def _backtrack_nd(pruned, pick_tabs, dp, B, K):
    end = tuple(B)
    if K is None:
        if not np.isfinite(dp[end]):
            return None
        state = end
    else:
        k_best = int(np.argmax(dp[(slice(None),) + end]))
        if not np.isfinite(dp[(k_best,) + end]):
            return None
        state = (k_best,) + end
    chosen_rev: List[_Candidate] = []
    for cands, pick in zip(reversed(pruned), reversed(pick_tabs)):
        j = int(pick[state])
        if j < 0:
            return None
        chosen_rev.append(cands[j])
        if K is None:
            state = tuple(s - cc for s, cc in zip(state, cands[j].cost))
        else:
            dk = 1 if cands[j].switch else 0
            state = (state[0] - dk,) + tuple(
                s - cc for s, cc in zip(state[1:], cands[j].cost))
    return list(reversed(chosen_rev))


def solve_cluster_brute(cluster, arrivals: Sequence[float],
                        obj: Objective = Objective(),
                        budget: Optional[float] = None,
                        max_replicas: int = DEFAULT_MAX_REPLICAS,
                        latency_model: str = "worst_case",
                        current=None,
                        switch_cost: float = 0.0,
                        switch_budget: Optional[int] = None,
                        sla_weights: Optional[Sequence[float]] = None,
                        overlap: bool = False,
                        serving=None
                        ) -> ClusterSolution:
    """Oracle: exhaustive cross-product over every pipeline's full feasible
    config set (not just the frontier) — validates the frontier
    construction, the knapsack, and the switch-penalty/SLA-weight/overlap
    accounting on toy clusters.  The incumbent (``current``) is appended to
    a pipeline's table when feasible at the new rate and not already in it
    (held replica counts are generally off the n*-substituted grid).  With
    ``overlap=True`` the budget constraint is evaluated over the transition
    charge ``sum_p max(old_p, new_p)`` (old from ``serving``, default
    ``current``) exactly as ``solve_cluster`` plans.  Heterogeneous
    clusters: the tables carry per-class cost vectors and feasibility is
    checked per class (overlap maxes taken elementwise), matching the
    ``_knapsack_nd`` fast path's constraint exactly."""
    t0 = time.perf_counter()
    hetero = bool(getattr(cluster, "is_hetero", False))
    classes = cluster.device_classes if hetero else None
    if budget is None:
        budgets = cluster.budget_vector if hetero else None
        budget = cluster.cores
    elif hetero:
        if not isinstance(budget, Mapping):
            raise ValueError("heterogeneous cluster needs a per-class "
                             "budget mapping, not a scalar")
        budgets = tuple(float(budget.get(c, 0.0)) for c in classes)
    weights = _resolve_weights(cluster, sla_weights)
    if current is not None and len(current.pipelines) != len(cluster.pipelines):
        raise ValueError("current config/cluster pipeline count mismatch")
    old_costs = _overlap_old_costs(cluster, current, overlap, serving,
                                   classes)
    serving_cfg = serving if (serving is not None and current is not None) \
        else None
    if serving_cfg is not None and \
            len(serving_cfg.pipelines) != len(cluster.pipelines):
        raise ValueError("serving config/cluster pipeline count mismatch")
    tables = []
    for p_i, (pipe, lam) in enumerate(zip(cluster.pipelines, arrivals)):
        opts, picks, cost, score, pas_v, lat = _combo_eval(
            pipe, lam, obj, max_replicas, latency_model)
        if len(cost) == 0:
            return _cluster_infeasible(cluster, t0, "cluster_brute")
        if hetero:
            cvec = _combo_cost_by_class(opts, picks, classes).T
            tab = [FrontierPoint(float(cost[i]), float(score[i]),
                                 float(pas_v[i]), float(lat[i]),
                                 _point_config(opts, picks, i),
                                 cost_vec=tuple(float(x) for x in cvec[i]))
                   for i in range(len(cost))]
        else:
            tab = [FrontierPoint(float(cost[i]), float(score[i]),
                                 float(pas_v[i]), float(lat[i]),
                                 _point_config(opts, picks, i))
                   for i in range(len(cost))]
        if current is not None:
            inc = evaluate_config(pipe, current.pipelines[p_i], lam, obj,
                                  latency_model, classes)
            if inc is not None and all(p.config != inc.config for p in tab):
                tab.append(inc)
        if serving_cfg is not None and \
                serving_cfg.pipelines[p_i] != current.pipelines[p_i]:
            rev = evaluate_config(pipe, serving_cfg.pipelines[p_i], lam,
                                  obj, latency_model, classes)
            if rev is not None and all(p.config != rev.config for p in tab):
                tab.append(rev)
        tables.append(tab)
    best_v, best = -np.inf, None
    for combo in itertools.product(*tables):
        if hetero:
            if old_costs is not None:
                tot_vec = [sum(max(p.cost_vec[c], o[c]) for p, o
                               in zip(combo, old_costs))
                           for c in range(len(classes))]
            else:
                tot_vec = [sum(p.cost_vec[c] for p in combo)
                           for c in range(len(classes))]
            if any(t > b + 1e-9 for t, b in zip(tot_vec, budgets)):
                continue
        else:
            if old_costs is not None:
                tot_c = sum(max(p.cost, o) for p, o in zip(combo, old_costs))
            else:
                tot_c = sum(p.cost for p in combo)
            if tot_c > budget + 1e-9:
                continue
        n_sw = _charged_switches(combo, current, serving_cfg)
        if switch_budget is not None and n_sw > switch_budget:
            continue
        v = sum(w * p.objective for w, p in zip(weights, combo)) \
            - switch_cost * n_sw
        if v > best_v:
            best_v, best = v, combo
    if best is None:
        return _cluster_infeasible(cluster, t0, "cluster_brute")
    return _cluster_solution(cluster, list(best), t0, "cluster_brute",
                             weights, current, switch_cost, serving_cfg)
