"""Workload traces (paper §5.1).

The paper replays four excerpts of the archived 2021-08 Twitter stream
(bursty / steady-low / steady-high / fluctuating) and trains its LSTM on 14
days of the trace.  The archive is not available offline, so we synthesize a
statistically matched stand-in: a diurnal sinusoid + AR(1) noise +
Poisson-seeded exponential-decay bursts, calibrated to the paper's plotted
RPS ranges (~5-40 RPS).  Excerpt generators reproduce the four shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np


@dataclasses.dataclass
class TraceConfig:
    seed: int = 0
    base_rps: float = 14.0
    diurnal_amp: float = 6.0
    noise_sigma: float = 1.6
    noise_rho: float = 0.95
    burst_rate_per_hour: float = 1.2
    burst_amp: float = 18.0
    burst_decay_s: float = 90.0


def synth_trace(seconds: int, cfg: TraceConfig = TraceConfig()) -> np.ndarray:
    """Per-second arrival rates (RPS), length ``seconds``."""
    rng = np.random.default_rng(cfg.seed)
    t = np.arange(seconds, dtype=np.float64)
    diurnal = cfg.base_rps + cfg.diurnal_amp * np.sin(2 * np.pi * t / 86_400.0
                                                      - np.pi / 2)
    # AR(1) noise
    eps = rng.standard_normal(seconds) * cfg.noise_sigma * np.sqrt(1 - cfg.noise_rho ** 2)
    noise = np.empty(seconds)
    acc = 0.0
    for i in range(seconds):
        acc = cfg.noise_rho * acc + eps[i]
        noise[i] = acc
    # bursts
    burst = np.zeros(seconds)
    n_bursts = rng.poisson(cfg.burst_rate_per_hour * seconds / 3600.0)
    for _ in range(n_bursts):
        s0 = rng.integers(seconds)
        amp = cfg.burst_amp * (0.5 + rng.random())
        dur = int(6 * cfg.burst_decay_s)
        idx = np.arange(s0, min(s0 + dur, seconds))
        burst[idx] += amp * np.exp(-(idx - s0) / cfg.burst_decay_s)
    return np.clip(diurnal + noise + burst, 0.5, None)


def make_days(days: int = 21, cfg: TraceConfig = TraceConfig()) -> np.ndarray:
    return synth_trace(days * 86_400, cfg)


# ---------------------------------------------------------------------------
# the four evaluation excerpts (Fig. 7)
#
# The paper trains its LSTM on the first 14 days of the Twitter trace and
# picks the four excerpt shapes from the remaining 7 *unseen* days of the
# SAME trace.  We do the same: scan the test region of the synthesized trace
# for the 10-minute window best matching each shape's statistics, so the
# predictor's train/test distributions match the paper's protocol.
# ---------------------------------------------------------------------------
TRAIN_DAYS = 14
TOTAL_DAYS = 21
_trace_cache: Dict[int, np.ndarray] = {}


def full_trace(cfg: TraceConfig = TraceConfig()) -> np.ndarray:
    key = cfg.seed
    if key not in _trace_cache:
        _trace_cache[key] = make_days(TOTAL_DAYS, cfg)
    return _trace_cache[key]


def train_region(cfg: TraceConfig = TraceConfig()) -> np.ndarray:
    return full_trace(cfg)[:TRAIN_DAYS * 86_400]


def test_region(cfg: TraceConfig = TraceConfig()) -> np.ndarray:
    return full_trace(cfg)[TRAIN_DAYS * 86_400:]


def _window_features(w: np.ndarray):
    mean = w.mean()
    return mean, w.std() / (mean + 1e-9), w.max() / (mean + 1e-9)


def excerpt(kind: str, seconds: int = 600,
            cfg: TraceConfig = TraceConfig()) -> np.ndarray:
    test = test_region(cfg)
    stride = max(seconds // 2, 1)
    wins = [(s, test[s:s + seconds]) for s in
            range(0, len(test) - seconds, stride)]
    feats = [(_window_features(w), s, w) for s, w in wins]
    means = np.array([f[0][0] for f in feats])
    lo, hi = np.quantile(means, 0.25), np.quantile(means, 0.75)

    def pick(score_fn):
        best = max(feats, key=lambda f: score_fn(*f[0]))
        return best[2].copy()

    if kind == "steady_low":
        return pick(lambda m, cv, pk: -abs(m - lo) * 5 - cv * 20 - pk)
    if kind == "steady_high":
        return pick(lambda m, cv, pk: -abs(m - hi) * 5 - cv * 20 - pk)
    if kind == "bursty":
        return pick(lambda m, cv, pk: pk)
    if kind == "fluctuating":
        return pick(lambda m, cv, pk: cv - max(pk - 2.5, 0.0))
    raise ValueError(kind)


EXCERPTS = ("bursty", "steady_low", "steady_high", "fluctuating")


def arrivals_from_rates(rates: np.ndarray, seed: int = 0) -> np.ndarray:
    """Poisson-sample concrete arrival timestamps from per-second rates."""
    rng = np.random.default_rng(seed)
    times = []
    for sec, lam in enumerate(rates):
        n = rng.poisson(lam)
        times.extend(sec + np.sort(rng.random(n)))
    return np.asarray(times)
