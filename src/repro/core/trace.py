"""Workload traces (paper §5.1).

The paper replays four excerpts of the archived 2021-08 Twitter stream
(bursty / steady-low / steady-high / fluctuating) and trains its LSTM on 14
days of the trace.  The archive is not available offline, so we synthesize a
statistically matched stand-in: a diurnal sinusoid + AR(1) noise +
Poisson-seeded exponential-decay bursts, calibrated to the paper's plotted
RPS ranges (~5-40 RPS).  Excerpt generators reproduce the four shapes.

Production-scale extensions (the BENCH_scale scenario): ``TraceConfig.scale``
multiplies the whole synthesized rate curve, lifting a paper-shaped trace
into the thousands-of-RPS regime without changing its shape, and
``scale_excerpt`` generates the two extra stress shapes that regime needs —
``heavy_tailed`` (Pareto-amplitude burst storm: most bursts are small, a
few are enormous) and ``flash_crowd`` (a coordinated step to a multiple of
base load with a sharp ramp and slow decay).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Union

import numpy as np

# seeds accepted everywhere a stream is created: a plain int (legacy,
# bit-compatible), a SeedSequence (the sweep harness's collision-free
# derivation — see ``np.random.SeedSequence.spawn``) or an already-built
# Generator
SeedLike = Union[int, np.random.SeedSequence, np.random.Generator]

try:                                     # vectorized AR(1) (see _ar1_noise)
    from scipy.signal import lfilter as _lfilter
except ImportError:                      # pragma: no cover - scipy is baked in
    _lfilter = None


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Frozen (hashable) so caches can key on the *full* configuration —
    keying on ``seed`` alone silently returned the first-seen config's
    trace for any same-seed config (the PR 6 cache-collision fix)."""
    seed: int = 0
    base_rps: float = 14.0
    diurnal_amp: float = 6.0
    noise_sigma: float = 1.6
    noise_rho: float = 0.95
    burst_rate_per_hour: float = 1.2
    burst_amp: float = 18.0
    burst_decay_s: float = 90.0
    # multiplies the final clipped rate curve: shape-preserving lift into
    # the production regime (scale=1.0 is bit-identical to the pre-knob
    # synthesizer)
    scale: float = 1.0


def _ar1_noise(eps: np.ndarray, rho: float) -> np.ndarray:
    """AR(1) recurrence ``acc = rho * acc + eps[i]`` over the whole array.

    Runs as one C-level IIR filter pass (``scipy.signal.lfilter`` with
    transfer function 1 / (1 - rho z^-1)) — bit-identical to the python
    loop it replaced (same fused multiply-add per step in float64, pinned
    by ``tests/test_trace.py``), and the dominant cost of synthesizing
    21-day predictor traces and 100-pipeline BENCH_scale workloads.
    """
    if _lfilter is not None:
        return _lfilter([1.0], [1.0, -rho], eps)
    noise = np.empty(len(eps))           # pragma: no cover - scipy absent
    acc = 0.0
    for i in range(len(eps)):
        acc = rho * acc + eps[i]
        noise[i] = acc
    return noise


def synth_trace(seconds: int, cfg: TraceConfig = TraceConfig()) -> np.ndarray:
    """Per-second arrival rates (RPS), length ``seconds``."""
    rng = np.random.default_rng(cfg.seed)
    t = np.arange(seconds, dtype=np.float64)
    diurnal = cfg.base_rps + cfg.diurnal_amp * np.sin(2 * np.pi * t / 86_400.0
                                                      - np.pi / 2)
    # AR(1) noise, vectorized (one lfilter pass instead of a python loop)
    eps = rng.standard_normal(seconds) * cfg.noise_sigma * np.sqrt(1 - cfg.noise_rho ** 2)
    noise = _ar1_noise(eps, cfg.noise_rho)
    # bursts
    burst = np.zeros(seconds)
    n_bursts = rng.poisson(cfg.burst_rate_per_hour * seconds / 3600.0)
    for _ in range(n_bursts):
        s0 = rng.integers(seconds)
        amp = cfg.burst_amp * (0.5 + rng.random())
        dur = int(6 * cfg.burst_decay_s)
        idx = np.arange(s0, min(s0 + dur, seconds))
        burst[idx] += amp * np.exp(-(idx - s0) / cfg.burst_decay_s)
    rates = np.clip(diurnal + noise + burst, 0.5, None)
    if cfg.scale != 1.0:
        rates = rates * cfg.scale
    return rates


def make_days(days: int = 21, cfg: TraceConfig = TraceConfig()) -> np.ndarray:
    return synth_trace(days * 86_400, cfg)


# ---------------------------------------------------------------------------
# the four evaluation excerpts (Fig. 7)
#
# The paper trains its LSTM on the first 14 days of the Twitter trace and
# picks the four excerpt shapes from the remaining 7 *unseen* days of the
# SAME trace.  We do the same: scan the test region of the synthesized trace
# for the 10-minute window best matching each shape's statistics, so the
# predictor's train/test distributions match the paper's protocol.
# ---------------------------------------------------------------------------
TRAIN_DAYS = 14
TOTAL_DAYS = 21
TRACE_CACHE_MAX = 8          # full 21-day traces are ~14 MB each


class BoundedTraceCache:
    """LRU-bounded memo for full 21-day traces.

    The pre-PR-7 module-level dict grew without limit: a thousand-cell
    sweep touching many ``TraceConfig``s would pin one 21-day float64
    array (~14 MB) per distinct config for the life of the process.  An
    LRU with a small cap keeps the common case (one or two configs hit
    repeatedly by excerpt mining / predictor training) free while making
    eviction harmless: ``synth_trace`` is a pure function of the config,
    so a re-miss regenerates the exact same bytes (regression-pinned in
    ``tests/test_trace.py``).

    Keyed on the FULL frozen ``TraceConfig`` — two same-seed configs with
    different shape parameters must never share an entry (the PR 6
    cache-collision fix).
    """

    def __init__(self, max_entries: int = TRACE_CACHE_MAX):
        if max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        self._tab: "OrderedDict[TraceConfig, np.ndarray]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._tab)

    def __contains__(self, cfg: TraceConfig) -> bool:
        return cfg in self._tab

    def get(self, cfg: TraceConfig, builder) -> np.ndarray:
        arr = self._tab.get(cfg)
        if arr is not None:
            self.hits += 1
            self._tab.move_to_end(cfg)
            return arr
        self.misses += 1
        arr = builder(cfg)
        while len(self._tab) >= self.max_entries:
            self._tab.popitem(last=False)
        self._tab[cfg] = arr
        return arr

    def clear(self) -> None:
        self._tab.clear()


_trace_cache = BoundedTraceCache()


def full_trace(cfg: TraceConfig = TraceConfig()) -> np.ndarray:
    return _trace_cache.get(cfg, lambda c: make_days(TOTAL_DAYS, c))


def train_region(cfg: TraceConfig = TraceConfig()) -> np.ndarray:
    return full_trace(cfg)[:TRAIN_DAYS * 86_400]


def test_region(cfg: TraceConfig = TraceConfig()) -> np.ndarray:
    return full_trace(cfg)[TRAIN_DAYS * 86_400:]


def _window_features(w: np.ndarray):
    mean = w.mean()
    return mean, w.std() / (mean + 1e-9), w.max() / (mean + 1e-9)


def excerpt(kind: str, seconds: int = 600,
            cfg: TraceConfig = TraceConfig()) -> np.ndarray:
    test = test_region(cfg)
    stride = max(seconds // 2, 1)
    wins = [(s, test[s:s + seconds]) for s in
            range(0, len(test) - seconds, stride)]
    feats = [(_window_features(w), s, w) for s, w in wins]
    means = np.array([f[0][0] for f in feats])
    lo, hi = np.quantile(means, 0.25), np.quantile(means, 0.75)

    def pick(score_fn):
        best = max(feats, key=lambda f: score_fn(*f[0]))
        return best[2].copy()

    if kind == "steady_low":
        return pick(lambda m, cv, pk: -abs(m - lo) * 5 - cv * 20 - pk)
    if kind == "steady_high":
        return pick(lambda m, cv, pk: -abs(m - hi) * 5 - cv * 20 - pk)
    if kind == "bursty":
        return pick(lambda m, cv, pk: pk)
    if kind == "fluctuating":
        return pick(lambda m, cv, pk: cv - max(pk - 2.5, 0.0))
    raise ValueError(kind)


EXCERPTS = ("bursty", "steady_low", "steady_high", "fluctuating")


# ---------------------------------------------------------------------------
# production-scale stress excerpts (BENCH_scale)
#
# The Fig.-7 shapes cover the paper's 5-40 RPS regime.  A cluster serving
# millions of users additionally sees (a) heavy-tailed burst storms — many
# small spikes, a few enormous ones, the classic self-similar-traffic
# signature — and (b) flash crowds: a coordinated step to a multiple of
# base load (breaking news, a sale going live) with a sharp ramp and a slow
# decay.  These are synthesized directly (not mined from the 21-day trace)
# so the bench controls their magnitude exactly.
# ---------------------------------------------------------------------------
SCALE_EXCERPTS = ("heavy_tailed", "flash_crowd")


def scale_excerpt(kind: str, seconds: int = 600,
                  cfg: TraceConfig = TraceConfig()) -> np.ndarray:
    """Per-second RPS for one production-scale stress shape.

    ``heavy_tailed``: base load plus Poisson-seeded bursts whose amplitudes
    are Pareto-distributed (tail index 1.5): the expected largest burst in a
    window grows with the window, so capacity planning off the mean fails —
    exactly the regime adaptive reconfiguration is for.

    ``flash_crowd``: steady base load until a crowd lands mid-window — a
    few-second ramp to ``burst_amp``x base, a plateau, then exponential
    decay with ``burst_decay_s``.  One event per window, deterministic in
    ``cfg.seed``.

    Both respect ``cfg.scale`` exactly like ``synth_trace``.
    """
    rng = np.random.default_rng(cfg.seed)
    t = np.arange(seconds, dtype=np.float64)
    base = cfg.base_rps + cfg.noise_sigma * _ar1_noise(
        rng.standard_normal(seconds) * np.sqrt(1 - cfg.noise_rho ** 2),
        cfg.noise_rho)
    if kind == "heavy_tailed":
        rates = np.array(base)
        n_bursts = max(int(rng.poisson(
            max(cfg.burst_rate_per_hour, 6.0) * seconds / 3600.0)), 1)
        # Pareto(1.5) amplitudes relative to burst_amp: median ~1.6x, the
        # occasional draw 10-50x — the heavy tail is the point
        amps = cfg.burst_amp * (1.0 + rng.pareto(1.5, n_bursts))
        starts = rng.integers(0, seconds, n_bursts)
        for s0, amp in zip(starts, amps):
            dur = int(4 * cfg.burst_decay_s)
            idx = np.arange(s0, min(s0 + dur, seconds))
            rates[idx] += amp * np.exp(-(idx - s0) / cfg.burst_decay_s)
    elif kind == "flash_crowd":
        rates = np.array(base)
        s0 = int(rng.integers(seconds // 4, seconds // 2))
        ramp_s = max(int(rng.integers(3, 9)), 1)
        plateau_s = int(cfg.burst_decay_s)
        peak = cfg.burst_amp * max(cfg.base_rps, 1.0)
        ramp = np.minimum((t - s0) / ramp_s, 1.0)
        hold = np.where(t < s0 + ramp_s + plateau_s, 1.0,
                        np.exp(-(t - s0 - ramp_s - plateau_s)
                               / cfg.burst_decay_s))
        rates += np.where(t >= s0, peak * ramp * hold, 0.0)
    else:
        raise ValueError(kind)
    rates = np.clip(rates, 0.5, None)
    if cfg.scale != 1.0:
        rates = rates * cfg.scale
    return rates


def arrivals_from_rates(rates: np.ndarray, seed: SeedLike = 0) -> np.ndarray:
    """Poisson-sample concrete arrival timestamps from per-second rates.

    ``seed`` may be an int (legacy, bit-compatible), a
    ``np.random.SeedSequence`` (what the sweep harness derives per cell —
    spawned children are collision-free by construction, unlike
    arithmetic on a base int) or a ``Generator``.
    """
    rng = np.random.default_rng(seed)
    times = []
    for sec, lam in enumerate(rates):
        n = rng.poisson(lam)
        times.extend(sec + np.sort(rng.random(n)))
    return np.asarray(times)
