"""Event-driven simulator of multi-stage inference pipelines (paper §3:
"a discrete event simulator uses these profiling data to estimate the
end-to-end latency and throughput of the pipeline based on the number of
replicas, model variants, and batch sizes at each stage").

The core is cluster-general: ``ClusterSimulator`` runs the stages of N
pipelines (a ``ClusterModel`` sharing one core budget C) in **one event
heap**, with per-pipeline metrics and a shared-pool replica ledger — a
reconfigure that grows one pipeline must fit inside C minus the other
pipelines' current allocations, else ``CoreBudgetExceeded``.
``PipelineSimulator`` is the N=1 special case and keeps the original
single-pipeline API (``metrics``, ``lam_est``, ``reconfigure(PipelineConfig)``).

Per stage: one central queue (batch formation) feeding ``n_s`` replicas
round-robin; service time of a batch of size k under variant m is the
profiled quadratic l_m(k).  Implements the §4.5 dropping policy: requests
whose age exceeds drop_factor x SLA_P are dropped at batch formation.
Reconfiguration (variant/batch/replicas) takes effect immediately at the
adaptation boundary; in-flight batches finish under the old service time.

The core is purely event-driven — there is no periodic "tick".  A
partially filled batch arms exactly one ``timeout`` event at
``head_enter + wait_bound`` (Eq. 7 via ``core.queueing.wait_bound``); the
event carries a per-stage generation counter so that when the batch
dispatches early (filled up, or flushed by an upstream completion) the
stale timeout is ignored on pop instead of being searched for and removed
from the heap.  A dispatch blocked on busy/cold-starting replicas arms a
``wake`` event at the soonest replica-free time.  Per-dispatch drop scans,
latency accumulation and the per-stage ``free_at`` replica scan all run
vectorized over numpy buffers/arrays that parallel the per-stage queues.
"""
from __future__ import annotations

import heapq
import itertools
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster import ClusterConfig, ClusterModel, single
from repro.core.pipeline import PipelineConfig, PipelineModel, StageConfig
from repro.core.queueing import wait_bound
from repro.serving.request import Request, RequestPool

_EPS = 1e-12
_INF = float("inf")
# replica-fleet size beyond which the free_at dispatch scan lifts the ready
# times into an ndarray: below this, python list scans beat numpy's per-op
# overhead (same tradeoff as the _StageQueue columns)
_NP_SCAN_MIN = 24


class CoreBudgetExceeded(RuntimeError):
    """A reconfigure asked for more cores than the shared pool has left."""


class _FloatBuf:
    """Growable float64 buffer (amortized O(1) append, vectorized extend)."""

    __slots__ = ("_data", "_n")

    def __init__(self, cap: int = 256):
        self._data = np.empty(cap, dtype=np.float64)
        self._n = 0

    def __len__(self) -> int:
        return self._n

    def _grow_to(self, need: int) -> None:
        cap = len(self._data)
        if need > cap:
            new = np.empty(max(need, 2 * cap), dtype=np.float64)
            new[:self._n] = self._data[:self._n]
            self._data = new

    def append(self, x: float) -> None:
        self._grow_to(self._n + 1)
        self._data[self._n] = x
        self._n += 1

    def extend(self, xs: np.ndarray) -> None:
        k = len(xs)
        self._grow_to(self._n + k)
        self._data[self._n:self._n + k] = xs
        self._n += k

    def view(self) -> np.ndarray:
        return self._data[:self._n]


class SimMetrics:
    """Aggregate counters; latencies live in a growable float64 buffer so
    per-batch completion extends an array instead of appending Python
    floats one by one."""

    __slots__ = ("_lat", "completed", "dropped", "arrived")

    def __init__(self):
        self._lat = _FloatBuf()
        self.completed = 0
        self.dropped = 0
        self.arrived = 0

    @property
    def latencies(self) -> np.ndarray:
        """Completed-request latencies as a float64 array view."""
        return self._lat.view()

    def sla_violations(self, sla: float) -> float:
        """Fraction of arrived requests violating the SLA (drops count)."""
        if self.arrived == 0:
            return 0.0
        late = int(np.count_nonzero(self._lat.view() > sla))
        return (late + self.dropped) / self.arrived


class _StageQueue:
    """FIFO of requests with parallel columns (absolute arrival time,
    stage-enter time).  Columns are plain lists — batches are small, so
    per-event python appends/slices beat numpy's per-op overhead — and are
    lifted into an ndarray only when a drop scan actually runs, which the
    ``min_arr`` guard makes rare.  ``head`` is a logical front pointer;
    storage compacts lazily."""

    __slots__ = ("reqs", "_arr", "_enter", "head", "min_arr")

    def __init__(self):
        self.reqs: List[Request] = []
        self._arr: List[float] = []
        self._enter: List[float] = []
        self.head = 0
        # conservative lower bound on the oldest live arrival: lets the
        # caller skip the drop scan entirely while nothing can be expired
        self.min_arr = _INF

    def __len__(self) -> int:
        return len(self.reqs) - self.head

    def push(self, req: Request, now: float) -> None:
        self._arr.append(req.arrival)
        self._enter.append(now)
        if req.arrival < self.min_arr:
            self.min_arr = req.arrival
        self.reqs.append(req)

    def push_many(self, reqs: Sequence[Request], arrs: Sequence[float],
                  now: float) -> None:
        """Append a whole upstream batch with its arrival column."""
        self._arr.extend(arrs)
        self._enter.extend([now] * len(reqs))
        m = min(arrs)
        if m < self.min_arr:
            self.min_arr = m
        self.reqs.extend(reqs)

    def head_enter(self) -> float:
        return self._enter[self.head]

    def head_arrival(self) -> float:
        return self._arr[self.head]

    def pop_batch(self, k: int) -> Tuple[List[Request], List[float]]:
        h = self.head
        e = h + k
        batch = self.reqs[h:e]
        arrs = self._arr[h:e]
        self.head = e
        t = len(self.reqs)
        if e == t:
            self.min_arr = _INF
        if e >= 512 and 2 * e >= t:
            del self.reqs[:e]
            del self._arr[:e]
            del self._enter[:e]
            self.head = 0
        return batch, arrs

    def drop_expired(self, now: float, threshold: float) -> List[Request]:
        """Remove (and return) every queued request older than ``threshold``.

        The age test runs vectorized over the arrival column; callers only
        reach this when ``min_arr`` says something may actually be old."""
        h, t = self.head, len(self.reqs)
        if h == t:
            self.min_arr = _INF
            return []
        live_arr = np.array(self._arr[h:t], dtype=np.float64)
        oldest = float(live_arr.min())
        if now - oldest <= threshold:
            self.min_arr = oldest        # tightened bound, nothing expired
            return []
        expired = (now - live_arr) > threshold
        keep = ~expired
        dropped = list(itertools.compress(self.reqs[h:t], expired))
        kept = list(itertools.compress(self.reqs[h:t], keep))
        self.reqs = kept
        self._arr = list(itertools.compress(self._arr[h:t], keep))
        self._enter = list(itertools.compress(self._enter[h:t], keep))
        self.head = 0
        self.min_arr = min(self._arr) if kept else _INF
        return dropped

    def discard_rids(self, rids) -> List[Request]:
        """Remove (and return) every queued request whose ``rid`` is in the
        given set — §4.5 drop propagation purging a cancelled request's
        sibling-branch copies (DAG pipelines only)."""
        h, t = self.head, len(self.reqs)
        live = self.reqs[h:t]
        sel = [r.rid in rids for r in live]
        if not any(sel):
            return []
        keep = [not m for m in sel]
        removed = list(itertools.compress(live, sel))
        self.reqs = list(itertools.compress(live, keep))
        self._arr = list(itertools.compress(self._arr[h:t], keep))
        self._enter = list(itertools.compress(self._enter[h:t], keep))
        self.head = 0
        self.min_arr = min(self._arr) if self.reqs else _INF
        return removed


class ClusterSimulator:
    """All pipelines of a ``ClusterModel`` in one event heap.

    Stages are flattened to global indices (pipeline p's stage i at
    ``_first[p] + i``); every per-stage structure (queue, replica
    ``free_at`` array, generation counter, timeout/wake markers) is one
    flat list over global stages, so the event machinery is exactly the
    single-pipeline machinery run over a larger index space.  Metrics,
    arrival-rate estimates and drop thresholds are per-pipeline.
    """

    def __init__(self, cluster: ClusterModel, config: ClusterConfig,
                 drop_factor: float = 2.0, max_wait: float = 0.5,
                 seed: int = 0, variant_switch_delay: float = 0.0,
                 scale_up_delay: float = 0.0,
                 adaptation_delay: float = 0.0,
                 record_timeline: bool = False,
                 request_pool: Optional[RequestPool] = None):
        """``variant_switch_delay``: cold-start of a stage whose model
        variant changed (container pull + model load; the paper reports an
        ~8 s adaptation process and mitigates pull time with MinIO).
        ``scale_up_delay``: startup of additionally provisioned replicas.
        ``adaptation_delay``: the §5.3 adaptation window — a reconfigured
        pipeline keeps *serving at its old config* for this long before the
        new one takes effect (the decision commits immediately: the replica
        ledger charges the new allocation and ``pipeline_config`` returns
        the target, but queues/batching/service run the old config until
        the deferred ``apply`` event fires).  ``serving_config`` exposes
        what is actually serving; ``reconfig_log`` records every committed
        *decision* as ``(decided_at, pipeline, scheduled_apply_at)`` — a
        decision superseded inside its window keeps its entry (its
        disruption was paid) but its scheduled apply never fires.
        Transition-overlap accounting: during the window the old replica
        fleet is still serving while the new one provisions, so the ledger
        charges the pipeline ``max(old, new)`` cores from the decision
        instant until the apply fires (then drops to the new cost).  A
        grant of a downsizer's freed cores to another pipeline inside the
        window therefore raises ``CoreBudgetExceeded`` *at decision time*
        — instantaneous serving capacity can never exceed C
        (``peak_serving_cores`` is the run's witness; the overlap-aware
        ``optimizer.solve_cluster(..., overlap=True)`` plans against the
        same ``max(old, new)`` charge so its proposals are admissible by
        construction).
        ``record_timeline``: also fill each request's per-stage
        ``stage_enter``/``stage_exit`` dicts (debug/inspection; the hot
        path skips these dict writes — aggregate metrics, drop marks and
        ``done`` stamps are always recorded).
        ``request_pool``: when set, completed/dropped requests are released
        back to the pool at their terminal event — callers that keep
        references to injected requests must not pass a pool."""
        if len(config.pipelines) != len(cluster.pipelines):
            raise ValueError("config/cluster pipeline count mismatch")
        self.cluster = cluster
        self.n_pipelines = len(cluster.pipelines)
        self.core_budget = float(cluster.cores)
        # per-device-class ledger axis (None on a scalar-budget cluster —
        # every vector path below is gated on it, so the single-class run
        # is instruction-for-instruction the legacy scalar ledger)
        self._classes = cluster.device_classes \
            if getattr(cluster, "is_hetero", False) else None
        self._budget_vec = cluster.budget_vector \
            if self._classes is not None else None
        self.drop_factor = drop_factor
        self.max_wait = max_wait
        self.variant_switch_delay = variant_switch_delay
        self.scale_up_delay = scale_up_delay
        self.adaptation_delay = adaptation_delay
        self.record_timeline = record_timeline
        self._pool = request_pool

        # ---- flatten stages to global indices ---------------------------
        self._stage_models = []              # StageModel per global stage
        self._pipe_of: List[int] = []        # owning pipeline per stage
        self._next: List[int] = []           # next global stage (-1 = sink)
        self._first: List[int] = []          # entry stage per pipeline
        self._stages_of: List[range] = []    # global stage range per pipeline
        # DAG topology (chains never consult these beyond the _dag_route
        # bool): children per global stage, parent counts, and whether the
        # owning pipeline routes through the DAG path at all
        self._children: List[Tuple[int, ...]] = []
        self._n_parents: List[int] = []
        self._dag_route: List[bool] = []     # per stage: owner is a DAG
        self._dag_pipe: List[bool] = []      # per pipeline
        for pipe in cluster.pipelines:
            base = len(self._stage_models)
            ns = len(pipe.stages)
            chain = pipe.is_chain
            self._first.append(base)
            self._stages_of.append(range(base, base + ns))
            self._dag_pipe.append(not chain)
            for i, st in enumerate(pipe.stages):
                self._stage_models.append(st)
                self._pipe_of.append(len(self._first) - 1)
                self._next.append(base + i + 1 if i + 1 < ns else -1)
                self._dag_route.append(not chain)
                if chain:
                    self._children.append((base + i + 1,) if i + 1 < ns
                                          else ())
                    self._n_parents.append(1 if i else 0)
                else:
                    self._children.append(tuple(
                        base + c for c in pipe.children_of(i)))
                    self._n_parents.append(len(pipe.parents_of(i)))
        self.n_stages = len(self._stage_models)
        # per-pipeline DAG request tracking: rid counters, in-flight token
        # counts (queued copies + in-service copies + one per partial-join
        # entry), the §4.5-cancelled rid set, and per-join partial buffers
        # (rid -> parents delivered so far)
        self._rid_next: List[int] = [0] * self.n_pipelines
        self._inflight: List[dict] = [{} for _ in range(self.n_pipelines)]
        self._dead: List[set] = [set() for _ in range(self.n_pipelines)]
        self._join_buf: List[Optional[dict]] = [
            {} if n > 1 else None for n in self._n_parents]
        # pooled DAG runs: rid -> Request registry so a request is released
        # exactly once, at full retirement (when its rid leaves
        # ``_inflight``) — never while sibling fan-out copies of the same
        # object are still queued, in service or buffered at a join
        self._req_of: List[dict] = [{} for _ in range(self.n_pipelines)]

        self.configs: List[StageConfig] = []
        for cfg in config.pipelines:
            self.configs.extend(cfg.stages)
        if len(self.configs) != self.n_stages:
            raise ValueError("config/pipeline stage count mismatch")

        self.queues: List[_StageQueue] = [
            _StageQueue() for _ in range(self.n_stages)]
        # per-stage replica ready times; plain lists like the queue columns
        # (replica fleets are usually small, python beats numpy's per-op
        # overhead) — the dispatch scan lifts to a vectorized ndarray pass
        # only past _NP_SCAN_MIN replicas, where batching wins
        self.free_at: List[List[float]] = [
            [0.0] * sc.replicas for sc in self.configs]
        self.rr: List[int] = [0] * self.n_stages
        self.now = 0.0

        # ---- per-pipeline control/metrics state -------------------------
        self.metrics_by_pipe: List[SimMetrics] = [
            SimMetrics() for _ in range(self.n_pipelines)]
        self.sla_of: List[float] = [p.sla for p in cluster.pipelines]
        self._lam_of: List[float] = [10.0] * self.n_pipelines
        # shared-pool replica ledger: cores currently held per pipeline.
        # While a §5.3 adaptation window is in flight this is the
        # transition charge max(serving, committed) — the old fleet still
        # serves while the new one provisions — and drops to the committed
        # cost when the deferred apply fires.  _serving_cost tracks what
        # the serving fleets alone hold (<= _alloc elementwise always).
        self._alloc: List[float] = [
            cfg.cost(pipe) for cfg, pipe
            in zip(config.pipelines, cluster.pipelines)]
        self._serving_cost: List[float] = list(self._alloc)
        if sum(self._alloc) > self.core_budget + 1e-9:
            raise CoreBudgetExceeded(
                f"initial config needs {sum(self._alloc)} cores, "
                f"budget is {self.core_budget}")
        # per-class ledger mirror: one cost vector per pipeline, same
        # max(old, new) transition discipline applied elementwise
        self._alloc_vec: Optional[List[Tuple[float, ...]]] = None
        self._serving_vec: Optional[List[Tuple[float, ...]]] = None
        if self._classes is not None:
            self._alloc_vec = [
                tuple(cfg.cost_by_class(pipe, self._classes))
                for cfg, pipe in zip(config.pipelines, cluster.pipelines)]
            self._serving_vec = list(self._alloc_vec)
            for c, b in enumerate(self._budget_vec):
                tot = sum(v[c] for v in self._alloc_vec)
                if tot > b + 1e-9:
                    raise CoreBudgetExceeded(
                        f"initial config needs {tot} {self._classes[c]} "
                        f"cores, class budget is {b}")
        # invariant witness: sup over time of sum(_serving_cost) — serving
        # cost is piecewise constant between (re)configuration instants, so
        # maxing at every change captures the exact supremum.  A zero-delay
        # *joint* reconfigure is semantically atomic: per-pipeline partial
        # sums mid-loop are states that never existed, so peak sampling is
        # suppressed until the whole joint config has been applied.
        self.peak_serving_cores = float(sum(self._serving_cost))
        self.peak_serving_by_class: Optional[Tuple[float, ...]] = None
        if self._serving_vec is not None:
            self.peak_serving_by_class = tuple(
                sum(v[c] for v in self._serving_vec)
                for c in range(len(self._classes)))
        self._joint_apply = False

        self._events: List[Tuple[float, int, str, object]] = []
        self._seq = itertools.count()
        # injections bypass the heap: adapter/benchmark workloads inject in
        # (near-)sorted time order, so arrivals live in sorted parallel
        # columns (time, entry stage, request) consumed by a front pointer
        # and merged with the heap in run_until.  Parallel lists instead of
        # tuples so ``inject_arrivals`` can bulk-extend a whole decision
        # window's arrivals in three C-level extends (pre-sized batching —
        # no per-request tuple churn).
        self._inj_t: List[float] = []
        self._inj_s: List[int] = []
        self._inj_r: List[Request] = []
        self._inj_i = 0
        self._inj_sorted = True
        # hot-path caches: SLA_P and drop threshold are per-pipeline config
        # constants (flattened per-stage for the dispatch path); per-batch
        # service latency and wait bounds change only on reconfigure /
        # lam_est updates
        self._drop_thr_s: List[float] = [
            drop_factor * self.sla_of[p] for p in self._pipe_of]
        self._lat_tab: List[List[float]] = []
        self._wb: Optional[List[float]] = None
        self._refresh_lat_tab()
        # lazy-cancellation state: one pending timeout/wake marker per stage
        self._gen: List[int] = [0] * self.n_stages
        self._timeout_at: List[float] = [_INF] * self.n_stages
        self._wake_at: List[float] = [_INF] * self.n_stages
        # §5.3 adaptation-window state: committed-but-not-yet-serving config
        # per pipeline, with a generation counter so a superseding decision
        # lazily cancels the stale deferred apply event
        self._pending_cfg: List[Optional[PipelineConfig]] = \
            [None] * self.n_pipelines
        self._pending_gen: List[int] = [0] * self.n_pipelines
        # every committed reconfiguration DECISION, as (decided_at,
        # pipeline, scheduled_apply_at).  Each entry starts an adaptation
        # window (the §5.3 disruption is paid from decided_at); a later
        # decision inside the window supersedes the earlier one, whose
        # scheduled apply then never fires — so this logs decisions made,
        # not rollouts completed, and n_reconfigs == len(reconfig_log)
        self.reconfig_log: List[Tuple[float, int, float]] = []
        self.n_reconfigs = 0
        # observability (benchmarks / invariants)
        self.events_processed = 0
        self.peak_queue_depth = 0
        self.in_service = 0

    # -- control plane --------------------------------------------------
    def reconfigure_pipeline(self, p: int, config: PipelineConfig,
                             _check_budget: bool = True) -> None:
        """Reconfigure one pipeline inside the shared core pool.

        The new allocation must fit in ``core_budget`` minus the other
        pipelines' current allocations (the replica ledger); a violating
        request raises ``CoreBudgetExceeded`` and changes nothing.

        A proposal equal to the committed config is a no-op (it neither
        re-arms timeouts nor counts as a reconfiguration).  With
        ``adaptation_delay > 0`` a genuine change *commits* now (ledger,
        ``pipeline_config``) but the stages keep serving the old config
        until the deferred apply event fires ``adaptation_delay`` later;
        re-proposing the serving config mid-transition cancels the pending
        rollout instead of scheduling a new one.  The ledger charge
        through the window is ``max(serving, new)`` — the old fleet serves
        it out while the new one provisions — so an overlapping grant of
        not-yet-freed cores is rejected here, at decision time.
        """
        pipe = self.cluster.pipelines[p]
        if len(config.stages) != len(pipe.stages):
            raise ValueError("config/pipeline stage count mismatch")
        if config == self.pipeline_config(p):     # committed already
            return
        new_cost = config.cost(pipe)
        if self.adaptation_delay > 0:
            trans_cost = max(self._serving_cost[p], new_cost)
        else:
            trans_cost = new_cost
        trans_vec: Optional[Tuple[float, ...]] = None
        if self._classes is not None:
            new_vec = config.cost_by_class(pipe, self._classes)
            if self.adaptation_delay > 0:
                trans_vec = tuple(max(a, b) for a, b
                                  in zip(self._serving_vec[p], new_vec))
            else:
                trans_vec = new_vec
        if _check_budget:
            others = sum(self._alloc) - self._alloc[p]
            if others + trans_cost > self.core_budget + 1e-9:
                raise CoreBudgetExceeded(
                    f"pipeline {p} wants {trans_cost} cores through its "
                    f"transition but only {self.core_budget - others} of "
                    f"{self.core_budget} are unallocated")
            if trans_vec is not None:
                for c, b in enumerate(self._budget_vec):
                    oth = sum(v[c] for v in self._alloc_vec) \
                        - self._alloc_vec[p][c]
                    if oth + trans_vec[c] > b + 1e-9:
                        raise CoreBudgetExceeded(
                            f"pipeline {p} wants {trans_vec[c]} "
                            f"{self._classes[c]} cores through its "
                            f"transition but only {b - oth} of {b} are "
                            f"unallocated")
        self._alloc[p] = trans_cost
        if trans_vec is not None:
            self._alloc_vec[p] = trans_vec
        if self._pending_cfg[p] is not None and \
                config == self.serving_config(p):
            # revert to what is already serving: cancel the pending rollout
            # (the cancel itself starts no new adaptation window, so it adds
            # no log entry; the aborted decision's entry remains, its
            # scheduled apply never fires)
            self._pending_cfg[p] = None
            self._pending_gen[p] += 1
            return
        self.n_reconfigs += 1
        if self.adaptation_delay > 0:
            apply_at = self.now + self.adaptation_delay
            self._pending_cfg[p] = config
            self._pending_gen[p] += 1
            self._push(apply_at, "apply", (p, self._pending_gen[p]))
            self.reconfig_log.append((self.now, p, apply_at))
            return
        self.reconfig_log.append((self.now, p, self.now))
        self._apply_pipeline_config(p, config)

    def _apply_pipeline_config(self, p: int, config: PipelineConfig) -> None:
        """Make ``config`` the serving configuration of pipeline ``p``
        (immediately at zero adaptation delay, else at the deferred apply
        event)."""
        for s, sc in zip(self._stages_of[p], config.stages):
            old = self.free_at[s]
            n = sc.replicas
            switched = sc.variant != self.configs[s].variant
            if switched and self.variant_switch_delay > 0:
                # cold start: every replica of the stage reloads the model
                ready = self.now + self.variant_switch_delay
                old[:] = [max(t, ready) for t in old]
            if n >= len(old):
                start = self.now + (self.variant_switch_delay if switched
                                    else self.scale_up_delay)
                old.extend([start] * (n - len(old)))
            else:
                # keep the soonest-free replicas
                old.sort()
                del old[n:]
            self.configs[s] = sc
            # batch size / replica availability changed: pending deadlines
            # are stale, re-arm from current state
            self._bump(s)
            self._wake_at[s] = _INF
        # the old fleet stops serving here: settle the ledger from the
        # transition charge max(old, new) down to the new steady-state cost
        cost = config.cost(self.cluster.pipelines[p])
        self._alloc[p] = cost
        self._serving_cost[p] = cost
        if self._classes is not None:
            vec = tuple(config.cost_by_class(self.cluster.pipelines[p],
                                             self._classes))
            self._alloc_vec[p] = vec
            self._serving_vec[p] = vec
        if not self._joint_apply:
            self._note_serving_peak()
        self._refresh_lat_tab(self._stages_of[p])
        self._wb = None
        for s in self._stages_of[p]:
            self._try_dispatch(s)

    def reconfigure(self, config: ClusterConfig) -> None:
        """Atomically reconfigure every pipeline to a joint configuration.

        With ``adaptation_delay > 0`` the admission check is the
        *transition* cost — every changed pipeline charged
        ``max(serving, new)`` through its window — so a joint proposal
        that only fits after the windows close is rejected now, not
        silently over-committed mid-window."""
        cost = self.transition_cost(config)
        if cost > self.core_budget + 1e-9:
            raise CoreBudgetExceeded(
                f"joint config needs {cost} cores through its transition, "
                f"budget is {self.core_budget}")
        if self._classes is not None and not self.fits_transition(config):
            raise CoreBudgetExceeded(
                "joint config exceeds a device-class budget through its "
                "transition")
        self._joint_apply = True
        try:
            for p, cfg in enumerate(config.pipelines):
                self.reconfigure_pipeline(p, cfg, _check_budget=False)
        finally:
            self._joint_apply = False
        self._note_serving_peak()

    def set_lam_est(self, p: int, v: float) -> None:
        """Update pipeline ``p``'s arrival-rate estimate (re-arms pending
        batch-formation timeouts, whose Eq. 7 deadline depends on it)."""
        v = float(v)
        if v == self._lam_of[p]:
            return
        self._lam_of[p] = v
        self._wb = None                  # wait bounds depend on lambda
        # pending batch-formation timeouts were armed under the old lambda;
        # supersede and re-arm them so the deadline tracks the new Eq. 7
        # bound (the legacy core re-evaluated the bound on every tick)
        for s in self._stages_of[p]:
            if self._timeout_at[s] != _INF:
                self._bump(s)
                self._try_dispatch(s)

    # -- invariants / observability --------------------------------------
    @property
    def queued(self) -> int:
        return sum(len(q) for q in self.queues)

    @property
    def allocated_cores(self) -> float:
        """Cores currently held across all pipelines (the ledger total,
        transition charges included)."""
        return float(sum(self._alloc))

    @property
    def serving_cores(self) -> float:
        """Cores the currently *serving* replica fleets hold — during a
        §5.3 window this is the old fleets' total, which the ledger's
        ``max(old, new)`` charge bounds from above, so
        ``serving_cores <= allocated_cores <= core_budget`` always."""
        return float(sum(self._serving_cost))

    def _note_serving_peak(self) -> None:
        total = sum(self._serving_cost)
        if total > self.peak_serving_cores:
            self.peak_serving_cores = total
        if self._serving_vec is not None:
            self.peak_serving_by_class = tuple(
                max(p, sum(v[c] for v in self._serving_vec))
                for c, p in enumerate(self.peak_serving_by_class))

    @property
    def serving_cluster_config(self) -> ClusterConfig:
        """The joint configuration the stages are actually serving right
        now (per-pipeline ``serving_config``)."""
        return ClusterConfig(tuple(self.serving_config(p)
                                   for p in range(self.n_pipelines)))

    def transition_cost(self, config: ClusterConfig) -> float:
        """Cores a joint reconfiguration to ``config`` would hold through
        its §5.3 adaptation windows: each pipeline charged
        ``max(serving, new)`` — delegated to
        ``ClusterConfig.transition_cost`` against the serving config (at
        zero adaptation delay there is no window and this is just
        ``config.cost``).  ``fits_transition`` is the admission predicate
        the adapter checks before applying a joint proposal."""
        if self.adaptation_delay <= 0:
            return config.cost(self.cluster)
        return config.transition_cost(self.cluster,
                                      self.serving_cluster_config)

    def fits_transition(self, config: ClusterConfig) -> bool:
        """Does reconfiguring to ``config`` fit the core budget throughout
        its adaptation windows (not merely after them)?"""
        if self.adaptation_delay <= 0:
            return config.fits(self.cluster)
        return config.fits_transition(self.cluster,
                                      self.serving_cluster_config)

    def pipeline_config(self, p: int) -> PipelineConfig:
        """The configuration pipeline ``p`` is *committed* to: the pending
        transition target while an adaptation window is in flight, else the
        serving config.  This is what the replica ledger charges and what a
        holding adapter must re-propose — holding the serving (pre-
        transition) config instead would cancel an in-flight rollout."""
        pending = self._pending_cfg[p]
        if pending is not None:
            return pending
        return PipelineConfig(tuple(self.configs[s]
                                    for s in self._stages_of[p]))

    def serving_config(self, p: int) -> PipelineConfig:
        """The configuration pipeline ``p``'s stages are actually serving
        right now (the old config while a transition is in flight)."""
        return PipelineConfig(tuple(self.configs[s]
                                    for s in self._stages_of[p]))

    @property
    def current_config(self) -> ClusterConfig:
        """The joint configuration the simulator is committed to."""
        return ClusterConfig(tuple(self.pipeline_config(p)
                                   for p in range(self.n_pipelines)))

    # -- hot-path caches --------------------------------------------------
    def _refresh_lat_tab(self, stages=None) -> None:
        """Per-stage service-latency table l_m(k) for k = 0..batch under the
        current variant (one vectorized evaluation per reconfigured stage).

        ``stages``: global stage indices to refresh (default: all) — a
        per-pipeline reconfigure only rebuilds its own stages' tables.
        """
        if stages is None:
            stages = range(self.n_stages)
            self._lat_tab = [None] * self.n_stages
            self._batch_of = [0] * self.n_stages
        for s in stages:
            st, sc = self._stage_models[s], self.configs[s]
            ks = np.arange(sc.batch + 1, dtype=np.float64)
            ks[0] = 1.0                  # k=0 never dispatched; keep finite
            self._lat_tab[s] = \
                st.variant(sc.variant).latency(ks, sc.device).tolist()
            self._batch_of[s] = sc.batch

    def _wait_bounds(self) -> List[float]:
        if self._wb is None:
            self._wb = [wait_bound(sc.batch, self._lam_of[p], self.max_wait)
                        for sc, p in zip(self.configs, self._pipe_of)]
        return self._wb

    # -- event machinery --------------------------------------------------
    def _push(self, t: float, kind: str, payload) -> None:
        heapq.heappush(self._events, (t, next(self._seq), kind, payload))

    def _bump(self, s: int) -> None:
        """Supersede any pending timeout for stage ``s`` (lazy cancel)."""
        self._gen[s] += 1
        self._timeout_at[s] = _INF

    def _schedule_timeout(self, s: int, t: float) -> None:
        if t < self._timeout_at[s] - _EPS:
            self._timeout_at[s] = t
            self._push(t, "timeout", (s, self._gen[s]))

    def _schedule_wake(self, s: int, t: float) -> None:
        if t <= self.now + _EPS:
            t = self.now + 1e-9
        if t < self._wake_at[s] - _EPS:
            self._wake_at[s] = t
            self._push(t, "wake", s)

    def inject(self, req: Request, pipeline: int = 0) -> None:
        self.metrics_by_pipe[pipeline].arrived += 1
        t = req.arrival
        ts = self._inj_t
        if ts and t < ts[-1]:
            self._inj_sorted = False
        ts.append(t)
        self._inj_s.append(self._first[pipeline])
        self._inj_r.append(req)

    def inject_arrivals(self, times: Sequence[float],
                        pipeline: int = 0) -> None:
        """Bulk-inject one pipeline's arrivals for a whole decision window.

        The pre-sized batching path the adapters use: one vectorized
        order check plus three C-level list extends replace a per-request
        python loop of ``inject`` calls (tuple build, sortedness check and
        metrics bump each).  Requests are acquired from the attached
        ``request_pool`` in one bulk pass when the simulator has one
        (``RequestPool.acquire_many``), else freshly allocated; each
        carries its pipeline's SLA, exactly as the per-request path.
        Equivalent to ``inject`` call-for-call — the equivalence tests pin
        identical metrics and latency streams.
        """
        times = np.asarray(times, dtype=np.float64)
        k = times.size
        if k == 0:
            return
        ts = times.tolist()
        col = self._inj_t
        if (col and ts[0] < col[-1]) or \
                (k > 1 and bool(np.any(times[1:] < times[:-1]))):
            self._inj_sorted = False
        self.metrics_by_pipe[pipeline].arrived += k
        sla = self.sla_of[pipeline]
        if self._pool is not None:
            reqs = self._pool.acquire_many(ts, sla)
        else:
            reqs = [Request(arrival=t, sla=sla) for t in ts]
        col.extend(ts)
        self._inj_s.extend([self._first[pipeline]] * k)
        self._inj_r.extend(reqs)

    def _stage_latency(self, s: int, k: int) -> float:
        tab = self._lat_tab[s]
        if k < len(tab):
            return tab[k]
        sc = self.configs[s]
        v = self._stage_models[s].variant(sc.variant)
        return float(v.latency(max(k, 1), sc.device))

    def _try_dispatch(self, s: int) -> None:
        q = self.queues[s]
        now = self.now
        # §4.5 drop policy — the min-arrival bound lets the common
        # nothing-to-expire case skip the vectorized scan entirely
        thr = self._drop_thr_s[s]
        if now - q.min_arr > thr:
            dropped = q.drop_expired(now, thr)
            if dropped:
                for r in dropped:
                    r.dropped_at = s
                    r.done = now
                self.metrics_by_pipe[self._pipe_of[s]].dropped += len(dropped)
                self._bump(s)
                if self._dag_route[s]:
                    # §4.5 drop propagation: cancel the sibling branches'
                    # in-flight copies of every dropped request.  Pool
                    # release happens inside the cancel path at full
                    # retirement — sibling copies of the same object may
                    # still be in flight here
                    self._dag_cancel(s, [r.rid for r in dropped])
                elif self._pool is not None:
                    self._pool.release_many(dropped)
        nq = len(q.reqs) - q.head
        if not nq:
            return
        batch_sz = self.configs[s].batch
        free = self.free_at[s]
        limit = now + _EPS
        # hot-loop locals: every dispatched batch costs one heap push, one
        # replica-slot write and one generation bump
        tab = self._lat_tab[s]
        tab_n = len(tab)
        events = self._events
        seq = self._seq
        push = heapq.heappush
        gen = self._gen
        while nq:
            if nq < batch_sz:
                # a forming batch waits for its Eq. 7 deadline before the
                # replica state matters: dispatch happens at
                # max(deadline, soonest-free) either way, so checking the
                # deadline first skips the replica scan on the (common)
                # still-forming path
                deadline = q.head_enter() + self._wait_bounds()[s]
                if now < deadline - _EPS:
                    self._schedule_timeout(s, deadline)
                    return
                k = nq
            else:
                k = batch_sz
            nf = len(free)
            if nf == 0:
                # zero replicas configured: requests can only age out
                self._schedule_wake(s, q.head_arrival() + thr)
                return
            if nf > _NP_SCAN_MIN:
                # large fleet: one vectorized pass over the ready times
                arr = np.asarray(free)
                avail = (arr <= limit).nonzero()[0]
                n_avail = avail.size
                if n_avail == 0:
                    self._schedule_wake(s, float(arr.min()))
                    return
                rep = int(avail[self.rr[s] % n_avail])
            else:
                avail = [i for i, t in enumerate(free) if t <= limit]
                n_avail = len(avail)
                if n_avail == 0:
                    self._schedule_wake(s, min(free))
                    return
                rep = avail[self.rr[s] % n_avail]
            batch, arrs = q.pop_batch(k)
            nq -= k
            self.rr[s] += 1
            done_t = now + (tab[k] if k < tab_n
                            else self._stage_latency(s, k))
            free[rep] = done_t
            self.in_service += k
            push(events, (done_t, next(seq), "done", (s, batch, arrs)))
            gen[s] += 1                  # inlined _bump (lazy cancel)
            self._timeout_at[s] = _INF

    def _handle(self, kind: str, payload) -> None:
        if kind == "arrive":
            s, reqs, arrs = payload
            q = self.queues[s]
            if self._dag_route[s] and self._n_parents[s] == 0:
                # DAG pipeline entry: stamp per-pipeline request ids (join
                # matching / drop propagation) and open the token count
                p = self._pipe_of[s]
                infl = self._inflight[p]
                rid = self._rid_next[p]
                for r in reqs:
                    r.rid = rid
                    infl[rid] = 1
                    rid += 1
                self._rid_next[p] = rid
                if self._pool is not None:
                    reg = self._req_of[p]
                    for r in reqs:
                        reg[r.rid] = r
            if arrs is None:
                for r in reqs:
                    q.push(r, self.now)
            else:
                q.push_many(reqs, arrs, self.now)
            if self.record_timeline:
                for r in reqs:
                    r.stage_enter[s] = self.now
            d = len(q.reqs) - q.head
            if d > self.peak_queue_depth:
                self.peak_queue_depth = d
            # fast path: the batch is still forming (not full), its head is
            # unchanged and already has a live timeout armed, and nothing
            # can have expired — this arrival cannot trigger a dispatch
            if (d >= self._batch_of[s]
                    or self._timeout_at[s] == _INF
                    or self.now - q.min_arr > self._drop_thr_s[s]):
                self._try_dispatch(s)
        elif kind == "done":
            s, batch, arrs = payload
            self.in_service -= len(batch)
            if self.record_timeline:
                for r in batch:
                    r.stage_exit[s] = self.now
            if self._dag_route[s]:
                self._done_dag(s, batch, arrs)
            else:
                nxt = self._next[s]
                if nxt >= 0:
                    # synchronous handoff: the next-stage arrival is at
                    # this same instant, so deliver it directly instead of
                    # taking a round-trip through the heap
                    self._handle("arrive", (nxt, batch, arrs))
                else:
                    now = self.now
                    for r in batch:
                        r.done = now
                    m = self.metrics_by_pipe[self._pipe_of[s]]
                    m.completed += len(batch)
                    m._lat.extend([now - a for a in arrs])
                    if self._pool is not None:
                        self._pool.release_many(batch)
            q = self.queues[s]
            if len(q.reqs) > q.head:         # freed replica, waiting work
                self._try_dispatch(s)
        elif kind == "timeout":
            s, gen = payload
            if self._timeout_at[s] <= self.now + _EPS:
                self._timeout_at[s] = _INF
            if gen == self._gen[s]:          # else: superseded, ignore
                q = self.queues[s]
                if len(q.reqs) > q.head:
                    self._try_dispatch(s)
        elif kind == "wake":
            s = payload
            if self._wake_at[s] <= self.now + _EPS:
                self._wake_at[s] = _INF
            q = self.queues[s]
            if len(q.reqs) > q.head:
                self._try_dispatch(s)
        elif kind == "apply":
            # end of a §5.3 adaptation window: the committed config starts
            # serving (stale events from superseded decisions are ignored
            # via the pipeline generation counter)
            p, gen = payload
            if gen == self._pending_gen[p] and self._pending_cfg[p] is not None:
                cfg = self._pending_cfg[p]
                self._pending_cfg[p] = None
                self._apply_pipeline_config(p, cfg)

    # -- DAG routing (stages whose owning pipeline is not a chain) ---------
    #
    # Fan-out: a completed batch is replicated to every child (the same
    # Request objects — each queued copy, in-service copy and partial-join
    # entry carries one token in the per-pipeline ``_inflight`` count).  A
    # join (>1 parents) buffers per-request delivery counts keyed by rid
    # and enqueues the request only when its *last* parent delivers
    # (wait-for-all-parents).  A §4.5 drop of any copy cancels the whole
    # request: its rid joins ``_dead``, sibling queued copies and join
    # partials are purged immediately, and in-service copies are discarded
    # when their batch completes.  Chains never enter any of this — their
    # event path above is untouched (the equivalence tests pin
    # bit-identity).
    def _dec_token(self, p: int, rid: int) -> None:
        infl = self._inflight[p]
        n = infl[rid] - 1
        if n:
            infl[rid] = n
        else:
            del infl[rid]
            self._dead[p].discard(rid)
            if self._pool is not None:   # last copy gone: fully retired
                self._pool.release(self._req_of[p].pop(rid))

    def _done_dag(self, s: int, batch, arrs) -> None:
        p = self._pipe_of[s]
        infl = self._inflight[p]
        dead = self._dead[p]
        now = self.now
        if dead:
            alive, alive_arrs = [], []
            for r, a in zip(batch, arrs):
                if r.rid in dead:            # cancelled mid-service
                    self._dec_token(p, r.rid)
                else:
                    alive.append(r)
                    alive_arrs.append(a)
        else:
            alive, alive_arrs = list(batch), list(arrs)
        if not alive:
            return
        children = self._children[s]
        if not children:                     # sink: the request completes
            for r in alive:
                r.done = now
                del infl[r.rid]
            m = self.metrics_by_pipe[p]
            m.completed += len(alive)
            m._lat.extend([now - a for a in alive_arrs])
            if self._pool is not None:       # single sink: last copy each
                reg = self._req_of[p]
                for r in alive:
                    del reg[r.rid]
                self._pool.release_many(alive)
            return
        if len(children) > 1:                # fan-out: one token per copy
            extra = len(children) - 1
            for r in alive:
                infl[r.rid] += extra
        for c in children:
            if dead:
                # a drop during an earlier child's dispatch may have
                # cancelled requests this child still expects a copy of
                live_r, live_a = [], []
                for r, a in zip(alive, alive_arrs):
                    if r.rid in dead:
                        self._dec_token(p, r.rid)
                    else:
                        live_r.append(r)
                        live_a.append(a)
                if not live_r:
                    continue
            else:
                live_r, live_a = alive, alive_arrs
            if self._n_parents[c] > 1:
                self._deliver_join(c, live_r, live_a)
            else:
                self._handle("arrive", (c, live_r, live_a))

    def _deliver_join(self, c: int, reqs, arrs) -> None:
        """Wait-for-all-parents: buffer per-parent deliveries by rid; the
        request enters the join queue (with its original arrival time, in
        delivering-batch order) only when its last parent delivers."""
        buf = self._join_buf[c]
        need = self._n_parents[c]
        infl = self._inflight[self._pipe_of[c]]
        ready, ready_arrs = [], []
        for r, a in zip(reqs, arrs):
            cnt = buf.get(r.rid, 0) + 1
            if cnt < need:
                buf[r.rid] = cnt
                if cnt > 1:                  # absorbed into the one entry
                    infl[r.rid] -= 1
            else:                            # last parent: release to queue
                del buf[r.rid]
                infl[r.rid] -= 1             # entry + copy -> queued once
                ready.append(r)
                ready_arrs.append(a)
        if ready:
            self._handle("arrive", (c, ready, ready_arrs))

    def _dag_cancel(self, s: int, rids) -> None:
        """§4.5 drop propagation: requests dropped at stage ``s`` are dead
        everywhere — purge their queued sibling copies and join partials
        now; in-service copies are discarded at their done event."""
        p = self._pipe_of[s]
        infl = self._inflight[p]
        dead = self._dead[p]
        purge = set()
        for rid in rids:
            n = infl[rid] - 1
            if n:                            # copies still out there
                infl[rid] = n
                dead.add(rid)
                purge.add(rid)
            else:
                del infl[rid]
                if self._pool is not None:   # no other copies: retired now
                    self._pool.release(self._req_of[p].pop(rid))
        if not purge:
            return
        for j in self._stages_of[p]:
            if j == s:
                continue
            buf = self._join_buf[j]
            if buf:
                for rid in purge.intersection(buf):
                    del buf[rid]
                    self._dec_token(p, rid)
            q = self.queues[j]
            if len(q):
                removed = q.discard_rids(purge)
                if removed:
                    for r in removed:
                        self._dec_token(p, r.rid)
                    # queue shrank under a possibly armed timeout: re-arm
                    # from current state (and dispatch if past the deadline)
                    self._bump(j)
                    self._try_dispatch(j)

    def run_until(self, t_end: float) -> None:
        ev = self._events
        inj_t, inj_s, inj_r = self._inj_t, self._inj_s, self._inj_r
        if not self._inj_sorted:
            # compact the consumed prefix BEFORE sorting, or processed
            # arrivals would be shuffled back past the front pointer
            if self._inj_i:
                del inj_t[:self._inj_i]
                del inj_s[:self._inj_i]
                del inj_r[:self._inj_i]
                self._inj_i = 0
            # stable sort of the parallel columns by time (FIFO among
            # equal-time arrivals, like the old tuple sort keyed on t)
            order = sorted(range(len(inj_t)), key=inj_t.__getitem__)
            inj_t[:] = [inj_t[j] for j in order]
            inj_s[:] = [inj_s[j] for j in order]
            inj_r[:] = [inj_r[j] for j in order]
            self._inj_sorted = True
        i = self._inj_i
        n_inj = len(inj_t)
        pop = heapq.heappop
        handle = self._handle            # resolves subclass overrides once
        n_ev = 0
        while True:
            t_inj = inj_t[i] if i < n_inj else _INF
            if ev and ev[0][0] < t_inj:
                t = ev[0][0]
                if t > t_end:
                    break
                _, _, kind, payload = pop(ev)
                n_ev += 1
                if t > self.now:
                    self.now = t
                handle(kind, payload)
            elif t_inj <= t_end:
                # injection stream wins ties: matches the legacy ordering
                # where arrivals were heap-pushed before any derived event
                n_ev += 1
                if t_inj > self.now:
                    self.now = t_inj
                handle("arrive", (inj_s[i], (inj_r[i],), None))
                i += 1
            else:
                break
        self.events_processed += n_ev
        if i > 4096 and 2 * i >= n_inj:
            del inj_t[:i]
            del inj_s[:i]
            del inj_r[:i]
            i = 0
        self._inj_i = i
        if t_end > self.now:             # never rewind the event clock
            self.now = t_end


# ---------------------------------------------------------------------------
# structured-array event core
#
# The heapq core above pays per-event python on every arrival: a string-kind
# _handle dispatch, a per-request queue append, a Request object, and (under
# load) a _try_dispatch call that usually changes nothing.  At BENCH_scale
# (tens of thousands of arrivals per decision window) that per-arrival python
# IS the wall time — profiling shows the heap's C ops are <10% of it.  The
# structured core below keeps the exact event semantics but stores arrivals
# and stage queues as parallel numpy columns and delivers whole *runs* of
# arrivals (every injected arrival up to the next heap event) in vectorized
# bulk, computing analytically the first arrival that could change simulator
# state (fill a batch, arm a timeout, free a replica at a wake tie, or cross
# the §4.5 drop threshold) and handing only *that* one to the exact
# per-event path.  Every run it delivers is therefore event-for-event
# identical to the heapq core — the equivalence suite pins completed /
# dropped / latency streams / events_processed / reconfig_log bit-identical.
# ---------------------------------------------------------------------------

_EV_DONE, _EV_TIMEOUT, _EV_WAKE, _EV_APPLY = 0, 1, 2, 3
_KIND_IDS = {"done": _EV_DONE, "timeout": _EV_TIMEOUT,
             "wake": _EV_WAKE, "apply": _EV_APPLY}


class _EventColumns:
    """Pending derived events as parallel columns (time / kind / payload)
    indexed by slot, with a ``(time, seq, slot)`` heap over the slots and
    batch-pop of same-timestamp events.

    The heap tuples carry only scalars — comparisons never touch payload
    objects — and ``pop_batch`` drains every event sharing the head
    timestamp in one call (seq order, i.e. push order, preserved), so the
    run loop crosses the python/numpy boundary once per *timestamp*, not
    once per event."""

    __slots__ = ("kind", "pay", "_heap", "_free", "_seq")

    def __init__(self, cap: int = 256):
        self.kind = np.zeros(cap, dtype=np.int8)
        self.pay: List[object] = [None] * cap
        self._free = list(range(cap - 1, -1, -1))
        self._heap: List[Tuple[float, int, int]] = []
        self._seq = itertools.count()

    def __len__(self) -> int:
        return len(self._heap)

    def push(self, t: float, kind: int, payload) -> None:
        free = self._free
        if not free:
            cap = len(self.pay)
            grown = np.zeros(2 * cap, dtype=np.int8)
            grown[:cap] = self.kind
            self.kind = grown
            self.pay.extend([None] * cap)
            free.extend(range(2 * cap - 1, cap - 1, -1))
        slot = free.pop()
        self.kind[slot] = kind
        self.pay[slot] = payload
        heapq.heappush(self._heap, (t, next(self._seq), slot))

    def head_time(self) -> float:
        h = self._heap
        return h[0][0] if h else _INF

    def pop_batch(self) -> Tuple[float, List[int], List[object]]:
        """Pop every event sharing the head timestamp, in seq order."""
        h = self._heap
        pop = heapq.heappop
        t0, _, slot = pop(h)
        kinds = [int(self.kind[slot])]
        pays = [self.pay[slot]]
        self.pay[slot] = None
        self._free.append(slot)
        while h and h[0][0] == t0:
            _, _, slot = pop(h)
            kinds.append(int(self.kind[slot]))
            pays.append(self.pay[slot])
            self.pay[slot] = None
            self._free.append(slot)
        return t0, kinds, pays


class _ArrayStageQueue:
    """The struct core's stage queue: growable float64 parallel columns
    (absolute arrival time, stage-enter time) with a logical front pointer
    — no per-request python objects.  Batch pops, §4.5 drop scans and
    completion accounting all run as numpy slice ops."""

    __slots__ = ("_arr", "_enter", "_rid", "head", "n", "min_arr",
                 "sorted_fifo", "fifo_ok")

    def __init__(self, cap: int = 64, sorted_fifo: bool = False,
                 track_rid: bool = False):
        self._arr = np.empty(cap, dtype=np.float64)
        self._enter = np.empty(cap, dtype=np.float64)
        # DAG stages carry a third parallel column: the per-pipeline
        # request id (join matching + §4.5 drop propagation); chain stages
        # skip the column entirely
        self._rid = np.empty(cap, dtype=np.int64) if track_rid else None
        self.head = 0
        self.n = 0
        self.min_arr = _INF
        # first-stage queues normally receive ascending arrival times
        # (sorted injections + FIFO pops), so their drop scan is a prefix
        # search and min_arr is exact rather than a conservative bound.
        # ``fifo_ok`` tracks whether that holds *right now*: a stale
        # arrival injected after the clock passed it (a later run_until
        # delivering times older than what's already queued) breaks the
        # ascending order, degrading the queue to the masked scan until
        # it next empties.
        self.sorted_fifo = sorted_fifo
        self.fifo_ok = sorted_fifo

    def __len__(self) -> int:
        return self.n - self.head

    def _room(self, k: int) -> None:
        cap = self._arr.size
        if self.n + k <= cap:
            return
        live = self.n - self.head
        new_cap = max(2 * cap, live + k)
        na = np.empty(new_cap, dtype=np.float64)
        ne = np.empty(new_cap, dtype=np.float64)
        na[:live] = self._arr[self.head:self.n]
        ne[:live] = self._enter[self.head:self.n]
        if self._rid is not None:
            nr = np.empty(new_cap, dtype=np.int64)
            nr[:live] = self._rid[self.head:self.n]
            self._rid = nr
        self._arr = na
        self._enter = ne
        self.head = 0
        self.n = live

    def push_scalar(self, arrival: float, enter: float,
                    rid: int = -1) -> None:
        self._room(1)
        n = self.n
        if self.fifo_ok and n > self.head and arrival < self._arr[n - 1]:
            self.fifo_ok = False
        self._arr[n] = arrival
        self._enter[n] = enter
        if self._rid is not None:
            self._rid[n] = rid
        self.n = n + 1
        if arrival < self.min_arr:
            self.min_arr = arrival

    def push_bulk(self, arrivals: np.ndarray, enter,
                  rids: Optional[np.ndarray] = None,
                  ascending: bool = False) -> None:
        """Append a block of arrivals; ``enter`` may be a scalar (upstream
        handoff: the whole batch enters now) or a parallel array (bulk
        injection of stale + fresh arrivals).  A sorted_fifo queue only
        ever receives ascending blocks, so the min is the first element;
        handoff batches popped from a non-first stage can be out of order
        (completions overtake) and need the full scan — unless the caller
        proves the block ascending (``ascending=True``: a FIFO pop from a
        still-sorted queue, as the round core's chain loop tracks)."""
        k = arrivals.size
        self._room(k)
        n = self.n
        if self.fifo_ok and n > self.head and arrivals[0] < self._arr[n - 1]:
            self.fifo_ok = False
        self._arr[n:n + k] = arrivals
        self._enter[n:n + k] = enter
        if self._rid is not None:
            self._rid[n:n + k] = rids
        self.n = n + k
        m = float(arrivals[0]) if (self.sorted_fifo or ascending) \
            else float(arrivals.min())
        if m < self.min_arr:
            self.min_arr = m

    def head_enter(self) -> float:
        return self._enter[self.head]

    def head_arrival(self) -> float:
        return self._arr[self.head]

    def pop_batch(self, k: int) -> np.ndarray:
        h = self.head
        e = h + k
        arrs = self._arr[h:e].copy()
        self.head = e
        if e == self.n:
            self.min_arr = _INF
            self.head = self.n = 0
            self.fifo_ok = self.sorted_fifo
        elif e >= 4096 and 2 * e >= self.n:
            live = self.n - e
            self._arr[:live] = self._arr[e:self.n].copy()
            self._enter[:live] = self._enter[e:self.n].copy()
            if self._rid is not None:
                self._rid[:live] = self._rid[e:self.n].copy()
            self.head = 0
            self.n = live
        return arrs

    def pop_batch_rid(self, k: int) -> Tuple[np.ndarray, np.ndarray]:
        """``pop_batch`` plus the batch's rid column (DAG stages only)."""
        h = self.head
        rids = self._rid[h:h + k].copy()
        return self.pop_batch(k), rids

    def drop_expired_rid(self, now: float, threshold: float) -> np.ndarray:
        """``drop_expired`` returning the dropped requests' rids (DAG
        stages only) — same drop set, same tightened-``min_arr`` semantics
        on both the prefix and the masked path."""
        h = self.head
        rid = self._rid
        if self.fifo_ok:
            k = self.drop_expired(now, threshold)
            return rid[h:h + k].copy()   # prefix drop: head advanced by k
        t = self.n
        live = self._arr[h:t]
        keep = (now - live) <= threshold
        dropped = rid[h:t][~keep].copy()
        if dropped.size:
            # masked compaction must carry the rid column along
            rid[:t - h - dropped.size] = rid[h:t][keep]
        self.drop_expired(now, threshold)
        return dropped

    def discard_rids(self, rids) -> np.ndarray:
        """Remove (and return the rids of) every queued request whose rid
        is in the given set — §4.5 drop propagation purging a cancelled
        request's sibling-branch copies.  Removal preserves arrival order,
        so ``fifo_ok`` survives."""
        h, t = self.head, self.n
        live_rid = self._rid[h:t]
        sel = np.fromiter((int(r) in rids for r in live_rid),
                          dtype=bool, count=t - h)
        if not sel.any():
            return live_rid[:0]
        keep = ~sel
        removed = live_rid[sel].copy()
        kept_arr = self._arr[h:t][keep]
        k = kept_arr.size
        self._arr[:k] = kept_arr
        self._enter[:k] = self._enter[h:t][keep]
        self._rid[:k] = live_rid[keep]
        self.head = 0
        self.n = k
        if k:
            self.min_arr = float(kept_arr[0] if self.fifo_ok
                                 else kept_arr.min())
        else:
            self.min_arr = _INF
            self.fifo_ok = self.sorted_fifo
        return removed

    def drop_expired(self, now: float, threshold: float) -> int:
        """Drop every queued request older than ``threshold``; returns the
        count (the struct core keeps no per-request objects to return).
        Same tightened-bound semantics as ``_StageQueue.drop_expired`` on
        both paths: while ``fifo_ok`` holds, the prefix search lands on
        the identical drop set and the identical tightened ``min_arr``;
        a queue de-ordered by stale injections takes the masked scan."""
        h, t = self.head, self.n
        if h == t:
            self.min_arr = _INF
            self.fifo_ok = self.sorted_fifo
            return 0
        arr = self._arr
        if self.fifo_ok:
            # expired entries form a prefix of the ascending column; find
            # the cutoff by binary search, then settle the rounding
            # boundary with the reference's exact `now - a > thr` test
            j = h + int(arr[h:t].searchsorted(now - threshold, side="left"))
            while j < t and now - arr[j] > threshold:
                j += 1
            while j > h and not (now - arr[j - 1] > threshold):
                j -= 1
            if j == t:
                self.min_arr = _INF
                self.head = self.n = 0
                self.fifo_ok = self.sorted_fifo
            else:
                self.min_arr = float(arr[j])
                self.head = j
            return j - h
        live = arr[h:t]
        oldest = float(live.min())
        if now - oldest <= threshold:
            self.min_arr = oldest        # tightened bound, nothing expired
            return 0
        keep = (now - live) <= threshold
        kept = live[keep]
        kept_enter = self._enter[h:t][keep]
        k = kept.size
        self._arr[:k] = kept
        self._enter[:k] = kept_enter
        self.head = 0
        self.n = k
        if k:
            self.min_arr = float(kept.min())
        else:
            self.min_arr = _INF
            self.fifo_ok = self.sorted_fifo
        return (t - h) - k


class _StructCore:
    """Mixin implementing the structured-array event core (see the section
    comment above).  Combine with ``ClusterSimulator`` /
    ``PipelineSimulator`` via ``StructClusterSimulator`` /
    ``StructPipelineSimulator``.

    Limitations (by design — the hot path carries no request objects):
    per-request bookkeeping is skipped: ``record_timeline`` is rejected, an
    attached ``request_pool`` is ignored (nothing is acquired or released),
    and ``inject``-ed ``Request`` objects contribute only their arrival
    timestamp (``done``/``dropped_at`` are never written back).  All
    aggregate metrics — completed/dropped/arrived, latency streams,
    ``events_processed``, ``reconfig_log``, peaks — are bit-identical to
    the heapq core."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        if self.record_timeline:
            raise ValueError(
                "the struct event core keeps no per-request objects; "
                "use the heapq core for record_timeline")
        self._pool = None                # never acquire/release requests
        firsts = set(self._first)
        self.queues = [_ArrayStageQueue(sorted_fifo=s in firsts,
                                        track_rid=self._dag_route[s])
                       for s in range(self.n_stages)]
        self._evq = _EventColumns()
        # per-pipeline injected-arrival buffers (arrivals only ever target
        # a pipeline's first stage, so the global merge the heapq core
        # performs is deferred to the trigger heap below)
        P = self.n_pipelines
        self._pt = [np.empty(256, dtype=np.float64) for _ in range(P)]
        self._pi = [0] * P               # consumed-prefix cursor
        self._pn = [0] * P               # logical end
        self._p_unsorted = [False] * P
        # lazy-delivery trigger state: per pipeline, the buffer index of
        # the first arrival needing the exact per-event path (see
        # _first_trigger) and a version counter invalidating stale trigger
        # heap entries; _now0 is the clock at run_until entry (the floor
        # for stage-enter times of stale injections)
        self._next_k = [0] * P
        self._trig_ver = [0] * P
        self._trigh: List[Tuple[float, int, int]] = []
        self._now0 = 0.0
        # inj_pipe[s]: pipeline index when s is its pipeline's first
        # (injection-receiving) stage, else -1
        ip = [-1] * self.n_stages
        for p in range(P):
            ip[self._first[p]] = p
        self._inj_pipe = ip

    # -- event push (string kinds arrive from shared control-plane code) --
    def _push(self, t: float, kind: str, payload) -> None:
        self._evq.push(t, _KIND_IDS[kind], payload)

    # -- injection ----------------------------------------------------------
    def _p_room(self, p: int, k: int) -> None:
        buf = self._pt[p]
        cap = buf.size
        if self._pn[p] + k <= cap:
            return
        i, n = self._pi[p], self._pn[p]
        live = n - i
        nt = np.empty(max(2 * cap, live + k), dtype=np.float64)
        nt[:live] = buf[i:n]
        self._pt[p] = nt
        self._pi[p] = 0
        self._pn[p] = live

    def inject(self, req: Request, pipeline: int = 0) -> None:
        self.metrics_by_pipe[pipeline].arrived += 1
        self._p_room(pipeline, 1)
        t = float(req.arrival)
        n = self._pn[pipeline]
        if n and t < self._pt[pipeline][n - 1]:
            self._p_unsorted[pipeline] = True
        self._pt[pipeline][n] = t
        self._pn[pipeline] = n + 1

    def inject_arrivals(self, times: Sequence[float],
                        pipeline: int = 0) -> None:
        times = np.asarray(times, dtype=np.float64)
        k = times.size
        if k == 0:
            return
        self.metrics_by_pipe[pipeline].arrived += k
        self._p_room(pipeline, k)
        n = self._pn[pipeline]
        buf = self._pt[pipeline]
        if (n and times[0] < buf[n - 1]) or \
                (k > 1 and bool(np.any(times[1:] < times[:-1]))):
            self._p_unsorted[pipeline] = True
        buf[n:n + k] = times
        self._pn[pipeline] = n + k

    # -- the exact per-event paths (mirrors of the heapq core) -------------
    def _arrive_one(self, s: int, t: float) -> None:
        """Deliver one arrival through the exact heapq-core arrive path."""
        q = self.queues[s]
        if self._dag_route[s]:
            # DAG pipeline entry: stamp the per-pipeline request id and
            # open its in-flight token count (mirrors the heapq arrive)
            p = self._pipe_of[s]
            rid = self._rid_next[p]
            self._inflight[p][rid] = 1
            self._rid_next[p] = rid + 1
            q.push_scalar(t, self.now, rid)
        else:
            q.push_scalar(t, self.now)
        d = q.n - q.head
        if d > self.peak_queue_depth:
            self.peak_queue_depth = d
        if (d >= self._batch_of[s]
                or self._timeout_at[s] == _INF
                or self.now - q.min_arr > self._drop_thr_s[s]):
            self._try_dispatch(s)

    def _arrive_batch(self, s: int, arrs: np.ndarray) -> None:
        """Synchronous upstream handoff (the heapq core's push_many path)."""
        q = self.queues[s]
        q.push_bulk(arrs, self.now)
        d = q.n - q.head
        if d > self.peak_queue_depth:
            self.peak_queue_depth = d
        if (d >= self._batch_of[s]
                or self._timeout_at[s] == _INF
                or self.now - q.min_arr > self._drop_thr_s[s]):
            self._try_dispatch(s)

    # -- DAG routing on arrays (mirrors the heapq core's _done_dag /
    # _deliver_join / _dag_cancel, with rid columns instead of Request
    # objects; identical token accounting and delivery order) ------------
    def _arrive_batch_rid(self, s: int, arrs: np.ndarray,
                          rids: np.ndarray) -> None:
        """Synchronous upstream handoff carrying the rid column."""
        q = self.queues[s]
        q.push_bulk(arrs, self.now, rids)
        d = q.n - q.head
        if d > self.peak_queue_depth:
            self.peak_queue_depth = d
        if (d >= self._batch_of[s]
                or self._timeout_at[s] == _INF
                or self.now - q.min_arr > self._drop_thr_s[s]):
            self._try_dispatch(s)

    def _done_dag(self, s: int, arrs: np.ndarray,       # type: ignore[override]
                  rids: np.ndarray) -> None:
        p = self._pipe_of[s]
        infl = self._inflight[p]
        dead = self._dead[p]
        if dead:
            keep = np.fromiter((int(r) not in dead for r in rids),
                               dtype=bool, count=rids.size)
            for r in rids[~keep]:        # cancelled mid-service
                self._dec_token(p, int(r))
            arrs = arrs[keep]
            rids = rids[keep]
        if not arrs.size:
            return
        children = self._children[s]
        if not children:                 # sink: the request completes
            for r in rids:
                del infl[int(r)]
            m = self.metrics_by_pipe[p]
            m.completed += arrs.size
            m._lat.extend(self.now - arrs)
            return
        if len(children) > 1:            # fan-out: one token per copy
            extra = len(children) - 1
            for r in rids:
                infl[int(r)] += extra
        for c in children:
            if dead:
                # a drop during an earlier child's dispatch may have
                # cancelled requests this child still expects a copy of
                keep = np.fromiter((int(r) not in dead for r in rids),
                                   dtype=bool, count=rids.size)
                for r in rids[~keep]:
                    self._dec_token(p, int(r))
                live_a = arrs[keep]
                live_r = rids[keep]
                if not live_a.size:
                    continue
            else:
                live_a, live_r = arrs, rids
            if self._n_parents[c] > 1:
                self._deliver_join(c, live_a, live_r)
            else:
                self._arrive_batch_rid(c, live_a, live_r)

    def _deliver_join(self, c: int, arrs: np.ndarray,   # type: ignore[override]
                      rids: np.ndarray) -> None:
        buf = self._join_buf[c]
        need = self._n_parents[c]
        infl = self._inflight[self._pipe_of[c]]
        ready: List[int] = []
        for idx in range(rids.size):
            rid = int(rids[idx])
            cnt = buf.get(rid, 0) + 1
            if cnt < need:
                buf[rid] = cnt
                if cnt > 1:              # absorbed into the one entry
                    infl[rid] -= 1
            else:                        # last parent: release to queue
                del buf[rid]
                infl[rid] -= 1
                ready.append(idx)
        if ready:
            sel = np.array(ready)
            self._arrive_batch_rid(c, arrs[sel], rids[sel])

    def _dag_cancel(self, s: int, rids) -> None:        # type: ignore[override]
        p = self._pipe_of[s]
        infl = self._inflight[p]
        dead = self._dead[p]
        purge = set()
        for rid in rids:
            rid = int(rid)
            n = infl[rid] - 1
            if n:                        # copies still out there
                infl[rid] = n
                dead.add(rid)
                purge.add(rid)
            else:
                del infl[rid]
        if not purge:
            return
        for j in self._stages_of[p]:
            if j == s:
                continue
            buf = self._join_buf[j]
            if buf:
                for rid in purge.intersection(buf):
                    del buf[rid]
                    self._dec_token(p, rid)
            q = self.queues[j]
            if len(q):
                removed = q.discard_rids(purge)
                if removed.size:
                    for r in removed:
                        self._dec_token(p, int(r))
                    self._bump(j)
                    self._try_dispatch(j)

    def _try_dispatch(self, s: int) -> None:
        q = self.queues[s]
        now = self.now
        thr = self._drop_thr_s[s]
        dag = self._dag_route[s]
        if now - q.min_arr > thr:
            if dag:
                rids_dropped = q.drop_expired_rid(now, thr)
                if rids_dropped.size:
                    self.metrics_by_pipe[self._pipe_of[s]].dropped += \
                        rids_dropped.size
                    self._bump(s)
                    self._dag_cancel(s, rids_dropped)
            else:
                k_dropped = q.drop_expired(now, thr)
                if k_dropped:
                    self.metrics_by_pipe[self._pipe_of[s]].dropped += \
                        k_dropped
                    self._bump(s)
        nq = q.n - q.head
        if not nq:
            return
        batch_sz = self.configs[s].batch
        free = self.free_at[s]
        limit = now + _EPS
        tab = self._lat_tab[s]
        tab_n = len(tab)
        evq = self._evq
        gen = self._gen
        while nq:
            if nq < batch_sz:
                deadline = q.head_enter() + self._wait_bounds()[s]
                if now < deadline - _EPS:
                    self._schedule_timeout(s, deadline)
                    return
                k = nq
            else:
                k = batch_sz
            nf = len(free)
            if nf == 0:
                self._schedule_wake(s, q.head_arrival() + thr)
                return
            if nf > _NP_SCAN_MIN:
                arr = np.asarray(free)
                avail = (arr <= limit).nonzero()[0]
                n_avail = avail.size
                if n_avail == 0:
                    self._schedule_wake(s, float(arr.min()))
                    return
                rep = int(avail[self.rr[s] % n_avail])
            else:
                avail = [i for i, t in enumerate(free) if t <= limit]
                n_avail = len(avail)
                if n_avail == 0:
                    self._schedule_wake(s, min(free))
                    return
                rep = avail[self.rr[s] % n_avail]
            if dag:
                arrs, rids = q.pop_batch_rid(k)
            else:
                arrs = q.pop_batch(k)
            nq -= k
            self.rr[s] += 1
            done_t = now + (tab[k] if k < tab_n
                            else self._stage_latency(s, k))
            free[rep] = done_t
            self.in_service += k
            evq.push(done_t, _EV_DONE,
                     (s, arrs, rids) if dag else (s, arrs))
            gen[s] += 1                  # inlined _bump (lazy cancel)
            self._timeout_at[s] = _INF

    def _handle_ev(self, kind: int, payload) -> None:
        if kind == _EV_DONE:
            s = payload[0]
            if self._dag_route[s]:           # 3-tuple payload with rids
                _, arrs, rids = payload
                self.in_service -= arrs.size
                self._done_dag(s, arrs, rids)
            else:
                _, arrs = payload
                self.in_service -= arrs.size
                nxt = self._next[s]
                if nxt >= 0:
                    self._arrive_batch(nxt, arrs)
                else:
                    m = self.metrics_by_pipe[self._pipe_of[s]]
                    m.completed += arrs.size
                    m._lat.extend(self.now - arrs)   # vectorized per-batch
            q = self.queues[s]
            if q.n > q.head:
                self._try_dispatch(s)
        elif kind == _EV_TIMEOUT:
            s, gen = payload
            if self._timeout_at[s] <= self.now + _EPS:
                self._timeout_at[s] = _INF
            if gen == self._gen[s]:
                q = self.queues[s]
                if q.n > q.head:
                    self._try_dispatch(s)
        elif kind == _EV_WAKE:
            s = payload
            if self._wake_at[s] <= self.now + _EPS:
                self._wake_at[s] = _INF
            q = self.queues[s]
            if q.n > q.head:
                self._try_dispatch(s)
        else:                            # _EV_APPLY
            p, gen = payload
            if gen == self._pending_gen[p] and \
                    self._pending_cfg[p] is not None:
                cfg = self._pending_cfg[p]
                self._pending_cfg[p] = None
                self._apply_pipeline_config(p, cfg)

    # -- lazy bulk arrival delivery ----------------------------------------
    #
    # Injected arrivals only ever target a pipeline's first stage, and an
    # arrival that merely appends to its stage queue commutes with every
    # event touching *other* stages.  So instead of merging arrivals into
    # the global event order (the heapq core's loop), each pipeline's
    # buffer is delivered lazily: `_first_trigger` classifies the first
    # pending arrival that the heapq core would do anything for beyond a
    # queue append; those *triggers* are sequenced against the event heap
    # (a (time, pipeline, version) heap with lazy invalidation), while the
    # pure appends before them land as one slice op per stage — `_sync` —
    # only when an event or trigger actually touches that stage.
    def _first_trigger(self, s: int, buf: np.ndarray, i: int, n: int) -> int:
        """Absolute index in ``[i, n]`` of the first pending arrival in
        ``buf[i:n]`` (one injection stage's buffer, ascending) needing the
        exact per-event path — fill the forming batch (dispatch), find no
        live timeout (arm one), tie with the armed wake's replica-free
        time, or cross the §4.5 drop threshold ``min_arr + thr``; ``n`` if
        every pending arrival is a pure append."""
        q = self.queues[s]
        wake = self._wake_at[s]
        if wake != _INF:
            # no replica frees before the wake fires; arrivals only queue
            # (dispatch attempts are provable no-ops).  Near the wake
            # instant a replica may tie with an arrival, so route that
            # boundary through the exact path.
            k = i + int(buf[i:n].searchsorted(wake - 1e-9, side="left"))
        elif self._timeout_at[s] != _INF:
            # forming batch with a live timeout: appends are pure while
            # the queue stays strictly below the batch size
            k = i + self._batch_of[s] - 1 - (q.n - q.head)
            if k < i:
                k = i
            elif k > n:
                k = n
        else:
            k = i                        # next arrival arms/dispatches
        if k > i:
            # §4.5 drop trigger: the heapq core consults
            # now - min_arr > thr at each arrival, with now there equal to
            # max(run-entry clock, arrival time) and min_arr the
            # conservative bound over queued + pending arrivals
            m_eff = q.min_arr
            t0 = buf[i]
            if t0 < m_eff:
                m_eff = t0
            t_trig = m_eff + self._drop_thr_s[s]
            if self._now0 > t_trig:
                k = i
            elif buf[k - 1] > t_trig:    # only search when drops imminent
                kd = i + int(buf[i:n].searchsorted(t_trig, side="right"))
                if kd < k:
                    k = kd
        return k

    def _recompute_trigger(self, p: int) -> None:
        """Reclassify pipeline ``p``'s next trigger after anything touched
        its injection stage's state (dispatch, drop, timeout/wake marker,
        config apply) and push the fresh entry; the version bump
        invalidates every stale heap entry for ``p``."""
        ver = self._trig_ver[p] + 1
        self._trig_ver[p] = ver
        i, n = self._pi[p], self._pn[p]
        if i >= n:
            self._next_k[p] = n
            return
        buf = self._pt[p]
        k = self._first_trigger(self._first[p], buf, i, n)
        self._next_k[p] = k
        if k < n:
            heapq.heappush(self._trigh, (buf[k], p, ver))

    def _sync(self, p: int, tau: float) -> int:
        """Deliver pipeline ``p``'s pending pure-append arrivals with
        ``t <= tau`` as one queue-column slice op.  Appends never reach
        past ``_next_k`` (the next trigger), so no classification can be
        violated.  Returns the number delivered."""
        i = self._pi[p]
        lim_k = self._next_k[p]
        if i >= lim_k:
            return 0
        buf = self._pt[p]
        if buf[i] > tau:
            return 0
        j = i + int(buf[i:lim_k].searchsorted(tau, side="right"))
        vals = buf[i:j]
        now0 = self._now0
        # stage-enter is the arrival's own instant, except stale
        # (past-time) injections which enter at the run-entry clock
        enter = np.maximum(vals, now0) if now0 > vals[0] else vals
        q = self.queues[self._first[p]]
        if self._dag_pipe[p]:
            rid0 = self._rid_next[p]
            k = vals.size
            infl = self._inflight[p]
            for rid in range(rid0, rid0 + k):
                infl[rid] = 1
            self._rid_next[p] = rid0 + k
            q.push_bulk(vals, enter, np.arange(rid0, rid0 + k,
                                               dtype=np.int64))
        else:
            q.push_bulk(vals, enter)
        d = q.n - q.head
        if d > self.peak_queue_depth:
            self.peak_queue_depth = d
        self._pi[p] = j
        return j - i

    def run_until(self, t_end: float) -> None:
        P = self.n_pipelines
        for p in range(P):
            if self._p_unsorted[p]:
                self._pt[p][self._pi[p]:self._pn[p]].sort(kind="stable")
                self._p_unsorted[p] = False
        self._now0 = self.now
        trigh: List[Tuple[float, int, int]] = []
        self._trigh = trigh
        for p in range(P):
            self._recompute_trigger(p)
        evq = self._evq
        heap = evq._heap
        trig_ver = self._trig_ver
        first = self._first
        inj_pipe = self._inj_pipe
        handle = self._handle_ev
        n_ev = 0
        while True:
            while trigh and trigh[0][2] != trig_ver[trigh[0][1]]:
                heapq.heappop(trigh)
            t_trig = trigh[0][0] if trigh else _INF
            t_head = heap[0][0] if heap else _INF
            # arrivals win ties against events, exactly like the heapq core
            if t_trig <= t_head and t_trig <= t_end:
                t, p, _ = heapq.heappop(trigh)
                n_ev += self._sync(p, t) + 1
                tf = float(t)
                if tf > self.now:
                    self.now = tf
                self._pi[p] = self._next_k[p] + 1
                self._arrive_one(first[p], tf)
                self._recompute_trigger(p)
                continue
            if t_head <= t_end:
                t, kinds, pays = evq.pop_batch()
                if t > self.now:
                    self.now = t
                for kd, pay in zip(kinds, pays):
                    if kd == _EV_DONE or kd == _EV_TIMEOUT:
                        s = pay[0]
                    elif kd == _EV_WAKE:
                        s = pay
                    else:
                        s = first[pay[0]]
                    p = inj_pipe[s]
                    if p >= 0:
                        # the event touches an injection stage: its pending
                        # appends up to t must land first, and its trigger
                        # classification is stale afterwards
                        n_ev += self._sync(p, t)
                        handle(kd, pay)
                        self._recompute_trigger(p)
                    else:
                        handle(kd, pay)
                n_ev += len(kinds)
                continue
            break
        for p in range(P):
            n_ev += self._sync(p, t_end)
            i = self._pi[p]
            if i > 4096 and 2 * i >= self._pn[p]:
                n = self._pn[p]
                live = n - i
                self._pt[p][:live] = self._pt[p][i:n].copy()
                self._pi[p] = 0
                self._pn[p] = live
        self.events_processed += n_ev
        if t_end > self.now:             # never rewind the event clock
            self.now = t_end


# ---------------------------------------------------------------------------
# service-round event core
#
# The struct core above still sequences every derived event (done / timeout /
# wake / apply) through ONE global heap and re-classifies a pipeline's
# arrival trigger on every event that touches its injection stage — at
# BENCH_scale that global interleaving is pure overhead, because pipelines
# sharing a cluster do not interact between control-plane actions: queues,
# replica fleets, generation counters, metrics and DAG state are all
# per-pipeline, and the only cross-pipeline couplings are the replica
# ledger (consulted at reconfigure time, outside run_until) and the
# ``peak_serving_cores`` witness (touched only by §5.3 apply events).
#
# The round core exploits that independence: each pipeline keeps its own
# event columns, and ``run_until`` retires one pipeline's *entire* event
# frontier — service starts, completions, timeout fires, wake scans, bulk
# arrival appends — in one round before moving to the next, instead of
# interleaving single events across pipelines.  Within a pipeline the event
# order is exactly the struct core's (same (t, seq) discipline, same
# tie-breaks), so every per-pipeline stream is bit-identical by
# construction; the order-coupled remainder — the relative order of §5.3
# apply events across pipelines, which is what the serving-peak witness
# observes — is restored exactly by logging each apply's ledger settlement
# and replaying the log in global (t, seq) order afterwards.  Chains
# additionally run a fully inlined per-pipeline loop (locals instead of
# attribute chases, dispatch/arrive/sync inlined); DAG pipelines and any
# other order-coupled path (joins, drop propagation, deferred applies)
# take the exact scalar struct path per event, still inside their own
# round.  The equivalence suites pin completed / dropped / latency
# streams / events_processed / reconfig_log / peaks bit-identical to BOTH
# existing cores.
# ---------------------------------------------------------------------------


class _RoutedEventQueue:
    """``_EventColumns``-compatible push target that files each derived
    event into the owning pipeline's private round heap.  Control-plane
    code (reconfigure, set_lam_est, deferred applies) pushes through the
    shared ``_push``/``_try_dispatch`` paths without knowing which core
    runs underneath; the shared ``seq`` counter keeps same-timestamp
    events in push order exactly like ``_EventColumns``."""

    __slots__ = ("_sim",)

    def __init__(self, sim):
        self._sim = sim

    def push(self, t: float, kind: int, payload) -> None:
        sim = self._sim
        if kind == _EV_WAKE:
            p = sim._pipe_of[payload]
        elif kind == _EV_APPLY:
            p = payload[0]
        else:                            # done / timeout payloads lead with s
            p = sim._pipe_of[payload[0]]
        heapq.heappush(sim._pq[p], (t, next(sim._rseq), kind, payload))


class _RoundCore(_StructCore):
    """Mixin implementing the service-round event core (see the section
    comment above).  Same external contract and limitations as
    ``_StructCore`` — aggregate metrics bit-identical to both other cores,
    no per-request objects — at a higher events/sec: pipelines are retired
    in independent rounds instead of through one globally interleaved
    heap."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        # one event heap per pipeline, ordered by (t, seq) with a shared
        # seq counter — the struct core's global order restricted to the
        # pipeline, which is all any per-pipeline state can observe
        self._pq: List[List[Tuple[float, int, int, object]]] = [
            [] for _ in range(self.n_pipelines)]
        self._rseq = itertools.count()
        self._evq = _RoutedEventQueue(self)
        # §5.3 apply events are the one cross-pipeline coupling inside a
        # run (the serving-peak witness sums every pipeline's serving cost
        # at each settlement): while a round runs, ledger settlements are
        # logged instead of sampled, then replayed in global (t, seq)
        # order against the run-entry snapshot
        self._defer_peak = False
        self._peak_log: List[tuple] = []
        self._apply_seq = 0
        self._apply_p = 0

    def _note_serving_peak(self) -> None:
        if self._defer_peak:
            p = self._apply_p
            vec = None if self._serving_vec is None else self._serving_vec[p]
            self._peak_log.append((self.now, self._apply_seq, p,
                                   self._serving_cost[p], vec))
            return
        super()._note_serving_peak()

    def _replay_serving_peaks(self, snap: List[float],
                              vsnap: Optional[List[tuple]]) -> None:
        """Replay the round's deferred ledger settlements in global
        (t, seq) order against the run-entry serving snapshot — the exact
        sequence of ``sum(_serving_cost)`` values the struct core samples
        at each apply event."""
        log = self._peak_log
        log.sort(key=lambda e: (e[0], e[1]))
        peak = self.peak_serving_cores
        for _t, _seq, p, cost, vec in log:
            snap[p] = cost
            total = sum(snap)
            if total > peak:
                peak = total
            if vec is not None:
                vsnap[p] = vec
                self.peak_serving_by_class = tuple(
                    max(pk, sum(v[c] for v in vsnap))
                    for c, pk in enumerate(self.peak_serving_by_class))
        self.peak_serving_cores = peak
        log.clear()

    def run_until(self, t_end: float) -> None:
        P = self.n_pipelines
        for p in range(P):
            if self._p_unsorted[p]:
                self._pt[p][self._pi[p]:self._pn[p]].sort(kind="stable")
                self._p_unsorted[p] = False
        now0 = self.now
        self._now0 = now0
        snap = list(self._serving_cost)
        vsnap = None if self._serving_vec is None else list(self._serving_vec)
        self._defer_peak = True
        n_ev = 0
        try:
            for p in range(P):
                self.now = now0
                if self._dag_pipe[p]:
                    n_ev += self._run_pipe_generic(p, t_end)
                else:
                    n_ev += self._run_pipe_chain(p, t_end)
        finally:
            self._defer_peak = False
        if self._peak_log:
            self._replay_serving_peaks(snap, vsnap)
        self.events_processed += n_ev
        self.now = t_end if t_end > now0 else now0

    def _compact_buf(self, p: int) -> None:
        i = self._pi[p]
        if i > 4096 and 2 * i >= self._pn[p]:
            n = self._pn[p]
            live = n - i
            self._pt[p][:live] = self._pt[p][i:n].copy()
            self._pi[p] = 0
            self._pn[p] = live

    def _run_pipe_generic(self, p: int, t_end: float) -> int:
        """One pipeline's round through the exact scalar struct paths —
        the order-coupled fallback (DAG joins / drop propagation, and any
        topology the inlined chain loop doesn't cover)."""
        pq = self._pq[p]
        first = self._first[p]
        buf = self._pt[p]
        handle = self._handle_ev
        pop = heapq.heappop
        i, n = self._pi[p], self._pn[p]
        k = self._first_trigger(first, buf, i, n) if i < n else n
        self._next_k[p] = k
        n_ev = 0
        while True:
            t_trig = buf[k] if k < n else _INF
            t_head = pq[0][0] if pq else _INF
            # arrivals win ties against events, exactly like both cores
            if t_trig <= t_head and t_trig <= t_end:
                tf = float(t_trig)
                n_ev += self._sync(p, tf) + 1
                if tf > self.now:
                    self.now = tf
                self._pi[p] = k + 1
                self._arrive_one(first, tf)
                i = self._pi[p]
                k = self._first_trigger(first, buf, i, n) if i < n else n
                self._next_k[p] = k
                continue
            if t_head > t_end:
                break
            if t_head > self.now:
                self.now = t_head
            t0, sq, kind, pay = pop(pq)
            batch = [(sq, kind, pay)]
            while pq and pq[0][0] == t0:
                _t, sq2, kd2, py2 = pop(pq)
                batch.append((sq2, kd2, py2))
            for sq, kind, pay in batch:
                if kind == _EV_DONE or kind == _EV_TIMEOUT:
                    s = pay[0]
                elif kind == _EV_WAKE:
                    s = pay
                else:
                    s = first            # apply: settles this pipeline
                if s == first:
                    n_ev += self._sync(p, t0)
                    if kind == _EV_APPLY:
                        self._apply_seq = sq
                        self._apply_p = p
                    handle(kind, pay)
                    i = self._pi[p]
                    k = self._first_trigger(first, buf, i, n) \
                        if i < n else n
                    self._next_k[p] = k
                else:
                    handle(kind, pay)
            n_ev += len(batch)
        n_ev += self._sync(p, t_end)
        self._compact_buf(p)
        return n_ev

    def _run_pipe_chain(self, p: int, t_end: float) -> int:
        """One chain pipeline's round, fully inlined: the struct core's
        _first_trigger / _sync / _arrive_one / _handle_ev / _try_dispatch
        bodies with per-stage state in locals — instruction-for-
        instruction the same state transitions (the equivalence and golden
        suites pin it), minus the per-event attribute chases and method
        dispatch."""
        pq = self._pq[p]
        base = self._first[p]
        buf = self._pt[p]
        queues = self.queues
        q0 = queues[base]
        gen = self._gen
        timeout_at = self._timeout_at
        wake_at = self._wake_at
        free_at = self.free_at
        rr = self.rr
        nxt = self._next
        thr_g = self._drop_thr_s
        lat_tab = self._lat_tab
        batch_of = self._batch_of
        m = self.metrics_by_pipe[p]
        lat_buf = m._lat
        rseq = self._rseq
        push = heapq.heappush
        pop = heapq.heappop
        now0 = self._now0
        thr0 = thr_g[base]
        insvc = 0
        peak_qd = self.peak_queue_depth
        n_ev = 0

        def dispatch(s: int, now: float) -> None:
            # struct _try_dispatch, chain path, with hot state in closure
            nonlocal insvc
            q = queues[s]
            thr = thr_g[s]
            if now - q.min_arr > thr:
                kd = q.drop_expired(now, thr)
                if kd:
                    m.dropped += kd
                    gen[s] += 1
                    timeout_at[s] = _INF
            nq = q.n - q.head
            if not nq:
                return
            batch_sz = batch_of[s]
            free = free_at[s]
            limit = now + _EPS
            tab = lat_tab[s]
            tab_n = len(tab)
            while nq:
                if nq < batch_sz:
                    wb = self._wb
                    if wb is None:
                        wb = self._wait_bounds()
                    deadline = float(q._enter[q.head] + wb[s])
                    if now < deadline - _EPS:
                        if deadline < timeout_at[s] - _EPS:   # _schedule_timeout
                            timeout_at[s] = deadline
                            push(pq, (deadline, next(rseq), _EV_TIMEOUT,
                                      (s, gen[s])))
                        return
                    k = nq
                else:
                    k = batch_sz
                # armed-wake short-circuit: the wake marker was set to the
                # fleet's min free time, and free times only move when a
                # service starts, which needs a free replica — so strictly
                # before the marker no replica can be available and the
                # rearm attempt is provably the no-op the struct core
                # recomputes from scratch
                w = wake_at[s]
                if w != _INF and now < w - _EPS and free:
                    return
                nf = len(free)
                if nf == 0:
                    t = float(q._arr[q.head] + thr)
                    if t <= now + _EPS:                       # _schedule_wake
                        t = now + 1e-9
                    if t < wake_at[s] - _EPS:
                        wake_at[s] = t
                        push(pq, (t, next(rseq), _EV_WAKE, s))
                    return
                if nf > _NP_SCAN_MIN:
                    arr = np.asarray(free)
                    avail = (arr <= limit).nonzero()[0]
                    n_avail = avail.size
                    if n_avail == 0:
                        t = float(arr.min())
                        if t <= now + _EPS:
                            t = now + 1e-9
                        if t < wake_at[s] - _EPS:
                            wake_at[s] = t
                            push(pq, (t, next(rseq), _EV_WAKE, s))
                        return
                    rep = int(avail[rr[s] % n_avail])
                else:
                    avail = [j for j, tv in enumerate(free) if tv <= limit]
                    n_avail = len(avail)
                    if n_avail == 0:
                        t = float(min(free))
                        if t <= now + _EPS:
                            t = now + 1e-9
                        if t < wake_at[s] - _EPS:
                            wake_at[s] = t
                            push(pq, (t, next(rseq), _EV_WAKE, s))
                        return
                    rep = avail[rr[s] % n_avail]
                asc = q.fifo_ok
                arrs = q.pop_batch(k)
                nq -= k
                rr[s] += 1
                done_t = now + (tab[k] if k < tab_n
                                else self._stage_latency(s, k))
                free[rep] = done_t
                insvc += k
                push(pq, (done_t, next(rseq), _EV_DONE, (s, arrs, asc)))
                gen[s] += 1              # inlined _bump (lazy cancel)
                timeout_at[s] = _INF

        # round-scoped classification caches: buf[i0:n] is immutable and
        # ascending for the whole round, so the absolute insertion point of
        # a given wake time / drop trigger is computed once per distinct
        # value instead of once per event (struct re-searches every time)
        i0 = self._pi[p]
        pi = i0
        n = self._pn[p]
        cw_val = cd_val = None
        cw_pos = cd_pos = 0

        def classify(i: int) -> int:
            # struct _first_trigger for the injection stage
            nonlocal cw_val, cw_pos, cd_val, cd_pos
            w = wake_at[base]
            if w != _INF:
                if w != cw_val:
                    cw_val = w
                    cw_pos = i0 + int(
                        buf[i0:n].searchsorted(w - 1e-9, side="left"))
                k = cw_pos if cw_pos > i else i
            elif timeout_at[base] != _INF:
                k = i + batch_of[base] - 1 - (q0.n - q0.head)
                if k < i:
                    k = i
                elif k > n:
                    k = n
            else:
                k = i
            if k > i:
                m_eff = q0.min_arr
                t0v = buf.item(i)
                if t0v < m_eff:
                    m_eff = t0v
                t_trig = m_eff + thr0
                if now0 > t_trig:
                    k = i
                elif buf.item(k - 1) > t_trig:
                    if t_trig != cd_val:
                        cd_val = t_trig
                        cd_pos = i0 + int(
                            buf[i0:n].searchsorted(t_trig, side="right"))
                    kd = cd_pos if cd_pos > i else i
                    if kd < k:
                        k = kd
            return k

        def deliver(j: int) -> int:
            # struct _sync tail: hand buf[pi:j] to the injection queue
            nonlocal pi, peak_qd
            cnt = j - pi
            vals = buf[pi:j]
            enter = np.maximum(vals, now0) if now0 > vals[0] else vals
            q0.push_bulk(vals, enter)
            d = q0.n - q0.head
            if d > peak_qd:
                peak_qd = d
            pi = j
            return cnt

        k = classify(pi) if pi < n else n
        now = self.now
        while True:
            t_trig = buf.item(k) if k < n else _INF
            t_head = pq[0][0] if pq else _INF
            if t_trig <= t_head and t_trig <= t_end:
                tf = t_trig
                # everything in [pi, k) is <= buf[k] by sort order, so the
                # pre-trigger sync is one unconditional block delivery
                if pi < k:
                    n_ev += deliver(k)
                n_ev += 1
                if tf > now:
                    now = tf
                pi = k + 1
                q0.push_scalar(tf, now)                       # _arrive_one
                d = q0.n - q0.head
                if d > peak_qd:
                    peak_qd = d
                if (d >= batch_of[base] or timeout_at[base] == _INF
                        or now - q0.min_arr > thr0):
                    dispatch(base, now)
                k = classify(pi) if pi < n else n
                continue
            if t_head > t_end:
                break
            if t_head > now:
                now = t_head
            t0, sq, kind, pay = pop(pq)
            batch = [(sq, kind, pay)]
            while pq and pq[0][0] == t0:
                _t, sq2, kd2, py2 = pop(pq)
                batch.append((sq2, kd2, py2))
            for sq, kind, pay in batch:
                if kind == _EV_DONE:
                    s = pay[0]
                    if s == base:
                        if pi < k and buf[pi] <= t0:          # _sync
                            j = pi + int(
                                buf[pi:k].searchsorted(t0, side="right"))
                            n_ev += deliver(j)
                    arrs = pay[1]
                    ksz = arrs.size
                    insvc -= ksz
                    nx = nxt[s]
                    if nx >= 0:
                        q = queues[nx]                        # _arrive_batch
                        q.push_bulk(arrs, now, None,
                                    len(pay) == 3 and pay[2])
                        d = q.n - q.head
                        if d > peak_qd:
                            peak_qd = d
                        if (d >= batch_of[nx] or timeout_at[nx] == _INF
                                or now - q.min_arr > thr_g[nx]):
                            dispatch(nx, now)
                    else:
                        m.completed += ksz
                        lat_buf.extend(now - arrs)
                    q = queues[s]
                    if q.n > q.head:
                        dispatch(s, now)
                    if s == base:
                        k = classify(pi) if pi < n else n
                elif kind == _EV_TIMEOUT:
                    s, g = pay
                    if s == base:
                        if pi < k and buf[pi] <= t0:
                            j = pi + int(
                                buf[pi:k].searchsorted(t0, side="right"))
                            n_ev += deliver(j)
                    if timeout_at[s] <= now + _EPS:
                        timeout_at[s] = _INF
                    if g == gen[s]:
                        q = queues[s]
                        if q.n > q.head:
                            dispatch(s, now)
                    if s == base:
                        k = classify(pi) if pi < n else n
                elif kind == _EV_WAKE:
                    s = pay
                    if s == base:
                        if pi < k and buf[pi] <= t0:
                            j = pi + int(
                                buf[pi:k].searchsorted(t0, side="right"))
                            n_ev += deliver(j)
                    if wake_at[s] <= now + _EPS:
                        wake_at[s] = _INF
                    q = queues[s]
                    if q.n > q.head:
                        dispatch(s, now)
                    if s == base:
                        k = classify(pi) if pi < n else n
                else:                    # _EV_APPLY: order-coupled, exact path
                    if pi < k and buf[pi] <= t0:
                        j = pi + int(
                            buf[pi:k].searchsorted(t0, side="right"))
                        n_ev += deliver(j)
                    self.now = now
                    self._pi[p] = pi
                    self._apply_seq = sq
                    self._apply_p = p
                    self._handle_ev(kind, pay)
                    k = classify(pi) if pi < n else n
            n_ev += len(batch)
        if pi < n and buf[pi] <= t_end:                       # final _sync
            lim = k if k < n else n
            if pi < lim:
                j = pi + int(buf[pi:lim].searchsorted(t_end, side="right"))
                if j > pi:
                    n_ev += deliver(j)
        self.now = now
        self._pi[p] = pi
        self._next_k[p] = k
        self.in_service += insvc
        if peak_qd > self.peak_queue_depth:
            self.peak_queue_depth = peak_qd
        self._compact_buf(p)
        return n_ev


class PipelineSimulator(ClusterSimulator):
    """The N=1 special case: one pipeline, unbounded core budget, the
    original single-pipeline API.  Shares every event-machinery code path
    with ``ClusterSimulator`` — cluster equivalence at N=1 is structural,
    and the equivalence tests pin it."""

    def __init__(self, pipe: PipelineModel, config: PipelineConfig, **kw):
        super().__init__(single(pipe), ClusterConfig((config,)), **kw)
        self.pipe = pipe

    @property
    def metrics(self) -> SimMetrics:
        return self.metrics_by_pipe[0]

    @property
    def sla_p(self) -> float:
        return self.sla_of[0]

    @property
    def lam_est(self) -> float:
        return self._lam_of[0]

    @lam_est.setter
    def lam_est(self, v: float) -> None:
        self.set_lam_est(0, v)

    @property
    def current_config(self) -> PipelineConfig:
        """The configuration the simulator is committed to (the pending
        transition target while an adaptation window is in flight; see
        ``pipeline_config`` vs ``serving_config``)."""
        return self.pipeline_config(0)

    def reconfigure(self, config: PipelineConfig) -> None:  # type: ignore[override]
        self.reconfigure_pipeline(0, config)


class StructClusterSimulator(_StructCore, ClusterSimulator):
    """``ClusterSimulator`` on the structured-array event core."""


class StructPipelineSimulator(_StructCore, PipelineSimulator):
    """``PipelineSimulator`` on the structured-array event core."""


class RoundClusterSimulator(_RoundCore, ClusterSimulator):
    """``ClusterSimulator`` on the service-round event core."""


class RoundPipelineSimulator(_RoundCore, PipelineSimulator):
    """``PipelineSimulator`` on the service-round event core."""


EVENT_CORES = ("heap", "struct", "round")


def make_cluster_simulator(cluster, config, event_core: str = "heap", **kw):
    """Build a cluster simulator on the chosen event core.

    ``"heap"`` is the per-event reference core (full per-request
    bookkeeping: timelines, pools, injected-object writeback); ``"struct"``
    is the structured-array bulk core — bit-identical aggregate results,
    several times the throughput at production scale; ``"round"`` is the
    service-round core — bit-identical to both, retiring each pipeline's
    event frontier in independent rounds for another multiple on top (see
    ``benchmarks/bench_scale.py``)."""
    if event_core == "heap":
        return ClusterSimulator(cluster, config, **kw)
    if event_core == "struct":
        return StructClusterSimulator(cluster, config, **kw)
    if event_core == "round":
        return RoundClusterSimulator(cluster, config, **kw)
    raise ValueError(f"unknown event core {event_core!r}; "
                     f"expected one of {EVENT_CORES}")
