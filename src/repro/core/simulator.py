"""Discrete-event simulator of a multi-stage inference pipeline (paper §3:
"a discrete event simulator uses these profiling data to estimate the
end-to-end latency and throughput of the pipeline based on the number of
replicas, model variants, and batch sizes at each stage").

Per stage: one central queue (batch formation) feeding `n_s` replicas
round-robin; service time of a batch of size k under variant m is the
profiled quadratic l_m(k).  Implements the §4.5 dropping policy: requests
whose age exceeds drop_factor x SLA_P are dropped at batch formation.
Reconfiguration (variant/batch/replicas) takes effect immediately at the
adaptation boundary; in-flight batches finish under the old service time.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.pipeline import PipelineConfig, PipelineModel, StageConfig
from repro.serving.request import Request


@dataclasses.dataclass
class SimMetrics:
    latencies: List[float] = dataclasses.field(default_factory=list)
    completed: int = 0
    dropped: int = 0
    arrived: int = 0

    def sla_violations(self, sla: float) -> float:
        """Fraction of arrived requests violating the SLA (drops count)."""
        if self.arrived == 0:
            return 0.0
        late = sum(1 for l in self.latencies if l > sla)
        return (late + self.dropped) / self.arrived


class PipelineSimulator:
    def __init__(self, pipe: PipelineModel, config: PipelineConfig,
                 drop_factor: float = 2.0, max_wait: float = 0.5,
                 seed: int = 0, variant_switch_delay: float = 0.0,
                 scale_up_delay: float = 0.0):
        """``variant_switch_delay``: cold-start of a stage whose model
        variant changed (container pull + model load; the paper reports an
        ~8 s adaptation process and mitigates pull time with MinIO).
        ``scale_up_delay``: startup of additionally provisioned replicas."""
        self.pipe = pipe
        self.n_stages = len(pipe.stages)
        self.configs: List[StageConfig] = list(config.stages)
        self.drop_factor = drop_factor
        self.max_wait = max_wait
        self.variant_switch_delay = variant_switch_delay
        self.scale_up_delay = scale_up_delay
        self.queues: List[List[Request]] = [[] for _ in range(self.n_stages)]
        self.free_at: List[List[float]] = [
            [0.0] * sc.replicas for sc in self.configs]
        self.rr: List[int] = [0] * self.n_stages
        self.now = 0.0
        self.metrics = SimMetrics()
        self._events: List[Tuple[float, int, str, object]] = []
        self._seq = itertools.count()
        self.lam_est = 10.0

    # -- control plane --------------------------------------------------
    def reconfigure(self, config: PipelineConfig) -> None:
        for s, sc in enumerate(config.stages):
            old = self.free_at[s]
            n = sc.replicas
            switched = sc.variant != self.configs[s].variant
            if switched and self.variant_switch_delay > 0:
                # cold start: every replica of the stage reloads the model
                ready = self.now + self.variant_switch_delay
                old[:] = [max(t, ready) for t in old]
            if n >= len(old):
                start = self.now + (self.variant_switch_delay if switched
                                    else self.scale_up_delay)
                old.extend([start] * (n - len(old)))
            else:
                # keep the soonest-free replicas
                old.sort()
                del old[n:]
            self.configs[s] = sc

    # -- event machinery --------------------------------------------------
    def _push(self, t: float, kind: str, payload) -> None:
        heapq.heappush(self._events, (t, next(self._seq), kind, payload))

    def inject(self, req: Request) -> None:
        self.metrics.arrived += 1
        self._push(req.arrival, "arrive", (0, req))

    def _stage_latency(self, s: int, k: int) -> float:
        sc = self.configs[s]
        v = self.pipe.stages[s].variant(sc.variant)
        return float(v.latency(max(k, 1)))

    def _try_dispatch(self, s: int) -> None:
        q = self.queues[s]
        sc = self.configs[s]
        sla_p = self.pipe.sla
        # §4.5 drop policy
        kept = []
        for r in q:
            if (self.now - r.arrival) > self.drop_factor * sla_p:
                r.dropped_at = s
                r.done = self.now
                self.metrics.dropped += 1
            else:
                kept.append(r)
        q[:] = kept
        while q:
            # a replica must be free
            free_idx = [i for i, t in enumerate(self.free_at[s])
                        if t <= self.now + 1e-12]
            if not free_idx:
                return
            full = len(q) >= sc.batch
            waited = self.now - q[0].stage_enter.get(s, q[0].arrival)
            timeout = waited >= self._wait_bound(sc.batch)
            if not (full or timeout):
                return
            k = min(sc.batch, len(q))
            batch, q[:] = q[:k], q[k:]
            rep = free_idx[self.rr[s] % len(free_idx)]
            self.rr[s] += 1
            lat = self._stage_latency(s, k)
            done_t = self.now + lat
            self.free_at[s][rep] = done_t
            self._push(done_t, "done", (s, batch))

    def _wait_bound(self, batch: int) -> float:
        """Batch-formation timeout ~ worst-case queue delay (Eq. 7)."""
        return min(self.max_wait, (batch - 1) / max(self.lam_est, 1e-6)) \
            if batch > 1 else 0.0

    def _handle(self, kind: str, payload) -> None:
        if kind == "arrive":
            s, req = payload
            req.stage_enter[s] = self.now
            self.queues[s].append(req)
            self._try_dispatch(s)
        elif kind == "done":
            s, batch = payload
            for r in batch:
                r.stage_exit[s] = self.now
                if s + 1 < self.n_stages:
                    self._push(self.now, "arrive", (s + 1, r))
                else:
                    r.done = self.now
                    self.metrics.completed += 1
                    self.metrics.latencies.append(r.latency)
            self._try_dispatch(s)
        elif kind == "tick":
            s = payload
            self._try_dispatch(s)

    def run_until(self, t_end: float, tick: float = 0.05) -> None:
        # periodic dispatch ticks let partially filled batches time out
        t = self.now
        while t < t_end:
            t += tick
            for s in range(self.n_stages):
                self._push(t, "tick", s)
        while self._events and self._events[0][0] <= t_end:
            t, _, kind, payload = heapq.heappop(self._events)
            self.now = max(self.now, t)
            self._handle(kind, payload)
        self.now = t_end
