"""Event-driven simulator of a multi-stage inference pipeline (paper §3:
"a discrete event simulator uses these profiling data to estimate the
end-to-end latency and throughput of the pipeline based on the number of
replicas, model variants, and batch sizes at each stage").

Per stage: one central queue (batch formation) feeding `n_s` replicas
round-robin; service time of a batch of size k under variant m is the
profiled quadratic l_m(k).  Implements the §4.5 dropping policy: requests
whose age exceeds drop_factor x SLA_P are dropped at batch formation.
Reconfiguration (variant/batch/replicas) takes effect immediately at the
adaptation boundary; in-flight batches finish under the old service time.

The core is purely event-driven — there is no periodic "tick".  A
partially filled batch arms exactly one ``timeout`` event at
``head_enter + wait_bound`` (Eq. 7 via ``core.queueing.wait_bound``); the
event carries a per-stage generation counter so that when the batch
dispatches early (filled up, or flushed by an upstream completion) the
stale timeout is ignored on pop instead of being searched for and removed
from the heap.  A dispatch blocked on busy/cold-starting replicas arms a
``wake`` event at the soonest replica-free time.  Per-dispatch drop scans
and latency accumulation run vectorized over numpy buffers that parallel
the per-stage queues.
"""
from __future__ import annotations

import heapq
import itertools
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.pipeline import PipelineConfig, PipelineModel, StageConfig
from repro.core.queueing import wait_bound
from repro.serving.request import Request

_EPS = 1e-12
_INF = float("inf")


class _FloatBuf:
    """Growable float64 buffer (amortized O(1) append, vectorized extend)."""

    __slots__ = ("_data", "_n")

    def __init__(self, cap: int = 256):
        self._data = np.empty(cap, dtype=np.float64)
        self._n = 0

    def __len__(self) -> int:
        return self._n

    def _grow_to(self, need: int) -> None:
        cap = len(self._data)
        if need > cap:
            new = np.empty(max(need, 2 * cap), dtype=np.float64)
            new[:self._n] = self._data[:self._n]
            self._data = new

    def append(self, x: float) -> None:
        self._grow_to(self._n + 1)
        self._data[self._n] = x
        self._n += 1

    def extend(self, xs: np.ndarray) -> None:
        k = len(xs)
        self._grow_to(self._n + k)
        self._data[self._n:self._n + k] = xs
        self._n += k

    def view(self) -> np.ndarray:
        return self._data[:self._n]


class SimMetrics:
    """Aggregate counters; latencies live in a growable float64 buffer so
    per-batch completion extends an array instead of appending Python
    floats one by one."""

    __slots__ = ("_lat", "completed", "dropped", "arrived")

    def __init__(self):
        self._lat = _FloatBuf()
        self.completed = 0
        self.dropped = 0
        self.arrived = 0

    @property
    def latencies(self) -> np.ndarray:
        """Completed-request latencies as a float64 array view."""
        return self._lat.view()

    def sla_violations(self, sla: float) -> float:
        """Fraction of arrived requests violating the SLA (drops count)."""
        if self.arrived == 0:
            return 0.0
        late = int(np.count_nonzero(self._lat.view() > sla))
        return (late + self.dropped) / self.arrived


class _StageQueue:
    """FIFO of requests with parallel columns (absolute arrival time,
    stage-enter time).  Columns are plain lists — batches are small, so
    per-event python appends/slices beat numpy's per-op overhead — and are
    lifted into an ndarray only when a drop scan actually runs, which the
    ``min_arr`` guard makes rare.  ``head`` is a logical front pointer;
    storage compacts lazily."""

    __slots__ = ("reqs", "_arr", "_enter", "head", "min_arr")

    def __init__(self):
        self.reqs: List[Request] = []
        self._arr: List[float] = []
        self._enter: List[float] = []
        self.head = 0
        # conservative lower bound on the oldest live arrival: lets the
        # caller skip the drop scan entirely while nothing can be expired
        self.min_arr = _INF

    def __len__(self) -> int:
        return len(self.reqs) - self.head

    def push(self, req: Request, now: float) -> None:
        self._arr.append(req.arrival)
        self._enter.append(now)
        if req.arrival < self.min_arr:
            self.min_arr = req.arrival
        self.reqs.append(req)

    def push_many(self, reqs: Sequence[Request], arrs: Sequence[float],
                  now: float) -> None:
        """Append a whole upstream batch with its arrival column."""
        self._arr.extend(arrs)
        self._enter.extend([now] * len(reqs))
        m = min(arrs)
        if m < self.min_arr:
            self.min_arr = m
        self.reqs.extend(reqs)

    def head_enter(self) -> float:
        return self._enter[self.head]

    def head_arrival(self) -> float:
        return self._arr[self.head]

    def pop_batch(self, k: int) -> Tuple[List[Request], List[float]]:
        h = self.head
        e = h + k
        batch = self.reqs[h:e]
        arrs = self._arr[h:e]
        self.head = e
        t = len(self.reqs)
        if e == t:
            self.min_arr = _INF
        if e >= 512 and 2 * e >= t:
            del self.reqs[:e]
            del self._arr[:e]
            del self._enter[:e]
            self.head = 0
        return batch, arrs

    def drop_expired(self, now: float, threshold: float) -> List[Request]:
        """Remove (and return) every queued request older than ``threshold``.

        The age test runs vectorized over the arrival column; callers only
        reach this when ``min_arr`` says something may actually be old."""
        h, t = self.head, len(self.reqs)
        if h == t:
            self.min_arr = _INF
            return []
        live_arr = np.array(self._arr[h:t], dtype=np.float64)
        oldest = float(live_arr.min())
        if now - oldest <= threshold:
            self.min_arr = oldest        # tightened bound, nothing expired
            return []
        expired = (now - live_arr) > threshold
        keep = ~expired
        dropped = list(itertools.compress(self.reqs[h:t], expired))
        kept = list(itertools.compress(self.reqs[h:t], keep))
        self.reqs = kept
        self._arr = list(itertools.compress(self._arr[h:t], keep))
        self._enter = list(itertools.compress(self._enter[h:t], keep))
        self.head = 0
        self.min_arr = min(self._arr) if kept else _INF
        return dropped


class PipelineSimulator:
    def __init__(self, pipe: PipelineModel, config: PipelineConfig,
                 drop_factor: float = 2.0, max_wait: float = 0.5,
                 seed: int = 0, variant_switch_delay: float = 0.0,
                 scale_up_delay: float = 0.0,
                 record_timeline: bool = False):
        """``variant_switch_delay``: cold-start of a stage whose model
        variant changed (container pull + model load; the paper reports an
        ~8 s adaptation process and mitigates pull time with MinIO).
        ``scale_up_delay``: startup of additionally provisioned replicas.
        ``record_timeline``: also fill each request's per-stage
        ``stage_enter``/``stage_exit`` dicts (debug/inspection; the hot
        path skips these dict writes — aggregate metrics, drop marks and
        ``done`` stamps are always recorded)."""
        self.pipe = pipe
        self.n_stages = len(pipe.stages)
        self.configs: List[StageConfig] = list(config.stages)
        self.drop_factor = drop_factor
        self.max_wait = max_wait
        self.variant_switch_delay = variant_switch_delay
        self.scale_up_delay = scale_up_delay
        self.record_timeline = record_timeline
        self.queues: List[_StageQueue] = [
            _StageQueue() for _ in range(self.n_stages)]
        self.free_at: List[List[float]] = [
            [0.0] * sc.replicas for sc in self.configs]
        self.rr: List[int] = [0] * self.n_stages
        self.now = 0.0
        self.metrics = SimMetrics()
        self._events: List[Tuple[float, int, str, object]] = []
        self._seq = itertools.count()
        # injections bypass the heap: adapter/benchmark workloads inject in
        # (near-)sorted time order, so arrivals live in a sorted list
        # consumed by a front pointer and merged with the heap in run_until
        self._inj: List[Tuple[float, Request]] = []
        self._inj_i = 0
        self._inj_sorted = True
        # hot-path caches: SLA_P and drop threshold are config constants;
        # per-batch service latency and wait bounds change only on
        # reconfigure / lam_est updates
        self.sla_p = pipe.sla
        self._drop_thr = drop_factor * self.sla_p
        self._lam_est = 10.0
        self._lat_tab: List[List[float]] = []
        self._wb: Optional[List[float]] = None
        self._refresh_lat_tab()
        # lazy-cancellation state: one pending timeout/wake marker per stage
        self._gen: List[int] = [0] * self.n_stages
        self._timeout_at: List[float] = [_INF] * self.n_stages
        self._wake_at: List[float] = [_INF] * self.n_stages
        # observability (benchmarks / invariants)
        self.events_processed = 0
        self.peak_queue_depth = 0
        self.in_service = 0

    # -- control plane --------------------------------------------------
    def reconfigure(self, config: PipelineConfig) -> None:
        for s, sc in enumerate(config.stages):
            old = self.free_at[s]
            n = sc.replicas
            switched = sc.variant != self.configs[s].variant
            if switched and self.variant_switch_delay > 0:
                # cold start: every replica of the stage reloads the model
                ready = self.now + self.variant_switch_delay
                old[:] = [max(t, ready) for t in old]
            if n >= len(old):
                start = self.now + (self.variant_switch_delay if switched
                                    else self.scale_up_delay)
                old.extend([start] * (n - len(old)))
            else:
                # keep the soonest-free replicas
                old.sort()
                del old[n:]
            self.configs[s] = sc
            # batch size / replica availability changed: pending deadlines
            # are stale, re-arm from current state
            self._bump(s)
            self._wake_at[s] = _INF
        self._refresh_lat_tab()
        self._wb = None
        for s in range(self.n_stages):
            self._try_dispatch(s)

    # -- invariants ------------------------------------------------------
    @property
    def queued(self) -> int:
        return sum(len(q) for q in self.queues)

    # -- hot-path caches --------------------------------------------------
    @property
    def lam_est(self) -> float:
        return self._lam_est

    @lam_est.setter
    def lam_est(self, v: float) -> None:
        v = float(v)
        if v == self._lam_est:
            return
        self._lam_est = v
        self._wb = None                  # wait bounds depend on lambda
        # pending batch-formation timeouts were armed under the old lambda;
        # supersede and re-arm them so the deadline tracks the new Eq. 7
        # bound (the legacy core re-evaluated the bound on every tick)
        for s, t in enumerate(self._timeout_at):
            if t != _INF:
                self._bump(s)
                self._try_dispatch(s)

    def _refresh_lat_tab(self) -> None:
        """Per-stage service-latency table l_m(k) for k = 0..batch under the
        current variant (one vectorized evaluation per reconfigure)."""
        self._lat_tab = []
        self._batch_of = []
        for st, sc in zip(self.pipe.stages, self.configs):
            ks = np.arange(sc.batch + 1, dtype=np.float64)
            ks[0] = 1.0                  # k=0 never dispatched; keep finite
            self._lat_tab.append(
                st.variant(sc.variant).latency(ks).tolist())
            self._batch_of.append(sc.batch)

    def _wait_bounds(self) -> List[float]:
        if self._wb is None:
            self._wb = [wait_bound(sc.batch, self._lam_est, self.max_wait)
                        for sc in self.configs]
        return self._wb

    # -- event machinery --------------------------------------------------
    def _push(self, t: float, kind: str, payload) -> None:
        heapq.heappush(self._events, (t, next(self._seq), kind, payload))

    def _bump(self, s: int) -> None:
        """Supersede any pending timeout for stage ``s`` (lazy cancel)."""
        self._gen[s] += 1
        self._timeout_at[s] = _INF

    def _schedule_timeout(self, s: int, t: float) -> None:
        if t < self._timeout_at[s] - _EPS:
            self._timeout_at[s] = t
            self._push(t, "timeout", (s, self._gen[s]))

    def _schedule_wake(self, s: int, t: float) -> None:
        if t <= self.now + _EPS:
            t = self.now + 1e-9
        if t < self._wake_at[s] - _EPS:
            self._wake_at[s] = t
            self._push(t, "wake", s)

    def inject(self, req: Request) -> None:
        self.metrics.arrived += 1
        inj = self._inj
        if inj and req.arrival < inj[-1][0]:
            self._inj_sorted = False
        inj.append((req.arrival, req))

    def _stage_latency(self, s: int, k: int) -> float:
        tab = self._lat_tab[s]
        if k < len(tab):
            return tab[k]
        sc = self.configs[s]
        v = self.pipe.stages[s].variant(sc.variant)
        return float(v.latency(max(k, 1)))

    def _try_dispatch(self, s: int) -> None:
        q = self.queues[s]
        now = self.now
        # §4.5 drop policy — the min-arrival bound lets the common
        # nothing-to-expire case skip the vectorized scan entirely
        if now - q.min_arr > self._drop_thr:
            dropped = q.drop_expired(now, self._drop_thr)
            if dropped:
                for r in dropped:
                    r.dropped_at = s
                    r.done = now
                self.metrics.dropped += len(dropped)
                self._bump(s)
        sc = self.configs[s]
        free = self.free_at[s]
        nq = len(q.reqs) - q.head
        while nq:
            if not free:
                # zero replicas configured: requests can only age out
                self._schedule_wake(s, q.head_arrival() + self._drop_thr)
                return
            free_idx = [i for i, t in enumerate(free) if t <= now + _EPS]
            if not free_idx:
                self._schedule_wake(s, min(free))
                return
            if nq < sc.batch:
                deadline = q.head_enter() + self._wait_bounds()[s]
                if now < deadline - _EPS:
                    self._schedule_timeout(s, deadline)
                    return
                k = nq
            else:
                k = sc.batch
            batch, arrs = q.pop_batch(k)
            nq -= k
            rep = free_idx[self.rr[s] % len(free_idx)]
            self.rr[s] += 1
            done_t = now + self._stage_latency(s, k)
            free[rep] = done_t
            self.in_service += k
            self._push(done_t, "done", (s, batch, arrs))
            self._bump(s)

    def _handle(self, kind: str, payload) -> None:
        if kind == "arrive":
            s, reqs, arrs = payload
            q = self.queues[s]
            if arrs is None:
                for r in reqs:
                    q.push(r, self.now)
            else:
                q.push_many(reqs, arrs, self.now)
            if self.record_timeline:
                for r in reqs:
                    r.stage_enter[s] = self.now
            d = len(q.reqs) - q.head
            if d > self.peak_queue_depth:
                self.peak_queue_depth = d
            # fast path: the batch is still forming (not full), its head is
            # unchanged and already has a live timeout armed, and nothing
            # can have expired — this arrival cannot trigger a dispatch
            if (d >= self._batch_of[s]
                    or self._timeout_at[s] == _INF
                    or self.now - q.min_arr > self._drop_thr):
                self._try_dispatch(s)
        elif kind == "done":
            s, batch, arrs = payload
            self.in_service -= len(batch)
            if self.record_timeline:
                for r in batch:
                    r.stage_exit[s] = self.now
            if s + 1 < self.n_stages:
                # synchronous handoff: the next-stage arrival is at this
                # same instant, so deliver it directly instead of taking a
                # round-trip through the heap
                self._handle("arrive", (s + 1, batch, arrs))
            else:
                now = self.now
                for r in batch:
                    r.done = now
                self.metrics.completed += len(batch)
                self.metrics._lat.extend([now - a for a in arrs])
            q = self.queues[s]
            if len(q.reqs) > q.head:         # freed replica, waiting work
                self._try_dispatch(s)
        elif kind == "timeout":
            s, gen = payload
            if self._timeout_at[s] <= self.now + _EPS:
                self._timeout_at[s] = _INF
            if gen == self._gen[s]:          # else: superseded, ignore
                q = self.queues[s]
                if len(q.reqs) > q.head:
                    self._try_dispatch(s)
        elif kind == "wake":
            s = payload
            if self._wake_at[s] <= self.now + _EPS:
                self._wake_at[s] = _INF
            q = self.queues[s]
            if len(q.reqs) > q.head:
                self._try_dispatch(s)

    def run_until(self, t_end: float) -> None:
        ev = self._events
        inj = self._inj
        if not self._inj_sorted:
            # compact the consumed prefix BEFORE sorting, or processed
            # arrivals would be shuffled back past the front pointer
            if self._inj_i:
                del inj[:self._inj_i]
                self._inj_i = 0
            inj.sort(key=lambda x: x[0])
            self._inj_sorted = True
        i = self._inj_i
        n_inj = len(inj)
        pop = heapq.heappop
        while True:
            t_inj = inj[i][0] if i < n_inj else _INF
            if ev and ev[0][0] < t_inj:
                t = ev[0][0]
                if t > t_end:
                    break
                _, _, kind, payload = pop(ev)
                self.events_processed += 1
                if t > self.now:
                    self.now = t
                self._handle(kind, payload)
            elif t_inj <= t_end:
                # injection stream wins ties: matches the legacy ordering
                # where arrivals were heap-pushed before any derived event
                t, req = inj[i]
                i += 1
                self.events_processed += 1
                if t > self.now:
                    self.now = t
                self._handle("arrive", (0, (req,), None))
            else:
                break
        if i > 4096 and 2 * i >= n_inj:
            del inj[:i]
            i = 0
        self._inj_i = i
        if t_end > self.now:             # never rewind the event clock
            self.now = t_end
