"""Cluster-level data model: multiple pipelines sharing one core pool.

The paper plans each pipeline in isolation; its §6 discussion (and the
cluster-level arbitration in INFaaS / InferLine) points at the production
setting this module models: N linear pipelines contending for a single
budget of ``cores`` (the paper's cost unit — CPU cores), where cost
arbitration happens *across* pipelines.

``ClusterModel`` is the static description (which pipelines, how many
cores total); ``ClusterConfig`` is one joint configuration (one
``PipelineConfig`` per pipeline) with a total-cost accessor and a budget
check.  The single-pipeline stack is the N=1 special case throughout:
``ClusterSimulator`` (core.simulator) runs every pipeline's stages in one
event heap, and ``solve_cluster`` (core.optimizer) arbitrates per-pipeline
Pareto frontiers under ``sum(cost) <= cores``.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence, Tuple

from repro.core.pipeline import PipelineConfig, PipelineModel

_COST_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class ClusterModel:
    """N pipelines plus the shared core budget C they contend for.

    ``cores`` may be a plain scalar (the legacy single fungible pool) or a
    mapping of device-class budgets, e.g. ``{"cpu": 512, "gpu": 16}`` —
    INFaaS-style heterogeneous pools.  A mapping is normalized into
    ``class_budgets`` (sorted ``(class, budget)`` tuples, part of the
    model's identity) and ``cores`` becomes the scalar total, so every
    legacy total-budget reader keeps working; ``is_hetero`` gates all
    per-class arbitration/ledger paths, which a scalar-budget cluster
    never enters.

    ``sla_weights`` (INFaaS-style workload importance): per-pipeline
    multipliers on the arbitration objective — a pipeline with weight 2
    counts double in the joint knapsack, so under contention its accuracy
    is sacrificed last.  ``None`` means every pipeline weighs 1.0.
    """
    name: str
    pipelines: Tuple[PipelineModel, ...]
    cores: float = float("inf")          # shared budget C (inf = unbounded)
    sla_weights: Optional[Tuple[float, ...]] = None
    class_budgets: Optional[Tuple[Tuple[str, float], ...]] = None

    def __post_init__(self):
        if not self.pipelines:
            raise ValueError("a cluster needs at least one pipeline")
        if isinstance(self.cores, Mapping):
            if self.class_budgets is not None:
                raise ValueError(
                    "pass per-class budgets via cores OR class_budgets")
            object.__setattr__(self, "class_budgets",
                               tuple(self.cores.items()))
            object.__setattr__(self, "cores", None)
        if self.class_budgets is not None:
            cb = tuple(sorted((str(c), float(b))
                              for c, b in self.class_budgets))
            if not cb:
                raise ValueError("per-class budgets must name >= 1 class")
            if len({c for c, _ in cb}) != len(cb):
                raise ValueError("duplicate device class in budgets")
            if any(b < 0 for _, b in cb):
                raise ValueError("per-class budgets must be >= 0")
            object.__setattr__(self, "class_budgets", cb)
            object.__setattr__(self, "cores",
                               float(sum(b for _, b in cb)))
            classes = {c for c, _ in cb}
            for pipe in self.pipelines:
                for st in pipe.stages:
                    for v in st.variants:
                        missing = set(v.device_classes) - classes
                        if missing:
                            raise ValueError(
                                f"variant {v.name} targets device classes "
                                f"{sorted(missing)} with no budget")
        if self.sla_weights is not None:
            if len(self.sla_weights) != len(self.pipelines):
                raise ValueError("one SLA weight per pipeline required")
            if any(w <= 0 for w in self.sla_weights):
                raise ValueError("SLA weights must be positive")

    @property
    def is_hetero(self) -> bool:
        """True when the budget is per-device-class (vector paths gated
        here; a scalar-budget cluster never enters them)."""
        return self.class_budgets is not None

    @property
    def device_classes(self) -> Tuple[str, ...]:
        """Budgeted device classes, sorted (``("cpu",)`` for a scalar
        budget) — the canonical axis order of every cost vector."""
        if self.class_budgets is None:
            return ("cpu",)
        return tuple(c for c, _ in self.class_budgets)

    @property
    def budget_vector(self) -> Tuple[float, ...]:
        """Per-class budgets aligned with ``device_classes`` (a scalar
        budget is the single-class vector ``(cores,)``)."""
        if self.class_budgets is None:
            return (float(self.cores),)
        return tuple(b for _, b in self.class_budgets)

    @property
    def n_pipelines(self) -> int:
        return len(self.pipelines)

    @property
    def weights(self) -> Tuple[float, ...]:
        """Effective per-pipeline SLA weights (1.0 when unset)."""
        if self.sla_weights is None:
            return tuple(1.0 for _ in self.pipelines)
        return tuple(float(w) for w in self.sla_weights)

    def pipeline(self, name: str) -> PipelineModel:
        for p in self.pipelines:
            if p.name == name:
                return p
        raise KeyError(name)


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """One joint configuration: a PipelineConfig per pipeline, in order."""
    pipelines: Tuple[PipelineConfig, ...]

    def cost(self, cluster: ClusterModel) -> float:
        """Total cores allocated across every pipeline's stages."""
        if len(self.pipelines) != len(cluster.pipelines):
            raise ValueError("config/cluster pipeline count mismatch")
        return float(sum(cfg.cost(pipe) for cfg, pipe
                         in zip(self.pipelines, cluster.pipelines)))

    def cost_by_class(self, cluster: ClusterModel) -> Tuple[float, ...]:
        """Total per-device-class cost vector, aligned with
        ``cluster.device_classes``."""
        if len(self.pipelines) != len(cluster.pipelines):
            raise ValueError("config/cluster pipeline count mismatch")
        classes = cluster.device_classes
        tot = [0.0] * len(classes)
        for cfg, pipe in zip(self.pipelines, cluster.pipelines):
            for c, v in zip(range(len(classes)),
                            cfg.cost_by_class(pipe, classes)):
                tot[c] += v
        return tuple(tot)

    def fits(self, cluster: ClusterModel) -> bool:
        """Does the joint allocation fit the shared budget — every class's
        budget under per-class budgets, the scalar C otherwise?"""
        if cluster.is_hetero:
            return all(c <= b + _COST_EPS
                       for c, b in zip(self.cost_by_class(cluster),
                                       cluster.budget_vector))
        return self.cost(cluster) <= cluster.cores + _COST_EPS

    def n_changes(self, other: "ClusterConfig") -> int:
        """How many pipelines differ between two joint configurations —
        the per-interval switch count the reconfiguration budget caps and
        the §5.3 adaptation penalty is charged per unit of."""
        if len(self.pipelines) != len(other.pipelines):
            raise ValueError("config pipeline count mismatch")
        return sum(1 for a, b in zip(self.pipelines, other.pipelines)
                   if a != b)

    def transition_cost(self, cluster: ClusterModel,
                        serving: "ClusterConfig") -> float:
        """Peak cores needed to move from ``serving`` to this config when
        every changed pipeline's old replica fleet serves out a §5.3
        adaptation window: ``sum_p max(old_p, new_p)``.

        During a transition both fleets are provisioned — the old one is
        still serving, the new one is starting — so the honest capacity
        charge per pipeline is the larger of the two allocations, not the
        post-transition one.  This is what the overlap-aware solver plans
        against and what the simulator's ledger holds until the deferred
        apply event fires."""
        if len(self.pipelines) != len(serving.pipelines):
            raise ValueError("config pipeline count mismatch")
        if len(self.pipelines) != len(cluster.pipelines):
            raise ValueError("config/cluster pipeline count mismatch")
        return float(sum(max(new.cost(pipe), old.cost(pipe))
                         for new, old, pipe in zip(self.pipelines,
                                                   serving.pipelines,
                                                   cluster.pipelines)))

    def transition_cost_by_class(self, cluster: ClusterModel,
                                 serving: "ClusterConfig"
                                 ) -> Tuple[float, ...]:
        """Per-class peak transition charge: ``max(old, new)`` per pipeline
        taken *elementwise per device class* (the old fleet's GPU replicas
        and the new fleet's CPU replicas coexist through the window), then
        summed across pipelines.  Aligned with ``cluster.device_classes``."""
        if len(self.pipelines) != len(serving.pipelines):
            raise ValueError("config pipeline count mismatch")
        if len(self.pipelines) != len(cluster.pipelines):
            raise ValueError("config/cluster pipeline count mismatch")
        classes = cluster.device_classes
        tot = [0.0] * len(classes)
        for new, old, pipe in zip(self.pipelines, serving.pipelines,
                                  cluster.pipelines):
            nv = new.cost_by_class(pipe, classes)
            ov = old.cost_by_class(pipe, classes)
            for c in range(len(classes)):
                tot[c] += max(nv[c], ov[c])
        return tuple(tot)

    def fits_transition(self, cluster: ClusterModel,
                        serving: "ClusterConfig") -> bool:
        """Does the move from ``serving`` to this config fit the budget
        *throughout* the adaptation window (old and new fleets counted at
        ``max`` — per device class under per-class budgets), not merely
        after it?"""
        if cluster.is_hetero:
            return all(
                c <= b + _COST_EPS
                for c, b in zip(self.transition_cost_by_class(cluster,
                                                              serving),
                                cluster.budget_vector))
        return self.transition_cost(cluster, serving) \
            <= cluster.cores + _COST_EPS


def single(pipe: PipelineModel, cores: float = float("inf")) -> ClusterModel:
    """Wrap one pipeline as a cluster (the N=1 special case)."""
    return ClusterModel(pipe.name, (pipe,), cores)


def proportional_split(cluster: ClusterModel,
                       demands: Sequence[float]) -> Tuple[float, ...]:
    """Split the core budget proportionally to per-pipeline demand (RPS).

    This is the static-split baseline's arbitration rule: pipeline i gets
    ``C * lam_i / sum(lam)``; the joint solver instead trades cores across
    pipelines by marginal objective gain.
    """
    if len(demands) != cluster.n_pipelines:
        raise ValueError("one demand per pipeline required")
    if cluster.cores == float("inf"):
        return tuple(float("inf") for _ in demands)
    total = float(sum(max(float(d), 0.0) for d in demands))
    if total <= 0.0:
        return tuple(cluster.cores / cluster.n_pipelines for _ in demands)
    return tuple(cluster.cores * max(float(d), 0.0) / total for d in demands)


def proportional_split_by_class(cluster: ClusterModel,
                                demands: Sequence[float]
                                ) -> Tuple[Tuple[float, ...], ...]:
    """Per-class proportional split: pipeline i gets the demand share
    ``B_c * lam_i / sum(lam)`` of *every* class budget ``B_c`` — the
    strongest static-split strawman on a heterogeneous pool (each share
    keeps the pool's class mix; the joint solver instead trades classes
    across pipelines).  Returns one per-class cap vector per pipeline,
    aligned with ``cluster.device_classes``."""
    if len(demands) != cluster.n_pipelines:
        raise ValueError("one demand per pipeline required")
    budgets = cluster.budget_vector
    total = float(sum(max(float(d), 0.0) for d in demands))
    if total <= 0.0:
        return tuple(tuple(b / cluster.n_pipelines for b in budgets)
                     for _ in demands)
    return tuple(tuple(b * max(float(d), 0.0) / total for b in budgets)
                 for d in demands)
