"""Cluster-level data model: multiple pipelines sharing one core pool.

The paper plans each pipeline in isolation; its §6 discussion (and the
cluster-level arbitration in INFaaS / InferLine) points at the production
setting this module models: N linear pipelines contending for a single
budget of ``cores`` (the paper's cost unit — CPU cores), where cost
arbitration happens *across* pipelines.

``ClusterModel`` is the static description (which pipelines, how many
cores total); ``ClusterConfig`` is one joint configuration (one
``PipelineConfig`` per pipeline) with a total-cost accessor and a budget
check.  The single-pipeline stack is the N=1 special case throughout:
``ClusterSimulator`` (core.simulator) runs every pipeline's stages in one
event heap, and ``solve_cluster`` (core.optimizer) arbitrates per-pipeline
Pareto frontiers under ``sum(cost) <= cores``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

from repro.core.pipeline import PipelineConfig, PipelineModel

_COST_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class ClusterModel:
    """N pipelines plus the shared core budget C they contend for.

    ``sla_weights`` (INFaaS-style workload importance): per-pipeline
    multipliers on the arbitration objective — a pipeline with weight 2
    counts double in the joint knapsack, so under contention its accuracy
    is sacrificed last.  ``None`` means every pipeline weighs 1.0.
    """
    name: str
    pipelines: Tuple[PipelineModel, ...]
    cores: float = float("inf")          # shared budget C (inf = unbounded)
    sla_weights: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        if not self.pipelines:
            raise ValueError("a cluster needs at least one pipeline")
        if self.sla_weights is not None:
            if len(self.sla_weights) != len(self.pipelines):
                raise ValueError("one SLA weight per pipeline required")
            if any(w <= 0 for w in self.sla_weights):
                raise ValueError("SLA weights must be positive")

    @property
    def n_pipelines(self) -> int:
        return len(self.pipelines)

    @property
    def weights(self) -> Tuple[float, ...]:
        """Effective per-pipeline SLA weights (1.0 when unset)."""
        if self.sla_weights is None:
            return tuple(1.0 for _ in self.pipelines)
        return tuple(float(w) for w in self.sla_weights)

    def pipeline(self, name: str) -> PipelineModel:
        for p in self.pipelines:
            if p.name == name:
                return p
        raise KeyError(name)


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """One joint configuration: a PipelineConfig per pipeline, in order."""
    pipelines: Tuple[PipelineConfig, ...]

    def cost(self, cluster: ClusterModel) -> float:
        """Total cores allocated across every pipeline's stages."""
        if len(self.pipelines) != len(cluster.pipelines):
            raise ValueError("config/cluster pipeline count mismatch")
        return float(sum(cfg.cost(pipe) for cfg, pipe
                         in zip(self.pipelines, cluster.pipelines)))

    def fits(self, cluster: ClusterModel) -> bool:
        """Does the joint allocation fit the shared budget C?"""
        return self.cost(cluster) <= cluster.cores + _COST_EPS

    def n_changes(self, other: "ClusterConfig") -> int:
        """How many pipelines differ between two joint configurations —
        the per-interval switch count the reconfiguration budget caps and
        the §5.3 adaptation penalty is charged per unit of."""
        if len(self.pipelines) != len(other.pipelines):
            raise ValueError("config pipeline count mismatch")
        return sum(1 for a, b in zip(self.pipelines, other.pipelines)
                   if a != b)

    def transition_cost(self, cluster: ClusterModel,
                        serving: "ClusterConfig") -> float:
        """Peak cores needed to move from ``serving`` to this config when
        every changed pipeline's old replica fleet serves out a §5.3
        adaptation window: ``sum_p max(old_p, new_p)``.

        During a transition both fleets are provisioned — the old one is
        still serving, the new one is starting — so the honest capacity
        charge per pipeline is the larger of the two allocations, not the
        post-transition one.  This is what the overlap-aware solver plans
        against and what the simulator's ledger holds until the deferred
        apply event fires."""
        if len(self.pipelines) != len(serving.pipelines):
            raise ValueError("config pipeline count mismatch")
        if len(self.pipelines) != len(cluster.pipelines):
            raise ValueError("config/cluster pipeline count mismatch")
        return float(sum(max(new.cost(pipe), old.cost(pipe))
                         for new, old, pipe in zip(self.pipelines,
                                                   serving.pipelines,
                                                   cluster.pipelines)))

    def fits_transition(self, cluster: ClusterModel,
                        serving: "ClusterConfig") -> bool:
        """Does the move from ``serving`` to this config fit the budget C
        *throughout* the adaptation window (old and new fleets counted at
        ``max``), not merely after it?"""
        return self.transition_cost(cluster, serving) \
            <= cluster.cores + _COST_EPS


def single(pipe: PipelineModel, cores: float = float("inf")) -> ClusterModel:
    """Wrap one pipeline as a cluster (the N=1 special case)."""
    return ClusterModel(pipe.name, (pipe,), cores)


def proportional_split(cluster: ClusterModel,
                       demands: Sequence[float]) -> Tuple[float, ...]:
    """Split the core budget proportionally to per-pipeline demand (RPS).

    This is the static-split baseline's arbitration rule: pipeline i gets
    ``C * lam_i / sum(lam)``; the joint solver instead trades cores across
    pipelines by marginal objective gain.
    """
    if len(demands) != cluster.n_pipelines:
        raise ValueError("one demand per pipeline required")
    if cluster.cores == float("inf"):
        return tuple(float("inf") for _ in demands)
    total = float(sum(max(float(d), 0.0) for d in demands))
    if total <= 0.0:
        return tuple(cluster.cores / cluster.n_pipelines for _ in demands)
    return tuple(cluster.cores * max(float(d), 0.0) / total for d in demands)
