"""Cluster-level data model: multiple pipelines sharing one core pool.

The paper plans each pipeline in isolation; its §6 discussion (and the
cluster-level arbitration in INFaaS / InferLine) points at the production
setting this module models: N linear pipelines contending for a single
budget of ``cores`` (the paper's cost unit — CPU cores), where cost
arbitration happens *across* pipelines.

``ClusterModel`` is the static description (which pipelines, how many
cores total); ``ClusterConfig`` is one joint configuration (one
``PipelineConfig`` per pipeline) with a total-cost accessor and a budget
check.  The single-pipeline stack is the N=1 special case throughout:
``ClusterSimulator`` (core.simulator) runs every pipeline's stages in one
event heap, and ``solve_cluster`` (core.optimizer) arbitrates per-pipeline
Pareto frontiers under ``sum(cost) <= cores``.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

from repro.core.pipeline import PipelineConfig, PipelineModel

_COST_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class ClusterModel:
    """N pipelines plus the shared core budget C they contend for."""
    name: str
    pipelines: Tuple[PipelineModel, ...]
    cores: float = float("inf")          # shared budget C (inf = unbounded)

    def __post_init__(self):
        if not self.pipelines:
            raise ValueError("a cluster needs at least one pipeline")

    @property
    def n_pipelines(self) -> int:
        return len(self.pipelines)

    def pipeline(self, name: str) -> PipelineModel:
        for p in self.pipelines:
            if p.name == name:
                return p
        raise KeyError(name)


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """One joint configuration: a PipelineConfig per pipeline, in order."""
    pipelines: Tuple[PipelineConfig, ...]

    def cost(self, cluster: ClusterModel) -> float:
        """Total cores allocated across every pipeline's stages."""
        if len(self.pipelines) != len(cluster.pipelines):
            raise ValueError("config/cluster pipeline count mismatch")
        return float(sum(cfg.cost(pipe) for cfg, pipe
                         in zip(self.pipelines, cluster.pipelines)))

    def fits(self, cluster: ClusterModel) -> bool:
        """Does the joint allocation fit the shared budget C?"""
        return self.cost(cluster) <= cluster.cores + _COST_EPS


def single(pipe: PipelineModel, cores: float = float("inf")) -> ClusterModel:
    """Wrap one pipeline as a cluster (the N=1 special case)."""
    return ClusterModel(pipe.name, (pipe,), cores)


def proportional_split(cluster: ClusterModel,
                       demands: Sequence[float]) -> Tuple[float, ...]:
    """Split the core budget proportionally to per-pipeline demand (RPS).

    This is the static-split baseline's arbitration rule: pipeline i gets
    ``C * lam_i / sum(lam)``; the joint solver instead trades cores across
    pipelines by marginal objective gain.
    """
    if len(demands) != cluster.n_pipelines:
        raise ValueError("one demand per pipeline required")
    if cluster.cores == float("inf"):
        return tuple(float("inf") for _ in demands)
    total = float(sum(max(float(d), 0.0) for d in demands))
    if total <= 0.0:
        return tuple(cluster.cores / cluster.n_pipelines for _ in demands)
    return tuple(cluster.cores * max(float(d), 0.0) / total for d in demands)
