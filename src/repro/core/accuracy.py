"""Pipeline accuracy metrics (paper §4.1 + Appendix C).

PAS  (Eq. 8): product of per-stage accuracies (kept on a 0-100 scale:
             100 * prod(a_s / 100), matching the paper's plotted ranges).
PAS' (Eq. 11): sum of rank-normalized per-stage accuracies (Appendix C) —
             the linear alternative; both must rank configurations
             consistently in the end-to-end experiments.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.pipeline import PipelineConfig, PipelineModel


def pas(accs: Sequence[float]) -> float:
    """accs: chosen per-stage accuracies in [0, 100]."""
    p = 100.0
    for a in accs:
        p *= a / 100.0
    return p


def pas_of(config: PipelineConfig, pipe: PipelineModel) -> float:
    return pas([st.variant(sc.variant).acc(sc.device)
                for sc, st in zip(config.stages, pipe.stages)])


def rank_normalized(accuracies: Sequence[float]) -> np.ndarray:
    """Scale a stage's variant accuracies to [0, 1] by rank (Appendix C)."""
    a = np.asarray(accuracies, dtype=np.float64)
    order = np.argsort(np.argsort(a))
    if len(a) == 1:
        return np.ones(1)
    return order / (len(a) - 1.0)


def pas_prime_tables(pipe: PipelineModel):
    """Per-stage rank-normalized accuracy lookup for PAS' (Eq. 11), keyed
    ``(variant name, device class)``.  Ranks run over the stage's flattened
    (variant, class) accuracy list in declaration order — for single-class
    stages that is exactly the legacy per-variant ranking."""
    out = []
    for st in pipe.stages:
        pairs = [(v.name, d) for v in st.variants for d in v.device_classes]
        accs = [st.variant(n).acc(d) for n, d in pairs]
        out.append(dict(zip(pairs, rank_normalized(accs))))
    return out


def pas_prime_of(config: PipelineConfig, pipe: PipelineModel) -> float:
    tables = pas_prime_tables(pipe)
    return float(sum(t[(sc.variant, sc.device)]
                     for t, sc in zip(tables, config.stages)))
