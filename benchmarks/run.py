"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows plus commentary lines prefixed
with '#'.  Results are also written to results/bench/*.json for
EXPERIMENTS.md.

  PYTHONPATH=src python -m benchmarks.run [--only NAME] [--fast]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import numpy as np

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "bench")

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    line = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(line)
    print(line, flush=True)


def note(msg: str) -> None:
    print(f"# {msg}", flush=True)


def save(name: str, obj) -> None:
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, f"{name}.json"), "w") as f:
        json.dump(obj, f, indent=1, default=float)


# ---------------------------------------------------------------------------
# Table 2 / Fig 2: variant latency/throughput under allocations
# ---------------------------------------------------------------------------
def bench_table2_variant_profiles(fast: bool) -> None:
    from repro.core import paper_profiles as PP
    from repro.core import profiler as PF
    rows = []
    for task in ("object_classification", "object_detection"):
        for p in PP.task_profiles(task):
            a, b, c = p.coeffs()
            for cores in (1, 4, 8):
                lat1 = (a + b + c) / PF.alloc_speedup(cores)
                rows.append({"task": task, "variant": p.name, "cores": cores,
                             "latency_ms": lat1 * 1e3,
                             "throughput_rps": 1.0 / lat1,
                             "accuracy": p.accuracy})
    save("table2_profiles", rows)
    r18 = [r for r in rows if r["variant"] == "resnet18" and r["cores"] == 1][0]
    emit("table2.resnet18_b1_core1", r18["latency_ms"] * 1e3,
         f"lat={r18['latency_ms']:.0f}ms_paper=75ms")
    r50 = [r for r in rows if r["variant"] == "resnet50" and r["cores"] == 1][0]
    emit("table2.resnet50_b1_core1", r50["latency_ms"] * 1e3,
         f"lat={r50['latency_ms']:.0f}ms_paper=135ms")


# ---------------------------------------------------------------------------
# Table 3: two-stage configuration options
# ---------------------------------------------------------------------------
def bench_table3_config_space(fast: bool) -> None:
    from repro.core import optimizer as OPT
    from repro.core import paper_profiles as PP
    pipe = PP.video()
    lam = 20.0
    rows = []
    for st in pipe.stages:
        opts = OPT.stage_options(st, lam)
        for j in range(len(opts.names)):
            if opts.feasible[j]:
                rows.append({"stage": st.name, "variant": opts.names[j],
                             "batch": int(opts.batches[j]),
                             "replicas": int(opts.replicas[j]),
                             "latency_s": float(opts.lat[j]),
                             "cost": float(opts.cost[j]),
                             "accuracy": float(opts.acc[j])})
    save("table3_options", rows)
    emit("table3.video_option_count", 0.0, f"n_feasible={len(rows)}@20rps")


# ---------------------------------------------------------------------------
# Figs 8-12: end-to-end pipelines x workloads x policies
# ---------------------------------------------------------------------------
def bench_e2e_pipelines(fast: bool) -> None:
    from repro.core import adapter as AD
    from repro.core import optimizer as OPT
    from repro.core import paper_profiles as PP
    from repro.core import trace as TR
    seconds = 120 if fast else 300
    pipelines = ["video"] if fast else list(PP.PIPELINES)
    out: Dict[str, Dict] = {}
    for pname in pipelines:
        pipe = PP.PIPELINES[pname]()
        obj = OPT.Objective(**PP.PAPER_WEIGHTS[pname], metric="pas")
        for wname in TR.EXCERPTS:
            rates = TR.excerpt(wname, seconds=seconds)
            for pol in ("ipa", "fa2_low", "fa2_high", "rim"):
                t0 = time.time()
                res = AD.run_trace(pipe, rates, policy=pol, obj=obj, seed=11)
                s = res.summary()
                s["wall_s"] = time.time() - t0
                out[f"{pname}/{wname}/{pol}"] = s
                note(f"{pname}/{wname}/{pol}: pas={s['mean_pas']} "
                     f"cost={s['mean_cost']} viol={s['sla_violation_rate']}")
        ipa_pas = np.mean([out[f"{pname}/{w}/ipa"]["mean_pas"]
                           for w in TR.EXCERPTS])
        low_pas = np.mean([out[f"{pname}/{w}/fa2_low"]["mean_pas"]
                           for w in TR.EXCERPTS])
        low_cost = np.mean([out[f"{pname}/{w}/fa2_low"]["mean_cost"]
                            for w in TR.EXCERPTS])
        ipa_cost = np.mean([out[f"{pname}/{w}/ipa"]["mean_cost"]
                            for w in TR.EXCERPTS])
        gain = 100.0 * (ipa_pas - low_pas) / low_pas
        emit(f"e2e.{pname}.accuracy_gain_vs_fa2low_pct", 0.0,
             f"{gain:.1f}pct_at_cost_x{ipa_cost/max(low_cost,1e-9):.2f}")
    save("e2e_pipelines", out)


# ---------------------------------------------------------------------------
# Fig 13: optimizer decision time vs pipeline size
# ---------------------------------------------------------------------------
def bench_fig13_decision_time(fast: bool) -> None:
    from repro.core import optimizer as OPT
    from repro.core.pipeline import ModelVariant, PipelineModel, StageModel
    rng = np.random.default_rng(0)
    grid = [2, 6, 10] if fast else [2, 4, 6, 10]
    rows = []
    for n_stages in grid:
        for n_models in grid:
            stages = []
            for s in range(n_stages):
                variants = tuple(
                    ModelVariant(f"s{s}v{v}", float(rng.uniform(40, 95)),
                                 int(rng.choice([1, 2, 4, 8])),
                                 (1e-5, float(rng.uniform(0.01, 0.1)),
                                  float(rng.uniform(0.01, 0.2))))
                    for v in range(n_models))
                sla = 5.0 * float(np.mean([v.latency(1) for v in variants]))
                stages.append(StageModel(f"s{s}", variants, sla))
            pipe = PipelineModel("bench", tuple(stages))
            obj = OPT.Objective(alpha=5, beta=0.5, metric="pas_prime")
            t0 = time.perf_counter()
            sol = OPT.solve_milp(pipe, 20.0, obj)
            dt = time.perf_counter() - t0
            rows.append({"stages": n_stages, "models": n_models,
                         "milp_s": dt, "feasible": sol.feasible})
            emit(f"fig13.milp_{n_stages}stages_{n_models}models", dt * 1e6,
                 f"{dt*1e3:.1f}ms_feasible={sol.feasible}")
    worst = max(r["milp_s"] for r in rows)
    note(f"fig13: worst decision time {worst*1e3:.0f}ms "
         f"(paper: <2s for 10x10 with Gurobi)")
    save("fig13_decision_time", rows)


# ---------------------------------------------------------------------------
# Fig 14: alpha/beta adaptability (accuracy-vs-cost frontier)
# ---------------------------------------------------------------------------
def bench_fig14_adaptability(fast: bool) -> None:
    from repro.core import optimizer as OPT
    from repro.core import paper_profiles as PP
    pipelines = ["video"] if fast else list(PP.PIPELINES)
    rows = []
    for pname in pipelines:
        pipe = PP.PIPELINES[pname]()
        lam = 15.0
        for alpha, beta, tag in ((0.2, 2.0, "resource_prior"),
                                 (2.0, 1.0, "balanced"),
                                 (50.0, 0.2, "accuracy_prior")):
            sol = OPT.solve_enum(pipe, lam,
                                 OPT.Objective(alpha=alpha, beta=beta))
            rows.append({"pipeline": pname, "pref": tag, "alpha": alpha,
                         "beta": beta, "pas": sol.pas, "cost": sol.cost})
            emit(f"fig14.{pname}.{tag}", sol.solve_time * 1e6,
                 f"pas={sol.pas:.1f}_cost={sol.cost:.0f}")
    save("fig14_adaptability", rows)


# ---------------------------------------------------------------------------
# Fig 15: end-to-end latency CDFs per policy
# ---------------------------------------------------------------------------
def bench_fig15_latency_cdf(fast: bool) -> None:
    from repro.core import adapter as AD
    from repro.core import optimizer as OPT
    from repro.core import paper_profiles as PP
    from repro.core import trace as TR
    pipe = PP.video()
    obj = OPT.Objective(**PP.PAPER_WEIGHTS["video"], metric="pas")
    rates = TR.excerpt("fluctuating", seconds=120 if fast else 240)
    out = {}
    for pol in ("ipa", "fa2_low", "fa2_high", "rim"):
        res = AD.run_trace(pipe, rates, policy=pol, obj=obj, seed=5)
        pct = {f"p{p}": float(np.percentile(res.latencies, p))
               for p in (50, 90, 99)}
        out[pol] = pct
        emit(f"fig15.video.{pol}", pct["p50"] * 1e6,
             f"p50={pct['p50']:.2f}s_p99={pct['p99']:.2f}s")
    save("fig15_latency_cdf", out)


# ---------------------------------------------------------------------------
# Fig 16: predictor ablation
# ---------------------------------------------------------------------------
def bench_fig16_predictor(fast: bool) -> None:
    from repro.core import adapter as AD
    from repro.core import optimizer as OPT
    from repro.core import paper_profiles as PP
    from repro.core import predictor as PR
    from repro.core import trace as TR
    pipe = PP.video()
    obj = OPT.Objective(**PP.PAPER_WEIGHTS["video"], metric="pas")
    rates = TR.excerpt("bursty", seconds=120 if fast else 300)
    t0 = time.time()
    lstm = PR.LSTMPredictor.train(TR.train_region(),
                                  steps=150 if fast else 400,
                                  stride=60 if fast else 30)
    train_s = time.time() - t0
    X, y = PR.make_windows(TR.test_region(), stride=200)
    sm = PR.smape(lstm.predict_batch(X), y)
    note(f"fig16: LSTM trained in {train_s:.0f}s, SMAPE={sm:.2f}% "
         f"(paper: <10min, 6.6%)")
    out = {"smape": sm, "train_s": train_s}
    for name, kw in (("reactive", {}), ("lstm", dict(predictor=lstm)),
                     ("oracle", dict(oracle=PR.OraclePredictor(rates)))):
        res = AD.run_trace(pipe, rates, policy="ipa", obj=obj, seed=7, **kw)
        out[name] = res.summary()
        emit(f"fig16.{name}", 0.0,
             f"viol={res.sla_violation_rate:.4f}_cost={res.mean_cost:.1f}")
    save("fig16_predictor", out)


# ---------------------------------------------------------------------------
# Appendix C: PAS' alternative metric consistency
# ---------------------------------------------------------------------------
def bench_appendixC_pas_prime(fast: bool) -> None:
    from repro.core import adapter as AD
    from repro.core import optimizer as OPT
    from repro.core import paper_profiles as PP
    from repro.core import trace as TR
    pipe = PP.video()
    rates = TR.excerpt("fluctuating", seconds=120)
    out = {}
    for metric in ("pas", "pas_prime"):
        obj = (OPT.Objective(alpha=2.0, beta=1.0, metric="pas")
               if metric == "pas"
               else OPT.Objective(alpha=30.0, beta=1.0, metric="pas_prime"))
        rs = {}
        for pol in ("ipa", "fa2_low", "fa2_high"):
            res = AD.run_trace(pipe, rates, policy=pol, obj=obj, seed=9)
            rs[pol] = (res.mean_pas, res.mean_cost)
        out[metric] = rs
        order = sorted(rs, key=lambda p: rs[p][0])
        emit(f"appendixC.{metric}.policy_order", 0.0, ">".join(order))
    same = (sorted(out["pas"], key=lambda p: out["pas"][p][0])
            == sorted(out["pas_prime"], key=lambda p: out["pas_prime"][p][0]))
    note(f"appendixC: metric-invariant policy ranking = {same} "
         f"(paper: both metrics agree)")
    save("appendixC_pas_prime", out)


# ---------------------------------------------------------------------------
# real data plane: JAX serving engine microbench (our Fig-2 analogue)
# ---------------------------------------------------------------------------
def bench_engine_profiles(fast: bool) -> None:
    from repro import configs
    from repro.core import profiler as PF
    from repro.serving.engine import StageServer
    arch = "yi-34b"
    fam = configs.get_variant_family(arch)
    srv = StageServer(arch, fam, gen_tokens=2)
    profs = PF.profile_stage_server(srv, batches=(1, 2) if fast else (1, 2, 4),
                                    repeats=1)
    rows = []
    for p in profs:
        thr = [b / l for b, l in zip(p.batches, p.latencies)]
        rows.append({"variant": p.name, "batches": p.batches,
                     "latencies_s": p.latencies, "throughput_rps": thr,
                     "accuracy": p.accuracy})
        emit(f"engine.{p.name}.b1", p.latencies[0] * 1e6,
             f"thr_bmax={thr[-1]:.2f}rps_acc={p.accuracy}")
    note("engine: real-JAX profiles feed the same build_stage path as the "
         "paper tables (Fig-2 analogue)")
    save("engine_profiles", rows)


# ---------------------------------------------------------------------------
# kernels microbench (interpret-mode wall time is NOT TPU perf; ensures the
# kernels run + gives call overhead — roofline comes from the dry-run)
# ---------------------------------------------------------------------------
def bench_kernels(fast: bool) -> None:
    import jax

    from repro.kernels import ops, ref
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    b, s, h, kv, hd = 1, 256, 4, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    for name, fn in (("flash_interp",
                      lambda: ops.flash_attention(q, k, v, block_q=128,
                                                  block_k=128,
                                                  interpret=True)),
                     ("flash_ref", lambda: ref.flash_attention_ref(q, k, v))):
        fn()
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(fn())
        emit(f"kernels.{name}", (time.perf_counter() - t0) / 3 * 1e6,
             f"shape={b}x{s}x{h}x{hd}")


# ---------------------------------------------------------------------------
# §4.5 dropping-policy ablation (ours; the paper states the mechanism)
# ---------------------------------------------------------------------------
def bench_drop_ablation(fast: bool) -> None:
    import numpy as _np

    from repro.core import optimizer as OPT
    from repro.core import paper_profiles as PP
    from repro.core import trace as TR
    from repro.core.simulator import PipelineSimulator
    from repro.serving.request import Request
    pipe = PP.video()
    lam = 28.0                                   # deliberately overloaded
    sol = OPT.solve_enum(pipe, 14.0, OPT.Objective())   # sized for half load
    rates = _np.full(60 if fast else 120, lam)
    times = TR.arrivals_from_rates(rates, seed=3)
    out = {}
    for df in (1.0, 2.0, 1e9):
        sim = PipelineSimulator(pipe, sol.config, drop_factor=df)
        for t in times:
            sim.inject(Request(arrival=float(t), sla=pipe.sla))
        sim.run_until(float(len(rates)) + 20 * pipe.sla)
        m = sim.metrics
        viol = m.sla_violations(pipe.sla)
        p99 = float(_np.percentile(m.latencies, 99)) if len(m.latencies) else 0.0
        out[str(df)] = {"dropped": m.dropped, "violations": viol, "p99": p99}
        emit(f"drop.factor_{df:g}", 0.0,
             f"dropped={m.dropped}_viol={viol:.3f}_p99={p99:.1f}s")
    note("drop: without dropping (factor inf) back-pressure inflates p99; "
         "factor 2 (paper) bounds tail latency at the cost of drops")
    save("drop_ablation", out)


# ---------------------------------------------------------------------------
# roofline table from dry-run artifacts
# ---------------------------------------------------------------------------
def bench_roofline(fast: bool) -> None:
    d = os.path.join(os.path.dirname(RESULTS), "dryrun")
    if not os.path.isdir(d):
        note("roofline: no dry-run artifacts (run repro.launch.dryrun --all)")
        return
    n, ok = 0, 0
    for f in sorted(os.listdir(d)):
        if not f.endswith(".json"):
            continue
        rec = json.load(open(os.path.join(d, f)))
        n += 1
        if not rec.get("ok"):
            note(f"roofline MISSING {f}: {str(rec.get('error', '?'))[:100]}")
            continue
        ok += 1
        emit(f"roofline.{rec['arch']}.{rec['shape']}."
             f"{'mp' if 'pod' in rec['mesh'] else 'sp'}",
             max(rec["compute_s"], rec["memory_s"], rec["collective_s"]) * 1e6,
             f"bound={rec['bottleneck']}_useful={rec['useful_flops_ratio']:.2f}")
    note(f"roofline: {ok}/{n} dry-run cases ok")


BENCHES = {
    "table2": bench_table2_variant_profiles,
    "table3": bench_table3_config_space,
    "e2e": bench_e2e_pipelines,
    "fig13": bench_fig13_decision_time,
    "fig14": bench_fig14_adaptability,
    "fig15": bench_fig15_latency_cdf,
    "fig16": bench_fig16_predictor,
    "appendixC": bench_appendixC_pas_prime,
    "drop": bench_drop_ablation,
    "engine": bench_engine_profiles,
    "kernels": bench_kernels,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if args.only and args.only != name:
            continue
        note(f"--- {name} ---")
        t0 = time.time()
        fn(args.fast)
        note(f"{name} done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
