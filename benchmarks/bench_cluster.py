#!/usr/bin/env python
"""Cluster co-scheduling benchmark: joint knapsack arbitration vs
proportional static split on one shared core pool.

Replays anti-correlated bursty traces (each pipeline bursts while the
others are quiet — the regime where moving cores *across* pipelines pays)
for 2-4 pipelines through one ``ClusterSimulator`` under every cluster
policy:

* ``ipa``            -- joint: one knapsack over per-pipeline Pareto
                        frontiers under the shared budget C
                        (``optimizer.solve_cluster``)
* ``split_ipa``      -- C split proportionally to demand, per-pipeline
                        cost-capped IPA inside each share
* ``split_fa2_low`` / ``split_fa2_high`` / ``split_rim``
                     -- same split, paper baselines inside each share

Emits ``BENCH_cluster.json`` next to the repo root and asserts the
headline: IPA-joint achieves strictly higher mean PAS than every
proportional static-split baseline at the same total core budget.
Every policy record carries the per-phase wall breakdown
(``solver_wall_s`` — time inside the joint solver, surfaced by
``ClusterTraceResult`` — vs ``sim_wall_s``) plus the run's
``FrontierCache`` hit/miss stats, so solver-vs-simulator regressions
are attributable from the JSON alone.
``--smoke`` runs a seconds-scale 2-pipeline subset and gates on
*pointwise solver dominance*: at every adaptation boundary's demand
vector, whenever the split is feasible the joint knapsack must be
feasible with at least the split's objective — that IS guaranteed by
construction (the split's feasible set is a subset of the joint's), so a
violation always means the arbitration layer broke.  (The realized
trajectory means are NOT construction-guaranteed — hold dynamics differ
between policies — so they gate only the full run, where they are
deterministic under the fixed seeds.)  Wired into ``scripts/tier1.sh``.

The ``hetero`` scenario makes device class a planning axis: every variant
also ships a faster gpu build, the budget is per-class
(``{"cpu": C, "gpu": small}``) and the joint multi-dimensional knapsack
is gated against a family of per-class proportional splits (demand,
uniform, midpoint) — split feasible sets are subsets of the joint's, so
joint >= every split is construction-guaranteed pointwise, the full run
demands a strict win somewhere plus a realized mean-PAS win, every solve
(including a 60-pipeline scale probe) must fit the 10 s decision
interval, and both event cores must replay each plan bit-identically.

The ``switch`` scenario replays the joint policy with the §5.3 adaptation
window modeled (8 s during which a reconfigured pipeline serves its old
config) with and without switch-cost hysteresis, recording
reconfigurations/hour and realized mean PAS for both.  Gates: hysteresis
must reconfigure strictly less often (``--smoke``: no more often) at
equal-or-better realized mean PAS, and — the overlap invariant — the
cores held by the *serving* replica fleets must never exceed C at any
instant (``peak_serving_cores <= C``): the overlap-aware solver plans
each changed pipeline at ``max(old, new)`` through its window and the
simulator's transition-charged ledger enforces the same at decision
time, so a downsizer's freed cores are never granted to a grower before
the window closes.  The penalty is sized at the scale of the objective's
cost-term churn (beta x a few cores), so accuracy-driven switches always
clear it and only PAS-neutral replica-shuffling thrash is suppressed.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import adapter as AD                      # noqa: E402
from repro.core import baselines as BL                    # noqa: E402
from repro.core import optimizer as OPT                   # noqa: E402
from repro.core.cluster import ClusterModel               # noqa: E402
from repro.core.pipeline import (DeviceProfile, ModelVariant,  # noqa: E402
                                 PipelineModel, StageModel)

POLICIES = ("ipa", "split_ipa", "split_fa2_low", "split_fa2_high",
            "split_rim")
OBJ = OPT.Objective(alpha=1.0, beta=0.02, delta=1e-6, metric="pas")
# §5.3: ~8 s adaptation process per reconfiguration; the hysteresis
# penalty is that transition expressed as lost objective, sized to the
# cost-term churn scale (see module docstring): beta x 4 cores.  Overlap-
# aware arbitration already makes every switch consume transition headroom
# (the max(old, new) charge), so the explicit penalty sits one notch below
# the pre-overlap beta x 5 — at beta x 5 the hysteresis run starts holding
# through accuracy-driven switches and loses realized PAS.
ADAPT_DELAY_S = 8.0
SWITCH_COST = 0.08
# decision ceiling for the hetero scenario: every joint multi-dimensional
# knapsack solve — including the 60-pipeline scale probe — must fit the
# 10 s adaptation interval
SOLVE_CEILING_S = 10.0


def _pipeline(name: str, l1a: float, l1b: float, accs) -> PipelineModel:
    """Two-stage pipeline with light/mid/heavy variants per stage; the
    accuracy spread differs per pipeline so the marginal accuracy-per-core
    differs — exactly what joint arbitration exploits."""
    def stage(sname, l1):
        variants = tuple(
            ModelVariant(f"{sname}_{tag}", acc, alloc,
                         (l1 * scale * 0.002, l1 * scale * 0.7,
                          l1 * scale * 0.3))
            for tag, acc, alloc, scale in zip(
                ("light", "mid", "heavy"), accs, (1, 2, 4), (1.0, 1.8, 3.2)))
        return StageModel(sname, variants, sla=5 * l1 * 1.8,
                          batch_choices=(1, 2, 4, 8, 16))
    return PipelineModel(name, (stage(f"{name}_a", l1a),
                                stage(f"{name}_b", l1b)))


def make_cluster(n_pipelines: int) -> ClusterModel:
    protos = [
        _pipeline("vision", 0.040, 0.030, (55.0, 71.0, 82.0)),
        _pipeline("audio", 0.050, 0.020, (62.0, 70.0, 76.0)),
        _pipeline("nlp", 0.030, 0.030, (66.0, 74.0, 80.0)),
        _pipeline("video", 0.045, 0.025, (52.0, 68.0, 84.0)),
    ]
    return ClusterModel("bench_cluster", tuple(protos[:n_pipelines]))


def anti_correlated_traces(seconds: int, n: int, seed: int = 7,
                           base: float = 4.0, amp: float = 22.0,
                           cycle: float = 90.0, decay: float = 14.0):
    """Rotating bursts: pipeline i spikes while the others idle at base
    load, phase-shifted so at most one pipeline is near peak at a time."""
    rng = np.random.default_rng(seed)
    t = np.arange(seconds, dtype=np.float64)
    traces = []
    for i in range(n):
        phase = (t - i * cycle / n) % cycle
        burst = amp * np.exp(-phase / decay)
        noise = rng.normal(0.0, 0.4, seconds)
        traces.append(np.clip(base + burst + noise, 0.5, None))
    return traces


def pick_budget(cluster: ClusterModel, rates, frac: float = 0.7) -> int:
    """Size C off the worst rotating window (one pipeline at peak, the
    rest at base): ``frac`` of the unconstrained joint cost there, so the
    budget binds during every burst and arbitration actually matters."""
    unbounded = ClusterModel(cluster.name, cluster.pipelines, float("inf"))
    peaks = [float(r.max()) for r in rates]
    bases = [float(np.median(r)) for r in rates]
    worst = 0.0
    for i in range(len(rates)):
        lams = [p if j == i else b
                for j, (p, b) in enumerate(zip(peaks, bases))]
        sol = OPT.solve_cluster(unbounded, lams, OBJ)
        worst = max(worst, sol.cost)
    return max(int(round(frac * worst)), len(rates) * 2)


def solver_dominance_check(cluster, rates, interval: float = 10.0) -> list:
    """Pointwise arbitration check at every adaptation boundary's reactive
    demand vector: split feasible => joint feasible with >= objective.
    Returns a list of violation strings (empty = arbitration healthy)."""
    horizon = max(len(r) for r in rates)
    fails = []
    for t0 in np.arange(0.0, horizon, interval):
        # the same estimator the adapter uses, so the gate probes exactly
        # the demand vectors the trajectory visits
        lam_hat = [AD.reactive_demand(r, float(t0), interval) for r in rates]
        split = BL.cluster_split(cluster, lam_hat, "ipa", OBJ)
        if not split.feasible:
            continue
        joint = BL.cluster_ipa(cluster, lam_hat, OBJ)
        if not joint.feasible or joint.objective < split.objective - 1e-9:
            fails.append(
                f"t={t0}: joint {joint.objective if joint.feasible else 'infeasible'}"
                f" < split {split.objective} at lam={lam_hat}")
    return fails


def switch_scenario(cluster, rates, seconds: int, smoke: bool):
    """Joint policy with the §5.3 adaptation window, with vs. without
    switch-cost hysteresis, plus the overlap invariant: instantaneous
    serving cost <= C at every instant of every run.  Returns
    (record, failures)."""
    runs = {}
    fails = []
    for tag, sc in (("no_hysteresis", 0.0), ("hysteresis", SWITCH_COST)):
        res = AD.run_cluster_trace(cluster, rates, policy="ipa", obj=OBJ,
                                   seed=11, switch_cost=sc,
                                   adaptation_delay=ADAPT_DELAY_S)
        runs[tag] = {
            "switch_cost": sc,
            "reconfigs": res.n_reconfigs,
            "reconfigs_per_hour": round(res.n_reconfigs * 3600.0 / seconds, 1),
            "mean_pas": round(res.mean_pas, 3),
            "mean_cost": round(res.mean_cost, 2),
            "peak_serving_cores": round(res.peak_serving_cores, 2),
            "dropped": res.dropped,
            "solver_wall_s": round(res.solver_wall_s, 3),
            "frontier_cache": res.frontier_cache_stats,
        }
        print(f"switch/{tag}: reconfigs={res.n_reconfigs} "
              f"({runs[tag]['reconfigs_per_hour']}/h) "
              f"pas={runs[tag]['mean_pas']} "
              f"peak_serving={runs[tag]['peak_serving_cores']} "
              f"dropped={res.dropped}")
        if res.peak_serving_cores > cluster.cores + 1e-9:
            fails.append(
                f"switch/{tag}: serving cost transiently exceeded the "
                f"budget ({res.peak_serving_cores} > {cluster.cores}) — "
                f"the transition-overlap invariant is broken")
    no_h, hyst = runs["no_hysteresis"], runs["hysteresis"]
    if smoke:
        if hyst["reconfigs"] > no_h["reconfigs"]:
            fails.append(f"switch: hysteresis reconfigured more often "
                         f"({hyst['reconfigs']} > {no_h['reconfigs']})")
    elif hyst["reconfigs"] >= no_h["reconfigs"]:
        fails.append(f"switch: hysteresis must reconfigure strictly less "
                     f"({hyst['reconfigs']} >= {no_h['reconfigs']})")
    if hyst["mean_pas"] < no_h["mean_pas"] - 1e-9:
        fails.append(f"switch: hysteresis lost realized PAS "
                     f"({hyst['mean_pas']} < {no_h['mean_pas']})")
    record = {"adaptation_delay_s": ADAPT_DELAY_S, **runs}
    return record, fails


def dag_scenario(smoke: bool):
    """Joint IPA on the video fan-out DAG vs the chain-linearized plan at
    equal cost budget.

    The linearized planner (pre-DAG IPA) charges every stage's latency
    against one serial budget; the DAG planner prices latency along the
    critical path, so at the linearized plan's own cost its feasible set
    is a strict superset — the DAG objective can never be lower (the gate
    is construction-guaranteed, never flaky) and is strictly higher
    wherever the slack on the off-critical branch buys a heavier variant.
    Each plan is then replayed through the DAG simulator (fan-out, join,
    drop propagation) on both event cores, which must agree exactly.
    Returns (record, failures)."""
    from repro.core.paper_profiles import video_fanout
    from repro.core.pipeline import PipelineConfig
    from repro.core.simulator import (PipelineSimulator,
                                      StructPipelineSimulator)

    dag = video_fanout()
    lin = dag.linearize()
    rates = (8.0, 16.0) if smoke else (4.0, 8.0, 12.0, 16.0, 20.0, 24.0)
    seconds = 20 if smoke else 60
    fails = []
    rows = []
    strictly_better = False

    def replay(config: PipelineConfig, lam: float) -> dict:
        rng = np.random.default_rng(23)
        times = np.cumsum(rng.exponential(1.0 / lam, int(lam * seconds)))
        out = {}
        for tag, cls in (("heap", PipelineSimulator),
                         ("struct", StructPipelineSimulator)):
            sim = cls(dag, config, drop_factor=2.0, max_wait=0.5)
            sim.lam_est = lam
            sim.inject_arrivals(times)
            sim.run_until(float(times[-1]) + 10.0)
            m = sim.metrics
            out[tag] = (m.arrived, m.completed, m.dropped,
                        sim.events_processed, m.latencies.tobytes())
        if out["heap"] != out["struct"]:
            fails.append(f"dag: event cores diverged at lam={lam}: "
                         f"{out['heap'][:4]} vs {out['struct'][:4]}")
        arrived, completed, dropped, _, _ = out["heap"]
        lats = np.frombuffer(out["heap"][4])
        return {
            "arrived": arrived, "completed": completed, "dropped": dropped,
            "p99_latency_s": round(float(np.percentile(lats, 99)), 4)
            if lats.size else None,
        }

    for lam in rates:
        sol_lin = OPT.solve_vec(lin, lam, OBJ)
        if not sol_lin.feasible:
            fails.append(f"dag: linearized plan infeasible at lam={lam}")
            continue
        sol_dag = OPT.solve_capped(dag, lam, OBJ, cost_cap=sol_lin.cost)
        if not sol_dag.feasible:
            fails.append(f"dag: DAG plan infeasible at lam={lam} under the "
                         f"linearized plan's own budget {sol_lin.cost} — "
                         f"the feasible-set superset is broken")
            continue
        if sol_dag.objective < sol_lin.objective - 1e-9:
            fails.append(f"dag: DAG objective {sol_dag.objective} < "
                         f"linearized {sol_lin.objective} at lam={lam} at "
                         f"equal budget {sol_lin.cost}")
        if sol_dag.objective > sol_lin.objective + 1e-9:
            strictly_better = True
        rows.append({
            "lam": lam, "cost_budget": sol_lin.cost,
            "lin_objective": round(sol_lin.objective, 4),
            "dag_objective": round(sol_dag.objective, 4),
            "lin_pas": round(sol_lin.pas, 4),
            "dag_pas": round(sol_dag.pas, 4),
            "lin_sla_bound_s": round(sol_lin.latency, 4),
            "dag_critical_path_s": round(sol_dag.latency, 4),
            "realized_lin": replay(sol_lin.config, lam),
            "realized_dag": replay(sol_dag.config, lam),
        })
        print(f"dag lam={lam}: budget={sol_lin.cost} "
              f"lin_obj={rows[-1]['lin_objective']} "
              f"dag_obj={rows[-1]['dag_objective']} "
              f"dag_completed={rows[-1]['realized_dag']['completed']}"
              f"/{rows[-1]['realized_dag']['arrived']}")
    if not strictly_better:
        fails.append("dag: DAG plan never strictly beat the linearized "
                     "plan at any rate — critical-path slack buys nothing")
    record = {"pipeline": dag.name, "paths": [list(p) for p in dag.paths()],
              "sla_s": round(dag.sla, 4), "rates": rows}
    return record, fails


def _hetero_pipeline(name: str, l1a: float, l1b: float, accs,
                     gpu_speed: float = 4.0) -> PipelineModel:
    """``_pipeline`` with a gpu build per variant: ``gpu_speed``x faster at
    one gpu unit per replica and +2 accuracy (reduced-precision builds are
    profiled separately) — every pipeline *wants* the gpu, so a scarce gpu
    budget creates the contention the joint solver has to arbitrate."""
    def stage(sname, l1):
        variants = []
        for tag, acc, alloc, scale in zip(
                ("light", "mid", "heavy"), accs, (1, 2, 4), (1.0, 1.8, 3.2)):
            coeffs = (l1 * scale * 0.002, l1 * scale * 0.7, l1 * scale * 0.3)
            gpu_coeffs = tuple(c / gpu_speed for c in coeffs)
            variants.append(ModelVariant(
                f"{sname}_{tag}", acc, alloc, coeffs,
                device_profiles=(DeviceProfile("cpu", coeffs, alloc, acc),
                                 DeviceProfile("gpu", gpu_coeffs, 1,
                                               acc + 2.0))))
        return StageModel(sname, tuple(variants), sla=5 * l1 * 1.8,
                          batch_choices=(1, 2, 4, 8, 16))
    return PipelineModel(name, (stage(f"{name}_a", l1a),
                                stage(f"{name}_b", l1b)))


def make_hetero_cluster(n_pipelines: int, cpu: float, gpu: float
                        ) -> ClusterModel:
    protos = [
        _hetero_pipeline("vision", 0.040, 0.030, (55.0, 71.0, 82.0)),
        _hetero_pipeline("audio", 0.050, 0.020, (62.0, 70.0, 76.0)),
        _hetero_pipeline("nlp", 0.030, 0.030, (66.0, 74.0, 80.0)),
        _hetero_pipeline("video", 0.045, 0.025, (52.0, 68.0, 84.0)),
    ]
    pipes = tuple(protos[i % len(protos)] if i < len(protos) else
                  _hetero_pipeline(f"{protos[i % len(protos)].name}{i}",
                                   0.030 + 0.005 * (i % 4),
                                   0.020 + 0.004 * (i % 3),
                                   (55.0 + (i % 5), 70.0 + (i % 4),
                                    80.0 + (i % 6)))
                  for i in range(n_pipelines))
    return ClusterModel("bench_hetero", pipes, cores={"cpu": cpu, "gpu": gpu})


def _split_objective(cluster, lams, shares, cache=None):
    """Objective of a generic per-class proportional split: pipeline i
    plans alone inside ``shares[i]`` of EVERY class budget.  Any such
    partition's feasible set is a subset of the joint solver's, so the
    joint objective is >= this by construction.  Returns -inf when any
    share is infeasible (the split policy would hold)."""
    classes = cluster.device_classes
    budgets = cluster.budget_vector
    total = 0.0
    for pipe, lam, share, w in zip(cluster.pipelines, lams, shares,
                                   cluster.weights):
        cap = tuple(share * b for b in budgets)
        sol = OPT.solve_capped(pipe, lam, OBJ, cap, cache=cache,
                               classes=classes)
        if not sol.feasible:
            return -np.inf
        total += w * sol.objective
    return total


def hetero_scenario(smoke: bool, seconds: int):
    """Device class as a planning axis: joint multi-dimensional knapsack
    vs per-class proportional splits under gpu contention.

    Gates (construction-guaranteed, never flaky):
      * at every adaptation boundary's demand vector, the joint solver's
        objective is >= EVERY per-class proportional split tried (demand-
        proportional, uniform, and their midpoint) — each split's
        feasible set is a subset of the joint's;
      * every joint solve finishes under ``SOLVE_CEILING_S``, including a
        wide scale probe (60 pipelines full, 12 smoke);
      * the chosen plans replay bit-identically through both event cores.
    The full run additionally requires a strict win over the *best* split
    at some boundary (gpu contention must actually pay) and a realized
    mean-PAS win for the joint trace over ``split_ipa``.
    Returns (record, failures)."""
    n = 2 if smoke else 3
    gpu_budget = 2.0 if smoke else 3.0
    base = make_hetero_cluster(n, cpu=1.0, gpu=gpu_budget)
    rates = anti_correlated_traces(seconds, n, seed=13)
    # size the cpu budget off the cpu-only demand peak (the homogeneous
    # cluster shares the hetero pipelines' cpu tables) so bursts bind on
    # cpu and the scarce gpu is genuinely contended
    cpu_budget = float(pick_budget(
        ClusterModel("tmp", make_cluster(n).pipelines, float("inf")), rates))
    cluster = ClusterModel(base.name, base.pipelines,
                           cores={"cpu": cpu_budget, "gpu": gpu_budget})
    print(f"hetero: {n} pipelines, C={{cpu: {cpu_budget:.0f}, "
          f"gpu: {gpu_budget:.0f}}}, {seconds}s traces")
    fails = []
    cache = OPT.FrontierCache()
    uniform = [1.0 / n] * n
    rows = []
    strict_win = False
    max_solve = 0.0
    interval = 10.0
    for t0 in np.arange(0.0, float(max(len(r) for r in rates)), interval):
        lam_hat = [AD.reactive_demand(r, float(t0), interval) for r in rates]
        joint = BL.cluster_ipa(cluster, lam_hat, OBJ, cache=cache)
        max_solve = max(max_solve, joint.solve_time)
        demand = [lam / sum(lam_hat) for lam in lam_hat]
        split_objs = {
            "demand": _split_objective(cluster, lam_hat, demand, cache),
            "uniform": _split_objective(cluster, lam_hat, uniform, cache),
            "mid": _split_objective(
                cluster, lam_hat,
                [(d + u) / 2 for d, u in zip(demand, uniform)], cache),
        }
        best_name = max(split_objs, key=lambda k: split_objs[k])
        best = split_objs[best_name]
        if np.isfinite(best):
            if not joint.feasible or joint.objective < best - 1e-9:
                fails.append(
                    f"hetero: joint "
                    f"{joint.objective if joint.feasible else 'infeasible'} "
                    f"< split[{best_name}] {best} at t={t0} lam={lam_hat}")
            elif joint.objective > best + 1e-9:
                strict_win = True
        rows.append({"t": float(t0),
                     "joint_objective": round(joint.objective, 4)
                     if joint.feasible else None,
                     "best_split": best_name,
                     "split_objectives": {k: (round(v, 4)
                                              if np.isfinite(v) else None)
                                          for k, v in split_objs.items()},
                     "solve_s": round(joint.solve_time, 4)})
    if max_solve > SOLVE_CEILING_S:
        fails.append(f"hetero: joint solve took {max_solve:.2f}s "
                     f"(> {SOLVE_CEILING_S}s decision ceiling)")
    if not smoke and not strict_win:
        fails.append("hetero: joint never strictly beat the best per-class "
                     "split at any boundary — gpu contention buys nothing")

    # scale probe: one wide joint solve must fit the decision interval
    n_wide = 12 if smoke else 60
    wide = make_hetero_cluster(n_wide, cpu=float(n_wide * 8),
                               gpu=float(max(n_wide // 4, 4)))
    lams_wide = [4.0 + (i % 7) for i in range(n_wide)]
    sol_wide = BL.cluster_ipa(wide, lams_wide, OBJ)
    if not sol_wide.feasible:
        fails.append(f"hetero: {n_wide}-pipeline scale probe infeasible")
    if sol_wide.solve_time > SOLVE_CEILING_S:
        fails.append(f"hetero: {n_wide}-pipeline solve took "
                     f"{sol_wide.solve_time:.2f}s (> {SOLVE_CEILING_S}s)")
    print(f"hetero: scale probe n={n_wide} solve={sol_wide.solve_time:.2f}s "
          f"max boundary solve={max_solve:.3f}s")

    # realized traces, both policies, both event cores bit-identical
    realized = {}
    for pol in ("ipa", "split_ipa"):
        reps = {}
        for core in ("heap", "struct"):
            res = AD.run_cluster_trace(cluster, rates, policy=pol, obj=OBJ,
                                       seed=11, event_core=core)
            reps[core] = res
        a, b = reps["heap"], reps["struct"]
        sig = lambda r: (r.sim_events, r.n_reconfigs, r.completed, r.dropped,  # noqa: E731,E501
                         round(r.peak_serving_cores, 6),
                         tuple((p.arrived, p.completed, p.dropped)
                               for p in r.per_pipeline))
        if sig(a) != sig(b):
            fails.append(f"hetero: event cores diverged for {pol}: "
                         f"{sig(a)} vs {sig(b)}")
        realized[pol] = {
            "mean_pas": round(a.mean_pas, 3),
            "mean_cost": round(a.mean_cost, 2),
            "dropped": a.dropped, "completed": a.completed,
            "sim_events": a.sim_events, "n_reconfigs": a.n_reconfigs,
            "peak_serving_cores": round(a.peak_serving_cores, 2),
        }
        print(f"hetero/{pol}: pas={realized[pol]['mean_pas']} "
              f"cost={realized[pol]['mean_cost']} "
              f"dropped={realized[pol]['dropped']}")
    if not smoke and realized["ipa"]["mean_pas"] <= \
            realized["split_ipa"]["mean_pas"]:
        fails.append(f"hetero: realized joint PAS "
                     f"{realized['ipa']['mean_pas']} <= split "
                     f"{realized['split_ipa']['mean_pas']}")
    record = {
        "n_pipelines": n,
        "budgets": {"cpu": cpu_budget, "gpu": gpu_budget},
        "max_boundary_solve_s": round(max_solve, 4),
        "scale_probe": {"n_pipelines": n_wide,
                        "solve_s": round(sol_wide.solve_time, 4),
                        "ceiling_s": SOLVE_CEILING_S},
        "strict_win": strict_win,
        "realized": realized,
        "boundaries": rows,
    }
    return record, fails


def bench_policies(cluster, rates, policies) -> dict:
    out = {}
    for pol in policies:
        t0 = time.perf_counter()
        res = AD.run_cluster_trace(cluster, rates, policy=pol, obj=OBJ,
                                   seed=11)
        wall = time.perf_counter() - t0
        out[pol] = {
            "wall_s": round(wall, 3),
            "solver_wall_s": round(res.solver_wall_s, 3),
            "sim_wall_s": round(wall - res.solver_wall_s, 3),
            "events_per_sec": round(res.sim_events / max(wall, 1e-9)),
            "frontier_cache": res.frontier_cache_stats,
            "sim_events": res.sim_events,
            "peak_queue_depth": res.peak_queue_depth,
            "mean_pas": round(res.mean_pas, 3),
            "mean_cost": round(res.mean_cost, 2),
            "mean_objective": round(res.mean_objective(OBJ), 3),
            "dropped": res.dropped,
            "completed": res.completed,
            "per_pipeline_pas": [round(r.mean_pas, 3)
                                 for r in res.per_pipeline],
            "per_pipeline_cost": [round(r.mean_cost, 2)
                                  for r in res.per_pipeline],
        }
        print(f"policy {pol}: pas={out[pol]['mean_pas']} "
              f"cost={out[pol]['mean_cost']} "
              f"obj={out[pol]['mean_objective']} "
              f"dropped={out[pol]['dropped']} ({out[pol]['wall_s']}s wall)")
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale 2-pipeline run for the tier-1 "
                         "gate; asserts joint >= split objective but does "
                         "not overwrite BENCH_cluster.json")
    ap.add_argument("--seconds", type=int, default=None,
                    help="trace length (default: 300, smoke: 40)")
    ap.add_argument("--pipelines", type=int, default=None,
                    help="cluster size 2-4 (default: 3, smoke: 2)")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: <repo>/BENCH_cluster.json)")
    args = ap.parse_args()

    seconds = args.seconds or (40 if args.smoke else 300)
    n_pipes = args.pipelines or (2 if args.smoke else 3)
    cluster0 = make_cluster(n_pipes)
    rates = anti_correlated_traces(seconds, n_pipes)
    budget = pick_budget(cluster0, rates)
    cluster = ClusterModel(cluster0.name, cluster0.pipelines, float(budget))
    print(f"cluster: {n_pipes} pipelines, C={budget} cores, {seconds}s "
          f"anti-correlated bursty traces "
          f"(rate {min(r.min() for r in rates):.1f}-"
          f"{max(r.max() for r in rates):.1f} rps)")

    policies = ("ipa", "split_ipa") if args.smoke else POLICIES
    results = bench_policies(cluster, rates, policies)
    switch_rec, switch_fails = switch_scenario(cluster, rates, seconds,
                                               args.smoke)
    dag_rec, dag_fails = dag_scenario(args.smoke)
    hetero_rec, hetero_fails = hetero_scenario(args.smoke,
                                               40 if args.smoke else seconds)

    # pointwise arbitration health: construction-guaranteed, never flaky
    fails = (solver_dominance_check(cluster, rates) + switch_fails
             + dag_fails + hetero_fails)
    if not args.smoke:
        # realized headline (deterministic under the fixed seeds): joint
        # strictly beats every split on mean PAS at the same budget
        ipa_r = results["ipa"]
        for pol in policies:
            if pol == "ipa":
                continue
            if ipa_r["mean_pas"] <= results[pol]["mean_pas"]:
                fails.append(f"pas: ipa {ipa_r['mean_pas']} <= "
                             f"{pol} {results[pol]['mean_pas']}")
    if fails:
        for f in fails:
            print(f"FAIL: {f}")
        return 1
    print("PASS: IPA joint arbitration dominates the static split "
          f"({'pointwise objective' if args.smoke else 'pointwise objective + realized mean PAS'}) "
          f"at C={budget}")

    result = {
        "bench": "cluster_cosched",
        "trace_seconds": seconds,
        "n_pipelines": n_pipes,
        "core_budget": budget,
        "objective": {"alpha": OBJ.alpha, "beta": OBJ.beta,
                      "delta": OBJ.delta, "metric": OBJ.metric},
        "smoke": bool(args.smoke),
        "policies": results,
        "switch": switch_rec,
        "dag": dag_rec,
        "hetero": hetero_rec,
    }
    if not args.smoke or args.out:
        out = args.out or os.path.join(os.path.dirname(__file__), "..",
                                       "BENCH_cluster.json")
        with open(out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {os.path.abspath(out)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
