"""Shared ``--profile`` support for the benchmark scripts.

Wraps a bench section in ``cProfile`` and prints the top-25 functions by
cumulative time, so perf PRs start from evidence instead of guesses.
Profiling roughly doubles interpreter overhead, so callers skip their
hard throughput gates when it is on (the numbers are for reading, not
ratcheting).
"""
from __future__ import annotations

import cProfile
import pstats
from typing import Callable, TypeVar

T = TypeVar("T")

TOP_N = 25


def maybe_profile(enabled: bool, label: str, fn: Callable[[], T]) -> T:
    """Run ``fn`` (optionally under cProfile) and return its result.

    When ``enabled``, dumps the top-``TOP_N`` cumulative-time rows to
    stdout under a ``label`` header after the call."""
    if not enabled:
        return fn()
    prof = cProfile.Profile()
    prof.enable()
    try:
        out = fn()
    finally:
        prof.disable()
    print(f"\n=== cProfile [{label}] — top {TOP_N} by cumulative time ===")
    pstats.Stats(prof).strip_dirs().sort_stats("cumulative").print_stats(
        TOP_N)
    return out
