#!/usr/bin/env python
"""BENCH_scale: the production-scale scenario the ROADMAP north star asks
for — 50-100 pipelines on one shared pool at C>=512, thousands of
aggregate RPS synthesized from the heavy-tailed / flash-crowd excerpts
(``trace.scale_excerpt`` with the ``scale`` knob).

Three sections, emitted to ``BENCH_scale.json``:

* ``simulator`` — the same capacity-constrained replay (one joint-solver
  config, bulk-injected arrivals, windowed ``run_until``) through both
  event cores, recording wall, events and ev/s each.  The structured-
  array core must sustain >= 2x the heapq core's ev/s (>= 1.5x in
  ``--smoke``, where fixed costs loom larger) *and* land bit-identical
  aggregate metrics — the speedup is only admissible because the replay
  is event-for-event the same simulation.
* ``solver`` — ``optimizer.solve_cluster`` at the full pipeline count
  and budget in every planning mode the adapter uses (plain, switch-cost
  hysteresis, budgeted 2-D, overlap-aware transition planning).  Each
  solve must fit the paper's ~10 s decision interval (2 s in smoke);
  at C>=512 this is what the dominance-pruned knapsack buys.
* ``adapter`` (full runs only) — a short end-to-end ``run_cluster_trace``
  per event core: the whole monitor/predict/optimize/reconfigure loop
  must produce identical completed/dropped/event counts on both cores,
  and the JSON records the solver-vs-simulator wall split.

``peak_rss_mb`` records the process high-water mark after the heaviest
section.  ``--smoke`` (wired into ``scripts/tier1.sh``) shrinks the
trace, keeps the pipeline count at 50 and the budget at C=512, gates the
ev/s floor, the speedup ratio and the solver-wall ceiling, and writes
nothing.
"""
from __future__ import annotations

import argparse
import json
import os
import resource
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import adapter as AD                      # noqa: E402
from repro.core import optimizer as OPT                   # noqa: E402
from repro.core import trace as TR                        # noqa: E402
from repro.core.cluster import ClusterModel               # noqa: E402
from repro.core.pipeline import (ModelVariant, PipelineModel,  # noqa: E402
                                 StageModel)
from repro.core.simulator import make_cluster_simulator   # noqa: E402

from profiling_util import maybe_profile                  # noqa: E402

CORES = 512.0
EVENT_CORES = ("heap", "struct", "round")
OBJ = OPT.Objective(alpha=1.0, beta=0.02, delta=1e-6, metric="pas")


def build_cluster(n_pipes: int, rng: np.random.Generator) -> ClusterModel:
    """Two-stage pipelines with three variants per stage — the same shape
    the cluster bench uses, multiplied to production counts."""
    def stage(sname: str, l1: float) -> StageModel:
        variants = tuple(
            ModelVariant(f"{sname}_{tag}", acc, alloc,
                         (0.0, l1 * sc * 0.7, l1 * sc * 0.3))
            for tag, acc, alloc, sc in zip(
                ("light", "mid", "heavy"), (55.0, 70.0, 80.0), (1, 2, 4),
                (1.0, 1.8, 3.2)))
        return StageModel(sname, variants, sla=9.0 * l1,
                          batch_choices=(1, 2, 4, 8, 16))

    pipes = tuple(
        PipelineModel(f"p{i}", (
            stage(f"p{i}_a", 0.03 + 0.02 * rng.random()),
            stage(f"p{i}_b", 0.02 + 0.02 * rng.random())))
        for i in range(n_pipes))
    return ClusterModel("scale", pipes, CORES)


def build_traces(n_pipes: int, seconds: int, scale: float):
    """Alternate the two production stress shapes across pipelines."""
    rates, times = [], []
    for i in range(n_pipes):
        kind = TR.SCALE_EXCERPTS[i % len(TR.SCALE_EXCERPTS)]
        cfg = TR.TraceConfig(
            seed=i, base_rps=8.0, scale=scale,
            burst_amp=10.0 if kind == "heavy_tailed" else 4.0)
        r = TR.scale_excerpt(kind, seconds, cfg)
        rates.append(r)
        times.append(TR.arrivals_from_rates(r, seed=1000 + i))
    return rates, times


def replay(core: str, cluster, config, times, horizon: float,
           window: float = 10.0):
    """Fixed-config windowed replay; returns (wall_s, events, metrics)."""
    sim = make_cluster_simulator(cluster, config, event_core=core)
    t0 = time.perf_counter()
    for p, tt in enumerate(times):
        sim.inject_arrivals(tt, p)
    edge = 0.0
    while edge < horizon:
        edge += window
        sim.run_until(edge)
    wall = time.perf_counter() - t0
    metrics = [(m.arrived, m.completed, m.dropped)
               for m in sim.metrics_by_pipe]
    return wall, sim.events_processed, metrics


def bench_solver(cluster, lam0, lam1, switch_budget: int):
    """Wall time per planning mode, fresh caches (a cold boundary)."""
    walls = {}
    t0 = time.perf_counter()
    base = OPT.solve_cluster(cluster, lam0, OBJ)
    walls["plain_1d_s"] = time.perf_counter() - t0
    assert base.feasible, "scale scenario must be plannable at C=512"
    modes = {
        "switch_1d_s": dict(current=base.config, switch_cost=0.1),
        "budgeted_2d_s": dict(current=base.config, switch_cost=0.1,
                              switch_budget=switch_budget),
        "overlap_2d_s": dict(current=base.config, switch_cost=0.1,
                             switch_budget=switch_budget, overlap=True,
                             serving=base.config),
    }
    for name, kw in modes.items():
        t0 = time.perf_counter()
        OPT.solve_cluster(cluster, lam1, OBJ, **kw)
        walls[name] = time.perf_counter() - t0
    return base, walls


def adapter_section(cluster, rates, seconds: int, profile: bool = False):
    """End-to-end adaptation loop on every core: identical results, and
    the solver/simulator wall split the JSON promises."""
    out = {}
    check = {}
    for core in EVENT_CORES:
        t0 = time.perf_counter()
        res = maybe_profile(
            profile, f"adapter:{core}",
            lambda: AD.run_cluster_trace(
                cluster, rates, policy="ipa", obj=OBJ, interval=10.0,
                switch_cost=0.1,
                switch_budget=max(4, cluster.n_pipelines // 8),
                adaptation_delay=8.0, event_core=core))
        wall = time.perf_counter() - t0
        out[core] = {
            "trace_wall_s": round(wall, 3),
            "solver_wall_s": round(res.solver_wall_s, 3),
            "sim_wall_s": round(wall - res.solver_wall_s, 3),
            "sim_events": res.sim_events,
        }
        check[core] = (res.sim_events, res.n_reconfigs,
                       [(r.arrived, r.completed, r.dropped)
                        for r in res.per_pipeline])
    assert check["heap"] == check["struct"] == check["round"], \
        "adapter diverges between event cores"
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale gated subset for tier-1; no JSON")
    ap.add_argument("--profile", action="store_true",
                    help="cProfile each replay/adapter run and print the "
                         "top-25 cumulative table; throughput gates are "
                         "informational only under profiling overhead")
    args = ap.parse_args()

    n_pipes = 50 if args.smoke else 60
    seconds = 12 if args.smoke else 120
    scale = 6.0 if args.smoke else 5.0
    # ratio floors are 1.3/1.25 in both modes: the heapq reference core
    # itself got markedly faster on the current container (59-74k ev/s
    # vs the 43k the 2.32x artifact was recorded at), compressing the
    # ratios while struct/round ev/s held — the absolute ev/s floors
    # below carry the per-core ratchet; walls are best-of-N to keep the
    # ratios from flaking on one-off scheduler noise
    min_speedup = 1.3
    # the service-round engine must clearly beat the scalar struct core
    # in-run (ratio, noise-robust) AND in absolute ev/s (ratcheted from
    # the pre-round 40k struct floor)
    min_round_speedup = 1.25
    max_solve_s = 2.0 if args.smoke else 10.0
    min_evps = 40_000.0
    min_round_evps = 80_000.0
    if args.profile:                     # informational run, gates off
        min_speedup = min_round_speedup = 0.0
        min_evps = min_round_evps = 0.0
        max_solve_s = float("inf")

    rng = np.random.default_rng(0)
    cluster = build_cluster(n_pipes, rng)
    rates, times = build_traces(n_pipes, seconds, scale)
    total_arrivals = int(sum(t.size for t in times))
    aggregate_rps = float(sum(r.mean() for r in rates))
    # plan the replay config for the pre-burst base load (20th percentile)
    # — the IPA motivating regime: a flash crowd / Pareto burst lands on a
    # fleet sized for quiet traffic, and the simulator is measured during
    # the saturated window *before* adaptation would kick in
    lam0 = [float(np.percentile(r, 20.0)) for r in rates]
    lam1 = [float(r.max()) for r in rates]

    base, solver_walls = bench_solver(
        cluster, lam0, lam1, switch_budget=max(4, n_pipes // 8))
    worst_solve = max(solver_walls.values())

    horizon = seconds + 30.0
    repeats = 1 if args.profile else (3 if args.smoke else 2)
    sim = {}
    for core in EVENT_CORES:
        wall, events, metrics = maybe_profile(
            args.profile, f"replay:{core}",
            lambda: replay(core, cluster, base.config, times, horizon))
        for _ in range(repeats - 1):        # best-of-N against CPU noise
            w2, e2, m2 = replay(core, cluster, base.config, times, horizon)
            assert (e2, m2) == (events, metrics), \
                f"{core} core replay is nondeterministic"
            wall = min(wall, w2)
        sim[core] = {"wall_s": round(wall, 3), "events": events,
                     "evps": round(events / wall, 1), "metrics": metrics}
    assert sim["heap"]["metrics"] == sim["struct"]["metrics"] \
        == sim["round"]["metrics"], \
        "event cores diverge on the scale replay"
    assert sim["heap"]["events"] == sim["struct"]["events"] \
        == sim["round"]["events"]
    for core in sim:
        del sim[core]["metrics"]
    speedup = sim["struct"]["evps"] / sim["heap"]["evps"]
    round_speedup = sim["round"]["evps"] / sim["struct"]["evps"]

    adapter = None
    if not args.smoke:
        adapter = adapter_section(cluster, rates, seconds,
                                  profile=args.profile)

    peak_rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0

    print(f"scenario: {n_pipes} pipelines, C={CORES:.0f}, {seconds}s, "
          f"{aggregate_rps:.0f} aggregate RPS, {total_arrivals} arrivals")
    for core in EVENT_CORES:
        print(f"  {core:6s}: {sim[core]['events']} events in "
              f"{sim[core]['wall_s']:.2f}s = {sim[core]['evps']/1000:.0f}k "
              f"ev/s")
    print(f"  speedup: struct/heap {speedup:.2f}x (gate >= {min_speedup}x)"
          f"  round/struct {round_speedup:.2f}x "
          f"(gate >= {min_round_speedup}x)")
    print("  solver: " + "  ".join(f"{k}={v*1000:.0f}ms"
                                   for k, v in solver_walls.items())
          + f"  (gate <= {max_solve_s}s per solve)")
    print(f"  peak rss: {peak_rss_mb:.0f} MB")

    assert speedup >= min_speedup, \
        f"struct core speedup {speedup:.2f}x below the {min_speedup}x floor"
    assert sim["struct"]["evps"] >= min_evps, \
        f"struct ev/s {sim['struct']['evps']:.0f} below {min_evps:.0f} floor"
    assert round_speedup >= min_round_speedup, \
        f"round core speedup {round_speedup:.2f}x below the " \
        f"{min_round_speedup}x floor"
    assert sim["round"]["evps"] >= min_round_evps, \
        f"round ev/s {sim['round']['evps']:.0f} below " \
        f"{min_round_evps:.0f} floor"
    assert worst_solve <= max_solve_s, \
        f"solver wall {worst_solve:.2f}s exceeds {max_solve_s}s ceiling"

    if args.smoke:
        print("bench_scale --smoke OK")
        return
    if args.profile:
        # profiled walls are inflated by instrumentation — never let them
        # overwrite the canonical ratchet artifact
        print("bench_scale --profile: JSON not written")
        return

    payload = {
        "scenario": {
            "pipelines": n_pipes, "cores": CORES, "seconds": seconds,
            "scale": scale, "aggregate_rps": round(aggregate_rps, 1),
            "total_arrivals": total_arrivals,
            "excerpts": list(TR.SCALE_EXCERPTS),
        },
        "simulator": {**sim, "speedup": round(speedup, 2),
                      "round_speedup": round(round_speedup, 2),
                      "identical_metrics": True},
        "solver": {**{k: round(v, 4) for k, v in solver_walls.items()},
                   "max_solve_s": round(worst_solve, 4),
                   "decision_interval_s": 10.0},
        "adapter": adapter,
        "peak_rss_mb": round(peak_rss_mb, 1),
    }
    out = os.path.join(os.path.dirname(__file__), "..", "BENCH_scale.json")
    with open(out, "w") as fh:
        json.dump(payload, fh, indent=2)
    print(f"wrote {os.path.abspath(out)}")


if __name__ == "__main__":
    main()
