#!/usr/bin/env python
"""Parallel Pareto sweep: the study runner over
(policy x SLA x core budget C x trace replicate x objective weights).

IPA's claim is a trade-off *surface* — accuracy vs cost vs
reconfigurations under varying SLAs and budgets — so this bench replaces
spot checks with a grid of full policy-trace runs and emits one tidy
``BENCH_sweep.json`` of Pareto surfaces with seed-level 95% confidence
intervals.  The worker side lives in ``repro.core.study``; this script is
the scheduler:

* **fan-out**: cells run on a ``ProcessPoolExecutor`` (spawn context,
  ``study.worker_init`` as the pool initializer so every worker keeps a
  long-lived warm ``FrontierCache`` + trace memo across the cells it
  drains).  Cells are sorted heavy-first (budget x trace length) and
  submitted in small chunks, so free workers steal queued chunks and a
  heavy cell can never straggle the tail of the pool.
* **determinism**: every cell derives its streams from
  ``np.random.SeedSequence`` spawn keys rooted at the grid seed, so the
  aggregate is byte-identical for any worker count; ``--smoke`` proves it
  by running the same tiny grid at nproc=1 and nproc=4 and comparing
  ``study.result_hash`` (wall-clock fields stripped).
* **resume**: each finished cell is an atomic shard in ``--shards``;
  rerunning skips shards whose embedded spec still matches (crash-safe
  incremental progress; ``--fresh`` wipes them).
* **evidence**: the JSON carries per-cell ``solver_wall_s`` /
  ``sim_wall_s`` and per-cell ``FrontierCache`` hit/miss deltas plus a
  straggler rollup, so slow cells and cache-cold policies are diagnosable
  from the artifact alone.

Gates (``--smoke``, wired into ``scripts/tier1.sh``): (a) the nproc=1
and nproc=4 result hashes must be identical; (b) parallel wall at 4
workers must be >= 2x serial on the smoke grid — enforced only on hosts
with >= 4 CPUs (skipped, and recorded as skipped, below that: a
single-core container cannot physically speed up CPU-bound work by
fanning it out).
"""
from __future__ import annotations

import argparse
import json
import multiprocessing as mp
import os
import shutil
import sys
import tempfile
import time
from concurrent.futures import ProcessPoolExecutor, as_completed

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import study as ST                        # noqa: E402

SPEEDUP_FLOOR = 2.0
SPEEDUP_MIN_CPUS = 4

FULL_GRID = dict(policies=("ipa", "ipa_hyst", "split_ipa", "split_fa2_high"),
                 sla_scales=(0.85, 1.0, 1.3), budget_fracs=(0.6, 0.85),
                 reps=3, betas=(0.02,), seconds=240, n_pipelines=3)
SMOKE_GRID = dict(policies=("ipa", "split_ipa"), sla_scales=(1.0, 1.3),
                  budget_fracs=(0.7,), reps=2, betas=(0.02,), seconds=30,
                  n_pipelines=2)


def build_specs(g: dict, root_seed: int) -> tuple:
    budgets = ST.resolve_budgets(g["n_pipelines"], g["budget_fracs"])
    specs = ST.build_grid(g["policies"], g["sla_scales"], budgets,
                          g["reps"], g["betas"], g["seconds"],
                          g["n_pipelines"], root_seed=root_seed)
    return specs, budgets


def run_grid(specs, nproc: int, shard_dir=None, resume: bool = True,
             chunk=None, quiet: bool = False):
    """Drain the grid and return (records in canonical grid order, stats).

    nproc<=1 runs inline in this process (same code path as a worker,
    modulo the process boundary); nproc>1 fans chunks out over a spawn
    pool.  With ``shard_dir`` set, finished cells are persisted as atomic
    shards and — with ``resume`` — matching shards are loaded instead of
    recomputed.
    """
    t0 = time.perf_counter()
    done = {}
    if shard_dir and resume:
        for s in specs:
            rec = ST.load_shard(shard_dir, s)
            if rec is not None:
                done[s.cell_id] = rec
    todo = [s for s in specs if s.cell_id not in done]
    # heavy-first scheduling: the most expensive cells (big C, long
    # traces, wide clusters) enter the pool first, so the inevitable
    # stragglers overlap with the bulk instead of trailing it
    todo.sort(key=lambda s: -(s.seconds * s.budget * s.n_pipelines))
    n_done = 0
    if todo and nproc <= 1:
        ST.worker_init()
        for s in todo:
            rec = ST.run_cell_spec(s)
            if shard_dir:
                ST.write_shard(shard_dir, rec)
            done[s.cell_id] = rec
            n_done += 1
            if not quiet and n_done % 20 == 0:
                print(f"  serial: {n_done}/{len(todo)} cells")
    elif todo:
        # small chunks amortize task dispatch while keeping the queue
        # deep enough for work stealing (a free worker always finds a
        # pending chunk until the very tail)
        if chunk is None:
            chunk = max(1, len(todo) // (nproc * 4))
        chunks = [todo[i:i + chunk] for i in range(0, len(todo), chunk)]
        ctx = mp.get_context("spawn")
        with ProcessPoolExecutor(max_workers=nproc, mp_context=ctx,
                                 initializer=ST.worker_init) as ex:
            futs = [ex.submit(ST.run_chunk, c) for c in chunks]
            for fut in as_completed(futs):
                for rec in fut.result():
                    if shard_dir:
                        ST.write_shard(shard_dir, rec)
                    done[rec["cell"]] = rec
                    n_done += 1
                if not quiet:
                    print(f"  pool({nproc}): {n_done}/{len(todo)} cells")
    records = [done[s.cell_id] for s in specs]
    stats = {"wall_s": round(time.perf_counter() - t0, 3),
             "computed": len(todo), "from_shards": len(specs) - len(todo)}
    return records, stats


def measure_parallel(specs, nproc: int, shard_dir, resume: bool,
                     quiet: bool = False):
    """Serial pass (throwaway shards) then parallel pass (real shards);
    returns (parallel records, parallel-evidence dict, failures)."""
    fails = []
    print(f"serial pass (nproc=1, {len(specs)} cells)...")
    with tempfile.TemporaryDirectory() as td:
        rec_s, st_s = run_grid(specs, 1, td, resume=False, quiet=quiet)
    print(f"  serial wall {st_s['wall_s']}s")
    print(f"parallel pass (nproc={nproc})...")
    rec_p, st_p = run_grid(specs, nproc, shard_dir, resume=resume,
                           quiet=quiet)
    print(f"  parallel wall {st_p['wall_s']}s "
          f"({st_p['from_shards']} from shards)")
    h_s, h_p = ST.result_hash(rec_s), ST.result_hash(rec_p)
    if h_s != h_p:
        fails.append(f"nproc-invariance broken: serial hash {h_s[:16]} != "
                     f"nproc={nproc} hash {h_p[:16]}")
    speedup = round(st_s["wall_s"] / max(st_p["wall_s"], 1e-9), 3)
    cpus = os.cpu_count() or 1
    gate = "enforced" if cpus >= SPEEDUP_MIN_CPUS else \
        f"skipped (<{SPEEDUP_MIN_CPUS} CPUs: host has {cpus})"
    # a fair speedup needs the parallel pass to have computed every cell
    # (a shard-resumed pass measures disk reads, not the pool)
    if st_p["from_shards"] > 0:
        gate = "skipped (parallel pass resumed from shards)"
    if gate == "enforced" and speedup < SPEEDUP_FLOOR:
        fails.append(f"parallel speedup {speedup} < {SPEEDUP_FLOOR}x at "
                     f"{nproc} workers on {cpus} CPUs")
    evidence = {"serial_wall_s": st_s["wall_s"],
                "parallel_wall_s": st_p["wall_s"],
                "workers": nproc, "speedup": speedup,
                "cpu_count": cpus, "speedup_gate": gate,
                "nproc_invariant": h_s == h_p, "result_hash": h_p}
    return rec_p, evidence, fails


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid + the two tier-1 gates; writes no "
                         "JSON unless --out is given")
    ap.add_argument("--nproc", type=int, default=4,
                    help="parallel worker count (default 4)")
    ap.add_argument("--out", default=None,
                    help="output JSON (default <repo>/BENCH_sweep.json; "
                         "smoke: none)")
    ap.add_argument("--shards", default=None,
                    help="shard directory for incremental resume "
                         "(default <repo>/.sweep_shards; smoke: a temp dir)")
    ap.add_argument("--fresh", action="store_true",
                    help="ignore and wipe existing shards")
    ap.add_argument("--no-measure-parallel", action="store_true",
                    help="skip the serial reference pass (resume-friendly; "
                         "the JSON then carries no parallel evidence)")
    ap.add_argument("--seconds", type=int, default=None)
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--pipelines", type=int, default=None)
    ap.add_argument("--root-seed", type=int, default=0)
    ap.add_argument("--policies", default=None,
                    help="comma-separated subset of "
                         f"{sorted(ST.SWEEP_POLICIES)}")
    ap.add_argument("--sla-scales", default=None, help="comma-separated")
    ap.add_argument("--budget-fracs", default=None, help="comma-separated")
    ap.add_argument("--betas", default=None, help="comma-separated")
    args = ap.parse_args()

    g = dict(SMOKE_GRID if args.smoke else FULL_GRID)
    if args.seconds:
        g["seconds"] = args.seconds
    if args.reps:
        g["reps"] = args.reps
    if args.pipelines:
        g["n_pipelines"] = args.pipelines
    if args.policies:
        g["policies"] = tuple(args.policies.split(","))
    if args.sla_scales:
        g["sla_scales"] = tuple(float(x) for x in args.sla_scales.split(","))
    if args.budget_fracs:
        g["budget_fracs"] = tuple(float(x)
                                  for x in args.budget_fracs.split(","))
    if args.betas:
        g["betas"] = tuple(float(x) for x in args.betas.split(","))

    repo = os.path.join(os.path.dirname(__file__), "..")
    specs, budgets = build_specs(g, args.root_seed)
    print(f"grid: {len(g['policies'])} policies x "
          f"{len(g['sla_scales'])} SLA scales x {len(budgets)} budgets "
          f"{budgets} x {g['reps']} reps x {len(g['betas'])} betas "
          f"= {len(specs)} cells ({g['seconds']}s traces, "
          f"{g['n_pipelines']} pipelines)")

    tmp_ctx = None
    if args.smoke and args.shards is None:
        tmp_ctx = tempfile.TemporaryDirectory()
        shard_dir = tmp_ctx.name
    else:
        shard_dir = args.shards or os.path.join(repo, ".sweep_shards")
    if args.fresh and os.path.isdir(shard_dir):
        shutil.rmtree(shard_dir)

    try:
        if args.no_measure_parallel:
            records, st = run_grid(specs, args.nproc, shard_dir, resume=True)
            print(f"  wall {st['wall_s']}s ({st['from_shards']} from shards)")
            evidence, fails = None, []
        else:
            records, evidence, fails = measure_parallel(
                specs, args.nproc, shard_dir, resume=not args.fresh)
    finally:
        if tmp_ctx is not None:
            tmp_ctx.cleanup()

    agg = ST.aggregate(records)
    rhash = ST.result_hash(records)
    if evidence is not None:
        print(f"nproc-invariance: {'OK' if evidence['nproc_invariant'] else 'BROKEN'}"
              f" (hash {rhash[:16]}); speedup {evidence['speedup']}x "
              f"[{evidence['speedup_gate']}]")

    # surface sanity on any grid: every (sla, beta, budget) slice must
    # keep joint ipa's mean PAS >= split_ipa's (the feasible-set-superset
    # argument survives aggregation over paired replicates, which see
    # identical arrivals under both policies)
    by_key = {(r["policy"], r["sla_scale"], r["budget"], r["beta"]): r
              for r in agg["groups"]}
    for (pol, sla, c, beta), row in by_key.items():
        if pol != "ipa":
            continue
        split = by_key.get(("split_ipa", sla, c, beta))
        if split and row["mean_pas"]["mean"] < split["mean_pas"]["mean"] - 1e-9:
            fails.append(f"ipa mean PAS {row['mean_pas']['mean']} < "
                         f"split_ipa {split['mean_pas']['mean']} at "
                         f"sla={sla} C={c} beta={beta}")

    if fails:
        for f in fails:
            print(f"FAIL: {f}")
        return 1
    print(f"PASS: {len(specs)} cells, {len(agg['groups'])} surface groups, "
          f"{len(agg['pareto'])} Pareto slices")

    result = {
        "bench": "sweep_pareto",
        "grid": {**{k: list(v) if isinstance(v, tuple) else v
                    for k, v in g.items()},
                 "budgets": budgets, "root_seed": args.root_seed,
                 "adaptation_delay_s": ST.ADAPT_DELAY_S,
                 "hysteresis_switch_cost": ST.HYSTERESIS_SWITCH_COST,
                 "n_cells": len(specs)},
        "result_hash": rhash,
        "parallel": evidence,
        "timing": ST.timing_rollup(records),
        "aggregate": agg,
        "cells": records,
    }
    out = args.out
    if out is None and not args.smoke:
        out = os.path.join(repo, "BENCH_sweep.json")
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {os.path.abspath(out)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
