"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline tables.

  PYTHONPATH=src python -m benchmarks.roofline_report [--out results/roofline.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def fmt(x, digits=3):
    if x == 0:
        return "0"
    return f"{x:.{digits}g}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--out", default="results/roofline.md")
    args = ap.parse_args()

    recs = []
    for f in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        r = json.load(open(f))
        r["_file"] = os.path.basename(f)
        recs.append(r)

    lines = []
    for tag, title in (("singlepod", "Single pod (16x16 = 256 chips)"),
                       ("multipod", "Multi-pod (2x16x16 = 512 chips)")):
        rows = [r for r in recs if f"__{tag}__" in r["_file"]]
        if not rows:
            continue
        lines.append(f"### {title}\n")
        lines.append("| arch | shape | compute s | memory s | collective s |"
                     " bound | MODEL/HLO flops | arg+tmp GB/chip | fits 16G |")
        lines.append("|---|---|---|---|---|---|---|---|---|")
        for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
            if not r.get("ok"):
                lines.append(f"| {r['arch']} | {r['shape']} | FAILED: "
                             f"{str(r.get('error'))[:60]} | | | | | | |")
                continue
            gb = r["mem"]["argument_gb"] + r["mem"]["temp_gb"]
            lines.append(
                f"| {r['arch']} | {r['shape']} | {fmt(r['compute_s'])} | "
                f"{fmt(r['memory_s'])} | {fmt(r['collective_s'])} | "
                f"**{r['bottleneck']}** | {r['useful_flops_ratio']:.3f} | "
                f"{gb:.1f} | {'yes' if gb <= 16 else 'NO'} |")
        lines.append("")

    extra = [r for r in recs if "__singlepod__" not in r["_file"]
             and "__multipod__" not in r["_file"]]
    if extra:
        lines.append("### Hillclimb / variant runs\n")
        lines.append("| file | compute s | memory s | collective s | bound |"
                     " MODEL/HLO | note |")
        lines.append("|---|---|---|---|---|---|---|")
        for r in sorted(extra, key=lambda r: r["_file"]):
            if not r.get("ok"):
                lines.append(f"| {r['_file']} | FAILED | | | | | "
                             f"{str(r.get('error'))[:60]} |")
                continue
            lines.append(
                f"| {r['_file'].replace('.json','')} | {fmt(r['compute_s'])} |"
                f" {fmt(r['memory_s'])} | {fmt(r['collective_s'])} | "
                f"{r['bottleneck']} | {r['useful_flops_ratio']:.3f} | "
                f"mesh={r['mesh']} w={r.get('weights_mode','auto')} "
                f"moe={r.get('moe_impl')} |")
        lines.append("")

    out = "\n".join(lines)
    with open(args.out, "w") as f:
        f.write(out)
    print(out)


if __name__ == "__main__":
    main()
