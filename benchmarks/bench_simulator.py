#!/usr/bin/env python
"""Simulator-core benchmark: event-driven vs legacy tick flood.

Replays a bursty trace through a synthetic 4-stage pipeline and measures

* the raw cores head-to-head on a fixed configuration (wall time, events
  processed, events/sec, peak queue depth, completed/dropped counts), and
* the adaptation loop (``adapter.run_trace``) under all four policies
  (ipa / fa2_low / fa2_high / rim) on the event-driven core, with a
  per-phase wall-time breakdown: ``solver_wall_s`` (time inside the
  per-interval decision solver, surfaced by ``TraceResult``) vs
  ``sim_wall_s`` (everything else: event processing, arrival injection,
  bookkeeping).

Emits ``BENCH_sim.json`` next to the repo root so the perf trajectory of
the simulator hot path is tracked from PR 1 onward.  ``--smoke`` runs a
seconds-scale subset and is wired into ``scripts/tier1.sh`` so a perf
regression fails the tier-1 gate loudly — both the raw-core speedup
floor and the *policy-trace throughput floor* (events/sec with the
solver in the decision loop, the number that used to be solver-bound by
two orders of magnitude before the vectorized ``optimizer.solve_vec``
path landed).

Known scenario degeneracy (kept deliberately, regression-tested in
``tests/test_bench_scenarios.py``): at the default objective
(alpha=1, beta=0.1) the ``ipa`` policy sits in the all-heavy-variant
corner at every demand point this trace visits — each variant downgrade
loses ~4 PAS while saving only ~0.1-0.8 objective units of cores — and
cost-minimizing inside that corner is exactly FA2-high's fixed-variant
solve, so ``ipa`` and ``fa2_high`` report identical trajectories here.
That is the objective's verdict on this pipeline, not a policy-wiring
bug: raise beta (e.g. 2.0) and the two policies diverge at every demand
point.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import adapter as AD                      # noqa: E402
from repro.core import trace as TR                        # noqa: E402
from repro.core.pipeline import (ModelVariant, PipelineConfig,  # noqa: E402
                                 PipelineModel, StageConfig, StageModel)
from repro.core.simulator import PipelineSimulator        # noqa: E402
from repro.core.simulator_legacy import LegacyTickSimulator  # noqa: E402
from repro.serving.request import Request                 # noqa: E402

from profiling_util import maybe_profile                  # noqa: E402

POLICIES = ("ipa", "fa2_low", "fa2_high", "rim")


def four_stage_pipeline() -> PipelineModel:
    """Synthetic 4-stage pipeline in the paper's latency/accuracy regime
    (per-stage light/mid/heavy variants, quadratic latency, Table-7-style
    base allocations)."""
    def stage(name, l1, accs):
        variants = tuple(
            ModelVariant(f"{name}_{tag}", acc, alloc,
                         (l1 * scale * 0.002, l1 * scale * 0.7,
                          l1 * scale * 0.3))
            for tag, acc, alloc, scale in zip(
                ("light", "mid", "heavy"), accs, (1, 2, 4), (1.0, 1.8, 3.2)))
        return StageModel(name, variants, sla=5 * l1 * 1.8,
                          batch_choices=(1, 2, 4, 8, 16))
    return PipelineModel("bench4", (
        stage("detect", 0.040, (62.0, 71.0, 79.0)),
        stage("classify", 0.030, (66.0, 74.0, 81.0)),
        stage("caption", 0.050, (58.0, 68.0, 77.0)),
        stage("rank", 0.020, (70.0, 76.0, 83.0)),
    ))


def bursty_trace(seconds: int) -> np.ndarray:
    """Quiet baseline with sharp spikes — the paper's 'bursty' Twitter
    excerpt shape, and the regime where the legacy tick flood burns the
    most no-op events."""
    cfg = TR.TraceConfig(seed=7, base_rps=5.0, diurnal_amp=2.0,
                         noise_sigma=1.0, burst_rate_per_hour=30.0,
                         burst_amp=25.0, burst_decay_s=45.0)
    return TR.synth_trace(seconds, cfg)


def fixed_config(pipe: PipelineModel, peak_rps: float) -> PipelineConfig:
    """Mid-variant config sized for ~the trace peak: realistic queueing
    without permanent collapse."""
    stages = []
    for st in pipe.stages:
        v = st.variants[1]
        batch = 4
        n = max(1, math.ceil(peak_rps / float(v.throughput(batch))))
        stages.append(StageConfig(v.name, batch, n))
    return PipelineConfig(tuple(stages))


def replay_core(sim_cls, pipe, config, arrivals, horizon, step=10.0):
    sim = sim_cls(pipe, config)
    for t in arrivals:
        sim.inject(Request(arrival=float(t), sla=pipe.sla))
    t0 = time.perf_counter()
    b = 0.0
    while b < horizon:
        b = min(b + step, horizon)
        sim.run_until(b)
    wall = time.perf_counter() - t0
    m = sim.metrics
    return sim, {
        "wall_s": round(wall, 4),
        "events": sim.events_processed,
        "events_per_sec": round(sim.events_processed / max(wall, 1e-9)),
        "completed": m.completed,
        "dropped": m.dropped,
        "sla_violation_rate": round(m.sla_violations(pipe.sla), 4),
    }


def bench_core(pipe, rates, arrivals, repeats: int = 5) -> dict:
    """Interleaved new/legacy pairs so container load drift cancels in the
    per-pair ratio; reports median-of-pairs speedup and best walls."""
    horizon = float(len(rates)) + 20 * pipe.sla
    config = fixed_config(pipe, float(rates.max()))

    pairs = []
    best_new = best_old = None
    sim_new = None
    for _ in range(repeats):
        sn, rn = replay_core(PipelineSimulator, pipe, config, arrivals,
                             horizon)
        _, ro = replay_core(LegacyTickSimulator, pipe, config, arrivals,
                            horizon)
        pairs.append(ro["wall_s"] / max(rn["wall_s"], 1e-9))
        if best_new is None or rn["wall_s"] < best_new["wall_s"]:
            best_new, sim_new = rn, sn
        if best_old is None or ro["wall_s"] < best_old["wall_s"]:
            best_old = ro

    for r in (best_new, best_old):
        r["events_per_sec"] = round(r["events"] / max(r["wall_s"], 1e-9))
    best_new["peak_queue_depth"] = sim_new.peak_queue_depth
    speedup = sorted(pairs)[len(pairs) // 2]
    return {"new": best_new, "legacy": best_old,
            "speedup": round(speedup, 2),
            "speedup_pairs": [round(p, 2) for p in pairs],
            "counts_match": (best_new["completed"] == best_old["completed"]
                             and best_new["dropped"] == best_old["dropped"])}


def bench_policies(pipe, rates, policies=POLICIES, profile=False) -> dict:
    out = {}
    for pol in policies:
        t0 = time.perf_counter()
        res = maybe_profile(
            profile, f"policy:{pol}",
            lambda: AD.run_trace(pipe, rates, policy=pol, seed=11,
                                 max_replicas=96))
        wall = time.perf_counter() - t0
        out[pol] = {
            "wall_s": round(wall, 3),
            "solver_wall_s": round(res.solver_wall_s, 3),
            "sim_wall_s": round(wall - res.solver_wall_s, 3),
            "sim_events": res.sim_events,
            "events_per_sec": round(res.sim_events / max(wall, 1e-9)),
            "peak_queue_depth": res.peak_queue_depth,
            "completed": res.completed,
            "dropped": res.dropped,
            "sla_violation_rate": round(res.sla_violation_rate, 4),
            "mean_pas": round(res.mean_pas, 3),
            "mean_cost": round(res.mean_cost, 2),
        }
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale run for the tier-1 gate; asserts "
                         "the event-driven core beats the tick baseline "
                         "but does not overwrite BENCH_sim.json")
    ap.add_argument("--seconds", type=int, default=None,
                    help="trace length (default: 600, smoke: 60)")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: <repo>/BENCH_sim.json)")
    ap.add_argument("--profile", action="store_true",
                    help="cProfile each core/policy run and print the "
                         "top-25 cumulative table; throughput gates are "
                         "informational only under profiling overhead")
    args = ap.parse_args()

    seconds = args.seconds or (60 if args.smoke else 600)
    pipe = four_stage_pipeline()
    rates = bursty_trace(seconds)
    arrivals = TR.arrivals_from_rates(rates, seed=11)
    print(f"trace: {seconds}s bursty, {len(arrivals)} requests, "
          f"rate {rates.min():.1f}-{rates.max():.1f} rps, "
          f"4-stage pipeline '{pipe.name}'")

    core = maybe_profile(args.profile, "core:new_vs_legacy",
                         lambda: bench_core(pipe, rates, arrivals))
    print(f"core: new {core['new']['wall_s']}s "
          f"({core['new']['events']} events) vs legacy "
          f"{core['legacy']['wall_s']}s ({core['legacy']['events']} events) "
          f"-> {core['speedup']}x, counts_match={core['counts_match']}")

    # ratcheted in the cluster-co-scheduling PR: the reordered dispatch path
    # (deadline check before replica scan) + hot-loop locals sustain ~6x
    # full / ~9.5x smoke on this container; floors keep headroom
    floor = 4.0 if args.smoke else 5.5
    if args.profile:
        floor = 0.0                      # informational run, gates off
    if core["speedup"] < floor:
        print(f"FAIL: event-driven core speedup {core['speedup']}x "
              f"below the {floor}x floor")
        return 1

    result = {
        "bench": "simulator_core",
        "trace_seconds": seconds,
        "n_requests": len(arrivals),
        "smoke": bool(args.smoke),
        "core": core,
        "notes": {
            "fa2_high": "identical to ipa by objective degeneracy on this "
                        "scenario (see module docstring; regression-tested "
                        "in tests/test_bench_scenarios.py)"},
    }
    # policy-trace throughput floor: events/sec WITH the solver in the
    # decision loop.  Pre-vectorization this ran ~1.1k ev/s (the per-call
    # jax.jit re-trace in solve_enum dominated by ~100x); solve_vec
    # sustains ~15-60k ev/s here.  Floors keep ~4x headroom for slow
    # containers while still catching a solver-path regression loudly.
    policy_floor = 3000 if args.smoke else 6500
    if args.profile:
        policy_floor = 0                 # informational run, gates off
    policies = ("ipa",) if args.smoke else POLICIES
    result["policies"] = bench_policies(pipe, rates, policies,
                                        profile=args.profile)
    for pol, r in result["policies"].items():
        print(f"policy {pol}: {r['wall_s']}s wall "
              f"(solver {r['solver_wall_s']}s + sim {r['sim_wall_s']}s), "
              f"{r['events_per_sec']} ev/s, peak_q={r['peak_queue_depth']},"
              f" dropped={r['dropped']}, pas={r['mean_pas']}")
    slow = {pol: r["events_per_sec"] for pol, r in
            result["policies"].items() if r["events_per_sec"] < policy_floor}
    if slow:
        print(f"FAIL: policy-trace throughput below the {policy_floor} "
              f"ev/s floor (solver in loop): {slow}")

    # an explicit --out is always honoured — on a floor failure the
    # per-phase breakdown is exactly the diagnostic worth keeping — but
    # the canonical BENCH_sim.json ratchet artifact is only overwritten
    # by a passing full run
    # profiled walls are inflated by instrumentation — never let them
    # overwrite the canonical ratchet artifact
    if args.out or (not args.smoke and not slow and not args.profile):
        out = args.out or os.path.join(os.path.dirname(__file__), "..",
                                       "BENCH_sim.json")
        with open(out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {os.path.abspath(out)}")
    return 1 if slow else 0


if __name__ == "__main__":
    sys.exit(main())
