"""Heterogeneous device-class planning: per-class variant tables, the
vector-cost Pareto frontier, the multi-dimensional cluster knapsack vs the
device-axis brute oracle (ties, switch budgets, overlap, mid-window
serving!=committed), per-class static splits, and the per-class simulator
ledger."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import accuracy as ACC
from repro.core import adapter as AD
from repro.core import baselines as BL
from repro.core import optimizer as OPT
from repro.core.cluster import (ClusterConfig, ClusterModel,
                                proportional_split_by_class)
from repro.core.pipeline import (DeviceProfile, ModelVariant, PipelineConfig,
                                 PipelineModel, StageConfig, StageModel)
from repro.core.simulator import ClusterSimulator, CoreBudgetExceeded


def hetero_variant(name: str, l1: float, scale: float, acc: float,
                   alloc: int, gpu_speed: float = 4.0,
                   gpu_acc_delta: float = 3.0) -> ModelVariant:
    """Two-class variant: the CPU profile mirrors the legacy fields; the
    GPU profile is ``gpu_speed``x faster at 1 core with a small accuracy
    delta (quantized/reduced-precision build)."""
    coeffs = (l1 * scale * 0.002, l1 * scale * 0.7, l1 * scale * 0.3)
    return ModelVariant(name, acc, alloc, coeffs, device_profiles=(
        DeviceProfile("cpu", coeffs, alloc, acc),
        DeviceProfile("gpu", tuple(c / gpu_speed for c in coeffs), 1,
                      acc + gpu_acc_delta)))


def hetero_pipeline(name: str, l1: float = 0.05,
                    accs=(60.0, 75.0, 85.0), gpu_speed: float = 4.0,
                    gpu_acc_delta: float = 3.0) -> PipelineModel:
    vs = tuple(hetero_variant(f"{name}_v{i}", l1, s, a, 2 ** i, gpu_speed,
                              gpu_acc_delta)
               for i, (a, s) in enumerate(zip(accs, (1.0, 1.7, 3.0))))
    return PipelineModel(name, (
        StageModel(f"{name}_s1", vs, sla=5 * l1 * 1.7, batch_choices=(1, 2)),
        StageModel(f"{name}_s2", vs, sla=5 * l1 * 1.7, batch_choices=(1, 2)),
    ))


def hetero_cluster(cpu: float = 24.0, gpu: float = 6.0,
                   **kw) -> ClusterModel:
    return ClusterModel("hc", (hetero_pipeline("A", **kw),
                               hetero_pipeline("B", l1=0.03,
                                               accs=(55.0, 68.0, 90.0),
                                               **kw)),
                        cores={"cpu": cpu, "gpu": gpu})


# ---------------------------------------------------------------------------
# data model: per-class tables and budgets
# ---------------------------------------------------------------------------
def test_device_profile_lookup_and_legacy_fields():
    v = hetero_variant("v", 0.05, 1.0, 60.0, 2)
    assert v.device_classes == ("cpu", "gpu")
    assert v.alloc("cpu") == 2 and v.alloc("gpu") == 1
    assert v.acc("gpu") == 63.0
    # None and "cpu" hit the variant's own fields (the legacy float path)
    assert float(v.latency(4)) == float(v.latency(4, "cpu"))
    assert float(v.latency(4, "gpu")) == pytest.approx(
        float(v.latency(4)) / 4.0)
    legacy = ModelVariant("w", 60.0, 2, (0.1, 0.2, 0.3))
    assert legacy.device_classes == ("cpu",)
    assert legacy.alloc("cpu") == 2
    with pytest.raises(KeyError):
        legacy.alloc("gpu")
    with pytest.raises(KeyError):
        v.alloc("tpu")


def test_cluster_budget_mapping_normalizes():
    cl = hetero_cluster(cpu=24.0, gpu=6.0)
    assert cl.is_hetero
    assert cl.device_classes == ("cpu", "gpu")
    assert cl.budget_vector == (24.0, 6.0)
    assert cl.cores == pytest.approx(30.0)      # scalar total for legacy readers
    scalar = ClusterModel("s", cl.pipelines, 30.0)
    assert not scalar.is_hetero
    assert scalar.device_classes == ("cpu",)
    assert scalar.budget_vector == (30.0,)


def test_cluster_rejects_unbudgeted_class_and_bad_budgets():
    pipes = hetero_cluster().pipelines
    with pytest.raises(ValueError):               # gpu variants, no gpu budget
        ClusterModel("x", pipes, cores={"cpu": 24.0})
    with pytest.raises(ValueError):
        ClusterModel("x", pipes, cores={"cpu": 24.0, "gpu": -1.0})
    with pytest.raises(ValueError):
        ClusterModel("x", pipes, cores={})


def test_cost_by_class_splits_and_sums_to_scalar_cost():
    cl = hetero_cluster()
    pipe = cl.pipelines[0]
    cfg = PipelineConfig((StageConfig("A_v0", 2, 3, "cpu"),
                          StageConfig("A_v1", 1, 2, "gpu")))
    by = cfg.cost_by_class(pipe, cl.device_classes)
    assert by == (3 * 1, 2 * 1)                  # cpu alloc 1, gpu alloc 1
    assert sum(by) == pytest.approx(cfg.cost(pipe))
    with pytest.raises(KeyError):
        cfg.cost_by_class(pipe, ("cpu",))        # gpu stage, no gpu column


def test_pas_prime_tables_keyed_by_variant_and_device():
    cl = hetero_cluster()
    pipe = cl.pipelines[0]
    tabs = ACC.pas_prime_tables(pipe)
    assert ("A_v0", "cpu") in tabs[0] and ("A_v0", "gpu") in tabs[0]
    # gpu build is strictly more accurate here, so it ranks strictly higher
    assert tabs[0][("A_v0", "gpu")] > tabs[0][("A_v0", "cpu")]


# ---------------------------------------------------------------------------
# vector-cost frontier
# ---------------------------------------------------------------------------
def test_frontier_vec_points_are_mutually_nondominated():
    cl = hetero_cluster()
    pts = OPT.pareto_frontier_vec(cl.pipelines[0], 12.0, OPT.Objective(),
                                  cl.device_classes, max_replicas=6)
    assert pts
    for p in pts:
        assert sum(p.cost_vec) == pytest.approx(p.cost)
        assert p.config.stages[0].device in ("cpu", "gpu")
    for i, a in enumerate(pts):
        for j, b in enumerate(pts):
            if i == j:
                continue
            dominates = (all(x <= y for x, y in zip(a.cost_vec, b.cost_vec))
                         and (a.objective > b.objective
                              or (a.objective == b.objective
                                  and a.cost_vec == b.cost_vec)))
            assert not dominates, (i, j)


def test_frontier_cache_exact_for_vector_costs():
    cl = hetero_cluster()
    cache = OPT.FrontierCache()
    classes = cl.device_classes
    a = cache.frontier(cl.pipelines[0], 12.0, OPT.Objective(), 6,
                       "worst_case", classes)
    b = OPT.pareto_frontier_vec(cl.pipelines[0], 12.0, OPT.Objective(),
                                classes, max_replicas=6)
    assert [(p.cost_vec, p.objective, p.config) for p in a] \
        == [(p.cost_vec, p.objective, p.config) for p in b]
    # hit on repeat, and the single-class key shape is untouched
    assert cache.frontier(cl.pipelines[0], 12.0, OPT.Objective(), 6,
                          "worst_case", classes) is not None
    assert cache.stats["hits"] >= 1


# ---------------------------------------------------------------------------
# single-pipeline solver vs device-axis brute oracle
# ---------------------------------------------------------------------------
@given(gpu_speed=st.floats(1.5, 6.0), delta=st.floats(-5.0, 5.0),
       lam=st.floats(1.0, 30.0), beta=st.floats(0.0, 0.2))
@settings(max_examples=20, deadline=None)
def test_solve_vec_matches_brute_on_device_axis(gpu_speed, delta, lam, beta):
    # solve_vec and solve_brute enumerate the same stage_options lattice —
    # with the device axis folded in, they must stay config-for-config
    # bit-identical (first-occurrence argmax over itertools.product order),
    # ties included (delta == 0 makes cpu/gpu placements tie exactly)
    pipe = hetero_pipeline("A", gpu_speed=gpu_speed, gpu_acc_delta=delta)
    obj = OPT.Objective(alpha=1.0, beta=beta)
    v = OPT.solve_vec(pipe, lam, obj, max_replicas=4)
    b = OPT.solve_brute(pipe, lam, obj, max_replicas=4)
    assert v.feasible == b.feasible
    if v.feasible:
        assert v.config == b.config
        assert v.objective == b.objective
        assert v.cost == b.cost


def test_solve_vec_device_ties_are_bit_identical():
    pipe = hetero_pipeline("A", gpu_acc_delta=0.0)
    obj = OPT.Objective(alpha=1.0, beta=0.0)
    v = OPT.solve_vec(pipe, 8.0, obj, max_replicas=4)
    b = OPT.solve_brute(pipe, 8.0, obj, max_replicas=4)
    assert v.feasible and b.feasible
    assert v.config == b.config
    assert v.objective == b.objective


# ---------------------------------------------------------------------------
# joint solver vs device-axis brute oracle
# ---------------------------------------------------------------------------
def _incumbent_for(cl, lams, **kw):
    sol = OPT.solve_cluster(cl, lams, max_replicas=4, **kw)
    assert sol.feasible
    return sol.config


@given(cpu=st.integers(6, 30), gpu=st.integers(0, 8),
       lam_a=st.floats(1.0, 25.0), lam_b=st.floats(1.0, 25.0))
@settings(max_examples=20, deadline=None)
def test_hetero_knapsack_matches_brute(cpu, gpu, lam_a, lam_b):
    cl = hetero_cluster(cpu=float(cpu), gpu=float(gpu))
    obj = OPT.Objective(alpha=1.0, beta=0.05)
    k = OPT.solve_cluster(cl, [lam_a, lam_b], obj, max_replicas=4)
    b = OPT.solve_cluster_brute(cl, [lam_a, lam_b], obj, max_replicas=4)
    assert k.feasible == b.feasible
    if k.feasible:
        assert k.objective == pytest.approx(b.objective, rel=1e-9)
        assert k.config.fits(cl)


@given(gpu_speed=st.floats(1.5, 6.0), delta=st.floats(-5.0, 5.0),
       lam_a=st.floats(1.0, 20.0), lam_b=st.floats(1.0, 20.0))
@settings(max_examples=15, deadline=None)
def test_hetero_knapsack_matches_brute_random_tables(gpu_speed, delta,
                                                     lam_a, lam_b):
    cl = ClusterModel("hc", (
        hetero_pipeline("A", gpu_speed=gpu_speed, gpu_acc_delta=delta),
        hetero_pipeline("B", l1=0.03, accs=(55.0, 68.0, 90.0),
                        gpu_speed=gpu_speed, gpu_acc_delta=delta)),
        cores={"cpu": 20.0, "gpu": 5.0})
    k = OPT.solve_cluster(cl, [lam_a, lam_b], max_replicas=4)
    b = OPT.solve_cluster_brute(cl, [lam_a, lam_b], max_replicas=4)
    assert k.feasible == b.feasible
    if k.feasible:
        assert k.objective == pytest.approx(b.objective, rel=1e-9)


def test_hetero_knapsack_exact_on_ties():
    # zero-delta profiles + beta=0 make cpu/gpu placements tie exactly in
    # objective (incomparable cost vectors carry identical values — a tie
    # shape the scalar frontier could never hold).  The DP must hit the
    # exact optimal value, land inside the brute oracle's full argmax set,
    # and pick deterministically (pruning and caching invisible on ties).
    cl = hetero_cluster(cpu=20.0, gpu=6.0, gpu_acc_delta=0.0)
    obj = OPT.Objective(alpha=1.0, beta=0.0)
    k = OPT.solve_cluster(cl, [8.0, 11.0], obj, max_replicas=4)
    b = OPT.solve_cluster_brute(cl, [8.0, 11.0], obj, max_replicas=4)
    assert k.feasible and b.feasible
    assert k.objective == b.objective
    # enumerate the oracle's complete argmax set the same way it scores
    classes = cl.device_classes
    tabs = [OPT.pareto_frontier_vec(p, lam, obj, classes, max_replicas=4)
            for p, lam in zip(cl.pipelines, [8.0, 11.0])]
    import itertools
    optima = set()
    for combo in itertools.product(*tabs):
        tot = [sum(p.cost_vec[c] for p in combo) for c in range(len(classes))]
        if any(t > bdg + 1e-9 for t, bdg in zip(tot, cl.budget_vector)):
            continue
        if sum(p.objective for p in combo) == b.objective:
            optima.add(ClusterConfig(tuple(p.config for p in combo)))
    assert len(optima) > 1                 # the scenario genuinely ties
    assert k.config in optima
    assert b.config in optima
    # deterministic: repeat solves (cached and uncached) pick identically
    again = OPT.solve_cluster(cl, [8.0, 11.0], obj, max_replicas=4)
    cached = OPT.solve_cluster(cl, [8.0, 11.0], obj, max_replicas=4,
                               cache=OPT.FrontierCache())
    assert again.config == k.config == cached.config


@given(cpu=st.integers(8, 26), gpu=st.integers(1, 6),
       sw=st.floats(0.0, 2.0), kbud=st.integers(0, 2),
       lam_a=st.floats(1.0, 20.0), lam_b=st.floats(1.0, 20.0))
@settings(max_examples=15, deadline=None)
def test_hetero_switch_budget_and_cost_match_brute(cpu, gpu, sw, kbud,
                                                   lam_a, lam_b):
    cl = hetero_cluster(cpu=float(cpu), gpu=float(gpu))
    try:
        current = _incumbent_for(cl, [6.0, 6.0])
    except AssertionError:
        return                             # tiny budget: no incumbent to hold
    k = OPT.solve_cluster(cl, [lam_a, lam_b], max_replicas=4,
                          current=current, switch_cost=sw,
                          switch_budget=kbud)
    b = OPT.solve_cluster_brute(cl, [lam_a, lam_b], max_replicas=4,
                                current=current, switch_cost=sw,
                                switch_budget=kbud)
    assert k.feasible == b.feasible
    if k.feasible:
        assert k.objective == pytest.approx(b.objective, rel=1e-9)
        assert k.n_switches <= kbud


@given(cpu=st.integers(8, 26), gpu=st.integers(1, 6),
       lam_a=st.floats(1.0, 20.0), lam_b=st.floats(1.0, 20.0))
@settings(max_examples=15, deadline=None)
def test_hetero_overlap_matches_brute(cpu, gpu, lam_a, lam_b):
    cl = hetero_cluster(cpu=float(cpu), gpu=float(gpu))
    try:
        current = _incumbent_for(cl, [6.0, 6.0])
    except AssertionError:
        return
    k = OPT.solve_cluster(cl, [lam_a, lam_b], max_replicas=4,
                          current=current, switch_cost=0.3, overlap=True)
    b = OPT.solve_cluster_brute(cl, [lam_a, lam_b], max_replicas=4,
                                current=current, switch_cost=0.3,
                                overlap=True)
    assert k.feasible == b.feasible
    if k.feasible:
        assert k.objective == pytest.approx(b.objective, rel=1e-9)
        # the committed choice must fit per class through the window
        assert k.config.fits_transition(cl, current)


def test_hetero_overlap_serving_differs_from_committed():
    # mid-window: serving != committed; the still-serving config is a free
    # revert and the overlap charge is taken against *serving*, per class
    cl = hetero_cluster(cpu=22.0, gpu=5.0)
    # serving planned on an empty gpu pool (cpu-only fleets), committed on
    # the full pool — guaranteed to differ, like a real mid-rollout window
    cpu_only = ClusterModel("hc0", cl.pipelines,
                            cores={"cpu": 22.0, "gpu": 0.0})
    serving = _incumbent_for(cpu_only, [5.0, 5.0])
    committed = _incumbent_for(cl, [14.0, 9.0])
    assert serving != committed
    k = OPT.solve_cluster(cl, [10.0, 16.0], max_replicas=4,
                          current=committed, switch_cost=0.4,
                          overlap=True, serving=serving)
    b = OPT.solve_cluster_brute(cl, [10.0, 16.0], max_replicas=4,
                                current=committed, switch_cost=0.4,
                                overlap=True, serving=serving)
    assert k.feasible == b.feasible
    if k.feasible:
        assert k.objective == pytest.approx(b.objective, rel=1e-9)
        assert k.config == b.config


def test_hetero_scalar_budget_rejected():
    cl = hetero_cluster()
    with pytest.raises(ValueError):
        OPT.solve_cluster(cl, [5.0, 5.0], budget=10.0, max_replicas=4)
    with pytest.raises(ValueError):
        OPT.solve_cluster_brute(cl, [5.0, 5.0], budget=10.0, max_replicas=4)


def test_single_class_budget_map_matches_scalar_solver():
    # a one-class budget mapping must pick exactly the scalar solver's
    # answer (the device axis is invisible with one class)
    from test_cluster import toy_cluster
    scalar = toy_cluster(cores=40.0)
    mapped = ClusterModel("toy", scalar.pipelines, cores={"cpu": 40.0})
    assert mapped.is_hetero and mapped.cores == 40.0
    for lams in ([5.0, 20.0], [18.0, 3.0]):
        a = OPT.solve_cluster(scalar, lams, max_replicas=4)
        b = OPT.solve_cluster(mapped, lams, max_replicas=4)
        assert a.feasible == b.feasible
        if a.feasible:
            assert a.config == b.config
            assert a.objective == b.objective


# ---------------------------------------------------------------------------
# per-class static split vs joint
# ---------------------------------------------------------------------------
def test_proportional_split_by_class_shares_every_budget():
    cl = hetero_cluster(cpu=24.0, gpu=6.0)
    caps = proportional_split_by_class(cl, [10.0, 20.0])
    assert caps == ((8.0, 2.0), (16.0, 4.0))
    even = proportional_split_by_class(cl, [0.0, 0.0])
    assert even == ((12.0, 3.0), (12.0, 3.0))


def test_solve_capped_vector_cap_matches_filtered_brute():
    cl = hetero_cluster()
    pipe, classes = cl.pipelines[0], cl.device_classes
    cap = (6.0, 2.0)
    sol = OPT.solve_capped(pipe, 9.0, cost_cap=cap, max_replicas=4,
                           classes=classes)
    pts = [p for p in OPT.pareto_frontier_vec(pipe, 9.0, OPT.Objective(),
                                              classes, max_replicas=4)
           if all(cv <= c + 1e-9 for cv, c in zip(p.cost_vec, cap))]
    assert sol.feasible == bool(pts)
    if pts:
        best = max(pts, key=lambda p: p.objective)
        assert sol.objective == best.objective
        assert sol.config == best.config
        assert all(cv <= c + 1e-9 for cv, c in zip(
            sol.config.cost_by_class(pipe, classes), cap))


def test_joint_never_loses_to_per_class_split():
    cl = hetero_cluster(cpu=20.0, gpu=4.0)
    for lams in ([6.0, 18.0], [15.0, 5.0], [10.0, 10.0]):
        joint = BL.cluster_ipa(cl, lams, max_replicas=4)
        split = BL.cluster_split(cl, lams, "ipa", max_replicas=4)
        if split.feasible:
            assert joint.feasible
            assert joint.objective >= split.objective - 1e-9
            assert split.config.fits(cl)


# ---------------------------------------------------------------------------
# simulator: per-class ledger
# ---------------------------------------------------------------------------
def _sol_config(cl, lams):
    sol = OPT.solve_cluster(cl, lams, max_replicas=4)
    assert sol.feasible
    return sol.config


def test_simulator_enforces_per_class_budgets():
    cl = hetero_cluster(cpu=24.0, gpu=2.0)
    cfg = _sol_config(cl, [5.0, 5.0])
    sim = ClusterSimulator(cl, cfg)
    # a config overflowing the gpu pool alone must be rejected even though
    # the scalar total fits
    greedy = PipelineConfig((StageConfig("A_v0", 1, 3, "gpu"),
                             StageConfig("A_v0", 1, 2, "cpu")))
    assert sum(greedy.cost_by_class(cl.pipelines[0],
                                    cl.device_classes)) <= cl.cores
    with pytest.raises(CoreBudgetExceeded):
        sim.reconfigure_pipeline(0, greedy)


def test_simulator_initial_per_class_overflow_raises():
    cl = hetero_cluster(cpu=24.0, gpu=1.0)
    bad = ClusterConfig((
        PipelineConfig((StageConfig("A_v0", 1, 2, "gpu"),
                        StageConfig("A_v0", 1, 1, "cpu"))),
        PipelineConfig((StageConfig("B_v0", 1, 1, "cpu"),
                        StageConfig("B_v0", 1, 1, "cpu")))))
    with pytest.raises(CoreBudgetExceeded):
        ClusterSimulator(cl, bad)


def test_transition_overlap_charged_per_class():
    # moving a stage cpu->gpu holds BOTH classes through the window: the
    # old cpu fleet serves while the gpu fleet provisions
    cl = hetero_cluster(cpu=9.0, gpu=2.0)
    cpu_cfg = ClusterConfig((
        PipelineConfig((StageConfig("A_v0", 1, 2, "cpu"),
                        StageConfig("A_v0", 1, 2, "cpu"))),
        PipelineConfig((StageConfig("B_v0", 1, 1, "cpu"),
                        StageConfig("B_v0", 1, 1, "cpu")))))
    sim = ClusterSimulator(cl, cpu_cfg, adaptation_delay=2.0)
    gpu_move = PipelineConfig((StageConfig("A_v0", 1, 2, "gpu"),
                               StageConfig("A_v0", 1, 2, "cpu")))
    sim.reconfigure_pipeline(0, gpu_move)
    # ledger holds max per class: cpu 4 (old fleet), gpu 2 (new fleet)
    assert sim._alloc_vec[0] == (4.0, 2.0)
    assert sim._serving_vec[0] == (4.0, 0.0)
    # a grant of the cpu cores the move will free must bounce mid-window —
    # the old cpu fleet is still serving them
    cpu_grow = PipelineConfig((StageConfig("B_v0", 1, 5, "cpu"),
                               StageConfig("B_v0", 1, 1, "cpu")))
    with pytest.raises(CoreBudgetExceeded):
        sim.reconfigure_pipeline(1, cpu_grow)
    sim.run_until(3.0)                     # window closes, ledger settles
    assert sim._alloc_vec[0] == (2.0, 2.0)
    assert sim._serving_vec[0] == (2.0, 2.0)
    sim.reconfigure_pipeline(1, cpu_grow)  # freed cpu cores now grantable
    assert sim.peak_serving_by_class is not None


def test_gpu_service_times_drawn_from_gpu_table():
    cl = hetero_cluster()
    pipe = cl.pipelines[0]
    v = pipe.stages[0].variants[0]
    cfg_cpu = ClusterConfig((
        PipelineConfig((StageConfig("A_v0", 1, 1, "cpu"),
                        StageConfig("A_v0", 1, 1, "cpu"))),
        PipelineConfig((StageConfig("B_v0", 1, 1, "cpu"),
                        StageConfig("B_v0", 1, 1, "cpu")))))
    cfg_gpu = ClusterConfig((
        PipelineConfig((StageConfig("A_v0", 1, 1, "gpu"),
                        StageConfig("A_v0", 1, 1, "gpu"))),
        cfg_cpu.pipelines[1]))
    sim_c = ClusterSimulator(cl, cfg_cpu)
    sim_g = ClusterSimulator(cl, cfg_gpu)
    assert sim_c._lat_tab[0][1] == pytest.approx(float(v.latency(1, "cpu")))
    assert sim_g._lat_tab[0][1] == pytest.approx(float(v.latency(1, "gpu")))
    assert sim_g._lat_tab[0][1] < sim_c._lat_tab[0][1]
