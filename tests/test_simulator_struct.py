"""The structured-array event core must be *event-for-event identical* to
the heapq reference core: same completed/dropped/arrived counts, the exact
same latency streams (bit-identical float64), the same
``events_processed``, reconfig log, peak depths and residual queue state —
on golden traces, the shared equivalence scenarios, and randomized bursty
cluster traces with mid-window ``adaptation_delay > 0`` transitions."""
import numpy as np
import pytest

from repro.core.cluster import ClusterModel, ClusterConfig
from repro.core.pipeline import (ModelVariant, PipelineModel, PipelineConfig,
                                 StageConfig, StageModel)
from repro.core.simulator import (ClusterSimulator, PipelineSimulator,
                                  StructClusterSimulator,
                                  StructPipelineSimulator,
                                  make_cluster_simulator, EVENT_CORES)
from repro.serving.request import Request

from test_simulator_equivalence import two_stage, EQUIV_TRACES


# ---------------------------------------------------------------------------
# exhaustive state snapshot: everything observable the cores must agree on
# ---------------------------------------------------------------------------
def full_snapshot(sim):
    return dict(
        per_pipe=[(m.arrived, m.completed, m.dropped,
                   tuple(np.asarray(m._lat.view()).tolist()))
                  for m in sim.metrics_by_pipe],
        events=sim.events_processed,
        reconfig=list(sim.reconfig_log),
        peak_depth=sim.peak_queue_depth,
        peak_cores=sim.peak_serving_cores,
        now=sim.now,
        queued=sim.queued,
        in_service=sim.in_service,
    )


def assert_same(heap_sim, struct_sim):
    a, b = full_snapshot(heap_sim), full_snapshot(struct_sim)
    for key in a:
        assert a[key] == b[key], f"struct core diverges on {key}"


# ---------------------------------------------------------------------------
# single-pipeline: the shared equivalence traces, replayed on both cores
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("trace_name", sorted(EQUIV_TRACES))
def test_pipeline_equiv_traces(trace_name):
    config, arrivals, horizon = EQUIV_TRACES[trace_name]
    pipe = two_stage()
    sims = []
    for cls in (PipelineSimulator, StructPipelineSimulator):
        sim = cls(pipe, config)
        sim.inject_arrivals(np.asarray(arrivals, dtype=np.float64))
        sim.run_until(horizon)
        sims.append(sim)
    assert_same(*sims)


# ---------------------------------------------------------------------------
# randomized bursty cluster traces with mid-run reconfigurations
# ---------------------------------------------------------------------------
def _rand_pipe(rng, name):
    stages = []
    for j in range(int(rng.integers(1, 4))):
        l1 = 0.01 + 0.08 * rng.random()
        variants = tuple(
            ModelVariant(f"{name}_s{j}_{v}", 50.0 + 10 * v, 1 + v,
                         (0.0, l1 * sc * 0.7, l1 * sc * 0.3))
            for v, sc in enumerate((1.0, 1.7, 2.9)))
        stages.append(StageModel(f"{name}_s{j}", variants,
                                 sla=l1 * (4 + 6 * rng.random()),
                                 batch_choices=(1, 2, 4, 8)))
    return PipelineModel(name, tuple(stages))


def _rand_cfg(rng, pipe):
    return PipelineConfig(tuple(
        StageConfig(st.variants[int(rng.integers(len(st.variants)))].name,
                    int(rng.choice([1, 2, 4, 8])),
                    int(rng.integers(1, 4)))
        for st in pipe.stages))


@pytest.mark.parametrize("seed", range(8))
def test_cluster_random_bursty_with_transitions(seed):
    """Both cores step through four 10 s windows of bursty traffic (exact
    arrival-time ties included), a mid-run reconfigure + ``set_lam_est``
    at windows 1 and 3, and an ``adaptation_delay`` that lands the config
    apply *inside* a later window — then drain."""
    rng = np.random.default_rng(seed)
    n_pipes = int(rng.integers(1, 4))
    pipes = tuple(_rand_pipe(rng, f"p{i}") for i in range(n_pipes))
    cluster = ClusterModel("fz", pipes, 9999.0)
    cc = ClusterConfig(tuple(_rand_cfg(rng, p) for p in pipes))
    delay = float(rng.choice([0.0, 1.5, 8.0]))

    plans = []
    for w in range(4):
        winj = []
        for p in range(n_pipes):
            lam = rng.choice([2.0, 30.0, 300.0])
            ts = np.sort(10.0 * w + 10.0 * rng.random(rng.poisson(lam * 10.0)))
            if ts.size > 4:              # exact-tie arrivals
                ts[1] = ts[0]
                ts[ts.size // 2] = ts[ts.size // 2 - 1]
            winj.append(ts)
        plans.append(winj)

    sims = []
    for cls in (ClusterSimulator, StructClusterSimulator):
        sim = cls(cluster, cc, adaptation_delay=delay)
        for w, winj in enumerate(plans):
            for p, ts in enumerate(winj):
                if (seed + 3 * w + 7 * p) % 3:
                    sim.inject_arrivals(ts, p)
                else:                    # scalar-inject path
                    for t in ts:
                        sim.inject(Request(arrival=float(t)), p)
            if w in (1, 3):
                r2 = np.random.default_rng(seed * 1000 + w)
                pidx = int(r2.integers(n_pipes))
                sim.reconfigure_pipeline(pidx, _rand_cfg(r2, pipes[pidx]))
                sim.set_lam_est(pidx, float(2.0 + 40.0 * r2.random()))
            sim.run_until(10.0 * (w + 1))
        sim.run_until(60.0)
        sims.append(sim)
    assert_same(*sims)
    if delay > 0.0 and sims[0].reconfig_log:
        # the transition landed mid-window: both cores logged the request
        # at the window edge with the apply at the delayed instant
        assert all(t in (10.0, 30.0) and t_apply == t + delay
                   for t, _p, t_apply in sims[0].reconfig_log)


# ---------------------------------------------------------------------------
# struct-core contract details
# ---------------------------------------------------------------------------
def test_factory_builds_both_cores_and_rejects_unknown():
    pipe = two_stage()
    cc = ClusterConfig((PipelineConfig((StageConfig("a0", 4, 1),
                                        StageConfig("b0", 2, 1))),))
    from repro.core.cluster import single
    cluster = single(pipe)
    assert EVENT_CORES == ("heap", "struct")
    assert isinstance(make_cluster_simulator(cluster, cc),
                      ClusterSimulator)
    assert isinstance(make_cluster_simulator(cluster, cc,
                                             event_core="struct"),
                      StructClusterSimulator)
    with pytest.raises(ValueError, match="unknown event core"):
        make_cluster_simulator(cluster, cc, event_core="vectorized")


def test_struct_core_rejects_record_timeline():
    pipe = two_stage()
    config = PipelineConfig((StageConfig("a0", 4, 1),
                             StageConfig("b0", 2, 1)))
    with pytest.raises(ValueError, match="record_timeline"):
        StructPipelineSimulator(pipe, config, record_timeline=True)


def test_struct_core_handles_unsorted_and_stale_injections():
    """Out-of-order bulk injections are sorted lazily; arrivals timestamped
    before the current clock enter their stage at the clock, exactly like
    the reference core."""
    pipe = two_stage()
    config = PipelineConfig((StageConfig("a0", 4, 1),
                             StageConfig("b0", 2, 1)))
    sims = []
    for cls in (PipelineSimulator, StructPipelineSimulator):
        sim = cls(pipe, config)
        sim.inject_arrivals(np.array([0.5, 0.1, 0.9, 0.3]))
        sim.run_until(2.0)
        sim.inject_arrivals(np.array([1.0, 1.7, 2.5]))  # 1.0, 1.7 stale
        sim.run_until(20.0)
        sims.append(sim)
    assert_same(*sims)
    assert sims[1].metrics.completed + sims[1].metrics.dropped == 7
