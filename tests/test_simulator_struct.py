"""The structured-array and service-round event cores must be
*event-for-event identical* to the heapq reference core: same
completed/dropped/arrived counts, the exact same latency streams
(bit-identical float64), the same ``events_processed``, reconfig log,
peak depths and residual queue state — on golden traces, the shared
equivalence scenarios, randomized bursty cluster traces with mid-window
``adaptation_delay > 0`` transitions, and hypothesis-random DAG/hetero
clusters."""
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.core.cluster import ClusterModel, ClusterConfig
from repro.core.pipeline import (DeviceProfile, ModelVariant, PipelineModel,
                                 PipelineConfig, StageConfig, StageModel)
from repro.core.simulator import (ClusterSimulator, PipelineSimulator,
                                  RoundClusterSimulator,
                                  RoundPipelineSimulator,
                                  StructClusterSimulator,
                                  StructPipelineSimulator,
                                  make_cluster_simulator, EVENT_CORES)
from repro.serving.request import Request

from test_simulator_equivalence import two_stage, EQUIV_TRACES

PIPE_CORES = (PipelineSimulator, StructPipelineSimulator,
              RoundPipelineSimulator)
CLUSTER_CORES = (ClusterSimulator, StructClusterSimulator,
                 RoundClusterSimulator)


# ---------------------------------------------------------------------------
# exhaustive state snapshot: everything observable the cores must agree on
# ---------------------------------------------------------------------------
def full_snapshot(sim):
    return dict(
        per_pipe=[(m.arrived, m.completed, m.dropped,
                   tuple(np.asarray(m._lat.view()).tolist()))
                  for m in sim.metrics_by_pipe],
        events=sim.events_processed,
        reconfig=list(sim.reconfig_log),
        peak_depth=sim.peak_queue_depth,
        peak_cores=sim.peak_serving_cores,
        now=sim.now,
        queued=sim.queued,
        in_service=sim.in_service,
    )


def assert_same(heap_sim, *others):
    a = full_snapshot(heap_sim)
    for other in others:
        b = full_snapshot(other)
        for key in a:
            assert a[key] == b[key], \
                f"{type(other).__name__} diverges on {key}"


# ---------------------------------------------------------------------------
# single-pipeline: the shared equivalence traces, replayed on both cores
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("trace_name", sorted(EQUIV_TRACES))
def test_pipeline_equiv_traces(trace_name):
    config, arrivals, horizon = EQUIV_TRACES[trace_name]
    pipe = two_stage()
    sims = []
    for cls in PIPE_CORES:
        sim = cls(pipe, config)
        sim.inject_arrivals(np.asarray(arrivals, dtype=np.float64))
        sim.run_until(horizon)
        sims.append(sim)
    assert_same(*sims)


# ---------------------------------------------------------------------------
# randomized bursty cluster traces with mid-run reconfigurations
# ---------------------------------------------------------------------------
def _rand_pipe(rng, name):
    stages = []
    for j in range(int(rng.integers(1, 4))):
        l1 = 0.01 + 0.08 * rng.random()
        variants = tuple(
            ModelVariant(f"{name}_s{j}_{v}", 50.0 + 10 * v, 1 + v,
                         (0.0, l1 * sc * 0.7, l1 * sc * 0.3))
            for v, sc in enumerate((1.0, 1.7, 2.9)))
        stages.append(StageModel(f"{name}_s{j}", variants,
                                 sla=l1 * (4 + 6 * rng.random()),
                                 batch_choices=(1, 2, 4, 8)))
    return PipelineModel(name, tuple(stages))


def _rand_cfg(rng, pipe):
    return PipelineConfig(tuple(
        StageConfig(st.variants[int(rng.integers(len(st.variants)))].name,
                    int(rng.choice([1, 2, 4, 8])),
                    int(rng.integers(1, 4)))
        for st in pipe.stages))


@pytest.mark.parametrize("seed", range(8))
def test_cluster_random_bursty_with_transitions(seed):
    """Both cores step through four 10 s windows of bursty traffic (exact
    arrival-time ties included), a mid-run reconfigure + ``set_lam_est``
    at windows 1 and 3, and an ``adaptation_delay`` that lands the config
    apply *inside* a later window — then drain."""
    rng = np.random.default_rng(seed)
    n_pipes = int(rng.integers(1, 4))
    pipes = tuple(_rand_pipe(rng, f"p{i}") for i in range(n_pipes))
    cluster = ClusterModel("fz", pipes, 9999.0)
    cc = ClusterConfig(tuple(_rand_cfg(rng, p) for p in pipes))
    delay = float(rng.choice([0.0, 1.5, 8.0]))

    plans = []
    for w in range(4):
        winj = []
        for p in range(n_pipes):
            lam = rng.choice([2.0, 30.0, 300.0])
            ts = np.sort(10.0 * w + 10.0 * rng.random(rng.poisson(lam * 10.0)))
            if ts.size > 4:              # exact-tie arrivals
                ts[1] = ts[0]
                ts[ts.size // 2] = ts[ts.size // 2 - 1]
            winj.append(ts)
        plans.append(winj)

    sims = []
    for cls in CLUSTER_CORES:
        sim = cls(cluster, cc, adaptation_delay=delay)
        for w, winj in enumerate(plans):
            for p, ts in enumerate(winj):
                if (seed + 3 * w + 7 * p) % 3:
                    sim.inject_arrivals(ts, p)
                else:                    # scalar-inject path
                    for t in ts:
                        sim.inject(Request(arrival=float(t)), p)
            if w in (1, 3):
                r2 = np.random.default_rng(seed * 1000 + w)
                pidx = int(r2.integers(n_pipes))
                sim.reconfigure_pipeline(pidx, _rand_cfg(r2, pipes[pidx]))
                sim.set_lam_est(pidx, float(2.0 + 40.0 * r2.random()))
            sim.run_until(10.0 * (w + 1))
        sim.run_until(60.0)
        sims.append(sim)
    assert_same(*sims)
    if delay > 0.0 and sims[0].reconfig_log:
        # the transition landed mid-window: both cores logged the request
        # at the window edge with the apply at the delayed instant
        assert all(t in (10.0, 30.0) and t_apply == t + delay
                   for t, _p, t_apply in sims[0].reconfig_log)


# ---------------------------------------------------------------------------
# struct-core contract details
# ---------------------------------------------------------------------------
def test_factory_builds_both_cores_and_rejects_unknown():
    pipe = two_stage()
    cc = ClusterConfig((PipelineConfig((StageConfig("a0", 4, 1),
                                        StageConfig("b0", 2, 1))),))
    from repro.core.cluster import single
    from repro.core.simulator import RoundClusterSimulator
    cluster = single(pipe)
    assert EVENT_CORES == ("heap", "struct", "round")
    assert isinstance(make_cluster_simulator(cluster, cc),
                      ClusterSimulator)
    assert isinstance(make_cluster_simulator(cluster, cc,
                                             event_core="struct"),
                      StructClusterSimulator)
    assert isinstance(make_cluster_simulator(cluster, cc,
                                             event_core="round"),
                      RoundClusterSimulator)
    with pytest.raises(ValueError, match="unknown event core"):
        make_cluster_simulator(cluster, cc, event_core="vectorized")


def test_struct_core_rejects_record_timeline():
    pipe = two_stage()
    config = PipelineConfig((StageConfig("a0", 4, 1),
                             StageConfig("b0", 2, 1)))
    with pytest.raises(ValueError, match="record_timeline"):
        StructPipelineSimulator(pipe, config, record_timeline=True)


def test_struct_core_handles_unsorted_and_stale_injections():
    """Out-of-order bulk injections are sorted lazily; arrivals timestamped
    before the current clock enter their stage at the clock, exactly like
    the reference core."""
    pipe = two_stage()
    config = PipelineConfig((StageConfig("a0", 4, 1),
                             StageConfig("b0", 2, 1)))
    sims = []
    for cls in PIPE_CORES:
        sim = cls(pipe, config)
        sim.inject_arrivals(np.array([0.5, 0.1, 0.9, 0.3]))
        sim.run_until(2.0)
        sim.inject_arrivals(np.array([1.0, 1.7, 2.5]))  # 1.0, 1.7 stale
        sim.run_until(20.0)
        sims.append(sim)
    assert_same(*sims)
    assert sims[1].metrics.completed + sims[1].metrics.dropped == 7


# ---------------------------------------------------------------------------
# hypothesis: all three cores agree event-for-event on random bursty
# DAG / hetero clusters
# ---------------------------------------------------------------------------
def _coeffs(l1):
    return (0.0, l1 * 0.7, l1 * 0.3)


def _dag_pipe(name, l_fast, l_slow):
    """Diamond fan-out: src -> (fast || slow) -> join sink."""
    def stage(sname, l1):
        return StageModel(sname, (ModelVariant(sname + "0", 70.0, 1,
                                               _coeffs(l1)),),
                          sla=6 * l1, batch_choices=(1, 2, 4))
    stages = (stage(f"{name}_src", 0.01), stage(f"{name}_fast", l_fast),
              stage(f"{name}_slow", l_slow), stage(f"{name}_sink", 0.01))
    return PipelineModel(name, stages, parents=((), (0,), (0,), (1, 2)))


def _hetero_pipe(name, l1, l2):
    """Two-stage chain whose heavy variant ships a 3x-faster gpu build."""
    heavy = ModelVariant(
        f"{name}a1", 75.0, 2, _coeffs(2 * l1),
        device_profiles=(DeviceProfile("cpu", _coeffs(2 * l1), 2, 75.0),
                         DeviceProfile("gpu", _coeffs(2 * l1 / 3.0), 1,
                                       78.0)))
    s1 = StageModel(f"{name}_a",
                    (ModelVariant(f"{name}a0", 60.0, 1, _coeffs(l1)), heavy),
                    sla=5 * l1, batch_choices=(1, 2, 4))
    s2 = StageModel(f"{name}_b",
                    (ModelVariant(f"{name}b0", 70.0, 1, _coeffs(l2)),),
                    sla=5 * l2, batch_choices=(1, 2, 4))
    return PipelineModel(name, (s1, s2))


@given(
    seed=st.integers(0, 2**16),
    l_slow=st.sampled_from([0.05, 0.12, 0.3]),
    on_gpu=st.sampled_from([False, True]),
    delay=st.sampled_from([0.0, 1.5]),
    burst=st.sampled_from([8.0, 40.0, 150.0]),
)
def test_three_cores_agree_random_dag_hetero(seed, l_slow, on_gpu, delay,
                                             burst):
    """heap vs struct vs round on a mixed cluster — one diamond DAG
    pipeline (fan-out, join, §4.5 drop propagation) plus one hetero chain
    (per-class ledger) — under bursty arrivals with exact ties and a
    mid-run reconfiguration: full snapshots must be identical."""
    rng = np.random.default_rng(seed)
    dag = _dag_pipe("d", l_fast=0.01, l_slow=l_slow)
    het = _hetero_pipe("h", 0.04, 0.02)
    cluster = ClusterModel("fzmix", (dag, het), cores={"cpu": 64.0,
                                                       "gpu": 8.0})
    cfg = ClusterConfig((
        PipelineConfig((StageConfig("d_src0", 1, 2),
                        StageConfig("d_fast0", 2, 2),
                        StageConfig("d_slow0", 1, 1),
                        StageConfig("d_sink0", 1, 2))),
        PipelineConfig((StageConfig("ha0", 2, 2),
                        StageConfig("hb0", 2, 1)))))
    cfg2 = ClusterConfig((
        cfg.pipelines[0],
        PipelineConfig((StageConfig("ha1", 2, 2, "gpu" if on_gpu
                                    else "cpu"),
                        StageConfig("hb0", 1, 2)))))
    # two 5 s windows of bursty traffic per pipeline, with exact-tie
    # arrivals; the hetero pipe reconfigures (possibly onto gpu) at t=5
    plans = []
    for w in range(2):
        winj = []
        for _p in range(2):
            ts = np.sort(5.0 * w + 5.0 * rng.random(rng.poisson(burst)))
            if ts.size > 2:
                ts[1] = ts[0]            # exact tie
            winj.append(ts)
        plans.append(winj)

    sims = []
    for cls in CLUSTER_CORES:
        sim = cls(cluster, cfg, adaptation_delay=delay, drop_factor=1.2,
                  max_wait=0.25)
        for w, winj in enumerate(plans):
            for p, ts in enumerate(winj):
                sim.inject_arrivals(ts, p)
            if w == 1:
                sim.reconfigure_pipeline(1, cfg2.pipelines[1])
                sim.set_lam_est(1, float(burst) / 5.0)
            sim.run_until(5.0 * (w + 1))
        sim.run_until(30.0)
        sims.append(sim)
    assert_same(*sims)
