"""MoE routing properties + layer-level invariants (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import MoEConfig
from repro.models import layers as L
from repro.models import moe as MO

pytestmark = pytest.mark.slow  # jax model hot loops: run via `pytest -m slow`



def _mcfg(e=4, k=2, cf=2.0):
    return MoEConfig(n_experts=e, top_k=k, d_ff_expert=32, capacity_factor=cf)


@given(seed=st.integers(0, 1000), e=st.integers(2, 8), k=st.integers(1, 3),
       t=st.integers(1, 64))
@settings(max_examples=40, deadline=None)
def test_positions_in_expert_unique_slots(seed, e, k, t):
    """No two (token, k) pairs may claim the same (expert, slot)."""
    k = min(k, e)
    rng = np.random.default_rng(seed)
    top_i = jnp.asarray(rng.integers(0, e, size=(t, k)))
    mcfg = MoEConfig(n_experts=e, top_k=k, d_ff_expert=8)
    pos = np.asarray(MO._positions_in_expert(top_i, mcfg, cap=t))
    seen = set()
    for ti in range(t):
        for kj in range(k):
            key = (int(top_i[ti, kj]), int(pos[ti, kj]))
            assert key not in seen, key
            seen.add(key)


@given(seed=st.integers(0, 500))
@settings(max_examples=20, deadline=None)
def test_einsum_and_gather_dispatch_agree(seed):
    rng = jax.random.PRNGKey(seed)
    ks = jax.random.split(rng, 2)
    mcfg = _mcfg(cf=4.0)          # ample capacity -> no drops -> exact match
    p = MO.init_moe(ks[0], 16, mcfg, True, jnp.float32)
    x = jax.random.normal(ks[1], (2, 8, 16))
    y1, a1 = MO.moe_apply(p, x, mcfg, impl="einsum")
    y2, a2 = MO.moe_apply(p, x, mcfg, impl="gather")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    assert float(a1) == pytest.approx(float(a2), rel=1e-5)


def test_capacity_drops_are_graceful():
    """With capacity factor ~0, outputs fall back to the shared path/zero
    without NaNs."""
    mcfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16,
                     capacity_factor=0.01, n_shared_experts=1, d_ff_shared=16)
    p = MO.init_moe(jax.random.PRNGKey(0), 8, mcfg, True, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 8))
    for impl in ("einsum", "gather"):
        y, aux = MO.moe_apply(p, x, mcfg, impl=impl)
        assert not jnp.isnan(y).any(), impl
        assert jnp.isfinite(aux)


def test_group_routing_matches_single_group_when_equal():
    mcfg = _mcfg(cf=4.0)
    p = MO.init_moe(jax.random.PRNGKey(2), 16, mcfg, True, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, 16))
    y1, _ = MO.moe_apply(p, x, mcfg, group_size=16)    # one group
    y2, _ = MO.moe_apply(p, x, mcfg, group_size=8)     # two groups
    # different capacity boundaries -> not identical, but same scale & finite
    assert jnp.isfinite(y2).all()
    assert float(jnp.std(y2)) == pytest.approx(float(jnp.std(y1)), rel=0.5)


def test_aux_loss_penalizes_imbalance():
    """A router that sends everything to expert 0 must cost more than a
    uniform router."""
    mcfg = MoEConfig(n_experts=4, top_k=1, d_ff_expert=8,
                     router_aux_weight=1.0)
    d = 8
    p = MO.init_moe(jax.random.PRNGKey(4), d, mcfg, True, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 32, d))
    biased = dict(p, router=jnp.zeros((d, 4)).at[:, 0].set(10.0))
    uniform = dict(p, router=jnp.zeros((d, 4)))
    _, aux_b = MO.moe_apply(biased, x, mcfg)
    _, aux_u = MO.moe_apply(uniform, x, mcfg)
    assert float(aux_b) > float(aux_u)


# ---------------------------------------------------------------------------
# shared layers
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 500), s=st.sampled_from([32, 64, 128]),
       chunk=st.sampled_from([16, 32]))
@settings(max_examples=20, deadline=None)
def test_chunked_attention_equals_naive(seed, s, chunk):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    b, h, kv, hd = 2, 4, 2, 16
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    a = L.attention_naive(q, k, v, pos, pos)
    c = L.attention_chunked(q, k, v, pos, pos, query_chunk=chunk)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=2e-5)


def test_rope_relative_position_property():
    """RoPE: q.k depends only on relative distance."""
    hd = 32
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))
    def dot_at(pq, pk):
        qr = L.apply_rope(q, jnp.array([[pq]]), 10_000.0)
        kr = L.apply_rope(k, jnp.array([[pk]]), 10_000.0)
        return float(jnp.sum(qr * kr))
    assert dot_at(5, 3) == pytest.approx(dot_at(105, 103), abs=1e-4)
    assert dot_at(5, 3) != pytest.approx(dot_at(5, 4), abs=1e-4)


def test_rms_norm_scale_invariance():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 16))
    g = jnp.zeros((16,))
    y1 = L.rms_norm(x, g)
    y2 = L.rms_norm(x * 100.0, g)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
