import os

# keep unit tests on the single real CPU device; the 512-device trick is
# exclusively for launch/dryrun.py subprocesses.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np  # noqa: E402
import pytest  # noqa: E402

# Deterministic hypothesis profiles: property tests must reproduce
# bit-for-bit across runs and machines, so the default profile is
# derandomized with an explicit (disabled) deadline — wall-clock noise on
# a shared 1-CPU container must never flake a property.  "thorough" is the
# opt-in wider search (HYPOTHESIS_PROFILE=thorough).  When hypothesis is
# absent, tests/_hypothesis_compat.py provides the deterministic fallback
# and there is nothing to configure.
try:
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile(
        "repro", derandomize=True, deadline=None, max_examples=25)
    _hyp_settings.register_profile(
        "thorough", derandomize=True, deadline=None, max_examples=300)
    _hyp_settings.load_profile(
        os.environ.get("HYPOTHESIS_PROFILE", "repro"))
except ModuleNotFoundError:
    pass

# fast-tier duration gate (scripts/tier1.sh runs pytest with
# --enforce-fast): any test not marked `slow` that takes longer than this
# fails the run — the tier-1 loop stays interactive by construction.
FAST_CEILING_S = 2.0
# tests that predate the gate and genuinely need the time.  Empty since
# the sweep spawn-pool test went @pytest.mark.slow (its property is
# covered fast by the chunk-drain variant + the tier-1 sweep smoke).
# Frozen: new tests either fit the ceiling or carry @pytest.mark.slow —
# do not add here.
FAST_GRANDFATHERED: set = set()
_fast_offenders = []


def pytest_addoption(parser):
    parser.addoption(
        "--enforce-fast", action="store_true", default=False,
        help=f"fail if any test not marked 'slow' takes "
             f"> {FAST_CEILING_S:.0f}s")


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_makereport(item, call):
    outcome = yield
    if (call.when == "call"
            and item.config.getoption("--enforce-fast")
            and call.duration > FAST_CEILING_S
            and item.get_closest_marker("slow") is None
            and item.nodeid not in FAST_GRANDFATHERED):
        _fast_offenders.append((item.nodeid, call.duration))


def pytest_terminal_summary(terminalreporter, exitstatus, config):
    if config.getoption("--enforce-fast") and _fast_offenders:
        terminalreporter.section("fast-tier duration gate")
        for nodeid, dur in _fast_offenders:
            terminalreporter.write_line(
                f"TOO SLOW ({dur:.2f}s > {FAST_CEILING_S:.0f}s): {nodeid}"
                "  -- speed it up or mark it @pytest.mark.slow")


def pytest_sessionfinish(session, exitstatus):
    if session.config.getoption("--enforce-fast") and _fast_offenders:
        session.exitstatus = 1


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """Bound in-process XLA-CPU JIT dylib accumulation: a full-suite run in
    one process can otherwise exhaust the JIT object cache and fail with
    'Failed to materialize symbols' on this 1-CPU/35GB container."""
    yield
    import jax
    jax.clear_caches()
