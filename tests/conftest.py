import os

# keep unit tests on the single real CPU device; the 512-device trick is
# exclusively for launch/dryrun.py subprocesses.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """Bound in-process XLA-CPU JIT dylib accumulation: a full-suite run in
    one process can otherwise exhaust the JIT object cache and fail with
    'Failed to materialize symbols' on this 1-CPU/35GB container."""
    yield
    import jax
    jax.clear_caches()
