"""Adversarial audit of the bulk-injection fast path and the request
pool at scale-bench sizes: exact window-boundary arrivals, exact-tie
timestamps, empty windows, stale (behind-the-clock) injections, mixed
scalar/bulk streams, and pool recycling must all leave the observable
simulation — metrics, latency streams, event counts — bit-identical to
the plain per-request path, on both event cores."""
import numpy as np
import pytest

from repro.core.pipeline import PipelineConfig, StageConfig
from repro.core.simulator import PipelineSimulator, StructPipelineSimulator
from repro.serving.request import Request, RequestPool

from test_simulator_equivalence import two_stage
from test_simulator_struct import assert_same, full_snapshot


def _config():
    return PipelineConfig((StageConfig("a0", 4, 2), StageConfig("b0", 2, 2)))


def _adversarial_windows(rng):
    """Window plan with every boundary pathology the fast path special-
    cases: arrivals exactly at window edges, duplicates of the edge,
    runs of exact ties, empty windows, and occasional stale arrivals
    timestamped before the already-run clock."""
    windows = []
    for w in range(6):
        t0, t1 = 2.0 * w, 2.0 * (w + 1)
        roll = rng.random()
        if roll < 0.2:
            ts = np.empty(0)
        else:
            ts = np.sort(t0 + (t1 - t0) * rng.random(int(rng.integers(1, 60))))
            ts = np.concatenate([ts, [t1, t1]])      # exact right-edge ties
            if roll < 0.5:
                ts = np.concatenate([[t0], ts])      # exact left edge
            if ts.size > 6:
                ts[2] = ts[1]                        # interior exact tie
            if w >= 2 and roll < 0.35:
                ts = np.concatenate([[t0 - 1.0], ts])  # stale arrival
        windows.append((np.sort(ts), t1))
    return windows


def _drive(sim, windows, bulk):
    for ts, t1 in windows:
        if bulk:
            sim.inject_arrivals(ts)
        else:
            for t in ts:
                sim.inject(Request(arrival=float(t), sla=sim.sla_of[0]), 0)
        sim.run_until(t1)
    sim.run_until(windows[-1][1] + 30.0)             # drain


@pytest.mark.parametrize("seed", range(10))
def test_bulk_scalar_and_struct_agree_on_adversarial_boundaries(seed):
    rng = np.random.default_rng(seed)
    windows = _adversarial_windows(rng)
    pipe = two_stage()
    sims = []
    for cls, bulk in ((PipelineSimulator, False),
                      (PipelineSimulator, True),
                      (StructPipelineSimulator, True)):
        sim = cls(pipe, _config())
        _drive(sim, windows, bulk)
        sims.append(sim)
    assert_same(sims[0], sims[1])
    assert_same(sims[0], sims[2])


@pytest.mark.parametrize("seed", range(6))
def test_pool_recycling_is_invisible_and_conserves(seed):
    """A pooled replay must match a pool-less one exactly, and at
    quiescence every pooled request is back on the free list with
    ``allocated + reused`` covering every arrival."""
    rng = np.random.default_rng(100 + seed)
    windows = _adversarial_windows(rng)
    total = sum(ts.size for ts, _ in windows)
    pool = RequestPool()
    plain = PipelineSimulator(two_stage(), _config())
    pooled = PipelineSimulator(two_stage(), _config(), request_pool=pool)
    for sim in (plain, pooled):
        _drive(sim, windows, bulk=True)
    assert_same(plain, pooled)
    assert pool.allocated + pool.reused == total
    assert len(pool._free) == pool.allocated         # all returned
    m = pooled.metrics
    assert m.completed + m.dropped == m.arrived == total


def test_acquire_many_matches_sequential_acquires():
    """Bulk acquisition recycles the same number of requests and stamps
    ids in arrival order, exactly as a loop of ``acquire`` calls."""
    seq, bulk = RequestPool(), RequestPool()
    for pool in (seq, bulk):
        pool.release_many([Request(arrival=0.0) for _ in range(3)])
    ts = [0.5, 1.0, 1.0, 2.5, 3.0]
    a = [seq.acquire(t, sla=1.0) for t in ts]
    b = bulk.acquire_many(ts, sla=1.0)
    assert [r.arrival for r in a] == [r.arrival for r in b] == ts
    assert all(r.sla == 1.0 for r in b)
    assert (seq.allocated, seq.reused) == (bulk.allocated, bulk.reused) \
        == (2, 3)
    for reqs in (a, b):                   # fresh ids, stamped in order
        ids = [r.req_id for r in reqs]
        assert ids == sorted(ids) and len(set(ids)) == len(ids)


def test_exact_boundary_injection_keeps_sorted_fast_path():
    """``ts[0] == col[-1]`` is still sorted — the fast path must not
    degrade to the sort, and a shuffled injection of the same times must
    take the slow path yet land on the identical simulation."""
    pipe = two_stage()
    fast = PipelineSimulator(pipe, _config())
    fast.inject_arrivals(np.array([0.1, 0.4, 0.7]))
    fast.inject_arrivals(np.array([0.7, 0.9]))       # exact boundary tie
    assert fast._inj_sorted
    slow = PipelineSimulator(pipe, _config())
    slow.inject_arrivals(np.array([0.7, 0.1, 0.9, 0.4, 0.7]))
    assert not slow._inj_sorted
    for sim in (fast, slow):
        sim.run_until(20.0)
    # equal-time FIFO differs between the two injection orders only in
    # which tied request is which — aggregate observables must agree
    fa, sa = full_snapshot(fast), full_snapshot(slow)
    assert fa == sa


@pytest.mark.parametrize("event_core", ["heap", "struct"])
def test_scale_window_with_heavy_ties(event_core):
    """One bench-sized window (>10k arrivals, long runs of exact ties)
    through the pooled bulk path: conservation plus pool quiescence."""
    rng = np.random.default_rng(7)
    base = np.sort(10.0 * rng.random(12_000))
    ts = np.sort(np.concatenate([base, base[::97], base[::101]]))
    pool = RequestPool() if event_core == "heap" else None
    cls = PipelineSimulator if event_core == "heap" \
        else StructPipelineSimulator
    sim = cls(two_stage(), _config(), request_pool=pool)
    sim.inject_arrivals(ts)
    sim.run_until(60.0)
    m = sim.metrics
    assert m.arrived == ts.size
    assert m.completed + m.dropped == ts.size
    if pool is not None:
        assert pool.allocated + pool.reused == ts.size
        assert len(pool._free) == pool.allocated
