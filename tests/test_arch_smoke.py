"""Per-architecture smoke tests (deliverable f): reduced variant of each
family runs one forward + one train step on CPU; output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import model as M
from repro.training import data, optim
from repro.training.train import make_train_step

pytestmark = pytest.mark.slow  # jax model hot loops: run via `pytest -m slow`



def _batch(cfg, b=2, s=32, rng=None):
    if rng is None:
        rng = jax.random.PRNGKey(0)
    out = {"tokens": jax.random.randint(rng, (b, s), 0, cfg.vocab)}
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(rng, (b, cfg.encoder_seq, cfg.d_model)) * 0.02
    if cfg.family == "vlm":
        out["patches"] = jax.random.normal(rng, (b, cfg.n_patches, cfg.d_model)) * 0.02
    return out


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_reduced_forward_shapes_no_nans(arch):
    cfg = configs.get_config(arch, reduced=True)
    assert cfg.n_layers <= 4 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    params = M.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    h, aux = M.forward(params, cfg, batch, impl="naive")
    lg = M.logits(params, cfg, h)
    assert lg.shape == (2, 32, cfg.vocab)
    assert not jnp.isnan(lg).any()
    assert not jnp.isnan(aux)


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = configs.get_config(arch, reduced=True)
    params = M.init(jax.random.PRNGKey(0), cfg)
    opt = optim.init_state(params)
    step = jax.jit(make_train_step(cfg, optim.AdamWConfig(total_steps=10),
                                   impl="naive"))
    batch = _batch(cfg)
    batch["labels"] = batch["tokens"]
    params2, opt2, m = step(params, opt, batch)
    assert jnp.isfinite(m["loss"])
    assert jnp.isfinite(m["grad_norm"]) and m["grad_norm"] > 0
    # parameters actually moved
    moved = jax.tree.reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x))),
        jax.tree.map(lambda a, b: (a.astype(jnp.float32)
                                   - b.astype(jnp.float32)), params, params2),
        0.0)
    assert moved > 0


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_decode_matches_forward(arch):
    """Incremental decode with KV/SSM caches == full forward logits."""
    cfg = configs.get_config(arch, reduced=True)
    params = M.init(jax.random.PRNGKey(1), cfg)
    B, S = 2, 64
    rng = jax.random.PRNGKey(2)
    batch = _batch(cfg, B, S, rng)
    toks = batch["tokens"]
    h, _ = M.forward(params, cfg, batch, impl="naive")
    full_lg = M.logits(params, cfg, h)
    npre = S - 3
    prefix = cfg.n_patches if cfg.family == "vlm" else 0
    pb = dict(batch, tokens=toks[:, :npre])
    hl, caches, plen = M.prefill(params, cfg, pb, impl="naive",
                                 capacity=prefix + S)
    lg = jnp.einsum("bd,vd->bv", hl, params["embed"])
    errs = [float(jnp.max(jnp.abs(lg - full_lg[:, npre - 1])))]
    clen = plen
    for t in range(npre, S):
        lg, caches = M.decode_step(params, cfg, caches, jnp.int32(clen),
                                   toks[:, t:t + 1])
        errs.append(float(jnp.max(jnp.abs(lg - full_lg[:, t]))))
        clen += 1
    assert max(errs) < 2e-4, errs


@pytest.mark.parametrize("arch", ["gemma3-27b", "kimi-k2-1t-a32b",
                                  "jamba-v0.1-52b", "mamba2-2.7b",
                                  "whisper-medium"])
def test_unrolled_stack_matches_scanned(arch):
    """The dry-run cost probes (unroll=True) compute the same function."""
    cfg = configs.get_config(arch, reduced=True)
    params = M.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    h1, _ = M.forward(params, cfg, batch, impl="naive", unroll=False)
    h2, _ = M.forward(params, cfg, batch, impl="naive", unroll=True)
    assert float(jnp.max(jnp.abs(h1 - h2))) < 1e-5


def test_full_configs_match_published_sizes():
    expected = {"gemma3-27b": 27.0, "mamba2-2.7b": 2.7, "whisper-medium": 0.76,
                "starcoder2-3b": 3.0, "starcoder2-15b": 15.7,
                "phi-3-vision-4.2b": 3.7, "kimi-k2-1t-a32b": 1044.0,
                "qwen2-moe-a2.7b": 14.0, "yi-34b": 34.0,
                "jamba-v0.1-52b": 51.0}
    for arch, bil in expected.items():
        got = configs.get_config(arch).n_params() / 1e9
        assert got == pytest.approx(bil, rel=0.08), (arch, got)


def test_moe_active_params():
    kimi = configs.get_config("kimi-k2-1t-a32b")
    assert kimi.n_active_params() / 1e9 == pytest.approx(33.0, rel=0.1)


def test_sliding_window_cache_is_bounded():
    """gemma3 local layers keep only window-sized caches (long_500k basis)."""
    cfg = configs.get_config("gemma3-27b", reduced=True)
    cache = jax.eval_shape(lambda: M.init_cache(cfg, 1, 4096))
    sizes = {leaf.shape[-3] for leaf in jax.tree.leaves(cache)
             if len(leaf.shape) >= 4}
    assert cfg.sliding_window in sizes       # local layers: ring buffer
    assert 4096 in sizes                      # global layers: full cache
