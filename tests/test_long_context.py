"""Long-context decode correctness: ring-buffer wraparound, SSM state over
long horizons, and reconfiguration cold-start accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.pipeline import (ModelVariant, PipelineConfig, PipelineModel,
                                 StageConfig, StageModel)
from repro.core.simulator import PipelineSimulator
from repro.models import model as M
from repro.serving.request import Request


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["gemma3-27b", "starcoder2-3b"])
def test_sliding_window_ring_wraparound(arch):
    """Decode FAR past the sliding window: the ring buffer must overwrite
    old entries and logits must keep matching the full forward pass."""
    cfg = configs.get_config(arch, reduced=True)
    W = cfg.sliding_window
    assert W is not None and W <= 64
    S = 3 * W            # cross the window boundary twice
    params = M.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0, cfg.vocab)
    h, _ = M.forward(params, cfg, {"tokens": toks}, impl="naive")
    full_lg = M.logits(params, cfg, h)

    npre = W // 2        # prefill shorter than the window
    _, caches, plen = M.prefill(params, cfg, {"tokens": toks[:, :npre]},
                                impl="naive", capacity=S)
    errs = []
    clen = plen
    for t in range(npre, S):
        lg, caches = M.decode_step(params, cfg, caches, jnp.int32(clen),
                                   toks[:, t:t + 1])
        # check every W//4 steps to keep runtime sane
        if t % (W // 4) == 0 or t == S - 1:
            errs.append(float(jnp.max(jnp.abs(lg - full_lg[:, t]))))
        clen += 1
    assert max(errs) < 2e-4, errs


@pytest.mark.slow
def test_mamba_state_long_horizon():
    """SSM decode over a horizon >> chunk size stays consistent."""
    cfg = configs.get_config("mamba2-2.7b", reduced=True)
    S = 4 * cfg.ssm.chunk_size
    params = M.init(jax.random.PRNGKey(2), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, S), 0, cfg.vocab)
    h, _ = M.forward(params, cfg, {"tokens": toks}, impl="naive")
    full_lg = M.logits(params, cfg, h)
    npre = S // 2
    _, caches, plen = M.prefill(params, cfg, {"tokens": toks[:, :npre]},
                                impl="naive", capacity=S)
    clen = plen
    errs = []
    for t in range(npre, S):
        lg, caches = M.decode_step(params, cfg, caches, jnp.int32(clen),
                                   toks[:, t:t + 1])
        if t % 16 == 0 or t == S - 1:
            errs.append(float(jnp.max(jnp.abs(lg - full_lg[:, t]))))
        clen += 1
    assert max(errs) < 5e-4, errs


# ---------------------------------------------------------------------------
# reconfiguration cold-start (paper §5.3: ~8 s adaptation process)
# ---------------------------------------------------------------------------
def _pipe():
    v1 = ModelVariant("light", 50.0, 1, (0.0, 0.02, 0.02))
    v2 = ModelVariant("heavy", 80.0, 2, (0.0, 0.05, 0.05))
    return PipelineModel("p", (StageModel("s", (v1, v2), sla=0.5,
                                          batch_choices=(1, 2)),))


def test_variant_switch_cold_start_delays_service():
    pipe = _pipe()
    lam = 10.0
    arr = np.linspace(0.0, 4.0, 40)
    results = {}
    for delay in (0.0, 2.0):
        sim = PipelineSimulator(pipe, PipelineConfig(
            (StageConfig("light", 1, 2),)), variant_switch_delay=delay)
        for t in arr:
            sim.inject(Request(arrival=float(t), sla=pipe.sla))
        sim.run_until(1.0)
        sim.reconfigure(PipelineConfig((StageConfig("heavy", 1, 2),)))
        sim.run_until(20.0)
        results[delay] = np.mean(sim.metrics.latencies)
    assert results[2.0] > results[0.0]       # cold start visibly hurts


def test_scale_up_delay_only_affects_new_replicas():
    pipe = _pipe()
    sim = PipelineSimulator(pipe, PipelineConfig(
        (StageConfig("light", 1, 1),)), scale_up_delay=5.0)
    sim.now = 1.0
    sim.reconfigure(PipelineConfig((StageConfig("light", 1, 3),)))
    free = sorted(sim.free_at[0])
    assert free[0] <= 1.0            # existing replica unaffected
    assert free[1] == free[2] == 6.0  # new ones start after the delay
