"""Optimizer correctness: Eq. 10 solvers vs oracle + invariants (property)."""
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import accuracy as ACC
from repro.core import baselines as BL
from repro.core import optimizer as OPT
from repro.core import paper_profiles as PP
from repro.core.pipeline import ModelVariant, PipelineModel, StageModel
from repro.core.queueing import queue_delay


def random_pipeline(rng: np.random.Generator, n_stages=None, n_variants=None):
    n_stages = n_stages or int(rng.integers(1, 4))
    stages = []
    for s in range(n_stages):
        nv = n_variants or int(rng.integers(1, 4))
        variants = []
        for v in range(nv):
            l1 = float(rng.uniform(0.01, 0.4))
            variants.append(ModelVariant(
                name=f"s{s}v{v}",
                accuracy=float(rng.uniform(30, 95)),
                base_alloc=int(rng.choice([1, 2, 4, 8])),
                latency_coeffs=(l1 * 0.001, l1 * 0.6, l1 * 0.4)))
        sla = float(5.0 * np.mean([v.latency(1) for v in variants]))
        stages.append(StageModel(f"stage{s}", tuple(variants), sla,
                                 batch_choices=(1, 2, 4, 8)))
    return PipelineModel("rand", tuple(stages))


@given(seed=st.integers(0, 10_000), lam=st.floats(0.5, 60.0))
@settings(max_examples=40, deadline=None)
def test_enum_matches_brute_oracle(seed, lam):
    pipe = random_pipeline(np.random.default_rng(seed))
    obj = OPT.Objective(alpha=2.0, beta=0.7, delta=1e-5, metric="pas")
    se = OPT.solve_enum(pipe, lam, obj)
    sb = OPT.solve_brute(pipe, lam, obj)
    assert se.feasible == sb.feasible
    if se.feasible:
        assert se.objective == pytest.approx(sb.objective, rel=1e-9)


@given(seed=st.integers(0, 10_000), lam=st.floats(0.5, 60.0),
       metric=st.sampled_from(["pas", "pas_prime", "log_pas"]))
@settings(max_examples=60, deadline=None)
def test_vec_is_bit_identical_to_brute(seed, lam, metric):
    """The hot-path contract: ``solve_vec`` (broadcast float64 numpy) and
    ``solve_brute`` (plain python) agree *bitwise* — same config (ties
    included: both scan the option lattice in itertools.product order and
    take the first maximum), same objective/pas/cost/latency floats."""
    pipe = random_pipeline(np.random.default_rng(seed))
    obj = OPT.Objective(alpha=2.0, beta=0.7, delta=1e-5, metric=metric)
    sv = OPT.solve_vec(pipe, lam, obj)
    sb = OPT.solve_brute(pipe, lam, obj)
    assert sv.feasible == sb.feasible
    if sv.feasible:
        assert sv.config == sb.config
        assert sv.objective == sb.objective
        assert sv.pas == sb.pas
        assert sv.cost == sb.cost
        assert sv.latency == sb.latency


@given(seed=st.integers(0, 10_000), lam=st.floats(0.5, 40.0))
@settings(max_examples=30, deadline=None)
def test_vec_matches_brute_under_restrictions(seed, lam):
    """The fa2/rim paths: restricted variants and pinned replication run
    through the same broadcast machinery, still bit-identical."""
    pipe = random_pipeline(np.random.default_rng(seed))
    lo = [s.lightest.name for s in pipe.stages]
    obj = OPT.Objective(alpha=0.0, beta=1.0, delta=1e-6)
    sv = OPT.solve_vec(pipe, lam, obj, restrict_variants=lo)
    sb = OPT.solve_brute(pipe, lam, obj, restrict_variants=lo)
    assert sv.feasible == sb.feasible
    if sv.feasible:
        assert sv.config == sb.config and sv.objective == sb.objective
    obj = OPT.Objective(alpha=1.0, beta=0.0, delta=1e-6)
    sv = OPT.solve_vec(pipe, lam, obj, fixed_replicas=8)
    sb = OPT.solve_brute(pipe, lam, obj, fixed_replicas=8)
    assert sv.feasible == sb.feasible
    if sv.feasible:
        assert sv.config == sb.config and sv.objective == sb.objective


def test_solve_auto_picks_vec():
    pipe = PP.video()
    sol = OPT.solve(pipe, 12.0, OPT.Objective())
    assert sol.solver == "vec"
    assert sol.feasible


def test_vec_rejects_oversized_lattice():
    pipe = PP.video()
    with pytest.raises(ValueError):
        OPT.solve_vec(pipe, 10.0, OPT.Objective(), max_combos=1)


@given(seed=st.integers(0, 10_000), lam=st.floats(0.5, 50.0))
@settings(max_examples=40, deadline=None)
def test_solution_satisfies_constraints(seed, lam):
    """Property: every returned config meets Eq. 10b/10c/10d."""
    pipe = random_pipeline(np.random.default_rng(seed))
    sol = OPT.solve_enum(pipe, lam, OPT.Objective())
    if not sol.feasible:
        return
    cfg = sol.config
    assert len(cfg.stages) == len(pipe.stages)             # 10d (one variant)
    total_lat = 0.0
    for sc, st_ in zip(cfg.stages, pipe.stages):
        v = st_.variant(sc.variant)                        # valid variant
        assert sc.batch in st_.batch_choices
        assert sc.replicas >= 1
        # 10c: n_s * h_s(b_s) >= lambda
        assert sc.replicas * v.throughput(sc.batch) >= lam - 1e-6
        total_lat += float(v.latency(sc.batch)) + queue_delay(sc.batch, lam)
    assert total_lat <= pipe.sla + 1e-9                    # 10b


@given(seed=st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_milp_matches_brute_on_linear_metric(seed):
    """MILP (HiGHS) is exact for the linear PAS' objective."""
    rng = np.random.default_rng(seed)
    pipe = random_pipeline(rng)
    lam = float(rng.uniform(1, 40))
    obj = OPT.Objective(alpha=3.0, beta=0.5, delta=1e-5, metric="pas_prime")
    sm = OPT.solve_milp(pipe, lam, obj)
    sb = OPT.solve_brute(pipe, lam, obj)
    assert sm.feasible == sb.feasible
    if sm.feasible:
        assert sm.objective == pytest.approx(sb.objective, rel=1e-6)


def test_replicas_are_minimal():
    """n*(m, b) = ceil(lambda / h) — the substitution the solvers rely on."""
    pipe = PP.video()
    lam = 20.0
    sol = OPT.solve_enum(pipe, lam, OPT.Objective())
    for sc, st_ in zip(sol.config.stages, pipe.stages):
        v = st_.variant(sc.variant)
        assert sc.replicas == math.ceil(lam / float(v.throughput(sc.batch)))


def test_alpha_beta_tradeoff_monotone():
    """Fig. 14: raising alpha (accuracy weight) never lowers PAS; raising
    beta (cost weight) never raises cost."""
    pipe = PP.video()
    lam = 15.0
    pas_vals, cost_vals = [], []
    for alpha in (0.1, 1.0, 10.0, 100.0):
        s = OPT.solve_enum(pipe, lam, OPT.Objective(alpha=alpha, beta=1.0))
        pas_vals.append(s.pas)
    assert all(b >= a - 1e-9 for a, b in zip(pas_vals, pas_vals[1:]))
    for beta in (0.01, 0.1, 1.0, 10.0):
        s = OPT.solve_enum(pipe, lam, OPT.Objective(alpha=1.0, beta=beta))
        cost_vals.append(s.cost)
    assert all(b <= a + 1e-9 for a, b in zip(cost_vals, cost_vals[1:]))


def test_ipa_between_fa2_low_and_high():
    """Table-1 premise: IPA's accuracy/cost sit between the FA2 pins."""
    pipe = PP.video()
    lam = 10.0
    obj = OPT.Objective(alpha=2.0, beta=1.0)
    ipa = BL.ipa(pipe, lam, obj=obj)
    low = BL.fa2(pipe, lam, "low")
    high = BL.fa2(pipe, lam, "high")
    assert low.pas - 1e-9 <= ipa.pas <= high.pas + 1e-9
    assert low.cost - 1e-9 <= ipa.cost


def test_rim_is_accuracy_greedy_and_expensive():
    pipe = PP.video()
    r = BL.rim(pipe, 10.0)
    h = BL.fa2(pipe, 10.0, "high")
    assert r.pas >= h.pas - 1e-9
    assert r.cost >= h.cost


def test_infeasible_when_sla_impossible():
    rng = np.random.default_rng(1)
    pipe = random_pipeline(rng)
    # shrink SLA below the fastest batch-1 latency
    tight = PipelineModel(pipe.name, tuple(
        StageModel(s.name, s.variants, sla=1e-6, batch_choices=s.batch_choices)
        for s in pipe.stages))
    sol = OPT.solve_enum(tight, 5.0, OPT.Objective())
    assert not sol.feasible


def test_pas_metrics():
    assert ACC.pas([100.0, 100.0]) == pytest.approx(100.0)
    assert ACC.pas([50.0, 50.0]) == pytest.approx(25.0)
    rn = ACC.rank_normalized([70.0, 90.0, 80.0])
    assert list(rn) == [0.0, 1.0, 0.5]
