"""Optional-`hypothesis` shim: property tests degrade to fixed examples.

Test modules import ``given``, ``settings`` and ``st`` from here instead of
from ``hypothesis`` directly.  When hypothesis is installed, the real thing
is re-exported unchanged.  When it is absent (the CI container does not
ship it), a tiny deterministic fallback runs each ``@given`` test over a
fixed set of examples: the strategy bounds first (lo/hi for scalars, the
first choice for ``sampled_from``), then seeded pseudo-random draws.  No
shrinking, no database — just enough coverage that the suite collects and
exercises the properties everywhere.

Only the strategy surface this repo uses is implemented: ``st.integers``,
``st.floats``, ``st.sampled_from``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import zlib

    import numpy as np

    _FALLBACK_EXAMPLES = 5

    class _Strategy:
        def __init__(self, draw, boundaries=()):
            self._draw = draw
            self._boundaries = tuple(boundaries)

        def example(self, rng: np.random.Generator, i: int):
            if i < len(self._boundaries):
                return self._boundaries[i]
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)),
                boundaries=(min_value, max_value))

        @staticmethod
        def floats(min_value: float, max_value: float) -> _Strategy:
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)),
                boundaries=(float(min_value), float(max_value)))

        @staticmethod
        def sampled_from(elements) -> _Strategy:
            seq = list(elements)
            return _Strategy(
                lambda rng: seq[int(rng.integers(len(seq)))],
                boundaries=(seq[0],))

    st = _Strategies()

    def settings(**_kwargs):
        """No-op stand-in for ``hypothesis.settings``."""
        return lambda fn: fn

    def given(**strategies):
        """Run the test once per fixed example instead of property search."""
        def deco(fn):
            # stable per-test seed so failures reproduce across runs
            seed = zlib.crc32(fn.__name__.encode())

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = np.random.default_rng(seed)
                executed = 0
                for i in range(_FALLBACK_EXAMPLES):
                    drawn = {name: strat.example(rng, i)
                             for name, strat in strategies.items()}
                    fn(*args, **drawn, **kwargs)
                    executed += 1
                # the fallback's whole value is that the property body
                # genuinely ran over every fixed example — a strategy or
                # loop regression that silently skips them must fail loud,
                # not collect as a vacuous pass
                assert executed == _FALLBACK_EXAMPLES, (
                    f"{fn.__name__}: only {executed}/{_FALLBACK_EXAMPLES} "
                    "fallback examples executed")
                wrapper.examples_executed = executed

            # hide the strategy-filled parameters from pytest's fixture
            # resolution (it would otherwise look for fixtures named after
            # them); leave any genuine fixture parameters visible
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies])
            return wrapper
        return deco
