"""Trace synthesizer: cache-collision regression, vectorized AR(1)
bit-identity, the production-scale knob and the scale stress excerpts."""
import numpy as np
import pytest

from repro.core import trace as TR


# ---------------------------------------------------------------------------
# the PR 6 cache-collision fix: full_trace memoized on cfg.seed only, so two
# same-seed configs with different shape parameters silently shared a trace
# ---------------------------------------------------------------------------
def test_full_trace_cache_keyed_on_full_config():
    a = TR.TraceConfig(seed=123, base_rps=10.0)
    b = TR.TraceConfig(seed=123, base_rps=40.0, burst_amp=60.0)
    ta = TR.full_trace(a)
    tb = TR.full_trace(b)
    assert not np.array_equal(ta, tb)
    # and the second lookup comes straight from the synthesizer, not a
    # stale entry for the first config (the original bug)
    np.testing.assert_array_equal(tb, TR.make_days(TR.TOTAL_DAYS, b))
    # the cache still caches: identical config objects hit the same entry
    assert TR.full_trace(TR.TraceConfig(seed=123, base_rps=10.0)) is ta


def test_trace_config_is_frozen_and_hashable():
    cfg = TR.TraceConfig(seed=5)
    assert hash(cfg) == hash(TR.TraceConfig(seed=5))
    with pytest.raises(dataclasses_FrozenError):
        cfg.seed = 6


# dataclasses raises FrozenInstanceError
import dataclasses  # noqa: E402

dataclasses_FrozenError = dataclasses.FrozenInstanceError


# ---------------------------------------------------------------------------
# PR 7: the trace cache is LRU-bounded (thousand-cell sweeps must not grow
# memory without limit), and eviction is harmless — a re-miss regenerates
# bit-identical bytes because synthesis is a pure function of the config
# ---------------------------------------------------------------------------
def test_bounded_cache_evicts_lru_and_regenerates_bitidentical():
    cache = TR.BoundedTraceCache(max_entries=3)
    build = lambda c: TR.synth_trace(2000, c)            # noqa: E731
    cfgs = [TR.TraceConfig(seed=i, base_rps=8.0 + i) for i in range(5)]
    first = {c: build(c).copy() for c in cfgs}
    for c in cfgs[:3]:
        cache.get(c, build)
    assert len(cache) == 3 and cache.misses == 3
    cache.get(cfgs[0], build)                            # refresh cfg 0
    assert cache.hits == 1
    cache.get(cfgs[3], build)                            # evicts cfg 1 (LRU)
    cache.get(cfgs[4], build)                            # evicts cfg 2
    assert len(cache) == 3
    assert cfgs[0] in cache and cfgs[3] in cache and cfgs[4] in cache
    assert cfgs[1] not in cache and cfgs[2] not in cache
    # the evicted config regenerates the exact same bytes on re-miss
    misses = cache.misses
    again = cache.get(cfgs[1], build)
    assert cache.misses == misses + 1
    np.testing.assert_array_equal(again, first[cfgs[1]])


def test_full_trace_respects_cache_bound(monkeypatch):
    monkeypatch.setattr(TR, "_trace_cache", TR.BoundedTraceCache(2))
    cfgs = [TR.TraceConfig(seed=900 + i) for i in range(3)]
    traces = [TR.full_trace(c).copy() for c in cfgs]
    assert len(TR._trace_cache) == 2                     # cfg 0 evicted
    np.testing.assert_array_equal(TR.full_trace(cfgs[0]), traces[0])


def test_bounded_cache_rejects_nonpositive_cap():
    with pytest.raises(ValueError):
        TR.BoundedTraceCache(max_entries=0)


# ---------------------------------------------------------------------------
# vectorized AR(1): bit-identical to the per-second python loop
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed,rho", [(0, 0.95), (7, 0.5), (42, 0.999)])
def test_ar1_noise_bit_identical_to_loop(seed, rho):
    rng = np.random.default_rng(seed)
    eps = rng.standard_normal(50_000) * 1.6 * np.sqrt(1 - rho ** 2)
    acc = 0.0
    ref = np.empty(len(eps))
    for i in range(len(eps)):
        acc = rho * acc + eps[i]
        ref[i] = acc
    np.testing.assert_array_equal(TR._ar1_noise(eps, rho), ref)


def test_synth_trace_deterministic_and_positive():
    cfg = TR.TraceConfig(seed=9)
    a = TR.synth_trace(3600, cfg)
    b = TR.synth_trace(3600, cfg)
    np.testing.assert_array_equal(a, b)
    assert np.all(a >= 0.5)


# ---------------------------------------------------------------------------
# the scale knob: shape-preserving lift into the thousands-of-RPS regime
# ---------------------------------------------------------------------------
def test_scale_knob_multiplies_the_whole_curve():
    base = TR.synth_trace(1200, TR.TraceConfig(seed=3))
    scaled = TR.synth_trace(1200, TR.TraceConfig(seed=3, scale=50.0))
    np.testing.assert_allclose(scaled, base * 50.0, rtol=1e-12)
    assert scaled.mean() > 400.0          # production regime


def test_scale_default_is_identity():
    cfg = TR.TraceConfig(seed=4)
    assert cfg.scale == 1.0
    np.testing.assert_array_equal(TR.synth_trace(600, cfg),
                                  TR.synth_trace(600, TR.TraceConfig(seed=4,
                                                                     scale=1.0)))


# ---------------------------------------------------------------------------
# production-scale stress excerpts
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", TR.SCALE_EXCERPTS)
def test_scale_excerpts_deterministic(kind):
    cfg = TR.TraceConfig(seed=11)
    a = TR.scale_excerpt(kind, 600, cfg)
    b = TR.scale_excerpt(kind, 600, cfg)
    np.testing.assert_array_equal(a, b)
    assert len(a) == 600 and np.all(a >= 0.5)


def test_heavy_tailed_excerpt_has_a_heavy_tail():
    """The max burst must tower over the median rate — the Pareto
    amplitudes are the point of the shape."""
    r = TR.scale_excerpt("heavy_tailed", 600, TR.TraceConfig(seed=9))
    assert r.max() > 4.0 * np.median(r)
    # and across seeds the shape always spikes well past the median
    ratios = [TR.scale_excerpt("heavy_tailed", 600,
                               TR.TraceConfig(seed=s)).max()
              / np.median(TR.scale_excerpt("heavy_tailed", 600,
                                           TR.TraceConfig(seed=s)))
              for s in range(6)]
    assert min(ratios) > 2.0


def test_flash_crowd_excerpt_steps_then_decays():
    cfg = TR.TraceConfig(seed=8, base_rps=10.0, burst_amp=12.0)
    r = TR.scale_excerpt("flash_crowd", 600, cfg)
    peak_i = int(np.argmax(r))
    # quiet before the crowd lands, a towering peak, decay after
    assert r[:max(peak_i - 60, 1)].max() < r[peak_i] / 3.0
    assert r[peak_i] > 5.0 * cfg.base_rps
    tail = r[min(peak_i + 300, 599):]
    assert tail.mean() < r[peak_i] / 2.0


def test_scale_excerpt_respects_scale_knob():
    a = TR.scale_excerpt("flash_crowd", 300, TR.TraceConfig(seed=1))
    b = TR.scale_excerpt("flash_crowd", 300, TR.TraceConfig(seed=1,
                                                            scale=10.0))
    np.testing.assert_allclose(b, a * 10.0, rtol=1e-12)


def test_scale_excerpt_rejects_unknown_kind():
    with pytest.raises(ValueError):
        TR.scale_excerpt("nope", 100)
