"""Predictive demand path through the cluster adapter (paper §3 predictor
+ Fig. 16 ablation, lifted to N pipelines): per-pipeline Oracle/LSTM
estimates, the burst-aware max-of-window fallback, and their wiring
through ``run_cluster_trace``."""
import numpy as np
import pytest

from repro.core import adapter as AD
from repro.core import optimizer as OPT
from repro.core import predictor as PR
from repro.core import trace as TR
from repro.core.cluster import ClusterModel
from test_cluster import toy_cluster


OBJ = OPT.Objective(alpha=1.0, beta=0.02)


def step_burst_rates():
    """Deterministic anti-correlated step bursts that start and stop
    mid-interval — the regime where looking ahead (oracle) beats trailing
    the window (reactive) on both edges of every burst."""
    t = np.arange(100, dtype=np.float64)
    r_a = np.where((t >= 25) & (t < 45), 24.0, 2.0)
    r_b = np.where((t >= 65) & (t < 85), 24.0, 2.0)
    return [r_a, r_b]


# ---------------------------------------------------------------------------
# oracle vs reactive
# ---------------------------------------------------------------------------
def test_oracle_never_worse_mean_pas_than_reactive():
    """Fig.-16 lifted to the cluster: ground-truth next-interval demand
    must never lose mean PAS to the reactive trailing-window estimate on a
    deterministic bursty trace — and on this one it is strictly better
    (reactive over-holds burst configs for a full trailing window after
    each burst ends) while also dropping strictly fewer requests (reactive
    under-provisions every burst onset)."""
    cl = toy_cluster(cores=18.0)
    rates = step_burst_rates()
    # oracle horizon = the adaptation interval: predict the max load of
    # exactly the window this decision will serve
    oracles = PR.OraclePredictor.for_traces(rates, horizon=int(AD.ADAPT_INTERVAL))
    reactive = AD.run_cluster_trace(cl, rates, policy="ipa", obj=OBJ, seed=5)
    oracle = AD.run_cluster_trace(cl, rates, policy="ipa", obj=OBJ, seed=5,
                                  oracles=oracles)
    assert oracle.mean_pas >= reactive.mean_pas - 1e-9
    assert oracle.mean_pas > reactive.mean_pas + 1e-6
    assert oracle.dropped < reactive.dropped
    # the oracle's lam_hat tracks the true next-interval load exactly
    for p, r in enumerate(rates):
        for rec in oracle.per_pipeline[p].intervals:
            fut = r[int(rec.t):int(rec.t) + int(AD.ADAPT_INTERVAL)]
            assert rec.lam_hat == pytest.approx(float(fut.max()))


@pytest.mark.slow
def test_per_pipeline_lstm_smape_under_single_pipeline_bound():
    """Per-pipeline LSTM predictors on synthetic Twitter-style traces stay
    under the SMAPE bound already asserted for the single-pipeline path
    (test_predictor_trace.test_lstm_learns_and_beats_trivial_baseline)."""
    for seed in (3, 11):
        trace = TR.synth_trace(86_400 * 2, TR.TraceConfig(seed=seed))
        (lstm,) = PR.train_cluster_predictors([trace[:86_400]], steps=200,
                                              stride=40)
        X, y = PR.make_windows(trace[86_400:], stride=200)
        s = PR.smape(lstm.predict_batch(X), y)
        assert s < 15.0, f"seed {seed}: SMAPE {s}"


def test_lstm_predictor_wires_into_cluster_trace():
    """A (stub) per-pipeline predictor's estimates must drive the recorded
    lam_hat — pipelines without one fall back to the windowed estimate."""
    class Stub:
        def __init__(self, v):
            self.v = v
            self.calls = 0

        def predict(self, history):
            self.calls += 1
            return self.v

    cl = toy_cluster(cores=40.0)
    rates = [np.full(40, 5.0), np.full(40, 5.0)]
    stub = Stub(7.5)
    res = AD.run_cluster_trace(cl, rates, policy="ipa", obj=OBJ, seed=2,
                               predictors=[stub, None])
    assert stub.calls > 0
    # boundary 0 bootstraps from the first-interval peak; later boundaries
    # use the predictor for pipe 0 and the reactive window for pipe 1
    for rec in res.per_pipeline[0].intervals[1:]:
        assert rec.lam_hat == 7.5
    for rec in res.per_pipeline[1].intervals[1:]:
        assert rec.lam_hat == 5.0


def test_predictor_released_when_trace_ends():
    """Ragged traces: once a pipeline's trace has ended its demand estimate
    must drop to 0 even under oracle/predictor estimation (a finished
    pipeline may not keep competing for shared cores)."""
    cl = toy_cluster(cores=30.0)
    rates = [np.full(40, 5.0), np.full(15, 5.0)]
    res = AD.run_cluster_trace(cl, rates, policy="ipa", obj=OBJ, seed=2,
                               oracles=PR.OraclePredictor.for_traces(rates))
    assert res.per_pipeline[1].intervals[-1].lam_hat == 0.0


# ---------------------------------------------------------------------------
# burst-aware max-of-window fallback
# ---------------------------------------------------------------------------
def test_burst_demand_longer_window_holds_past_peaks():
    """A burst that peaked 40 s ago is gone from the 20 s reactive window
    but still reserved by the 60 s burst-aware one."""
    trace = np.concatenate([np.full(10, 30.0), np.full(50, 2.0)])
    t0 = 50.0
    assert AD.reactive_demand(trace, t0) == 2.0
    assert AD.burst_demand(trace, t0) == 30.0
    # both bootstrap identically and release ended traces
    assert AD.burst_demand(trace, 0.0) == AD.reactive_demand(trace, 0.0)
    assert AD.burst_demand(trace, 60.0) == 0.0


def test_burst_mode_flows_through_cluster_trace():
    """demand_mode='burst' must reserve capacity through a burst's decay:
    right after the burst window slides out of the 20 s reactive window,
    the burst-aware run still plans for the peak."""
    cl = toy_cluster(cores=40.0)
    t = np.arange(60, dtype=np.float64)
    r_a = np.where(t < 10, 25.0, 2.0)
    rates = [r_a, np.full(60, 2.0)]
    reactive = AD.run_cluster_trace(cl, rates, policy="ipa", obj=OBJ, seed=4)
    burst = AD.run_cluster_trace(cl, rates, policy="ipa", obj=OBJ, seed=4,
                                 demand_mode="burst")
    # boundary t=40: burst seconds [0,10) left the 20 s window but are
    # still inside the 60 s one
    rec_r = [rec for rec in reactive.per_pipeline[0].intervals if rec.t == 40.0]
    rec_b = [rec for rec in burst.per_pipeline[0].intervals if rec.t == 40.0]
    assert rec_r[0].lam_hat == 2.0
    assert rec_b[0].lam_hat == 25.0
    with pytest.raises(ValueError):
        AD.run_cluster_trace(cl, rates, policy="ipa", obj=OBJ,
                             demand_mode="nope")


def test_predictor_length_validation():
    cl = toy_cluster()
    rates = [np.full(20, 2.0), np.full(20, 2.0)]
    with pytest.raises(ValueError):
        AD.run_cluster_trace(cl, rates, predictors=[None])
    with pytest.raises(ValueError):
        AD.run_cluster_trace(cl, rates, oracles=[None, None, None])
