"""Training substrate + real serving engine tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import profiler as PF
from repro.models import model as M
from repro.serving.batching import CentralQueue
from repro.serving.engine import PipelineEngine, StageServer
from repro.serving.request import Request
from repro.training import checkpoint, data, optim
from repro.training.train import cross_entropy, train_loop

pytestmark = pytest.mark.slow  # jax model hot loops: run via `pytest -m slow`



def test_loss_decreases_in_short_training():
    cfg = configs.get_config("starcoder2-3b", reduced=True)
    stream = data.SyntheticStream(cfg, data.DataConfig(seq_len=64,
                                                       batch_size=8))
    ocfg = optim.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=120)
    _, _, hist = train_loop(cfg, stream, steps=120, log_every=20, ocfg=ocfg,
                            verbose=False)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.2


def test_chunked_ce_matches_direct():
    rng = jax.random.PRNGKey(0)
    b, s, d, v = 2, 64, 32, 128
    hidden = jax.random.normal(rng, (b, s, d))
    embed = jax.random.normal(rng, (v, d))
    labels = jax.random.randint(rng, (b, s), 0, v)
    a = cross_entropy(hidden, embed, labels, chunk=16)
    bfull = cross_entropy(hidden, embed, labels, chunk=10**9)
    assert float(jnp.abs(a - bfull)) < 1e-4


def test_ce_label_masking():
    rng = jax.random.PRNGKey(1)
    hidden = jax.random.normal(rng, (1, 8, 16))
    embed = jax.random.normal(rng, (32, 16))
    labels = jnp.full((1, 8), -1)
    labels = labels.at[0, 0].set(3)
    one = cross_entropy(hidden, embed, labels)
    assert jnp.isfinite(one)


def test_adamw_schedule():
    cfg = optim.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(optim.schedule(cfg, jnp.int32(s))) for s in (0, 5, 10, 100)]
    assert lrs[0] == 0.0 and lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[3] == pytest.approx(1e-4, rel=0.01)   # min_lr_ratio * lr


def test_checkpoint_roundtrip(tmp_path):
    cfg = configs.get_config("yi-34b", reduced=True)
    params = M.init(jax.random.PRNGKey(0), cfg)
    path = str(tmp_path / "ckpt.npz")
    checkpoint.save(path, params)
    like = jax.eval_shape(lambda: params)
    restored = checkpoint.load(path, like)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_synthetic_stream_deterministic_and_learnable():
    cfg = configs.get_config("yi-34b", reduced=True)
    st = data.SyntheticStream(cfg, data.DataConfig(seq_len=32, batch_size=2))
    b0a, b0b = st.batch(0), st.batch(0)
    np.testing.assert_array_equal(b0a["tokens"], b0b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b0a["labels"][:, :-1], b0a["tokens"][:, 1:])


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def small_server():
    fam = configs.get_variant_family("yi-34b")[:2]
    return StageServer("clf", fam, gen_tokens=2)


def test_stage_server_switching(small_server):
    srv = small_server
    toks = np.zeros((2, 8), np.int32)
    out1, _ = srv.process(toks)
    assert out1.shape == (2, 2)
    acc1 = srv.accuracy
    srv.set_variant(list(srv.variants)[1])
    out2, _ = srv.process(toks)
    assert out2.shape == (2, 2)
    assert srv.accuracy != acc1


def test_variant_switch_changes_outputs(small_server):
    srv = small_server
    toks = np.arange(16, dtype=np.int32).reshape(2, 8)
    names = list(srv.variants)
    srv.set_variant(names[0]); o1, _ = srv.process(toks)
    srv.set_variant(names[1]); o2, _ = srv.process(toks)
    assert not np.array_equal(o1, o2)


def test_pipeline_engine_chains(small_server):
    fam2 = configs.get_variant_family("starcoder2-3b")[:2]
    eng = PipelineEngine([small_server,
                          StageServer("qa", fam2, gen_tokens=2)])
    out, lats = eng.serve(np.zeros((1, 8), np.int32))
    assert out.shape == (1, 2) and len(lats) == 2
    assert 0 < eng.pas <= 100


def test_profile_real_stage_server(small_server):
    profs = PF.profile_stage_server(small_server, batches=(1, 2), repeats=1)
    assert len(profs) == 2
    for p in profs:
        assert all(l > 0 for l in p.latencies)


# ---------------------------------------------------------------------------
# central queue
# ---------------------------------------------------------------------------
def test_central_queue_batching():
    q = CentralQueue(batch_size=4, max_wait=10.0)
    for i in range(6):
        q.push(Request(arrival=float(i) * 0.01))
    assert q.ready(0.06)
    batch = q.pop_batch(0.06)
    assert len(batch) == 4 and len(q) == 2


def test_central_queue_timeout():
    q = CentralQueue(batch_size=8, max_wait=0.5)
    q.push(Request(arrival=0.0))
    assert not q.ready(0.1)
    assert q.ready(0.6)                 # oldest waited past max_wait


def test_central_queue_drop_expired():
    q = CentralQueue(batch_size=4)
    q.push(Request(arrival=0.0, sla=1.0))
    q.push(Request(arrival=2.9, sla=1.0))
    dropped = q.drain_expired(3.0, stage=0, drop_factor=2.0)
    assert len(dropped) == 1 and dropped[0].dropped
    assert len(q) == 1
