"""LSTM predictor + trace synthesis tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import predictor as PR
from repro.core import trace as TR


def test_trace_shapes_and_positivity():
    t = TR.synth_trace(3600)
    assert t.shape == (3600,) and (t > 0).all()


def test_trace_deterministic():
    a = TR.synth_trace(600, TR.TraceConfig(seed=5))
    b = TR.synth_trace(600, TR.TraceConfig(seed=5))
    np.testing.assert_array_equal(a, b)


def test_excerpt_statistics():
    lo = TR.excerpt("steady_low", 600)
    hi = TR.excerpt("steady_high", 600)
    bu = TR.excerpt("bursty", 600)
    fl = TR.excerpt("fluctuating", 600)
    assert lo.mean() < hi.mean()
    assert lo.std() / lo.mean() < 0.2 and hi.std() / hi.mean() < 0.2
    assert bu.max() / bu.mean() > 2.0          # a real burst
    assert fl.std() / fl.mean() > 0.25


def test_arrivals_poisson_consistent():
    rates = np.full(200, 12.0)
    arr = TR.arrivals_from_rates(rates, seed=0)
    assert abs(len(arr) / 200 - 12.0) < 1.5    # ~3 sigma
    assert (np.diff(arr) >= 0).all()


def test_make_windows_alignment():
    t = np.arange(300, dtype=float)
    X, y = PR.make_windows(t, stride=20)
    assert X.shape[1] == PR.HISTORY
    # label = max of the 20 s following the window
    np.testing.assert_allclose(
        y[0], t[PR.HISTORY:PR.HISTORY + PR.HORIZON].max())


def test_lstm_shapes_and_determinism():
    p = PR.init_lstm(jax.random.PRNGKey(0))
    x = jnp.ones((3, PR.HISTORY))
    out = PR.lstm_apply(p, x)
    assert out.shape == (3,)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(PR.lstm_apply(p, x)))


@pytest.mark.slow
def test_lstm_learns_and_beats_trivial_baseline():
    trace = TR.synth_trace(86_400 * 2, TR.TraceConfig(seed=3))
    lstm = PR.LSTMPredictor.train(trace[:86_400], steps=200, stride=40)
    X, y = PR.make_windows(trace[86_400:], stride=200)
    pred = lstm.predict_batch(X)
    s_lstm = PR.smape(pred, y)
    s_last = PR.smape(X[:, -1], y)             # persistence baseline
    assert s_lstm < 15.0
    assert s_lstm < s_last + 1.0               # at least competitive


def test_reactive_and_oracle():
    r = PR.ReactivePredictor()
    hist = np.array([1.0, 2.0, 9.0] + [3.0] * 30)
    assert r.predict(hist) == 3.0 or r.predict(hist) >= 3.0
    tr = np.arange(100, dtype=float)
    o = PR.OraclePredictor(tr)
    assert o.predict_at(10) == tr[10:30].max()


def test_smape_bounds():
    assert PR.smape(np.ones(5), np.ones(5)) == 0.0
    assert 0 < PR.smape(np.ones(5) * 2, np.ones(5)) < 100.0
