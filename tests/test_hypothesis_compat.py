"""Meta-tests for the optional-hypothesis shim: the fallback must really
run every fixed example (boundary values first, then seeded draws) and be
deterministic across invocations — a vacuous pass here would silently
hollow out every property test in the suite."""
import _hypothesis_compat as HC
import pytest


@pytest.mark.skipif(HC.HAVE_HYPOTHESIS,
                    reason="real hypothesis installed; fallback inactive")
def test_fallback_executes_every_fixed_example():
    seen = []

    @HC.given(x=HC.st.integers(min_value=-3, max_value=9),
              y=HC.st.sampled_from(["a", "b"]))
    def prop(x, y):
        seen.append((x, y))

    prop()
    assert prop.examples_executed == HC._FALLBACK_EXAMPLES
    assert len(seen) == HC._FALLBACK_EXAMPLES
    # boundary examples lead: strategy bounds before pseudo-random draws
    assert seen[0][0] == -3 and seen[1][0] == 9
    assert seen[0][1] == "a"
    assert all(-3 <= x <= 9 and y in ("a", "b") for x, y in seen)
    # deterministic: a second run replays the identical example sequence
    first = list(seen)
    seen.clear()
    prop()
    assert seen == first


@pytest.mark.skipif(HC.HAVE_HYPOTHESIS,
                    reason="real hypothesis installed; fallback inactive")
def test_fallback_floats_respect_bounds_and_boundaries():
    seen = []

    @HC.given(v=HC.st.floats(min_value=0.5, max_value=2.5))
    def prop(v):
        seen.append(v)

    prop()
    assert seen[:2] == [0.5, 2.5]
    assert all(0.5 <= v <= 2.5 for v in seen)
    assert len(seen) == HC._FALLBACK_EXAMPLES


@pytest.mark.skipif(HC.HAVE_HYPOTHESIS,
                    reason="real hypothesis installed; fallback inactive")
def test_fallback_propagates_failures_with_example_values():
    @HC.given(x=HC.st.integers(min_value=0, max_value=100))
    def prop(x):
        assert x < 50   # boundary example 100 must trip this

    with pytest.raises(AssertionError):
        prop()
