"""DAG stage graphs: validation, critical-path latency (vs a brute
all-paths oracle), solver agreement on random DAGs, the zero-demand
queueing fix, and the variant tie-break fixes."""
import dataclasses

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import optimizer as OPT
from repro.core.pipeline import (ModelVariant, PipelineConfig, PipelineModel,
                                 StageConfig, StageModel)
from repro.core.queueing import expected_wait, queue_delay, wait_bound
from repro.core.simulator import PipelineSimulator, StructPipelineSimulator


def var(name, l1, acc=70.0, alloc=1):
    return ModelVariant(name, acc, alloc, (0.0, l1 * 0.7, l1 * 0.3))


def stage(name, l1, acc=70.0, alloc=1, sla=None):
    return StageModel(name, (var(name + "0", l1, acc, alloc),),
                      sla=sla if sla is not None else 5 * l1,
                      batch_choices=(1, 2, 4))


def diamond(sla_override=None):
    stages = tuple(stage(f"s{i}", 0.02 * (i + 1)) for i in range(4))
    return PipelineModel("diamond", stages,
                         parents=((), (0,), (0,), (1, 2)),
                         sla_override=sla_override)


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------
def test_parents_length_mismatch_rejected():
    with pytest.raises(ValueError, match="entries for"):
        PipelineModel("bad", (stage("a", 0.01), stage("b", 0.01)),
                      parents=((),))


def test_source_with_parents_rejected():
    with pytest.raises(ValueError, match="single source"):
        PipelineModel("bad", (stage("a", 0.01), stage("b", 0.01)),
                      parents=((1,), (0,)))


def test_orphan_stage_rejected():
    with pytest.raises(ValueError, match="only stage 0"):
        PipelineModel("bad", (stage("a", 0.01), stage("b", 0.01),
                              stage("c", 0.01)),
                      parents=((), (), (0, 1)))


def test_forward_parent_reference_rejected():
    with pytest.raises(ValueError, match="earlier stages"):
        PipelineModel("bad", (stage("a", 0.01), stage("b", 0.01),
                              stage("c", 0.01)),
                      parents=((), (2,), (1,)))


def test_multiple_sinks_rejected():
    # stage 1 feeds nothing and is not the last stage
    with pytest.raises(ValueError, match="single"):
        PipelineModel("bad", (stage("a", 0.01), stage("b", 0.01),
                              stage("c", 0.01)),
                      parents=((), (0,), (0,)))


def test_parents_deduped_and_sorted():
    pipe = PipelineModel("p", (stage("a", 0.01), stage("b", 0.01),
                               stage("c", 0.01), stage("d", 0.01)),
                         parents=((), (0,), (0, 0), (2, 1, 1)))
    assert pipe.parents == ((), (0,), (0,), (1, 2))


# ---------------------------------------------------------------------------
# graph accessors
# ---------------------------------------------------------------------------
def test_chain_is_chain_and_single_path():
    pipe = PipelineModel("c", (stage("a", 0.01), stage("b", 0.01),
                               stage("c", 0.01)))
    assert pipe.is_chain
    assert pipe.paths() == ((0, 1, 2),)
    assert pipe.children_of(0) == (1,)
    assert pipe.parents_of(2) == (1,)


def test_explicit_path_graph_counts_as_chain():
    pipe = PipelineModel("c", (stage("a", 0.01), stage("b", 0.01)),
                         parents=((), (0,)))
    assert pipe.is_chain
    assert pipe.sla == PipelineModel(
        "c", (stage("a", 0.01), stage("b", 0.01))).sla


def test_diamond_paths_and_critical_path():
    pipe = diamond()
    assert not pipe.is_chain
    assert pipe.paths() == ((0, 1, 3), (0, 2, 3))
    # stage SLAs are 5*l1 with l1 = 0.02*(i+1): path via stage 2 is heavier
    assert pipe.critical_path() == (0, 2, 3)
    assert pipe.critical_path(weights=[0, 9, 1, 0]) == (0, 1, 3)
    assert pipe.sla == pytest.approx(5 * (0.02 + 0.06 + 0.08))


def test_linearize_keeps_dag_budget():
    pipe = diamond(sla_override=0.33)
    lin = pipe.linearize()
    assert lin.is_chain
    assert lin.sla == pytest.approx(0.33)
    assert lin.stages == pipe.stages


def test_dag_latency_is_max_over_paths():
    pipe = diamond()
    cfg = PipelineConfig(tuple(StageConfig(s.variants[0].name, 1, 1)
                               for s in pipe.stages))
    lam = 10.0
    terms = [float(s.variants[0].latency(1)) for s in pipe.stages]
    want = max(terms[0] + terms[1] + terms[3], terms[0] + terms[2] + terms[3])
    assert cfg.latency(pipe, lam) == pytest.approx(want)
    # the linearized chain charges every stage: strictly larger here
    assert cfg.latency(pipe.linearize(), lam) > cfg.latency(pipe, lam)


# ---------------------------------------------------------------------------
# random DAGs: latency vs brute all-paths oracle; solve_vec vs solve_brute
# ---------------------------------------------------------------------------
def random_dag(seed: int):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 7))
    parents = [()]
    for i in range(1, n):
        k = int(rng.integers(1, min(i, 3) + 1))
        parents.append(tuple(sorted(rng.choice(i, size=k, replace=False))))
    # single sink: attach any unreferenced stage to the last one
    referenced = {p for ps in parents for p in ps}
    extra = [i for i in range(n - 1) if i not in referenced]
    if extra:
        parents[-1] = tuple(sorted(set(parents[-1]) | set(extra)))
    stages = tuple(
        stage(f"s{i}", float(rng.uniform(0.01, 0.08)),
              acc=float(rng.uniform(60.0, 90.0)),
              alloc=int(rng.integers(1, 3)))
        for i in range(n))
    return PipelineModel(f"rand{seed}", stages, parents=tuple(parents))


def oracle_paths(parents):
    """Brute DFS enumeration, independent of PipelineModel.paths()."""
    n = len(parents)
    children = [[] for _ in range(n)]
    for i, ps in enumerate(parents):
        for p in ps:
            children[p].append(i)
    out = []

    def walk(i, path):
        if not children[i]:
            out.append(tuple(path))
            return
        for c in children[i]:
            walk(c, path + [c])

    walk(0, [0])
    return out


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_random_dag_latency_matches_all_paths_oracle(seed):
    pipe = random_dag(seed)
    cfg = PipelineConfig(tuple(StageConfig(s.variants[0].name, b, 1)
                               for s, b in zip(pipe.stages, [1, 2, 4] * 2)))
    for lam in (0.0, 3.0, 25.0):
        terms = []
        for sc, s in zip(cfg.stages, pipe.stages):
            svc = float(s.variant(sc.variant).latency(sc.batch))
            terms.append(svc + float(queue_delay(sc.batch, lam)))
        want = max(sum(terms[i] for i in path)
                   for path in oracle_paths(pipe.effective_parents))
        got = cfg.latency(pipe, lam)
        assert got == want or (np.isinf(got) and np.isinf(want))
        assert set(pipe.paths()) == set(oracle_paths(pipe.effective_parents))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_random_dag_solve_vec_matches_brute(seed):
    pipe = random_dag(seed)
    for lam in (2.0, 9.0):
        sv = OPT.solve_vec(pipe, lam)
        sb = OPT.solve_brute(pipe, lam)
        assert sv.feasible == sb.feasible
        if sv.feasible:
            assert sv.config == sb.config
            assert sv.objective == sb.objective
            assert sv.latency == sb.latency


def test_dag_solve_milp_agrees_with_brute():
    pytest.importorskip("scipy")
    pipe = diamond()
    obj = OPT.Objective(metric="pas_prime")   # linear metric: MILP-exact
    for lam in (2.0, 8.0):
        sm = OPT.solve_milp(pipe, lam, obj)
        sb = OPT.solve_brute(pipe, lam, obj)
        assert sm.feasible == sb.feasible
        if sm.feasible:
            assert sm.objective == pytest.approx(sb.objective)
            assert sm.latency == pytest.approx(sb.latency)


# ---------------------------------------------------------------------------
# zero-demand queueing semantics (the lam=0 blow-up fix)
# ---------------------------------------------------------------------------
def test_queue_delay_zero_demand():
    d = queue_delay(np.array([1, 2, 8]), 0.0)
    assert d[0] == 0.0 and np.isinf(d[1]) and np.isinf(d[2])
    assert float(queue_delay(1, -1.0)) == 0.0
    assert expected_wait(1, 0.0) == 0.0
    assert expected_wait(4, 0.0) == float("inf")
    # the simulator timeout degrades to exactly max_wait, never overflows
    assert wait_bound(8, 0.0, max_wait=0.5) == 0.5
    assert wait_bound(1, 0.0, max_wait=0.5) == 0.0


def test_planner_zero_demand_feasible_at_batch_one():
    pipe = diamond()
    sol = OPT.solve_vec(pipe, 0.0)
    assert sol.feasible
    assert all(sc.batch == 1 for sc in sol.config.stages)
    assert np.isfinite(sol.latency)
    sb = OPT.solve_brute(pipe, 0.0)
    assert sol.config == sb.config and sol.objective == sb.objective


@pytest.mark.parametrize("cls", [PipelineSimulator, StructPipelineSimulator])
def test_simulator_zero_demand_estimate_serves(cls):
    """lam_est=0 (an idle interval) must not blow up batch timeouts: a
    sub-filled batch still dispatches at max_wait and completes."""
    pipe = PipelineModel("c2", (stage("a", 0.02), stage("b", 0.01)))
    cfg = PipelineConfig((StageConfig("a0", 4, 1), StageConfig("b0", 1, 1)))
    sim = cls(pipe, cfg, max_wait=0.25)
    sim.lam_est = 0.0
    sim.inject_arrivals(np.array([1.0]))
    sim.run_until(10.0)
    m = sim.metrics
    assert m.completed == 1 and m.dropped == 0
    # dispatched at the max_wait cap, not after an ~1e9 s clamp artifact
    assert float(m.latencies[0]) == pytest.approx(
        0.25 + float(pipe.stages[0].variants[0].latency(1))
        + float(pipe.stages[1].variants[0].latency(1)))


# ---------------------------------------------------------------------------
# variant tie-breaks (equal accuracy -> cheaper; equal alloc -> more accurate)
# ---------------------------------------------------------------------------
def test_heaviest_prefers_cheaper_at_equal_accuracy():
    s = StageModel("t", (ModelVariant("pricy", 80.0, 8, (0.0, 0.01, 0.01)),
                         ModelVariant("cheap", 80.0, 2, (0.0, 0.01, 0.01)),
                         ModelVariant("light", 60.0, 1, (0.0, 0.005, 0.005))),
                   sla=0.5)
    assert s.heaviest.name == "cheap"


def test_lightest_prefers_more_accurate_at_equal_alloc():
    s = StageModel("t", (ModelVariant("worse", 55.0, 1, (0.0, 0.01, 0.01)),
                         ModelVariant("better", 70.0, 1, (0.0, 0.01, 0.01)),
                         ModelVariant("heavy", 80.0, 4, (0.0, 0.02, 0.02))),
                   sla=0.5)
    assert s.lightest.name == "better"


def test_latency_coeffs_docstring_order():
    # (α, β, γ) multiply (b², b, 1) in that order
    v = ModelVariant("v", 50.0, 1, (1.0, 10.0, 100.0))
    assert float(v.latency(2)) == pytest.approx(1.0 * 4 + 10.0 * 2 + 100.0)
