"""Sharding rules + a real multi-device jit on a small host-device mesh.

The 512-device production dry-run needs its own process (XLA device count is
locked at first init), so the full sweep lives in launch/dryrun.py; here we
verify the same code path on an 8-device subprocess and the spec rules
in-process.
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.distributed import sharding as shd
from repro.models import model as M

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class FakeMesh:
    """Just enough Mesh interface for spec-rule tests."""

    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)
        self.axis_sizes = tuple(shape.values())


def test_param_specs_shard_big_dims():
    cfg = configs.get_config("yi-34b")
    params_shape = jax.eval_shape(lambda: M.init(jax.random.PRNGKey(0), cfg))
    mesh = FakeMesh({"data": 16, "model": 16})
    specs = shd.param_specs(params_shape, mesh, fsdp=False)
    assert specs["embed"] == P("model", None)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    # every mlp w_in shards its ffn dim over model
    for path, spec in flat:
        s = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path)
        if s.endswith("mlp/w_in"):
            assert spec[-1] == "model", (s, spec)


def test_param_specs_divisibility_respected():
    """starcoder2 kv=2 heads can't shard 16 ways -> replicated, not padded."""
    cfg = configs.get_config("starcoder2-3b")
    params_shape = jax.eval_shape(lambda: M.init(jax.random.PRNGKey(0), cfg))
    mesh = FakeMesh({"data": 16, "model": 16})
    specs = shd.param_specs(params_shape, mesh, fsdp=False)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    for path, spec in flat:
        s = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path)
        if "attn/wk" in s:
            assert spec[-2] is None     # 2 kv heads stay replicated


def test_fsdp_adds_data_axis():
    cfg = configs.get_config("kimi-k2-1t-a32b")
    params_shape = jax.eval_shape(lambda: M.init(jax.random.PRNGKey(0), cfg))
    mesh = FakeMesh({"data": 16, "model": 16})
    s_no = shd.param_specs(params_shape, mesh, fsdp=False)
    s_yes = shd.param_specs(params_shape, mesh, fsdp=True)
    def count_data(t):
        return sum("data" in str(s) for s in jax.tree.leaves(
            t, is_leaf=lambda x: isinstance(x, P)))
    assert count_data(s_yes) > count_data(s_no)


def test_cache_specs_context_parallel_when_batch_1():
    cfg = configs.get_config("gemma3-27b")
    cache_shape = jax.eval_shape(lambda: M.init_cache(cfg, 1, 8192))
    mesh = FakeMesh({"data": 16, "model": 16})
    shape = configs.INPUT_SHAPES["long_500k"]
    specs = shd.cache_specs(cfg, shape, mesh, cache_shape)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    seq_sharded = [spec for path, spec in flat
                   if str(path[-1]).find("k") >= 0 and spec[-3] == "data"]
    assert seq_sharded, "long-context decode must context-parallel the cache"


@pytest.mark.slow
def test_small_mesh_train_step_runs():
    """Actually execute a sharded train step on 8 host devices."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import configs
from repro.distributed import api as dapi, sharding as shd
from repro.models import model as M
from repro.training import optim
from repro.training.train import make_train_step

cfg = configs.get_config("qwen2-moe-a2.7b", reduced=True)
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((4, 2), ("data", "model"))
dapi.set_axis_rules(shd.axis_rules(mesh))
params = M.init(jax.random.PRNGKey(0), cfg)
opt = optim.init_state(params)
pspec = shd.param_specs(jax.eval_shape(lambda: params), mesh, fsdp=True)
ospec = {"mu": pspec, "nu": pspec, "step": P()}
step = make_train_step(cfg, optim.AdamWConfig(total_steps=5), impl="naive")
rng = jax.random.PRNGKey(1)
batch = {"tokens": jax.random.randint(rng, (8, 32), 0, cfg.vocab)}
batch["labels"] = batch["tokens"]
bspec = {k: P("data", None) for k in batch}
# newer jax: jax.set_mesh + PartitionSpec shardings; older jax: the Mesh is
# the context manager and jit needs concrete NamedShardings
mesh_ctx = getattr(jax, "set_mesh", None)
if mesh_ctx is None:
    mesh_ctx = lambda m: m
    to_sh = lambda tree: jax.tree.map(
        lambda sp: jax.sharding.NamedSharding(mesh, sp), tree,
        is_leaf=lambda x: isinstance(x, P))
    pspec, ospec, bspec = to_sh(pspec), to_sh(ospec), to_sh(bspec)
with mesh_ctx(mesh):
    jitted = jax.jit(step, in_shardings=(pspec, ospec, bspec),
                     out_shardings=(pspec, ospec, None))
    p2, o2, m = jitted(params, opt, batch)
print("LOSS", float(m["loss"]))
assert jnp.isfinite(m["loss"])
"""
    # pin the subprocess to cpu: the host-platform device-count trick works
    # on the cpu backend, and without the pin jax probes for TPUs (slow
    # GCP-metadata retries on plain containers)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "LOSS" in out.stdout


def test_dryrun_results_if_present():
    """Validate any dry-run artifacts already produced by the sweep."""
    d = os.path.join(REPO, "results", "dryrun")
    if not os.path.isdir(d) or not os.listdir(d):
        pytest.skip("no dry-run artifacts yet")
    bad = []
    for f in os.listdir(d):
        if not f.endswith(".json"):
            continue
        rec = json.load(open(os.path.join(d, f)))
        if not rec.get("ok"):
            bad.append((f, rec.get("error")))
            continue
        assert rec["hlo_flops_per_dev"] > 0
        assert rec["bottleneck"] in ("compute", "memory", "collective")
    assert not bad, bad
