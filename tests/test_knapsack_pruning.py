"""Dominance pruning in the cluster knapsack must be *invisible*: the
pruned DP has to return the exact same chosen candidates — same objects,
same tie-breaks — as the dense unpruned DP, not merely the same objective.
The reference implementations below are the pre-pruning dense DPs,
kept verbatim as oracles.  Tabs are generated with discrete values and
overlap-collapsed costs so exact (cost, value) ties actually occur.
"""
import numpy as np
import pytest

from repro.core.optimizer import (_Candidate, _knapsack_1d, _knapsack_2d,
                                  _prune_candidates)


# ---------------------------------------------------------------------------
# reference oracles: the dense DPs before pruning/column-capping
# ---------------------------------------------------------------------------
def _ref_knap_1d(cand_tabs, budget):
    if not np.isfinite(budget):
        return [max(cands, key=lambda c: c.value) for cands in cand_tabs]
    B = int(np.floor(budget + 1e-9))
    dp = np.zeros(B + 1)
    pick_tabs = []
    for cands in cand_tabs:
        cur = np.full(B + 1, -np.inf)
        pick = np.full(B + 1, -1, dtype=np.int64)
        for j, c in enumerate(cands):
            if c.cost > B:
                continue
            cand = dp[:B + 1 - c.cost] + c.value
            seg = cur[c.cost:]
            sel = pick[c.cost:]
            better = cand > seg
            seg[better] = cand[better]
            sel[better] = j
        pick_tabs.append(pick)
        dp = cur
    if not np.isfinite(dp[B]):
        return None
    b = B
    chosen_rev = []
    for cands, pick in zip(reversed(cand_tabs), reversed(pick_tabs)):
        j = int(pick[b])
        if j < 0:
            return None
        chosen_rev.append(cands[j])
        b -= cands[j].cost
    return list(reversed(chosen_rev))


def _ref_knap_2d(cand_tabs, budget, K):
    B = int(np.floor(budget + 1e-9))
    dp = np.full((K + 1, B + 1), -np.inf)
    dp[0, :] = 0.0
    pick_tabs = []
    for cands in cand_tabs:
        cur = np.full((K + 1, B + 1), -np.inf)
        pick = np.full((K + 1, B + 1), -1, dtype=np.int64)
        for j, c in enumerate(cands):
            if c.cost > B:
                continue
            dk = 1 if c.switch else 0
            for k in range(dk, K + 1):
                cand = dp[k - dk, :B + 1 - c.cost] + c.value
                seg = cur[k, c.cost:]
                sel = pick[k, c.cost:]
                better = cand > seg
                seg[better] = cand[better]
                sel[better] = j
        pick_tabs.append(pick)
        dp = cur
    k_best = int(np.argmax(dp[:, B]))
    if not np.isfinite(dp[k_best, B]):
        return None
    k, b = k_best, B
    chosen_rev = []
    for cands, pick in zip(reversed(cand_tabs), reversed(pick_tabs)):
        j = int(pick[k, b])
        if j < 0:
            return None
        chosen_rev.append(cands[j])
        b -= cands[j].cost
        k -= 1 if cands[j].switch else 0
    return list(reversed(chosen_rev))


# ---------------------------------------------------------------------------
# tab generator: discrete values (exact ties), overlap cost collapse,
# occasional stay-free pipelines (forced switches / infeasibility)
# ---------------------------------------------------------------------------
def _rand_tabs(rng, n_pipes):
    tabs = []
    for _ in range(n_pipes):
        ncand = int(rng.integers(2, 14))
        old = int(rng.integers(0, 8)) if rng.random() < 0.5 else 0
        tab = []
        for _ in range(ncand):
            cost = max(int(rng.integers(1, 12)), old)
            value = float(rng.integers(0, 8)) * 0.5   # discrete => ties
            tab.append(_Candidate(cost, value - 0.25, True, None))
        if rng.random() < 0.8:                        # free stay candidate
            tab.append(_Candidate(max(int(rng.integers(1, 10)), old),
                                  float(rng.integers(0, 8)) * 0.5,
                                  False, None))
        tabs.append(tab)
    return tabs


def _same_choice(a, b):
    if a is None or b is None:
        assert a is None and b is None
        return
    assert len(a) == len(b)
    for x, y in zip(a, b):
        assert x is y, (x, y)             # the very same candidate object


@pytest.mark.parametrize("seed", range(40))
def test_knapsack_1d_bit_identical_to_unpruned(seed):
    rng = np.random.default_rng(seed)
    tabs = _rand_tabs(rng, int(rng.integers(1, 6)))
    for budget in (float(rng.integers(3, 50)), np.inf):
        _same_choice(_knapsack_1d(tabs, budget), _ref_knap_1d(tabs, budget))


@pytest.mark.parametrize("seed", range(40))
def test_knapsack_2d_bit_identical_to_unpruned(seed):
    rng = np.random.default_rng(1000 + seed)
    tabs = _rand_tabs(rng, int(rng.integers(1, 6)))
    budget = float(rng.integers(3, 50))
    for K in (0, 1, int(rng.integers(1, len(tabs) + 1))):
        _same_choice(_knapsack_2d(tabs, budget, K),
                     _ref_knap_2d(tabs, budget, K))


@pytest.mark.parametrize("seed", range(20))
def test_prune_invariants(seed):
    """Survivors keep original order; every dropped candidate is strictly
    beaten (or exactly duplicated earlier) by a survivor whose switch
    class may substitute for it under the given mode."""
    rng = np.random.default_rng(2000 + seed)
    (tab,) = _rand_tabs(rng, 1)
    for cross in (True, False):
        kept = _prune_candidates(tab, cross_class=cross)
        idx = [tab.index(k) for k in kept]
        assert idx == sorted(idx)         # original order preserved
        kept_set = {id(k) for k in kept}
        for i, c in enumerate(tab):
            if id(c) in kept_set:
                continue
            subs = [d for d in kept
                    if cross or d.switch == c.switch]
            assert any(
                (d.cost <= c.cost and d.value > c.value) or
                (d.cost == c.cost and d.value == c.value
                 and tab.index(d) < i)
                for d in subs), (c, kept)


def test_prune_keeps_first_on_exact_tie():
    a = _Candidate(4, 1.0, True, None)
    b = _Candidate(4, 1.0, True, None)
    c = _Candidate(4, 1.0, False, None)
    kept = _prune_candidates([a, b, c], cross_class=True)
    assert len(kept) == 1 and kept[0] is a
    kept = _prune_candidates([a, b, c], cross_class=False)
    assert len(kept) == 2 and kept[0] is a and kept[1] is c


def test_prune_never_lets_switch_dominate_stay_in_class_mode():
    stay = _Candidate(9, 0.0, False, None)
    sw = _Candidate(1, 99.0, True, None)
    kept = _prune_candidates([sw, stay], cross_class=False)
    assert stay in kept                   # stays survive for the k-dim
    kept = _prune_candidates([sw, stay], cross_class=True)
    assert stay not in kept               # 1-D: strictly dominated
