"""Profiler (§4.2) + queueing (Eq. 7 / M/M/c) + paper-profile fidelity tests."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import optimizer as OPT
from repro.core import paper_profiles as PP
from repro.core import profiler as PF
from repro.core.queueing import expected_wait, queue_delay


@given(a=st.floats(0, 1e-3), b=st.floats(1e-4, 0.2), c=st.floats(1e-4, 0.5))
@settings(max_examples=50, deadline=None)
def test_quadratic_fit_recovers_exact_coeffs(a, b, c):
    batches = [1, 2, 4, 8, 16, 32, 64]
    lats = [a * x * x + b * x + c for x in batches]
    fa, fb, fc = PF.fit_quadratic(batches, lats)
    for x in batches:
        assert fa * x * x + fb * x + fc == pytest.approx(
            a * x * x + b * x + c, rel=1e-6, abs=1e-9)


def test_quadratic_beats_linear_mse():
    """§4.2: the quadratic fit has lower MSE than the linear one."""
    profs = PP.task_profiles("object_detection")
    for p in profs:
        q_mse = PF.fit_mse(p.batches, p.latencies, p.coeffs())
        l_mse = PF.fit_linear_mse(p.batches, p.latencies)
        assert q_mse <= l_mse + 1e-12


@given(b=st.integers(1, 64), lam=st.floats(0.1, 100))
@settings(max_examples=50, deadline=None)
def test_queue_delay_properties(b, lam):
    q = float(queue_delay(b, lam))
    assert q >= 0.0
    assert queue_delay(1, lam) == 0.0                   # first req never waits
    # monotone in batch, antitone in arrival rate
    assert float(queue_delay(b + 1, lam)) >= q
    assert float(queue_delay(b, lam * 2)) <= q + 1e-12


@given(b=st.integers(1, 64), lam=st.floats(0.1, 100), reps=st.integers(1, 32))
@settings(max_examples=50, deadline=None)
def test_expected_wait_below_worst_case_bound(b, lam, reps):
    """Property: the M/M/c-style expected batch-formation delay never
    exceeds Eq. 7's worst-case bound, for all (b, lambda, replicas)."""
    exp = expected_wait(b, lam, reps)
    assert 0.0 <= exp <= float(queue_delay(b, lam)) + 1e-12
    assert expected_wait(1, lam, reps) == 0.0    # a batch of one never waits


def test_expected_wait_queue_term_properties():
    """With a service time, the Erlang-C term is non-negative, shrinks with
    replicas, and blows up to inf when the stage is unstable."""
    b, lam, svc = 4, 20.0, 0.5           # offered load: 5 * 0.5 = 2.5 erlangs
    form = expected_wait(b, lam)
    assert expected_wait(b, lam, replicas=2, service_time=svc) == np.inf
    w4 = expected_wait(b, lam, replicas=4, service_time=svc)
    w8 = expected_wait(b, lam, replicas=8, service_time=svc)
    assert w4 > form and w8 > form       # queueing adds delay...
    assert w8 < w4                       # ...but more replicas shrink it


def test_default_latency_model_bit_identical_to_eq7():
    """The opt-in expected path must leave the default worst-case path
    untouched: stage_options with and without the explicit default agree
    exactly, and match the hand-computed Eq. 7 sum."""
    stage = PP.task_stage("object_detection")
    lam = 12.0
    dflt = OPT.stage_options(stage, lam)
    worst = OPT.stage_options(stage, lam, latency_model="worst_case")
    np.testing.assert_array_equal(dflt.lat, worst.lat)
    for j, (name, b) in enumerate(zip(dflt.names, dflt.batches)):
        v = stage.variant(name)
        assert dflt.lat[j] == float(v.latency(int(b))) + float(
            queue_delay(int(b), lam))


def test_expected_model_opt_in_path():
    """The expected path produces finite, service-time-bounded latencies
    for feasible options and rejects unknown model names."""
    stage = PP.task_stage("object_detection")
    lam = 12.0
    worst = OPT.stage_options(stage, lam)
    exp = OPT.stage_options(stage, lam, latency_model="expected")
    ok = worst.feasible & np.isfinite(exp.lat)
    assert ok.any()
    for j in np.flatnonzero(ok):
        svc = float(stage.variant(exp.names[j]).latency(int(exp.batches[j])))
        assert exp.lat[j] >= svc - 1e-12      # queueing only ever adds
    with pytest.raises(ValueError):
        OPT.stage_options(stage, lam, latency_model="bogus")


def test_base_alloc_monotone_in_threshold():
    """Eq. 1: a higher RPS threshold never yields a smaller allocation
    (Table 5's rows grow monotonically)."""
    prof = PP.task_profiles("object_detection")[2]      # yolov5m
    sla = PF.derive_stage_sla(PP.task_profiles("object_detection"))
    allocs = []
    for th in (1, 2, 4, 8, 16):
        r = PF.base_allocation(prof, th, sla, max_batch=8)
        allocs.append(r if r is not None else 10**9)
    assert all(b >= a for a, b in zip(allocs, allocs[1:]))


def test_paper_yolo_base_allocs_match_table7():
    st_ = PP.task_stage("object_detection")
    got = {v.name: v.base_alloc for v in st_.variants}
    assert got == {"yolov5n": 1, "yolov5s": 1, "yolov5m": 2,
                   "yolov5l": 4, "yolov5x": 8}


def test_paper_sla_table6_close():
    """Derived pipeline SLAs should reproduce Table 6 within tolerance for
    the audio/sum/nlp pipelines (video anchors differ, see DESIGN.md)."""
    expected = {"audio-qa": 9.23, "audio-sent": 9.42, "sum-qa": 3.84,
                "nlp": 17.61}
    for name, sla in expected.items():
        got = PP.PIPELINES[name]().sla
        assert got == pytest.approx(sla, rel=0.25), (name, got, sla)


def test_variant_accuracy_tables_verbatim():
    st_ = PP.task_stage("object_classification")
    accs = {v.name: v.accuracy for v in st_.variants}
    assert accs["resnet18"] == 69.75 and accs["resnet152"] == 78.31


def test_alloc_speedup_sublinear():
    sp = [PF.alloc_speedup(r) for r in (1, 2, 4, 8)]
    assert sp[0] == 1.0
    for r, s in zip((1, 2, 4, 8), sp):
        assert s <= r  # never superlinear
    # consistent with paper Table 2: ResNet18 75 ms @1 core -> 14 ms @8 cores
    assert PF.alloc_speedup(8) == pytest.approx(75 / 14, rel=0.25)
