"""Sweep-harness contracts (PR 7).

* **nproc invariance**: the same grid drained inline (nproc=1) and
  through a 2-worker spawn pool must aggregate to the identical result
  hash — cell results are pure functions of their specs, independent of
  scheduling, worker identity, and warm-cache history.
* **FrontierCache on/off parity per cell**: a cell computed against a
  warm shared cache equals the same cell computed with caching bypassed,
  modulo the volatile (wall-clock / cache-stats) fields — the invariant
  that makes per-worker warm state a pure wall-clock optimization.
* **seed hygiene**: per-cell streams derive from ``SeedSequence`` spawn
  keys; two distinct replicates never share an arrival stream, while the
  same replicate under different policies shares it exactly (paired
  comparison).  The legacy int-seed arithmetic is pinned bit-for-bit.
* **resume**: missing/corrupt/stale shards are recomputed, matching ones
  are trusted, and a resumed run reproduces the fresh run's hash.
"""
import json
import os
import pickle
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))

from repro.core import adapter as AD                           # noqa: E402
from repro.core import optimizer as OPT                        # noqa: E402
from repro.core import study as ST                             # noqa: E402
from repro.core import trace as TR                             # noqa: E402

import sweep as SW                                             # noqa: E402


def tiny_grid(reps: int = 2, seconds: int = 20):
    budgets = ST.resolve_budgets(2, (0.7,))
    return ST.build_grid(("ipa", "split_ipa"), (1.0,), budgets, reps,
                         (0.02,), seconds=seconds, n_pipelines=2)


# ---------------------------------------------------------------------------
# determinism across worker counts
# ---------------------------------------------------------------------------
def test_sweep_worker_schedule_invariance_hash():
    """Inline drain in canonical order vs two worker-style chunk drains
    (heavy-first scheduling order, fresh per-worker warm state each —
    the pool's code path minus the process boundary): identical hash and
    identical volatile-stripped records cell-for-cell.  The real spawn
    pool is covered by the slow-marked test below, and nproc=1-vs-4
    hash identity is independently gated every tier-1 run by
    ``benchmarks/sweep.py --smoke``."""
    specs = tiny_grid()
    rec1, _ = SW.run_grid(specs, 1, shard_dir=None, quiet=True)
    todo = sorted(specs,
                  key=lambda s: -(s.seconds * s.budget * s.n_pipelines))
    by_cell = {}
    for half in (todo[0::2], todo[1::2]):    # interleaved "workers"
        ST.worker_init()                     # fresh warm state per worker
        for rec in ST.run_chunk(list(half)):
            by_cell[rec["cell"]] = rec
    rec2 = [by_cell[s.cell_id] for s in specs]
    assert ST.result_hash(rec1) == ST.result_hash(rec2)
    for a, b in zip(rec1, rec2):
        assert ST.strip_volatile(a) == ST.strip_volatile(b)


@pytest.mark.slow
def test_sweep_nproc_invariance_hash():
    """Same grid, nproc=1 inline vs nproc=2 spawn pool: identical hash,
    and identical volatile-stripped records cell-for-cell.  Slow (the
    spawn pool costs ~2.4 s to boot); the fast tier covers the same
    property via the chunk-drain test above and the tier-1 sweep smoke
    gate."""
    specs = tiny_grid()
    rec1, _ = SW.run_grid(specs, 1, shard_dir=None, quiet=True)
    rec2, _ = SW.run_grid(specs, 2, shard_dir=None, quiet=True)
    assert ST.result_hash(rec1) == ST.result_hash(rec2)
    for a, b in zip(rec1, rec2):
        assert ST.strip_volatile(a) == ST.strip_volatile(b)


def test_sweep_rerun_same_process_identical():
    """Two inline drains in one process (second one on fully warm caches)
    are byte-identical — warm state cannot leak into results."""
    specs = tiny_grid(reps=1)
    rec1, _ = SW.run_grid(specs, 1, shard_dir=None, quiet=True)
    rec2, _ = SW.run_grid(specs, 1, shard_dir=None, quiet=True)
    assert ST.result_hash(rec1) == ST.result_hash(rec2)


def test_frontier_cache_on_off_parity_per_cell():
    """One cell against a warm shared FrontierCache vs caching bypassed:
    identical deterministic fields."""
    spec = tiny_grid(reps=1)[0]
    ST.worker_init()
    # warm the cache with a *different* cell first — parity must hold
    # even when the cache already carries other cells' frontiers
    other = tiny_grid(reps=2)[1]
    ST.run_cell_spec(other)
    cached = ST.run_cell_spec(spec)
    assert cached["frontier_cache"]["hits"] + \
        cached["frontier_cache"]["misses"] > 0
    policy, switch_cost = ST.SWEEP_POLICIES[spec.policy]
    uncached = AD.run_cell(
        ST.sweep_cluster(spec.n_pipelines, spec.sla_scale,
                         float(spec.budget)),
        ST.sweep_traces(spec.seconds, spec.n_pipelines,
                        np.random.default_rng(ST.trace_seedseq(spec))),
        policy=policy,
        obj=OPT.Objective(alpha=spec.alpha, beta=spec.beta, delta=1e-6),
        seed=ST.arrival_seedseq(spec), switch_cost=switch_cost,
        adaptation_delay=spec.adaptation_delay, frontier_cache=None,
        event_core=spec.event_core)
    uncached["cell"] = spec.cell_id
    uncached["spec"] = spec.to_dict()
    assert ST.strip_volatile(cached) == ST.strip_volatile(uncached)


def test_result_hash_ignores_wall_and_cache_fields():
    specs = tiny_grid(reps=1)
    rec, _ = SW.run_grid(specs, 1, shard_dir=None, quiet=True)
    h0 = ST.result_hash(rec)
    mutated = [dict(r) for r in rec]
    for r in mutated:
        r["wall_s"] = 999.0
        r["solver_wall_s"] = 123.0
        r["sim_wall_s"] = 876.0
        r["frontier_cache"] = {"hits": 0, "misses": 0}
    assert ST.result_hash(mutated) == h0
    # but a deterministic field must change the hash
    mutated[0]["mean_pas"] += 1.0
    assert ST.result_hash(mutated) != h0


# ---------------------------------------------------------------------------
# seed hygiene
# ---------------------------------------------------------------------------
def test_pipeline_seeds_int_path_is_legacy_arithmetic():
    assert AD._pipeline_seeds(11, 3) == [11, 1000014, 2000017]


def test_pipeline_seeds_seedsequence_idempotent():
    ss = np.random.SeedSequence(entropy=7, spawn_key=(3, 1))
    a = AD._pipeline_seeds(ss, 3)
    b = AD._pipeline_seeds(ss, 3)     # same object, second call
    assert [s.spawn_key for s in a] == [s.spawn_key for s in b]
    assert all(np.random.default_rng(x).random() ==
               np.random.default_rng(y).random()
               for x, y in zip(a, b))


def test_distinct_replicates_never_share_arrival_streams():
    """The satellite contract: two distinct cells (replicates) produce
    disjoint arrival streams on every pipeline — no arithmetic-collision
    class of bug can reintroduce sharing."""
    rates = np.full(30, 20.0)
    streams = {}
    for rep in (0, 1, 2):
        spec = ST.CellSpec(policy="ipa", sla_scale=1.0, budget=20, rep=rep,
                           beta=0.02, seconds=30, n_pipelines=3)
        for p, s in enumerate(AD._pipeline_seeds(ST.arrival_seedseq(spec),
                                                 3)):
            streams[(rep, p)] = TR.arrivals_from_rates(rates, seed=s)
    keys = list(streams)
    for i, a in enumerate(keys):
        for b in keys[i + 1:]:
            ta, tb = streams[a], streams[b]
            assert len(ta) != len(tb) or not np.array_equal(ta, tb), \
                f"streams {a} and {b} are identical"


def test_same_replicate_shares_workload_across_policies():
    """Paired design: cells differing only in policy/budget/SLA judge
    their policies on byte-identical traces and arrival seeds."""
    a = ST.CellSpec(policy="ipa", sla_scale=1.0, budget=20, rep=1,
                    beta=0.02, seconds=30, n_pipelines=2)
    b = ST.CellSpec(policy="split_ipa", sla_scale=1.3, budget=30, rep=1,
                    beta=0.02, seconds=30, n_pipelines=2)
    ta = ST.sweep_traces(30, 2, np.random.default_rng(ST.trace_seedseq(a)))
    tb = ST.sweep_traces(30, 2, np.random.default_rng(ST.trace_seedseq(b)))
    for x, y in zip(ta, tb):
        np.testing.assert_array_equal(x, y)
    assert ST.arrival_seedseq(a).spawn_key == ST.arrival_seedseq(b).spawn_key


def test_run_cluster_trace_accepts_seedsequence():
    cluster = ST.sweep_cluster(2, 1.0, 30.0)
    rates = ST.sweep_traces(20, 2, np.random.default_rng(0))
    ss = np.random.SeedSequence(5)
    r1 = AD.run_cluster_trace(cluster, rates, policy="split_ipa", seed=ss)
    r2 = AD.run_cluster_trace(cluster, rates, policy="split_ipa", seed=ss)
    assert r1.arrived == r2.arrived and r1.completed == r2.completed
    np.testing.assert_array_equal(r1.per_pipeline[0].latencies,
                                  r2.per_pipeline[0].latencies)


# ---------------------------------------------------------------------------
# FrontierCache pickling (warm caches cross the process boundary)
# ---------------------------------------------------------------------------
def test_frontier_cache_pickle_roundtrip():
    cache = OPT.FrontierCache(max_entries=64)
    pipe = ST.sweep_cluster(1).pipelines[0]
    obj = OPT.Objective()
    pts = cache.frontier(pipe, 12.0, obj)
    assert cache.misses == 1
    clone = pickle.loads(pickle.dumps(cache))
    assert clone.stats == cache.stats
    assert len(clone) == len(cache) == 1
    # the warm entry must hit in the clone and return equal frontiers
    pts2 = clone.frontier(pipe, 12.0, obj)
    assert clone.hits == cache.hits + 1
    assert [(p.cost, p.objective, p.config) for p in pts2] == \
        [(p.cost, p.objective, p.config) for p in pts]


def test_frontier_cache_stats_since():
    cache = OPT.FrontierCache()
    pipe = ST.sweep_cluster(1).pipelines[0]
    obj = OPT.Objective()
    cache.frontier(pipe, 10.0, obj)
    snap = cache.stats_snapshot()
    cache.frontier(pipe, 10.0, obj)      # hit
    cache.frontier(pipe, 11.0, obj)      # miss
    d = cache.stats_since(snap)
    assert d["hits"] == 1 and d["misses"] == 1 and d["hit_rate"] == 0.5


# ---------------------------------------------------------------------------
# shards + resume
# ---------------------------------------------------------------------------
def test_resume_recomputes_only_missing_and_stale(tmp_path, monkeypatch):
    specs = tiny_grid(reps=2)            # 4 cells
    shard_dir = str(tmp_path)
    rec1, st1 = SW.run_grid(specs, 1, shard_dir=shard_dir, quiet=True)
    assert st1["computed"] == len(specs) and st1["from_shards"] == 0
    h1 = ST.result_hash(rec1)

    # sabotage: delete one shard, corrupt a second, stale-spec a third
    os.unlink(ST.shard_path(shard_dir, specs[0]))
    with open(ST.shard_path(shard_dir, specs[1]), "w") as f:
        f.write("{not json")
    p2 = ST.shard_path(shard_dir, specs[2])
    with open(p2) as f:
        stale = json.load(f)
    stale["spec"]["seconds"] = 999       # as if the grid had been edited
    with open(p2, "w") as f:
        json.dump(stale, f)

    calls = []
    real = ST.run_cell_spec
    monkeypatch.setattr(ST, "run_cell_spec",
                        lambda s: calls.append(s.cell_id) or real(s))
    rec2, st2 = SW.run_grid(specs, 1, shard_dir=shard_dir, quiet=True)
    assert st2["computed"] == 3 and st2["from_shards"] == 1
    assert sorted(calls) == sorted(s.cell_id for s in specs[:3])
    assert ST.result_hash(rec2) == h1

    # a third run touches nothing
    calls.clear()
    rec3, st3 = SW.run_grid(specs, 1, shard_dir=shard_dir, quiet=True)
    assert st3["computed"] == 0 and not calls
    assert ST.result_hash(rec3) == h1


def test_shard_write_is_atomic_no_tmp_left(tmp_path):
    rec = {"cell": "x__y", "spec": {"a": 1}, "mean_pas": 1.0}
    ST.write_shard(str(tmp_path), rec)
    files = os.listdir(tmp_path)
    assert files == ["x__y.json"]
    with open(tmp_path / "x__y.json") as f:
        assert json.load(f) == rec


# ---------------------------------------------------------------------------
# aggregation
# ---------------------------------------------------------------------------
def test_aggregate_ci_and_pareto_flags():
    specs = tiny_grid(reps=2)
    rec, _ = SW.run_grid(specs, 1, shard_dir=None, quiet=True)
    agg = ST.aggregate(rec)
    assert len(agg["groups"]) == 2       # 2 policies x 1 sla x 1 C x 1 beta
    for row in agg["groups"]:
        assert row["mean_pas"]["n"] == 2
        assert row["mean_pas"]["ci95"] is not None
    (sl,) = agg["pareto"]
    assert {p["policy"] for p in sl["points"]} == {"ipa", "split_ipa"}
    # at equal budget the joint policy's PAS >= split's, so ipa can never
    # be flagged dominated by split_ipa alone
    ipa_pt = next(p for p in sl["points"] if p["policy"] == "ipa")
    split_pt = next(p for p in sl["points"] if p["policy"] == "split_ipa")
    assert ipa_pt["mean_pas"] >= split_pt["mean_pas"] - 1e-9


def test_ci_student_t_values():
    out = ST._ci([1.0, 2.0, 3.0])
    assert out["mean"] == 2.0 and out["n"] == 3
    # t(0.975, df=2) = 4.3027; sd = 1.0; ci95 = 4.3027 / sqrt(3)
    assert out["ci95"] == pytest.approx(4.3027 / np.sqrt(3), rel=1e-3)
    assert ST._ci([5.0])["ci95"] is None


def test_build_grid_rejects_unknown_policy():
    with pytest.raises(ValueError):
        ST.build_grid(("nope",), (1.0,), (20,), 1, (0.02,), 30, 2)
