"""FrontierCache: exact-keyed memoization of per-pipeline Pareto
frontiers across adaptation intervals.

The load-bearing property: with exact keying (the default), threading a
cache through ``solve_cluster`` / ``run_cluster_trace`` is pure
memoization — cached and uncached runs are **bit-identical** in every
chosen config, reconfiguration log entry and realized PAS/cost record,
including mid-window cases where the committed incumbent and the serving
config diverge while the arrival estimate repeats.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import adapter as AD
from repro.core import optimizer as OPT
from test_cluster import toy_cluster


# ---------------------------------------------------------------------------
# unit: keying, counters, invalidation, bounds
# ---------------------------------------------------------------------------
def test_cache_hits_on_repeated_rate_and_misses_on_new():
    cl = toy_cluster()
    cache = OPT.FrontierCache()
    obj = OPT.Objective()
    f1 = cache.frontier(cl.pipelines[0], 10.0, obj)
    assert (cache.hits, cache.misses) == (0, 1)
    f2 = cache.frontier(cl.pipelines[0], 10.0, obj)
    assert (cache.hits, cache.misses) == (1, 1)
    assert f2 is f1                       # shared, treated immutable
    cache.frontier(cl.pipelines[0], 11.0, obj)          # new rate
    cache.frontier(cl.pipelines[1], 10.0, obj)          # new pipeline
    cache.frontier(cl.pipelines[0], 10.0,
                   OPT.Objective(alpha=2.0))            # new objective
    cache.frontier(cl.pipelines[0], 10.0, obj, max_replicas=7)
    cache.frontier(cl.pipelines[0], 10.0, obj, latency_model="expected")
    assert cache.misses == 6
    assert len(cache) == 6


def test_cached_frontier_is_bit_identical_to_direct():
    cl = toy_cluster()
    cache = OPT.FrontierCache()
    obj = OPT.Objective(alpha=1.0, beta=0.05)
    for lam in (3.0, 11.5, 3.0, 24.0):
        got = cache.frontier(cl.pipelines[0], lam, obj)
        ref = OPT.pareto_frontier(cl.pipelines[0], lam, obj)
        assert [(p.cost, p.objective, p.pas, p.latency, p.config)
                for p in got] == \
            [(p.cost, p.objective, p.pas, p.latency, p.config) for p in ref]


def test_cache_clear_and_fifo_eviction():
    cl = toy_cluster()
    cache = OPT.FrontierCache(max_entries=2)
    obj = OPT.Objective()
    for lam in (1.0, 2.0, 3.0):           # third insert evicts the first
        cache.frontier(cl.pipelines[0], lam, obj)
    assert len(cache) == 2
    cache.frontier(cl.pipelines[0], 1.0, obj)      # evicted -> miss again
    assert cache.misses == 4
    cache.clear()
    assert len(cache) == 0
    cache.frontier(cl.pipelines[0], 3.0, obj)
    assert cache.misses == 5


def test_cache_quantize_buckets_nearby_rates():
    cl = toy_cluster()
    cache = OPT.FrontierCache(quantize=1.0)
    obj = OPT.Objective()
    a = cache.frontier(cl.pipelines[0], 10.2, obj)
    b = cache.frontier(cl.pipelines[0], 9.9, obj)   # same bucket: 10.0
    assert b is a and cache.hits == 1
    # the frontier is computed AT the bucketed rate — deterministic in the
    # bucket, never dependent on which member arrived first
    ref = OPT.pareto_frontier(cl.pipelines[0], 10.0, obj)
    assert [p.config for p in a] == [p.config for p in ref]


def test_cache_rejects_bad_args():
    with pytest.raises(ValueError):
        OPT.FrontierCache(quantize=0.0)
    with pytest.raises(ValueError):
        OPT.FrontierCache(max_entries=0)


def test_cache_stats_shape():
    cache = OPT.FrontierCache()
    assert cache.stats == {"hits": 0, "misses": 0, "entries": 0,
                           "hit_rate": 0.0}


# ---------------------------------------------------------------------------
# solver parity: cache in / cache out
# ---------------------------------------------------------------------------
@given(budget=st.integers(6, 55), lam_a=st.floats(1.0, 25.0),
       lam_b=st.floats(1.0, 25.0))
@settings(max_examples=15, deadline=None)
def test_solve_cluster_with_cache_is_bit_identical(budget, lam_a, lam_b):
    cl = toy_cluster(cores=float(budget))
    obj = OPT.Objective(alpha=1.0, beta=0.05)
    cache = OPT.FrontierCache()
    for _ in range(2):                    # second pass runs off pure hits
        cached = OPT.solve_cluster(cl, [lam_a, lam_b], obj, cache=cache)
        plain = OPT.solve_cluster(cl, [lam_a, lam_b], obj)
        assert cached.feasible == plain.feasible
        if cached.feasible:
            assert cached.config == plain.config
            assert cached.objective == plain.objective
            assert cached.cost == plain.cost
    assert cache.hits > 0


# ---------------------------------------------------------------------------
# end to end: cached vs uncached cluster traces, bit for bit
# ---------------------------------------------------------------------------
def _trace_signature(res):
    """Everything a solver-path change could perturb: per-interval chosen
    configs are reflected in (pas, cost, feasible), plus the reconfig log
    and the realized latency streams."""
    return (
        res.completed, res.dropped, res.arrived, res.sim_events,
        res.n_reconfigs, tuple(res.reconfig_log), res.peak_serving_cores,
        tuple(tuple((r.t, r.lam_hat, r.pas, r.cost, r.feasible)
                    for r in p.intervals) for p in res.per_pipeline),
        tuple(tuple(np.asarray(p.latencies).tolist())
              for p in res.per_pipeline),
    )


@given(seed=st.integers(0, 9999))
@settings(max_examples=6, deadline=None)
def test_cached_cluster_trace_bit_identical_on_bursty_traces(seed):
    """Property (the ISSUE's cache-correctness pin): cached vs uncached
    ``run_cluster_trace`` produce bit-identical configs, reconfig logs and
    realized PAS on random bursty traces — including mid-window incumbent
    cases (adaptation_delay > 0 with hysteresis, so ``current`` and
    ``serving`` diverge while the arrival estimate repeats)."""
    rng = np.random.default_rng(seed)
    cl = toy_cluster(cores=float(rng.integers(14, 30)))
    t = np.arange(50, dtype=np.float64)
    traces = []
    for _ in range(2):
        phase = rng.uniform(0.0, 40.0)
        burst = rng.uniform(6.0, 20.0) * np.exp(
            -((t - phase) % 40.0) / rng.uniform(4.0, 12.0))
        traces.append(np.clip(2.0 + burst + rng.normal(0.0, 0.3, 50),
                              0.5, None))
    for policy, kw in (("ipa", {"switch_cost": 0.05,
                                "adaptation_delay": 6.0}),
                       ("ipa", {}),
                       ("split_ipa", {"adaptation_delay": 6.0})):
        common = dict(policy=policy, obj=OPT.Objective(alpha=1.0, beta=0.02),
                      seed=seed % 7, **kw)
        cached = AD.run_cluster_trace(cl, traces, **common)   # auto cache
        plain = AD.run_cluster_trace(cl, traces, frontier_cache=None,
                                     **common)
        assert _trace_signature(cached) == _trace_signature(plain), \
            (policy, kw)
        assert cached.frontier_cache_stats is not None
        assert plain.frontier_cache_stats is None


def test_explicit_cache_instance_is_shared_across_runs():
    cl = toy_cluster(cores=24.0)
    traces = [np.full(30, 6.0), np.full(30, 4.0)]
    cache = OPT.FrontierCache()
    AD.run_cluster_trace(cl, traces, policy="ipa", frontier_cache=cache)
    first_misses = cache.misses
    res = AD.run_cluster_trace(cl, traces, policy="ipa",
                               frontier_cache=cache)
    # the second identical run re-solves from pure hits
    assert cache.misses == first_misses
    assert res.frontier_cache_stats["hits"] > 0
