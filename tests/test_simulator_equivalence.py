"""Event-driven simulator: old-vs-new equivalence, exact timeout
scheduling, and structural invariants.

The equivalence harness replays deterministic traces through both the
event-driven core (``core.simulator``) and the frozen tick-based seed
implementation (``core.simulator_legacy``) and requires *identical*
completed/dropped counts — the contract that let the tick flood be deleted
from the hot path.
"""
import numpy as np
import pytest

from repro.core import trace as TR
from repro.core.cluster import ClusterConfig, ClusterModel
from repro.core.pipeline import (DeviceProfile, ModelVariant, PipelineConfig,
                                 PipelineModel, StageConfig, StageModel)
from repro.core.queueing import wait_bound
from repro.core.simulator import (ClusterSimulator, PipelineSimulator,
                                  RoundClusterSimulator,
                                  StructClusterSimulator)
from repro.core.simulator_legacy import LegacyTickSimulator
from repro.serving.request import Request


def two_stage(lat1=0.05, lat2=0.03, extra_variant=False):
    def var(name, l1, acc, alloc=1):
        return ModelVariant(name, acc, alloc, (0.0, l1 * 0.7, l1 * 0.3))
    v1 = (var("a0", lat1, 60.0),)
    if extra_variant:
        v1 = v1 + (var("a1", 2 * lat1, 75.0, alloc=2),)
    s1 = StageModel("a", v1, sla=5 * lat1, batch_choices=(1, 2, 4))
    s2 = StageModel("b", (var("b0", lat2, 70.0),), sla=5 * lat2,
                    batch_choices=(1, 2, 4))
    return PipelineModel("tiny", (s1, s2))


def replay(cls, pipe, config, arrivals, horizon):
    sim = cls(pipe, config)
    for t in arrivals:
        sim.inject(Request(arrival=float(t), sla=pipe.sla))
    sim.run_until(horizon)
    return sim


# ---------------------------------------------------------------------------
# old-vs-new equivalence (acceptance: >= 3 deterministic traces)
# ---------------------------------------------------------------------------
PIPE = two_stage()
EQUIV_TRACES = {
    # full batches, no pressure
    "linspace_full": (PipelineConfig((StageConfig("a0", 4, 2),
                                      StageConfig("b0", 2, 2))),
                      np.linspace(0, 2, 64), 40.0),
    # lone requests that must time out of a sub-filled batch
    "sparse_timeout": (PipelineConfig((StageConfig("a0", 4, 1),
                                       StageConfig("b0", 4, 1))),
                       np.array([0.0, 3.0, 6.0, 9.0]), 30.0),
    # heavy overload: the §4.5 drop policy does the work
    "overload_drops": (PipelineConfig((StageConfig("a0", 1, 1),
                                       StageConfig("b0", 1, 1))),
                       TR.arrivals_from_rates(np.full(10, 50.0), seed=1),
                       10 + 20 * PIPE.sla),
    # moderate Poisson load with batching
    "poisson_mid": (PipelineConfig((StageConfig("a0", 2, 3),
                                    StageConfig("b0", 2, 2))),
                    TR.arrivals_from_rates(np.full(20, 12.0), seed=4),
                    20 + 100 * PIPE.sla),
}


@pytest.mark.parametrize("name", sorted(EQUIV_TRACES))
def test_equivalent_counts_old_vs_new(name):
    config, arrivals, horizon = EQUIV_TRACES[name]
    new = replay(PipelineSimulator, PIPE, config, arrivals, horizon)
    old = replay(LegacyTickSimulator, PIPE, config, arrivals, horizon)
    assert new.metrics.completed == old.metrics.completed
    assert new.metrics.dropped == old.metrics.dropped
    assert new.metrics.arrived == old.metrics.arrived == len(arrivals)


@pytest.mark.parametrize("name", sorted(EQUIV_TRACES))
def test_cluster_n1_event_for_event_equivalent(name):
    """A ClusterSimulator holding one pipeline must reproduce
    PipelineSimulator exactly: same completed/dropped counts, the same
    latency stream in the same order, and the same event count — the
    single-pipeline stack is the N=1 special case, not a parallel
    implementation."""
    config, arrivals, horizon = EQUIV_TRACES[name]
    single = replay(PipelineSimulator, PIPE, config, arrivals, horizon)

    clus = ClusterSimulator(ClusterModel("n1", (PIPE,)),
                            ClusterConfig((config,)))
    for t in arrivals:
        clus.inject(Request(arrival=float(t), sla=PIPE.sla), pipeline=0)
    clus.run_until(horizon)

    m1, mc = single.metrics, clus.metrics_by_pipe[0]
    assert mc.completed == m1.completed
    assert mc.dropped == m1.dropped
    assert mc.arrived == m1.arrived
    np.testing.assert_array_equal(mc.latencies, m1.latencies)
    assert clus.events_processed == single.events_processed


def test_new_core_schedules_far_fewer_events():
    """The whole point: no tick flood.  On the sparse trace the legacy core
    burns >1000 tick events; the event-driven one needs a few dozen."""
    config, arrivals, horizon = EQUIV_TRACES["sparse_timeout"]
    new = replay(PipelineSimulator, PIPE, config, arrivals, horizon)
    old = replay(LegacyTickSimulator, PIPE, config, arrivals, horizon)
    assert new.events_processed * 10 < old.events_processed


# ---------------------------------------------------------------------------
# pre-sized arrival batching: bulk inject == per-request inject
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(EQUIV_TRACES))
def test_bulk_injection_event_for_event_equivalent(name):
    """``inject_arrivals`` is the per-request ``inject`` loop call-for-
    call: same event count, same metrics, same latency stream — whether
    the whole trace lands in one bulk extend or in per-window cuts."""
    config, arrivals, horizon = EQUIV_TRACES[name]
    ref = replay(PipelineSimulator, PIPE, config, arrivals, horizon)

    bulk = PipelineSimulator(PIPE, config)
    bulk.inject_arrivals(arrivals)
    bulk.run_until(horizon)

    cuts = PipelineSimulator(PIPE, config)
    arr = np.asarray(arrivals, np.float64)
    edges = np.linspace(0.0, horizon, 5)
    lo = 0
    for b in edges[1:]:
        hi = int(np.searchsorted(arr, b, side="left"))
        cuts.inject_arrivals(arr[lo:hi])
        lo = hi
        cuts.run_until(float(b))
    cuts.inject_arrivals(arr[lo:])
    cuts.run_until(horizon)

    for sim in (bulk, cuts):
        m, mr = sim.metrics, ref.metrics
        assert (m.arrived, m.completed, m.dropped) == \
            (mr.arrived, mr.completed, mr.dropped)
        np.testing.assert_array_equal(m.latencies, mr.latencies)
        assert sim.events_processed == ref.events_processed


def test_bulk_injection_unsorted_and_empty():
    """Out-of-order bulk blocks trip the sortedness flag and are merged
    stably; empty blocks are free no-ops."""
    config, arrivals, horizon = EQUIV_TRACES["poisson_mid"]
    ref = replay(PipelineSimulator, PIPE, config, arrivals, horizon)

    sim = PipelineSimulator(PIPE, config)
    arr = np.asarray(arrivals, np.float64)
    half = len(arr) // 2
    sim.inject_arrivals(arr[half:])      # later block first
    sim.inject_arrivals(np.empty(0))
    sim.inject_arrivals(arr[:half])
    sim.run_until(horizon)
    assert (sim.metrics.arrived, sim.metrics.completed,
            sim.metrics.dropped) == (ref.metrics.arrived,
                                     ref.metrics.completed,
                                     ref.metrics.dropped)


def test_bulk_injection_acquires_from_attached_pool():
    from repro.serving.request import RequestPool
    config, arrivals, horizon = EQUIV_TRACES["linspace_full"]
    pool = RequestPool()
    sim = PipelineSimulator(PIPE, config, request_pool=pool)
    sim.inject_arrivals(arrivals)
    sim.run_until(horizon)
    assert pool.allocated >= 1
    # terminal events released every request back to the free list
    sim2 = PipelineSimulator(PIPE, config, request_pool=pool)
    sim2.inject_arrivals(arrivals)
    sim2.run_until(horizon)
    assert pool.reused > 0


def test_dag_pipeline_recycles_pool_requests():
    """Pool recycling on DAG pipelines: the shared Request object behind a
    fan-out is released exactly once, at *full retirement* (when its rid
    leaves the in-flight registry) — never while sibling copies are still
    live in a branch.  A pooled diamond run must be bit-identical to the
    unpooled one, actually reuse objects under windowed injection, and
    retire every registry entry by drain time."""
    from repro.serving.request import RequestPool

    def diamond():
        def stage(name, l1):
            v = ModelVariant(name + "0", 70.0, 1, (0.0, l1 * 0.7, l1 * 0.3))
            return StageModel(name, (v,), sla=5 * l1, batch_choices=(1, 2, 4))
        return PipelineModel(
            "diamond", (stage("src", 0.01), stage("fast", 0.01),
                        stage("slow", 0.05), stage("sink", 0.01)),
            parents=((), (0,), (0,), (1, 2)))

    pipe = diamond()
    cfg = PipelineConfig((StageConfig("src0", 1, 2),
                          StageConfig("fast0", 2, 2),
                          StageConfig("slow0", 1, 1),
                          StageConfig("sink0", 1, 2)))
    rng = np.random.default_rng(3)
    windows = [np.sort(5.0 * w + 5.0 * rng.random(150)) for w in range(4)]

    def run(pool):
        sim = PipelineSimulator(pipe, cfg, drop_factor=1.0, max_wait=0.1,
                                request_pool=pool)
        # windowed injection: releases from window w refill the free list
        # before window w+1 acquires — exercising actual reuse, not just
        # allocation
        for w, ts in enumerate(windows):
            sim.inject_arrivals(ts)
            sim.run_until(5.0 * (w + 1))
        sim.run_until(40.0)
        return sim

    plain = run(None)
    pool = RequestPool()
    pooled = run(pool)
    for a, b in ((plain, pooled),):
        assert a.metrics.arrived == b.metrics.arrived
        assert a.metrics.completed == b.metrics.completed
        assert a.metrics.dropped == b.metrics.dropped
        assert a.events_processed == b.events_processed
        np.testing.assert_array_equal(a.metrics.latencies,
                                      b.metrics.latencies)
    assert pool.reused > 0
    assert all(not infl for infl in pooled._inflight)
    assert all(not reg for reg in pooled._req_of)


# ---------------------------------------------------------------------------
# exact timeout scheduling
# ---------------------------------------------------------------------------
def test_lone_request_dispatches_at_exact_wait_bound():
    """A single queued request in a batch-4 stage leaves at precisely
    stage_enter + wait_bound (Eq. 7 capped), not at the next 50 ms tick."""
    pipe = two_stage()
    cfg = PipelineConfig((StageConfig("a0", 4, 1), StageConfig("b0", 1, 1)))
    sim = PipelineSimulator(pipe, cfg, record_timeline=True)
    bound = wait_bound(4, sim.lam_est, sim.max_wait)
    assert bound > 0.0
    r = Request(arrival=1.0, sla=pipe.sla)
    sim.inject(r)
    sim.run_until(20.0)
    l_a = float(pipe.stages[0].variants[0].latency(1))
    l_b = float(pipe.stages[1].variants[0].latency(1))
    assert r.stage_exit[0] == pytest.approx(1.0 + bound + l_a, abs=1e-9)
    assert r.done == pytest.approx(1.0 + bound + l_a + l_b, abs=1e-9)
    assert sim.metrics.completed == 1


def test_full_batch_dispatches_immediately_stale_timeout_ignored():
    """A batch that fills early leaves the moment the last request lands;
    the timeout armed for the first request is superseded (generation
    counter) and must not trigger a second, phantom dispatch."""
    pipe = two_stage()
    cfg = PipelineConfig((StageConfig("a0", 4, 1), StageConfig("b0", 1, 4)))
    sim = PipelineSimulator(pipe, cfg, record_timeline=True)
    arrivals = [0.0, 0.01, 0.02, 0.03]
    reqs = [Request(arrival=t, sla=pipe.sla) for t in arrivals]
    for r in reqs:
        sim.inject(r)
    sim.run_until(20.0)
    l_a4 = float(pipe.stages[0].variants[0].latency(4))
    # all four left stage 0 together, at the fill instant — well before the
    # wait_bound deadline armed at t=0
    exits = sorted(r.stage_exit[0] for r in reqs)
    assert exits[0] == exits[-1]                      # one batch, one exit
    assert exits[0] == pytest.approx(0.03 + l_a4, abs=1e-9)
    assert 0.03 + l_a4 < wait_bound(4, sim.lam_est, sim.max_wait)
    assert sim.metrics.completed == 4
    assert sim.metrics.dropped == 0


def test_second_wave_gets_fresh_timeout_after_early_dispatch():
    """After an early full-batch dispatch, a later lone request must arm a
    *new* timeout for itself (the stale one is gone, not inherited)."""
    pipe = two_stage()
    cfg = PipelineConfig((StageConfig("a0", 4, 2), StageConfig("b0", 1, 4)))
    sim = PipelineSimulator(pipe, cfg, record_timeline=True)
    wave1 = [Request(arrival=t, sla=pipe.sla) for t in
             (0.0, 0.005, 0.01, 0.015)]
    straggler = Request(arrival=0.1, sla=pipe.sla)
    for r in wave1 + [straggler]:
        sim.inject(r)
    sim.run_until(20.0)
    bound = wait_bound(4, sim.lam_est, sim.max_wait)
    l_a = float(pipe.stages[0].variants[0].latency(1))
    assert straggler.stage_exit[0] == pytest.approx(0.1 + bound + l_a,
                                                    abs=1e-9)
    assert sim.metrics.completed == 5


# ---------------------------------------------------------------------------
# structural invariants
# ---------------------------------------------------------------------------
def test_request_conservation_at_every_boundary():
    """arrived-so-far == completed + dropped + queued + in-service at any
    run_until boundary, and everything drains by the end."""
    pipe = two_stage()
    cfg = PipelineConfig((StageConfig("a0", 2, 2), StageConfig("b0", 2, 1)))
    arrivals = TR.arrivals_from_rates(np.full(12, 18.0), seed=7)
    sim = PipelineSimulator(pipe, cfg)
    for t in arrivals:
        sim.inject(Request(arrival=float(t), sla=pipe.sla))
    for boundary in np.arange(0.5, 12.5, 0.5):
        sim.run_until(float(boundary))
        landed = int(np.sum(arrivals <= boundary))
        m = sim.metrics
        assert m.completed + m.dropped + sim.queued + sim.in_service \
            == landed, boundary
    sim.run_until(12 + 100 * pipe.sla)
    m = sim.metrics
    assert m.arrived == len(arrivals)
    assert m.completed + m.dropped == m.arrived
    assert sim.queued == 0 and sim.in_service == 0
    assert len(m.latencies) == m.completed


def test_event_clock_never_goes_backwards():
    times = []

    class Probe(PipelineSimulator):
        def _handle(self, kind, payload):
            times.append(self.now)
            super()._handle(kind, payload)

    pipe = two_stage()
    cfg = PipelineConfig((StageConfig("a0", 4, 1), StageConfig("b0", 2, 1)))
    arrivals = TR.arrivals_from_rates(np.full(8, 25.0), seed=2)
    sim = Probe(pipe, cfg)
    for t in arrivals:
        sim.inject(Request(arrival=float(t), sla=pipe.sla))
    # split across several run_until calls to cover boundary resumption
    for b in (2.0, 4.0, 8.0, 8 + 50 * pipe.sla):
        sim.run_until(b)
    assert len(times) > 0
    assert all(a <= b + 1e-12 for a, b in zip(times, times[1:]))
    assert sim.metrics.completed + sim.metrics.dropped == len(arrivals)


def test_out_of_order_inject_after_partial_run():
    """A late, past-time injection between run_until calls must not
    re-deliver already-processed arrivals or lose the new one (regression:
    sorting the stream without compacting the consumed prefix)."""
    pipe = two_stage()
    cfg = PipelineConfig((StageConfig("a0", 1, 2), StageConfig("b0", 1, 2)))
    sim = PipelineSimulator(pipe, cfg)
    r1 = Request(arrival=5.0, sla=pipe.sla)
    sim.inject(r1)
    sim.run_until(10.0)
    assert sim.metrics.completed == 1
    r1_done = r1.done
    r2 = Request(arrival=3.0, sla=pipe.sla)     # in the past, out of order
    sim.inject(r2)
    sim.run_until(20.0)
    m = sim.metrics
    assert m.arrived == 2
    # r2 is 7 s stale when delivered -> the §4.5 drop policy takes it; what
    # must NOT happen is r1 being re-delivered (and re-counted) or r2
    # vanishing without a trace
    assert m.completed + m.dropped == 2
    assert r2.dropped or np.isfinite(r2.done)    # r2 accounted for
    assert r1.done == r1_done                    # r1 untouched


def test_lam_est_update_rearms_pending_timeout():
    """Raising lam_est mid-wait must shorten an already-armed timeout (the
    legacy core re-evaluated Eq. 7 every tick; the event core must re-arm)."""
    pipe = two_stage()
    cfg = PipelineConfig((StageConfig("a0", 4, 1), StageConfig("b0", 1, 1)))
    sim = PipelineSimulator(pipe, cfg)          # lam_est=10 -> bound 0.3
    r = Request(arrival=0.0, sla=pipe.sla)
    sim.inject(r)
    sim.run_until(0.05)                          # timeout armed at 0.3
    sim.lam_est = 100.0                          # new bound: 3/100 = 0.03
    sim.run_until(10.0)
    l_a = float(pipe.stages[0].variants[0].latency(1))
    l_b = float(pipe.stages[1].variants[0].latency(1))
    # past-due under the new bound -> dispatches at the update instant
    assert r.done == pytest.approx(0.05 + l_a + l_b, abs=1e-9)
    # and the legacy core agrees (bound re-read at the next tick)
    leg = LegacyTickSimulator(pipe, cfg)
    r2 = Request(arrival=0.0, sla=pipe.sla)
    leg.inject(r2)
    leg.run_until(0.05)
    leg.lam_est = 100.0
    leg.run_until(10.0)
    assert abs(r.done - r2.done) < 0.05 + 1e-9   # within one tick


def test_reconfigure_shrink_keeps_soonest_free_replicas():
    pipe = two_stage()
    sim = PipelineSimulator(pipe, PipelineConfig(
        (StageConfig("a0", 1, 3), StageConfig("b0", 1, 1))))
    sim.free_at[0] = [5.0, 1.0, 3.0]
    sim.reconfigure(PipelineConfig((StageConfig("a0", 1, 2),
                                    StageConfig("b0", 1, 1))))
    assert sorted(sim.free_at[0]) == [1.0, 3.0]


def test_adaptation_window_serves_old_config_until_apply():
    """§5.3 transition: a reconfigured pipeline keeps serving the old
    config for the adaptation window; the decision commits immediately
    (``current_config``) but the rollout applies later."""
    pipe = two_stage(extra_variant=True)
    cfg_old = PipelineConfig((StageConfig("a0", 1, 1), StageConfig("b0", 1, 1)))
    cfg_new = PipelineConfig((StageConfig("a1", 1, 2), StageConfig("b0", 1, 1)))
    sim = PipelineSimulator(pipe, cfg_old, adaptation_delay=2.0)
    sim.reconfigure(cfg_new)
    assert sim.current_config == cfg_new          # committed
    assert sim.serving_config(0) == cfg_old       # still serving old
    # a request inside the window is served at the OLD variant's latency
    r = Request(arrival=0.5, sla=pipe.sla)
    sim.inject(r)
    sim.run_until(1.5)
    l_a0 = float(pipe.stages[0].variants[0].latency(1))
    l_a1 = float(pipe.stages[0].variants[1].latency(1))
    l_b0 = float(pipe.stages[1].variants[0].latency(1))
    assert r.done == pytest.approx(0.5 + l_a0 + l_b0, abs=1e-9)
    # after the window the new config serves
    r2 = Request(arrival=2.5, sla=pipe.sla)
    sim.inject(r2)
    sim.run_until(10.0)
    assert sim.serving_config(0) == cfg_new
    assert r2.done == pytest.approx(2.5 + l_a1 + l_b0, abs=1e-9)
    assert sim.reconfig_log == [(0.0, 0, 2.0)]
    assert sim.n_reconfigs == 1


def test_adaptation_window_supersede_and_noop():
    """A second decision inside the window replaces the target (stale
    apply events are generation-cancelled); re-proposing the committed
    config is a free no-op; re-proposing the serving config cancels the
    rollout without logging a phantom reconfiguration."""
    pipe = two_stage(extra_variant=True)
    cfg_a = PipelineConfig((StageConfig("a0", 1, 1), StageConfig("b0", 1, 1)))
    cfg_b = PipelineConfig((StageConfig("a0", 2, 2), StageConfig("b0", 1, 1)))
    cfg_c = PipelineConfig((StageConfig("a1", 1, 2), StageConfig("b0", 1, 1)))
    sim = PipelineSimulator(pipe, cfg_a, adaptation_delay=2.0)
    sim.reconfigure(cfg_b)                        # applies at 2.0
    sim.reconfigure(cfg_b)                        # no-op: already committed
    assert sim.n_reconfigs == 1
    sim.run_until(1.0)
    sim.reconfigure(cfg_c)                        # supersedes: applies at 3.0
    sim.run_until(2.5)
    assert sim.serving_config(0) == cfg_a         # stale apply was ignored
    sim.run_until(3.5)
    assert sim.serving_config(0) == cfg_c
    assert sim.reconfig_log == [(0.0, 0, 2.0), (1.0, 0, 3.0)]
    # cancel: propose what is already serving mid-rollout
    sim.run_until(4.0)
    sim.reconfigure(cfg_b)                        # applies at 6.0
    sim.reconfigure(cfg_c)                        # back to serving: cancel
    assert sim.current_config == cfg_c
    sim.run_until(8.0)
    assert sim.serving_config(0) == cfg_c         # rollout was cancelled
    assert sim.n_reconfigs == 3
    assert len(sim.reconfig_log) == 3


# ---------------------------------------------------------------------------
# golden 3-pipeline cluster trace: pins event counts, completion totals and
# the reconfiguration log so event-loop perf work can't silently change
# cluster semantics
# ---------------------------------------------------------------------------
def _golden_cluster():
    def mk(name, lat1, lat2):
        def var(vname, l1, acc, alloc=1):
            return ModelVariant(vname, acc, alloc, (0.0, l1 * 0.7, l1 * 0.3))
        s1 = StageModel(f"{name}_a", (var(f"{name}a0", lat1, 60.0),
                                      var(f"{name}a1", 2 * lat1, 75.0, 2)),
                        sla=5 * lat1, batch_choices=(1, 2, 4))
        s2 = StageModel(f"{name}_b", (var(f"{name}b0", lat2, 70.0),),
                        sla=5 * lat2, batch_choices=(1, 2, 4))
        return PipelineModel(name, (s1, s2))
    return ClusterModel("golden", (mk("p0", 0.05, 0.03),
                                   mk("p1", 0.04, 0.02),
                                   mk("p2", 0.06, 0.035)), cores=40.0)


@pytest.mark.parametrize("sim_cls", (ClusterSimulator,
                                     StructClusterSimulator,
                                     RoundClusterSimulator))
def test_golden_cluster_trace_is_pinned(sim_cls):
    """Deterministic seeded 3-pipeline cluster run with scripted
    mid-flight reconfigurations (adaptation windows in flight across
    boundaries).  The exact event count, per-pipeline completion/drop
    totals and the reconfiguration log are golden — any change means the
    cluster event-loop semantics moved and must be re-derived on purpose.
    All three event cores must replay the pin."""
    cl = _golden_cluster()
    cfg0 = ClusterConfig(tuple(
        PipelineConfig((StageConfig(p.stages[0].variants[0].name, 2, 2),
                        StageConfig(p.stages[1].variants[0].name, 2, 1)))
        for p in cl.pipelines))
    sim = sim_cls(cl, cfg0, adaptation_delay=1.5)
    for p, rate in enumerate((18.0, 90.0, 12.0)):
        for t in TR.arrivals_from_rates(np.full(12, rate), seed=100 + p):
            sim.inject(Request(arrival=float(t), sla=cl.pipelines[p].sla), p)
    sim.run_until(5.0)
    # variant upgrade on p0, replica grow on p1 (both roll out at 6.5)
    sim.reconfigure_pipeline(0, PipelineConfig(
        (StageConfig("p0a1", 2, 3), StageConfig("p0b0", 2, 1))))
    sim.reconfigure_pipeline(1, PipelineConfig(
        (StageConfig("p1a0", 2, 3), StageConfig("p1b0", 2, 2))))
    sim.run_until(6.0)
    # supersede p0's pending rollout mid-window (now rolls out at 7.5)
    sim.reconfigure_pipeline(0, PipelineConfig(
        (StageConfig("p0a1", 4, 2), StageConfig("p0b0", 2, 1))))
    sim.run_until(12 + 60 * max(sim.sla_of))
    assert sim.reconfig_log == [(5.0, 0, 6.5), (5.0, 1, 6.5), (6.0, 0, 7.5)]
    assert sim.n_reconfigs == 3
    totals = [(m.arrived, m.completed, m.dropped)
              for m in sim.metrics_by_pipe]
    assert totals == [(241, 241, 0), (1107, 334, 773), (132, 132, 0)]
    assert sim.events_processed == 3325
    assert sim.queued == 0 and sim.in_service == 0


def test_golden_trace_single_class_budget_map_is_invisible():
    """The identical golden scenario on a cluster whose budget is the
    single-class mapping ``{"cpu": 40.0}`` instead of the scalar ``40.0``
    must reproduce every pinned number event-for-event: with one device
    class the per-class ledger is the scalar ledger, and the device axis
    must be invisible."""
    base = _golden_cluster()
    cl = ClusterModel("golden", base.pipelines, cores={"cpu": 40.0})
    cfg0 = ClusterConfig(tuple(
        PipelineConfig((StageConfig(p.stages[0].variants[0].name, 2, 2),
                        StageConfig(p.stages[1].variants[0].name, 2, 1)))
        for p in cl.pipelines))
    sim = ClusterSimulator(cl, cfg0, adaptation_delay=1.5)
    for p, rate in enumerate((18.0, 90.0, 12.0)):
        for t in TR.arrivals_from_rates(np.full(12, rate), seed=100 + p):
            sim.inject(Request(arrival=float(t), sla=cl.pipelines[p].sla), p)
    sim.run_until(5.0)
    sim.reconfigure_pipeline(0, PipelineConfig(
        (StageConfig("p0a1", 2, 3), StageConfig("p0b0", 2, 1))))
    sim.reconfigure_pipeline(1, PipelineConfig(
        (StageConfig("p1a0", 2, 3), StageConfig("p1b0", 2, 2))))
    sim.run_until(6.0)
    sim.reconfigure_pipeline(0, PipelineConfig(
        (StageConfig("p0a1", 4, 2), StageConfig("p0b0", 2, 1))))
    sim.run_until(12 + 60 * max(sim.sla_of))
    assert sim.reconfig_log == [(5.0, 0, 6.5), (5.0, 1, 6.5), (6.0, 0, 7.5)]
    totals = [(m.arrived, m.completed, m.dropped)
              for m in sim.metrics_by_pipe]
    assert totals == [(241, 241, 0), (1107, 334, 773), (132, 132, 0)]
    assert sim.events_processed == 3325
    assert sim.queued == 0 and sim.in_service == 0


# ---------------------------------------------------------------------------
# golden heterogeneous cluster trace: pins the per-class ledger semantics
# (cpu→gpu moves, elementwise max(old, new) transition holding, gpu service
# times) across BOTH event cores
# ---------------------------------------------------------------------------
def _golden_hetero_cluster():
    """The golden cluster with a gpu class: every heavy ``a1`` variant also
    ships a gpu build that is 4x faster at alloc 1 with +3 accuracy, under
    a small shared gpu budget next to the cpu one."""
    def mk(name, lat1, lat2):
        def coeffs(l1):
            return (0.0, l1 * 0.7, l1 * 0.3)
        a1 = ModelVariant(
            f"{name}a1", 75.0, 2, coeffs(2 * lat1),
            device_profiles=(
                DeviceProfile("cpu", coeffs(2 * lat1), 2, 75.0),
                DeviceProfile("gpu", coeffs(2 * lat1 / 4.0), 1, 78.0)))
        s1 = StageModel(
            f"{name}_a",
            (ModelVariant(f"{name}a0", 60.0, 1, coeffs(lat1)), a1),
            sla=5 * lat1, batch_choices=(1, 2, 4))
        s2 = StageModel(
            f"{name}_b", (ModelVariant(f"{name}b0", 70.0, 1, coeffs(lat2)),),
            sla=5 * lat2, batch_choices=(1, 2, 4))
        return PipelineModel(name, (s1, s2))
    return ClusterModel("golden_hetero",
                        (mk("p0", 0.05, 0.03), mk("p1", 0.04, 0.02),
                         mk("p2", 0.06, 0.035)),
                        cores={"cpu": 40.0, "gpu": 4.0})


def _replay_golden_hetero(sim_cls):
    cl = _golden_hetero_cluster()
    cfg0 = ClusterConfig(tuple(
        PipelineConfig((StageConfig(p.stages[0].variants[0].name, 2, 2),
                        StageConfig(p.stages[1].variants[0].name, 2, 1)))
        for p in cl.pipelines))
    sim = sim_cls(cl, cfg0, adaptation_delay=1.5)
    for p, rate in enumerate((18.0, 90.0, 12.0)):
        for t in TR.arrivals_from_rates(np.full(12, rate), seed=200 + p):
            sim.inject(Request(arrival=float(t), sla=cl.pipelines[p].sla), p)
    sim.run_until(5.0)
    # p0 moves its first stage onto the gpu class mid-trace; p1 grows on cpu
    sim.reconfigure_pipeline(0, PipelineConfig(
        (StageConfig("p0a1", 2, 3, "gpu"), StageConfig("p0b0", 2, 1))))
    sim.reconfigure_pipeline(1, PipelineConfig(
        (StageConfig("p1a0", 2, 3), StageConfig("p1b0", 2, 2))))
    sim.run_until(6.0)
    # supersede p0's pending gpu rollout mid-window with a bigger batch
    sim.reconfigure_pipeline(0, PipelineConfig(
        (StageConfig("p0a1", 4, 2, "gpu"), StageConfig("p0b0", 2, 1))))
    sim.run_until(12 + 60 * max(sim.sla_of))
    totals = tuple((m.arrived, m.completed, m.dropped)
                   for m in sim.metrics_by_pipe)
    return (tuple(sim.reconfig_log), sim.n_reconfigs, totals,
            sim.events_processed, sim.queued, sim.in_service,
            sim.peak_serving_by_class, sim._alloc_vec, sim._serving_vec)


def test_golden_hetero_cluster_trace_is_pinned():
    """Seeded heterogeneous golden trace with a scripted cpu→gpu move
    (superseded mid-window): the event count, per-pipeline totals, the
    reconfiguration log, the per-class serving peak and the final
    per-class ledgers are golden, and all three event cores must replay
    them bit-identically."""
    heap = _replay_golden_hetero(ClusterSimulator)
    struct = _replay_golden_hetero(StructClusterSimulator)
    rnd = _replay_golden_hetero(RoundClusterSimulator)
    assert heap == struct
    assert heap == rnd
    (log, n_rec, totals, events, queued, in_service,
     peak_by_class, alloc_vec, serving_vec) = heap
    assert log == ((5.0, 0, 6.5), (5.0, 1, 6.5), (6.0, 0, 7.5))
    assert n_rec == 3
    assert totals == ((223, 223, 0), (1117, 336, 781), (126, 126, 0))
    assert events == 3345
    assert queued == 0 and in_service == 0
    # p0 settled with its first stage on gpu (2 replicas x alloc 1 gpu
    # units) and its second on cpu (1 replica x alloc 1)
    assert serving_vec[0] == (1.0, 2.0)
    assert alloc_vec[0] == (1.0, 2.0)
    # the other pipelines never touch the gpu class
    assert serving_vec[1][1] == 0.0 and serving_vec[2][1] == 0.0
    assert peak_by_class == (11.0, 2.0)


@pytest.mark.parametrize("name", sorted(EQUIV_TRACES))
def test_explicit_chain_parents_take_chain_fast_path(name):
    """A chain spelled as an explicit path graph (``parents=((), (0,))``)
    must be event-for-event identical to the implicit-chain form: the DAG
    machinery (request ids, join buffers, fan-out routing) must not
    engage at all for linear topologies."""
    explicit = PipelineModel("tiny", PIPE.stages, parents=((), (0,)))
    config, arrivals, horizon = EQUIV_TRACES[name]
    a = replay(PipelineSimulator, PIPE, config, arrivals, horizon)
    b = replay(PipelineSimulator, explicit, config, arrivals, horizon)
    assert not any(b._dag_pipe)
    assert b.metrics.completed == a.metrics.completed
    assert b.metrics.dropped == a.metrics.dropped
    assert b.events_processed == a.events_processed
    np.testing.assert_array_equal(b.metrics.latencies, a.metrics.latencies)


def test_reconfigure_variant_switch_applies_cold_start():
    pipe = two_stage(extra_variant=True)
    sim = PipelineSimulator(pipe, PipelineConfig(
        (StageConfig("a0", 1, 2), StageConfig("b0", 1, 1))),
        variant_switch_delay=2.0)
    sim.now = 1.0
    sim.reconfigure(PipelineConfig((StageConfig("a1", 1, 3),
                                    StageConfig("b0", 1, 1))))
    # old replicas reload the model; the added one starts after the same delay
    assert all(t == pytest.approx(3.0) for t in sim.free_at[0])
    # unchanged stage untouched
    assert sim.free_at[1] == [0.0]
