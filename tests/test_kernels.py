"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

pytestmark = pytest.mark.slow  # jax model hot loops: run via `pytest -m slow`



def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("b,s,h,kv,hd", [
    (1, 128, 4, 4, 64), (2, 256, 4, 2, 64), (1, 256, 8, 1, 32),
    (2, 128, 2, 2, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, h, kv, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, hd), dtype)
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [32, 64])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 32))
    k = jax.random.normal(ks[1], (2, 256, 2, 32))
    v = jax.random.normal(ks[2], (2, 256, 2, 32))
    out = ops.flash_attention(q, k, v, window=window, block_q=64, block_k=64,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-4,
                               rtol=2e-4)


@pytest.mark.parametrize("b,h,kv,hd,L", [
    (2, 8, 2, 64, 256), (1, 4, 4, 32, 128), (3, 16, 2, 128, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, h, kv, hd, L, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = jax.random.normal(ks[0], (b, h, hd), dtype)
    kc = jax.random.normal(ks[1], (b, L, kv, hd), dtype)
    vc = jax.random.normal(ks[2], (b, L, kv, hd), dtype)
    lens = jax.random.randint(ks[3], (b,), 1, L + 1)
    out = ops.decode_attention(q, kc, vc, lens, block_k=64, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_decode_attention_respects_length_mask():
    """Entries past `lengths` must not influence the output."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    b, h, kv, hd, L = 1, 4, 2, 32, 128
    q = jax.random.normal(ks[0], (b, h, hd))
    kc = jax.random.normal(ks[1], (b, L, kv, hd))
    vc = jax.random.normal(ks[2], (b, L, kv, hd))
    lens = jnp.array([64])
    out1 = ops.decode_attention(q, kc, vc, lens, block_k=64, interpret=True)
    kc2 = kc.at[:, 64:].set(999.0)
    vc2 = vc.at[:, 64:].set(-999.0)
    out2 = ops.decode_attention(q, kc2, vc2, lens, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (2, 128, 4, 16, 2, 8, 32), (1, 64, 2, 32, 1, 16, 16),
    (2, 96, 4, 16, 4, 8, 32),
])
def test_ssd_scan_sweep(b, s, h, p, g, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_neg = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, s, g, n))
    cm = jax.random.normal(ks[4], (b, s, g, n))
    y, f = ops.ssd_scan(x, dt, a_neg, bm, cm, chunk=chunk, interpret=True)
    yr, fr = ref.ssd_scan_ref(x, dt, a_neg, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=5e-4,
                               rtol=5e-3)
    np.testing.assert_allclose(np.asarray(f), np.asarray(fr), atol=5e-4,
                               rtol=5e-3)


def test_ssd_scan_initial_state_continuation():
    """Splitting a sequence in half and carrying state == one pass."""
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    b, s, h, p, g, n = 1, 128, 2, 16, 1, 8
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_neg = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, s, g, n))
    cm = jax.random.normal(ks[4], (b, s, g, n))
    y_full, f_full = ops.ssd_scan(x, dt, a_neg, bm, cm, chunk=32,
                                  interpret=True)
    m = s // 2
    y1, f1 = ops.ssd_scan(x[:, :m], dt[:, :m], a_neg, bm[:, :m], cm[:, :m],
                          chunk=32, interpret=True)
    y2, f2 = ops.ssd_scan(x[:, m:], dt[:, m:], a_neg, bm[:, m:], cm[:, m:],
                          chunk=32, init_state=f1, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(f2), np.asarray(f_full), atol=1e-4,
                               rtol=1e-3)


def test_model_layer_pallas_path_matches_jnp():
    """attention(impl='pallas') inside the model layer == chunked/naive."""
    from repro.models import layers as L
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    b, s, h, kv, hd = 2, 128, 4, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    a = L.attention(q, k, v, pos, pos, impl="naive")
    b_ = L.attention(q, k, v, pos, pos, impl="pallas")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=2e-4,
                               rtol=2e-4)


def test_mamba_layer_pallas_path_matches_jnp():
    from repro.configs.base import SSMConfig
    from repro.models import ssm as S
    scfg = SSMConfig(d_state=16, head_dim=32, expand=2, chunk_size=32)
    d = 64
    params = S.init_mamba(jax.random.PRNGKey(7), d, scfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 64, d))
    y1, c1 = S.mamba_forward(params, x, d, scfg, use_pallas=False)
    y2, c2 = S.mamba_forward(params, x, d, scfg, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-3,
                               rtol=1e-2)
    np.testing.assert_allclose(np.asarray(c1["state"]), np.asarray(c2["state"]),
                               atol=1e-3, rtol=1e-2)
