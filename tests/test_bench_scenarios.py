"""Regressions pinned on the benchmark scenarios themselves.

Two contracts live here:

* **Solver-path pin** (the perf PR's acceptance): replaying the
  ``BENCH_sim.json`` policy traces through the vectorized hot path
  (``optimizer.solve_vec``) and through the plain-python oracle
  (``solve_brute``) must be bit-identical — completed/dropped counts,
  event counts, interval records and the full latency stream.  The two
  solvers share accumulation order and tie-break by construction; this
  test catches any drift at the trace level, where a single flipped
  near-tie decision changes the whole downstream event stream.

* **fa2_high collapse** (investigated, expected): on the bench pipeline
  at the default objective (alpha=1, beta=0.1), ``ipa``'s optimum sits in
  the all-heavy-variant corner at every demand point the bursty trace
  visits — a variant downgrade loses ~4 PAS (multiplicative) while
  saving well under 1 objective unit of cores — and cost-minimizing
  within that corner is exactly FA2-high's fixed-variant solve.  So the
  identical ``ipa``/``fa2_high`` rows in ``BENCH_sim.json`` are objective
  degeneracy, not a policy-wiring bug: with a cost-heavy objective the
  two diverge.  This test pins both halves so a future wiring regression
  cannot hide behind "they were always equal".
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))

from bench_simulator import bursty_trace, four_stage_pipeline  # noqa: E402

from repro.core import adapter as AD                           # noqa: E402
from repro.core import baselines as BL                         # noqa: E402
from repro.core import optimizer as OPT                        # noqa: E402


@pytest.fixture(scope="module")
def bench_pipe():
    return four_stage_pipeline()


@pytest.fixture(scope="module")
def bench_rates():
    return bursty_trace(60)              # the --smoke scale


def _demand_points(rates, interval=10.0, window=20):
    """The reactive demand estimates a trace replay actually visits."""
    pts = {float(rates[:int(interval)].max())}
    for t0 in np.arange(interval, len(rates), interval):
        i = int(t0)
        pts.add(float(rates[max(i - window, 0):i].max()))
    return sorted(pts)


# ---------------------------------------------------------------------------
# solver-path pin: vec vs brute, whole traces, all policies
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["ipa", "fa2_low", "fa2_high", "rim"])
def test_policy_trace_bit_identical_vec_vs_brute(bench_pipe, bench_rates,
                                                 policy):
    vec = AD.run_trace(bench_pipe, bench_rates, policy=policy, seed=11,
                       max_replicas=96, solver="vec")
    brute = AD.run_trace(bench_pipe, bench_rates, policy=policy, seed=11,
                         max_replicas=96, solver="brute")
    assert (vec.arrived, vec.completed, vec.dropped, vec.sim_events,
            vec.peak_queue_depth) == \
        (brute.arrived, brute.completed, brute.dropped, brute.sim_events,
         brute.peak_queue_depth)
    assert np.array_equal(vec.latencies, brute.latencies)
    assert [(r.t, r.lam_hat, r.pas, r.cost, r.feasible)
            for r in vec.intervals] == \
        [(r.t, r.lam_hat, r.pas, r.cost, r.feasible)
         for r in brute.intervals]


def test_vec_is_the_default_trace_solver(bench_pipe, bench_rates):
    """``run_trace`` without a solver override runs the vec hot path —
    identical outputs to asking for it explicitly."""
    default = AD.run_trace(bench_pipe, bench_rates, policy="ipa", seed=11,
                           max_replicas=96)
    vec = AD.run_trace(bench_pipe, bench_rates, policy="ipa", seed=11,
                       max_replicas=96, solver="vec")
    assert np.array_equal(default.latencies, vec.latencies)
    assert default.completed == vec.completed
    assert default.solver_wall_s > 0.0


# ---------------------------------------------------------------------------
# fa2_high collapse: degeneracy documented and pinned
# ---------------------------------------------------------------------------
def test_fa2_high_collapse_is_objective_degeneracy(bench_pipe):
    """At the bench objective, ipa picks the all-heavy corner at every
    visited demand point and coincides with fa2_high exactly."""
    rates = bursty_trace(600)            # the full-bench demand points
    heavy = {s.name: s.heaviest.name for s in bench_pipe.stages}
    for lam in _demand_points(rates):
        ipa = BL.ipa(bench_pipe, lam, max_replicas=96)
        high = BL.fa2(bench_pipe, lam, "high", max_replicas=96)
        assert ipa.feasible and high.feasible
        assert all(sc.variant == heavy[st.name]
                   for sc, st in zip(ipa.config.stages, bench_pipe.stages))
        assert ipa.config == high.config, lam


def test_fa2_high_and_ipa_diverge_under_cost_pressure(bench_pipe):
    """Wiring sanity: the collapse is the objective's verdict, not a
    restriction leak — a cost-heavy objective pushes ipa out of the
    all-heavy corner, away from fa2_high."""
    heavy = {s.name: s.heaviest.name for s in bench_pipe.stages}
    diverged = 0
    for lam in (5.0, 12.0, 20.0):
        ipa = BL.ipa(bench_pipe, lam, obj=OPT.Objective(alpha=1.0, beta=2.0),
                     max_replicas=96)
        if any(sc.variant != heavy[st.name]
               for sc, st in zip(ipa.config.stages, bench_pipe.stages)):
            diverged += 1
    assert diverged == 3
