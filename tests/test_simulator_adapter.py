"""Discrete-event simulator invariants + adapter end-to-end behaviour."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import adapter as AD
from repro.core import optimizer as OPT
from repro.core import paper_profiles as PP
from repro.core import trace as TR
from repro.core.pipeline import (ModelVariant, PipelineConfig, PipelineModel,
                                 StageConfig, StageModel)
from repro.core.simulator import PipelineSimulator
from repro.serving.request import Request


def tiny_pipeline(lat1=0.05, lat2=0.03):
    def var(name, l1, acc):
        return ModelVariant(name, acc, 1, (0.0, l1 * 0.7, l1 * 0.3))
    s1 = StageModel("a", (var("a0", lat1, 60.0),), sla=5 * lat1,
                    batch_choices=(1, 2, 4))
    s2 = StageModel("b", (var("b0", lat2, 70.0),), sla=5 * lat2,
                    batch_choices=(1, 2, 4))
    return PipelineModel("tiny", (s1, s2))


def run_sim(pipe, config, arrivals, horizon):
    sim = PipelineSimulator(pipe, config)
    for t in arrivals:
        sim.inject(Request(arrival=float(t), sla=pipe.sla))
    sim.run_until(horizon)
    return sim


@given(seed=st.integers(0, 5000), lam=st.floats(1.0, 30.0))
@settings(max_examples=25, deadline=None)
def test_request_conservation(seed, lam):
    """arrived == completed + dropped once drained (no request lost)."""
    pipe = tiny_pipeline()
    rates = np.full(20, lam)
    arr = TR.arrivals_from_rates(rates, seed=seed)
    cfg = PipelineConfig((StageConfig("a0", 1, max(1, int(lam * 0.06) + 1)),
                          StageConfig("b0", 1, max(1, int(lam * 0.04) + 1))))
    sim = run_sim(pipe, cfg, arr, horizon=20 + 100 * pipe.sla)
    m = sim.metrics
    assert m.arrived == len(arr)
    assert m.completed + m.dropped == m.arrived
    assert len(m.latencies) == m.completed
    assert all(l >= 0 for l in m.latencies)


def test_latency_floor_is_service_time():
    """No request can finish faster than the sum of stage latencies."""
    pipe = tiny_pipeline()
    cfg = PipelineConfig((StageConfig("a0", 1, 4), StageConfig("b0", 1, 4)))
    arr = np.linspace(0, 5, 40)
    sim = run_sim(pipe, cfg, arr, horizon=50)
    v1 = pipe.stages[0].variants[0].latency(1)
    v2 = pipe.stages[1].variants[0].latency(1)
    floor = float(v1 + v2)
    assert min(sim.metrics.latencies) >= floor - 1e-9


def test_underprovision_queues_or_drops():
    """1 replica at 4x its capacity must violate SLAs / drop."""
    pipe = tiny_pipeline(lat1=0.1)
    cfg = PipelineConfig((StageConfig("a0", 1, 1), StageConfig("b0", 1, 1)))
    lam = 40.0
    arr = TR.arrivals_from_rates(np.full(10, lam), seed=0)
    sim = run_sim(pipe, cfg, arr, horizon=10 + 20 * pipe.sla)
    m = sim.metrics
    assert m.dropped > 0 or m.sla_violations(pipe.sla) > 0.3


def test_drop_policy_bounds_latency():
    """§4.5: completed requests' latency is bounded by ~drop_factor x SLA +
    residual service time (expired ones are dropped, not served)."""
    pipe = tiny_pipeline(lat1=0.1)
    cfg = PipelineConfig((StageConfig("a0", 1, 1), StageConfig("b0", 1, 1)))
    arr = TR.arrivals_from_rates(np.full(10, 50.0), seed=1)
    sim = run_sim(pipe, cfg, arr, horizon=10 + 20 * pipe.sla)
    bound = 2.0 * pipe.sla + pipe.sla  # drop threshold + tail service slack
    assert max(sim.metrics.latencies, default=0.0) <= bound


def test_batch_formation_respects_batch_size():
    pipe = tiny_pipeline()
    cfg = PipelineConfig((StageConfig("a0", 4, 2), StageConfig("b0", 2, 2)))
    arr = np.linspace(0, 2, 64)
    sim = run_sim(pipe, cfg, arr, horizon=40)
    assert sim.metrics.completed == 64


def test_reconfigure_changes_capacity():
    pipe = tiny_pipeline(lat1=0.1)
    lam = 30.0
    arr = TR.arrivals_from_rates(np.full(20, lam), seed=2)
    # under-provisioned whole time
    sim1 = run_sim(pipe, PipelineConfig((StageConfig("a0", 1, 1),
                                         StageConfig("b0", 1, 1))),
                   arr, horizon=20 + 20 * pipe.sla)
    # reconfigure to enough replicas after 2 s
    sim2 = PipelineSimulator(pipe, PipelineConfig(
        (StageConfig("a0", 1, 1), StageConfig("b0", 1, 1))))
    for t in arr:
        sim2.inject(Request(arrival=float(t), sla=pipe.sla))
    sim2.run_until(2.0)
    sim2.reconfigure(PipelineConfig((StageConfig("a0", 1, 8),
                                     StageConfig("b0", 1, 8))))
    sim2.run_until(20 + 20 * pipe.sla)
    assert sim2.metrics.dropped < sim1.metrics.dropped or \
        sim2.metrics.sla_violations(pipe.sla) < sim1.metrics.sla_violations(pipe.sla)


# ---------------------------------------------------------------------------
# adapter end-to-end (paper §5.2 behaviours, scaled down for CI)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def video_results():
    pipe = PP.video()
    rates = TR.excerpt("fluctuating", seconds=120)
    obj = OPT.Objective(**PP.PAPER_WEIGHTS["video"], metric="pas")
    return {pol: AD.run_trace(pipe, rates, policy=pol, obj=obj, seed=3)
            for pol in ("ipa", "fa2_low", "fa2_high", "rim")}


def test_fa2_pins_bracket_ipa_accuracy(video_results):
    r = video_results
    assert r["fa2_low"].mean_pas - 1e-6 <= r["ipa"].mean_pas \
        <= r["fa2_high"].mean_pas + 1e-6


def test_ipa_cheaper_than_fa2_high(video_results):
    assert video_results["ipa"].mean_cost <= video_results["fa2_high"].mean_cost


def test_rim_most_expensive(video_results):
    r = video_results
    assert r["rim"].mean_cost >= max(r["ipa"].mean_cost,
                                     r["fa2_high"].mean_cost)


def test_ipa_improves_accuracy_over_fa2_low_meaningfully(video_results):
    """Paper headline: up to 21% end-to-end accuracy gain vs cost-optimal."""
    r = video_results
    gain = (r["ipa"].mean_pas - r["fa2_low"].mean_pas) / r["fa2_low"].mean_pas
    assert gain > 0.10


def test_all_requests_accounted(video_results):
    for res in video_results.values():
        assert res.completed + res.dropped == res.arrived


def test_solver_wall_surfaced_end_to_end(video_results):
    """The per-phase bench breakdown needs no external instrumentation:
    every trace result carries the solver's total wall time (bootstrap
    included), consistent with its own interval records."""
    for res in video_results.values():
        per_interval = sum(r.solve_time for r in res.intervals)
        assert res.solver_wall_s >= per_interval > 0.0


def test_cluster_solver_wall_counts_joint_solves_once():
    from repro.core.cluster import ClusterModel
    pipe = tiny_pipeline()
    cl = ClusterModel("t2", (pipe, tiny_pipeline(0.04, 0.02)), 64.0)
    rates = [np.full(30, 5.0), np.full(30, 8.0)]
    res = AD.run_cluster_trace(cl, rates, policy="ipa")
    # each boundary's joint solve_time is stamped identically on every
    # pipeline's record (it is ONE joint solve, not per-pipeline work) and
    # the aggregate counts it once, plus the bootstrap solve on top
    t0s = [r.solve_time for r in res.per_pipeline[0].intervals]
    t1s = [r.solve_time for r in res.per_pipeline[1].intervals]
    assert t0s == t1s
    per_interval = sum(t0s)
    assert per_interval > 0.0
    assert res.solver_wall_s >= per_interval    # bootstrap adds, never less


def test_pool_acquire_many_matches_sequential():
    from repro.serving.request import RequestPool
    pool = RequestPool()
    first = pool.acquire_many([0.0, 1.0, 2.0], sla=1.5)
    assert [r.arrival for r in first] == [0.0, 1.0, 2.0]
    assert all(r.sla == 1.5 for r in first)
    assert (pool.allocated, pool.reused) == (3, 0)
    pool.release_many(first[:2])
    again = pool.acquire_many([3.0, 4.0, 5.0])
    assert [r.arrival for r in again] == [3.0, 4.0, 5.0]
    assert (pool.allocated, pool.reused) == (4, 2)
    # recycled objects come from the free list
    assert {id(r) for r in first[:2]} <= {id(r) for r in again}
