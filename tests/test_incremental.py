"""Incremental cross-interval planning (``optimizer.PlannerCache``).

Every layer the planner cache adds over the frontier memo — stage tables,
evaluate_config memo, pruned-tab memo, whole-solve memo, DP prefix
resume — is *pure memoization*: exact-keyed on value objects, so a solve
sequence threaded through one ``PlannerCache`` must be **bit-identical**
(same chosen configs, same float objective/cost bits, same charged switch
counts) to running every solve with ``cache=None``.  The properties here
cover the paths the DP-resume proof has to hold on: scalar budgets,
switch costs with an incumbent, per-interval switch budgets (the 2d DP),
hetero vector costs (the nd DP), and overlap charging with a serving
config that diverges from the committed incumbent.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import adapter as AD
from repro.core import optimizer as OPT
from test_cluster import toy_cluster
from test_hetero import hetero_cluster


def snap(sol):
    """Everything an incremental-solve bug could perturb, compared with
    exact float equality (bit-identity, not approx)."""
    if not sol.feasible:
        return ("infeasible",)
    return (sol.config, sol.objective, sol.cost, sol.n_switches,
            tuple((s.config, s.objective, s.pas, s.cost)
                  for s in sol.per_pipeline))


# ---------------------------------------------------------------------------
# whole-solve memo
# ---------------------------------------------------------------------------
def test_repeat_solve_is_whole_solution_memo_hit():
    cl = toy_cluster()
    plan = OPT.PlannerCache()
    a = OPT.solve_cluster(cl, [8.0, 14.0], cache=plan)
    b = OPT.solve_cluster(cl, [8.0, 14.0], cache=plan)
    ref = OPT.solve_cluster(cl, [8.0, 14.0])
    assert snap(a) == snap(b) == snap(ref)
    assert (plan.sol_hits, plan.sol_misses) == (1, 1)
    # the hit is a fresh wrapper with its own solve_time, not the cached
    # object handed out mutably
    assert b is not a


def test_infeasible_solves_are_memoized_too():
    cl = toy_cluster(cores=1.0)           # nothing fits
    plan = OPT.PlannerCache()
    a = OPT.solve_cluster(cl, [50.0, 50.0], max_replicas=2, cache=plan)
    b = OPT.solve_cluster(cl, [50.0, 50.0], max_replicas=2, cache=plan)
    assert not a.feasible and not b.feasible
    assert plan.sol_hits == 1


def test_solve_memo_keyed_on_every_input():
    """Perturbing any solve input must miss the whole-solve memo (and then
    still agree with cache=None)."""
    cl = toy_cluster()
    plan = OPT.PlannerCache()
    base = dict(budget=30.0, max_replicas=6)
    OPT.solve_cluster(cl, [8.0, 14.0], cache=plan, **base)
    variants = [
        ([8.0, 15.0], base),
        ([8.0, 14.0], dict(base, budget=28.0)),
        ([8.0, 14.0], dict(base, max_replicas=5)),
        ([8.0, 14.0], dict(base, latency_model="expected")),
        ([8.0, 14.0], dict(base, sla_weights=[2.0, 1.0])),
    ]
    for lams, kw in variants:
        got = OPT.solve_cluster(cl, lams, cache=plan, **kw)
        ref = OPT.solve_cluster(cl, lams, **kw)
        assert snap(got) == snap(ref), (lams, kw)
    assert plan.sol_hits == 0
    assert plan.sol_misses == 1 + len(variants)


# ---------------------------------------------------------------------------
# DP prefix resume
# ---------------------------------------------------------------------------
def test_single_pipeline_change_resumes_after_prefix():
    """Changing only the *last* pipeline's rate keeps the first pipeline's
    candidate tab bit-identical, so the DP resumes after a 1-pipeline
    prefix instead of recomputing it — and the answer matches cache=None
    exactly."""
    cl = toy_cluster()
    plan = OPT.PlannerCache()
    OPT.solve_cluster(cl, [8.0, 14.0], budget=30.0, cache=plan)
    assert plan.dp_prefix_pipes == 0      # cold solve: nothing to resume
    # 14 -> 44 moves pipeline 1's n* (and so its candidate tab); 8.0
    # repeats, so pipeline 0's tab is the exact cached objects
    got = OPT.solve_cluster(cl, [8.0, 44.0], budget=30.0, cache=plan)
    assert plan.dp_prefix_pipes == 1
    ref = OPT.solve_cluster(cl, [8.0, 44.0], budget=30.0)
    assert snap(got) == snap(ref)


def test_rate_change_that_keeps_tabs_identical_is_full_dp_reuse():
    """n* absorbs small rate moves: at lam 8 vs 11 the toy pipeline's
    frontier is value-identical, so the whole-solve memo misses but every
    candidate tab matches — the DP is reused outright and the answer still
    matches a cold cache=None solve bit-for-bit."""
    cl = toy_cluster()
    plan = OPT.PlannerCache()
    OPT.solve_cluster(cl, [8.0, 14.0], budget=30.0, cache=plan)
    got = OPT.solve_cluster(cl, [11.0, 14.0], budget=30.0, cache=plan)
    assert plan.sol_hits == 0 and plan.dp_full_hits == 1
    assert snap(got) == snap(OPT.solve_cluster(cl, [11.0, 14.0],
                                               budget=30.0))


def test_first_pipeline_change_falls_back_to_full_dp():
    """A change in pipeline 0 proves no prefix; the fallback full DP must
    still be bit-identical to cache=None."""
    cl = toy_cluster()
    plan = OPT.PlannerCache()
    OPT.solve_cluster(cl, [8.0, 14.0], budget=30.0, cache=plan)
    got = OPT.solve_cluster(cl, [40.0, 14.0], budget=30.0, cache=plan)
    assert plan.dp_prefix_pipes == 0 and plan.dp_full_hits == 0
    assert snap(got) == snap(OPT.solve_cluster(cl, [40.0, 14.0],
                                               budget=30.0))


def test_budget_change_invalidates_dp_state():
    """A different budget grid shares no dp rows: the stored state must be
    ignored (gkey mismatch), never sliced into the wrong-width arrays."""
    cl = toy_cluster()
    plan = OPT.PlannerCache()
    OPT.solve_cluster(cl, [8.0, 14.0], budget=30.0, cache=plan)
    got = OPT.solve_cluster(cl, [8.0, 14.0], budget=22.0, cache=plan)
    assert plan.dp_prefix_pipes == 0
    assert snap(got) == snap(OPT.solve_cluster(cl, [8.0, 14.0],
                                               budget=22.0))


# ---------------------------------------------------------------------------
# property: perturbed solve sequences with chained incumbents
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 4000), sw=st.floats(0.0, 1.0),
       kbud=st.integers(0, 2))
@settings(max_examples=15, deadline=None)
def test_sequences_bit_identical_with_switch_knobs(seed, sw, kbud):
    """Scalar-budget sequences under switch costs and (2d DP) switch
    budgets, incumbents chained boundary-to-boundary: PlannerCache solves
    must be bit-identical to fresh cache=None solves at every step."""
    cl = toy_cluster()
    rng = np.random.default_rng(seed)
    plan = OPT.PlannerCache()
    cur = None
    lams = [8.0, 14.0]
    kw = dict(switch_cost=float(sw), switch_budget=(kbud or None),
              max_replicas=6)
    for _step in range(5):
        lams[int(rng.integers(0, 2))] = float(
            np.round(rng.uniform(2.0, 25.0), 2))
        got = OPT.solve_cluster(cl, lams, current=cur, cache=plan, **kw)
        ref = OPT.solve_cluster(cl, lams, current=cur, **kw)
        assert snap(got) == snap(ref), (lams, cur is None)
        if got.feasible:
            cur = got.config


@given(seed=st.integers(0, 4000), overlap=st.sampled_from([False, True]))
@settings(max_examples=8, deadline=None)
def test_hetero_sequences_bit_identical(seed, overlap):
    """Hetero vector costs (the nd DP, reach-capped budget grid) with
    switch costs and — when ``overlap`` — transition charging against a
    serving config one boundary behind the committed incumbent."""
    cl = hetero_cluster()
    rng = np.random.default_rng(seed)
    plan = OPT.PlannerCache()
    cur = serving = None
    lams = [6.0, 9.0]
    for _step in range(4):
        lams[int(rng.integers(0, 2))] = float(
            np.round(rng.uniform(2.0, 18.0), 2))
        kw = dict(max_replicas=4, switch_cost=0.3, overlap=overlap,
                  serving=serving)
        got = OPT.solve_cluster(cl, lams, current=cur, cache=plan, **kw)
        ref = OPT.solve_cluster(cl, lams, current=cur, **kw)
        assert snap(got) == snap(ref), (lams, overlap)
        if got.feasible:
            serving = cur                 # serving lags the commit
            cur = got.config


# ---------------------------------------------------------------------------
# stage tables
# ---------------------------------------------------------------------------
def test_stage_options_with_tables_bit_identical():
    """``stage_options`` through a ``_StageTable`` memo must reproduce the
    direct enumeration bit-for-bit — every column, both latency models,
    single-class and hetero stages, feasible or not."""
    stages = [s for cl in (toy_cluster(), hetero_cluster())
              for p in cl.pipelines for s in p.stages]
    tables = {}
    for stg in stages:
        for lam in (0.0, 3.7, 12.0, 400.0):
            for lm in ("worst_case", "expected"):
                a = OPT.stage_options(stg, lam, max_replicas=5,
                                      latency_model=lm, tables=tables)
                b = OPT.stage_options(stg, lam, max_replicas=5,
                                      latency_model=lm)
                assert a.names == b.names and a.devices == b.devices
                for f in ("batches", "lat", "cost", "acc", "acc_norm",
                          "replicas", "feasible"):
                    np.testing.assert_array_equal(
                        getattr(a, f), getattr(b, f), err_msg=f)
    assert len(tables) == len(set(stages))


# ---------------------------------------------------------------------------
# end to end: the adapter's "auto" cache is a PlannerCache and the full
# trace stays bit-identical to no caching at all
# ---------------------------------------------------------------------------
def test_cluster_trace_with_planner_cache_matches_uncached():
    cl = toy_cluster(cores=24.0)
    t = np.arange(40, dtype=np.float64)
    traces = [np.clip(4.0 + 10.0 * np.exp(-((t - 12.0) % 30.0) / 6.0), 0.5,
                      None),
              np.full(40, 6.0)]
    common = dict(policy="ipa", obj=OPT.Objective(alpha=1.0, beta=0.02),
                  switch_cost=0.1, adaptation_delay=4.0, seed=3)
    plan = OPT.PlannerCache()
    got = AD.run_cluster_trace(cl, traces, frontier_cache=plan, **common)
    ref = AD.run_cluster_trace(cl, traces, frontier_cache=None, **common)
    assert got.n_reconfigs == ref.n_reconfigs
    assert got.reconfig_log == ref.reconfig_log
    assert [(p.completed, p.dropped) for p in got.per_pipeline] == \
        [(p.completed, p.dropped) for p in ref.per_pipeline]
    for a, b in zip(got.per_pipeline, ref.per_pipeline):
        np.testing.assert_array_equal(np.asarray(a.latencies),
                                      np.asarray(b.latencies))
        assert [(r.pas, r.cost, r.feasible) for r in a.intervals] == \
            [(r.pas, r.cost, r.feasible) for r in b.intervals]
    # the layered memos actually engaged
    st_ = plan.stats["planner"]
    assert st_["sol_misses"] > 0 and st_["stage_tables"] > 0
    assert st_["sol_hits"] + st_["dp_prefix_pipes"] + st_["dp_full_hits"] > 0
