"""DAG routing in both event cores: fan-out, wait-for-all-parents joins,
§4.5 drop propagation, conservation invariants, chain bit-identity with
explicit path-graph parents, and a pinned golden video fan-out trace."""
import numpy as np
import pytest

from repro.core.paper_profiles import video_fanout
from repro.core.pipeline import (ModelVariant, PipelineConfig, PipelineModel,
                                 StageConfig, StageModel)
from repro.core.simulator import (ClusterSimulator, PipelineSimulator,
                                  RoundPipelineSimulator,
                                  StructPipelineSimulator)

CORES = (PipelineSimulator, StructPipelineSimulator, RoundPipelineSimulator)


def var(name, l1, acc=70.0, alloc=1):
    return ModelVariant(name, acc, alloc, (0.0, l1 * 0.7, l1 * 0.3))


def stage(name, l1, sla=None):
    return StageModel(name, (var(name + "0", l1),),
                      sla=sla if sla is not None else 5 * l1,
                      batch_choices=(1, 2, 4))


def diamond(l_fast=0.01, l_slow=0.05):
    """0 -> (1 fast, 2 slow) -> 3 join."""
    stages = (stage("src", 0.01), stage("fast", l_fast),
              stage("slow", l_slow), stage("sink", 0.01))
    return PipelineModel("diamond", stages,
                         parents=((), (0,), (0,), (1, 2)))


def unit_config(pipe, batch=1, replicas=1):
    return PipelineConfig(tuple(
        StageConfig(s.variants[0].name, batch, replicas)
        for s in pipe.stages))


def drain(sim, times, horizon_pad=10.0, lam=None):
    if lam is not None:
        sim.lam_est = lam
    sim.inject_arrivals(np.asarray(times, dtype=np.float64))
    sim.run_until(float(np.max(times)) + horizon_pad)
    return sim


def assert_clean(sim):
    """No leaked DAG tracking state once the pipeline drains."""
    assert all(not d for d in sim._inflight)
    assert all(not s for s in sim._dead)
    assert all(not b for b in sim._join_buf if b is not None)
    m = sim.metrics_by_pipe[0]
    assert m.arrived == m.completed + m.dropped


# ---------------------------------------------------------------------------
# join semantics
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cls", CORES)
def test_join_waits_for_slowest_parent(cls):
    pipe = diamond(l_fast=0.01, l_slow=0.05)
    sim = cls(pipe, unit_config(pipe))
    drain(sim, [1.0], lam=1.0)
    m = sim.metrics
    assert m.completed == 1 and m.dropped == 0
    # e2e = src + max(fast, slow) + sink: the fast branch waits at the join
    assert float(m.latencies[0]) == pytest.approx(0.01 + 0.05 + 0.01)
    assert_clean(sim)


@pytest.mark.parametrize("cls", CORES)
def test_fanout_without_join_completes_once_per_request(cls):
    # 0 -> (1, 2): two sinks is invalid, so join them; the point is the
    # arrival stream is replicated, every stage sees all requests
    pipe = diamond()
    sim = cls(pipe, unit_config(pipe, batch=2, replicas=2))
    times = np.linspace(1.0, 3.0, 12)
    drain(sim, times, lam=6.0)
    m = sim.metrics
    assert m.arrived == 12
    assert m.completed == 12          # exactly once each, despite 2 branches
    assert_clean(sim)


@pytest.mark.parametrize("cls", CORES)
def test_join_matches_requests_not_positions(cls):
    """Batch boundaries differ per branch (different batch sizes), so the
    join must match by request id, not delivery position."""
    pipe = diamond(l_fast=0.01, l_slow=0.03)
    cfg = PipelineConfig((StageConfig("src0", 1, 1),
                          StageConfig("fast0", 4, 1),
                          StageConfig("slow0", 1, 2),
                          StageConfig("sink0", 2, 1)))
    sim = cls(pipe, cfg)
    times = np.linspace(1.0, 1.5, 9)
    drain(sim, times, lam=18.0)
    m = sim.metrics
    assert m.completed + m.dropped == 9
    assert m.completed >= 1
    assert_clean(sim)


# ---------------------------------------------------------------------------
# §4.5 drop propagation
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cls", CORES)
def test_drop_cancels_sibling_branch(cls):
    """A request dropped on one branch must not linger in the sibling
    queue or the join buffer, and is counted dropped exactly once."""
    pipe = diamond(l_fast=0.01, l_slow=0.2)
    # slow branch with zero-capacity pressure: 1 replica, long service
    cfg = PipelineConfig((StageConfig("src0", 1, 2),
                          StageConfig("fast0", 1, 2),
                          StageConfig("slow0", 1, 1),
                          StageConfig("sink0", 1, 2)))
    sim = cls(pipe, cfg, drop_factor=1.0, max_wait=0.1)
    times = np.cumsum(np.full(60, 1 / 30.0))  # 30 rps >> slow capacity 5rps
    drain(sim, times, lam=30.0)
    m = sim.metrics
    assert m.dropped > 0
    assert m.completed + m.dropped == 60
    assert_clean(sim)


@pytest.mark.parametrize("cls", CORES)
def test_overload_conservation_and_no_leak(cls):
    pipe = diamond()
    sim = cls(pipe, unit_config(pipe), drop_factor=1.0, max_wait=0.05)
    rng = np.random.default_rng(3)
    times = np.cumsum(rng.exponential(1 / 200.0, 2000))
    drain(sim, times, lam=200.0)
    m = sim.metrics
    assert m.dropped > 0
    assert_clean(sim)


# ---------------------------------------------------------------------------
# both cores bit-identical on DAGs
# ---------------------------------------------------------------------------
def _replay(cls, pipe, cfg, lam, n, drop_factor, seed):
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(1.0 / lam, n))
    sim = cls(pipe, cfg, drop_factor=drop_factor, max_wait=0.05)
    drain(sim, times, lam=lam)
    m = sim.metrics
    assert_clean(sim)
    return (m.arrived, m.completed, m.dropped, sim.events_processed,
            m.latencies.tobytes())


@pytest.mark.parametrize("lam,n,df", [(20.0, 400, 2.0), (300.0, 2000, 1.0),
                                      (80.0, 1500, 1.5)])
def test_struct_core_bit_identical_on_dag(lam, n, df):
    pipe = diamond()
    cfg = unit_config(pipe, batch=2)
    h = _replay(PipelineSimulator, pipe, cfg, lam, n, df, seed=0)
    s = _replay(StructPipelineSimulator, pipe, cfg, lam, n, df, seed=0)
    r = _replay(RoundPipelineSimulator, pipe, cfg, lam, n, df, seed=0)
    assert h == s
    assert h == r


# ---------------------------------------------------------------------------
# chains with explicit path-graph parents stay on the chain fast path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cls", CORES)
def test_explicit_chain_parents_bit_identical_to_implicit(cls):
    stages = (stage("a", 0.05), stage("b", 0.03))
    implicit = PipelineModel("tiny", stages)
    explicit = PipelineModel("tiny", stages, parents=((), (0,)))
    cfg = PipelineConfig((StageConfig("a0", 2, 2), StageConfig("b0", 2, 1)))
    rng = np.random.default_rng(11)
    times = np.cumsum(rng.exponential(1 / 15.0, 500))
    out = []
    for pipe in (implicit, explicit):
        sim = cls(pipe, cfg, drop_factor=1.5, max_wait=0.1)
        drain(sim, times, lam=15.0)
        m = sim.metrics
        out.append((m.arrived, m.completed, m.dropped,
                    sim.events_processed, m.latencies.tobytes()))
        # an explicit path graph is a chain: no DAG bookkeeping engaged
        assert not any(sim._dag_pipe)
        assert all(not d for d in sim._inflight)
    assert out[0] == out[1]


# ---------------------------------------------------------------------------
# golden seeded trace: the video fan-out preset, both cores, pinned
# ---------------------------------------------------------------------------
GOLDEN = dict(arrived=800, completed=794, dropped=6, events=4210,
              n_reconfigs=1, lat_sum=713.0026923255647,
              lat_max=1.7732977636746003)


@pytest.mark.parametrize("cls", CORES)
def test_golden_video_fanout_trace_is_pinned(cls):
    """End-to-end witness for the DAG machinery on the paper-profile
    fan-out preset, with a mid-trace reconfiguration.  Any change to
    fan-out routing, join matching, drop propagation or reconfig
    handling shows up here first — in either core."""
    pipe = video_fanout()
    cfg1 = PipelineConfig((StageConfig("decode-fixed", 1, 1),
                           StageConfig("yolov5m", 4, 2),
                           StageConfig("resnet50", 4, 2),
                           StageConfig("fusion-fixed", 1, 1)))
    cfg2 = PipelineConfig((StageConfig("decode-fixed", 1, 1),
                           StageConfig("yolov5s", 2, 3),
                           StageConfig("resnet34", 2, 2),
                           StageConfig("fusion-fixed", 1, 1)))
    rng = np.random.default_rng(42)
    times = np.cumsum(rng.exponential(1 / 12.0, 600))
    sim = cls(pipe, cfg1, drop_factor=1.2, max_wait=0.3)
    sim.lam_est = 12.0
    sim.inject_arrivals(times)
    sim.run_until(float(times[-1]) + 10.0)
    sim.reconfigure(cfg2)
    t2 = np.cumsum(rng.exponential(1 / 12.0, 200)) + sim.now
    sim.inject_arrivals(t2)
    sim.run_until(float(t2[-1]) + 10.0)
    m = sim.metrics
    assert m.arrived == GOLDEN["arrived"]
    assert m.completed == GOLDEN["completed"]
    assert m.dropped == GOLDEN["dropped"]
    assert sim.events_processed == GOLDEN["events"]
    assert sim.n_reconfigs == GOLDEN["n_reconfigs"]
    assert float(m.latencies.sum()) == GOLDEN["lat_sum"]
    assert float(m.latencies.max()) == GOLDEN["lat_max"]
    assert_clean(sim)


# ---------------------------------------------------------------------------
# DAG + chain sharing one cluster heap
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("make", [
    lambda: ClusterSimulator,
    lambda: __import__("repro.core.simulator", fromlist=["x"]
                       ).StructClusterSimulator,
    lambda: __import__("repro.core.simulator", fromlist=["x"]
                       ).RoundClusterSimulator,
])
def test_mixed_cluster_dag_and_chain(make):
    from repro.core.cluster import ClusterConfig, ClusterModel
    dag = diamond()
    chain = PipelineModel("chain", (stage("c1", 0.02), stage("c2", 0.02)))
    cluster = ClusterModel("mixed", (dag, chain))
    config = ClusterConfig((unit_config(dag),
                            PipelineConfig((StageConfig("c10", 1, 1),
                                            StageConfig("c20", 1, 1)))))
    sim = make()(cluster, config)
    rng = np.random.default_rng(5)
    for p in (0, 1):
        sim.set_lam_est(p, 10.0)
        sim.inject_arrivals(np.cumsum(rng.exponential(0.1, 100)), pipeline=p)
    sim.run_until(60.0)
    for p in (0, 1):
        m = sim.metrics_by_pipe[p]
        assert m.arrived == 100
        assert m.completed + m.dropped == 100
    assert not sim._inflight[0] and not sim._inflight[1]


# ---------------------------------------------------------------------------
# per-edge network-latency stage: delay moves the critical path, never the
# budget
# ---------------------------------------------------------------------------
def _edge_config(uplink_replicas=8):
    """Small-batch config for the ``video-edge`` preset; the free uplink
    gets plenty of replicas so the link never serializes."""
    return PipelineConfig((StageConfig("decode-fixed", 1, 1),
                           StageConfig("yolov5s", 1, 2),
                           StageConfig("uplink-link", 1, uplink_replicas),
                           StageConfig("resnet50", 1, 2),
                           StageConfig("fusion-fixed", 1, 1)))


def test_edge_delay_shifts_planner_critical_path_not_cost():
    """Planner side of the ``video-edge`` preset: growing the network
    delay lengthens the critical-path latency bound by (at least) the
    added delay, while the config's cost — scalar and per-class — is
    bit-identical at every delay and equal to the edge-less fan-out's."""
    from repro.core.paper_profiles import video_edge, video_fanout
    fast, slow = video_edge(0.001), video_edge(0.5)
    cfg = _edge_config()
    arrival = 4.0
    lat_fast, lat_slow = cfg.latency(fast, arrival), cfg.latency(slow, arrival)
    # negligible delay: the detection branch is critical and the link is
    # invisible; at 0.5 s the link drags the classification branch past
    # it, so the critical path jumps to (at least) the delay itself
    assert lat_fast < 0.25
    assert lat_slow >= 0.5
    assert lat_slow - lat_fast >= 0.3
    # zero-cost link: the uplink's variant allocates nothing...
    assert slow.stages[2].variants[0].base_alloc == 0
    # ...so cost never moves with the delay, and matches the fan-out
    base = PipelineConfig((StageConfig("decode-fixed", 1, 1),
                           StageConfig("yolov5s", 1, 2),
                           StageConfig("resnet50", 1, 2),
                           StageConfig("fusion-fixed", 1, 1)))
    assert cfg.cost(fast) == cfg.cost(slow) == base.cost(video_fanout())
    assert cfg.cost_by_class(fast, ("cpu",)) == (cfg.cost(fast),)
    # the solver prices the link at zero too: a solved plan's cost equals
    # the sum over its non-link stages
    from repro.core import optimizer as OPT
    sol = OPT.solve(slow, arrival, OPT.Objective())
    assert sol.feasible
    paid = sum(sc.replicas * st.variant(sc.variant).alloc(sc.device)
               for i, (sc, st) in enumerate(zip(sol.config.stages,
                                                slow.stages)) if i != 2)
    assert sol.cost == float(paid)


@pytest.mark.parametrize("cls", CORES)
def test_edge_delay_shifts_simulated_latency_not_budget(cls):
    """Simulator side: the same seeded trace through ``video-edge`` at two
    delays completes every request in both, shifted by ~the delay delta —
    and the cluster ledger admits the config at a budget with zero
    headroom for the link, proving the link is never charged."""
    from repro.core.cluster import ClusterConfig, ClusterModel
    from repro.core.paper_profiles import video_edge
    cfg = _edge_config()
    rng = np.random.default_rng(7)
    times = np.cumsum(rng.exponential(1 / 4.0, 200))
    lat_mean = {}
    for delay in (0.001, 0.5):
        pipe = video_edge(delay)
        sim = cls(pipe, cfg)
        sim.lam_est = 4.0
        sim.inject_arrivals(times)
        sim.run_until(float(times[-1]) + 20.0)
        m = sim.metrics
        assert m.completed == 200 and m.dropped == 0
        lat_mean[delay] = float(m.latencies.mean())
        assert_clean(sim)
    # the join waits on the slower branch: the shift is the slow link's
    # branch overtaking the detection branch, not the raw delay delta
    assert 0.3 <= lat_mean[0.5] - lat_mean[0.001] <= 0.55
    # ledger: budget == cost with the link priced at zero; any charge for
    # the uplink's 8 replicas would overflow at construction
    pipe = video_edge(0.5)
    cluster = ClusterModel("edge", (pipe,), cores=cfg.cost(pipe))
    csim = ClusterSimulator(cluster, ClusterConfig((cfg,)))
    csim.inject_arrivals(times, pipeline=0)
    csim.set_lam_est(0, 4.0)
    csim.run_until(float(times[-1]) + 20.0)
    assert csim.peak_serving_cores == cfg.cost(pipe)
    assert csim.metrics_by_pipe[0].completed == 200
