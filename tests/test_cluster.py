"""Cluster co-scheduling: data model, shared-pool ledger, joint solver
(knapsack vs brute oracle), and the cluster adapter end-to-end."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import adapter as AD
from repro.core import baselines as BL
from repro.core import optimizer as OPT
from repro.core.cluster import (ClusterConfig, ClusterModel,
                                proportional_split)
from repro.core.pipeline import (ModelVariant, PipelineConfig, PipelineModel,
                                 StageConfig, StageModel)
from repro.core.simulator import ClusterSimulator, CoreBudgetExceeded
from repro.serving.request import Request


def toy_pipeline(name: str, l1: float = 0.05,
                 accs=(60.0, 75.0, 85.0)) -> PipelineModel:
    vs = tuple(
        ModelVariant(f"{name}_v{i}", a, 2 ** i,
                     (l1 * s * 0.002, l1 * s * 0.7, l1 * s * 0.3))
        for i, (a, s) in enumerate(zip(accs, (1.0, 1.7, 3.0))))
    return PipelineModel(name, (
        StageModel(f"{name}_s1", vs, sla=5 * l1 * 1.7, batch_choices=(1, 2, 4)),
        StageModel(f"{name}_s2", vs, sla=5 * l1 * 1.7, batch_choices=(1, 2, 4)),
    ))


def toy_cluster(cores: float = 40.0) -> ClusterModel:
    return ClusterModel("toy", (toy_pipeline("A"),
                                toy_pipeline("B", l1=0.03,
                                             accs=(55.0, 68.0, 90.0))), cores)


# ---------------------------------------------------------------------------
# data model
# ---------------------------------------------------------------------------
def test_cluster_config_cost_is_sum_of_pipelines():
    cl = toy_cluster()
    sol_a = OPT.solve_capped(cl.pipelines[0], 10.0, OPT.Objective())
    sol_b = OPT.solve_capped(cl.pipelines[1], 10.0, OPT.Objective())
    joint = ClusterConfig((sol_a.config, sol_b.config))
    assert joint.cost(cl) == pytest.approx(sol_a.cost + sol_b.cost)
    assert joint.fits(cl) == (sol_a.cost + sol_b.cost <= cl.cores + 1e-9)


def test_proportional_split_sums_to_budget():
    cl = toy_cluster(cores=30.0)
    shares = proportional_split(cl, [10.0, 20.0])
    assert sum(shares) == pytest.approx(30.0)
    assert shares[0] == pytest.approx(10.0)
    assert shares[1] == pytest.approx(20.0)
    # zero total demand: even split, not div-by-zero
    even = proportional_split(cl, [0.0, 0.0])
    assert even[0] == even[1] == pytest.approx(15.0)


# ---------------------------------------------------------------------------
# joint solver: knapsack arbitration vs brute-force oracle
# ---------------------------------------------------------------------------
@given(budget=st.integers(4, 60), lam_a=st.floats(1.0, 25.0),
       lam_b=st.floats(1.0, 25.0))
@settings(max_examples=20, deadline=None)
def test_knapsack_matches_brute_force(budget, lam_a, lam_b):
    cl = ClusterModel("toy", toy_cluster().pipelines, float(budget))
    obj = OPT.Objective(alpha=1.0, beta=0.05)
    k = OPT.solve_cluster(cl, [lam_a, lam_b], obj)
    b = OPT.solve_cluster_brute(cl, [lam_a, lam_b], obj)
    assert k.feasible == b.feasible
    if k.feasible:
        assert k.objective == pytest.approx(b.objective, rel=1e-9)
        assert k.cost <= budget + 1e-9
        assert k.config.fits(cl)


# ---------------------------------------------------------------------------
# switch-cost-aware arbitration: knapsack vs brute oracle, hysteresis,
# reconfiguration budget, SLA weights
# ---------------------------------------------------------------------------
def _incumbent_for(cl, lams, obj):
    """A plausible held config: the joint solve at a perturbed rate pair
    (so its replica counts are generally off the new rates' frontiers)."""
    sol = OPT.solve_cluster(cl, lams, obj)
    return sol.config if sol.feasible else None


@given(budget=st.integers(6, 55), lam_a=st.floats(1.0, 25.0),
       lam_b=st.floats(1.0, 25.0), switch_cost=st.floats(0.0, 4.0),
       switch_budget=st.sampled_from([-1, 0, 1, 2]))
@settings(max_examples=20, deadline=None)
def test_switch_knapsack_matches_brute_force(budget, lam_a, lam_b,
                                             switch_cost, switch_budget):
    """The switch-cost-aware DP must agree with the cross-product oracle
    that enumerates all configs and subtracts transition costs."""
    cl = ClusterModel("toy", toy_cluster().pipelines, float(budget))
    obj = OPT.Objective(alpha=1.0, beta=0.05)
    current = _incumbent_for(cl, [lam_a * 0.7 + 1.0, lam_b * 0.9 + 1.0], obj)
    sb = None if switch_budget < 0 else int(switch_budget)
    weights = (1.0, 1.7)
    k = OPT.solve_cluster(cl, [lam_a, lam_b], obj, current=current,
                          switch_cost=switch_cost, switch_budget=sb,
                          sla_weights=weights)
    b = OPT.solve_cluster_brute(cl, [lam_a, lam_b], obj, current=current,
                                switch_cost=switch_cost, switch_budget=sb,
                                sla_weights=weights)
    assert k.feasible == b.feasible
    if k.feasible:
        assert k.objective == pytest.approx(b.objective, rel=1e-9, abs=1e-9)
        assert k.cost <= budget + 1e-9
        assert k.config.fits(cl)
        if sb is not None:
            assert k.n_switches <= sb
        if current is not None:
            assert k.n_switches == k.config.n_changes(current)


def test_switch_free_solver_bit_identical_to_pr2():
    """With switch cost 0 and uniform SLA weights the solver must be the
    PR 2 DP bit-for-bit — same objective float, same config — even when an
    incumbent is supplied."""
    cl_pipes = toy_cluster().pipelines
    obj = OPT.Objective(alpha=1.0, beta=0.05)
    for budget, lam_a, lam_b in [(10, 5.0, 8.0), (24, 22.0, 4.0),
                                 (40, 10.0, 10.0), (17, 3.3, 19.2),
                                 (6, 1.0, 1.0), (55, 25.0, 25.0)]:
        cl = ClusterModel("toy", cl_pipes, float(budget))
        base = OPT.solve_cluster(cl, [lam_a, lam_b], obj)
        new = OPT.solve_cluster(cl, [lam_a, lam_b], obj,
                                current=base.config if base.feasible else None,
                                switch_cost=0.0, sla_weights=(1.0, 1.0))
        assert new.feasible == base.feasible
        if base.feasible:
            assert new.objective == base.objective      # bit-identical
            assert new.cost == base.cost
            assert new.config == base.config


def test_hysteresis_holds_incumbent_against_marginal_gains():
    """A challenger must beat the incumbent by more than the transition
    cost: under a prohibitive switch cost the solver re-picks the held
    config wholesale (and reports zero switches)."""
    cl = toy_cluster(cores=30.0)
    obj = OPT.Objective(alpha=1.0, beta=0.02)
    inc = OPT.solve_cluster(cl, [10.0, 10.0], obj)
    assert inc.feasible
    # slightly perturbed rates the incumbent can still carry
    moved = OPT.solve_cluster(cl, [9.0, 11.0], obj, current=inc.config,
                              switch_cost=1e6)
    assert moved.feasible
    assert moved.n_switches == 0
    assert moved.config == inc.config
    # and with zero switch cost the solver is free to move off it
    free = OPT.solve_cluster(cl, [9.0, 11.0], obj, current=inc.config,
                             switch_cost=0.0)
    assert free.feasible
    assert free.objective >= moved.objective - 1e-9


def test_hysteresis_still_switches_when_incumbent_infeasible():
    """When the held config cannot carry the new rate there is no stay
    option: the solver must switch (and charge the penalty) rather than
    return the stale incumbent."""
    cl = toy_cluster(cores=40.0)
    obj = OPT.Objective(alpha=1.0, beta=0.02)
    inc = OPT.solve_cluster(cl, [2.0, 2.0], obj)
    assert inc.feasible
    sol = OPT.solve_cluster(cl, [24.0, 2.0], obj, current=inc.config,
                            switch_cost=1e6)
    assert sol.feasible
    assert sol.config.pipelines[0] != inc.config.pipelines[0]
    assert sol.n_switches >= 1
    assert sol.config.pipelines[0].supports(cl.pipelines[0], 24.0)


def test_switch_budget_caps_changes_per_interval():
    cl = toy_cluster(cores=40.0)
    obj = OPT.Objective(alpha=1.0, beta=0.02)
    inc = OPT.solve_cluster(cl, [2.0, 2.0], obj)
    assert inc.feasible
    # both pipelines want to move at the new rates
    free = OPT.solve_cluster(cl, [14.0, 14.0], obj, current=inc.config,
                             switch_cost=0.0)
    assert free.feasible and free.config.n_changes(inc.config) == 2
    capped = OPT.solve_cluster(cl, [14.0, 14.0], obj, current=inc.config,
                               switch_cost=0.0, switch_budget=1)
    # one pipeline's incumbent cannot carry 14 rps -> at most one change is
    # available for the genuinely-forced pipeline; the solve must either
    # fit the cap or be infeasible, never exceed it
    if capped.feasible:
        assert capped.n_switches <= 1
    # zero-budget: only feasible when every incumbent still carries its rate
    frozen = OPT.solve_cluster(cl, [14.0, 14.0], obj, current=inc.config,
                               switch_cost=0.0, switch_budget=0)
    if frozen.feasible:
        assert frozen.n_switches == 0
        assert frozen.config == inc.config


def test_sla_weights_shift_allocation_toward_heavy_pipeline():
    """Under a binding budget, weighting one pipeline must never lower its
    per-pipeline objective, and on this asymmetric cluster it strictly
    raises it (cores migrate toward the weighted pipeline)."""
    cl = toy_cluster(cores=18.0)
    obj = OPT.Objective(alpha=1.0, beta=0.02)
    lams = [12.0, 12.0]
    uniform = OPT.solve_cluster(cl, lams, obj)
    heavy_a = OPT.solve_cluster(cl, lams, obj, sla_weights=(8.0, 1.0))
    assert uniform.feasible and heavy_a.feasible
    assert heavy_a.per_pipeline[0].objective >= \
        uniform.per_pipeline[0].objective - 1e-9
    assert heavy_a.per_pipeline[0].objective > \
        uniform.per_pipeline[0].objective + 1e-6


def test_cluster_model_sla_weights_validation():
    pipes = toy_cluster().pipelines
    assert ClusterModel("w", pipes, 10.0).weights == (1.0, 1.0)
    assert ClusterModel("w", pipes, 10.0, (1.0, 2.0)).weights == (1.0, 2.0)
    with pytest.raises(ValueError):
        ClusterModel("w", pipes, 10.0, (1.0,))
    with pytest.raises(ValueError):
        ClusterModel("w", pipes, 10.0, (1.0, -2.0))


def test_cluster_default_weights_flow_into_solver():
    """solve_cluster defaults its SLA weights to the cluster's own."""
    pipes = toy_cluster().pipelines
    obj = OPT.Objective(alpha=1.0, beta=0.02)
    weighted_cl = ClusterModel("w", pipes, 22.0, (1.0, 8.0))
    plain_cl = ClusterModel("w", pipes, 22.0)
    implicit = OPT.solve_cluster(weighted_cl, [12.0, 12.0], obj)
    explicit = OPT.solve_cluster(plain_cl, [12.0, 12.0], obj,
                                 sla_weights=(1.0, 8.0))
    assert implicit.config == explicit.config
    assert implicit.objective == pytest.approx(explicit.objective)


def test_weighted_cluster_keeps_joint_split_commensurable():
    """cluster_split must weight its summed objective by the cluster's
    sla_weights exactly as cluster_ipa does, or the joint-vs-split
    dominance gate is vacuous on weighted clusters.  Dominance itself
    still holds: the split's combo lies in the joint's feasible set and
    per-pipeline argmaxes are weight-invariant."""
    pipes = toy_cluster().pipelines
    obj = OPT.Objective(alpha=1.0, beta=0.02)
    lams = [22.0, 4.0]
    for w in ((4.0, 1.0), (1.0, 4.0)):
        cl = ClusterModel("w", pipes, 24.0, w)
        joint = BL.cluster_ipa(cl, lams, obj)
        split = BL.cluster_split(cl, lams, "ipa", obj)
        assert joint.feasible and split.feasible
        assert joint.objective >= split.objective - 1e-9
        # the split's sum really is the weighted one
        assert split.objective == pytest.approx(
            sum(wi * s.objective for wi, s in zip(w, split.per_pipeline)))


def test_split_policies_reject_joint_only_knobs():
    """Silently ignoring the switch/weight knobs for split policies would
    benchmark the wrong experiment — they must be rejected loudly."""
    cl = toy_cluster(cores=30.0)
    rates = [np.full(20, 3.0), np.full(20, 3.0)]
    for kw in ({"switch_cost": 0.1}, {"switch_budget": 1},
               {"sla_weights": (2.0, 1.0)}):
        with pytest.raises(ValueError):
            AD.run_cluster_trace(cl, rates, policy="split_ipa", **kw)
    # adaptation_delay is simulator-side and legal for every policy
    res = AD.run_cluster_trace(cl, rates, policy="split_ipa",
                               obj=OPT.Objective(alpha=1.0, beta=0.02),
                               adaptation_delay=2.0, seed=1)
    assert res.completed + res.dropped == res.arrived


def test_cluster_config_n_changes():
    cl = toy_cluster()
    a = OPT.solve_cluster(cl, [5.0, 5.0], OPT.Objective()).config
    b = OPT.solve_cluster(cl, [20.0, 5.0], OPT.Objective()).config
    assert a.n_changes(a) == 0
    mixed = ClusterConfig((b.pipelines[0], a.pipelines[1]))
    assert mixed.n_changes(a) == (1 if b.pipelines[0] != a.pipelines[0] else 0)
    with pytest.raises(ValueError):
        a.n_changes(ClusterConfig((a.pipelines[0],)))


def test_joint_dominates_proportional_split():
    """The split's feasible set is a subset of the joint's: the knapsack
    objective can never be worse, and on asymmetric demand it is strictly
    better here."""
    cl = toy_cluster(cores=24.0)
    obj = OPT.Objective(alpha=1.0, beta=0.02)
    lams = [22.0, 4.0]                   # anti-correlated burst snapshot
    joint = BL.cluster_ipa(cl, lams, obj)
    split = BL.cluster_split(cl, lams, "ipa", obj)
    assert joint.feasible and split.feasible
    assert joint.objective >= split.objective - 1e-9
    assert joint.objective > split.objective + 1e-6


def test_pareto_frontier_is_strictly_improving():
    pipe = toy_pipeline("A")
    pts = OPT.pareto_frontier(pipe, 12.0, OPT.Objective(alpha=1.0, beta=0.05))
    assert pts, "frontier must be non-empty at a feasible rate"
    costs = [p.cost for p in pts]
    objs = [p.objective for p in pts]
    assert costs == sorted(costs)
    assert all(b > a for a, b in zip(costs, costs[1:]))
    assert all(b > a for a, b in zip(objs, objs[1:]))


def test_unbounded_budget_picks_per_pipeline_best():
    cl = ClusterModel("toy", toy_cluster().pipelines, float("inf"))
    obj = OPT.Objective(alpha=1.0, beta=0.05)
    sol = OPT.solve_cluster(cl, [10.0, 10.0], obj)
    for pipe, s in zip(cl.pipelines, sol.per_pipeline):
        best = OPT.pareto_frontier(pipe, 10.0, obj)[-1]
        assert s.objective == pytest.approx(best.objective)


# ---------------------------------------------------------------------------
# shared-pool replica ledger
# ---------------------------------------------------------------------------
def _fit_config(pipe, lam):
    sol = OPT.solve_capped(pipe, lam, OPT.Objective(alpha=0.0, beta=1.0))
    assert sol.feasible
    return sol.config


def test_reconfigure_over_budget_raises_and_changes_nothing():
    cl = toy_cluster(cores=8.0)
    cfg_a = _fit_config(cl.pipelines[0], 2.0)
    cfg_b = _fit_config(cl.pipelines[1], 2.0)
    sim = ClusterSimulator(cl, ClusterConfig((cfg_a, cfg_b)))
    before = sim.pipeline_config(0)
    # grow pipeline 0 far past what C minus pipeline 1's allocation allows
    big = PipelineConfig(tuple(
        StageConfig(sc.variant, sc.batch, sc.replicas + 50)
        for sc in cfg_a.stages))
    with pytest.raises(CoreBudgetExceeded):
        sim.reconfigure_pipeline(0, big)
    assert sim.pipeline_config(0) == before
    assert sim.allocated_cores <= cl.cores + 1e-9


def test_reconfigure_within_budget_updates_ledger():
    cl = toy_cluster(cores=40.0)
    cfg_a = _fit_config(cl.pipelines[0], 2.0)
    cfg_b = _fit_config(cl.pipelines[1], 2.0)
    sim = ClusterSimulator(cl, ClusterConfig((cfg_a, cfg_b)))
    start = sim.allocated_cores
    grown = PipelineConfig(tuple(
        StageConfig(sc.variant, sc.batch, sc.replicas + 1)
        for sc in cfg_a.stages))
    sim.reconfigure_pipeline(0, grown)
    assert sim.allocated_cores > start
    assert sim.current_config.fits(cl)


def test_initial_config_over_budget_rejected():
    cl = toy_cluster(cores=2.0)          # too small for two pipelines
    cfg_a = _fit_config(cl.pipelines[0], 10.0)
    cfg_b = _fit_config(cl.pipelines[1], 10.0)
    with pytest.raises(CoreBudgetExceeded):
        ClusterSimulator(cl, ClusterConfig((cfg_a, cfg_b)))


# ---------------------------------------------------------------------------
# shared event loop: per-pipeline isolation of metrics, shared clock
# ---------------------------------------------------------------------------
def test_two_pipelines_one_heap_conserve_requests_separately():
    cl = toy_cluster(cores=float("inf"))
    cfg_a = _fit_config(cl.pipelines[0], 12.0)
    cfg_b = _fit_config(cl.pipelines[1], 8.0)
    sim = ClusterSimulator(cl, ClusterConfig((cfg_a, cfg_b)))
    rng = np.random.default_rng(3)
    n_a, n_b = 120, 80
    for t in np.sort(rng.uniform(0, 10, n_a)):
        sim.inject(Request(arrival=float(t), sla=cl.pipelines[0].sla), 0)
    for t in np.sort(rng.uniform(0, 10, n_b)):
        sim.inject(Request(arrival=float(t), sla=cl.pipelines[1].sla), 1)
    sim.run_until(10 + 100 * max(sim.sla_of))
    ma, mb = sim.metrics_by_pipe
    assert ma.arrived == n_a and mb.arrived == n_b
    assert ma.completed + ma.dropped == n_a
    assert mb.completed + mb.dropped == n_b
    assert sim.queued == 0 and sim.in_service == 0
    assert len(ma.latencies) == ma.completed
    assert len(mb.latencies) == mb.completed


def test_per_pipeline_lam_est_independent():
    cl = toy_cluster(cores=float("inf"))
    sim = ClusterSimulator(cl, ClusterConfig((
        _fit_config(cl.pipelines[0], 5.0), _fit_config(cl.pipelines[1], 5.0))))
    sim.set_lam_est(0, 50.0)
    assert sim._lam_of == [50.0, 10.0]


# ---------------------------------------------------------------------------
# cluster adapter end-to-end
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def cluster_results():
    cl = toy_cluster(cores=26.0)
    t = np.arange(60, dtype=np.float64)
    # anti-correlated: A bursts first half, B second half
    r_a = np.clip(4.0 + 18.0 * np.exp(-((t - 10) % 60) / 8.0), 0.5, None)
    r_b = np.clip(4.0 + 18.0 * np.exp(-((t - 40) % 60) / 8.0), 0.5, None)
    obj = OPT.Objective(alpha=1.0, beta=0.02)
    return obj, {pol: AD.run_cluster_trace(cl, [r_a, r_b], policy=pol,
                                           obj=obj, seed=5)
                 for pol in ("ipa", "split_ipa", "split_fa2_low")}


def test_cluster_trace_conserves_requests(cluster_results):
    _, results = cluster_results
    for res in results.values():
        for r in res.per_pipeline:
            assert r.completed + r.dropped == r.arrived
        assert res.arrived == sum(r.arrived for r in res.per_pipeline)


def test_cluster_trace_stays_within_budget(cluster_results):
    _, results = cluster_results
    for res in results.values():
        for records in zip(*(r.intervals for r in res.per_pipeline)):
            assert sum(rec.cost for rec in records) <= res.budget + 1e-9


def test_joint_beats_split_on_objective_end_to_end(cluster_results):
    obj, results = cluster_results
    joint = results["ipa"].mean_objective(obj)
    assert joint >= results["split_ipa"].mean_objective(obj) - 1e-6
    assert joint >= results["split_fa2_low"].mean_objective(obj) - 1e-6


def test_joint_beats_split_on_pas_end_to_end(cluster_results):
    _, results = cluster_results
    assert results["ipa"].mean_pas > results["split_ipa"].mean_pas - 1e-9


def test_infeasible_hold_mid_transition_keeps_committed_target():
    """Regression (held-config drift): when the joint solver returns an
    infeasible plan while a reconfiguration is still rolling out, the
    adapter must hold the simulator's committed config — the in-flight
    transition target — NOT the pre-transition config the stages are still
    serving.  Re-proposing the serving config would silently cancel the
    committed rollout and drift the cost/PAS records."""
    cl = ClusterModel("one", (toy_pipeline("A"),), cores=1000.0)
    # interval 4 s, adaptation window 6 s: the t=8 decision is still in
    # flight at the t=12 boundary
    r = np.concatenate([np.full(4, 3.0), np.full(4, 12.0),
                        np.full(4, 60.0), np.full(4, 3.0)])
    res = AD.run_cluster_trace(cl, [r], policy="ipa",
                               obj=OPT.Objective(alpha=1.0, beta=0.02),
                               interval=4.0, seed=3, max_replicas=2,
                               adaptation_delay=6.0)
    recs = res.per_pipeline[0].intervals
    assert [rec.t for rec in recs] == [0.0, 4.0, 8.0, 12.0]
    # t=8: demand jumped to 12 -> a genuine change was committed
    assert recs[2].feasible
    assert recs[2].cost > recs[1].cost
    # t=12: 60 rps is infeasible at max_replicas=2 -> the adapter holds;
    # the held record must carry the committed (transition-target) cost,
    # not the pre-transition config's
    assert not recs[3].feasible
    assert recs[3].lam_hat == 60.0
    assert recs[3].cost == recs[2].cost
    # exactly one committed change, decided at t=8, applying at t=14 —
    # the hold must not have restarted (or cancelled) the rollout
    assert res.n_reconfigs == 1
    assert res.reconfig_log == [(8.0, 0, 14.0)]


def test_ragged_traces_supported():
    """Pipelines may stop receiving traffic at different times: a shorter
    trace must yield lam_true=0 intervals (not a zero-size .max() crash)
    and its demand estimate must drop to 0 so it stops competing for the
    shared pool."""
    cl = toy_cluster(cores=30.0)
    r_a = np.full(40, 5.0)
    r_b = np.full(15, 5.0)               # ends mid-run
    res = AD.run_cluster_trace(cl, [r_a, r_b],
                               policy="ipa",
                               obj=OPT.Objective(alpha=1.0, beta=0.05),
                               seed=2)
    assert len(res.per_pipeline[0].intervals) == \
        len(res.per_pipeline[1].intervals) == 4
    dead = res.per_pipeline[1].intervals[-1]
    assert dead.lam_true == 0.0
    assert dead.lam_hat == 0.0           # finished pipelines release demand
    for r in res.per_pipeline:
        assert r.completed + r.dropped == r.arrived
