"""Cluster co-scheduling: data model, shared-pool ledger, joint solver
(knapsack vs brute oracle), and the cluster adapter end-to-end."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import adapter as AD
from repro.core import baselines as BL
from repro.core import optimizer as OPT
from repro.core.cluster import (ClusterConfig, ClusterModel,
                                proportional_split)
from repro.core.pipeline import (ModelVariant, PipelineConfig, PipelineModel,
                                 StageConfig, StageModel)
from repro.core.simulator import ClusterSimulator, CoreBudgetExceeded
from repro.serving.request import Request


def toy_pipeline(name: str, l1: float = 0.05,
                 accs=(60.0, 75.0, 85.0)) -> PipelineModel:
    vs = tuple(
        ModelVariant(f"{name}_v{i}", a, 2 ** i,
                     (l1 * s * 0.002, l1 * s * 0.7, l1 * s * 0.3))
        for i, (a, s) in enumerate(zip(accs, (1.0, 1.7, 3.0))))
    return PipelineModel(name, (
        StageModel(f"{name}_s1", vs, sla=5 * l1 * 1.7, batch_choices=(1, 2, 4)),
        StageModel(f"{name}_s2", vs, sla=5 * l1 * 1.7, batch_choices=(1, 2, 4)),
    ))


def toy_cluster(cores: float = 40.0) -> ClusterModel:
    return ClusterModel("toy", (toy_pipeline("A"),
                                toy_pipeline("B", l1=0.03,
                                             accs=(55.0, 68.0, 90.0))), cores)


# ---------------------------------------------------------------------------
# data model
# ---------------------------------------------------------------------------
def test_cluster_config_cost_is_sum_of_pipelines():
    cl = toy_cluster()
    sol_a = OPT.solve_capped(cl.pipelines[0], 10.0, OPT.Objective())
    sol_b = OPT.solve_capped(cl.pipelines[1], 10.0, OPT.Objective())
    joint = ClusterConfig((sol_a.config, sol_b.config))
    assert joint.cost(cl) == pytest.approx(sol_a.cost + sol_b.cost)
    assert joint.fits(cl) == (sol_a.cost + sol_b.cost <= cl.cores + 1e-9)


def test_proportional_split_sums_to_budget():
    cl = toy_cluster(cores=30.0)
    shares = proportional_split(cl, [10.0, 20.0])
    assert sum(shares) == pytest.approx(30.0)
    assert shares[0] == pytest.approx(10.0)
    assert shares[1] == pytest.approx(20.0)
    # zero total demand: even split, not div-by-zero
    even = proportional_split(cl, [0.0, 0.0])
    assert even[0] == even[1] == pytest.approx(15.0)


# ---------------------------------------------------------------------------
# joint solver: knapsack arbitration vs brute-force oracle
# ---------------------------------------------------------------------------
@given(budget=st.integers(4, 60), lam_a=st.floats(1.0, 25.0),
       lam_b=st.floats(1.0, 25.0))
@settings(max_examples=20, deadline=None)
def test_knapsack_matches_brute_force(budget, lam_a, lam_b):
    cl = ClusterModel("toy", toy_cluster().pipelines, float(budget))
    obj = OPT.Objective(alpha=1.0, beta=0.05)
    k = OPT.solve_cluster(cl, [lam_a, lam_b], obj)
    b = OPT.solve_cluster_brute(cl, [lam_a, lam_b], obj)
    assert k.feasible == b.feasible
    if k.feasible:
        assert k.objective == pytest.approx(b.objective, rel=1e-9)
        assert k.cost <= budget + 1e-9
        assert k.config.fits(cl)


# ---------------------------------------------------------------------------
# switch-cost-aware arbitration: knapsack vs brute oracle, hysteresis,
# reconfiguration budget, SLA weights
# ---------------------------------------------------------------------------
def _incumbent_for(cl, lams, obj):
    """A plausible held config: the joint solve at a perturbed rate pair
    (so its replica counts are generally off the new rates' frontiers)."""
    sol = OPT.solve_cluster(cl, lams, obj)
    return sol.config if sol.feasible else None


@given(budget=st.integers(6, 55), lam_a=st.floats(1.0, 25.0),
       lam_b=st.floats(1.0, 25.0), switch_cost=st.floats(0.0, 4.0),
       switch_budget=st.sampled_from([-1, 0, 1, 2]))
@settings(max_examples=20, deadline=None)
def test_switch_knapsack_matches_brute_force(budget, lam_a, lam_b,
                                             switch_cost, switch_budget):
    """The switch-cost-aware DP must agree with the cross-product oracle
    that enumerates all configs and subtracts transition costs."""
    cl = ClusterModel("toy", toy_cluster().pipelines, float(budget))
    obj = OPT.Objective(alpha=1.0, beta=0.05)
    current = _incumbent_for(cl, [lam_a * 0.7 + 1.0, lam_b * 0.9 + 1.0], obj)
    sb = None if switch_budget < 0 else int(switch_budget)
    weights = (1.0, 1.7)
    k = OPT.solve_cluster(cl, [lam_a, lam_b], obj, current=current,
                          switch_cost=switch_cost, switch_budget=sb,
                          sla_weights=weights)
    b = OPT.solve_cluster_brute(cl, [lam_a, lam_b], obj, current=current,
                                switch_cost=switch_cost, switch_budget=sb,
                                sla_weights=weights)
    assert k.feasible == b.feasible
    if k.feasible:
        assert k.objective == pytest.approx(b.objective, rel=1e-9, abs=1e-9)
        assert k.cost <= budget + 1e-9
        assert k.config.fits(cl)
        if sb is not None:
            assert k.n_switches <= sb
        if current is not None:
            assert k.n_switches == k.config.n_changes(current)


def test_switch_free_solver_bit_identical_to_pr2():
    """With switch cost 0 and uniform SLA weights the solver must be the
    PR 2 DP bit-for-bit — same objective float, same config — even when an
    incumbent is supplied."""
    cl_pipes = toy_cluster().pipelines
    obj = OPT.Objective(alpha=1.0, beta=0.05)
    for budget, lam_a, lam_b in [(10, 5.0, 8.0), (24, 22.0, 4.0),
                                 (40, 10.0, 10.0), (17, 3.3, 19.2),
                                 (6, 1.0, 1.0), (55, 25.0, 25.0)]:
        cl = ClusterModel("toy", cl_pipes, float(budget))
        base = OPT.solve_cluster(cl, [lam_a, lam_b], obj)
        new = OPT.solve_cluster(cl, [lam_a, lam_b], obj,
                                current=base.config if base.feasible else None,
                                switch_cost=0.0, sla_weights=(1.0, 1.0))
        assert new.feasible == base.feasible
        if base.feasible:
            assert new.objective == base.objective      # bit-identical
            assert new.cost == base.cost
            assert new.config == base.config


def test_hysteresis_holds_incumbent_against_marginal_gains():
    """A challenger must beat the incumbent by more than the transition
    cost: under a prohibitive switch cost the solver re-picks the held
    config wholesale (and reports zero switches)."""
    cl = toy_cluster(cores=30.0)
    obj = OPT.Objective(alpha=1.0, beta=0.02)
    inc = OPT.solve_cluster(cl, [10.0, 10.0], obj)
    assert inc.feasible
    # slightly perturbed rates the incumbent can still carry
    moved = OPT.solve_cluster(cl, [9.0, 11.0], obj, current=inc.config,
                              switch_cost=1e6)
    assert moved.feasible
    assert moved.n_switches == 0
    assert moved.config == inc.config
    # and with zero switch cost the solver is free to move off it
    free = OPT.solve_cluster(cl, [9.0, 11.0], obj, current=inc.config,
                             switch_cost=0.0)
    assert free.feasible
    assert free.objective >= moved.objective - 1e-9


def test_hysteresis_still_switches_when_incumbent_infeasible():
    """When the held config cannot carry the new rate there is no stay
    option: the solver must switch (and charge the penalty) rather than
    return the stale incumbent."""
    cl = toy_cluster(cores=40.0)
    obj = OPT.Objective(alpha=1.0, beta=0.02)
    inc = OPT.solve_cluster(cl, [2.0, 2.0], obj)
    assert inc.feasible
    sol = OPT.solve_cluster(cl, [24.0, 2.0], obj, current=inc.config,
                            switch_cost=1e6)
    assert sol.feasible
    assert sol.config.pipelines[0] != inc.config.pipelines[0]
    assert sol.n_switches >= 1
    assert sol.config.pipelines[0].supports(cl.pipelines[0], 24.0)


def test_switch_budget_caps_changes_per_interval():
    cl = toy_cluster(cores=40.0)
    obj = OPT.Objective(alpha=1.0, beta=0.02)
    inc = OPT.solve_cluster(cl, [2.0, 2.0], obj)
    assert inc.feasible
    # both pipelines want to move at the new rates
    free = OPT.solve_cluster(cl, [14.0, 14.0], obj, current=inc.config,
                             switch_cost=0.0)
    assert free.feasible and free.config.n_changes(inc.config) == 2
    capped = OPT.solve_cluster(cl, [14.0, 14.0], obj, current=inc.config,
                               switch_cost=0.0, switch_budget=1)
    # one pipeline's incumbent cannot carry 14 rps -> at most one change is
    # available for the genuinely-forced pipeline; the solve must either
    # fit the cap or be infeasible, never exceed it
    if capped.feasible:
        assert capped.n_switches <= 1
    # zero-budget: only feasible when every incumbent still carries its rate
    frozen = OPT.solve_cluster(cl, [14.0, 14.0], obj, current=inc.config,
                               switch_cost=0.0, switch_budget=0)
    if frozen.feasible:
        assert frozen.n_switches == 0
        assert frozen.config == inc.config


def test_sla_weights_shift_allocation_toward_heavy_pipeline():
    """Under a binding budget, weighting one pipeline must never lower its
    per-pipeline objective, and on this asymmetric cluster it strictly
    raises it (cores migrate toward the weighted pipeline)."""
    cl = toy_cluster(cores=18.0)
    obj = OPT.Objective(alpha=1.0, beta=0.02)
    lams = [12.0, 12.0]
    uniform = OPT.solve_cluster(cl, lams, obj)
    heavy_a = OPT.solve_cluster(cl, lams, obj, sla_weights=(8.0, 1.0))
    assert uniform.feasible and heavy_a.feasible
    assert heavy_a.per_pipeline[0].objective >= \
        uniform.per_pipeline[0].objective - 1e-9
    assert heavy_a.per_pipeline[0].objective > \
        uniform.per_pipeline[0].objective + 1e-6


def test_cluster_model_sla_weights_validation():
    pipes = toy_cluster().pipelines
    assert ClusterModel("w", pipes, 10.0).weights == (1.0, 1.0)
    assert ClusterModel("w", pipes, 10.0, (1.0, 2.0)).weights == (1.0, 2.0)
    with pytest.raises(ValueError):
        ClusterModel("w", pipes, 10.0, (1.0,))
    with pytest.raises(ValueError):
        ClusterModel("w", pipes, 10.0, (1.0, -2.0))


def test_cluster_default_weights_flow_into_solver():
    """solve_cluster defaults its SLA weights to the cluster's own."""
    pipes = toy_cluster().pipelines
    obj = OPT.Objective(alpha=1.0, beta=0.02)
    weighted_cl = ClusterModel("w", pipes, 22.0, (1.0, 8.0))
    plain_cl = ClusterModel("w", pipes, 22.0)
    implicit = OPT.solve_cluster(weighted_cl, [12.0, 12.0], obj)
    explicit = OPT.solve_cluster(plain_cl, [12.0, 12.0], obj,
                                 sla_weights=(1.0, 8.0))
    assert implicit.config == explicit.config
    assert implicit.objective == pytest.approx(explicit.objective)


def test_weighted_cluster_keeps_joint_split_commensurable():
    """cluster_split must weight its summed objective by the cluster's
    sla_weights exactly as cluster_ipa does, or the joint-vs-split
    dominance gate is vacuous on weighted clusters.  Dominance itself
    still holds: the split's combo lies in the joint's feasible set and
    per-pipeline argmaxes are weight-invariant."""
    pipes = toy_cluster().pipelines
    obj = OPT.Objective(alpha=1.0, beta=0.02)
    lams = [22.0, 4.0]
    for w in ((4.0, 1.0), (1.0, 4.0)):
        cl = ClusterModel("w", pipes, 24.0, w)
        joint = BL.cluster_ipa(cl, lams, obj)
        split = BL.cluster_split(cl, lams, "ipa", obj)
        assert joint.feasible and split.feasible
        assert joint.objective >= split.objective - 1e-9
        # the split's sum really is the weighted one
        assert split.objective == pytest.approx(
            sum(wi * s.objective for wi, s in zip(w, split.per_pipeline)))


def test_split_policies_reject_joint_only_knobs():
    """Silently ignoring the switch/weight knobs for split policies would
    benchmark the wrong experiment — they must be rejected loudly."""
    cl = toy_cluster(cores=30.0)
    rates = [np.full(20, 3.0), np.full(20, 3.0)]
    for kw in ({"switch_cost": 0.1}, {"switch_budget": 1},
               {"sla_weights": (2.0, 1.0)}):
        with pytest.raises(ValueError):
            AD.run_cluster_trace(cl, rates, policy="split_ipa", **kw)
    # adaptation_delay is simulator-side and legal for every policy
    res = AD.run_cluster_trace(cl, rates, policy="split_ipa",
                               obj=OPT.Objective(alpha=1.0, beta=0.02),
                               adaptation_delay=2.0, seed=1)
    assert res.completed + res.dropped == res.arrived


# ---------------------------------------------------------------------------
# transition-overlap-aware arbitration: during a §5.3 adaptation window a
# changed pipeline holds max(old, new) cores (the old fleet serves while the
# new one provisions), and both the solver and the ledger must account for it
# ---------------------------------------------------------------------------
@given(budget=st.integers(8, 55), lam_a=st.floats(1.0, 25.0),
       lam_b=st.floats(1.0, 25.0), switch_cost=st.floats(0.0, 2.0),
       switch_budget=st.sampled_from([-1, 1, 2]))
@settings(max_examples=20, deadline=None)
def test_overlap_knapsack_matches_brute_force(budget, lam_a, lam_b,
                                              switch_cost, switch_budget):
    """The overlap-aware DP (knapsack weights = max(old, new)) must agree
    with the cross-product oracle evaluating the same transition charge,
    including when the serving config differs from the committed incumbent
    (a window already in flight at decision time)."""
    cl = ClusterModel("toy", toy_cluster().pipelines, float(budget))
    obj = OPT.Objective(alpha=1.0, beta=0.05)
    current = _incumbent_for(cl, [lam_a * 0.7 + 1.0, lam_b * 0.9 + 1.0], obj)
    serving = _incumbent_for(cl, [lam_a * 0.5 + 2.0, lam_b * 1.1 + 0.5], obj)
    sb = None if switch_budget < 0 else int(switch_budget)
    k = OPT.solve_cluster(cl, [lam_a, lam_b], obj, current=current,
                          switch_cost=switch_cost, switch_budget=sb,
                          overlap=True, serving=serving)
    b = OPT.solve_cluster_brute(cl, [lam_a, lam_b], obj, current=current,
                                switch_cost=switch_cost, switch_budget=sb,
                                overlap=True, serving=serving)
    assert k.feasible == b.feasible
    if k.feasible:
        assert k.objective == pytest.approx(b.objective, rel=1e-9, abs=1e-9)
        assert k.config.fits(cl)
        if current is not None:
            old = serving if serving is not None else current
            assert k.config.transition_cost(cl, old) <= budget + 1e-9


def test_overlap_off_or_without_incumbent_is_the_pr3_path():
    """``overlap=False`` (the default, what the adapter passes at zero
    adaptation delay) must be bit-for-bit the PR 3 solver, and
    ``overlap=True`` with no incumbent is a no-op (nothing old to overlap
    with)."""
    cl = toy_cluster(cores=24.0)
    obj = OPT.Objective(alpha=1.0, beta=0.02)
    inc = OPT.solve_cluster(cl, [10.0, 10.0], obj)
    assert inc.feasible
    base = OPT.solve_cluster(cl, [8.0, 14.0], obj, current=inc.config,
                             switch_cost=0.1)
    off = OPT.solve_cluster(cl, [8.0, 14.0], obj, current=inc.config,
                            switch_cost=0.1, overlap=False)
    assert off.objective == base.objective           # bit-identical
    assert off.config == base.config
    plain = OPT.solve_cluster(cl, [8.0, 14.0], obj)
    noop = OPT.solve_cluster(cl, [8.0, 14.0], obj, overlap=True)
    assert noop.objective == plain.objective
    assert noop.config == plain.config
    # a serving config of the wrong shape is rejected loudly
    with pytest.raises(ValueError):
        OPT.solve_cluster(cl, [8.0, 14.0], obj, current=inc.config,
                          overlap=True,
                          serving=ClusterConfig((inc.config.pipelines[0],)))


def test_revert_to_serving_is_a_free_candidate():
    """Mid-window the still-serving config can be re-proposed for free —
    the simulator cancels the pending rollout without a new window — so
    the solver must not charge it switch_cost: under a prohibitive
    penalty, with a committed rollout that turned out wrong, the solver
    reverts to the serving config rather than holding the bad incumbent,
    and reports zero charged switches."""
    cl = toy_cluster(cores=40.0)
    obj = OPT.Objective(alpha=1.0, beta=0.02)
    serving_sol = OPT.solve_cluster(cl, [10.0, 10.0], obj)
    committed_sol = OPT.solve_cluster(cl, [2.0, 2.0], obj,
                                      budget=10.0)    # a cheap rollout
    assert serving_sol.feasible and committed_sol.feasible
    assert serving_sol.config != committed_sol.config
    # demand stays at 10 rps: the cheap committed target is the mistake,
    # the serving config is (near-)optimal.  A prohibitive switch cost
    # must not trap the solver on the committed incumbent.
    sol = OPT.solve_cluster(cl, [10.0, 10.0], obj,
                            current=committed_sol.config, switch_cost=1e6,
                            overlap=True, serving=serving_sol.config)
    assert sol.feasible
    assert sol.n_switches == 0
    assert sol.config == serving_sol.config
    assert sol.objective > -1e5          # nothing was charged the penalty
    # and the oracle agrees on the semantics
    b = OPT.solve_cluster_brute(cl, [10.0, 10.0], obj,
                                current=committed_sol.config,
                                switch_cost=1e6,
                                overlap=True, serving=serving_sol.config)
    assert b.feasible
    assert b.objective == pytest.approx(sol.objective, rel=1e-9, abs=1e-9)
    # a revert does not consume a switch-budget slot either
    frozen = OPT.solve_cluster(cl, [10.0, 10.0], obj,
                               current=committed_sol.config,
                               switch_cost=0.0, switch_budget=0,
                               overlap=True, serving=serving_sol.config)
    assert frozen.feasible and frozen.n_switches == 0


def test_overlap_charges_serving_not_committed():
    """Mid-window the cores are held by the *serving* fleet: with a large
    serving cost the overlap-aware solve must become infeasible at a budget
    the committed-cost view would accept."""
    cl = toy_cluster(cores=24.0)
    obj = OPT.Objective(alpha=1.0, beta=0.02)
    small = OPT.solve_cluster(cl, [2.0, 2.0], obj)
    assert small.feasible and small.cost <= 20.0
    # a serving config pinned at heavy variants, far over the committed cost
    heavy = ClusterConfig(tuple(
        PipelineConfig(tuple(StageConfig(st_m.heaviest.name, 1, 3)
                             for st_m in pipe.stages))
        for pipe in cl.pipelines))
    assert heavy.cost(cl) > cl.cores
    sol = OPT.solve_cluster(cl, [2.0, 2.0], obj, current=small.config,
                            switch_cost=0.1, overlap=True, serving=heavy)
    # whatever is chosen, the serving fleets alone exceed C through any
    # window, so no transition plan can fit
    assert not sol.feasible


def _explicit(pipe, variant_i: int, replicas: int) -> PipelineConfig:
    return PipelineConfig(tuple(
        StageConfig(st_m.variants[variant_i].name, 1, replicas)
        for st_m in pipe.stages))


def test_ledger_holds_transition_charge_until_apply():
    """Golden deferred-grant run: a downsizer's freed cores must not be
    grantable until its window closes.  Pre-overlap this exact sequence was
    admissible (the post-transition joint config fits C), and the serving
    fleets transiently held 24 of 20 cores."""
    cl = toy_cluster(cores=20.0)
    a, b = cl.pipelines
    a_big, a_small = _explicit(a, 2, 2), _explicit(a, 0, 1)   # 16 -> 2 cores
    b_small, b_big = _explicit(b, 0, 1), _explicit(b, 2, 1)   # 2 -> 8 cores
    sim = ClusterSimulator(cl, ClusterConfig((a_big, b_small)),
                           adaptation_delay=5.0)
    assert sim.allocated_cores == 18.0
    # the post-transition joint target fits C — PR 3 admitted it wholesale
    flipped = ClusterConfig((a_small, b_big))
    assert flipped.fits(cl)
    assert flipped.transition_cost(cl, sim.current_config) == 24.0
    assert not sim.fits_transition(flipped)
    with pytest.raises(CoreBudgetExceeded):
        sim.reconfigure(flipped)                 # rejected at decision time
    # staged: the downsize alone is always admissible (its charge is the
    # old cost it already holds) ...
    sim.reconfigure_pipeline(0, a_small)
    assert sim.pipeline_config(0) == a_small     # committed
    assert sim.serving_config(0) == a_big        # old fleet serves the window
    assert sim.allocated_cores == 18.0           # charge held at max(16, 2)
    assert sim.serving_cores == 18.0
    # ... but the freed cores are not grantable mid-window
    with pytest.raises(CoreBudgetExceeded):
        sim.reconfigure_pipeline(1, b_big)
    sim.run_until(6.0)                           # a's window closes at 5.0
    assert sim.serving_config(0) == a_small
    assert sim.allocated_cores == 4.0            # 2 + 2: charge settled
    sim.reconfigure_pipeline(1, b_big)           # deferred grant now fits
    sim.run_until(12.0)
    assert sim.serving_config(1) == b_big
    assert sim.allocated_cores == 10.0
    assert sim.reconfig_log == [(0.0, 0, 5.0), (6.0, 1, 11.0)]
    # the witness: serving fleets never exceeded C at any instant
    assert sim.peak_serving_cores <= cl.cores + 1e-9


def test_supersede_mid_window_charges_serving_not_stale_target():
    """A decision superseding another inside its window re-charges against
    what is *serving* (the original old fleet) — the superseded target's
    fleet never started, so its charge must be released."""
    cl = toy_cluster(cores=20.0)
    a, b = cl.pipelines
    sim = ClusterSimulator(cl, ClusterConfig((_explicit(a, 2, 2),   # 16
                                              _explicit(b, 0, 1))),  # 2
                           adaptation_delay=5.0)
    sim.reconfigure_pipeline(0, _explicit(a, 0, 1))   # 16 -> 2, charge 16
    sim.run_until(2.0)
    sim.reconfigure_pipeline(0, _explicit(a, 1, 1))   # supersede: target 4
    assert sim._alloc[0] == 16.0                      # still max(serving=16, 4)
    # cancel back to the serving config releases the transition entirely
    sim.reconfigure_pipeline(0, _explicit(a, 2, 2))
    assert sim._alloc[0] == 16.0
    assert sim.pipeline_config(0) == _explicit(a, 2, 2)
    sim.run_until(10.0)
    assert sim.serving_config(0) == _explicit(a, 2, 2)  # rollout cancelled
    assert sim.allocated_cores == 18.0


def test_zero_delay_ledger_unchanged():
    """With adaptation_delay == 0 there is no window: the ledger charges
    the new cost immediately (the PR 2/3 behaviour, pinned)."""
    cl = toy_cluster(cores=20.0)
    a, b = cl.pipelines
    sim = ClusterSimulator(cl, ClusterConfig((_explicit(a, 2, 2),
                                              _explicit(b, 0, 1))))
    sim.reconfigure(ClusterConfig((_explicit(a, 0, 1), _explicit(b, 2, 1))))
    assert sim.allocated_cores == 10.0
    assert sim.serving_cores == 10.0
    assert sim.serving_config(0) == _explicit(a, 0, 1)  # applied immediately
    assert sim.peak_serving_cores == 18.0               # the initial config


def test_zero_delay_joint_swap_is_atomic_for_the_peak_witness():
    """A zero-delay joint reconfigure is semantically atomic: a swap that
    grows a lower-index pipeline before the higher-index one shrinks must
    not record the mid-loop partial sum (a state that never existed) in
    peak_serving_cores."""
    cl = toy_cluster(cores=20.0)
    a, b = cl.pipelines
    sim = ClusterSimulator(cl, ClusterConfig((_explicit(a, 0, 1),   # 2
                                              _explicit(b, 2, 2))))  # 16
    sim.reconfigure(ClusterConfig((_explicit(a, 2, 2),    # grow A first ...
                                   _explicit(b, 0, 1))))  # ... then shrink B
    assert sim.allocated_cores == 18.0
    assert sim.peak_serving_cores == 18.0   # not the fictitious 32 mid-swap
    assert sim.peak_serving_cores <= cl.cores + 1e-9


@given(seed=st.integers(0, 9999))
@settings(max_examples=8, deadline=None)
def test_serving_cost_never_exceeds_budget_on_bursty_traces(seed):
    """The tentpole invariant: with adaptation_delay > 0, the cores held
    by the serving fleets never exceed C at any instant — and therefore
    the realized (blended) per-interval cost records sum within C too —
    on random bursty traces, for the joint policy and the static split."""
    rng = np.random.default_rng(seed)
    cl = toy_cluster(cores=float(rng.integers(14, 30)))
    t = np.arange(50, dtype=np.float64)
    traces = []
    for _ in range(2):
        phase = rng.uniform(0.0, 40.0)
        burst = rng.uniform(6.0, 20.0) * np.exp(
            -((t - phase) % 40.0) / rng.uniform(4.0, 12.0))
        traces.append(np.clip(2.0 + burst + rng.normal(0.0, 0.3, 50),
                              0.5, None))
    for policy, kw in (("ipa", {"switch_cost": 0.05}), ("split_ipa", {})):
        res = AD.run_cluster_trace(cl, traces, policy=policy,
                                   obj=OPT.Objective(alpha=1.0, beta=0.02),
                                   seed=seed % 7, adaptation_delay=6.0, **kw)
        assert res.peak_serving_cores <= cl.cores + 1e-9, policy
        for recs in zip(*(r.intervals for r in res.per_pipeline)):
            assert sum(rec.cost for rec in recs) <= cl.cores + 1e-9, policy


def test_split_policy_stages_opposite_resizes_instead_of_freezing():
    """Regression (staged admission): a split policy's sub-solvers propose
    a simultaneous shrink+grow on an anti-correlated demand flip; its
    combined transition charge max(old,new)+max(old,new) never fits C, so
    a plain hold-all admission would freeze the stale allocation forever.
    The adapter must stage it — downsize now, grow once the freed cores
    leave their window — and converge to the flipped allocation, without
    ever letting serving cost exceed C."""
    cl = toy_cluster(cores=20.0)
    flip = 20
    r_a = np.concatenate([np.full(flip, 20.0), np.full(50, 4.0)])
    r_b = np.concatenate([np.full(flip, 4.0), np.full(50, 20.0)])
    res = AD.run_cluster_trace(cl, [r_a, r_b], policy="split_ipa",
                               obj=OPT.Objective(alpha=1.0, beta=0.02),
                               seed=4, adaptation_delay=8.0)
    assert res.peak_serving_cores <= cl.cores + 1e-9
    rec_a = res.per_pipeline[0].intervals
    rec_b = res.per_pipeline[1].intervals
    # before the flip A holds the lion's share ...
    assert rec_a[1].cost > rec_b[1].cost
    # ... and after it the allocation must actually flip (the staged path:
    # A's downsize is admitted first, B's grow lands a boundary later)
    assert rec_b[-1].cost > rec_a[-1].cost
    # at least one post-flip interval applied a proposal for B
    assert any(rec.feasible for rec in rec_b if rec.t >= flip)
    # and the staging order is visible in the decision log: the donor's
    # downsize is decided strictly before the receiver's grow
    a_dec = [t for t, p, _ in res.reconfig_log if p == 0]
    b_dec = [t for t, p, _ in res.reconfig_log if p == 1]
    assert a_dec and b_dec
    assert min(b_dec) > min(a_dec)


def test_interval_cost_records_blend_time_weighted():
    """Regression: during an adaptation window the interval cost record is
    the realized time-weighted blend of old and new cost (it used to
    report the committed config's cost for the whole interval)."""
    cl = ClusterModel("one", (toy_pipeline("A"),), cores=1000.0)
    # rate step at t=8, first seen at the t=12 boundary; interval 4 s,
    # window 6 s -> the rollout decided at 12 applies at 18, so the t=12
    # interval is fully old and the t=16 interval is a half/half blend
    r = np.concatenate([np.full(8, 3.0), np.full(16, 12.0)])
    res = AD.run_cluster_trace(cl, [r], policy="ipa",
                               obj=OPT.Objective(alpha=1.0, beta=0.02),
                               interval=4.0, seed=3, max_replicas=2,
                               switch_cost=0.01, adaptation_delay=6.0)
    recs = res.per_pipeline[0].intervals
    assert [rec.t for rec in recs] == [0.0, 4.0, 8.0, 12.0, 16.0, 20.0]
    assert res.reconfig_log == [(12.0, 0, 18.0)]
    old_cost, new_cost = recs[2].cost, recs[5].cost
    assert new_cost > old_cost                   # the step forced a grow
    assert recs[3].cost == pytest.approx(old_cost)            # fully in window
    assert recs[4].cost == pytest.approx(0.5 * old_cost + 0.5 * new_cost)
    # PAS blends with the same fraction (realized semantics match)
    assert recs[4].pas == pytest.approx(0.5 * recs[3].pas + 0.5 * recs[5].pas)


def test_cluster_config_n_changes():
    cl = toy_cluster()
    a = OPT.solve_cluster(cl, [5.0, 5.0], OPT.Objective()).config
    b = OPT.solve_cluster(cl, [20.0, 5.0], OPT.Objective()).config
    assert a.n_changes(a) == 0
    mixed = ClusterConfig((b.pipelines[0], a.pipelines[1]))
    assert mixed.n_changes(a) == (1 if b.pipelines[0] != a.pipelines[0] else 0)
    with pytest.raises(ValueError):
        a.n_changes(ClusterConfig((a.pipelines[0],)))


def test_joint_dominates_proportional_split():
    """The split's feasible set is a subset of the joint's: the knapsack
    objective can never be worse, and on asymmetric demand it is strictly
    better here."""
    cl = toy_cluster(cores=24.0)
    obj = OPT.Objective(alpha=1.0, beta=0.02)
    lams = [22.0, 4.0]                   # anti-correlated burst snapshot
    joint = BL.cluster_ipa(cl, lams, obj)
    split = BL.cluster_split(cl, lams, "ipa", obj)
    assert joint.feasible and split.feasible
    assert joint.objective >= split.objective - 1e-9
    assert joint.objective > split.objective + 1e-6


def test_pareto_frontier_is_strictly_improving():
    pipe = toy_pipeline("A")
    pts = OPT.pareto_frontier(pipe, 12.0, OPT.Objective(alpha=1.0, beta=0.05))
    assert pts, "frontier must be non-empty at a feasible rate"
    costs = [p.cost for p in pts]
    objs = [p.objective for p in pts]
    assert costs == sorted(costs)
    assert all(b > a for a, b in zip(costs, costs[1:]))
    assert all(b > a for a, b in zip(objs, objs[1:]))


def test_unbounded_budget_picks_per_pipeline_best():
    cl = ClusterModel("toy", toy_cluster().pipelines, float("inf"))
    obj = OPT.Objective(alpha=1.0, beta=0.05)
    sol = OPT.solve_cluster(cl, [10.0, 10.0], obj)
    for pipe, s in zip(cl.pipelines, sol.per_pipeline):
        best = OPT.pareto_frontier(pipe, 10.0, obj)[-1]
        assert s.objective == pytest.approx(best.objective)


# ---------------------------------------------------------------------------
# shared-pool replica ledger
# ---------------------------------------------------------------------------
def _fit_config(pipe, lam):
    sol = OPT.solve_capped(pipe, lam, OPT.Objective(alpha=0.0, beta=1.0))
    assert sol.feasible
    return sol.config


def test_reconfigure_over_budget_raises_and_changes_nothing():
    cl = toy_cluster(cores=8.0)
    cfg_a = _fit_config(cl.pipelines[0], 2.0)
    cfg_b = _fit_config(cl.pipelines[1], 2.0)
    sim = ClusterSimulator(cl, ClusterConfig((cfg_a, cfg_b)))
    before = sim.pipeline_config(0)
    # grow pipeline 0 far past what C minus pipeline 1's allocation allows
    big = PipelineConfig(tuple(
        StageConfig(sc.variant, sc.batch, sc.replicas + 50)
        for sc in cfg_a.stages))
    with pytest.raises(CoreBudgetExceeded):
        sim.reconfigure_pipeline(0, big)
    assert sim.pipeline_config(0) == before
    assert sim.allocated_cores <= cl.cores + 1e-9


def test_reconfigure_within_budget_updates_ledger():
    cl = toy_cluster(cores=40.0)
    cfg_a = _fit_config(cl.pipelines[0], 2.0)
    cfg_b = _fit_config(cl.pipelines[1], 2.0)
    sim = ClusterSimulator(cl, ClusterConfig((cfg_a, cfg_b)))
    start = sim.allocated_cores
    grown = PipelineConfig(tuple(
        StageConfig(sc.variant, sc.batch, sc.replicas + 1)
        for sc in cfg_a.stages))
    sim.reconfigure_pipeline(0, grown)
    assert sim.allocated_cores > start
    assert sim.current_config.fits(cl)


def test_initial_config_over_budget_rejected():
    cl = toy_cluster(cores=2.0)          # too small for two pipelines
    cfg_a = _fit_config(cl.pipelines[0], 10.0)
    cfg_b = _fit_config(cl.pipelines[1], 10.0)
    with pytest.raises(CoreBudgetExceeded):
        ClusterSimulator(cl, ClusterConfig((cfg_a, cfg_b)))


# ---------------------------------------------------------------------------
# shared event loop: per-pipeline isolation of metrics, shared clock
# ---------------------------------------------------------------------------
def test_two_pipelines_one_heap_conserve_requests_separately():
    cl = toy_cluster(cores=float("inf"))
    cfg_a = _fit_config(cl.pipelines[0], 12.0)
    cfg_b = _fit_config(cl.pipelines[1], 8.0)
    sim = ClusterSimulator(cl, ClusterConfig((cfg_a, cfg_b)))
    rng = np.random.default_rng(3)
    n_a, n_b = 120, 80
    for t in np.sort(rng.uniform(0, 10, n_a)):
        sim.inject(Request(arrival=float(t), sla=cl.pipelines[0].sla), 0)
    for t in np.sort(rng.uniform(0, 10, n_b)):
        sim.inject(Request(arrival=float(t), sla=cl.pipelines[1].sla), 1)
    sim.run_until(10 + 100 * max(sim.sla_of))
    ma, mb = sim.metrics_by_pipe
    assert ma.arrived == n_a and mb.arrived == n_b
    assert ma.completed + ma.dropped == n_a
    assert mb.completed + mb.dropped == n_b
    assert sim.queued == 0 and sim.in_service == 0
    assert len(ma.latencies) == ma.completed
    assert len(mb.latencies) == mb.completed


def test_per_pipeline_lam_est_independent():
    cl = toy_cluster(cores=float("inf"))
    sim = ClusterSimulator(cl, ClusterConfig((
        _fit_config(cl.pipelines[0], 5.0), _fit_config(cl.pipelines[1], 5.0))))
    sim.set_lam_est(0, 50.0)
    assert sim._lam_of == [50.0, 10.0]


# ---------------------------------------------------------------------------
# cluster adapter end-to-end
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def cluster_results():
    cl = toy_cluster(cores=26.0)
    t = np.arange(60, dtype=np.float64)
    # anti-correlated: A bursts first half, B second half
    r_a = np.clip(4.0 + 18.0 * np.exp(-((t - 10) % 60) / 8.0), 0.5, None)
    r_b = np.clip(4.0 + 18.0 * np.exp(-((t - 40) % 60) / 8.0), 0.5, None)
    obj = OPT.Objective(alpha=1.0, beta=0.02)
    return obj, {pol: AD.run_cluster_trace(cl, [r_a, r_b], policy=pol,
                                           obj=obj, seed=5)
                 for pol in ("ipa", "split_ipa", "split_fa2_low")}


def test_cluster_trace_conserves_requests(cluster_results):
    _, results = cluster_results
    for res in results.values():
        for r in res.per_pipeline:
            assert r.completed + r.dropped == r.arrived
        assert res.arrived == sum(r.arrived for r in res.per_pipeline)


def test_cluster_trace_stays_within_budget(cluster_results):
    _, results = cluster_results
    for res in results.values():
        for records in zip(*(r.intervals for r in res.per_pipeline)):
            assert sum(rec.cost for rec in records) <= res.budget + 1e-9


def test_joint_beats_split_on_objective_end_to_end(cluster_results):
    obj, results = cluster_results
    joint = results["ipa"].mean_objective(obj)
    assert joint >= results["split_ipa"].mean_objective(obj) - 1e-6
    assert joint >= results["split_fa2_low"].mean_objective(obj) - 1e-6


def test_joint_beats_split_on_pas_end_to_end(cluster_results):
    _, results = cluster_results
    assert results["ipa"].mean_pas > results["split_ipa"].mean_pas - 1e-9


def test_infeasible_hold_mid_transition_keeps_committed_target():
    """Regression (held-config drift): when the joint solver returns an
    infeasible plan while a reconfiguration is still rolling out, the
    adapter must hold the simulator's committed config — the in-flight
    transition target — NOT the pre-transition config the stages are still
    serving.  Re-proposing the serving config would silently cancel the
    committed rollout and drift the cost/PAS records."""
    cl = ClusterModel("one", (toy_pipeline("A"),), cores=1000.0)
    # interval 4 s, adaptation window 6 s: the t=8 decision is still in
    # flight at the t=12 boundary
    r = np.concatenate([np.full(4, 3.0), np.full(4, 12.0),
                        np.full(4, 60.0), np.full(4, 3.0)])
    res = AD.run_cluster_trace(cl, [r], policy="ipa",
                               obj=OPT.Objective(alpha=1.0, beta=0.02),
                               interval=4.0, seed=3, max_replicas=2,
                               adaptation_delay=6.0)
    recs = res.per_pipeline[0].intervals
    assert [rec.t for rec in recs] == [0.0, 4.0, 8.0, 12.0]
    # t=8: demand jumped to 12 -> a genuine change was committed; the whole
    # [8,12) interval sits inside the 6 s window (applies at t=14), so the
    # realized cost record is still the serving (old) config's
    assert recs[2].feasible
    assert recs[2].cost == recs[1].cost
    # t=12: 60 rps is infeasible at max_replicas=2 -> the adapter holds the
    # committed (transition-target) config, whose rollout lands at t=14:
    # the realized record blends old and grown cost half/half and must
    # show the grow — a cancelled/re-proposed-serving rollout would have
    # kept the old cost forever
    assert not recs[3].feasible
    assert recs[3].lam_hat == 60.0
    assert recs[3].cost > recs[2].cost
    # exactly one committed change, decided at t=8, applying at t=14 —
    # the hold must not have restarted (or cancelled) the rollout
    assert res.n_reconfigs == 1
    assert res.reconfig_log == [(8.0, 0, 14.0)]


def test_ragged_traces_supported():
    """Pipelines may stop receiving traffic at different times: a shorter
    trace must yield lam_true=0 intervals (not a zero-size .max() crash)
    and its demand estimate must drop to 0 so it stops competing for the
    shared pool."""
    cl = toy_cluster(cores=30.0)
    r_a = np.full(40, 5.0)
    r_b = np.full(15, 5.0)               # ends mid-run
    res = AD.run_cluster_trace(cl, [r_a, r_b],
                               policy="ipa",
                               obj=OPT.Objective(alpha=1.0, beta=0.05),
                               seed=2)
    assert len(res.per_pipeline[0].intervals) == \
        len(res.per_pipeline[1].intervals) == 4
    dead = res.per_pipeline[1].intervals[-1]
    assert dead.lam_true == 0.0
    assert dead.lam_hat == 0.0           # finished pipelines release demand
    for r in res.per_pipeline:
        assert r.completed + r.dropped == r.arrived
