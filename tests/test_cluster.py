"""Cluster co-scheduling: data model, shared-pool ledger, joint solver
(knapsack vs brute oracle), and the cluster adapter end-to-end."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import adapter as AD
from repro.core import baselines as BL
from repro.core import optimizer as OPT
from repro.core.cluster import (ClusterConfig, ClusterModel,
                                proportional_split)
from repro.core.pipeline import (ModelVariant, PipelineConfig, PipelineModel,
                                 StageConfig, StageModel)
from repro.core.simulator import ClusterSimulator, CoreBudgetExceeded
from repro.serving.request import Request


def toy_pipeline(name: str, l1: float = 0.05,
                 accs=(60.0, 75.0, 85.0)) -> PipelineModel:
    vs = tuple(
        ModelVariant(f"{name}_v{i}", a, 2 ** i,
                     (l1 * s * 0.002, l1 * s * 0.7, l1 * s * 0.3))
        for i, (a, s) in enumerate(zip(accs, (1.0, 1.7, 3.0))))
    return PipelineModel(name, (
        StageModel(f"{name}_s1", vs, sla=5 * l1 * 1.7, batch_choices=(1, 2, 4)),
        StageModel(f"{name}_s2", vs, sla=5 * l1 * 1.7, batch_choices=(1, 2, 4)),
    ))


def toy_cluster(cores: float = 40.0) -> ClusterModel:
    return ClusterModel("toy", (toy_pipeline("A"),
                                toy_pipeline("B", l1=0.03,
                                             accs=(55.0, 68.0, 90.0))), cores)


# ---------------------------------------------------------------------------
# data model
# ---------------------------------------------------------------------------
def test_cluster_config_cost_is_sum_of_pipelines():
    cl = toy_cluster()
    sol_a = OPT.solve_capped(cl.pipelines[0], 10.0, OPT.Objective())
    sol_b = OPT.solve_capped(cl.pipelines[1], 10.0, OPT.Objective())
    joint = ClusterConfig((sol_a.config, sol_b.config))
    assert joint.cost(cl) == pytest.approx(sol_a.cost + sol_b.cost)
    assert joint.fits(cl) == (sol_a.cost + sol_b.cost <= cl.cores + 1e-9)


def test_proportional_split_sums_to_budget():
    cl = toy_cluster(cores=30.0)
    shares = proportional_split(cl, [10.0, 20.0])
    assert sum(shares) == pytest.approx(30.0)
    assert shares[0] == pytest.approx(10.0)
    assert shares[1] == pytest.approx(20.0)
    # zero total demand: even split, not div-by-zero
    even = proportional_split(cl, [0.0, 0.0])
    assert even[0] == even[1] == pytest.approx(15.0)


# ---------------------------------------------------------------------------
# joint solver: knapsack arbitration vs brute-force oracle
# ---------------------------------------------------------------------------
@given(budget=st.integers(4, 60), lam_a=st.floats(1.0, 25.0),
       lam_b=st.floats(1.0, 25.0))
@settings(max_examples=20, deadline=None)
def test_knapsack_matches_brute_force(budget, lam_a, lam_b):
    cl = ClusterModel("toy", toy_cluster().pipelines, float(budget))
    obj = OPT.Objective(alpha=1.0, beta=0.05)
    k = OPT.solve_cluster(cl, [lam_a, lam_b], obj)
    b = OPT.solve_cluster_brute(cl, [lam_a, lam_b], obj)
    assert k.feasible == b.feasible
    if k.feasible:
        assert k.objective == pytest.approx(b.objective, rel=1e-9)
        assert k.cost <= budget + 1e-9
        assert k.config.fits(cl)


def test_joint_dominates_proportional_split():
    """The split's feasible set is a subset of the joint's: the knapsack
    objective can never be worse, and on asymmetric demand it is strictly
    better here."""
    cl = toy_cluster(cores=24.0)
    obj = OPT.Objective(alpha=1.0, beta=0.02)
    lams = [22.0, 4.0]                   # anti-correlated burst snapshot
    joint = BL.cluster_ipa(cl, lams, obj)
    split = BL.cluster_split(cl, lams, "ipa", obj)
    assert joint.feasible and split.feasible
    assert joint.objective >= split.objective - 1e-9
    assert joint.objective > split.objective + 1e-6


def test_pareto_frontier_is_strictly_improving():
    pipe = toy_pipeline("A")
    pts = OPT.pareto_frontier(pipe, 12.0, OPT.Objective(alpha=1.0, beta=0.05))
    assert pts, "frontier must be non-empty at a feasible rate"
    costs = [p.cost for p in pts]
    objs = [p.objective for p in pts]
    assert costs == sorted(costs)
    assert all(b > a for a, b in zip(costs, costs[1:]))
    assert all(b > a for a, b in zip(objs, objs[1:]))


def test_unbounded_budget_picks_per_pipeline_best():
    cl = ClusterModel("toy", toy_cluster().pipelines, float("inf"))
    obj = OPT.Objective(alpha=1.0, beta=0.05)
    sol = OPT.solve_cluster(cl, [10.0, 10.0], obj)
    for pipe, s in zip(cl.pipelines, sol.per_pipeline):
        best = OPT.pareto_frontier(pipe, 10.0, obj)[-1]
        assert s.objective == pytest.approx(best.objective)


# ---------------------------------------------------------------------------
# shared-pool replica ledger
# ---------------------------------------------------------------------------
def _fit_config(pipe, lam):
    sol = OPT.solve_capped(pipe, lam, OPT.Objective(alpha=0.0, beta=1.0))
    assert sol.feasible
    return sol.config


def test_reconfigure_over_budget_raises_and_changes_nothing():
    cl = toy_cluster(cores=8.0)
    cfg_a = _fit_config(cl.pipelines[0], 2.0)
    cfg_b = _fit_config(cl.pipelines[1], 2.0)
    sim = ClusterSimulator(cl, ClusterConfig((cfg_a, cfg_b)))
    before = sim.pipeline_config(0)
    # grow pipeline 0 far past what C minus pipeline 1's allocation allows
    big = PipelineConfig(tuple(
        StageConfig(sc.variant, sc.batch, sc.replicas + 50)
        for sc in cfg_a.stages))
    with pytest.raises(CoreBudgetExceeded):
        sim.reconfigure_pipeline(0, big)
    assert sim.pipeline_config(0) == before
    assert sim.allocated_cores <= cl.cores + 1e-9


def test_reconfigure_within_budget_updates_ledger():
    cl = toy_cluster(cores=40.0)
    cfg_a = _fit_config(cl.pipelines[0], 2.0)
    cfg_b = _fit_config(cl.pipelines[1], 2.0)
    sim = ClusterSimulator(cl, ClusterConfig((cfg_a, cfg_b)))
    start = sim.allocated_cores
    grown = PipelineConfig(tuple(
        StageConfig(sc.variant, sc.batch, sc.replicas + 1)
        for sc in cfg_a.stages))
    sim.reconfigure_pipeline(0, grown)
    assert sim.allocated_cores > start
    assert sim.current_config.fits(cl)


def test_initial_config_over_budget_rejected():
    cl = toy_cluster(cores=2.0)          # too small for two pipelines
    cfg_a = _fit_config(cl.pipelines[0], 10.0)
    cfg_b = _fit_config(cl.pipelines[1], 10.0)
    with pytest.raises(CoreBudgetExceeded):
        ClusterSimulator(cl, ClusterConfig((cfg_a, cfg_b)))


# ---------------------------------------------------------------------------
# shared event loop: per-pipeline isolation of metrics, shared clock
# ---------------------------------------------------------------------------
def test_two_pipelines_one_heap_conserve_requests_separately():
    cl = toy_cluster(cores=float("inf"))
    cfg_a = _fit_config(cl.pipelines[0], 12.0)
    cfg_b = _fit_config(cl.pipelines[1], 8.0)
    sim = ClusterSimulator(cl, ClusterConfig((cfg_a, cfg_b)))
    rng = np.random.default_rng(3)
    n_a, n_b = 120, 80
    for t in np.sort(rng.uniform(0, 10, n_a)):
        sim.inject(Request(arrival=float(t), sla=cl.pipelines[0].sla), 0)
    for t in np.sort(rng.uniform(0, 10, n_b)):
        sim.inject(Request(arrival=float(t), sla=cl.pipelines[1].sla), 1)
    sim.run_until(10 + 100 * max(sim.sla_of))
    ma, mb = sim.metrics_by_pipe
    assert ma.arrived == n_a and mb.arrived == n_b
    assert ma.completed + ma.dropped == n_a
    assert mb.completed + mb.dropped == n_b
    assert sim.queued == 0 and sim.in_service == 0
    assert len(ma.latencies) == ma.completed
    assert len(mb.latencies) == mb.completed


def test_per_pipeline_lam_est_independent():
    cl = toy_cluster(cores=float("inf"))
    sim = ClusterSimulator(cl, ClusterConfig((
        _fit_config(cl.pipelines[0], 5.0), _fit_config(cl.pipelines[1], 5.0))))
    sim.set_lam_est(0, 50.0)
    assert sim._lam_of == [50.0, 10.0]


# ---------------------------------------------------------------------------
# cluster adapter end-to-end
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def cluster_results():
    cl = toy_cluster(cores=26.0)
    t = np.arange(60, dtype=np.float64)
    # anti-correlated: A bursts first half, B second half
    r_a = np.clip(4.0 + 18.0 * np.exp(-((t - 10) % 60) / 8.0), 0.5, None)
    r_b = np.clip(4.0 + 18.0 * np.exp(-((t - 40) % 60) / 8.0), 0.5, None)
    obj = OPT.Objective(alpha=1.0, beta=0.02)
    return obj, {pol: AD.run_cluster_trace(cl, [r_a, r_b], policy=pol,
                                           obj=obj, seed=5)
                 for pol in ("ipa", "split_ipa", "split_fa2_low")}


def test_cluster_trace_conserves_requests(cluster_results):
    _, results = cluster_results
    for res in results.values():
        for r in res.per_pipeline:
            assert r.completed + r.dropped == r.arrived
        assert res.arrived == sum(r.arrived for r in res.per_pipeline)


def test_cluster_trace_stays_within_budget(cluster_results):
    _, results = cluster_results
    for res in results.values():
        for records in zip(*(r.intervals for r in res.per_pipeline)):
            assert sum(rec.cost for rec in records) <= res.budget + 1e-9


def test_joint_beats_split_on_objective_end_to_end(cluster_results):
    obj, results = cluster_results
    joint = results["ipa"].mean_objective(obj)
    assert joint >= results["split_ipa"].mean_objective(obj) - 1e-6
    assert joint >= results["split_fa2_low"].mean_objective(obj) - 1e-6


def test_joint_beats_split_on_pas_end_to_end(cluster_results):
    _, results = cluster_results
    assert results["ipa"].mean_pas > results["split_ipa"].mean_pas - 1e-9


def test_ragged_traces_supported():
    """Pipelines may stop receiving traffic at different times: a shorter
    trace must yield lam_true=0 intervals (not a zero-size .max() crash)
    and its demand estimate must drop to 0 so it stops competing for the
    shared pool."""
    cl = toy_cluster(cores=30.0)
    r_a = np.full(40, 5.0)
    r_b = np.full(15, 5.0)               # ends mid-run
    res = AD.run_cluster_trace(cl, [r_a, r_b],
                               policy="ipa",
                               obj=OPT.Objective(alpha=1.0, beta=0.05),
                               seed=2)
    assert len(res.per_pipeline[0].intervals) == \
        len(res.per_pipeline[1].intervals) == 4
    dead = res.per_pipeline[1].intervals[-1]
    assert dead.lam_true == 0.0
    assert dead.lam_hat == 0.0           # finished pipelines release demand
    for r in res.per_pipeline:
        assert r.completed + r.dropped == r.arrived
